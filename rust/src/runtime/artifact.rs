//! Artifact bundle loader: `manifest.json`, `weights.bin`,
//! `eval_tokens.bin` produced by `python/compile/aot.py`.

use crate::cfg::json::Json;
use crate::util::error::{anyhow, bail, Context, Result};
use std::io::Read;
use std::path::{Path, PathBuf};

/// One named tensor from `weights.bin`.
#[derive(Clone, Debug)]
pub struct Tensor {
    pub name: String,
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn len(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// Parsed artifact bundle.
#[derive(Debug)]
pub struct Bundle {
    pub dir: PathBuf,
    pub manifest: Json,
    /// Trained parameters in manifest order (the PJRT input order).
    pub params: Vec<Tensor>,
    /// Held-out evaluation tokens (byte-level).
    pub eval_tokens: Vec<u8>,
}

impl Bundle {
    /// Load the bundle from a directory.
    pub fn load(dir: &str) -> Result<Bundle> {
        let dir = PathBuf::from(dir);
        let manifest_text = std::fs::read_to_string(dir.join("manifest.json"))
            .with_context(|| format!("read {}/manifest.json", dir.display()))?;
        let manifest = Json::parse(&manifest_text).map_err(|e| anyhow!("manifest: {e}"))?;
        let params = read_weights(&dir.join("weights.bin"))?;
        // cross-check against the manifest's declared order
        let declared = manifest
            .req("params")
            .map_err(|e| anyhow!(e))?
            .as_arr()
            .ok_or_else(|| anyhow!("manifest params must be an array"))?;
        if declared.len() != params.len() {
            bail!(
                "manifest declares {} params, weights.bin has {}",
                declared.len(),
                params.len()
            );
        }
        for (d, t) in declared.iter().zip(params.iter()) {
            let name = d.req("name").map_err(|e| anyhow!(e))?.as_str().unwrap_or("");
            if name != t.name {
                bail!("param order mismatch: manifest {name} vs weights {}", t.name);
            }
        }
        let eval_tokens = read_tokens(&dir.join("eval_tokens.bin"))?;
        Ok(Bundle {
            dir,
            manifest,
            params,
            eval_tokens,
        })
    }

    /// Path of a named HLO artifact.
    pub fn hlo_path(&self, name: &str) -> PathBuf {
        self.dir.join(format!("{name}.hlo.txt"))
    }

    /// Model config value from the manifest.
    pub fn config_usize(&self, key: &str) -> Result<usize> {
        self.manifest
            .req("config")
            .and_then(|c| c.req(key))
            .map_err(|e| anyhow!(e))?
            .as_usize()
            .ok_or_else(|| anyhow!("config.{key} must be a uint"))
    }

    /// Find a parameter by manifest name.
    pub fn param(&self, name: &str) -> Option<&Tensor> {
        self.params.iter().find(|t| t.name == name)
    }
}

/// Read the `SPX1` weights container.
pub fn read_weights(path: &Path) -> Result<Vec<Tensor>> {
    let mut f = std::fs::File::open(path).with_context(|| format!("open {path:?}"))?;
    let mut magic = [0u8; 4];
    f.read_exact(&mut magic)?;
    if &magic != b"SPX1" {
        bail!("{path:?}: bad magic {magic:?}");
    }
    let count = read_u32(&mut f)? as usize;
    let mut out = Vec::with_capacity(count);
    for _ in 0..count {
        let name_len = read_u16(&mut f)? as usize;
        let mut name = vec![0u8; name_len];
        f.read_exact(&mut name)?;
        let ndim = read_u8(&mut f)? as usize;
        let mut shape = Vec::with_capacity(ndim);
        for _ in 0..ndim {
            shape.push(read_u32(&mut f)? as usize);
        }
        let n: usize = shape.iter().product();
        let mut bytes = vec![0u8; n * 4];
        f.read_exact(&mut bytes)?;
        let data = bytes
            .chunks_exact(4)
            .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
            .collect();
        out.push(Tensor {
            name: String::from_utf8(name).context("tensor name utf8")?,
            shape,
            data,
        });
    }
    Ok(out)
}

/// Read the `SPT1` token container.
pub fn read_tokens(path: &Path) -> Result<Vec<u8>> {
    let mut f = std::fs::File::open(path).with_context(|| format!("open {path:?}"))?;
    let mut magic = [0u8; 4];
    f.read_exact(&mut magic)?;
    if &magic != b"SPT1" {
        bail!("{path:?}: bad magic");
    }
    let count = read_u32(&mut f)? as usize;
    let mut tokens = vec![0u8; count];
    f.read_exact(&mut tokens)?;
    Ok(tokens)
}

fn read_u8(f: &mut impl Read) -> Result<u8> {
    let mut b = [0u8; 1];
    f.read_exact(&mut b)?;
    Ok(b[0])
}

fn read_u16(f: &mut impl Read) -> Result<u16> {
    let mut b = [0u8; 2];
    f.read_exact(&mut b)?;
    Ok(u16::from_le_bytes(b))
}

fn read_u32(f: &mut impl Read) -> Result<u32> {
    let mut b = [0u8; 4];
    f.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;

    fn write_test_weights(path: &Path, tensors: &[(&str, Vec<usize>, Vec<f32>)]) {
        let mut f = std::fs::File::create(path).unwrap();
        f.write_all(b"SPX1").unwrap();
        f.write_all(&(tensors.len() as u32).to_le_bytes()).unwrap();
        for (name, shape, data) in tensors {
            f.write_all(&(name.len() as u16).to_le_bytes()).unwrap();
            f.write_all(name.as_bytes()).unwrap();
            f.write_all(&[shape.len() as u8]).unwrap();
            for d in shape {
                f.write_all(&(*d as u32).to_le_bytes()).unwrap();
            }
            for x in data {
                f.write_all(&x.to_le_bytes()).unwrap();
            }
        }
    }

    #[test]
    fn weights_roundtrip() {
        let dir = std::env::temp_dir().join("sparamx_test_weights");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("w.bin");
        write_test_weights(
            &path,
            &[
                ("emb", vec![4, 2], (0..8).map(|i| i as f32).collect()),
                ("scalar", vec![], vec![7.5]),
            ],
        );
        let ts = read_weights(&path).unwrap();
        assert_eq!(ts.len(), 2);
        assert_eq!(ts[0].name, "emb");
        assert_eq!(ts[0].shape, vec![4, 2]);
        assert_eq!(ts[0].data[7], 7.0);
        assert_eq!(ts[1].data, vec![7.5]);
    }

    #[test]
    fn rejects_bad_magic() {
        let dir = std::env::temp_dir().join("sparamx_test_badmagic");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.bin");
        std::fs::write(&path, b"NOPE....").unwrap();
        assert!(read_weights(&path).is_err());
        assert!(read_tokens(&path).is_err());
    }

    #[test]
    fn tokens_roundtrip() {
        let dir = std::env::temp_dir().join("sparamx_test_tokens");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("t.bin");
        let mut f = std::fs::File::create(&path).unwrap();
        f.write_all(b"SPT1").unwrap();
        f.write_all(&3u32.to_le_bytes()).unwrap();
        f.write_all(&[10, 20, 30]).unwrap();
        drop(f);
        assert_eq!(read_tokens(&path).unwrap(), vec![10, 20, 30]);
    }
}
