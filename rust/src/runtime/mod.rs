//! PJRT runtime: loads AOT HLO-text artifacts and executes them.
pub mod artifact;
pub mod executor;
