//! PJRT executor: load HLO-text artifacts, compile once on the CPU
//! client, execute from the Rust hot path.
//!
//! Python never runs here — the `.hlo.txt` files were lowered once at
//! build time (`make artifacts`). Pattern follows
//! `/opt/xla-example/load_hlo/`.
//!
//! The real executor needs the `xla` (xla-rs) bindings, which are not
//! part of the offline build. It is therefore gated behind the `pjrt`
//! cargo feature (supply a vendored `xla` crate to enable it); the
//! default build compiles an API-compatible stub whose `Runtime::cpu()`
//! fails with a clear message. The artifact integration tests skip when
//! artifacts are missing *or* the stub is active, so `cargo test` stays
//! green on machines without the bindings.

#[cfg(feature = "pjrt")]
mod imp {
    use crate::util::error::{ensure, Context, Result};
    use std::path::Path;

    pub use xla::Literal;

    /// Shared PJRT CPU client (one per process).
    pub struct Runtime {
        client: xla::PjRtClient,
    }

    impl Runtime {
        /// Create the CPU PJRT client.
        pub fn cpu() -> Result<Runtime> {
            Ok(Runtime {
                client: xla::PjRtClient::cpu().context("create PJRT CPU client")?,
            })
        }

        pub fn platform(&self) -> String {
            self.client.platform_name()
        }

        /// Load + compile an HLO-text artifact.
        pub fn load_hlo(&self, path: &Path) -> Result<Executable> {
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().context("artifact path must be utf-8")?,
            )
            .with_context(|| format!("parse HLO text {path:?}"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .with_context(|| format!("compile {path:?}"))?;
            Ok(Executable {
                exe,
                name: path
                    .file_name()
                    .map(|s| s.to_string_lossy().into_owned())
                    .unwrap_or_default(),
            })
        }
    }

    /// A compiled computation ready to execute.
    pub struct Executable {
        exe: xla::PjRtLoadedExecutable,
        pub name: String,
    }

    impl Executable {
        /// Execute with literal inputs; returns the flattened output tuple
        /// (aot.py lowers everything with `return_tuple=True`).
        pub fn run(&self, inputs: &[Literal]) -> Result<Vec<Literal>> {
            let result = self
                .exe
                .execute::<Literal>(inputs)
                .with_context(|| format!("execute {}", self.name))?[0][0]
                .to_literal_sync()?;
            Ok(result.to_tuple()?)
        }
    }

    /// Build an f32 literal of the given shape.
    pub fn lit_f32(data: &[f32], dims: &[i64]) -> Result<Literal> {
        let n: i64 = dims.iter().product();
        ensure!(n as usize == data.len(), "shape/data mismatch");
        Ok(Literal::vec1(data).reshape(dims)?)
    }

    /// Build an i32 literal of the given shape.
    pub fn lit_i32(data: &[i32], dims: &[i64]) -> Result<Literal> {
        let n: i64 = dims.iter().product();
        ensure!(n as usize == data.len(), "shape/data mismatch");
        Ok(Literal::vec1(data).reshape(dims)?)
    }

    /// Build a u32 literal of the given shape.
    pub fn lit_u32(data: &[u32], dims: &[i64]) -> Result<Literal> {
        let n: i64 = dims.iter().product();
        ensure!(n as usize == data.len(), "shape/data mismatch");
        Ok(Literal::vec1(data).reshape(dims)?)
    }

    /// Build an i8 literal of the given shape.
    pub fn lit_i8(data: &[i8], dims: &[i64]) -> Result<Literal> {
        let n: i64 = dims.iter().product();
        ensure!(n as usize == data.len(), "shape/data mismatch");
        let bytes: Vec<u8> = data.iter().map(|&x| x as u8).collect();
        let lit = Literal::create_from_shape_and_untyped_data(
            xla::ElementType::S8,
            &dims.iter().map(|&d| d as usize).collect::<Vec<_>>(),
            &bytes,
        )?;
        Ok(lit)
    }

    /// Extract an f32 vector from a literal.
    pub fn to_f32(lit: &Literal) -> Result<Vec<f32>> {
        Ok(lit.to_vec::<f32>()?)
    }

    /// Extract an i32 vector from a literal.
    pub fn to_i32(lit: &Literal) -> Result<Vec<i32>> {
        Ok(lit.to_vec::<i32>()?)
    }
}

#[cfg(not(feature = "pjrt"))]
mod imp {
    use crate::util::error::{bail, ensure, Result};
    use std::path::Path;

    /// Host-side literal: typed data plus shape. The stub keeps enough
    /// structure that literal construction and extraction round-trip, so
    /// code that only marshals data (no execution) works unchanged.
    #[derive(Clone, Debug)]
    pub struct Literal {
        data: LitData,
        dims: Vec<i64>,
    }

    #[derive(Clone, Debug)]
    enum LitData {
        F32(Vec<f32>),
        I32(Vec<i32>),
        U32(Vec<u32>),
        I8(Vec<i8>),
    }

    /// Stub runtime: construction fails so callers surface a clear error
    /// instead of silently producing garbage.
    pub struct Runtime {
        _priv: (),
    }

    impl Runtime {
        pub fn cpu() -> Result<Runtime> {
            bail!(
                "built without the `pjrt` feature: the PJRT executor is \
                 unavailable (rebuild with `--features pjrt` and a vendored \
                 `xla` crate to execute AOT artifacts)"
            )
        }

        pub fn platform(&self) -> String {
            "stub".into()
        }

        pub fn load_hlo(&self, path: &Path) -> Result<Executable> {
            bail!("pjrt stub: cannot load {path:?}")
        }
    }

    /// Stub executable (never constructed; `Runtime::cpu()` fails first).
    pub struct Executable {
        pub name: String,
    }

    impl Executable {
        pub fn run(&self, _inputs: &[Literal]) -> Result<Vec<Literal>> {
            bail!("pjrt stub: cannot execute {}", self.name)
        }
    }

    fn check_shape(len: usize, dims: &[i64]) -> Result<()> {
        let n: i64 = dims.iter().product();
        ensure!(n as usize == len, "shape/data mismatch");
        Ok(())
    }

    /// Build an f32 literal of the given shape.
    pub fn lit_f32(data: &[f32], dims: &[i64]) -> Result<Literal> {
        check_shape(data.len(), dims)?;
        Ok(Literal {
            data: LitData::F32(data.to_vec()),
            dims: dims.to_vec(),
        })
    }

    /// Build an i32 literal of the given shape.
    pub fn lit_i32(data: &[i32], dims: &[i64]) -> Result<Literal> {
        check_shape(data.len(), dims)?;
        Ok(Literal {
            data: LitData::I32(data.to_vec()),
            dims: dims.to_vec(),
        })
    }

    /// Build a u32 literal of the given shape.
    pub fn lit_u32(data: &[u32], dims: &[i64]) -> Result<Literal> {
        check_shape(data.len(), dims)?;
        Ok(Literal {
            data: LitData::U32(data.to_vec()),
            dims: dims.to_vec(),
        })
    }

    /// Build an i8 literal of the given shape.
    pub fn lit_i8(data: &[i8], dims: &[i64]) -> Result<Literal> {
        check_shape(data.len(), dims)?;
        Ok(Literal {
            data: LitData::I8(data.to_vec()),
            dims: dims.to_vec(),
        })
    }

    /// Extract an f32 vector from a literal.
    pub fn to_f32(lit: &Literal) -> Result<Vec<f32>> {
        match &lit.data {
            LitData::F32(v) => Ok(v.clone()),
            other => bail!("literal is not f32: {other:?}"),
        }
    }

    /// Extract an i32 vector from a literal.
    pub fn to_i32(lit: &Literal) -> Result<Vec<i32>> {
        match &lit.data {
            LitData::I32(v) => Ok(v.clone()),
            other => bail!("literal is not i32: {other:?}"),
        }
    }

    #[cfg(test)]
    mod tests {
        use super::*;

        #[test]
        fn stub_runtime_fails_loudly() {
            let e = Runtime::cpu().unwrap_err();
            assert!(e.to_string().contains("pjrt"), "{e}");
        }

        #[test]
        fn stub_literals_roundtrip() {
            let l = lit_f32(&[1.0, 2.0, 3.0, 4.0], &[2, 2]).unwrap();
            assert_eq!(to_f32(&l).unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
            assert!(to_i32(&l).is_err());
            assert!(lit_i32(&[1, 2, 3], &[2, 2]).is_err(), "shape mismatch");
        }
    }
}

pub use imp::*;
