//! PJRT executor: load HLO-text artifacts, compile once on the CPU
//! client, execute from the Rust hot path.
//!
//! Python never runs here — the `.hlo.txt` files were lowered once at
//! build time (`make artifacts`). Pattern follows
//! `/opt/xla-example/load_hlo/`.

use anyhow::{Context, Result};
use std::path::Path;

/// Shared PJRT CPU client (one per process).
pub struct Runtime {
    client: xla::PjRtClient,
}

impl Runtime {
    /// Create the CPU PJRT client.
    pub fn cpu() -> Result<Runtime> {
        Ok(Runtime {
            client: xla::PjRtClient::cpu().context("create PJRT CPU client")?,
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile an HLO-text artifact.
    pub fn load_hlo(&self, path: &Path) -> Result<Executable> {
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("artifact path must be utf-8")?,
        )
        .with_context(|| format!("parse HLO text {path:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compile {path:?}"))?;
        Ok(Executable {
            exe,
            name: path
                .file_name()
                .map(|s| s.to_string_lossy().into_owned())
                .unwrap_or_default(),
        })
    }
}

/// A compiled computation ready to execute.
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
    pub name: String,
}

impl Executable {
    /// Execute with literal inputs; returns the flattened output tuple
    /// (aot.py lowers everything with `return_tuple=True`).
    pub fn run(&self, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let result = self
            .exe
            .execute::<xla::Literal>(inputs)
            .with_context(|| format!("execute {}", self.name))?[0][0]
            .to_literal_sync()?;
        Ok(result.to_tuple()?)
    }
}

/// Build an f32 literal of the given shape.
pub fn lit_f32(data: &[f32], dims: &[i64]) -> Result<xla::Literal> {
    let n: i64 = dims.iter().product();
    anyhow::ensure!(n as usize == data.len(), "shape/data mismatch");
    Ok(xla::Literal::vec1(data).reshape(dims)?)
}

/// Build an i32 literal of the given shape.
pub fn lit_i32(data: &[i32], dims: &[i64]) -> Result<xla::Literal> {
    let n: i64 = dims.iter().product();
    anyhow::ensure!(n as usize == data.len(), "shape/data mismatch");
    Ok(xla::Literal::vec1(data).reshape(dims)?)
}

/// Build a u32 literal of the given shape.
pub fn lit_u32(data: &[u32], dims: &[i64]) -> Result<xla::Literal> {
    let n: i64 = dims.iter().product();
    anyhow::ensure!(n as usize == data.len(), "shape/data mismatch");
    Ok(xla::Literal::vec1(data).reshape(dims)?)
}

/// Build an i8 literal of the given shape.
pub fn lit_i8(data: &[i8], dims: &[i64]) -> Result<xla::Literal> {
    let n: i64 = dims.iter().product();
    anyhow::ensure!(n as usize == data.len(), "shape/data mismatch");
    let bytes: Vec<u8> = data.iter().map(|&x| x as u8).collect();
    let lit = xla::Literal::create_from_shape_and_untyped_data(
        xla::ElementType::S8,
        &dims.iter().map(|&d| d as usize).collect::<Vec<_>>(),
        &bytes,
    )?;
    Ok(lit)
}

/// Extract an f32 vector from a literal.
pub fn to_f32(lit: &xla::Literal) -> Result<Vec<f32>> {
    Ok(lit.to_vec::<f32>()?)
}

/// Extract an i32 vector from a literal.
pub fn to_i32(lit: &xla::Literal) -> Result<Vec<i32>> {
    Ok(lit.to_vec::<i32>()?)
}
