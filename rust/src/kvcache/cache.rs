//! The §6.2 KV-cache layout: a **static sparse segment** (the prefilled
//! context, magnitude-pruned and packed in the SparAMX format, constant
//! size, stored in model state like weights) plus a **dynamic dense
//! tail** (tokens generated after prefill, appended without touching the
//! static segment).
//!
//! PyTorch's stock path reallocates the whole cache every token
//! (`torch.cat`) and materializes `repeat_kv` for GQA; this layout avoids
//! both, which is where the paper's ">6× faster decoding" at long
//! context comes from. [`NaiveCache`] models the stock behaviour for the
//! §6.2 benchmark.

use crate::sparse::format::SparseTensor;
use crate::sparse::prune::magnitude_prune;
use crate::util::bf16::round_f32;

/// Per-(layer, kv-head) cache: sparse static segment + dense tail.
///
/// `PartialEq` is bit-exact over both segments — the equality the
/// checkpoint round-trip tests assert.
#[derive(Clone, Debug, PartialEq)]
pub struct HeadCache {
    /// Kᵀ of the prefilled context: `head_dim × n_static` (inner dim ×
    /// "neurons"), so QKᵀ maps onto the sparse GEMM directly.
    pub k_static: SparseTensor,
    /// V of the prefilled context: `n_static × head_dim`.
    pub v_static: SparseTensor,
    /// Dynamic K rows, `[t][head_dim]` row-major.
    pub k_dyn: Vec<f32>,
    /// Dynamic V rows, `[t][head_dim]` row-major.
    pub v_dyn: Vec<f32>,
    pub head_dim: usize,
    /// Tokens in the static segment.
    pub n_static: usize,
}

impl HeadCache {
    /// Build from prefilled K/V (`ctx × head_dim`, row-major, one row per
    /// token), pruning K at `k_sparsity` and V at `v_sparsity`
    /// (magnitude, within this head — §6.1).
    pub fn from_prefill(
        k: &[f32],
        v: &[f32],
        ctx: usize,
        head_dim: usize,
        k_sparsity: f64,
        v_sparsity: f64,
    ) -> HeadCache {
        assert_eq!(k.len(), ctx * head_dim);
        assert_eq!(v.len(), ctx * head_dim);
        let kp = magnitude_prune(k, k_sparsity);
        let vp = magnitude_prune(v, v_sparsity);
        // transpose K to head_dim × ctx for the QKᵀ GEMM mapping
        let mut kt = vec![0f32; head_dim * ctx];
        for t in 0..ctx {
            for d in 0..head_dim {
                kt[d * ctx + t] = kp[t * head_dim + d];
            }
        }
        HeadCache {
            k_static: SparseTensor::pack_f32(&kt, head_dim, ctx),
            v_static: SparseTensor::pack_f32(&vp, ctx, head_dim),
            k_dyn: Vec::new(),
            v_dyn: Vec::new(),
            head_dim,
            n_static: ctx,
        }
    }

    /// Append one generated token's K/V rows to the dynamic tail —
    /// O(head_dim), no reallocation of the static segment.
    pub fn append(&mut self, k_row: &[f32], v_row: &[f32]) {
        assert_eq!(k_row.len(), self.head_dim);
        assert_eq!(v_row.len(), self.head_dim);
        self.k_dyn.extend(k_row.iter().map(|&x| round_f32(x)));
        self.v_dyn.extend(v_row.iter().map(|&x| round_f32(x)));
    }

    /// Total tokens visible to attention.
    pub fn len(&self) -> usize {
        self.n_static + self.k_dyn.len() / self.head_dim
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Dynamic-tail token count.
    pub fn dyn_len(&self) -> usize {
        self.k_dyn.len() / self.head_dim
    }

    /// Bytes held by the cache (sparse static + dense tail, BF16 tail
    /// assumed 2 bytes/elem as the engine stores it).
    pub fn bytes(&self) -> usize {
        self.k_static.bytes_sparse()
            + self.v_static.bytes_sparse()
            + (self.k_dyn.len() + self.v_dyn.len()) * 2
    }
}

/// Whole-model cache: `layers × kv_heads` head caches.
#[derive(Clone, Debug, PartialEq)]
pub struct KvCache {
    pub heads: Vec<Vec<HeadCache>>, // [layer][kv_head]
    pub kv_heads: usize,
}

impl KvCache {
    /// Build from per-layer, per-head prefill tensors via a closure
    /// yielding `(k, v)` for each (layer, head).
    pub fn from_prefill<F>(
        layers: usize,
        kv_heads: usize,
        ctx: usize,
        head_dim: usize,
        k_sparsity: f64,
        v_sparsity: f64,
        mut kv_for: F,
    ) -> KvCache
    where
        F: FnMut(usize, usize) -> (Vec<f32>, Vec<f32>),
    {
        let heads = (0..layers)
            .map(|l| {
                (0..kv_heads)
                    .map(|h| {
                        let (k, v) = kv_for(l, h);
                        HeadCache::from_prefill(&k, &v, ctx, head_dim, k_sparsity, v_sparsity)
                    })
                    .collect()
            })
            .collect();
        KvCache { heads, kv_heads }
    }

    /// The head cache serving query head `q_head` of `q_heads` total
    /// (GQA mapping — no materialized `repeat_kv`).
    pub fn head_for_query(&self, layer: usize, q_head: usize, q_heads: usize) -> &HeadCache {
        let group = q_heads / self.kv_heads;
        &self.heads[layer][q_head / group]
    }

    /// Total cache bytes.
    pub fn bytes(&self) -> usize {
        self.heads.iter().flatten().map(|h| h.bytes()).sum()
    }
}

/// One fused-attention work unit: a (slot, kv-head) [`HeadCache`] whose
/// static segment is shared by that slot's GQA group of query heads —
/// the rows `attend_sparse_batched` gathers into one activation block.
#[derive(Debug)]
pub struct HeadGroup<'a> {
    /// Row index into the co-resident batch (ascending slot order).
    pub slot: usize,
    /// KV head this group attends through.
    pub kv_head: usize,
    /// The shared split cache for this (slot, kv-head).
    pub cache: &'a HeadCache,
}

/// Layer-major view over co-resident slots' caches: every (slot,
/// kv-head) [`HeadCache`] of layer `layer`, slot-major and
/// kv-head-minor — the gather list the fused attention path walks (and
/// the shard worker pool scatters; groups are mutually independent, so
/// any execution order is bit-exact). Slots may hold caches of
/// different context lengths; each group carries its own segment.
pub fn layer_head_groups<'a>(
    caches: &'a [&'a mut KvCache],
    layer: usize,
) -> Vec<HeadGroup<'a>> {
    let mut groups = Vec::with_capacity(caches.len() * caches.first().map_or(0, |c| c.kv_heads));
    for (slot, cache) in caches.iter().enumerate() {
        for (kv_head, hc) in cache.heads[layer].iter().enumerate() {
            groups.push(HeadGroup {
                slot,
                kv_head,
                cache: hc,
            });
        }
    }
    groups
}

/// The stock-PyTorch cache behaviour for the §6.2 comparison: every
/// appended token reallocates and copies the full cache (torch.cat), and
/// each attention call materializes the GQA repeat.
#[derive(Clone, Debug, Default)]
pub struct NaiveCache {
    pub k: Vec<f32>, // ctx × head_dim
    pub v: Vec<f32>,
    pub head_dim: usize,
}

impl NaiveCache {
    pub fn new(k: Vec<f32>, v: Vec<f32>, head_dim: usize) -> NaiveCache {
        NaiveCache { k, v, head_dim }
    }

    /// torch.cat-style append: allocate new buffers and copy everything.
    pub fn append_realloc(&mut self, k_row: &[f32], v_row: &[f32]) {
        let mut nk = Vec::with_capacity(self.k.len() + self.head_dim);
        nk.extend_from_slice(&self.k);
        nk.extend_from_slice(k_row);
        let mut nv = Vec::with_capacity(self.v.len() + self.head_dim);
        nv.extend_from_slice(&self.v);
        nv.extend_from_slice(v_row);
        self.k = nk;
        self.v = nv;
    }

    /// Materialize the `repeat_kv` expansion for `group` query heads —
    /// the copy stock Llama GQA attention performs each step.
    pub fn repeat_kv(&self, group: usize) -> (Vec<f32>, Vec<f32>) {
        let mut k = Vec::with_capacity(self.k.len() * group);
        let mut v = Vec::with_capacity(self.v.len() * group);
        for _ in 0..group {
            k.extend_from_slice(&self.k);
            v.extend_from_slice(&self.v);
        }
        (k, v)
    }

    pub fn len(&self) -> usize {
        self.k.len() / self.head_dim.max(1)
    }

    pub fn is_empty(&self) -> bool {
        self.k.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::XorShift;

    fn rand_kv(ctx: usize, d: usize, seed: u64) -> (Vec<f32>, Vec<f32>) {
        let mut g = XorShift::new(seed);
        (g.normal_vec(ctx * d, 1.0), g.normal_vec(ctx * d, 1.0))
    }

    #[test]
    fn prefill_prunes_to_requested_sparsity() {
        let (k, v) = rand_kv(64, 32, 1);
        let hc = HeadCache::from_prefill(&k, &v, 64, 32, 0.3, 0.5);
        assert!((hc.k_static.sparsity() - 0.3).abs() < 0.02);
        assert!((hc.v_static.sparsity() - 0.5).abs() < 0.02);
        assert_eq!(hc.len(), 64);
        assert_eq!(hc.dyn_len(), 0);
    }

    #[test]
    fn k_is_stored_transposed() {
        let (k, v) = rand_kv(16, 8, 2);
        let hc = HeadCache::from_prefill(&k, &v, 16, 8, 0.0, 0.0);
        assert_eq!(hc.k_static.rows, 8); // head_dim
        assert_eq!(hc.k_static.cols, 16); // ctx
        assert_eq!(hc.v_static.rows, 16);
        assert_eq!(hc.v_static.cols, 8);
        // spot-check transposition via dense reconstruction
        let kt = hc.k_static.to_dense_f32();
        assert_eq!(kt[0 * 16 + 3], round_f32(k[3 * 8 + 0]));
    }

    #[test]
    fn append_grows_only_the_tail() {
        let (k, v) = rand_kv(32, 16, 3);
        let mut hc = HeadCache::from_prefill(&k, &v, 32, 16, 0.3, 0.5);
        let before = hc.k_static.nnz();
        hc.append(&vec![1.0; 16], &vec![2.0; 16]);
        hc.append(&vec![3.0; 16], &vec![4.0; 16]);
        assert_eq!(hc.len(), 34);
        assert_eq!(hc.dyn_len(), 2);
        assert_eq!(hc.k_static.nnz(), before, "static segment untouched");
    }

    #[test]
    fn gqa_head_mapping() {
        let cache = KvCache::from_prefill(2, 2, 8, 4, 0.0, 0.0, |l, h| {
            let val = (l * 10 + h) as f32 + 1.0;
            (vec![val; 32], vec![val; 32])
        });
        // 8 query heads over 2 kv heads → group of 4
        let hc = cache.head_for_query(1, 5, 8);
        // query head 5 → kv head 1 → value 1*10 + 1 + 1 = 12.0
        assert_eq!(hc.v_static.to_dense_f32()[0], 12.0);
    }

    #[test]
    fn layer_view_walks_slots_then_kv_heads() {
        // 2 layers × 2 kv heads, 3 slots with distinct context lengths
        let mut caches: Vec<KvCache> = (0..3)
            .map(|s| {
                let ctx = 4 + s; // unequal static segments per slot
                KvCache::from_prefill(2, 2, ctx, 4, 0.0, 0.0, |l, h| {
                    let val = (s * 100 + l * 10 + h) as f32 + 1.0;
                    (vec![val; ctx * 4], vec![val; ctx * 4])
                })
            })
            .collect();
        let refs: Vec<&mut KvCache> = caches.iter_mut().collect();
        let groups = layer_head_groups(&refs, 1);
        assert_eq!(groups.len(), 3 * 2, "slots × kv_heads per layer");
        // slot-major, kv-head-minor order
        let order: Vec<(usize, usize)> = groups.iter().map(|g| (g.slot, g.kv_head)).collect();
        assert_eq!(order, vec![(0, 0), (0, 1), (1, 0), (1, 1), (2, 0), (2, 1)]);
        // each group exposes its own slot's segment, layer-selected
        for g in &groups {
            assert_eq!(g.cache.n_static, 4 + g.slot, "slot geometry preserved");
            let want = (g.slot * 100 + 10 + g.kv_head) as f32 + 1.0;
            assert_eq!(g.cache.v_static.to_dense_f32()[0], want);
        }
    }

    #[test]
    fn naive_cache_append_copies() {
        let mut nc = NaiveCache::new(vec![1.0; 8], vec![2.0; 8], 4);
        nc.append_realloc(&[9.0; 4], &[8.0; 4]);
        assert_eq!(nc.len(), 3);
        assert_eq!(nc.k[8], 9.0);
        let (rk, _) = nc.repeat_kv(4);
        assert_eq!(rk.len(), nc.k.len() * 4);
    }

    #[test]
    fn cache_bytes_shrink_with_sparsity() {
        let (k, v) = rand_kv(256, 64, 4);
        let dense = HeadCache::from_prefill(&k, &v, 256, 64, 0.0, 0.0);
        let sparse = HeadCache::from_prefill(&k, &v, 256, 64, 0.5, 0.5);
        assert!(sparse.bytes() < dense.bytes());
    }
}
