//! §6.2 KV-cache manager (static sparse + dynamic dense tail).
pub mod cache;
pub mod attention;
