//! Decode attention over the split cache (paper §6): the static segment's
//! QKᵀ and R·V matmuls run through the configured [`Backend`]'s sparse
//! kernel; the dynamic tail is dense (it is small and changes every
//! token, so compressing it would cost more than it saves — §7 "not
//! suitable for dynamic KV").

use super::cache::HeadCache;
use crate::amx::EventCounters;
use crate::backend::{Backend, RefBackend};
use crate::util::bf16::round_f32;

/// Numerically-stable softmax in place.
pub fn softmax(xs: &mut [f32]) {
    if xs.is_empty() {
        return;
    }
    let max = xs.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let mut sum = 0.0;
    for x in xs.iter_mut() {
        *x = (*x - max).exp();
        sum += *x;
    }
    if sum > 0.0 {
        for x in xs.iter_mut() {
            *x /= sum;
        }
    }
}

/// One query head's decode attention over a [`HeadCache`], running the
/// static segment through `backend`'s sparse kernel. Returns the
/// `head_dim` output and ticks `ctr` with the kernel events (for the
/// Fig 15 cost model).
pub fn attend_sparse(
    hc: &HeadCache,
    q: &[f32],
    backend: &Backend,
    ctr: &mut EventCounters,
) -> Vec<f32> {
    assert_eq!(q.len(), hc.head_dim);
    let scale = 1.0 / (hc.head_dim as f32).sqrt();
    let n_static = hc.n_static;
    let n_dyn = hc.dyn_len();
    let mut scores = vec![0f32; n_static + n_dyn];

    // QKᵀ static: q (1 × head_dim) × Kᵀ (head_dim × n_static), sparse
    if n_static > 0 {
        let s = backend.sparse_gemm_bf16(q, 1, &hc.k_static, ctr);
        scores[..n_static].copy_from_slice(&s);
    }
    // QKᵀ dynamic tail: dense dot products
    for t in 0..n_dyn {
        let row = &hc.k_dyn[t * hc.head_dim..(t + 1) * hc.head_dim];
        let mut acc = 0.0;
        for d in 0..hc.head_dim {
            acc += round_f32(q[d]) * row[d];
        }
        scores[n_static + t] = acc;
        ctr.input_bytes += (hc.head_dim * 2) as u64;
        ctr.avx_fma += hc.head_dim.div_ceil(32) as u64;
    }
    for s in scores.iter_mut() {
        *s *= scale;
    }
    softmax(&mut scores);

    // R·V static: r (1 × n_static) × V (n_static × head_dim), sparse
    let mut out = vec![0f32; hc.head_dim];
    if n_static > 0 {
        let o = backend.sparse_gemm_bf16(&scores[..n_static], 1, &hc.v_static, ctr);
        out.copy_from_slice(&o);
    }
    // R·V dynamic tail
    for t in 0..n_dyn {
        let r = scores[n_static + t];
        let row = &hc.v_dyn[t * hc.head_dim..(t + 1) * hc.head_dim];
        for d in 0..hc.head_dim {
            out[d] += r * row[d];
        }
        ctr.avx_fma += hc.head_dim.div_ceil(16) as u64;
    }
    out
}

/// Dense-reference attention (the Fig 15 baseline and the numerics
/// oracle): same math on the *unpruned-layout* dense matrices, through
/// the reference backend's oracle matmul.
pub fn attend_dense_ref(
    k: &[f32],
    v: &[f32],
    ctx: usize,
    head_dim: usize,
    q: &[f32],
) -> Vec<f32> {
    let scale = 1.0 / (head_dim as f32).sqrt();
    // scores = q · Kᵀ
    let mut kt = vec![0f32; head_dim * ctx];
    for t in 0..ctx {
        for d in 0..head_dim {
            kt[d * ctx + t] = k[t * head_dim + d];
        }
    }
    let mut scores = RefBackend::matmul_f32(q, 1, &kt, head_dim, ctx);
    for s in scores.iter_mut() {
        *s *= scale;
    }
    softmax(&mut scores);
    RefBackend::matmul_f32(&scores, 1, v, ctx, head_dim)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::XorShift;

    #[test]
    fn softmax_is_a_distribution() {
        let mut xs = vec![1.0, 2.0, 3.0, -1e9];
        softmax(&mut xs);
        assert!((xs.iter().sum::<f32>() - 1.0).abs() < 1e-5);
        assert!(xs[2] > xs[1] && xs[1] > xs[0]);
        assert!(xs[3] < 1e-6);
    }

    #[test]
    fn softmax_handles_extremes() {
        let mut xs = vec![1e30f32, 1e30];
        softmax(&mut xs);
        assert!((xs[0] - 0.5).abs() < 1e-5);
        softmax(&mut []);
    }

    #[test]
    fn sparse_attention_matches_dense_ref_at_zero_sparsity() {
        let mut g = XorShift::new(31);
        let (ctx, d) = (48, 32);
        let k = g.normal_vec(ctx * d, 1.0);
        let v = g.normal_vec(ctx * d, 1.0);
        let q = g.normal_vec(d, 1.0);
        let hc = super::super::cache::HeadCache::from_prefill(&k, &v, ctx, d, 0.0, 0.0);
        let mut ctr = EventCounters::default();
        let got = attend_sparse(&hc, &q, &Backend::amx(), &mut ctr);
        let want = attend_dense_ref(&k, &v, ctx, d, &q);
        for (a, b) in got.iter().zip(want.iter()) {
            assert!((a - b).abs() < 0.05, "{a} vs {b}");
        }
        assert!(ctr.vpexpand > 0, "static path must use the sparse kernel");
    }

    #[test]
    fn attention_backends_agree() {
        // The attention path must be backend-agnostic: AMX, AVX, and the
        // reference oracle produce the same output up to bf16 noise.
        let mut g = XorShift::new(34);
        let (ctx, d) = (64, 32);
        let k = g.normal_vec(ctx * d, 1.0);
        let v = g.normal_vec(ctx * d, 1.0);
        let q = g.normal_vec(d, 1.0);
        let hc = super::super::cache::HeadCache::from_prefill(&k, &v, ctx, d, 0.3, 0.5);
        let mut c_amx = EventCounters::default();
        let amx = attend_sparse(&hc, &q, &Backend::amx(), &mut c_amx);
        let mut c_avx = EventCounters::default();
        let avx = attend_sparse(&hc, &q, &Backend::avx(), &mut c_avx);
        let mut c_ref = EventCounters::default();
        let oracle = attend_sparse(&hc, &q, &Backend::reference(), &mut c_ref);
        for i in 0..d {
            assert!((amx[i] - avx[i]).abs() < 0.05, "amx vs avx at {i}");
            assert!((amx[i] - oracle[i]).abs() < 0.05, "amx vs ref at {i}");
        }
        assert!(c_amx.tdp_bf16 > 0, "AMX path uses tile compute");
        assert!(c_avx.tdp_bf16 == 0 && c_avx.avx_fma > 0, "AVX path is vector-only");
    }

    #[test]
    fn sparse_attention_with_dynamic_tail() {
        let mut g = XorShift::new(32);
        let (ctx, d) = (32, 16);
        let k = g.normal_vec(ctx * d, 1.0);
        let v = g.normal_vec(ctx * d, 1.0);
        let q = g.normal_vec(d, 1.0);
        let mut hc = super::super::cache::HeadCache::from_prefill(&k, &v, ctx, d, 0.0, 0.0);
        let k2 = g.normal_vec(d, 1.0);
        let v2 = g.normal_vec(d, 1.0);
        hc.append(&k2, &v2);
        // dense reference over the concatenated cache
        let mut kall = k.clone();
        kall.extend_from_slice(&k2);
        let mut vall = v.clone();
        vall.extend_from_slice(&v2);
        let want = attend_dense_ref(&kall, &vall, ctx + 1, d, &q);
        let mut ctr = EventCounters::default();
        let got = attend_sparse(&hc, &q, &Backend::amx(), &mut ctr);
        for (a, b) in got.iter().zip(want.iter()) {
            assert!((a - b).abs() < 0.05, "{a} vs {b}");
        }
    }

    #[test]
    fn pruned_cache_output_stays_close() {
        // §6.1: moderate KV pruning perturbs attention output only mildly
        let mut g = XorShift::new(33);
        let (ctx, d) = (64, 32);
        let k = g.normal_vec(ctx * d, 1.0);
        let v = g.normal_vec(ctx * d, 1.0);
        let q = g.normal_vec(d, 1.0);
        let dense = attend_dense_ref(&k, &v, ctx, d, &q);
        let hc = super::super::cache::HeadCache::from_prefill(&k, &v, ctx, d, 0.3, 0.5);
        let mut ctr = EventCounters::default();
        let pruned = attend_sparse(&hc, &q, &Backend::amx(), &mut ctr);
        let rms_base: f32 =
            (dense.iter().map(|x| x * x).sum::<f32>() / d as f32).sqrt();
        let rms_err: f32 = (dense
            .iter()
            .zip(pruned.iter())
            .map(|(a, b)| (a - b) * (a - b))
            .sum::<f32>()
            / d as f32)
            .sqrt();
        assert!(
            rms_err < 0.8 * rms_base,
            "pruning destroyed attention: err {rms_err} vs base {rms_base}"
        );
    }

    #[test]
    fn empty_cache_attention() {
        let hc = super::super::cache::HeadCache::from_prefill(&[], &[], 0, 8, 0.0, 0.0);
        let mut ctr = EventCounters::default();
        let out = attend_sparse(&hc, &[1.0; 8], &Backend::amx(), &mut ctr);
        assert_eq!(out, vec![0.0; 8]);
    }
}
