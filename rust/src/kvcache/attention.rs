//! Decode attention over the split cache (paper §6): the static segment's
//! QKᵀ and R·V matmuls run through the configured [`Backend`]'s sparse
//! kernel; the dynamic tail is dense (it is small and changes every
//! token, so compressing it would cost more than it saves — §7 "not
//! suitable for dynamic KV").
//!
//! Two entry points serve the token loop:
//!
//! * [`attend_sparse_scratched`] — one query row through the batch-1
//!   kernels, reusing an [`AttentionScratch`] so the loop stops
//!   allocating score buffers per call;
//! * [`attend_sparse_batched`] — all query rows sharing one
//!   [`HeadCache`] (a GQA group's query heads) gathered into a single
//!   activation block and run through the `*_batched` kernel entry
//!   points, streaming the static K/V segments **once per step** for
//!   the whole group instead of once per query row.
//!
//! The fused path is a pure streaming transform: every per-row float
//! operation (dynamic-tail dots, scaling, softmax, tail accumulation)
//! runs through the same helpers as the looped path in the same order,
//! and the batched GEMM entry points are bit-exact vs. looping batch-1
//! by the PR 7 contract — so fused output is bit-exact vs. looped.

use super::cache::HeadCache;
use crate::amx::EventCounters;
use crate::backend::{Backend, RefBackend};
use crate::util::bf16::round_f32;

/// Numerically-stable softmax in place. Fully-masked rows — all `-inf`
/// scores, or inputs whose exponentials underflow to a zero (or
/// non-finite) sum — are handled explicitly: the row becomes all-zero
/// weights (it attends nowhere) instead of silently keeping whatever
/// unnormalized values fell out of `exp`.
pub fn softmax(xs: &mut [f32]) {
    softmax_split(xs, &mut []);
}

/// Softmax over the logical concatenation `head ‖ tail` in place — the
/// split-cache score row (static segment, then dynamic tail) without
/// requiring the two parts to be contiguous. Operates in strict
/// head-then-tail order so a row split across two buffers produces
/// bit-identical results to the same row held contiguously.
///
/// Masked-row contract (shared with [`softmax`]): if every entry is
/// `-inf`, or the exponential sum is zero or non-finite, both parts are
/// explicitly zeroed.
pub fn softmax_split(head: &mut [f32], tail: &mut [f32]) {
    if head.is_empty() && tail.is_empty() {
        return;
    }
    let max = head
        .iter()
        .chain(tail.iter())
        .cloned()
        .fold(f32::NEG_INFINITY, f32::max);
    if max == f32::NEG_INFINITY {
        // fully-masked row: attend nowhere, explicitly
        head.fill(0.0);
        tail.fill(0.0);
        return;
    }
    let mut sum = 0.0;
    for x in head.iter_mut().chain(tail.iter_mut()) {
        *x = (*x - max).exp();
        sum += *x;
    }
    if sum > 0.0 && sum.is_finite() {
        for x in head.iter_mut().chain(tail.iter_mut()) {
            *x /= sum;
        }
    } else {
        // exp underflowed every entry (or produced non-finite garbage):
        // zero the row rather than leave it unnormalized
        head.fill(0.0);
        tail.fill(0.0);
    }
}

/// Row-wise softmax over a contiguous `rows × cols` score block, each
/// row independently through [`softmax`] (masked rows included).
pub fn softmax_rows(xs: &mut [f32], cols: usize) {
    if cols == 0 {
        return;
    }
    for row in xs.chunks_mut(cols) {
        softmax(row);
    }
}

/// Reusable per-layer attention scratch: the static and dynamic-tail
/// score blocks for up to `n_q` query rows. The token loop holds one of
/// these across layers, heads, and groups so neither the looped nor the
/// fused attention path allocates score buffers per call.
///
/// The static block is kept contiguous (`n_q × n_static`, row-major)
/// because it is exactly the batched R·V GEMM's activation input; the
/// dynamic block lives separately so appending tail tokens never
/// reshapes the static scores.
#[derive(Clone, Debug, Default)]
pub struct AttentionScratch {
    /// Static-segment scores, `n_q × n_static` row-major.
    scores_static: Vec<f32>,
    /// Dynamic-tail scores, `n_q × n_dyn` row-major.
    scores_dyn: Vec<f32>,
}

impl AttentionScratch {
    /// Size the score blocks for `n_q` query rows over a cache with
    /// `n_static`/`n_dyn` tokens. Capacity is retained across calls, so
    /// steady-state decode steps perform no allocation here.
    fn reserve(&mut self, n_q: usize, n_static: usize, n_dyn: usize) {
        self.scores_static.clear();
        self.scores_static.resize(n_q * n_static, 0.0);
        self.scores_dyn.clear();
        self.scores_dyn.resize(n_q * n_dyn, 0.0);
    }
}

/// QKᵀ over the dynamic tail for one query row: dense dots in token
/// order, ticking the same per-token events as the looped path always
/// has (shared by both attention entry points for bit-exactness).
fn dyn_tail_scores(hc: &HeadCache, q: &[f32], sd: &mut [f32], ctr: &mut EventCounters) {
    let hd = hc.head_dim;
    for (t, s) in sd.iter_mut().enumerate() {
        let row = &hc.k_dyn[t * hd..(t + 1) * hd];
        let mut acc = 0.0;
        for d in 0..hd {
            acc += round_f32(q[d]) * row[d];
        }
        *s = acc;
        ctr.input_bytes += (hd * 2) as u64;
        ctr.avx_fma += hd.div_ceil(32) as u64;
    }
}

/// Scale one row's split scores and softmax them — static part first,
/// then the tail, matching the op order of a contiguous score vector.
fn scale_softmax_row(ss: &mut [f32], sd: &mut [f32], scale: f32) {
    for s in ss.iter_mut() {
        *s *= scale;
    }
    for s in sd.iter_mut() {
        *s *= scale;
    }
    softmax_split(ss, sd);
}

/// R·V over the dynamic tail for one query row, accumulating into `out`
/// (shared by both attention entry points).
fn dyn_tail_accum(hc: &HeadCache, sd: &[f32], out: &mut [f32], ctr: &mut EventCounters) {
    let hd = hc.head_dim;
    for (t, &r) in sd.iter().enumerate() {
        let row = &hc.v_dyn[t * hd..(t + 1) * hd];
        for d in 0..hd {
            out[d] += r * row[d];
        }
        ctr.avx_fma += hd.div_ceil(16) as u64;
    }
}

/// One query head's decode attention over a [`HeadCache`], running the
/// static segment through `backend`'s sparse kernel. Returns the
/// `head_dim` output and ticks `ctr` with the kernel events (for the
/// Fig 15 cost model).
///
/// Convenience wrapper over [`attend_sparse_scratched`] for one-shot
/// callers; hot loops pass a reused [`AttentionScratch`] instead.
pub fn attend_sparse(
    hc: &HeadCache,
    q: &[f32],
    backend: &Backend,
    ctr: &mut EventCounters,
) -> Vec<f32> {
    let mut scratch = AttentionScratch::default();
    let mut out = vec![0f32; hc.head_dim];
    attend_sparse_scratched(hc, q, backend, &mut scratch, &mut out, ctr);
    out
}

/// The looped attention path with caller-owned buffers: identical math
/// to [`attend_sparse`], but scores live in `scratch` and the result is
/// written into `out` (`head_dim` long) — no per-call allocation in the
/// token loop.
pub fn attend_sparse_scratched(
    hc: &HeadCache,
    q: &[f32],
    backend: &Backend,
    scratch: &mut AttentionScratch,
    out: &mut [f32],
    ctr: &mut EventCounters,
) {
    assert_eq!(q.len(), hc.head_dim);
    assert_eq!(out.len(), hc.head_dim);
    let scale = 1.0 / (hc.head_dim as f32).sqrt();
    let n_static = hc.n_static;
    let n_dyn = hc.dyn_len();
    scratch.reserve(1, n_static, n_dyn);

    // QKᵀ static: q (1 × head_dim) × Kᵀ (head_dim × n_static), sparse
    if n_static > 0 {
        let s = backend.sparse_gemm_bf16(q, 1, &hc.k_static, ctr);
        scratch.scores_static.copy_from_slice(&s);
    }
    // QKᵀ dynamic tail: dense dot products
    dyn_tail_scores(hc, q, &mut scratch.scores_dyn, ctr);
    scale_softmax_row(&mut scratch.scores_static, &mut scratch.scores_dyn, scale);

    // R·V static: r (1 × n_static) × V (n_static × head_dim), sparse
    if n_static > 0 {
        let o = backend.sparse_gemm_bf16(&scratch.scores_static, 1, &hc.v_static, ctr);
        out.copy_from_slice(&o);
    } else {
        out.fill(0.0);
    }
    // R·V dynamic tail
    dyn_tail_accum(hc, &scratch.scores_dyn, out, ctr);
}

/// Fused multi-query decode attention over one shared [`HeadCache`]:
/// the `n_q` query rows that attend over the same static segment (a GQA
/// group's query heads for one slot) gathered into one `n_q × head_dim`
/// block. QKᵀ and R·V each run as **one** `sparse_gemm_bf16_batched`
/// call, so the static K and V segments stream once per step for the
/// whole group instead of once per query row; the dynamic tail, scaling,
/// and row softmax run per row through the exact helpers the looped path
/// uses. Output lands in `out` (`n_q × head_dim`, row-major), bit-exact
/// vs. calling [`attend_sparse`] row by row.
pub fn attend_sparse_batched(
    hc: &HeadCache,
    q_block: &[f32],
    n_q: usize,
    backend: &Backend,
    scratch: &mut AttentionScratch,
    out: &mut [f32],
    ctr: &mut EventCounters,
) {
    let hd = hc.head_dim;
    assert_eq!(q_block.len(), n_q * hd);
    assert_eq!(out.len(), n_q * hd);
    if n_q == 0 {
        return;
    }
    let scale = 1.0 / (hd as f32).sqrt();
    let n_static = hc.n_static;
    let n_dyn = hc.dyn_len();
    scratch.reserve(n_q, n_static, n_dyn);

    // QKᵀ static: one batched sparse GEMM over the whole group — the K
    // static segment streams once, not `n_q` times
    if n_static > 0 {
        let s = backend.sparse_gemm_bf16_batched(q_block, n_q, &hc.k_static, ctr);
        scratch.scores_static.copy_from_slice(&s);
    }
    // per-row dynamic tail + scale + row softmax (masked rows explicit)
    for r in 0..n_q {
        let qrow = &q_block[r * hd..(r + 1) * hd];
        let ss = &mut scratch.scores_static[r * n_static..(r + 1) * n_static];
        let sd = &mut scratch.scores_dyn[r * n_dyn..(r + 1) * n_dyn];
        dyn_tail_scores(hc, qrow, sd, ctr);
        scale_softmax_row(ss, sd, scale);
    }
    // R·V static: the softmaxed static block is already the batched
    // GEMM's activation layout — one call streams V once for the group
    if n_static > 0 {
        let o = backend.sparse_gemm_bf16_batched(&scratch.scores_static, n_q, &hc.v_static, ctr);
        out.copy_from_slice(&o);
    } else {
        out.fill(0.0);
    }
    // R·V dynamic tail per row
    for r in 0..n_q {
        let sd = &scratch.scores_dyn[r * n_dyn..(r + 1) * n_dyn];
        dyn_tail_accum(hc, sd, &mut out[r * hd..(r + 1) * hd], ctr);
    }
}

/// Dense-reference attention (the Fig 15 baseline and the numerics
/// oracle): same math on the *unpruned-layout* dense matrices, through
/// the reference backend's oracle matmul.
pub fn attend_dense_ref(
    k: &[f32],
    v: &[f32],
    ctx: usize,
    head_dim: usize,
    q: &[f32],
) -> Vec<f32> {
    let scale = 1.0 / (head_dim as f32).sqrt();
    // scores = q · Kᵀ
    let mut kt = vec![0f32; head_dim * ctx];
    for t in 0..ctx {
        for d in 0..head_dim {
            kt[d * ctx + t] = k[t * head_dim + d];
        }
    }
    let mut scores = RefBackend::matmul_f32(q, 1, &kt, head_dim, ctx);
    for s in scores.iter_mut() {
        *s *= scale;
    }
    softmax(&mut scores);
    RefBackend::matmul_f32(&scores, 1, v, ctx, head_dim)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::XorShift;

    #[test]
    fn softmax_is_a_distribution() {
        let mut xs = vec![1.0, 2.0, 3.0, -1e9];
        softmax(&mut xs);
        assert!((xs.iter().sum::<f32>() - 1.0).abs() < 1e-5);
        assert!(xs[2] > xs[1] && xs[1] > xs[0]);
        assert!(xs[3] < 1e-6);
    }

    #[test]
    fn softmax_handles_extremes() {
        let mut xs = vec![1e30f32, 1e30];
        softmax(&mut xs);
        assert!((xs[0] - 0.5).abs() < 1e-5);
        softmax(&mut []);
    }

    #[test]
    fn softmax_masked_rows_become_explicit_zeros() {
        // all-(-inf) row: attends nowhere → all-zero weights, no NaNs
        let mut xs = vec![f32::NEG_INFINITY; 4];
        softmax(&mut xs);
        assert_eq!(xs, vec![0.0; 4], "masked row must zero, not NaN");
        // split layout agrees with the contiguous one
        let mut head = vec![f32::NEG_INFINITY; 2];
        let mut tail = vec![f32::NEG_INFINITY; 2];
        softmax_split(&mut head, &mut tail);
        assert_eq!(head, vec![0.0; 2]);
        assert_eq!(tail, vec![0.0; 2]);
        // one live entry among masked ones still normalizes
        let mut xs = vec![f32::NEG_INFINITY, 0.5, f32::NEG_INFINITY];
        softmax(&mut xs);
        assert_eq!(xs, vec![0.0, 1.0, 0.0]);
    }

    #[test]
    fn softmax_rows_handles_masked_rows_independently() {
        // 3 rows × 2 cols: live, masked, live — the masked middle row
        // zeroes explicitly while its neighbours normalize
        let mut block = vec![
            1.0,
            1.0,
            f32::NEG_INFINITY,
            f32::NEG_INFINITY,
            2.0,
            0.0,
        ];
        softmax_rows(&mut block, 2);
        assert!((block[0] - 0.5).abs() < 1e-6);
        assert!((block[1] - 0.5).abs() < 1e-6);
        assert_eq!(&block[2..4], &[0.0, 0.0], "masked row");
        assert!((block[4] + block[5] - 1.0).abs() < 1e-6);
        assert!(block[4] > block[5]);
        // row-wise application is bit-identical to per-row softmax
        let mut row = vec![2.0f32, 0.0];
        softmax(&mut row);
        assert_eq!(&block[4..6], &row[..]);
    }

    #[test]
    fn softmax_split_matches_contiguous_bitwise() {
        let mut g = XorShift::new(99);
        let xs = g.normal_vec(12, 2.0);
        let mut whole = xs.clone();
        softmax(&mut whole);
        let (mut head, mut tail) = (xs[..7].to_vec(), xs[7..].to_vec());
        softmax_split(&mut head, &mut tail);
        head.extend_from_slice(&tail);
        assert_eq!(whole, head, "split softmax must be bit-exact");
    }

    #[test]
    fn sparse_attention_matches_dense_ref_at_zero_sparsity() {
        let mut g = XorShift::new(31);
        let (ctx, d) = (48, 32);
        let k = g.normal_vec(ctx * d, 1.0);
        let v = g.normal_vec(ctx * d, 1.0);
        let q = g.normal_vec(d, 1.0);
        let hc = super::super::cache::HeadCache::from_prefill(&k, &v, ctx, d, 0.0, 0.0);
        let mut ctr = EventCounters::default();
        let got = attend_sparse(&hc, &q, &Backend::amx(), &mut ctr);
        let want = attend_dense_ref(&k, &v, ctx, d, &q);
        for (a, b) in got.iter().zip(want.iter()) {
            assert!((a - b).abs() < 0.05, "{a} vs {b}");
        }
        assert!(ctr.vpexpand > 0, "static path must use the sparse kernel");
    }

    #[test]
    fn attention_backends_agree() {
        // The attention path must be backend-agnostic: AMX, AVX, and the
        // reference oracle produce the same output up to bf16 noise.
        let mut g = XorShift::new(34);
        let (ctx, d) = (64, 32);
        let k = g.normal_vec(ctx * d, 1.0);
        let v = g.normal_vec(ctx * d, 1.0);
        let q = g.normal_vec(d, 1.0);
        let hc = super::super::cache::HeadCache::from_prefill(&k, &v, ctx, d, 0.3, 0.5);
        let mut c_amx = EventCounters::default();
        let amx = attend_sparse(&hc, &q, &Backend::amx(), &mut c_amx);
        let mut c_avx = EventCounters::default();
        let avx = attend_sparse(&hc, &q, &Backend::avx(), &mut c_avx);
        let mut c_ref = EventCounters::default();
        let oracle = attend_sparse(&hc, &q, &Backend::reference(), &mut c_ref);
        for i in 0..d {
            assert!((amx[i] - avx[i]).abs() < 0.05, "amx vs avx at {i}");
            assert!((amx[i] - oracle[i]).abs() < 0.05, "amx vs ref at {i}");
        }
        assert!(c_amx.tdp_bf16 > 0, "AMX path uses tile compute");
        assert!(c_avx.tdp_bf16 == 0 && c_avx.avx_fma > 0, "AVX path is vector-only");
    }

    #[test]
    fn scratched_attention_matches_allocating_wrapper_and_reuses_buffers() {
        let mut g = XorShift::new(36);
        let (ctx, d) = (40, 16);
        let k = g.normal_vec(ctx * d, 1.0);
        let v = g.normal_vec(ctx * d, 1.0);
        let mut hc = super::super::cache::HeadCache::from_prefill(&k, &v, ctx, d, 0.3, 0.5);
        hc.append(&g.normal_vec(d, 1.0), &g.normal_vec(d, 1.0));
        let mut scratch = AttentionScratch::default();
        let mut out = vec![0f32; d];
        for _ in 0..3 {
            // repeated calls reuse the same scratch; results stay
            // bit-identical to the fresh-allocation wrapper
            let q = g.normal_vec(d, 1.0);
            let mut c1 = EventCounters::default();
            attend_sparse_scratched(&hc, &q, &Backend::amx(), &mut scratch, &mut out, &mut c1);
            let mut c2 = EventCounters::default();
            let want = attend_sparse(&hc, &q, &Backend::amx(), &mut c2);
            assert_eq!(out, want, "scratched vs wrapper diverged");
            assert_eq!(c1, c2, "event counters diverged");
        }
    }

    #[test]
    fn batched_attention_is_bit_exact_vs_looped_rows() {
        let mut g = XorShift::new(37);
        let (ctx, d, n_q) = (32, 16, 4);
        let k = g.normal_vec(ctx * d, 1.0);
        let v = g.normal_vec(ctx * d, 1.0);
        let mut hc = super::super::cache::HeadCache::from_prefill(&k, &v, ctx, d, 0.3, 0.5);
        hc.append(&g.normal_vec(d, 1.0), &g.normal_vec(d, 1.0));
        let qb = g.normal_vec(n_q * d, 1.0);
        for backend in [Backend::amx(), Backend::avx(), Backend::reference()] {
            let mut scratch = AttentionScratch::default();
            let mut fused = vec![0f32; n_q * d];
            let mut cf = EventCounters::default();
            attend_sparse_batched(&hc, &qb, n_q, &backend, &mut scratch, &mut fused, &mut cf);
            for r in 0..n_q {
                let mut cl = EventCounters::default();
                let want = attend_sparse(&hc, &qb[r * d..(r + 1) * d], &backend, &mut cl);
                assert_eq!(
                    &fused[r * d..(r + 1) * d],
                    &want[..],
                    "{} row {r} diverged",
                    backend.name()
                );
            }
        }
    }

    #[test]
    fn sparse_attention_with_dynamic_tail() {
        let mut g = XorShift::new(32);
        let (ctx, d) = (32, 16);
        let k = g.normal_vec(ctx * d, 1.0);
        let v = g.normal_vec(ctx * d, 1.0);
        let q = g.normal_vec(d, 1.0);
        let mut hc = super::super::cache::HeadCache::from_prefill(&k, &v, ctx, d, 0.0, 0.0);
        let k2 = g.normal_vec(d, 1.0);
        let v2 = g.normal_vec(d, 1.0);
        hc.append(&k2, &v2);
        // dense reference over the concatenated cache
        let mut kall = k.clone();
        kall.extend_from_slice(&k2);
        let mut vall = v.clone();
        vall.extend_from_slice(&v2);
        let want = attend_dense_ref(&kall, &vall, ctx + 1, d, &q);
        let mut ctr = EventCounters::default();
        let got = attend_sparse(&hc, &q, &Backend::amx(), &mut ctr);
        for (a, b) in got.iter().zip(want.iter()) {
            assert!((a - b).abs() < 0.05, "{a} vs {b}");
        }
    }

    #[test]
    fn pruned_cache_output_stays_close() {
        // §6.1: moderate KV pruning perturbs attention output only mildly
        let mut g = XorShift::new(33);
        let (ctx, d) = (64, 32);
        let k = g.normal_vec(ctx * d, 1.0);
        let v = g.normal_vec(ctx * d, 1.0);
        let q = g.normal_vec(d, 1.0);
        let dense = attend_dense_ref(&k, &v, ctx, d, &q);
        let hc = super::super::cache::HeadCache::from_prefill(&k, &v, ctx, d, 0.3, 0.5);
        let mut ctr = EventCounters::default();
        let pruned = attend_sparse(&hc, &q, &Backend::amx(), &mut ctr);
        let rms_base: f32 =
            (dense.iter().map(|x| x * x).sum::<f32>() / d as f32).sqrt();
        let rms_err: f32 = (dense
            .iter()
            .zip(pruned.iter())
            .map(|(a, b)| (a - b) * (a - b))
            .sum::<f32>()
            / d as f32)
            .sqrt();
        assert!(
            rms_err < 0.8 * rms_base,
            "pruning destroyed attention: err {rms_err} vs base {rms_base}"
        );
    }

    #[test]
    fn empty_cache_attention() {
        let hc = super::super::cache::HeadCache::from_prefill(&[], &[], 0, 8, 0.0, 0.0);
        let mut ctr = EventCounters::default();
        let out = attend_sparse(&hc, &[1.0; 8], &Backend::amx(), &mut ctr);
        assert_eq!(out, vec![0.0; 8]);
        // fused path on an empty cache is likewise all-zero
        let b = Backend::amx();
        let mut scratch = AttentionScratch::default();
        let mut fused = vec![9.0f32; 16];
        attend_sparse_batched(&hc, &[1.0; 16], 2, &b, &mut scratch, &mut fused, &mut ctr);
        assert_eq!(fused, vec![0.0; 16]);
    }
}
