//! The serving coordinator: requests, continuous batcher, engine, server.
pub mod request;
pub mod batcher;
pub mod metrics;
pub mod engine;
pub mod server;
