//! Serving metrics: counters, latency distributions, a fixed-bucket
//! per-decode-step histogram, and per-(engine path, backend) step
//! accounting — all exposed through the server's stats output.

use crate::cfg::Json;
use crate::util::stats::Summary;
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

/// Upper bounds (milliseconds) of the fixed step-latency buckets; one
/// extra overflow bucket catches everything slower.
pub const STEP_BUCKET_BOUNDS_MS: [f64; 10] =
    [0.05, 0.1, 0.25, 0.5, 1.0, 2.5, 5.0, 10.0, 50.0, 100.0];

/// Lock-free fixed-bucket histogram of per-decode-step wall time.
#[derive(Default)]
pub struct StepHistogram {
    counts: [AtomicU64; STEP_BUCKET_BOUNDS_MS.len() + 1],
}

impl StepHistogram {
    pub fn record(&self, secs: f64) {
        let ms = secs * 1e3;
        let idx = STEP_BUCKET_BOUNDS_MS
            .iter()
            .position(|&b| ms <= b)
            .unwrap_or(STEP_BUCKET_BOUNDS_MS.len());
        self.counts[idx].fetch_add(1, Ordering::Relaxed);
    }

    /// Snapshot of the bucket counts (last entry is the overflow bucket).
    pub fn counts(&self) -> Vec<u64> {
        self.counts.iter().map(|c| c.load(Ordering::Relaxed)).collect()
    }

    pub fn total(&self) -> u64 {
        self.counts.iter().map(|c| c.load(Ordering::Relaxed)).sum()
    }
}

/// Upper bounds (active slots) of the fixed batch-occupancy buckets;
/// one extra overflow bucket catches anything wider.
pub const OCCUPANCY_BUCKET_BOUNDS: [u64; 7] = [1, 2, 4, 8, 16, 32, 64];

/// Lock-free fixed-bucket histogram of active-slot count per decode
/// step — how full the fused activation block actually runs.
#[derive(Default)]
pub struct OccupancyHistogram {
    counts: [AtomicU64; OCCUPANCY_BUCKET_BOUNDS.len() + 1],
}

impl OccupancyHistogram {
    pub fn record(&self, active: usize) {
        let idx = OCCUPANCY_BUCKET_BOUNDS
            .iter()
            .position(|&b| active as u64 <= b)
            .unwrap_or(OCCUPANCY_BUCKET_BOUNDS.len());
        self.counts[idx].fetch_add(1, Ordering::Relaxed);
    }

    /// Snapshot of the bucket counts (last entry is the overflow bucket).
    pub fn counts(&self) -> Vec<u64> {
        self.counts.iter().map(|c| c.load(Ordering::Relaxed)).collect()
    }

    pub fn total(&self) -> u64 {
        self.counts.iter().map(|c| c.load(Ordering::Relaxed)).sum()
    }
}

/// Engine-wide metrics registry (thread-safe).
#[derive(Default)]
pub struct Metrics {
    pub requests_admitted: AtomicU64,
    pub requests_rejected: AtomicU64,
    pub requests_completed: AtomicU64,
    pub tokens_generated: AtomicU64,
    pub decode_steps: AtomicU64,
    pub prefills: AtomicU64,
    latencies_s: Mutex<Vec<f64>>,
    step_times_s: Mutex<Vec<f64>>,
    /// Fixed-bucket distribution of per-decode-step latency.
    pub step_hist: StepHistogram,
    /// Fixed-bucket distribution of active slots per decode step.
    pub batch_occupancy: OccupancyHistogram,
    /// Decode steps served one slot at a time (batch-1 regime).
    pub steps_decode_b1: AtomicU64,
    /// Decode steps served through the fused multi-slot regime.
    pub steps_decode_fused: AtomicU64,
    /// Looped↔fused regime transitions the engine's dwell counter let
    /// through (hysteresis suppresses per-step oscillation, so a high
    /// flip count means genuinely shifting occupancy).
    pub regime_flips: AtomicU64,
    /// Steps served, keyed by `"<engine path>/<backend>"` (e.g.
    /// `native/amx`, `pjrt/xla`) — which path actually produced tokens.
    steps_by_path: Mutex<BTreeMap<String, u64>>,
    /// Sharded-execution epochs flushed from the worker pool.
    pub shard_epochs: AtomicU64,
    /// Accumulated busy seconds per shard lane (index = shard id),
    /// summed across all flushed epochs.
    shard_time_s: Mutex<Vec<f64>>,
    /// Fault-recovery counters (PR 9). `faults_injected` mirrors the
    /// fault subsystem's cumulative injection count; the rest count
    /// recovery actions the serving stack actually took, so a chaos run
    /// can assert the ladder fired: injected faults → worker respawns /
    /// epoch retries at the pool, backend quarantines → plan recompiles
    /// at the engine, deadline expirations at admission/step level.
    pub faults_injected: AtomicU64,
    pub worker_respawns: AtomicU64,
    pub epoch_retries: AtomicU64,
    pub backend_quarantines: AtomicU64,
    pub plan_recompiles: AtomicU64,
    pub deadline_expirations: AtomicU64,
    /// Crash-consistency and probation counters (PR 10): snapshot files
    /// written at the checkpoint cadence, in-flight slots restored at
    /// startup, snapshots rejected (torn/corrupt/incompatible), shadow
    /// probes routed to quarantined backends, probation releases, and
    /// deadline sweeps that retired a slot *before* a step the pricing
    /// model said it could not survive.
    pub checkpoints_written: AtomicU64,
    pub slots_restored: AtomicU64,
    pub restore_rejected: AtomicU64,
    pub probe_calls: AtomicU64,
    pub quarantine_releases: AtomicU64,
    pub preemptive_deadline_sweeps: AtomicU64,
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics::default()
    }

    pub fn record_latency(&self, secs: f64) {
        self.latencies_s.lock().expect("metrics lock").push(secs);
    }

    /// Record one decode step: raw sample, histogram bucket, and the
    /// `"<engine path>/<backend>"` label that served it. Callers
    /// precompute the label once at load (the pair is constant for an
    /// engine's lifetime), so the hot path allocates only on the first
    /// step of a new label.
    pub fn record_step(&self, secs: f64, path_backend: &str) {
        self.step_times_s.lock().expect("metrics lock").push(secs);
        self.step_hist.record(secs);
        let mut by = self.steps_by_path.lock().expect("metrics lock");
        match by.get_mut(path_backend) {
            Some(n) => *n += 1,
            None => {
                by.insert(path_backend.to_string(), 1);
            }
        }
    }

    /// Snapshot of steps served per `"path/backend"` key.
    pub fn steps_by_path(&self) -> BTreeMap<String, u64> {
        self.steps_by_path.lock().expect("metrics lock").clone()
    }

    /// Record which decode regime served a step and how many slots it
    /// gathered: the occupancy histogram plus the per-regime counter.
    pub fn record_decode_regime(&self, active: usize, fused: bool) {
        self.batch_occupancy.record(active);
        if fused {
            self.steps_decode_fused.fetch_add(1, Ordering::Relaxed);
        } else {
            self.steps_decode_b1.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Fold one drained [`ShardStatsSnapshot`] into the gauges: epochs
    /// add up, per-shard busy seconds accumulate lane-by-lane (the
    /// vector grows to the widest shard count seen).
    pub fn record_shard_stats(&self, snap: &crate::shard::ShardStatsSnapshot) {
        if snap.epochs == 0 && snap.epoch_retries == 0 && snap.per_shard_time_s.is_empty() {
            return;
        }
        self.shard_epochs.fetch_add(snap.epochs, Ordering::Relaxed);
        self.epoch_retries.fetch_add(snap.epoch_retries, Ordering::Relaxed);
        let mut times = self.shard_time_s.lock().expect("metrics lock");
        if times.len() < snap.per_shard_time_s.len() {
            times.resize(snap.per_shard_time_s.len(), 0.0);
        }
        for (t, &s) in times.iter_mut().zip(snap.per_shard_time_s.iter()) {
            *t += s;
        }
    }

    /// Accumulated per-shard busy seconds (empty when unsharded).
    pub fn shard_times_s(&self) -> Vec<f64> {
        self.shard_time_s.lock().expect("metrics lock").clone()
    }

    /// Shard-imbalance gauge: slowest-lane over fastest-lane busy time.
    /// `1.0` means perfectly balanced (or unsharded / no data yet).
    pub fn shard_imbalance(&self) -> f64 {
        let times = self.shard_time_s.lock().expect("metrics lock");
        let mx = times.iter().cloned().fold(f64::MIN, f64::max);
        let mn = times.iter().cloned().fold(f64::MAX, f64::min);
        if times.is_empty() || mn <= 0.0 {
            1.0
        } else {
            mx / mn
        }
    }

    /// End-to-end request latency summary, if any completed.
    pub fn latency_summary(&self) -> Option<Summary> {
        let l = self.latencies_s.lock().expect("metrics lock");
        (!l.is_empty()).then(|| Summary::from(&l))
    }

    /// Per-decode-step time summary.
    pub fn step_summary(&self) -> Option<Summary> {
        let l = self.step_times_s.lock().expect("metrics lock");
        (!l.is_empty()).then(|| Summary::from(&l))
    }

    /// One-line report for logs and the serve example.
    pub fn report(&self) -> String {
        let steps = self.decode_steps.load(Ordering::Relaxed);
        let toks = self.tokens_generated.load(Ordering::Relaxed);
        let done = self.requests_completed.load(Ordering::Relaxed);
        let rej = self.requests_rejected.load(Ordering::Relaxed);
        let flips = self.regime_flips.load(Ordering::Relaxed);
        let step = self
            .step_summary()
            .map(|s| format!("{:.2}ms", s.mean * 1e3))
            .unwrap_or_else(|| "n/a".into());
        let lat = self
            .latency_summary()
            .map(|s| format!("p50 {:.1}ms p99 {:.1}ms", s.p50 * 1e3, s.p99 * 1e3))
            .unwrap_or_else(|| "n/a".into());
        let paths = {
            let by = self.steps_by_path.lock().expect("metrics lock");
            if by.is_empty() {
                "n/a".to_string()
            } else {
                by.iter()
                    .map(|(k, v)| format!("{k}:{v}"))
                    .collect::<Vec<_>>()
                    .join(" ")
            }
        };
        let mut line = format!(
            "completed={done} rejected={rej} tokens={toks} steps={steps} \
             regime_flips={flips} step_mean={step} latency {lat} served_by {paths}"
        );
        let faults = self.faults_injected.load(Ordering::Relaxed);
        let respawns = self.worker_respawns.load(Ordering::Relaxed);
        let retries = self.epoch_retries.load(Ordering::Relaxed);
        let quar = self.backend_quarantines.load(Ordering::Relaxed);
        let recompiles = self.plan_recompiles.load(Ordering::Relaxed);
        let deadlines = self.deadline_expirations.load(Ordering::Relaxed);
        if faults + respawns + retries + quar + recompiles + deadlines > 0 {
            line.push_str(&format!(
                " recovery faults={faults} respawns={respawns} retries={retries} \
                 quarantines={quar} recompiles={recompiles} deadlines={deadlines}"
            ));
        }
        let ckpts = self.checkpoints_written.load(Ordering::Relaxed);
        let restored = self.slots_restored.load(Ordering::Relaxed);
        let rejected = self.restore_rejected.load(Ordering::Relaxed);
        let probes = self.probe_calls.load(Ordering::Relaxed);
        let releases = self.quarantine_releases.load(Ordering::Relaxed);
        let sweeps = self.preemptive_deadline_sweeps.load(Ordering::Relaxed);
        if ckpts + restored + rejected + probes + releases + sweeps > 0 {
            line.push_str(&format!(
                " crash_consistency checkpoints={ckpts} restored={restored} \
                 restore_rejected={rejected} probes={probes} releases={releases} \
                 preemptive_sweeps={sweeps}"
            ));
        }
        line
    }

    /// Structured stats for the server's `{"stats": true}` endpoint:
    /// counters, the step-latency histogram, and which engine
    /// path/backend served each step.
    pub fn stats_json(&self, engine: &str) -> Json {
        let hist_counts = self
            .step_hist
            .counts()
            .into_iter()
            .map(|c| Json::Num(c as f64))
            .collect::<Vec<_>>();
        let bounds = STEP_BUCKET_BOUNDS_MS
            .iter()
            .map(|&b| Json::Num(b))
            .collect::<Vec<_>>();
        let by_path = Json::Obj(
            self.steps_by_path()
                .into_iter()
                .map(|(k, v)| (k, Json::Num(v as f64)))
                .collect(),
        );
        let step_mean_ms = self.step_summary().map(|s| s.mean * 1e3).unwrap_or(0.0);
        Json::obj(vec![
            ("engine", Json::Str(engine.into())),
            (
                "requests_admitted",
                Json::Num(self.requests_admitted.load(Ordering::Relaxed) as f64),
            ),
            (
                "requests_completed",
                Json::Num(self.requests_completed.load(Ordering::Relaxed) as f64),
            ),
            (
                "requests_rejected",
                Json::Num(self.requests_rejected.load(Ordering::Relaxed) as f64),
            ),
            (
                "tokens_generated",
                Json::Num(self.tokens_generated.load(Ordering::Relaxed) as f64),
            ),
            (
                "decode_steps",
                Json::Num(self.decode_steps.load(Ordering::Relaxed) as f64),
            ),
            ("prefills", Json::Num(self.prefills.load(Ordering::Relaxed) as f64)),
            ("step_mean_ms", Json::Num(step_mean_ms)),
            ("step_hist_bounds_ms", Json::Arr(bounds)),
            ("step_hist_counts", Json::Arr(hist_counts)),
            ("steps_by_path", by_path),
            (
                "steps_by_regime",
                Json::obj(vec![
                    (
                        "decode_b1",
                        Json::Num(self.steps_decode_b1.load(Ordering::Relaxed) as f64),
                    ),
                    (
                        "decode_fused",
                        Json::Num(self.steps_decode_fused.load(Ordering::Relaxed) as f64),
                    ),
                    (
                        "prefill",
                        Json::Num(self.prefills.load(Ordering::Relaxed) as f64),
                    ),
                ]),
            ),
            (
                "regime_flips",
                Json::Num(self.regime_flips.load(Ordering::Relaxed) as f64),
            ),
            (
                "batch_occupancy_bounds",
                Json::Arr(
                    OCCUPANCY_BUCKET_BOUNDS
                        .iter()
                        .map(|&b| Json::Num(b as f64))
                        .collect(),
                ),
            ),
            (
                "batch_occupancy_counts",
                Json::Arr(
                    self.batch_occupancy
                        .counts()
                        .into_iter()
                        .map(|c| Json::Num(c as f64))
                        .collect(),
                ),
            ),
            (
                "shard_epochs",
                Json::Num(self.shard_epochs.load(Ordering::Relaxed) as f64),
            ),
            (
                "shard_time_ms",
                Json::Arr(
                    self.shard_times_s()
                        .into_iter()
                        .map(|s| Json::Num(s * 1e3))
                        .collect(),
                ),
            ),
            ("shard_imbalance", Json::Num(self.shard_imbalance())),
            (
                "faults_injected",
                Json::Num(self.faults_injected.load(Ordering::Relaxed) as f64),
            ),
            (
                "worker_respawns",
                Json::Num(self.worker_respawns.load(Ordering::Relaxed) as f64),
            ),
            (
                "epoch_retries",
                Json::Num(self.epoch_retries.load(Ordering::Relaxed) as f64),
            ),
            (
                "backend_quarantines",
                Json::Num(self.backend_quarantines.load(Ordering::Relaxed) as f64),
            ),
            (
                "plan_recompiles",
                Json::Num(self.plan_recompiles.load(Ordering::Relaxed) as f64),
            ),
            (
                "deadline_expirations",
                Json::Num(self.deadline_expirations.load(Ordering::Relaxed) as f64),
            ),
            (
                "checkpoints_written",
                Json::Num(self.checkpoints_written.load(Ordering::Relaxed) as f64),
            ),
            (
                "slots_restored",
                Json::Num(self.slots_restored.load(Ordering::Relaxed) as f64),
            ),
            (
                "restore_rejected",
                Json::Num(self.restore_rejected.load(Ordering::Relaxed) as f64),
            ),
            (
                "probe_calls",
                Json::Num(self.probe_calls.load(Ordering::Relaxed) as f64),
            ),
            (
                "quarantine_releases",
                Json::Num(self.quarantine_releases.load(Ordering::Relaxed) as f64),
            ),
            (
                "preemptive_deadline_sweeps",
                Json::Num(self.preemptive_deadline_sweeps.load(Ordering::Relaxed) as f64),
            ),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_and_summaries() {
        let m = Metrics::new();
        m.requests_completed.fetch_add(2, Ordering::Relaxed);
        m.record_latency(0.1);
        m.record_latency(0.3);
        m.record_step(0.01, "native/amx");
        let l = m.latency_summary().unwrap();
        assert!((l.mean - 0.2).abs() < 1e-12);
        assert!(m.step_summary().is_some());
        assert!(m.report().contains("completed=2"));
        assert!(m.report().contains("native/amx:1"));
    }

    #[test]
    fn empty_summaries_are_none() {
        let m = Metrics::new();
        assert!(m.latency_summary().is_none());
        assert!(m.report().contains("n/a"));
    }

    #[test]
    fn histogram_buckets_cover_the_range() {
        let h = StepHistogram::default();
        h.record(0.00003); // 0.03 ms → first bucket
        h.record(0.0006); // 0.6 ms → the 1.0 ms bucket
        h.record(9.0); // 9 s → overflow
        let c = h.counts();
        assert_eq!(c.len(), STEP_BUCKET_BOUNDS_MS.len() + 1);
        assert_eq!(c[0], 1);
        assert_eq!(c[4], 1, "{c:?}");
        assert_eq!(*c.last().unwrap(), 1);
        assert_eq!(h.total(), 3);
    }

    #[test]
    fn regime_counters_and_occupancy_histogram() {
        let m = Metrics::new();
        m.record_decode_regime(1, false);
        m.record_decode_regime(3, true);
        m.record_decode_regime(5, true);
        m.record_decode_regime(200, true);
        assert_eq!(m.steps_decode_b1.load(Ordering::Relaxed), 1);
        assert_eq!(m.steps_decode_fused.load(Ordering::Relaxed), 3);
        let c = m.batch_occupancy.counts();
        assert_eq!(c.len(), OCCUPANCY_BUCKET_BOUNDS.len() + 1);
        assert_eq!(c[0], 1, "{c:?}"); // 1 slot → first bucket
        assert_eq!(c[2], 1, "{c:?}"); // 3 slots → the ≤4 bucket
        assert_eq!(c[3], 1, "{c:?}"); // 5 slots → the ≤8 bucket
        assert_eq!(*c.last().unwrap(), 1, "overflow bucket");
        assert_eq!(m.batch_occupancy.total(), 4);
        m.regime_flips.fetch_add(2, Ordering::Relaxed);
        let v = Json::parse(&m.stats_json("native").to_string()).unwrap();
        let reg = v.get("steps_by_regime").unwrap();
        assert_eq!(reg.get("decode_b1").unwrap().as_usize(), Some(1));
        assert_eq!(reg.get("decode_fused").unwrap().as_usize(), Some(3));
        assert_eq!(reg.get("prefill").unwrap().as_usize(), Some(0));
        assert_eq!(v.get("regime_flips").unwrap().as_usize(), Some(2));
        assert!(m.report().contains("regime_flips=2"));
        let oc = v.get("batch_occupancy_counts").unwrap().as_arr().unwrap();
        assert_eq!(oc.len(), OCCUPANCY_BUCKET_BOUNDS.len() + 1);
        let total: f64 = oc.iter().filter_map(|c| c.as_f64()).sum();
        assert_eq!(total as u64, 4);
    }

    #[test]
    fn shard_stats_accumulate_and_gauge_imbalance() {
        use crate::shard::ShardStatsSnapshot;
        let m = Metrics::new();
        // unsharded engines report a balanced gauge
        assert_eq!(m.shard_imbalance(), 1.0);
        m.record_shard_stats(&ShardStatsSnapshot {
            per_shard_time_s: vec![0.001, 0.002],
            epochs: 3,
            epoch_retries: 1,
        });
        m.record_shard_stats(&ShardStatsSnapshot {
            per_shard_time_s: vec![0.001, 0.002],
            epochs: 2,
            epoch_retries: 0,
        });
        // empty snapshots (nothing drained this step) are a no-op
        m.record_shard_stats(&ShardStatsSnapshot {
            per_shard_time_s: vec![],
            epochs: 0,
            epoch_retries: 0,
        });
        assert_eq!(m.shard_epochs.load(Ordering::Relaxed), 5);
        assert_eq!(m.epoch_retries.load(Ordering::Relaxed), 1);
        let times = m.shard_times_s();
        assert_eq!(times.len(), 2);
        assert!((times[0] - 0.002).abs() < 1e-12);
        assert!((times[1] - 0.004).abs() < 1e-12);
        assert!((m.shard_imbalance() - 2.0).abs() < 1e-9);
        let v = Json::parse(&m.stats_json("native").to_string()).unwrap();
        assert_eq!(v.get("shard_epochs").unwrap().as_usize(), Some(5));
        assert_eq!(v.get("shard_time_ms").unwrap().as_arr().unwrap().len(), 2);
        assert!(v.get("shard_imbalance").unwrap().as_f64().unwrap() > 1.9);
    }

    #[test]
    fn stats_json_roundtrips() {
        let m = Metrics::new();
        m.tokens_generated.fetch_add(5, Ordering::Relaxed);
        m.requests_admitted.fetch_add(2, Ordering::Relaxed);
        m.record_step(0.002, "native/amx");
        m.record_step(0.004, "native/amx");
        m.record_step(0.004, "pjrt/xla");
        let line = m.stats_json("native").to_string();
        let v = Json::parse(&line).unwrap();
        assert_eq!(v.get("engine").unwrap().as_str(), Some("native"));
        assert_eq!(v.get("tokens_generated").unwrap().as_usize(), Some(5));
        assert_eq!(v.get("requests_admitted").unwrap().as_usize(), Some(2));
        let by = v.get("steps_by_path").unwrap();
        assert_eq!(by.get("native/amx").unwrap().as_usize(), Some(2));
        assert_eq!(by.get("pjrt/xla").unwrap().as_usize(), Some(1));
        let counts = v.get("step_hist_counts").unwrap().as_arr().unwrap();
        assert_eq!(counts.len(), STEP_BUCKET_BOUNDS_MS.len() + 1);
        let total: f64 = counts.iter().filter_map(|c| c.as_f64()).sum();
        assert_eq!(total as u64, 3);
    }

    #[test]
    fn recovery_counters_surface_in_stats_and_report() {
        let m = Metrics::new();
        // quiet engines keep the report line free of recovery noise
        assert!(!m.report().contains("recovery"));
        let v = Json::parse(&m.stats_json("native").to_string()).unwrap();
        assert_eq!(v.get("faults_injected").unwrap().as_usize(), Some(0));
        assert_eq!(v.get("worker_respawns").unwrap().as_usize(), Some(0));
        m.faults_injected.store(3, Ordering::Relaxed);
        m.worker_respawns.fetch_add(2, Ordering::Relaxed);
        m.epoch_retries.fetch_add(1, Ordering::Relaxed);
        m.backend_quarantines.fetch_add(1, Ordering::Relaxed);
        m.plan_recompiles.fetch_add(1, Ordering::Relaxed);
        m.deadline_expirations.fetch_add(4, Ordering::Relaxed);
        let v = Json::parse(&m.stats_json("native").to_string()).unwrap();
        assert_eq!(v.get("faults_injected").unwrap().as_usize(), Some(3));
        assert_eq!(v.get("worker_respawns").unwrap().as_usize(), Some(2));
        assert_eq!(v.get("epoch_retries").unwrap().as_usize(), Some(1));
        assert_eq!(v.get("backend_quarantines").unwrap().as_usize(), Some(1));
        assert_eq!(v.get("plan_recompiles").unwrap().as_usize(), Some(1));
        assert_eq!(v.get("deadline_expirations").unwrap().as_usize(), Some(4));
        let r = m.report();
        assert!(r.contains("respawns=2"), "{r}");
        assert!(r.contains("deadlines=4"), "{r}");
    }

    #[test]
    fn crash_consistency_counters_surface_in_stats_and_report() {
        let m = Metrics::new();
        // engines that never checkpoint/probe keep the line quiet
        assert!(!m.report().contains("crash_consistency"));
        let v = Json::parse(&m.stats_json("native").to_string()).unwrap();
        for key in [
            "checkpoints_written",
            "slots_restored",
            "restore_rejected",
            "probe_calls",
            "quarantine_releases",
            "preemptive_deadline_sweeps",
        ] {
            assert_eq!(v.get(key).unwrap().as_usize(), Some(0), "{key}");
        }
        m.checkpoints_written.fetch_add(5, Ordering::Relaxed);
        m.slots_restored.fetch_add(2, Ordering::Relaxed);
        m.restore_rejected.fetch_add(1, Ordering::Relaxed);
        m.probe_calls.fetch_add(6, Ordering::Relaxed);
        m.quarantine_releases.fetch_add(1, Ordering::Relaxed);
        m.preemptive_deadline_sweeps.fetch_add(3, Ordering::Relaxed);
        let v = Json::parse(&m.stats_json("native").to_string()).unwrap();
        assert_eq!(v.get("checkpoints_written").unwrap().as_usize(), Some(5));
        assert_eq!(v.get("slots_restored").unwrap().as_usize(), Some(2));
        assert_eq!(v.get("restore_rejected").unwrap().as_usize(), Some(1));
        assert_eq!(v.get("probe_calls").unwrap().as_usize(), Some(6));
        assert_eq!(v.get("quarantine_releases").unwrap().as_usize(), Some(1));
        assert_eq!(
            v.get("preemptive_deadline_sweeps").unwrap().as_usize(),
            Some(3)
        );
        let r = m.report();
        assert!(r.contains("checkpoints=5"), "{r}");
        assert!(r.contains("restore_rejected=1"), "{r}");
        assert!(r.contains("preemptive_sweeps=3"), "{r}");
    }
}
