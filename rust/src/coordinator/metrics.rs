//! Serving metrics: counters and latency distributions.

use crate::util::stats::Summary;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

/// Engine-wide metrics registry (thread-safe).
#[derive(Default)]
pub struct Metrics {
    pub requests_admitted: AtomicU64,
    pub requests_rejected: AtomicU64,
    pub requests_completed: AtomicU64,
    pub tokens_generated: AtomicU64,
    pub decode_steps: AtomicU64,
    pub prefills: AtomicU64,
    latencies_s: Mutex<Vec<f64>>,
    step_times_s: Mutex<Vec<f64>>,
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics::default()
    }

    pub fn record_latency(&self, secs: f64) {
        self.latencies_s.lock().expect("metrics lock").push(secs);
    }

    pub fn record_step(&self, secs: f64) {
        self.step_times_s.lock().expect("metrics lock").push(secs);
    }

    /// End-to-end request latency summary, if any completed.
    pub fn latency_summary(&self) -> Option<Summary> {
        let l = self.latencies_s.lock().expect("metrics lock");
        (!l.is_empty()).then(|| Summary::from(&l))
    }

    /// Per-decode-step time summary.
    pub fn step_summary(&self) -> Option<Summary> {
        let l = self.step_times_s.lock().expect("metrics lock");
        (!l.is_empty()).then(|| Summary::from(&l))
    }

    /// One-line report for logs and the serve example.
    pub fn report(&self) -> String {
        let steps = self.decode_steps.load(Ordering::Relaxed);
        let toks = self.tokens_generated.load(Ordering::Relaxed);
        let done = self.requests_completed.load(Ordering::Relaxed);
        let rej = self.requests_rejected.load(Ordering::Relaxed);
        let step = self
            .step_summary()
            .map(|s| format!("{:.2}ms", s.mean * 1e3))
            .unwrap_or_else(|| "n/a".into());
        let lat = self
            .latency_summary()
            .map(|s| format!("p50 {:.1}ms p99 {:.1}ms", s.p50 * 1e3, s.p99 * 1e3))
            .unwrap_or_else(|| "n/a".into());
        format!(
            "completed={done} rejected={rej} tokens={toks} steps={steps} \
             step_mean={step} latency {lat}"
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_and_summaries() {
        let m = Metrics::new();
        m.requests_completed.fetch_add(2, Ordering::Relaxed);
        m.record_latency(0.1);
        m.record_latency(0.3);
        m.record_step(0.01);
        let l = m.latency_summary().unwrap();
        assert!((l.mean - 0.2).abs() < 1e-12);
        assert!(m.step_summary().is_some());
        assert!(m.report().contains("completed=2"));
    }

    #[test]
    fn empty_summaries_are_none() {
        let m = Metrics::new();
        assert!(m.latency_summary().is_none());
        assert!(m.report().contains("n/a"));
    }
}
