//! Continuous batcher: a bounded admission queue feeding the engine's
//! fixed decode slots.
//!
//! The decode artifact has a fixed batch dimension (AOT shapes are
//! static), so the engine exposes `max_batch` slots; the batcher admits
//! requests into free slots as earlier requests finish — continuous
//! batching at token granularity, the serving pattern the paper's
//! high-batch AMX advantage (Figs 12/13) presumes.

use super::request::Request;
use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};
use std::time::Duration;

/// Bounded MPSC admission queue with backpressure.
pub struct AdmissionQueue {
    inner: Mutex<Inner>,
    available: Condvar,
    capacity: usize,
}

struct Inner {
    queue: VecDeque<Request>,
    closed: bool,
}

/// Why an admission failed.
#[derive(Debug, PartialEq, Eq)]
pub enum AdmitError {
    /// Queue at capacity — caller should shed load or retry later.
    Full,
    /// Queue shut down.
    Closed,
}

impl AdmissionQueue {
    pub fn new(capacity: usize) -> AdmissionQueue {
        AdmissionQueue {
            inner: Mutex::new(Inner {
                queue: VecDeque::new(),
                closed: false,
            }),
            available: Condvar::new(),
            capacity: capacity.max(1),
        }
    }

    /// Non-blocking admit; rejects when full (backpressure).
    pub fn admit(&self, req: Request) -> Result<(), AdmitError> {
        let mut inner = self.inner.lock().expect("queue lock");
        if inner.closed {
            return Err(AdmitError::Closed);
        }
        if inner.queue.len() >= self.capacity {
            return Err(AdmitError::Full);
        }
        inner.queue.push_back(req);
        self.available.notify_one();
        Ok(())
    }

    /// Pop up to `n` requests, waiting up to `window` for the first one.
    /// Returns an empty vec on timeout, `None` once closed and drained.
    pub fn take_batch(&self, n: usize, window: Duration) -> Option<Vec<Request>> {
        let mut inner = self.inner.lock().expect("queue lock");
        if inner.queue.is_empty() && !inner.closed {
            let (guard, _timeout) = self
                .available
                .wait_timeout(inner, window)
                .expect("queue wait");
            inner = guard;
        }
        if inner.queue.is_empty() {
            return if inner.closed { None } else { Some(Vec::new()) };
        }
        let take = inner.queue.len().min(n.max(1));
        Some(inner.queue.drain(..take).collect())
    }

    /// Close the queue: pending requests still drain, new ones rejected.
    pub fn close(&self) {
        self.inner.lock().expect("queue lock").closed = true;
        self.available.notify_all();
    }

    /// Current depth (diagnostics).
    pub fn depth(&self) -> usize {
        self.inner.lock().expect("queue lock").queue.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc;
    use std::time::Instant;

    fn req(id: u64) -> Request {
        let (tx, _rx) = mpsc::channel();
        // keep receiver alive via leak: tests only inspect queue behaviour
        std::mem::forget(_rx);
        Request {
            id,
            prompt: vec![],
            max_new_tokens: 1,
            arrived: Instant::now(),
            respond: tx,
        }
    }

    #[test]
    fn fifo_order_and_batch_limit() {
        let q = AdmissionQueue::new(10);
        for i in 0..5 {
            q.admit(req(i)).unwrap();
        }
        let batch = q.take_batch(3, Duration::from_millis(1)).unwrap();
        assert_eq!(batch.iter().map(|r| r.id).collect::<Vec<_>>(), vec![0, 1, 2]);
        assert_eq!(q.depth(), 2);
    }

    #[test]
    fn backpressure_when_full() {
        let q = AdmissionQueue::new(2);
        q.admit(req(0)).unwrap();
        q.admit(req(1)).unwrap();
        assert_eq!(q.admit(req(2)), Err(AdmitError::Full));
    }

    #[test]
    fn timeout_returns_empty() {
        let q = AdmissionQueue::new(2);
        let batch = q.take_batch(4, Duration::from_millis(5)).unwrap();
        assert!(batch.is_empty());
    }

    #[test]
    fn close_rejects_and_drains() {
        let q = AdmissionQueue::new(4);
        q.admit(req(0)).unwrap();
        q.close();
        assert_eq!(q.admit(req(1)), Err(AdmitError::Closed));
        // pending request drains
        let batch = q.take_batch(4, Duration::from_millis(1)).unwrap();
        assert_eq!(batch.len(), 1);
        // then the queue reports closed
        assert!(q.take_batch(4, Duration::from_millis(1)).is_none());
    }

    #[test]
    fn concurrent_producers() {
        let q = std::sync::Arc::new(AdmissionQueue::new(100));
        std::thread::scope(|s| {
            for t in 0..4 {
                let q = std::sync::Arc::clone(&q);
                s.spawn(move || {
                    for i in 0..10 {
                        q.admit(req(t * 100 + i)).unwrap();
                    }
                });
            }
        });
        assert_eq!(q.depth(), 40);
    }
}
