//! Continuous batcher: a bounded admission queue feeding the engine's
//! fixed decode slots.
//!
//! The decode artifact has a fixed batch dimension (AOT shapes are
//! static), so the engine exposes `max_batch` slots; the batcher admits
//! requests into free slots as earlier requests finish — continuous
//! batching at token granularity, the serving pattern the paper's
//! high-batch AMX advantage (Figs 12/13) presumes.

use super::request::Request;
use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};
use std::time::Duration;

/// Plan-aware per-request latency budget: the engine's compiled
/// [`crate::models::plan::DecodePlan`] predicts the cost of one decode
/// step (`predicted_step_s`, the sum of every planned linear's modeled
/// time), so a request asking for `n` tokens is predicted to cost
/// `n * per_token_s` seconds of decode. Requests whose prediction
/// exceeds `budget_s` are rejected at admission — before any prefill
/// work — instead of discovered-too-late at completion.
#[derive(Clone, Copy, Debug)]
pub struct LatencyBudget {
    /// Maximum predicted decode seconds a request may cost.
    pub budget_s: f64,
    /// Plan-predicted seconds per generated token.
    pub per_token_s: f64,
}

impl LatencyBudget {
    /// Whether a request for `max_new_tokens` fits the budget. A
    /// non-positive or non-finite per-token prediction disables the
    /// check (admit everything) rather than rejecting everything.
    pub fn admits(&self, max_new_tokens: usize) -> bool {
        if !(self.per_token_s.is_finite() && self.per_token_s > 0.0) {
            return true;
        }
        max_new_tokens as f64 * self.per_token_s <= self.budget_s
    }
}

/// Bounded MPSC admission queue with backpressure.
pub struct AdmissionQueue {
    inner: Mutex<Inner>,
    available: Condvar,
    capacity: usize,
    /// Optional plan-aware admission budget (`None` admits by capacity
    /// alone).
    budget: Option<LatencyBudget>,
}

struct Inner {
    queue: VecDeque<Request>,
    closed: bool,
}

/// Why an admission failed.
#[derive(Debug, PartialEq, Eq)]
pub enum AdmitError {
    /// Queue at capacity — caller should shed load or retry later.
    Full,
    /// Queue shut down.
    Closed,
    /// Predicted decode time exceeds the configured latency budget —
    /// retrying without shrinking `max_new_tokens` will never succeed.
    OverBudget,
}

impl AdmissionQueue {
    pub fn new(capacity: usize) -> AdmissionQueue {
        AdmissionQueue::with_budget(capacity, None)
    }

    /// Queue with an optional plan-aware admission budget.
    pub fn with_budget(capacity: usize, budget: Option<LatencyBudget>) -> AdmissionQueue {
        AdmissionQueue {
            inner: Mutex::new(Inner {
                queue: VecDeque::new(),
                closed: false,
            }),
            available: Condvar::new(),
            capacity: capacity.max(1),
            budget,
        }
    }

    /// The active admission budget, if any.
    pub fn budget(&self) -> Option<LatencyBudget> {
        self.budget
    }

    /// Non-blocking admit; rejects when full (backpressure) or when the
    /// request's predicted decode time blows the latency budget.
    pub fn admit(&self, req: Request) -> Result<(), AdmitError> {
        // Chaos seam (PR 10): an `admit_stall` fault delays *this*
        // admission before the queue lock is taken, so a stalled
        // admission can never block co-admitted requests arriving on
        // other connection threads. Unarmed: one relaxed atomic load.
        crate::fault::on_admit();
        let mut inner = self.inner.lock().expect("queue lock");
        if inner.closed {
            return Err(AdmitError::Closed);
        }
        if let Some(b) = &self.budget {
            if !b.admits(req.max_new_tokens) {
                return Err(AdmitError::OverBudget);
            }
        }
        if inner.queue.len() >= self.capacity {
            return Err(AdmitError::Full);
        }
        inner.queue.push_back(req);
        self.available.notify_one();
        Ok(())
    }

    /// Pop up to `n` requests, waiting up to `window` for the first one.
    /// Returns an empty vec on timeout, `None` once closed and drained.
    pub fn take_batch(&self, n: usize, window: Duration) -> Option<Vec<Request>> {
        let mut inner = self.inner.lock().expect("queue lock");
        if inner.queue.is_empty() && !inner.closed {
            let (guard, _timeout) = self
                .available
                .wait_timeout(inner, window)
                .expect("queue wait");
            inner = guard;
        }
        if inner.queue.is_empty() {
            return if inner.closed { None } else { Some(Vec::new()) };
        }
        let take = inner.queue.len().min(n.max(1));
        Some(inner.queue.drain(..take).collect())
    }

    /// Close the queue: pending requests still drain, new ones rejected.
    pub fn close(&self) {
        self.inner.lock().expect("queue lock").closed = true;
        self.available.notify_all();
    }

    /// Current depth (diagnostics).
    pub fn depth(&self) -> usize {
        self.inner.lock().expect("queue lock").queue.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc;
    use std::time::Instant;

    fn req(id: u64) -> Request {
        req_tokens(id, 1)
    }

    fn req_tokens(id: u64, max_new_tokens: usize) -> Request {
        let (tx, _rx) = mpsc::channel();
        // keep receiver alive via leak: tests only inspect queue behaviour
        std::mem::forget(_rx);
        Request {
            id,
            prompt: vec![],
            max_new_tokens,
            arrived: Instant::now(),
            respond: tx,
            deadline_ms: None,
            cancel: std::sync::Arc::new(std::sync::atomic::AtomicBool::new(false)),
        }
    }

    #[test]
    fn fifo_order_and_batch_limit() {
        let q = AdmissionQueue::new(10);
        for i in 0..5 {
            q.admit(req(i)).unwrap();
        }
        let batch = q.take_batch(3, Duration::from_millis(1)).unwrap();
        assert_eq!(batch.iter().map(|r| r.id).collect::<Vec<_>>(), vec![0, 1, 2]);
        assert_eq!(q.depth(), 2);
    }

    #[test]
    fn backpressure_when_full() {
        let q = AdmissionQueue::new(2);
        q.admit(req(0)).unwrap();
        q.admit(req(1)).unwrap();
        assert_eq!(q.admit(req(2)), Err(AdmitError::Full));
    }

    #[test]
    fn timeout_returns_empty() {
        let q = AdmissionQueue::new(2);
        let batch = q.take_batch(4, Duration::from_millis(5)).unwrap();
        assert!(batch.is_empty());
    }

    #[test]
    fn close_rejects_and_drains() {
        let q = AdmissionQueue::new(4);
        q.admit(req(0)).unwrap();
        q.close();
        assert_eq!(q.admit(req(1)), Err(AdmitError::Closed));
        // pending request drains
        let batch = q.take_batch(4, Duration::from_millis(1)).unwrap();
        assert_eq!(batch.len(), 1);
        // then the queue reports closed
        assert!(q.take_batch(4, Duration::from_millis(1)).is_none());
    }

    #[test]
    fn budget_rejects_over_predicted_requests() {
        // 1 ms/token predicted, 10 ms budget → at most 10 tokens
        let budget = LatencyBudget {
            budget_s: 0.010,
            per_token_s: 0.001,
        };
        assert!(budget.admits(10));
        assert!(!budget.admits(11));
        let q = AdmissionQueue::with_budget(8, Some(budget));
        q.admit(req_tokens(0, 10)).unwrap();
        assert_eq!(q.admit(req_tokens(1, 64)), Err(AdmitError::OverBudget));
        // within-budget traffic still flows after a rejection
        q.admit(req_tokens(2, 5)).unwrap();
        assert_eq!(q.depth(), 2);
    }

    #[test]
    fn degenerate_budget_admits_everything() {
        let budget = LatencyBudget {
            budget_s: 0.010,
            per_token_s: 0.0,
        };
        assert!(budget.admits(usize::MAX / 2));
        let q = AdmissionQueue::with_budget(2, Some(budget));
        q.admit(req_tokens(0, 1_000_000)).unwrap();
        assert_eq!(q.depth(), 1);
        assert!(AdmissionQueue::new(2).budget().is_none());
    }

    #[test]
    fn concurrent_producers() {
        let q = std::sync::Arc::new(AdmissionQueue::new(100));
        std::thread::scope(|s| {
            for t in 0..4 {
                let q = std::sync::Arc::clone(&q);
                s.spawn(move || {
                    for i in 0..10 {
                        q.admit(req(t * 100 + i)).unwrap();
                    }
                });
            }
        });
        assert_eq!(q.depth(), 40);
    }
}
