//! TCP line-protocol server: one JSON request per line, one JSON
//! response per line.
//!
//! Request:  `{"prompt": "...", "max_new_tokens": 32}`
//! Response: `{"id": 1, "text": "...", "tokens": 32,
//!             "latency_ms": 12.3, "per_token_ms": 0.4}`
//! Stats:    `{"stats": true}` → serving counters, the per-decode-step
//!           latency histogram, and which engine path/backend served
//!           each step (see [`crate::coordinator::metrics`]).
//! Errors:   `{"error": "..."}` (malformed request, backpressure, or a
//!           predicted decode time over the `--latency-budget-ms`
//!           admission budget).

use super::batcher::{AdmissionQueue, AdmitError};
use super::metrics::Metrics;
use super::request::Request;
use crate::cfg::json::Json;
use crate::log_info;
use crate::util::error::Result;
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc};
use std::time::Instant;

/// Everything a client handler needs besides its socket.
pub struct ServerCtx {
    pub queue: Arc<AdmissionQueue>,
    pub default_max_tokens: usize,
    /// Engine metrics, served by the `{"stats": true}` request.
    pub metrics: Arc<Metrics>,
    /// Engine description string (path + plan) echoed in stats output.
    pub engine: String,
}

static NEXT_ID: AtomicU64 = AtomicU64::new(1);

/// Parse one request line into a [`Request`] + its response receiver.
pub fn parse_request(
    line: &str,
    default_max_tokens: usize,
) -> Result<(Request, mpsc::Receiver<super::request::Response>), String> {
    request_from_json(&Json::parse(line)?, default_max_tokens)
}

/// Build a [`Request`] from an already-parsed JSON value (the client
/// handler parses each line exactly once).
pub fn request_from_json(
    v: &Json,
    default_max_tokens: usize,
) -> Result<(Request, mpsc::Receiver<super::request::Response>), String> {
    let prompt = v
        .req("prompt")?
        .as_str()
        .ok_or("prompt must be a string")?
        .as_bytes()
        .to_vec();
    let max_new_tokens = v
        .get("max_new_tokens")
        .and_then(|x| x.as_usize())
        .unwrap_or(default_max_tokens);
    let (tx, rx) = mpsc::channel();
    Ok((
        Request {
            id: NEXT_ID.fetch_add(1, Ordering::Relaxed),
            prompt,
            max_new_tokens,
            arrived: Instant::now(),
            respond: tx,
        },
        rx,
    ))
}

/// Format a response line.
pub fn format_response(resp: &super::request::Response) -> String {
    Json::obj(vec![
        ("id", Json::Num(resp.id as f64)),
        ("text", Json::Str(resp.text())),
        ("tokens", Json::Num(resp.tokens.len() as f64)),
        ("latency_ms", Json::Num(resp.total_latency_s * 1e3)),
        ("queue_ms", Json::Num(resp.queue_latency_s * 1e3)),
        ("per_token_ms", Json::Num(resp.per_token_s * 1e3)),
    ])
    .to_string()
}

fn error_line(msg: &str) -> String {
    Json::obj(vec![("error", Json::Str(msg.into()))]).to_string()
}

/// Whether a parsed request is a stats query (`{"stats": true}`).
fn is_stats_request(v: &Json) -> bool {
    v.get("stats").and_then(|s| s.as_bool()).unwrap_or(false)
}

fn handle_client(stream: TcpStream, ctx: Arc<ServerCtx>) {
    let peer = stream
        .peer_addr()
        .map(|a| a.to_string())
        .unwrap_or_default();
    let mut writer = match stream.try_clone() {
        Ok(w) => w,
        Err(_) => return,
    };
    let reader = BufReader::new(stream);
    for line in reader.lines() {
        let Ok(line) = line else { break };
        if line.trim().is_empty() {
            continue;
        }
        // each line is parsed exactly once, then routed
        let reply = match Json::parse(line.trim()) {
            Err(e) => error_line(&e),
            Ok(v) if is_stats_request(&v) => ctx.metrics.stats_json(&ctx.engine).to_string(),
            Ok(v) => match request_from_json(&v, ctx.default_max_tokens) {
                Err(e) => error_line(&e),
                Ok((req, rx)) => match ctx.queue.admit(req) {
                    Err(AdmitError::Full) => {
                        ctx.metrics.requests_rejected.fetch_add(1, Ordering::Relaxed);
                        error_line("queue full, retry later")
                    }
                    Err(AdmitError::OverBudget) => {
                        ctx.metrics.requests_rejected.fetch_add(1, Ordering::Relaxed);
                        error_line("request exceeds latency budget")
                    }
                    Err(AdmitError::Closed) => error_line("server shutting down"),
                    Ok(()) => {
                        ctx.metrics.requests_admitted.fetch_add(1, Ordering::Relaxed);
                        match rx.recv() {
                            Ok(resp) => format_response(&resp),
                            Err(_) => error_line("engine dropped request"),
                        }
                    }
                },
            },
        };
        if writer.write_all(reply.as_bytes()).is_err()
            || writer.write_all(b"\n").is_err()
        {
            break;
        }
    }
    log_info!("client {peer} disconnected");
}

/// Accept loop: one thread per connection (the engine itself is the
/// serial resource; connection concurrency is cheap).
pub fn serve(listener: TcpListener, ctx: ServerCtx) {
    log_info!(
        "listening on {} ({})",
        listener.local_addr().map(|a| a.to_string()).unwrap_or_default(),
        ctx.engine
    );
    let ctx = Arc::new(ctx);
    for stream in listener.incoming() {
        match stream {
            Ok(s) => {
                let c = Arc::clone(&ctx);
                std::thread::spawn(move || handle_client(s, c));
            }
            Err(e) => {
                log_info!("accept error: {e}");
                break;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_request_happy_path() {
        let (req, _rx) =
            parse_request(r#"{"prompt": "hello", "max_new_tokens": 7}"#, 32).unwrap();
        assert_eq!(req.prompt, b"hello");
        assert_eq!(req.max_new_tokens, 7);
    }

    #[test]
    fn parse_request_defaults_max_tokens() {
        let (req, _rx) = parse_request(r#"{"prompt": "x"}"#, 9).unwrap();
        assert_eq!(req.max_new_tokens, 9);
    }

    #[test]
    fn parse_request_rejects_garbage() {
        assert!(parse_request("not json", 1).is_err());
        assert!(parse_request(r#"{"no_prompt": 1}"#, 1).is_err());
        assert!(parse_request(r#"{"prompt": 5}"#, 1).is_err());
    }

    #[test]
    fn stats_request_is_recognized() {
        let parse = |s: &str| Json::parse(s).unwrap();
        assert!(is_stats_request(&parse(r#"{"stats": true}"#)));
        assert!(!is_stats_request(&parse(r#"{"stats": false}"#)));
        assert!(!is_stats_request(&parse(r#"{"prompt": "hi"}"#)));
    }

    #[test]
    fn response_roundtrips_as_json() {
        let resp = super::super::request::Response {
            id: 3,
            tokens: b"ok".to_vec(),
            total_latency_s: 0.5,
            queue_latency_s: 0.1,
            per_token_s: 0.01,
        };
        let line = format_response(&resp);
        let v = Json::parse(&line).unwrap();
        assert_eq!(v.get("text").unwrap().as_str(), Some("ok"));
        assert_eq!(v.get("tokens").unwrap().as_usize(), Some(2));
    }
}
