//! TCP line-protocol server: one JSON request per line, one JSON
//! response per line.
//!
//! Request:  `{"prompt": "...", "max_new_tokens": 32}`
//! Response: `{"id": 1, "text": "...", "tokens": 32,
//!             "latency_ms": 12.3, "per_token_ms": 0.4}`
//! Stats:    `{"stats": true}` → serving counters, the per-decode-step
//!           latency histogram, and which engine path/backend served
//!           each step (see [`crate::coordinator::metrics`]).
//! Errors:   structured `{"error": "...", ...}` objects (malformed
//!           request, backpressure, or a predicted decode time over the
//!           `--latency-budget-ms` admission budget). Backpressure
//!           rejections carry a `retry_after_ms` hint derived from the
//!           plan's predicted step time, so well-behaved clients can
//!           back off for roughly one request's worth of decode.
//!
//! Hardening (PR 9): every accepted socket gets read/write timeouts so
//! a stalled client cannot pin a connection thread forever, requests
//! may carry a `deadline_ms`, and a client that disconnects mid-decode
//! has its slot cancelled (detected by a non-blocking `peek` while the
//! handler waits on the engine).
//!
//! Edge chaos (PR 10): the accept/read/write path is a deterministic
//! fault seam. Connections are numbered by [`crate::fault::on_client_connect`];
//! a pinned `slow_client` fault delays that connection's line handling,
//! a pinned `disconnect` fault truncates the reply crossing a byte
//! threshold and severs the socket, and the read loop runs a
//! byte-progress watchdog ([`LINE_DEADLINE`]) so a slow-loris peer
//! trickling bytes inside the idle timeout still cannot pin its handler
//! thread. Damage is bounded per connection: co-admitted requests on
//! other connections are never stalled. Backpressure `retry_after_ms`
//! hints scale with the live queue depth, so backed-off clients retry
//! proportionally to actual load.

use super::batcher::{AdmissionQueue, AdmitError};
use super::metrics::Metrics;
use super::request::Request;
use crate::cfg::json::Json;
use crate::log_info;
use crate::util::error::Result;
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc};
use std::time::{Duration, Instant};

/// Per-connection socket timeouts: a client that stops sending (read)
/// or stops draining (write) is disconnected rather than pinning its
/// handler thread forever.
pub const READ_TIMEOUT: Duration = Duration::from_secs(30);
pub const WRITE_TIMEOUT: Duration = Duration::from_secs(10);

/// How often the handler probes for client disconnect while waiting on
/// the engine.
const DISCONNECT_PROBE: Duration = Duration::from_millis(100);

/// Byte-progress watchdog: ceiling on how long one request line may
/// take to arrive in full. A slow-loris peer trickling one byte per
/// [`READ_TIMEOUT`] window would otherwise hold its handler thread
/// forever while looking alive.
pub const LINE_DEADLINE: Duration = Duration::from_secs(60);

/// Socket poll granularity while a partial line is pending: short
/// enough to enforce [`LINE_DEADLINE`] promptly, long enough to cost
/// nothing against well-behaved clients (which send whole lines).
const LINE_POLL: Duration = Duration::from_millis(200);

/// Ceiling on one request line's size: a peer streaming an
/// unterminated line cannot grow the handler's buffer without bound.
const MAX_LINE_BYTES: usize = 1 << 20;

/// Everything a client handler needs besides its socket.
pub struct ServerCtx {
    pub queue: Arc<AdmissionQueue>,
    pub default_max_tokens: usize,
    /// Engine metrics, served by the `{"stats": true}` request.
    pub metrics: Arc<Metrics>,
    /// Engine description string (path + plan) echoed in stats output.
    pub engine: String,
    /// Plan-predicted seconds per decode step: the basis of the
    /// `retry_after_ms` backoff hint on queue-full rejections.
    pub predicted_step_s: f64,
}

static NEXT_ID: AtomicU64 = AtomicU64::new(1);

/// Parse one request line into a [`Request`] + its response receiver.
pub fn parse_request(
    line: &str,
    default_max_tokens: usize,
) -> Result<(Request, mpsc::Receiver<super::request::Response>), String> {
    request_from_json(&Json::parse(line)?, default_max_tokens)
}

/// Build a [`Request`] from an already-parsed JSON value (the client
/// handler parses each line exactly once).
pub fn request_from_json(
    v: &Json,
    default_max_tokens: usize,
) -> Result<(Request, mpsc::Receiver<super::request::Response>), String> {
    let prompt = v
        .req("prompt")?
        .as_str()
        .ok_or("prompt must be a string")?
        .as_bytes()
        .to_vec();
    let max_new_tokens = v
        .get("max_new_tokens")
        .and_then(|x| x.as_usize())
        .unwrap_or(default_max_tokens);
    let deadline_ms = v.get("deadline_ms").and_then(|x| x.as_usize()).map(|x| x as u64);
    let (tx, rx) = mpsc::channel();
    Ok((
        Request {
            id: NEXT_ID.fetch_add(1, Ordering::Relaxed),
            prompt,
            max_new_tokens,
            arrived: Instant::now(),
            respond: tx,
            deadline_ms,
            cancel: Arc::new(AtomicBool::new(false)),
        },
        rx,
    ))
}

/// Format a response line. Partial results (deadline, cancellation,
/// engine fault) carry a `partial_reason` field.
pub fn format_response(resp: &super::request::Response) -> String {
    let mut fields = vec![
        ("id", Json::Num(resp.id as f64)),
        ("text", Json::Str(resp.text())),
        ("tokens", Json::Num(resp.tokens.len() as f64)),
        ("latency_ms", Json::Num(resp.total_latency_s * 1e3)),
        ("queue_ms", Json::Num(resp.queue_latency_s * 1e3)),
        ("per_token_ms", Json::Num(resp.per_token_s * 1e3)),
    ];
    if let Some(reason) = &resp.partial_reason {
        fields.push(("partial_reason", Json::Str(reason.clone())));
    }
    Json::obj(fields).to_string()
}

/// Structured error object; backpressure rejections attach a
/// `retry_after_ms` backoff hint.
fn error_json(msg: &str, retry_after_ms: Option<f64>) -> String {
    let mut fields = vec![("error", Json::Str(msg.into()))];
    if let Some(ms) = retry_after_ms {
        fields.push(("retry_after_ms", Json::Num(ms)));
    }
    Json::obj(fields).to_string()
}

/// Whether the peer has closed its end: a non-blocking `peek` that sees
/// EOF. Safe to call while no other thread reads this socket (each
/// connection has exactly one handler thread). `WouldBlock`/`TimedOut`
/// mean "no data yet, still alive"; any other error counts as closed.
fn connection_closed(stream: &TcpStream) -> bool {
    if stream.set_nonblocking(true).is_err() {
        return false;
    }
    let mut byte = [0u8; 1];
    let closed = match stream.peek(&mut byte) {
        Ok(0) => true,
        Ok(_) => false,
        Err(e) => !matches!(
            e.kind(),
            std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
        ),
    };
    let _ = stream.set_nonblocking(false);
    closed
}

/// Whether a parsed request is a stats query (`{"stats": true}`).
fn is_stats_request(v: &Json) -> bool {
    v.get("stats").and_then(|s| s.as_bool()).unwrap_or(false)
}

/// Read one `\n`-terminated line under the byte-progress watchdog:
/// the handler blocks up to [`READ_TIMEOUT`] for a line to *start*,
/// but once its first byte arrives the whole line must land within
/// [`LINE_DEADLINE`] — a slow-loris peer trickling one byte per idle
/// window cannot pin the thread. Returns `None` on EOF, timeout,
/// oversized line, or I/O error; the caller drops the connection.
fn read_line_bounded(reader: &mut BufReader<TcpStream>) -> Option<String> {
    let mut line: Vec<u8> = Vec::new();
    let mut started: Option<Instant> = None;
    loop {
        // idle wait between requests vs. fast poll mid-line
        let timeout = if started.is_none() {
            READ_TIMEOUT
        } else {
            LINE_POLL
        };
        if reader.get_ref().set_read_timeout(Some(timeout)).is_err() {
            return None;
        }
        let buf = match reader.fill_buf() {
            Ok(b) => b,
            Err(e) => {
                let timed_out = matches!(
                    e.kind(),
                    std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                );
                if !timed_out {
                    return None;
                }
                match started {
                    // peer idle between requests: normal read timeout
                    None => return None,
                    Some(t0) if t0.elapsed() >= LINE_DEADLINE => return None,
                    Some(_) => continue,
                }
            }
        };
        if buf.is_empty() {
            return None; // EOF
        }
        if started.is_none() {
            started = Some(Instant::now());
        }
        let (consume, done) = match buf.iter().position(|&b| b == b'\n') {
            Some(nl) => (nl + 1, true),
            None => (buf.len(), false),
        };
        line.extend_from_slice(&buf[..consume]);
        reader.consume(consume);
        if done {
            return Some(String::from_utf8_lossy(&line).into_owned());
        }
        if line.len() > MAX_LINE_BYTES {
            return None;
        }
        if started.map(|t0| t0.elapsed() >= LINE_DEADLINE).unwrap_or(false) {
            return None; // line started but never finished in time
        }
    }
}

/// Write one reply line through the disconnect fault seam. A pinned
/// `disconnect` fault whose byte threshold this write crosses truncates
/// the reply mid-line and severs the socket — modeling a peer (or the
/// path to it) vanishing between two TCP segments. `written` is this
/// connection's cumulative reply byte counter. Returns `false` when the
/// connection is done (severed or write error).
fn write_reply(writer: &mut TcpStream, conn: u64, written: &mut u64, reply: &str) -> bool {
    let mut payload = Vec::with_capacity(reply.len() + 1);
    payload.extend_from_slice(reply.as_bytes());
    payload.push(b'\n');
    if let Some(cut) = crate::fault::on_client_write(conn, *written, payload.len()) {
        let cut = cut.min(payload.len());
        let _ = writer.write_all(&payload[..cut]);
        let _ = writer.flush();
        let _ = writer.shutdown(std::net::Shutdown::Both);
        *written += cut as u64;
        return false;
    }
    if writer.write_all(&payload).is_err() {
        return false;
    }
    *written += payload.len() as u64;
    true
}

fn handle_client(stream: TcpStream, ctx: Arc<ServerCtx>) {
    // number the connection for the deterministic chaos seams; 0 (and
    // one relaxed load) when no fault plan is armed
    let conn = crate::fault::on_client_connect();
    let peer = stream
        .peer_addr()
        .map(|a| a.to_string())
        .unwrap_or_default();
    let mut writer = match stream.try_clone() {
        Ok(w) => w,
        Err(_) => return,
    };
    let mut written = 0u64;
    let mut reader = BufReader::new(stream);
    while let Some(line) = read_line_bounded(&mut reader) {
        if line.trim().is_empty() {
            continue;
        }
        // a pinned `slow_client` fault stalls *this* connection's line
        // here — before any parse or admission — so the damage stays on
        // this handler thread
        crate::fault::on_client_line(conn);
        // each line is parsed exactly once, then routed
        let reply = match Json::parse(line.trim()) {
            Err(e) => error_json(&e, None),
            Ok(v) if is_stats_request(&v) => ctx.metrics.stats_json(&ctx.engine).to_string(),
            Ok(v) => match request_from_json(&v, ctx.default_max_tokens) {
                Err(e) => error_json(&e, None),
                Ok((req, rx)) => {
                    let cancel = Arc::clone(&req.cancel);
                    match ctx.queue.admit(req) {
                        Err(AdmitError::Full) => {
                            ctx.metrics.requests_rejected.fetch_add(1, Ordering::Relaxed);
                            // back off for roughly one request's worth of
                            // predicted decode time, scaled by the queue
                            // depth already ahead of the retry
                            let depth = (ctx.queue.depth() + 1) as f64;
                            let hint = ctx.predicted_step_s
                                * ctx.default_max_tokens as f64
                                * depth
                                * 1e3;
                            error_json("queue full, retry later", Some(hint))
                        }
                        Err(AdmitError::OverBudget) => {
                            ctx.metrics.requests_rejected.fetch_add(1, Ordering::Relaxed);
                            error_json("request exceeds latency budget", None)
                        }
                        Err(AdmitError::Closed) => error_json("server shutting down", None),
                        Ok(()) => {
                            ctx.metrics.requests_admitted.fetch_add(1, Ordering::Relaxed);
                            loop {
                                match rx.recv_timeout(DISCONNECT_PROBE) {
                                    Ok(resp) => break format_response(&resp),
                                    Err(mpsc::RecvTimeoutError::Timeout) => {
                                        // client gone mid-decode → cancel the
                                        // slot; the engine still responds (a
                                        // partial), so this loop terminates.
                                        if !cancel.load(Ordering::Relaxed)
                                            && connection_closed(&writer)
                                        {
                                            cancel.store(true, Ordering::Relaxed);
                                        }
                                    }
                                    Err(mpsc::RecvTimeoutError::Disconnected) => {
                                        break error_json("engine dropped request", None)
                                    }
                                }
                            }
                        }
                    }
                }
            },
        };
        if !write_reply(&mut writer, conn, &mut written, &reply) {
            break;
        }
    }
    log_info!("client {peer} disconnected");
}

/// Accept loop: one thread per connection (the engine itself is the
/// serial resource; connection concurrency is cheap).
pub fn serve(listener: TcpListener, ctx: ServerCtx) {
    log_info!(
        "listening on {} ({})",
        listener.local_addr().map(|a| a.to_string()).unwrap_or_default(),
        ctx.engine
    );
    let ctx = Arc::new(ctx);
    for stream in listener.incoming() {
        match stream {
            Ok(s) => {
                // stalled peers time out instead of pinning the thread
                let _ = s.set_read_timeout(Some(READ_TIMEOUT));
                let _ = s.set_write_timeout(Some(WRITE_TIMEOUT));
                let c = Arc::clone(&ctx);
                std::thread::spawn(move || handle_client(s, c));
            }
            Err(e) => {
                log_info!("accept error: {e}");
                break;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_request_happy_path() {
        let (req, _rx) =
            parse_request(r#"{"prompt": "hello", "max_new_tokens": 7}"#, 32).unwrap();
        assert_eq!(req.prompt, b"hello");
        assert_eq!(req.max_new_tokens, 7);
    }

    #[test]
    fn parse_request_defaults_max_tokens() {
        let (req, _rx) = parse_request(r#"{"prompt": "x"}"#, 9).unwrap();
        assert_eq!(req.max_new_tokens, 9);
    }

    #[test]
    fn parse_request_rejects_garbage() {
        assert!(parse_request("not json", 1).is_err());
        assert!(parse_request(r#"{"no_prompt": 1}"#, 1).is_err());
        assert!(parse_request(r#"{"prompt": 5}"#, 1).is_err());
    }

    #[test]
    fn stats_request_is_recognized() {
        let parse = |s: &str| Json::parse(s).unwrap();
        assert!(is_stats_request(&parse(r#"{"stats": true}"#)));
        assert!(!is_stats_request(&parse(r#"{"stats": false}"#)));
        assert!(!is_stats_request(&parse(r#"{"prompt": "hi"}"#)));
    }

    #[test]
    fn response_roundtrips_as_json() {
        let resp = super::super::request::Response {
            id: 3,
            tokens: b"ok".to_vec(),
            total_latency_s: 0.5,
            queue_latency_s: 0.1,
            per_token_s: 0.01,
            partial_reason: None,
        };
        let line = format_response(&resp);
        let v = Json::parse(&line).unwrap();
        assert_eq!(v.get("text").unwrap().as_str(), Some("ok"));
        assert_eq!(v.get("tokens").unwrap().as_usize(), Some(2));
        assert!(v.get("partial_reason").is_none(), "complete → no reason field");
        let partial = super::super::request::Response {
            partial_reason: Some("deadline".into()),
            ..resp
        };
        let v = Json::parse(&format_response(&partial)).unwrap();
        assert_eq!(v.get("partial_reason").unwrap().as_str(), Some("deadline"));
    }

    #[test]
    fn parse_request_reads_deadline_and_cancel_starts_clear() {
        let (req, _rx) =
            parse_request(r#"{"prompt": "x", "deadline_ms": 250}"#, 8).unwrap();
        assert_eq!(req.deadline_ms, Some(250));
        assert!(!req.cancel.load(Ordering::Relaxed));
        let (req, _rx) = parse_request(r#"{"prompt": "x"}"#, 8).unwrap();
        assert_eq!(req.deadline_ms, None);
    }

    #[test]
    fn error_json_is_structured() {
        let v = Json::parse(&error_json("queue full, retry later", Some(12.5))).unwrap();
        assert_eq!(v.get("error").unwrap().as_str(), Some("queue full, retry later"));
        assert!((v.get("retry_after_ms").unwrap().as_f64().unwrap() - 12.5).abs() < 1e-9);
        let v = Json::parse(&error_json("bad request", None)).unwrap();
        assert!(v.get("retry_after_ms").is_none());
    }
}
