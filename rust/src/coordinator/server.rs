//! TCP line-protocol server: one JSON request per line, one JSON
//! response per line.
//!
//! Request:  `{"prompt": "...", "max_new_tokens": 32}`
//! Response: `{"id": 1, "text": "...", "tokens": 32,
//!             "latency_ms": 12.3, "per_token_ms": 0.4}`
//! Stats:    `{"stats": true}` → serving counters, the per-decode-step
//!           latency histogram, and which engine path/backend served
//!           each step (see [`crate::coordinator::metrics`]).
//! Errors:   structured `{"error": "...", ...}` objects (malformed
//!           request, backpressure, or a predicted decode time over the
//!           `--latency-budget-ms` admission budget). Backpressure
//!           rejections carry a `retry_after_ms` hint derived from the
//!           plan's predicted step time, so well-behaved clients can
//!           back off for roughly one request's worth of decode.
//!
//! Hardening (PR 9): every accepted socket gets read/write timeouts so
//! a stalled client cannot pin a connection thread forever, requests
//! may carry a `deadline_ms`, and a client that disconnects mid-decode
//! has its slot cancelled (detected by a non-blocking `peek` while the
//! handler waits on the engine).

use super::batcher::{AdmissionQueue, AdmitError};
use super::metrics::Metrics;
use super::request::Request;
use crate::cfg::json::Json;
use crate::log_info;
use crate::util::error::Result;
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc};
use std::time::{Duration, Instant};

/// Per-connection socket timeouts: a client that stops sending (read)
/// or stops draining (write) is disconnected rather than pinning its
/// handler thread forever.
pub const READ_TIMEOUT: Duration = Duration::from_secs(30);
pub const WRITE_TIMEOUT: Duration = Duration::from_secs(10);

/// How often the handler probes for client disconnect while waiting on
/// the engine.
const DISCONNECT_PROBE: Duration = Duration::from_millis(100);

/// Everything a client handler needs besides its socket.
pub struct ServerCtx {
    pub queue: Arc<AdmissionQueue>,
    pub default_max_tokens: usize,
    /// Engine metrics, served by the `{"stats": true}` request.
    pub metrics: Arc<Metrics>,
    /// Engine description string (path + plan) echoed in stats output.
    pub engine: String,
    /// Plan-predicted seconds per decode step: the basis of the
    /// `retry_after_ms` backoff hint on queue-full rejections.
    pub predicted_step_s: f64,
}

static NEXT_ID: AtomicU64 = AtomicU64::new(1);

/// Parse one request line into a [`Request`] + its response receiver.
pub fn parse_request(
    line: &str,
    default_max_tokens: usize,
) -> Result<(Request, mpsc::Receiver<super::request::Response>), String> {
    request_from_json(&Json::parse(line)?, default_max_tokens)
}

/// Build a [`Request`] from an already-parsed JSON value (the client
/// handler parses each line exactly once).
pub fn request_from_json(
    v: &Json,
    default_max_tokens: usize,
) -> Result<(Request, mpsc::Receiver<super::request::Response>), String> {
    let prompt = v
        .req("prompt")?
        .as_str()
        .ok_or("prompt must be a string")?
        .as_bytes()
        .to_vec();
    let max_new_tokens = v
        .get("max_new_tokens")
        .and_then(|x| x.as_usize())
        .unwrap_or(default_max_tokens);
    let deadline_ms = v.get("deadline_ms").and_then(|x| x.as_usize()).map(|x| x as u64);
    let (tx, rx) = mpsc::channel();
    Ok((
        Request {
            id: NEXT_ID.fetch_add(1, Ordering::Relaxed),
            prompt,
            max_new_tokens,
            arrived: Instant::now(),
            respond: tx,
            deadline_ms,
            cancel: Arc::new(AtomicBool::new(false)),
        },
        rx,
    ))
}

/// Format a response line. Partial results (deadline, cancellation,
/// engine fault) carry a `partial_reason` field.
pub fn format_response(resp: &super::request::Response) -> String {
    let mut fields = vec![
        ("id", Json::Num(resp.id as f64)),
        ("text", Json::Str(resp.text())),
        ("tokens", Json::Num(resp.tokens.len() as f64)),
        ("latency_ms", Json::Num(resp.total_latency_s * 1e3)),
        ("queue_ms", Json::Num(resp.queue_latency_s * 1e3)),
        ("per_token_ms", Json::Num(resp.per_token_s * 1e3)),
    ];
    if let Some(reason) = &resp.partial_reason {
        fields.push(("partial_reason", Json::Str(reason.clone())));
    }
    Json::obj(fields).to_string()
}

/// Structured error object; backpressure rejections attach a
/// `retry_after_ms` backoff hint.
fn error_json(msg: &str, retry_after_ms: Option<f64>) -> String {
    let mut fields = vec![("error", Json::Str(msg.into()))];
    if let Some(ms) = retry_after_ms {
        fields.push(("retry_after_ms", Json::Num(ms)));
    }
    Json::obj(fields).to_string()
}

/// Whether the peer has closed its end: a non-blocking `peek` that sees
/// EOF. Safe to call while no other thread reads this socket (each
/// connection has exactly one handler thread). `WouldBlock`/`TimedOut`
/// mean "no data yet, still alive"; any other error counts as closed.
fn connection_closed(stream: &TcpStream) -> bool {
    if stream.set_nonblocking(true).is_err() {
        return false;
    }
    let mut byte = [0u8; 1];
    let closed = match stream.peek(&mut byte) {
        Ok(0) => true,
        Ok(_) => false,
        Err(e) => !matches!(
            e.kind(),
            std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
        ),
    };
    let _ = stream.set_nonblocking(false);
    closed
}

/// Whether a parsed request is a stats query (`{"stats": true}`).
fn is_stats_request(v: &Json) -> bool {
    v.get("stats").and_then(|s| s.as_bool()).unwrap_or(false)
}

fn handle_client(stream: TcpStream, ctx: Arc<ServerCtx>) {
    let peer = stream
        .peer_addr()
        .map(|a| a.to_string())
        .unwrap_or_default();
    let mut writer = match stream.try_clone() {
        Ok(w) => w,
        Err(_) => return,
    };
    let reader = BufReader::new(stream);
    for line in reader.lines() {
        let Ok(line) = line else { break };
        if line.trim().is_empty() {
            continue;
        }
        // each line is parsed exactly once, then routed
        let reply = match Json::parse(line.trim()) {
            Err(e) => error_json(&e, None),
            Ok(v) if is_stats_request(&v) => ctx.metrics.stats_json(&ctx.engine).to_string(),
            Ok(v) => match request_from_json(&v, ctx.default_max_tokens) {
                Err(e) => error_json(&e, None),
                Ok((req, rx)) => {
                    let cancel = Arc::clone(&req.cancel);
                    match ctx.queue.admit(req) {
                        Err(AdmitError::Full) => {
                            ctx.metrics.requests_rejected.fetch_add(1, Ordering::Relaxed);
                            // back off for roughly one request's worth of
                            // predicted decode time
                            let hint =
                                ctx.predicted_step_s * ctx.default_max_tokens as f64 * 1e3;
                            error_json("queue full, retry later", Some(hint))
                        }
                        Err(AdmitError::OverBudget) => {
                            ctx.metrics.requests_rejected.fetch_add(1, Ordering::Relaxed);
                            error_json("request exceeds latency budget", None)
                        }
                        Err(AdmitError::Closed) => error_json("server shutting down", None),
                        Ok(()) => {
                            ctx.metrics.requests_admitted.fetch_add(1, Ordering::Relaxed);
                            loop {
                                match rx.recv_timeout(DISCONNECT_PROBE) {
                                    Ok(resp) => break format_response(&resp),
                                    Err(mpsc::RecvTimeoutError::Timeout) => {
                                        // client gone mid-decode → cancel the
                                        // slot; the engine still responds (a
                                        // partial), so this loop terminates.
                                        if !cancel.load(Ordering::Relaxed)
                                            && connection_closed(&writer)
                                        {
                                            cancel.store(true, Ordering::Relaxed);
                                        }
                                    }
                                    Err(mpsc::RecvTimeoutError::Disconnected) => {
                                        break error_json("engine dropped request", None)
                                    }
                                }
                            }
                        }
                    }
                }
            },
        };
        if writer.write_all(reply.as_bytes()).is_err()
            || writer.write_all(b"\n").is_err()
        {
            break;
        }
    }
    log_info!("client {peer} disconnected");
}

/// Accept loop: one thread per connection (the engine itself is the
/// serial resource; connection concurrency is cheap).
pub fn serve(listener: TcpListener, ctx: ServerCtx) {
    log_info!(
        "listening on {} ({})",
        listener.local_addr().map(|a| a.to_string()).unwrap_or_default(),
        ctx.engine
    );
    let ctx = Arc::new(ctx);
    for stream in listener.incoming() {
        match stream {
            Ok(s) => {
                // stalled peers time out instead of pinning the thread
                let _ = s.set_read_timeout(Some(READ_TIMEOUT));
                let _ = s.set_write_timeout(Some(WRITE_TIMEOUT));
                let c = Arc::clone(&ctx);
                std::thread::spawn(move || handle_client(s, c));
            }
            Err(e) => {
                log_info!("accept error: {e}");
                break;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_request_happy_path() {
        let (req, _rx) =
            parse_request(r#"{"prompt": "hello", "max_new_tokens": 7}"#, 32).unwrap();
        assert_eq!(req.prompt, b"hello");
        assert_eq!(req.max_new_tokens, 7);
    }

    #[test]
    fn parse_request_defaults_max_tokens() {
        let (req, _rx) = parse_request(r#"{"prompt": "x"}"#, 9).unwrap();
        assert_eq!(req.max_new_tokens, 9);
    }

    #[test]
    fn parse_request_rejects_garbage() {
        assert!(parse_request("not json", 1).is_err());
        assert!(parse_request(r#"{"no_prompt": 1}"#, 1).is_err());
        assert!(parse_request(r#"{"prompt": 5}"#, 1).is_err());
    }

    #[test]
    fn stats_request_is_recognized() {
        let parse = |s: &str| Json::parse(s).unwrap();
        assert!(is_stats_request(&parse(r#"{"stats": true}"#)));
        assert!(!is_stats_request(&parse(r#"{"stats": false}"#)));
        assert!(!is_stats_request(&parse(r#"{"prompt": "hi"}"#)));
    }

    #[test]
    fn response_roundtrips_as_json() {
        let resp = super::super::request::Response {
            id: 3,
            tokens: b"ok".to_vec(),
            total_latency_s: 0.5,
            queue_latency_s: 0.1,
            per_token_s: 0.01,
            partial_reason: None,
        };
        let line = format_response(&resp);
        let v = Json::parse(&line).unwrap();
        assert_eq!(v.get("text").unwrap().as_str(), Some("ok"));
        assert_eq!(v.get("tokens").unwrap().as_usize(), Some(2));
        assert!(v.get("partial_reason").is_none(), "complete → no reason field");
        let partial = super::super::request::Response {
            partial_reason: Some("deadline".into()),
            ..resp
        };
        let v = Json::parse(&format_response(&partial)).unwrap();
        assert_eq!(v.get("partial_reason").unwrap().as_str(), Some("deadline"));
    }

    #[test]
    fn parse_request_reads_deadline_and_cancel_starts_clear() {
        let (req, _rx) =
            parse_request(r#"{"prompt": "x", "deadline_ms": 250}"#, 8).unwrap();
        assert_eq!(req.deadline_ms, Some(250));
        assert!(!req.cancel.load(Ordering::Relaxed));
        let (req, _rx) = parse_request(r#"{"prompt": "x"}"#, 8).unwrap();
        assert_eq!(req.deadline_ms, None);
    }

    #[test]
    fn error_json_is_structured() {
        let v = Json::parse(&error_json("queue full, retry later", Some(12.5))).unwrap();
        assert_eq!(v.get("error").unwrap().as_str(), Some("queue full, retry later"));
        assert!((v.get("retry_after_ms").unwrap().as_f64().unwrap() - 12.5).abs() < 1e-9);
        let v = Json::parse(&error_json("bad request", None)).unwrap();
        assert!(v.get("retry_after_ms").is_none());
    }
}
