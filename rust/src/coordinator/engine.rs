//! The generation engine: continuous batching over one of two
//! interchangeable decode paths.
//!
//! * **Native** (the default): the model is compiled into a
//!   [`DecodePlan`] at load — one cached kernel [`Selection`] per
//!   distinct linear shape, weights pre-packed per layer — and every
//!   prefill/decode projection runs the selected sparse/dense kernel
//!   through the [`crate::backend`] dispatch layer end-to-end, with
//!   attention on the split KV cache (`kvcache/attention.rs`). This is
//!   the paper's serving configuration: all linears on the custom
//!   kernels, preprocessing once at load (§7).
//! * **PJRT**: the AOT `prefill`/`decode_step` executables (requires
//!   the `pjrt` feature + a compiled artifact bundle). Kept as the
//!   cross-check path; select it with `--engine pjrt`.
//!
//! Both paths share the same continuous-batching slots: the engine owns
//! `decode_batch` slots, each holding one in-flight request's cache
//! state; finished slots are refilled from the admission queue every
//! step. Per-slot positions make mixed-progress batches safe.
//!
//! Weight handling follows the paper's deployment: parameters are
//! magnitude-pruned to the configured sparsity at load time, then kept
//! static for the process lifetime (preprocessing happens once — §7).

use super::batcher::AdmissionQueue;
use super::metrics::Metrics;
use super::request::{Request, Response};
use crate::amx::EventCounters;
use crate::backend::{Backend, BackendRegistry, Dtype, GemmShape, Selection};
use crate::cfg::RuntimeConfig;
use crate::kvcache::cache::KvCache;
use crate::log_info;
use crate::models::plan::{DecodePlan, NativeModel, RegimeBatches};
use crate::models::tinyforward::TinyModel;
use crate::runtime::artifact::Bundle;
use crate::runtime::executor::{lit_f32, lit_i32, to_f32, Executable, Literal, Runtime};
use crate::sparse::prune::magnitude_prune_inplace;
use crate::util::error::{anyhow, Context, Result};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Static model geometry (from the artifact manifest on the PJRT path,
/// from the loaded weights + runtime config on the native path).
#[derive(Clone, Copy, Debug)]
pub struct Geometry {
    pub layers: usize,
    pub kv_heads: usize,
    pub head_dim: usize,
    pub max_ctx: usize,
    pub vocab: usize,
    pub decode_batch: usize,
    pub prefill_len: usize,
}

impl Geometry {
    pub fn from_bundle(bundle: &Bundle) -> Result<Geometry> {
        Ok(Geometry {
            layers: bundle.config_usize("layers")?,
            kv_heads: bundle.config_usize("kv_heads")?,
            head_dim: bundle.config_usize("head_dim")?,
            max_ctx: bundle.config_usize("max_ctx")?,
            vocab: bundle.config_usize("vocab")?,
            decode_batch: bundle
                .manifest
                .req("decode_batch")
                .map_err(|e| anyhow!(e))?
                .as_usize()
                .ok_or_else(|| anyhow!("decode_batch"))?,
            prefill_len: bundle
                .manifest
                .req("prefill_len")
                .map_err(|e| anyhow!(e))?
                .as_usize()
                .ok_or_else(|| anyhow!("prefill_len"))?,
        })
    }

    fn for_model(model: &TinyModel, cfg: &RuntimeConfig) -> Geometry {
        Geometry {
            layers: model.layers.len(),
            kv_heads: model.kv_heads,
            head_dim: model.head_dim,
            max_ctx: cfg.max_ctx,
            vocab: model.vocab,
            decode_batch: cfg.max_batch,
            prefill_len: cfg.max_ctx,
        }
    }

    fn cache_elems(&self) -> usize {
        self.layers * self.decode_batch * self.kv_heads * self.max_ctx * self.head_dim
    }
}

/// Consecutive disagreeing steps before the decode regime may flip:
/// occupancy oscillating around the fuse threshold (slots finishing and
/// refilling every step) would otherwise bounce looped↔fused each step.
const REGIME_DWELL_STEPS: u32 = 4;

/// Decode steps between shadow-probe rounds while any backend sits in
/// quarantine (probation, PR 10). Probes are mirrored GEMMs that are
/// never served, so the cadence only trades release latency against
/// probe overhead; healthy engines skip the whole path on one relaxed
/// atomic load.
const PROBE_EVERY_STEPS: u64 = 4;

/// Hysteresis on the looped↔fused decode-regime pick. The instantaneous
/// pick (`active > 1 && fused_batch > 1`) is fed in every step; the
/// regime actually served only flips after [`REGIME_DWELL_STEPS`]
/// consecutive steps of disagreement. Holding either regime is safe:
/// the fused path at one active slot runs `decode_step_batched` with
/// `nb = 1` and the looped path at several slots runs them serially —
/// both bit-exact, only differently amortized.
#[derive(Debug, Default)]
struct RegimeHysteresis {
    /// Regime currently in effect (`None` until the first step adopts
    /// the instantaneous pick without counting a flip).
    current: Option<bool>,
    /// Consecutive steps the instantaneous pick disagreed with
    /// `current`.
    dwell: u32,
}

impl RegimeHysteresis {
    /// Feed one step's instantaneous pick; returns `(regime_in_effect,
    /// flipped_this_step)`.
    fn decide(&mut self, want: bool) -> (bool, bool) {
        match self.current {
            None => {
                self.current = Some(want);
                (want, false)
            }
            Some(cur) if cur == want => {
                self.dwell = 0;
                (cur, false)
            }
            Some(cur) => {
                self.dwell += 1;
                if self.dwell >= REGIME_DWELL_STEPS {
                    self.current = Some(want);
                    self.dwell = 0;
                    (want, true)
                } else {
                    (cur, false)
                }
            }
        }
    }
}

/// One decode slot's state.
struct Slot {
    req: Option<Request>,
    generated: Vec<u8>,
    /// Valid cache positions for this slot.
    cache_len: usize,
    /// Next absolute position to feed.
    pos: usize,
    /// Current token to feed.
    token: u8,
    started: Option<Instant>,
    decode_time: f64,
}

impl Slot {
    fn empty() -> Slot {
        Slot {
            req: None,
            generated: Vec::new(),
            cache_len: 0,
            pos: 0,
            token: 0,
            started: None,
            decode_time: 0.0,
        }
    }

    fn active(&self) -> bool {
        self.req.is_some()
    }
}

/// The PJRT decode path: AOT executables + host-mirrored caches.
struct PjrtPath {
    decode: Executable,
    prefill: Executable,
    /// Pruned parameter literals, fed to every call (PJRT copies
    /// internally; the tiny model makes that cheap).
    param_data: Vec<(Vec<f32>, Vec<i64>)>,
    /// KV caches as host vectors, updated functionally from the artifact
    /// outputs: `[layers, B, kvh, max_ctx, hd]`.
    k_cache: Vec<f32>,
    v_cache: Vec<f32>,
}

impl PjrtPath {
    fn param_literals(&self) -> Result<Vec<Literal>> {
        self.param_data
            .iter()
            .map(|(data, dims)| lit_f32(data, dims))
            .collect()
    }
}

/// The native decode path: plan-compiled model + per-slot KV caches.
struct NativePath {
    model: NativeModel,
    /// One split cache (sparse static + dense tail) per decode slot.
    caches: Vec<Option<KvCache>>,
    /// Accumulated kernel events across prefills and decode steps.
    ctr: EventCounters,
    /// The registry the plan was compiled against, kept live for
    /// degraded-mode re-planning: backend quarantines recorded here
    /// steer the next [`Engine::recompile_plan`] onto the survivors.
    registry: BackendRegistry,
    /// Regime batches the plan was compiled at; recompiles reuse them
    /// (degraded mode changes backends, never geometry).
    batches: RegimeBatches,
}

enum EnginePath {
    Pjrt(PjrtPath),
    Native(NativePath),
}

/// The serving engine.
pub struct Engine {
    geo: Geometry,
    slots: Vec<Slot>,
    pub metrics: Arc<Metrics>,
    /// Representative load-time selection: the LM-head plan on the
    /// native path (the widest linear of a decode step), the resolved
    /// ancillary backend on the PJRT path. Per-layer plans live in
    /// [`Engine::plan`].
    selection: Selection,
    /// Precomputed `"<path>/<backend>"` metrics label (constant for the
    /// engine's lifetime; avoids per-step allocation).
    step_label: String,
    /// Distinct sharded backends the plan dispatches through; their
    /// per-shard timings are drained into [`Metrics`] after every step.
    /// Empty when the plan selected no sharded kernel.
    shard_backends: Vec<Backend>,
    /// Distinct persistent worker pools reachable from the plan
    /// (sharded linear backends + the attention scatter pool); their
    /// respawn counters drain into [`Metrics`] after every step.
    pools: Vec<Arc<crate::shard::WorkerPool>>,
    /// The attention scatter pool chosen at load, re-wired into the
    /// model after every plan recompile.
    attn_pool: Option<Arc<crate::shard::WorkerPool>>,
    /// Dwell-counted looped↔fused regime state (native path; PJRT's
    /// artifact always runs the full batch).
    hysteresis: RegimeHysteresis,
    /// Productive steps since the last checkpoint write (counts toward
    /// `cfg.checkpoint_every_steps`; unused when checkpointing is off).
    ckpt_tick: u64,
    /// Steps observed while some backend was quarantined (drives the
    /// [`PROBE_EVERY_STEPS`] probation cadence).
    probe_tick: u64,
    cfg: RuntimeConfig,
    path: EnginePath,
}

impl Engine {
    /// Load an engine from an artifact bundle, honouring
    /// `cfg.engine`: `auto`/`native` serve through the plan-compiled
    /// native path (the runtime handle is unused), `pjrt` compiles the
    /// AOT executables.
    pub fn load(rt: &Runtime, bundle: &Bundle, cfg: RuntimeConfig) -> Result<Engine> {
        if cfg.engine.resolved_native() {
            Engine::load_native(bundle, cfg)
        } else {
            Engine::load_pjrt(rt, bundle, cfg)
        }
    }

    /// Load the native engine: weights from the bundle, pruned to the
    /// configured sparsity, plan-compiled against the probed registry.
    pub fn load_native(bundle: &Bundle, cfg: RuntimeConfig) -> Result<Engine> {
        let model = TinyModel::from_bundle(bundle)?;
        Engine::from_tiny_model(model, cfg)
    }

    /// Build the native engine directly from a loaded model (tests and
    /// benches construct synthetic models without artifacts on disk).
    /// Prunes projections and LM head to `cfg.weight_sparsity`, then
    /// compiles the [`DecodePlan`] — selection runs here, never in the
    /// token loop.
    pub fn from_tiny_model(mut model: TinyModel, cfg: RuntimeConfig) -> Result<Engine> {
        if cfg.weight_sparsity > 0.0 {
            model.prune_weights(cfg.weight_sparsity);
            // the PJRT load prunes every 2-D matrix except the embedding;
            // match it (norm gains and embeddings stay dense)
            magnitude_prune_inplace(&mut model.lm_head, cfg.weight_sparsity);
        }
        let geo = Geometry::for_model(&model, &cfg);
        let topo = crate::shard::NumaTopology::detect();
        let shards = cfg.shards.resolve(&topo);
        let registry = BackendRegistry::probe().with_shards(shards, topo);
        // dual-regime plan: batch-1 decode, fused decode at the resolved
        // fuse batch, and prefill at the prompt geometry — all selections
        // fixed here, never in the token loop
        let fuse = cfg.max_batch_fuse.resolve(cfg.max_batch);
        let batches = RegimeBatches {
            decode_fused: fuse,
            prefill: geo.prefill_len,
        };
        let mut native = NativeModel::with_regimes(
            &registry,
            cfg.backend,
            model,
            cfg.weight_sparsity,
            batches,
        );
        let selection = native.plan.lm_head.selection.clone();
        log_info!(
            "engine native: {} (caps {}, {} NUMA node(s), shards={}, \
             directive backend={} engine={})",
            native.plan.describe(),
            registry.caps().describe(),
            topo.nodes,
            shards,
            cfg.backend,
            cfg.engine
        );
        let slots = (0..geo.decode_batch).map(|_| Slot::empty()).collect();
        let caches = (0..geo.decode_batch).map(|_| None).collect();
        let shard_backends = collect_shard_backends(&native.plan);
        // Fused-attention scatter pool: independent (slot, kv-head)
        // groups fan out over the sharded backends' persistent worker
        // pool when the plan has one; otherwise spin one up on
        // multi-shard hosts. (The model ignores it when the attention
        // backend is itself sharded — nested scatter would deadlock.)
        let attn_pool = shard_backends
            .iter()
            .find_map(|b| b.worker_pool())
            .or_else(|| {
                (shards > 1)
                    .then(|| Arc::new(crate::shard::WorkerPool::with_topology(shards, &topo)))
            });
        native.set_attention_pool(attn_pool.clone());
        let pools = collect_pools(&shard_backends, attn_pool.as_ref());
        Ok(Engine {
            geo,
            slots,
            metrics: Arc::new(Metrics::new()),
            step_label: format!("native/{}", selection.backend.name()),
            selection,
            shard_backends,
            pools,
            attn_pool,
            hysteresis: RegimeHysteresis::default(),
            ckpt_tick: 0,
            probe_tick: 0,
            cfg,
            path: EnginePath::Native(NativePath {
                model: native,
                caches,
                ctr: EventCounters::default(),
                registry,
                batches,
            }),
        })
    }

    /// Load the PJRT engine: artifacts, pruned weight literals, compiled
    /// executables, resolved ancillary backend.
    pub fn load_pjrt(rt: &Runtime, bundle: &Bundle, cfg: RuntimeConfig) -> Result<Engine> {
        let geo = Geometry::from_bundle(bundle)?;
        let decode = rt.load_hlo(&bundle.hlo_path("decode_step"))?;
        let prefill = rt.load_hlo(&bundle.hlo_path("prefill"))?;
        let mut param_data = Vec::with_capacity(bundle.params.len());
        for t in &bundle.params {
            let mut data = t.data.clone();
            // prune matrices only (norm gains and embeddings stay dense,
            // like the paper's linear-layer-only pruning)
            if t.shape.len() == 2 && cfg.weight_sparsity > 0.0 && t.name != "emb" {
                magnitude_prune_inplace(&mut data, cfg.weight_sparsity);
            }
            let dims: Vec<i64> = t.shape.iter().map(|&d| d as i64).collect();
            param_data.push((data, dims));
        }
        // resolve the kernel backend against the model's widest linear
        // (hidden × vocab, the LM head) — the shape that dominates a
        // tiny-model decode step. Fallback reconstructs hidden from the
        // *query* heads (kv_heads undersizes it under GQA).
        let hidden = bundle
            .config_usize("hidden")
            .or_else(|_| bundle.config_usize("heads").map(|h| h * geo.head_dim))
            .unwrap_or(geo.head_dim * geo.kv_heads);
        let registry = BackendRegistry::probe();
        let shape = GemmShape::new(geo.decode_batch, hidden, geo.vocab);
        let selection = registry.resolve(cfg.backend, shape, cfg.weight_sparsity, Dtype::Bf16);
        log_info!(
            "engine pjrt: ancillary backend {} (caps {}, directive {})",
            selection.describe(),
            registry.caps().describe(),
            cfg.backend
        );
        let slots = (0..geo.decode_batch).map(|_| Slot::empty()).collect();
        Ok(Engine {
            path: EnginePath::Pjrt(PjrtPath {
                decode,
                prefill,
                param_data,
                k_cache: vec![0.0; geo.cache_elems()],
                v_cache: vec![0.0; geo.cache_elems()],
            }),
            geo,
            slots,
            metrics: Arc::new(Metrics::new()),
            step_label: "pjrt/xla".to_string(),
            selection,
            shard_backends: Vec::new(),
            pools: Vec::new(),
            attn_pool: None,
            hysteresis: RegimeHysteresis::default(),
            ckpt_tick: 0,
            probe_tick: 0,
            cfg,
        })
    }

    pub fn geometry(&self) -> Geometry {
        self.geo
    }

    /// The kernel backend this engine dispatches its widest linear
    /// through (per-layer plans may differ — see [`Engine::plan`]).
    pub fn backend(&self) -> &Backend {
        &self.selection.backend
    }

    /// The load-time representative selection (plan + modeled time).
    pub fn selection(&self) -> &Selection {
        &self.selection
    }

    /// Plan-predicted seconds for one decode step: the compiled plan's
    /// per-linear cost sum on the native path; the representative
    /// LM-head selection on PJRT (no per-layer plan exists there).
    /// Drives the `--latency-budget-ms` admission check.
    pub fn predicted_step_s(&self) -> f64 {
        match &self.path {
            EnginePath::Native(np) => np.model.plan.predicted_step_s(),
            EnginePath::Pjrt(_) => self.selection.predicted_s,
        }
    }

    /// Which decode path serves tokens: `"native"` or `"pjrt"`.
    pub fn engine_path(&self) -> &'static str {
        match self.path {
            EnginePath::Native(_) => "native",
            EnginePath::Pjrt(_) => "pjrt",
        }
    }

    /// The compiled per-layer plan (native path only).
    pub fn plan(&self) -> Option<&DecodePlan> {
        match &self.path {
            EnginePath::Native(np) => Some(&np.model.plan),
            EnginePath::Pjrt(_) => None,
        }
    }

    /// Kernel events accumulated by the native path (empty on PJRT).
    pub fn kernel_events(&self) -> EventCounters {
        match &self.path {
            EnginePath::Native(np) => np.ctr.clone(),
            EnginePath::Pjrt(_) => EventCounters::default(),
        }
    }

    /// Slots currently holding an in-flight request.
    pub fn active_slots(&self) -> usize {
        self.slots.iter().filter(|s| s.active()).count()
    }

    /// Bytes resident in per-slot KV caches (native path; 0 on PJRT,
    /// whose monolithic cache never shrinks). Cancelled and finished
    /// slots free their cache, so chaos tests assert this returns to 0.
    pub fn kv_resident_bytes(&self) -> usize {
        match &self.path {
            EnginePath::Native(np) => np.caches.iter().flatten().map(|c| c.bytes()).sum(),
            EnginePath::Pjrt(_) => 0,
        }
    }

    /// The registry the native plan was compiled against (tests assert
    /// quarantine state through this; `None` on PJRT).
    pub fn registry(&self) -> Option<&BackendRegistry> {
        match &self.path {
            EnginePath::Native(np) => Some(&np.registry),
            EnginePath::Pjrt(_) => None,
        }
    }

    /// One-line engine description for banners and the stats endpoint.
    pub fn describe(&self) -> String {
        match &self.path {
            EnginePath::Native(np) => format!("native [{}]", np.model.plan.describe()),
            EnginePath::Pjrt(_) => format!("pjrt [ancillary {}]", self.selection.describe()),
        }
    }

    /// Admit new requests into free slots (prefilling their caches).
    fn fill_slots(&mut self, queue: &AdmissionQueue) -> Result<bool> {
        let free: Vec<usize> = (0..self.slots.len())
            .filter(|&i| !self.slots[i].active())
            .collect();
        if free.is_empty() {
            return Ok(true);
        }
        let window = Duration::from_micros(self.cfg.batch_window_us);
        // block only when totally idle; otherwise poll
        let wait = if free.len() == self.slots.len() {
            window.max(Duration::from_millis(1))
        } else {
            Duration::from_micros(1)
        };
        let Some(reqs) = queue.take_batch(free.len(), wait) else {
            // queue closed; engine drains remaining slots then stops
            return Ok(self.slots.iter().any(|s| s.active()));
        };
        if reqs.is_empty() {
            return Ok(true);
        }
        self.prefill_into_slots(&free, reqs)?;
        Ok(true)
    }

    /// Prefill newly admitted requests (path-dispatched).
    fn prefill_into_slots(&mut self, free: &[usize], reqs: Vec<Request>) -> Result<()> {
        if matches!(self.path, EnginePath::Native(_)) {
            self.native_prefill(free, reqs)
        } else {
            self.pjrt_prefill(free, reqs)
        }
    }

    /// Native prefill: per-request planned forward over the prompt
    /// prefix, building the pruned static KV segment for the slot. The
    /// final prompt token is fed by the first decode step (which
    /// appends it to the dynamic tail and emits the first logits).
    fn native_prefill(&mut self, free: &[usize], reqs: Vec<Request>) -> Result<()> {
        let g = self.geo;
        let EnginePath::Native(np) = &mut self.path else {
            unreachable!("native_prefill on pjrt path")
        };
        for (slot_idx, req) in free.iter().copied().zip(reqs.into_iter()) {
            let t0 = Instant::now();
            // leave room for at least one generated token
            let plen = req.prompt.len().min(g.max_ctx - 1).max(1);
            let prefix = if req.prompt.is_empty() {
                &[][..]
            } else {
                &req.prompt[..plen - 1]
            };
            let cache =
                np.model
                    .prefill(prefix, self.cfg.k_sparsity, self.cfg.v_sparsity, &mut np.ctr);
            np.caches[slot_idx] = Some(cache);
            self.metrics.prefills.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
            self.slots[slot_idx] = Slot {
                token: *req.prompt.get(plen - 1).unwrap_or(&32),
                pos: plen - 1,
                cache_len: plen - 1,
                generated: Vec::new(),
                started: Some(Instant::now()),
                decode_time: t0.elapsed().as_secs_f64(),
                req: Some(req),
            };
        }
        Ok(())
    }

    /// Run the batched PJRT prefill artifact for newly admitted requests.
    fn pjrt_prefill(&mut self, free: &[usize], reqs: Vec<Request>) -> Result<()> {
        let g = self.geo;
        let EnginePath::Pjrt(pj) = &mut self.path else {
            unreachable!("pjrt_prefill on native path")
        };
        let b = g.decode_batch;
        let mut tokens = vec![32i32; b * g.prefill_len]; // pad with spaces
        let mut assigned: Vec<(usize, Request)> = Vec::new();
        for (slot_idx, req) in free.iter().copied().zip(reqs.into_iter()) {
            let plen = req.prompt.len().min(g.prefill_len);
            for (j, &byte) in req.prompt[..plen].iter().enumerate() {
                tokens[slot_idx * g.prefill_len + j] = byte as i32;
            }
            assigned.push((slot_idx, req));
        }
        let mut inputs = pj.param_literals()?;
        inputs.push(lit_i32(&tokens, &[b as i64, g.prefill_len as i64])?);
        let t0 = Instant::now();
        let outs = pj.prefill.run(&inputs).context("prefill")?;
        // count per request (like the native path), not per artifact call
        self.metrics
            .prefills
            .fetch_add(assigned.len() as u64, std::sync::atomic::Ordering::Relaxed);
        let _logits = to_f32(&outs[0])?;
        let k = to_f32(&outs[1])?; // [L, B, kvh, S, hd]
        let v = to_f32(&outs[2])?;
        // scatter prefill K/V into the engine cache slots
        let (kvh, hd, s, maxc) = (g.kv_heads, g.head_dim, g.prefill_len, g.max_ctx);
        for (slot_idx, req) in assigned {
            for l in 0..g.layers {
                for h in 0..kvh {
                    for t in 0..s {
                        let src = (((l * b + slot_idx) * kvh + h) * s + t) * hd;
                        let dst = (((l * b + slot_idx) * kvh + h) * maxc + t) * hd;
                        pj.k_cache[dst..dst + hd].copy_from_slice(&k[src..src + hd]);
                        pj.v_cache[dst..dst + hd].copy_from_slice(&v[src..src + hd]);
                    }
                }
            }
            let plen = req.prompt.len().min(s).max(1);
            let slot = &mut self.slots[slot_idx];
            *slot = Slot {
                token: *req.prompt.get(plen - 1).unwrap_or(&32),
                pos: plen - 1,
                cache_len: plen,
                generated: Vec::new(),
                started: Some(Instant::now()),
                decode_time: t0.elapsed().as_secs_f64(),
                req: Some(req),
            };
        }
        Ok(())
    }

    /// One decode step over all active slots (path-dispatched). Returns
    /// the number of active slots processed.
    fn step(&mut self) -> Result<usize> {
        self.cancel_expired_slots();
        let active: Vec<usize> = (0..self.slots.len())
            .filter(|&i| self.slots[i].active())
            .collect();
        if active.is_empty() {
            self.drain_recovery();
            self.drive_probation();
            return Ok(0);
        }
        // produce the next token per active slot
        let produced = match &mut self.path {
            EnginePath::Native(np) => {
                let slots = &self.slots;
                let metrics = &self.metrics;
                let hysteresis = &mut self.hysteresis;
                let run = || native_produce(np, slots, metrics, hysteresis, &active);
                if crate::fault::armed() {
                    // Last-resort backstop: while fault injection is
                    // live, no panic escapes the engine step. Unarmed
                    // panics are real bugs and propagate unchanged.
                    std::panic::catch_unwind(std::panic::AssertUnwindSafe(run)).ok()
                } else {
                    Some(run())
                }
            }
            EnginePath::Pjrt(pj) => {
                // the AOT artifact always runs the full batch; occupancy
                // still tracks how many slots carried live requests
                self.metrics
                    .record_decode_regime(active.len(), active.len() > 1);
                let g = self.geo;
                let b = g.decode_batch;
                let mut token = vec![0i32; b];
                let mut pos = vec![0i32; b];
                let mut cache_len = vec![1i32; b];
                for &i in &active {
                    token[i] = self.slots[i].token as i32;
                    pos[i] = self.slots[i].pos as i32;
                    cache_len[i] = self.slots[i].cache_len as i32;
                }
                let dims_cache = [
                    g.layers as i64,
                    b as i64,
                    g.kv_heads as i64,
                    g.max_ctx as i64,
                    g.head_dim as i64,
                ];
                let mut inputs = pj.param_literals()?;
                inputs.push(lit_i32(&token, &[b as i64])?);
                inputs.push(lit_i32(&pos, &[b as i64])?);
                inputs.push(lit_f32(&pj.k_cache, &dims_cache)?);
                inputs.push(lit_f32(&pj.v_cache, &dims_cache)?);
                inputs.push(lit_i32(&cache_len, &[b as i64])?);
                let t0 = Instant::now();
                let outs = pj.decode.run(&inputs).context("decode_step")?;
                let dt = t0.elapsed().as_secs_f64();
                let logits = to_f32(&outs[0])?; // [B, V]
                pj.k_cache = to_f32(&outs[1])?;
                pj.v_cache = to_f32(&outs[2])?;
                let next: Vec<(usize, u8)> = active
                    .iter()
                    .map(|&i| (i, argmax(&logits[i * g.vocab..(i + 1) * g.vocab]) as u8))
                    .collect();
                Some((next, dt))
            }
        };
        let Some((next_tokens, dt)) = produced else {
            // an injected fault escaped every recovery layer: this
            // step's model state is unknowable, so drain the active
            // slots with partial results instead of crashing the server
            for &i in &active {
                self.finish_slot_with(i, Some("engine_fault".to_string()));
            }
            self.drain_recovery();
            self.drive_probation();
            return Ok(active.len());
        };
        self.metrics.record_step(dt, &self.step_label);
        // drain per-shard timings accumulated by sharded kernels this step
        for b in &self.shard_backends {
            if let Some(snap) = b.shard_stats() {
                self.metrics.record_shard_stats(&snap);
            }
        }
        self.metrics
            .decode_steps
            .fetch_add(1, std::sync::atomic::Ordering::Relaxed);

        let mut finished = Vec::new();
        for (i, next) in next_tokens {
            let slot = &mut self.slots[i];
            slot.decode_time += dt;
            slot.generated.push(next);
            slot.token = next;
            slot.pos += 1;
            slot.cache_len += 1;
            self.metrics
                .tokens_generated
                .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
            let done = slot.generated.len()
                >= slot
                    .req
                    .as_ref()
                    .map(|r| r.max_new_tokens)
                    .unwrap_or(0)
                    .min(self.cfg.max_new_tokens)
                || slot.cache_len >= self.geo.max_ctx;
            if done {
                finished.push(i);
            }
        }
        for i in finished {
            self.finish_slot(i);
        }
        self.drain_recovery();
        self.drive_probation();
        Ok(active.len())
    }

    /// Sweep the slots for disconnected clients and deadlines *before*
    /// the step. Deadline-aware pricing (PR 10): the upcoming step is
    /// priced from the compiled plan ([`Engine::next_step_price_s`]),
    /// and a slot whose remaining budget cannot cover it is retired now
    /// instead of one step late — the pricing model is a lower bound,
    /// so a slot that could still make its deadline is never swept
    /// early. Each swept slot frees its KV cache immediately and
    /// answers with the partial result decoded so far.
    fn cancel_expired_slots(&mut self) {
        let predicted_step_ms = self.next_step_price_s() * 1e3;
        let mut expired: Vec<(usize, &'static str, bool)> = Vec::new();
        for (i, slot) in self.slots.iter().enumerate() {
            let Some(req) = &slot.req else { continue };
            if req.cancel.load(std::sync::atomic::Ordering::Relaxed) {
                expired.push((i, "cancelled", false));
            } else if let Some(d) = req.deadline_ms {
                let elapsed = req.arrived.elapsed().as_millis() as u64;
                if deadline_sweep_due(elapsed, d, predicted_step_ms) {
                    // preemptive ⇔ swept strictly before the deadline
                    expired.push((i, "deadline", elapsed < d));
                }
            }
        }
        for (i, reason, preemptive) in expired {
            if reason == "deadline" {
                self.metrics
                    .deadline_expirations
                    .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                if preemptive {
                    self.metrics
                        .preemptive_deadline_sweeps
                        .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                }
            }
            self.finish_slot_with(i, Some(reason.to_string()));
        }
    }

    /// Plan-predicted seconds of the *upcoming* decode step: the
    /// fused-regime price while the dwell-held regime is fused, the
    /// batch-1 price otherwise (PJRT: the representative selection).
    fn next_step_price_s(&self) -> f64 {
        match &self.path {
            EnginePath::Native(np) => {
                if self.hysteresis.current == Some(true) {
                    np.model.plan.predicted_fused_step_s()
                } else {
                    np.model.plan.predicted_step_s()
                }
            }
            EnginePath::Pjrt(_) => self.selection.predicted_s,
        }
    }

    /// Post-step recovery drain: surface injected-fault and respawn
    /// counters, fold kernel-failure records into the registry's health
    /// state, and recompile the plan when a backend was newly
    /// quarantined (degraded-mode re-planning).
    fn drain_recovery(&mut self) {
        self.metrics
            .faults_injected
            .store(crate::fault::injected_count(), std::sync::atomic::Ordering::Relaxed);
        for p in &self.pools {
            let r = p.take_respawns();
            if r > 0 {
                self.metrics
                    .worker_respawns
                    .fetch_add(r, std::sync::atomic::Ordering::Relaxed);
            }
        }
        let failures = crate::fault::drain_backend_failures();
        if failures.is_empty() {
            return;
        }
        let mut newly_quarantined = false;
        if let EnginePath::Native(np) = &self.path {
            for name in &failures {
                if np.registry.record_failure(name) {
                    self.metrics
                        .backend_quarantines
                        .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                    newly_quarantined = true;
                }
            }
        }
        if newly_quarantined {
            self.recompile_plan();
        }
    }

    /// Degraded-mode re-planning: recompile the decode plan against the
    /// registry's current health state (quarantined backends are
    /// skipped; a quarantined pinned backend reroutes to the reference
    /// oracle). KV caches are untouched — they store plain f32 K/V, not
    /// backend state — so in-flight slots keep decoding mid-request on
    /// the new plan without losing a step.
    fn recompile_plan(&mut self) {
        let EnginePath::Native(np) = &mut self.path else { return };
        np.model.plan = DecodePlan::compile_with(
            &np.registry,
            self.cfg.backend,
            &np.model.model,
            self.cfg.weight_sparsity,
            np.batches,
        );
        self.shard_backends = collect_shard_backends(&np.model.plan);
        // rewire the attention scatter pool (a fresh plan starts bare)
        let attn = self
            .shard_backends
            .iter()
            .find_map(|b| b.worker_pool())
            .or_else(|| self.attn_pool.clone());
        np.model.set_attention_pool(attn.clone());
        self.attn_pool = attn;
        self.pools = collect_pools(&self.shard_backends, self.attn_pool.as_ref());
        self.selection = np.model.plan.lm_head.selection.clone();
        self.step_label = format!("native/{}", self.selection.backend.name());
        self.metrics
            .plan_recompiles
            .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        log_info!(
            "plan recompiled (degraded mode): {} — quarantined [{}]",
            np.model.plan.describe(),
            np.registry.quarantined().join(", ")
        );
    }

    fn finish_slot(&mut self, i: usize) {
        self.finish_slot_with(i, None)
    }

    /// Retire slot `i`, releasing its KV cache and answering its
    /// request. `partial_reason` marks an early stop (deadline,
    /// cancellation, engine fault); `None` means ran to completion.
    fn finish_slot_with(&mut self, i: usize, partial_reason: Option<String>) {
        if let EnginePath::Native(np) = &mut self.path {
            np.caches[i] = None; // release the slot's KV memory
        }
        let slot = std::mem::replace(&mut self.slots[i], Slot::empty());
        let Some(req) = slot.req else { return };
        let total = req.arrived.elapsed().as_secs_f64();
        let queue_latency = slot
            .started
            .map(|s| (s.duration_since(req.arrived)).as_secs_f64())
            .unwrap_or(0.0);
        let n = slot.generated.len().max(1);
        let resp = Response {
            id: req.id,
            tokens: slot.generated,
            total_latency_s: total,
            queue_latency_s: queue_latency,
            per_token_s: slot.decode_time / n as f64,
            partial_reason,
        };
        self.metrics.record_latency(total);
        self.metrics
            .requests_completed
            .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        let _ = req.respond.send(resp); // receiver may have gone away
    }

    /// Quarantine probation (PR 10): while any backend sits in
    /// quarantine, every [`PROBE_EVERY_STEPS`] steps one small
    /// deterministic GEMM is mirrored to each quarantined backend and
    /// compared against the serving backend's output. The probe result
    /// is never served; [`BackendRegistry::record_probe`] re-admits the
    /// backend after `PROBATION_PROBES` consecutive clean probes, and a
    /// release triggers exactly one plan recompile (shared across
    /// same-round releases). Healthy engines pay one relaxed atomic
    /// load per step; probes bypass the fault seam so pinned
    /// `kernel_fail` schedules are never consumed by probation traffic.
    fn drive_probation(&mut self) {
        let names = match &self.path {
            EnginePath::Native(np) if np.registry.has_quarantined() => np.registry.quarantined(),
            _ => return,
        };
        self.probe_tick += 1;
        if self.probe_tick % PROBE_EVERY_STEPS != 0 {
            return;
        }
        // Fixed synthetic probe operand: deterministic (probe traffic can
        // never perturb served tokens) and dense (every kernel class
        // runs it).
        let mut g = crate::util::XorShift::new(0x5052_4f42);
        let (rows, cols) = (32, 32);
        let w = g.normal_vec(rows * cols, 0.5);
        let dw = crate::amx::kernels::DenseWeights::pack_f32(&w, rows, cols);
        let x = g.normal_vec(rows, 1.0);
        let want = self.selection.backend.probe_gemm_bf16(&x, 1, &dw);
        let EnginePath::Native(np) = &self.path else { return };
        let mut released = false;
        for name in names {
            let Some(b) = np.registry.backend_by_name(&name) else {
                continue;
            };
            self.metrics
                .probe_calls
                .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
            let got = b.probe_gemm_bf16(&x, 1, &dw);
            let clean = match (&want, &got) {
                (Some(w), Some(g)) => probe_outputs_agree(w, g),
                _ => false, // either side panicked → not a clean probe
            };
            if np.registry.record_probe(&name, clean) {
                released = true;
                self.metrics
                    .quarantine_releases
                    .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                log_info!("backend {name} re-admitted after clean probation");
            }
        }
        if released {
            self.recompile_plan();
        }
    }

    /// Capture every active slot into a checkpoint
    /// [`crate::fault::checkpoint::Snapshot`] (native path; the PJRT
    /// artifact's monolithic cache is not snapshotted). Only
    /// backend-agnostic state goes in — token bytes, positions, f32/bf16
    /// KV segments. Backend selections are never serialized: the
    /// restoring process compiles its own plan.
    pub fn snapshot(&self) -> crate::fault::checkpoint::Snapshot {
        let EnginePath::Native(np) = &self.path else {
            return crate::fault::checkpoint::Snapshot::default();
        };
        let mut slots = Vec::new();
        for (i, slot) in self.slots.iter().enumerate() {
            let (Some(req), Some(cache)) = (&slot.req, &np.caches[i]) else {
                continue;
            };
            slots.push(crate::fault::checkpoint::SlotSnapshot {
                id: req.id,
                prompt: req.prompt.clone(),
                max_new_tokens: req.max_new_tokens,
                generated: slot.generated.clone(),
                cache_len: slot.cache_len,
                pos: slot.pos,
                token: slot.token,
                decode_time: slot.decode_time,
                deadline_remaining_ms: req
                    .deadline_ms
                    .map(|d| d.saturating_sub(req.arrived.elapsed().as_millis() as u64)),
                cancelled: req.cancel.load(std::sync::atomic::Ordering::Relaxed),
                cache: cache.clone(),
            });
        }
        crate::fault::checkpoint::Snapshot { slots }
    }

    /// Write a slot snapshot when the checkpoint cadence comes due.
    /// With `--checkpoint` unset this is one string-emptiness check per
    /// step; armed, serialization still only happens every
    /// `checkpoint_every_steps` productive steps — never inside the
    /// token loop itself.
    fn maybe_checkpoint(&mut self) {
        if self.cfg.checkpoint.is_empty() {
            return;
        }
        self.ckpt_tick += 1;
        if self.ckpt_tick < self.cfg.checkpoint_every_steps {
            return;
        }
        self.ckpt_tick = 0;
        let snap = self.snapshot();
        match crate::fault::checkpoint::save(&self.cfg.checkpoint, &snap) {
            Ok(()) => {
                self.metrics
                    .checkpoints_written
                    .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
            }
            Err(e) => log_info!("checkpoint write failed: {e}"),
        }
    }

    /// Restore in-flight slots from a snapshot file written by a
    /// previous process. A missing file is a clean cold start; a
    /// torn/corrupt/incompatible snapshot (or one slot whose geometry
    /// does not fit this engine) is skipped and counted as
    /// `restore_rejected` rather than trusted. Restored slots decode on
    /// *this* process's compiled plan — selections are never restored
    /// from disk — so continuation is bit-exact whenever the serving
    /// kernel class matches, even across differing `SPARAMX_CAPS`.
    ///
    /// Returns one `(request id, receiver)` pair per restored slot; the
    /// caller must drain each receiver. The restored slot re-enters the
    /// normal lifecycle and still leaves the engine in exactly one of
    /// the four ways (completion / deadline / cancel / engine-fault),
    /// answering its channel exactly once. Deadlines are re-anchored to
    /// the restore instant: downtime does not count against a request.
    pub fn restore_from_file(
        &mut self,
        path: &str,
    ) -> Vec<(u64, std::sync::mpsc::Receiver<Response>)> {
        use std::sync::atomic::Ordering::Relaxed;
        if path.is_empty() || !std::path::Path::new(path).exists() {
            return Vec::new();
        }
        let snap = match crate::fault::checkpoint::load(path) {
            Ok(s) => s,
            Err(e) => {
                self.metrics.restore_rejected.fetch_add(1, Relaxed);
                log_info!("checkpoint restore rejected: {e}");
                return Vec::new();
            }
        };
        let geo = self.geo;
        let EnginePath::Native(np) = &mut self.path else {
            self.metrics.restore_rejected.fetch_add(1, Relaxed);
            log_info!("checkpoint restore rejected: pjrt path does not restore slots");
            return Vec::new();
        };
        let mut out = Vec::new();
        for s in snap.slots {
            let Some(i) = self.slots.iter().position(|sl| !sl.active()) else {
                self.metrics.restore_rejected.fetch_add(1, Relaxed);
                log_info!("restore rejected: no free slot for request {}", s.id);
                continue;
            };
            let fits = s.cache.heads.len() == geo.layers
                && s.cache.kv_heads == geo.kv_heads
                && s.cache_len < geo.max_ctx
                && s.cache.heads.iter().flatten().all(|h| h.head_dim == geo.head_dim);
            if !fits {
                self.metrics.restore_rejected.fetch_add(1, Relaxed);
                log_info!("restore rejected: geometry mismatch for request {}", s.id);
                continue;
            }
            let (req, rx) = Request::restored(
                s.id,
                s.prompt,
                s.max_new_tokens,
                s.deadline_remaining_ms,
                s.cancelled,
            );
            np.caches[i] = Some(s.cache);
            self.slots[i] = Slot {
                req: Some(req),
                generated: s.generated,
                cache_len: s.cache_len,
                pos: s.pos,
                token: s.token,
                started: Some(Instant::now()),
                decode_time: s.decode_time,
            };
            self.metrics.slots_restored.fetch_add(1, Relaxed);
            out.push((s.id, rx));
        }
        if !out.is_empty() {
            log_info!("restored {} in-flight slot(s) from {path}", out.len());
        }
        out
    }

    /// Serve until the queue closes and all slots drain.
    pub fn run(&mut self, queue: &AdmissionQueue) -> Result<()> {
        loop {
            let keep_going = self.fill_slots(queue)?;
            let processed = self.step()?;
            if processed > 0 {
                self.maybe_checkpoint();
            }
            if !keep_going && processed == 0 {
                return Ok(());
            }
        }
    }
}

/// Whether a slot with `elapsed_ms` spent of its `deadline_ms` budget
/// must be swept before a step predicted to take `predicted_step_ms`:
/// already expired, or certain to expire mid-step. The prediction is a
/// lower bound on the true step cost, so a `false` here never strands a
/// slot that could not have finished in time — it only moves the sweep
/// one step earlier when expiry is provable.
fn deadline_sweep_due(elapsed_ms: u64, deadline_ms: u64, predicted_step_ms: f64) -> bool {
    elapsed_ms >= deadline_ms || elapsed_ms as f64 + predicted_step_ms >= deadline_ms as f64
}

/// Probe-output agreement: generous elementwise tolerance absorbing
/// bf16 rounding and accumulation-order differences across kernel
/// classes. A panicking or garbage-producing backend lands far outside
/// it; a healthy backend of any class lands far inside.
fn probe_outputs_agree(a: &[f32], b: &[f32]) -> bool {
    if a.len() != b.len() {
        return false;
    }
    a.iter().zip(b).all(|(x, y)| (x - y).abs() <= 0.05 * (1.0 + x.abs()))
}

/// Produce one decode step's tokens on the native path. Free-standing
/// over disjoint engine fields so the caller can wrap it in
/// `catch_unwind` (the fault-injection backstop) without borrowing the
/// whole engine.
fn native_produce(
    np: &mut NativePath,
    slots: &[Slot],
    metrics: &Metrics,
    hysteresis: &mut RegimeHysteresis,
    active: &[usize],
) -> (Vec<(usize, u8)>, f64) {
    let t0 = Instant::now();
    // regime pick from live slot count: multi-slot steps fuse into one
    // batched GEMM per projection (unless fusion is disabled);
    // single-slot steps run the batch-1 plan. The selections themselves
    // were fixed at plan compile, and a dwell counter keeps occupancy
    // noise around the fuse threshold from flipping the regime every
    // step.
    let want = active.len() > 1 && np.model.plan.fused_batch > 1;
    let (fused, flipped) = hysteresis.decide(want);
    if flipped {
        metrics
            .regime_flips
            .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
    }
    metrics.record_decode_regime(active.len(), fused);
    let next: Vec<(usize, u8)> = if fused {
        let tokens: Vec<u8> = active.iter().map(|&i| slots[i].token).collect();
        let positions: Vec<usize> = active.iter().map(|&i| slots[i].pos).collect();
        // `active` is ascending, so iterating caches in index order
        // keeps row b ↔ slot active[b]
        let mut cache_refs: Vec<&mut KvCache> = np
            .caches
            .iter_mut()
            .enumerate()
            .filter_map(|(i, c)| {
                active
                    .contains(&i)
                    .then(|| c.as_mut().expect("active slot has a cache"))
            })
            .collect();
        let logits =
            np.model
                .decode_step_batched(&tokens, &positions, &mut cache_refs, &mut np.ctr);
        active
            .iter()
            .zip(logits.iter())
            .map(|(&i, l)| (i, argmax(l) as u8))
            .collect()
    } else {
        let mut next = Vec::with_capacity(active.len());
        for &i in active {
            let slot = &slots[i];
            let cache = np.caches[i].as_mut().expect("active slot has a cache");
            let logits = np.model.decode_step(slot.token, slot.pos, cache, &mut np.ctr);
            next.push((i, argmax(&logits) as u8));
        }
        next
    };
    (next, t0.elapsed().as_secs_f64())
}

/// Distinct sharded backends reachable from any regime's selection in
/// `plan` (their per-shard timings drain into metrics each step).
fn collect_shard_backends(plan: &DecodePlan) -> Vec<Backend> {
    let mut out: Vec<Backend> = Vec::new();
    let mut add = |b: &Backend| {
        if b.kind() == crate::backend::BackendKind::Sharded && !out.iter().any(|x| x == b) {
            out.push(b.clone());
        }
    };
    for l in &plan.layers {
        for p in [&l.wq, &l.wk, &l.wv, &l.wo, &l.wgate, &l.wup, &l.wdown] {
            // any regime's selection may route through a sharded
            // backend; all of them drain into the metrics
            add(&p.selection.backend);
            add(&p.fused.backend);
            add(&p.prefill.backend);
        }
    }
    add(&plan.lm_head.selection.backend);
    add(&plan.lm_head.fused.backend);
    add(&plan.lm_head.prefill.backend);
    add(&plan.attention);
    out
}

/// Distinct persistent worker pools (by identity) reachable from the
/// sharded backends plus the attention scatter pool.
fn collect_pools(
    shard_backends: &[Backend],
    attn_pool: Option<&Arc<crate::shard::WorkerPool>>,
) -> Vec<Arc<crate::shard::WorkerPool>> {
    let mut pools: Vec<Arc<crate::shard::WorkerPool>> = Vec::new();
    let candidates = shard_backends
        .iter()
        .filter_map(|b| b.worker_pool())
        .chain(attn_pool.cloned());
    for p in candidates {
        if !pools.iter().any(|q| Arc::ptr_eq(q, &p)) {
            pools.push(p);
        }
    }
    pools
}

fn argmax(xs: &[f32]) -> usize {
    let mut best = 0;
    for (i, &x) in xs.iter().enumerate() {
        if x > xs[best] {
            best = i;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn argmax_picks_first_max() {
        assert_eq!(argmax(&[1.0, 5.0, 5.0, 2.0]), 1);
        assert_eq!(argmax(&[-3.0]), 0);
    }

    #[test]
    fn hysteresis_adopts_first_pick_without_flip() {
        let mut h = RegimeHysteresis::default();
        assert_eq!(h.decide(true), (true, false));
        assert_eq!(h.decide(true), (true, false));
        let mut h = RegimeHysteresis::default();
        assert_eq!(h.decide(false), (false, false));
    }

    #[test]
    fn hysteresis_ignores_oscillation_around_threshold() {
        // occupancy bouncing 1,2,1,2,... never sustains a disagreement
        // long enough to flip
        let mut h = RegimeHysteresis::default();
        assert_eq!(h.decide(false), (false, false));
        for _ in 0..20 {
            assert_eq!(h.decide(true), (false, false), "held through blip");
            assert_eq!(h.decide(false), (false, false), "agreement resets dwell");
        }
    }

    #[test]
    fn hysteresis_flips_once_after_sustained_change() {
        let mut h = RegimeHysteresis::default();
        assert_eq!(h.decide(false), (false, false));
        let mut flips = 0;
        for step in 0..10 {
            let (fused, flipped) = h.decide(true);
            if flipped {
                flips += 1;
            }
            if step + 1 < REGIME_DWELL_STEPS as usize {
                assert!(!fused, "step {step}: still dwelling");
            } else {
                assert!(fused, "step {step}: sustained change took effect");
            }
        }
        assert_eq!(flips, 1, "sustained change flips exactly once");
    }

    #[test]
    fn deadline_sweep_prices_the_upcoming_step() {
        // already expired → due regardless of the step price
        assert!(deadline_sweep_due(10, 10, 0.0));
        assert!(deadline_sweep_due(11, 10, 0.0));
        // in budget and the step fits → not due
        assert!(!deadline_sweep_due(5, 10, 4.9));
        // in budget but the step provably cannot finish in time →
        // preemptive sweep, one step earlier than expiry
        assert!(deadline_sweep_due(5, 10, 5.0));
        assert!(deadline_sweep_due(0, 10, 25.0));
        // zero-deadline requests still expire immediately
        assert!(deadline_sweep_due(0, 0, 0.0));
    }

    #[test]
    fn probe_agreement_tolerates_rounding_not_garbage() {
        let a = vec![1.0f32, -2.0, 0.5];
        let mut b = a.clone();
        b[0] += 0.01; // bf16-scale rounding noise
        assert!(probe_outputs_agree(&a, &b));
        b[0] = 7.0;
        assert!(!probe_outputs_agree(&a, &b), "garbage output disagrees");
        assert!(!probe_outputs_agree(&a, &a[..2]), "length mismatch disagrees");
    }
}
