//! Request types for the serving coordinator.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc};
use std::time::Instant;

/// Unique request id.
pub type RequestId = u64;

/// A generation request submitted to the engine.
#[derive(Debug)]
pub struct Request {
    pub id: RequestId,
    /// Byte-level prompt tokens.
    pub prompt: Vec<u8>,
    pub max_new_tokens: usize,
    /// Wall-clock admission time (for queueing-latency metrics).
    pub arrived: Instant,
    /// Completion channel.
    pub respond: mpsc::Sender<Response>,
    /// Per-request deadline, measured from `arrived`. When the engine
    /// reaches a decode step past the deadline the slot is cancelled and
    /// the partial result is returned with `partial_reason: "deadline"`.
    /// `None` → no deadline.
    pub deadline_ms: Option<u64>,
    /// Cooperative cancellation token. The server's connection thread
    /// sets this when the client disconnects; the engine checks it each
    /// step and frees the slot (returning whatever was decoded so far
    /// with `partial_reason: "cancelled"`).
    pub cancel: Arc<AtomicBool>,
}

impl Request {
    /// Whether this request has been cancelled (client gone).
    pub fn cancelled(cancel: &Arc<AtomicBool>) -> bool {
        cancel.load(Ordering::Relaxed)
    }

    /// Rebuild a request from a checkpoint snapshot. The answer channel
    /// is freshly created — the pre-crash client connection is gone —
    /// and its receiver is returned for the caller to drain, so the
    /// restored slot keeps the PR 9 invariant: it leaves the engine in
    /// exactly one of the four ways (completion / deadline / cancel /
    /// engine-fault) and answers its channel exactly once. The deadline
    /// budget is re-anchored to the restore instant (downtime does not
    /// count against the request), and a pre-crash cancellation is
    /// honored on the first post-restore sweep.
    pub fn restored(
        id: RequestId,
        prompt: Vec<u8>,
        max_new_tokens: usize,
        deadline_remaining_ms: Option<u64>,
        cancelled: bool,
    ) -> (Request, mpsc::Receiver<Response>) {
        let (tx, rx) = mpsc::channel();
        let req = Request {
            id,
            prompt,
            max_new_tokens,
            arrived: Instant::now(),
            respond: tx,
            deadline_ms: deadline_remaining_ms,
            cancel: Arc::new(AtomicBool::new(cancelled)),
        };
        (req, rx)
    }
}

/// The engine's reply.
#[derive(Clone, Debug)]
pub struct Response {
    pub id: RequestId,
    /// Generated tokens (prompt excluded).
    pub tokens: Vec<u8>,
    /// Seconds from admission to completion.
    pub total_latency_s: f64,
    /// Seconds spent waiting in the queue before a slot was free.
    pub queue_latency_s: f64,
    /// Mean seconds per generated token (decode only).
    pub per_token_s: f64,
    /// `None` → the request ran to completion. `Some(reason)` → the
    /// engine stopped early and `tokens` holds a partial result;
    /// reasons: `"deadline"` (per-request deadline expired),
    /// `"cancelled"` (client disconnected), `"engine_fault"` (an
    /// injected fault escaped recovery and the slot was drained).
    pub partial_reason: Option<String>,
}

impl Response {
    /// Generated text (lossy UTF-8 — the tiny model is byte-level).
    pub fn text(&self) -> String {
        String::from_utf8_lossy(&self.tokens).into_owned()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn response_text_is_lossy_utf8() {
        let (tx, _rx) = mpsc::channel();
        let _req = Request {
            id: 1,
            prompt: b"hi".to_vec(),
            max_new_tokens: 4,
            arrived: Instant::now(),
            respond: tx,
            deadline_ms: None,
            cancel: Arc::new(AtomicBool::new(false)),
        };
        let r = Response {
            id: 1,
            tokens: vec![104, 105, 0xFF],
            total_latency_s: 0.1,
            queue_latency_s: 0.0,
            per_token_s: 0.03,
            partial_reason: None,
        };
        assert!(r.text().starts_with("hi"));
    }

    #[test]
    fn restored_request_reanchors_deadline_and_keeps_identity() {
        let (req, rx) = Request::restored(9, b"hi".to_vec(), 6, Some(250), false);
        assert_eq!(req.id, 9);
        assert_eq!(req.max_new_tokens, 6);
        assert_eq!(req.deadline_ms, Some(250));
        assert!(!Request::cancelled(&req.cancel));
        // the fresh channel answers exactly once
        req.respond
            .send(Response {
                id: 9,
                tokens: vec![1],
                total_latency_s: 0.0,
                queue_latency_s: 0.0,
                per_token_s: 0.0,
                partial_reason: None,
            })
            .unwrap();
        assert_eq!(rx.recv().unwrap().id, 9);
        // a pre-crash cancellation survives the round trip
        let (req, _rx) = Request::restored(10, Vec::new(), 1, None, true);
        assert!(Request::cancelled(&req.cancel));
    }

    #[test]
    fn cancel_token_flips_once_set() {
        let cancel = Arc::new(AtomicBool::new(false));
        assert!(!Request::cancelled(&cancel));
        cancel.store(true, Ordering::Relaxed);
        assert!(Request::cancelled(&cancel));
    }
}
