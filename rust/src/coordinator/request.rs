//! Request types for the serving coordinator.

use std::sync::mpsc;
use std::time::Instant;

/// Unique request id.
pub type RequestId = u64;

/// A generation request submitted to the engine.
#[derive(Debug)]
pub struct Request {
    pub id: RequestId,
    /// Byte-level prompt tokens.
    pub prompt: Vec<u8>,
    pub max_new_tokens: usize,
    /// Wall-clock admission time (for queueing-latency metrics).
    pub arrived: Instant,
    /// Completion channel.
    pub respond: mpsc::Sender<Response>,
}

/// The engine's reply.
#[derive(Clone, Debug)]
pub struct Response {
    pub id: RequestId,
    /// Generated tokens (prompt excluded).
    pub tokens: Vec<u8>,
    /// Seconds from admission to completion.
    pub total_latency_s: f64,
    /// Seconds spent waiting in the queue before a slot was free.
    pub queue_latency_s: f64,
    /// Mean seconds per generated token (decode only).
    pub per_token_s: f64,
}

impl Response {
    /// Generated text (lossy UTF-8 — the tiny model is byte-level).
    pub fn text(&self) -> String {
        String::from_utf8_lossy(&self.tokens).into_owned()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn response_text_is_lossy_utf8() {
        let (tx, _rx) = mpsc::channel();
        let _req = Request {
            id: 1,
            prompt: b"hi".to_vec(),
            max_new_tokens: 4,
            arrived: Instant::now(),
            respond: tx,
        };
        let r = Response {
            id: 1,
            tokens: vec![104, 105, 0xFF],
            total_latency_s: 0.1,
            queue_latency_s: 0.0,
            per_token_s: 0.03,
        };
        assert!(r.text().starts_with("hi"));
    }
}
