//! The paper's unstructured-sparsity weight format and tooling.
//!
//! * [`format`] — the bitmap (`weight_metadata`) + packed non-zeros
//!   (`weight_values`) representation of Figure 6, with tile-ordered
//!   layouts for the AMX kernels.
//! * [`prune`] — magnitude pruning (weights and KV cache, §6.1).
//! * [`partition`] — the `weight_value_index` per-thread start table of
//!   Figure 9, precomputed at model-load time.

pub mod format;
pub mod prune;
pub mod partition;

pub use format::{SparseTensor, TileOrder};
pub use partition::ThreadPartition;
