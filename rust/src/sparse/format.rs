//! The SparAMX compressed weight format (paper Figure 6).
//!
//! A weight matrix `W[K][N]` (K = inner/hidden dim, N = output
//! neurons) is stored as:
//!
//! * `weight_metadata` — a bitmap with one bit per element, `1` = non-zero;
//! * `weight_values`  — the non-zero values packed in consumption order.
//!
//! The consumption order is **tile order**: the matrix is carved into AMX
//! B-tiles of 16 rows × (32 BF16 | 64 INT8) elements. Each tile covers 16
//! output neurons × (32 | 64) inner-dim steps, pre-arranged in the VNNI
//! interleave the `tdpbf16ps`/`tdpbssd` instructions require (pairs /
//! quads of consecutive `k` sharing a tile row — paper §2.4, §4.5). One
//! tile row's metadata is exactly one 32-bit (BF16) or 64-bit (INT8)
//! word, which is what the kernel's `vpexpandw`/`vpexpandb` step consumes.
//!
//! Tiles are laid out with the inner (`k`) dimension fastest within a
//! 16-neuron column block, so each worker thread — which owns a
//! contiguous range of column blocks — reads a contiguous byte range of
//! both streams (enabling the Figure 9 `weight_value_index` partition).

use crate::util::bf16::Bf16;

/// Element type stored in a [`SparseTensor`].
pub trait Element: Copy + Default + PartialEq + std::fmt::Debug + Send + Sync {
    /// Elements per tile row (32 for BF16, 64 for INT8).
    const ROW_ELEMS: usize;
    /// VNNI group size: how many consecutive `k` share a tile row
    /// (2 for BF16, 4 for INT8).
    const VNNI: usize;
    /// Bytes per element.
    const BYTES: usize;
    fn is_zero(self) -> bool;
    fn to_f32(self) -> f32;
    fn from_f32(x: f32) -> Self;
}

impl Element for Bf16 {
    const ROW_ELEMS: usize = 32;
    const VNNI: usize = 2;
    const BYTES: usize = 2;
    fn is_zero(self) -> bool {
        Bf16::is_zero(self)
    }
    fn to_f32(self) -> f32 {
        Bf16::to_f32(self)
    }
    fn from_f32(x: f32) -> Self {
        Bf16::from_f32(x)
    }
}

impl Element for i8 {
    const ROW_ELEMS: usize = 64;
    const VNNI: usize = 4;
    const BYTES: usize = 1;
    fn is_zero(self) -> bool {
        self == 0
    }
    fn to_f32(self) -> f32 {
        self as f32
    }
    fn from_f32(x: f32) -> Self {
        x.round().clamp(-128.0, 127.0) as i8
    }
}

/// Geometry of the tile stream.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct TileOrder {
    /// Rows per tile (always 16 on AMX).
    pub tile_rows: usize,
    /// Elements per tile row (32 BF16 / 64 INT8).
    pub row_elems: usize,
    /// Output neurons covered per tile (always 16).
    pub cols_per_tile: usize,
    /// Inner-dim steps covered per tile (= tile_rows * VNNI).
    pub k_per_tile: usize,
}

impl TileOrder {
    pub fn for_elem<T: Element>() -> TileOrder {
        TileOrder {
            tile_rows: 16,
            row_elems: T::ROW_ELEMS,
            cols_per_tile: 16,
            k_per_tile: 16 * T::VNNI,
        }
    }

    /// Elements per tile.
    pub fn tile_elems(&self) -> usize {
        self.tile_rows * self.row_elems
    }
}

/// A weight matrix in the SparAMX bitmap + values format.
///
/// `PartialEq` compares every stored field bit-for-bit — what the
/// checkpoint/restore tests use to assert snapshot round-trips are exact.
#[derive(Clone, Debug, PartialEq)]
pub struct SparseTensor<T: Element = Bf16> {
    /// Logical (unpadded) inner dimension.
    pub rows: usize,
    /// Logical (unpadded) output-neuron count.
    pub cols: usize,
    /// Padded inner dimension (multiple of `order.k_per_tile`).
    pub rows_padded: usize,
    /// Padded column count (multiple of `order.cols_per_tile`).
    pub cols_padded: usize,
    pub order: TileOrder,
    /// One word per tile row; BF16 uses the low 32 bits, INT8 all 64.
    pub metadata: Vec<u64>,
    /// Non-zero values in tile scan order.
    pub values: Vec<T>,
    /// Cumulative non-zero count *before* each tile; one extra tail entry
    /// equal to `values.len()`. Powers O(1) random tile access and the
    /// `weight_value_index` thread partition.
    pub tile_nnz_prefix: Vec<u32>,
}

impl<T: Element> SparseTensor<T> {
    /// Number of k-chunks (tiles along the inner dimension).
    pub fn k_chunks(&self) -> usize {
        self.rows_padded / self.order.k_per_tile
    }

    /// Number of 16-neuron column blocks.
    pub fn col_blocks(&self) -> usize {
        self.cols_padded / self.order.cols_per_tile
    }

    /// Total number of tiles in the stream.
    pub fn num_tiles(&self) -> usize {
        self.k_chunks() * self.col_blocks()
    }

    /// Tile index for (column block, k chunk). The k dimension is fastest
    /// so a column range maps to a contiguous tile range.
    pub fn tile_index(&self, col_block: usize, k_chunk: usize) -> usize {
        debug_assert!(col_block < self.col_blocks() && k_chunk < self.k_chunks());
        col_block * self.k_chunks() + k_chunk
    }

    /// Metadata words (one per tile row) for a tile.
    pub fn tile_metadata(&self, tile: usize) -> &[u64] {
        let r = self.order.tile_rows;
        &self.metadata[tile * r..(tile + 1) * r]
    }

    /// Values slice and starting offset for a tile.
    pub fn tile_values(&self, tile: usize) -> (&[T], usize) {
        let start = self.tile_nnz_prefix[tile] as usize;
        let end = self.tile_nnz_prefix[tile + 1] as usize;
        (&self.values[start..end], start)
    }

    /// Number of stored non-zeros.
    pub fn nnz(&self) -> usize {
        self.values.len()
    }

    /// Fraction of *logical* elements that are zero.
    pub fn sparsity(&self) -> f64 {
        let logical = self.rows * self.cols;
        if logical == 0 {
            return 0.0;
        }
        1.0 - self.nnz() as f64 / logical as f64
    }

    /// Bytes of the dense representation (logical elements only).
    pub fn bytes_dense(&self) -> usize {
        self.rows * self.cols * T::BYTES
    }

    /// Bytes of the compressed stream actually moved from DRAM by the
    /// sparse kernel: bitmap (1 bit/element over the padded stream) +
    /// packed values.
    pub fn bytes_sparse(&self) -> usize {
        self.metadata.len() * (self.order.row_elems / 8) + self.values.len() * T::BYTES
    }

    /// Map a tile-local position back to logical (k, n). Returns `None`
    /// for padding positions.
    pub fn tile_pos_to_kn(
        &self,
        col_block: usize,
        k_chunk: usize,
        row: usize,
        col: usize,
    ) -> Option<(usize, usize)> {
        let v = T::VNNI;
        let k = k_chunk * self.order.k_per_tile + row * v + col % v;
        let n = col_block * self.order.cols_per_tile + col / v;
        (k < self.rows && n < self.cols).then_some((k, n))
    }

    /// Pack a dense row-major `rows x cols` matrix (`w[k * cols + n]`),
    /// storing only the non-zero elements (the compressed format).
    pub fn pack(w: &[T], rows: usize, cols: usize) -> SparseTensor<T> {
        Self::pack_impl(w, rows, cols, false)
    }

    /// Pack *all* logical elements — bitmap fully set inside the logical
    /// bounds, zeros stored explicitly. This is the operand layout a
    /// vector kernel uses to execute a matrix *densely*: every value
    /// streams, so event counters reflect dense traffic (used by the
    /// AVX backend's dense entry point; `sparsity()` reports 0).
    pub fn pack_dense(w: &[T], rows: usize, cols: usize) -> SparseTensor<T> {
        Self::pack_impl(w, rows, cols, true)
    }

    fn pack_impl(w: &[T], rows: usize, cols: usize, keep_zeros: bool) -> SparseTensor<T> {
        assert_eq!(w.len(), rows * cols, "shape mismatch");
        let order = TileOrder::for_elem::<T>();
        let rows_padded = rows.div_ceil(order.k_per_tile) * order.k_per_tile;
        let cols_padded = cols.div_ceil(order.cols_per_tile) * order.cols_per_tile;
        let k_chunks = rows_padded / order.k_per_tile;
        let col_blocks = cols_padded / order.cols_per_tile;
        let num_tiles = k_chunks * col_blocks;

        let mut metadata = Vec::with_capacity(num_tiles * order.tile_rows);
        let mut values = Vec::new();
        let mut tile_nnz_prefix = Vec::with_capacity(num_tiles + 1);

        let v = T::VNNI;
        for cb in 0..col_blocks {
            for kc in 0..k_chunks {
                tile_nnz_prefix.push(values.len() as u32);
                for r in 0..order.tile_rows {
                    let mut word = 0u64;
                    for c in 0..order.row_elems {
                        let k = kc * order.k_per_tile + r * v + c % v;
                        let n = cb * order.cols_per_tile + c / v;
                        if k < rows && n < cols {
                            let x = w[k * cols + n];
                            if keep_zeros || !x.is_zero() {
                                word |= 1 << c;
                                values.push(x);
                            }
                        }
                    }
                    metadata.push(word);
                }
            }
        }
        tile_nnz_prefix.push(values.len() as u32);

        SparseTensor {
            rows,
            cols,
            rows_padded,
            cols_padded,
            order,
            metadata,
            values,
            tile_nnz_prefix,
        }
    }

    /// Slice out a contiguous range of 16-neuron column blocks as a
    /// standalone tensor. Because the tile stream is column-block-major
    /// with k fastest, the slice is a contiguous cut of `metadata`,
    /// `values`, and `tile_nnz_prefix` — no element is re-ordered, so a
    /// kernel run on the slice accumulates each column in exactly the
    /// same k-order as on the whole tensor (the sharding bit-exactness
    /// invariant). Used by the shard subsystem's plan-compile packing.
    pub fn slice_col_blocks(&self, blocks: std::ops::Range<usize>) -> SparseTensor<T> {
        assert!(
            blocks.end <= self.col_blocks(),
            "slice {blocks:?} out of range ({} col blocks)",
            self.col_blocks()
        );
        let kc = self.k_chunks();
        let (t0, t1) = (blocks.start * kc, blocks.end * kc);
        let (v0, v1) = (
            self.tile_nnz_prefix[t0] as usize,
            self.tile_nnz_prefix[t1] as usize,
        );
        let cpt = self.order.cols_per_tile;
        let col0 = blocks.start * cpt;
        let r = self.order.tile_rows;
        SparseTensor {
            rows: self.rows,
            cols: self.cols.min(blocks.end * cpt).saturating_sub(col0),
            rows_padded: self.rows_padded,
            cols_padded: blocks.len() * cpt,
            order: self.order,
            metadata: self.metadata[t0 * r..t1 * r].to_vec(),
            values: self.values[v0..v1].to_vec(),
            tile_nnz_prefix: self.tile_nnz_prefix[t0..=t1]
                .iter()
                .map(|&p| p - v0 as u32)
                .collect(),
        }
    }

    /// Reconstruct the dense row-major matrix (tests / reference path).
    pub fn to_dense(&self) -> Vec<T> {
        let mut out = vec![T::default(); self.rows * self.cols];
        let v = self.order.tile_rows; // rows per tile
        let _ = v;
        for cb in 0..self.col_blocks() {
            for kc in 0..self.k_chunks() {
                let tile = self.tile_index(cb, kc);
                let meta = self.tile_metadata(tile);
                let (vals, _) = self.tile_values(tile);
                let mut vi = 0;
                for (r, &word) in meta.iter().enumerate() {
                    for c in 0..self.order.row_elems {
                        if word >> c & 1 == 1 {
                            let x = vals[vi];
                            vi += 1;
                            if let Some((k, n)) = self.tile_pos_to_kn(cb, kc, r, c) {
                                out[k * self.cols + n] = x;
                            }
                        }
                    }
                }
                debug_assert_eq!(vi, vals.len());
            }
        }
        out
    }
}

impl SparseTensor<Bf16> {
    /// Pack an f32 matrix, rounding values through BF16.
    pub fn pack_f32(w: &[f32], rows: usize, cols: usize) -> SparseTensor<Bf16> {
        let wb: Vec<Bf16> = w.iter().map(|&x| Bf16::from_f32(x)).collect();
        SparseTensor::pack(&wb, rows, cols)
    }

    /// [`SparseTensor::pack_dense`] from f32 (all elements stored).
    pub fn pack_dense_f32(w: &[f32], rows: usize, cols: usize) -> SparseTensor<Bf16> {
        let wb: Vec<Bf16> = w.iter().map(|&x| Bf16::from_f32(x)).collect();
        SparseTensor::pack_dense(&wb, rows, cols)
    }

    /// Dense matrix as f32 (reference path).
    pub fn to_dense_f32(&self) -> Vec<f32> {
        self.to_dense().iter().map(|x| x.to_f32()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::XorShift;

    fn random_pruned(rows: usize, cols: usize, sparsity: f64, seed: u64) -> Vec<f32> {
        let mut g = XorShift::new(seed);
        (0..rows * cols)
            .map(|_| {
                if g.next_f64() < sparsity {
                    0.0
                } else {
                    // avoid values that round to 0 in bf16
                    g.next_normal() + 3.0
                }
            })
            .collect()
    }

    #[test]
    fn roundtrip_bf16_aligned() {
        let (rows, cols) = (64, 32);
        let w = random_pruned(rows, cols, 0.5, 1);
        let sp = SparseTensor::pack_f32(&w, rows, cols);
        let back = sp.to_dense_f32();
        let expect: Vec<f32> = w.iter().map(|&x| Bf16::from_f32(x).to_f32()).collect();
        assert_eq!(back, expect);
    }

    #[test]
    fn roundtrip_bf16_unaligned_pads() {
        // 50x37: not multiples of 32/16 — padding must be transparent.
        let (rows, cols) = (50, 37);
        let w = random_pruned(rows, cols, 0.3, 2);
        let sp = SparseTensor::pack_f32(&w, rows, cols);
        assert_eq!(sp.rows_padded % 32, 0);
        assert_eq!(sp.cols_padded % 16, 0);
        let back = sp.to_dense_f32();
        let expect: Vec<f32> = w.iter().map(|&x| Bf16::from_f32(x).to_f32()).collect();
        assert_eq!(back, expect);
    }

    #[test]
    fn roundtrip_int8() {
        let mut g = XorShift::new(3);
        let (rows, cols) = (128, 48);
        let w: Vec<i8> = (0..rows * cols)
            .map(|_| {
                if g.next_f64() < 0.6 {
                    0
                } else {
                    (g.below(253) as i32 - 126) as i8
                }
            })
            .collect();
        let sp: SparseTensor<i8> = SparseTensor::pack(&w, rows, cols);
        assert_eq!(sp.to_dense(), w);
        assert_eq!(sp.order.row_elems, 64);
        assert_eq!(sp.order.k_per_tile, 64);
    }

    #[test]
    fn sparsity_and_nnz_accounting() {
        let (rows, cols) = (32, 16);
        let mut w = vec![0.0f32; rows * cols];
        w[0] = 1.0;
        w[5 * cols + 3] = 2.0;
        let sp = SparseTensor::pack_f32(&w, rows, cols);
        assert_eq!(sp.nnz(), 2);
        let expect = 1.0 - 2.0 / (rows * cols) as f64;
        assert!((sp.sparsity() - expect).abs() < 1e-12);
    }

    #[test]
    fn bytes_sparse_beats_dense_at_high_sparsity() {
        let (rows, cols) = (4096, 1024);
        let w = random_pruned(rows, cols, 0.7, 4);
        let sp = SparseTensor::pack_f32(&w, rows, cols);
        // bitmap = 1/16 of dense bf16; values ≈ 0.3 dense → ~0.36 total
        assert!(sp.bytes_sparse() < sp.bytes_dense() * 2 / 5);
    }

    #[test]
    fn bytes_sparse_exceeds_dense_when_dense_matrix() {
        let (rows, cols) = (64, 16);
        let w = vec![1.0f32; rows * cols];
        let sp = SparseTensor::pack_f32(&w, rows, cols);
        // 1 bit/element bitmap overhead: 17/16 of dense
        assert!(sp.bytes_sparse() > sp.bytes_dense());
    }

    #[test]
    fn tile_stream_is_contiguous_per_column_block() {
        let (rows, cols) = (96, 64);
        let w = random_pruned(rows, cols, 0.5, 5);
        let sp = SparseTensor::pack_f32(&w, rows, cols);
        assert_eq!(sp.num_tiles(), (96 / 32) * (64 / 16));
        // prefix array is monotone and consistent with per-tile values
        for t in 0..sp.num_tiles() {
            let (vals, start) = sp.tile_values(t);
            assert_eq!(start, sp.tile_nnz_prefix[t] as usize);
            let meta_pop: u32 = sp.tile_metadata(t).iter().map(|w| w.count_ones()).sum();
            assert_eq!(meta_pop as usize, vals.len());
        }
        assert_eq!(*sp.tile_nnz_prefix.last().unwrap() as usize, sp.nnz());
    }

    #[test]
    fn vnni_interleave_positions() {
        // Element (k=1, n=0) must land in tile row 0, col 1 (pair of k0,k1).
        let (rows, cols) = (32, 16);
        let mut w = vec![0.0f32; rows * cols];
        w[cols] = 7.0; // k=1, n=0
        let sp = SparseTensor::pack_f32(&w, rows, cols);
        assert_eq!(sp.tile_metadata(0)[0], 0b10); // row 0, bit 1
        assert_eq!(sp.tile_pos_to_kn(0, 0, 0, 1), Some((1, 0)));
    }

    #[test]
    fn pack_dense_streams_every_element() {
        let (rows, cols) = (64, 32);
        let w = random_pruned(rows, cols, 0.5, 6);
        let full = SparseTensor::pack_f32(&w, rows, cols); // compressed
        let all: Vec<Bf16> = w.iter().map(|&x| Bf16::from_f32(x)).collect();
        let dense = SparseTensor::pack_dense(&all, rows, cols);
        assert_eq!(dense.nnz(), rows * cols, "every element stored");
        assert_eq!(dense.sparsity(), 0.0);
        assert!(dense.nnz() > full.nnz());
        // reconstruction identical either way
        assert_eq!(dense.to_dense_f32(), full.to_dense_f32());
    }

    #[test]
    fn slice_col_blocks_matches_column_slice_of_whole() {
        // 48x112 = 7 column blocks; slice every contiguous block range
        // and check the dense reconstruction equals the column slice.
        let (rows, cols) = (48, 112);
        let w = random_pruned(rows, cols, 0.5, 7);
        let sp = SparseTensor::pack_f32(&w, rows, cols);
        let whole = sp.to_dense_f32();
        for (b0, b1) in [(0usize, 7usize), (0, 2), (2, 5), (6, 7), (3, 3)] {
            let sl = sp.slice_col_blocks(b0..b1);
            let (c0, c1) = (b0 * 16, (b1 * 16).min(cols));
            assert_eq!(sl.rows, rows);
            assert_eq!(sl.cols, c1.saturating_sub(c0));
            let got = sl.to_dense_f32();
            let mut expect = Vec::new();
            for k in 0..rows {
                expect.extend_from_slice(&whole[k * cols + c0..k * cols + c1]);
            }
            assert_eq!(got, expect, "blocks {b0}..{b1}");
        }
    }

    #[test]
    fn slice_col_blocks_int8_keeps_prefix_consistent() {
        let mut g = XorShift::new(8);
        let (rows, cols) = (64, 96);
        let w: Vec<i8> = (0..rows * cols)
            .map(|_| {
                if g.next_f64() < 0.5 {
                    0
                } else {
                    (g.below(253) as i32 - 126) as i8
                }
            })
            .collect();
        let sp: SparseTensor<i8> = SparseTensor::pack(&w, rows, cols);
        let sl = sp.slice_col_blocks(2..5);
        assert_eq!(sl.tile_nnz_prefix[0], 0);
        assert_eq!(*sl.tile_nnz_prefix.last().unwrap() as usize, sl.nnz());
        for t in 0..sl.num_tiles() {
            let (vals, _) = sl.tile_values(t);
            let pop: u32 = sl.tile_metadata(t).iter().map(|m| m.count_ones()).sum();
            assert_eq!(pop as usize, vals.len());
        }
        // dense content matches the column slice of the whole
        let whole = sp.to_dense();
        let got = sl.to_dense();
        let mut expect = Vec::new();
        for k in 0..rows {
            expect.extend_from_slice(&whole[k * cols + 32..k * cols + 80]);
        }
        assert_eq!(got, expect);
    }

    #[test]
    fn empty_matrix_edge() {
        let sp = SparseTensor::pack_f32(&[], 0, 0);
        assert_eq!(sp.nnz(), 0);
        assert_eq!(sp.num_tiles(), 0);
        assert_eq!(sp.sparsity(), 0.0);
        assert!(sp.to_dense_f32().is_empty());
    }
}
