//! Thread partitioning of the sparse stream (paper Figure 9).
//!
//! Unstructured sparsity means a worker cannot compute where its share of
//! `weight_values` begins without scanning every preceding bitmap word.
//! The paper's fix: at model-load time, precompute `weight_value_index` —
//! the starting offset into `weight_values` for each thread — and fix the
//! thread count for the lifetime of the packed model. This module builds
//! that table from a [`SparseTensor`]'s tile-nnz prefix sums.

use super::format::{Element, SparseTensor};
use crate::util::threadpool::partition_ranges;

/// Per-thread work assignment over a sparse weight stream.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ThreadPartition {
    /// Number of threads the table was built for (fixed thereafter).
    pub threads: usize,
    /// Column-block range `[start, end)` owned by each thread.
    pub col_block_ranges: Vec<(usize, usize)>,
    /// `weight_value_index[t]`: offset into `weight_values` where thread
    /// `t` begins consuming. One extra tail entry = total nnz.
    pub weight_value_index: Vec<usize>,
}

impl ThreadPartition {
    /// Build the partition for `threads` workers over `sp`. Column blocks
    /// (16 neurons each) are split contiguously and as evenly as possible;
    /// each thread's value offset is read from the tile prefix table —
    /// O(threads), not O(nnz), at load time (the prefix table itself is
    /// built during packing).
    pub fn build<T: Element>(sp: &SparseTensor<T>, threads: usize) -> ThreadPartition {
        let threads = threads.max(1);
        let ranges = partition_ranges(sp.col_blocks(), threads);
        let k_chunks = sp.k_chunks();
        let mut col_block_ranges = Vec::with_capacity(threads);
        let mut weight_value_index = Vec::with_capacity(threads + 1);
        for r in &ranges {
            col_block_ranges.push((r.start, r.end));
            let first_tile = r.start * k_chunks;
            weight_value_index.push(sp.tile_nnz_prefix[first_tile] as usize);
        }
        weight_value_index.push(sp.nnz());
        ThreadPartition {
            threads,
            col_block_ranges,
            weight_value_index,
        }
    }

    /// Values consumed by thread `t`.
    pub fn values_for(&self, t: usize) -> std::ops::Range<usize> {
        self.weight_value_index[t]..self.weight_value_index[t + 1]
    }

    /// Verify the table against a full scan of the stream — the invariant
    /// the paper's correctness depends on. Used by tests and debug builds.
    pub fn validate<T: Element>(&self, sp: &SparseTensor<T>) -> Result<(), String> {
        let k_chunks = sp.k_chunks();
        let mut running = 0usize;
        let mut t = 0usize;
        for cb in 0..sp.col_blocks() {
            while t < self.threads && self.col_block_ranges[t].0 == cb {
                if self.weight_value_index[t] != running {
                    return Err(format!(
                        "thread {t}: index {} != scanned {running}",
                        self.weight_value_index[t]
                    ));
                }
                t += 1;
            }
            for kc in 0..k_chunks {
                let tile = sp.tile_index(cb, kc);
                running += sp
                    .tile_metadata(tile)
                    .iter()
                    .map(|w| w.count_ones() as usize)
                    .sum::<usize>();
            }
        }
        // threads assigned empty ranges at the tail
        while t < self.threads {
            if self.weight_value_index[t] != running {
                return Err(format!("tail thread {t} index mismatch"));
            }
            t += 1;
        }
        if *self.weight_value_index.last().unwrap() != sp.nnz() {
            return Err("tail sentinel != nnz".into());
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::prune::magnitude_prune;
    use crate::util::XorShift;

    fn sample(rows: usize, cols: usize, sparsity: f64, seed: u64) -> SparseTensor {
        let mut g = XorShift::new(seed);
        let w: Vec<f32> = (0..rows * cols).map(|_| g.next_normal() + 2.0).collect();
        let w = magnitude_prune(&w, sparsity);
        SparseTensor::pack_f32(&w, rows, cols)
    }

    #[test]
    fn offsets_match_full_scan() {
        let sp = sample(128, 256, 0.5, 1);
        for threads in [1, 2, 3, 8, 16, 32] {
            let part = ThreadPartition::build(&sp, threads);
            part.validate(&sp).expect("partition invariant");
        }
    }

    #[test]
    fn ranges_cover_all_values_disjointly() {
        let sp = sample(64, 320, 0.7, 2);
        let part = ThreadPartition::build(&sp, 5);
        let mut covered = 0;
        for t in 0..5 {
            let r = part.values_for(t);
            assert_eq!(r.start, covered);
            covered = r.end;
        }
        assert_eq!(covered, sp.nnz());
    }

    #[test]
    fn more_threads_than_blocks() {
        let sp = sample(32, 32, 0.5, 3); // only 2 column blocks
        let part = ThreadPartition::build(&sp, 8);
        part.validate(&sp).expect("valid with idle threads");
        let nonempty = part
            .col_block_ranges
            .iter()
            .filter(|(s, e)| e > s)
            .count();
        assert_eq!(nonempty, 2);
    }

    #[test]
    fn dense_matrix_partitions_by_element_count() {
        let w = vec![1.0f32; 64 * 64];
        let sp = SparseTensor::pack_f32(&w, 64, 64);
        let part = ThreadPartition::build(&sp, 4);
        // 4 column blocks, 1 per thread, each 64*16 values
        for t in 0..4 {
            assert_eq!(part.values_for(t).len(), 64 * 16);
        }
    }

    #[test]
    fn rebuild_with_different_thread_count_changes_table() {
        // the paper: changing thread count requires recomputation
        let sp = sample(96, 96, 0.4, 4);
        let p2 = ThreadPartition::build(&sp, 2);
        let p3 = ThreadPartition::build(&sp, 3);
        assert_ne!(p2.weight_value_index, p3.weight_value_index);
        p2.validate(&sp).unwrap();
        p3.validate(&sp).unwrap();
    }
}
