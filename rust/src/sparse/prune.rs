//! Magnitude pruning for weights and the KV cache (paper §6.1).
//!
//! The paper prunes by magnitude: within a tensor (per layer for KV), the
//! smallest-|x| fraction is zeroed. Pruning a *sorted-threshold* fraction
//! exactly matches the paper's "values with the lowest magnitudes are
//! dropped within each layer".

/// Zero out the smallest-magnitude `sparsity` fraction of `w` (returns a
/// new vector). `sparsity` is clamped to [0, 1].
pub fn magnitude_prune(w: &[f32], sparsity: f64) -> Vec<f32> {
    let mut out = w.to_vec();
    magnitude_prune_inplace(&mut out, sparsity);
    out
}

/// In-place variant of [`magnitude_prune`].
pub fn magnitude_prune_inplace(w: &mut [f32], sparsity: f64) {
    let sparsity = sparsity.clamp(0.0, 1.0);
    let k = (w.len() as f64 * sparsity).round() as usize;
    if k == 0 {
        return;
    }
    if k >= w.len() {
        w.fill(0.0);
        return;
    }
    let thresh = kth_magnitude(w, k);
    // Zero strictly-below-threshold first, then zero ties until exactly k
    // elements are pruned (deterministic: earliest ties first).
    let mut pruned = 0;
    for x in w.iter_mut() {
        if x.abs() < thresh {
            *x = 0.0;
            pruned += 1;
        }
    }
    if pruned < k {
        for x in w.iter_mut() {
            if pruned == k {
                break;
            }
            if *x != 0.0 && x.abs() == thresh {
                *x = 0.0;
                pruned += 1;
            }
        }
    }
}

/// The k-th smallest |x| (1-based: k=1 gives the smallest). Uses
/// quickselect on a scratch copy — O(n) expected.
pub fn kth_magnitude(w: &[f32], k: usize) -> f32 {
    assert!(k >= 1 && k <= w.len());
    let mut mags: Vec<f32> = w.iter().map(|x| x.abs()).collect();
    let (_, kth, _) = mags.select_nth_unstable_by(k - 1, |a, b| {
        a.partial_cmp(b).expect("NaN magnitude")
    });
    *kth
}

/// Observed sparsity of a tensor.
pub fn sparsity_of(w: &[f32]) -> f64 {
    if w.is_empty() {
        return 0.0;
    }
    w.iter().filter(|&&x| x == 0.0).count() as f64 / w.len() as f64
}

/// Per-group magnitude pruning: prune each contiguous group of
/// `group_len` elements independently (used per-head / per-layer for the
/// KV cache so one head's outliers don't shield another head's values).
pub fn magnitude_prune_grouped(w: &[f32], group_len: usize, sparsity: f64) -> Vec<f32> {
    assert!(group_len > 0);
    let mut out = Vec::with_capacity(w.len());
    for chunk in w.chunks(group_len) {
        out.extend(magnitude_prune(chunk, sparsity));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::XorShift;

    #[test]
    fn prunes_exact_fraction() {
        let mut g = XorShift::new(1);
        let w: Vec<f32> = (0..1000).map(|_| g.next_normal()).collect();
        for s in [0.0, 0.1, 0.5, 0.9, 1.0] {
            let p = magnitude_prune(&w, s);
            let zeros = p.iter().filter(|&&x| x == 0.0).count();
            assert_eq!(zeros, (1000.0 * s).round() as usize, "sparsity {s}");
        }
    }

    #[test]
    fn keeps_largest_magnitudes() {
        let w = vec![0.1, -5.0, 0.2, 3.0, -0.05, 1.0];
        let p = magnitude_prune(&w, 0.5);
        assert_eq!(p, vec![0.0, -5.0, 0.0, 3.0, 0.0, 1.0]);
    }

    #[test]
    fn handles_ties_deterministically() {
        let w = vec![1.0f32; 8];
        let p = magnitude_prune(&w, 0.5);
        let zeros = p.iter().filter(|&&x| x == 0.0).count();
        assert_eq!(zeros, 4);
        // earliest ties pruned first
        assert_eq!(&p[..4], &[0.0; 4]);
        assert_eq!(&p[4..], &[1.0; 4]);
    }

    #[test]
    fn grouped_prunes_each_group() {
        // group 1 has huge values, group 2 tiny — global pruning would wipe
        // group 2 entirely; grouped pruning keeps half of each.
        let w = vec![100.0, 200.0, 300.0, 400.0, 0.01, 0.02, 0.03, 0.04];
        let p = magnitude_prune_grouped(&w, 4, 0.5);
        assert_eq!(
            p,
            vec![0.0, 0.0, 300.0, 400.0, 0.0, 0.0, 0.03, 0.04]
        );
    }

    #[test]
    fn kth_magnitude_matches_sort() {
        let mut g = XorShift::new(2);
        let w: Vec<f32> = (0..257).map(|_| g.next_normal()).collect();
        let mut sorted: Vec<f32> = w.iter().map(|x| x.abs()).collect();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        for k in [1, 7, 128, 257] {
            assert_eq!(kth_magnitude(&w, k), sorted[k - 1]);
        }
    }

    #[test]
    fn sparsity_of_reports() {
        assert_eq!(sparsity_of(&[0.0, 1.0, 0.0, 2.0]), 0.5);
        assert_eq!(sparsity_of(&[]), 0.0);
    }

    #[test]
    fn full_prune_zeroes_everything() {
        let w = vec![1.0, 2.0, 3.0];
        assert_eq!(magnitude_prune(&w, 1.0), vec![0.0; 3]);
    }
}
