//! [`AvxBackend`]: the Appendix-B AVX-512 column-group kernel behind the
//! [`LinearBackend`] API.
//!
//! AVX-512 has no tile unit, so the "dense" entry points run the sparse
//! kernel over an all-elements pack — the same modeling the baselines
//! use for llama.cpp's dense AVX class. INT8 on AVX is modeled coarsely:
//! numerics come from the exact reference GEMM and the cost prediction
//! halves the value-stream bytes (the same adjustment
//! [`crate::baselines::systems::linear_cost`] applies).

use super::{BackendKind, CpuCaps, Dtype, GemmShape, LinearBackend, RefBackend};
use crate::amx::kernels::{avx_sparse_gemm_bf16, avx_sparse_gemm_bf16_batched, DenseWeights};
use crate::amx::EventCounters;
use crate::perf::cost::avx_sparse_gemm_cost;
use crate::perf::{KernelCost, Machine};
use crate::sparse::format::SparseTensor;
use crate::util::bf16::Bf16;

/// Column groups the paper found best on its testbed (Appendix B).
pub const DEFAULT_COLUMN_GROUPS: usize = 16;

/// The AVX-512 backend; `column_groups` is the Appendix-B
/// `num_neuron_groups` knob baked into the packed layout at load time.
#[derive(Clone, Copy, Debug)]
pub struct AvxBackend {
    pub column_groups: usize,
}

impl Default for AvxBackend {
    fn default() -> AvxBackend {
        AvxBackend {
            column_groups: DEFAULT_COLUMN_GROUPS,
        }
    }
}

impl AvxBackend {
    pub fn with_groups(column_groups: usize) -> AvxBackend {
        AvxBackend {
            column_groups: column_groups.max(1),
        }
    }
}

impl LinearBackend for AvxBackend {
    fn name(&self) -> &'static str {
        "avx"
    }

    fn kind(&self) -> BackendKind {
        BackendKind::Avx
    }

    fn supported(&self, caps: &CpuCaps) -> bool {
        caps.avx512f && caps.avx512_vbmi2
    }

    fn gemm_bf16(
        &self,
        input: &[f32],
        batch: usize,
        w: &DenseWeights<Bf16>,
        ctr: &mut EventCounters,
    ) -> Vec<f32> {
        // dense on AVX = the sparse kernel over an all-elements pack.
        // `pack_dense` keeps zeros so every value streams and the
        // counters show genuine dense traffic (matching this backend's
        // dense-plan prediction, nnz = k·n). The tile-stream → vector
        // layout conversion here is O(k·n) per call: hot paths should
        // pre-pack with `SparseTensor::pack_dense` and call
        // `sparse_gemm_bf16` instead (the model-level `PackCache` does).
        let sp = SparseTensor::pack_dense(&w.to_dense(), w.rows, w.cols);
        avx_sparse_gemm_bf16(input, batch, &sp, self.column_groups, ctr)
    }

    fn sparse_gemm_bf16(
        &self,
        input: &[f32],
        batch: usize,
        sp: &SparseTensor<Bf16>,
        ctr: &mut EventCounters,
    ) -> Vec<f32> {
        avx_sparse_gemm_bf16(input, batch, sp, self.column_groups, ctr)
    }

    fn gemm_int8(
        &self,
        input: &[i8],
        batch: usize,
        w: &DenseWeights<i8>,
        ctr: &mut EventCounters,
    ) -> Vec<i32> {
        tick_int8(ctr, batch, w.rows, w.cols, w.rows * w.cols, self.column_groups, batch);
        RefBackend::matmul_i8(input, batch, &w.to_dense(), w.rows, w.cols)
    }

    fn sparse_gemm_int8(
        &self,
        input: &[i8],
        batch: usize,
        sp: &SparseTensor<i8>,
        ctr: &mut EventCounters,
    ) -> Vec<i32> {
        tick_int8(ctr, batch, sp.rows, sp.cols, sp.nnz(), self.column_groups, batch);
        RefBackend::matmul_i8(input, batch, &sp.to_dense(), sp.rows, sp.cols)
    }

    fn gemm_bf16_batched(
        &self,
        input: &[f32],
        batch: usize,
        w: &DenseWeights<Bf16>,
        ctr: &mut EventCounters,
    ) -> Vec<f32> {
        // one layout conversion + one multi-row kernel pass, vs. the
        // default's per-row convert-and-stream loop
        let sp = SparseTensor::pack_dense(&w.to_dense(), w.rows, w.cols);
        avx_sparse_gemm_bf16_batched(input, batch, &sp, self.column_groups, ctr)
    }

    fn sparse_gemm_bf16_batched(
        &self,
        input: &[f32],
        batch: usize,
        sp: &SparseTensor<Bf16>,
        ctr: &mut EventCounters,
    ) -> Vec<f32> {
        avx_sparse_gemm_bf16_batched(input, batch, sp, self.column_groups, ctr)
    }

    fn gemm_int8_batched(
        &self,
        input: &[i8],
        batch: usize,
        w: &DenseWeights<i8>,
        ctr: &mut EventCounters,
    ) -> Vec<i32> {
        tick_int8(ctr, batch, w.rows, w.cols, w.rows * w.cols, self.column_groups, 1);
        RefBackend::matmul_i8(input, batch, &w.to_dense(), w.rows, w.cols)
    }

    fn sparse_gemm_int8_batched(
        &self,
        input: &[i8],
        batch: usize,
        sp: &SparseTensor<i8>,
        ctr: &mut EventCounters,
    ) -> Vec<i32> {
        tick_int8(ctr, batch, sp.rows, sp.cols, sp.nnz(), self.column_groups, 1);
        RefBackend::matmul_i8(input, batch, &sp.to_dense(), sp.rows, sp.cols)
    }

    fn predict(
        &self,
        shape: GemmShape,
        sparsity: f64,
        dtype: Dtype,
        sparse: bool,
        m: &Machine,
    ) -> f64 {
        let GemmShape { batch, k, n } = shape;
        // dense plan: all elements stream (no bitmap saving)
        let s = if sparse { sparsity } else { 0.0 };
        let cost = avx_sparse_gemm_cost(batch, k, n, s, self.column_groups, m);
        match dtype {
            Dtype::Bf16 => cost.time,
            // INT8 halves the weight-value bytes of the BF16 stream
            Dtype::Int8 => int8_time(&cost),
        }
    }
}

/// The baselines' INT8-on-AVX adjustment, shared with
/// [`crate::baselines::systems`].
pub(crate) fn int8_time(cost: &KernelCost) -> f64 {
    (cost.dram_time * 0.5).max(cost.core_time)
}

/// Coarse event ticks for the INT8-on-AVX path (`vpdpbusd`-class FMA:
/// 64 MACs per op). `stream_passes` is how many times the bitmap +
/// values stream is walked: once per batch row on the per-slot entry
/// points, once total on the batched ones (the fused block amortizes
/// the weight stream, which is the whole point of batching).
fn tick_int8(
    ctr: &mut EventCounters,
    batch: usize,
    rows: usize,
    cols: usize,
    nnz: usize,
    groups: usize,
    stream_passes: usize,
) {
    let col_blocks = cols.div_ceil(16);
    // INT8 bitmap: one 64-bit word per tile row, 16 rows per tile →
    // 128 B per (col_block, k_chunk) tile, k padded to 64.
    let bitmap_bytes = col_blocks * rows.div_ceil(64) * 128;
    ctr.input_unique_bytes += (batch * rows) as u64;
    ctr.input_bytes += (batch * rows) as u64;
    ctr.weight_unique_bytes += (bitmap_bytes + nnz) as u64;
    ctr.weight_stream_bytes += ((bitmap_bytes + nnz) * stream_passes) as u64;
    ctr.avx_fma += ((batch * rows * cols).div_ceil(64)) as u64;
    ctr.output_bytes += (batch * cols * 4) as u64;
    let tasks = (col_blocks.div_ceil(groups.max(1))) as u64;
    ctr.parallel_tasks = match (ctr.parallel_tasks, tasks) {
        (0, x) => x,
        (a, b) => a.min(b),
    };
}
