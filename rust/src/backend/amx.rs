//! [`AmxBackend`]: the paper's AMX tile kernels (§4.1 dense, §4.3
//! sparse, §4.5 INT8) behind the [`LinearBackend`] API.

use super::{BackendKind, CpuCaps, Dtype, GemmShape, LinearBackend};
use crate::amx::kernels::{
    dense_amx_gemm_bf16, dense_amx_gemm_int8, sparse_amx_gemm_bf16, sparse_amx_gemm_int8,
    DenseWeights,
};
use crate::amx::EventCounters;
use crate::perf::cost::{
    dense_gemm_cost, dense_int8_gemm_cost, sparse_gemm_cost, sparse_int8_gemm_cost,
};
use crate::perf::Machine;
use crate::sparse::format::SparseTensor;
use crate::util::bf16::Bf16;

/// The AMX tile-kernel backend (stateless; the kernels own their
/// scratch).
#[derive(Clone, Copy, Debug, Default)]
pub struct AmxBackend;

impl LinearBackend for AmxBackend {
    fn name(&self) -> &'static str {
        "amx"
    }

    fn kind(&self) -> BackendKind {
        BackendKind::Amx
    }

    fn supported(&self, caps: &CpuCaps) -> bool {
        caps.amx_bf16
    }

    fn supported_dtype(&self, caps: &CpuCaps, dtype: Dtype) -> bool {
        match dtype {
            Dtype::Bf16 => caps.amx_bf16,
            Dtype::Int8 => caps.amx_int8,
        }
    }

    fn gemm_bf16(
        &self,
        input: &[f32],
        batch: usize,
        w: &DenseWeights<Bf16>,
        ctr: &mut EventCounters,
    ) -> Vec<f32> {
        dense_amx_gemm_bf16(input, batch, w, ctr)
    }

    fn sparse_gemm_bf16(
        &self,
        input: &[f32],
        batch: usize,
        sp: &SparseTensor<Bf16>,
        ctr: &mut EventCounters,
    ) -> Vec<f32> {
        sparse_amx_gemm_bf16(input, batch, sp, ctr)
    }

    fn gemm_int8(
        &self,
        input: &[i8],
        batch: usize,
        w: &DenseWeights<i8>,
        ctr: &mut EventCounters,
    ) -> Vec<i32> {
        dense_amx_gemm_int8(input, batch, w, ctr)
    }

    fn sparse_gemm_int8(
        &self,
        input: &[i8],
        batch: usize,
        sp: &SparseTensor<i8>,
        ctr: &mut EventCounters,
    ) -> Vec<i32> {
        sparse_amx_gemm_int8(input, batch, sp, ctr)
    }

    // The tile kernels already walk 32-row m-blocks and stream (or
    // decompress) each weight tile once per *call*, so a fused
    // activation block is a single plain call — that one call is what
    // amortizes the weight stream over the batch, vs. the default's
    // one-stream-per-row loop. Per output element the k-accumulation
    // schedule is row-independent, so these are bit-exact vs. looping
    // batch 1.

    fn gemm_bf16_batched(
        &self,
        input: &[f32],
        batch: usize,
        w: &DenseWeights<Bf16>,
        ctr: &mut EventCounters,
    ) -> Vec<f32> {
        dense_amx_gemm_bf16(input, batch, w, ctr)
    }

    fn sparse_gemm_bf16_batched(
        &self,
        input: &[f32],
        batch: usize,
        sp: &SparseTensor<Bf16>,
        ctr: &mut EventCounters,
    ) -> Vec<f32> {
        sparse_amx_gemm_bf16(input, batch, sp, ctr)
    }

    fn gemm_int8_batched(
        &self,
        input: &[i8],
        batch: usize,
        w: &DenseWeights<i8>,
        ctr: &mut EventCounters,
    ) -> Vec<i32> {
        dense_amx_gemm_int8(input, batch, w, ctr)
    }

    fn sparse_gemm_int8_batched(
        &self,
        input: &[i8],
        batch: usize,
        sp: &SparseTensor<i8>,
        ctr: &mut EventCounters,
    ) -> Vec<i32> {
        sparse_amx_gemm_int8(input, batch, sp, ctr)
    }

    fn predict(
        &self,
        shape: GemmShape,
        sparsity: f64,
        dtype: Dtype,
        sparse: bool,
        m: &Machine,
    ) -> f64 {
        let GemmShape { batch, k, n } = shape;
        match (dtype, sparse) {
            (Dtype::Bf16, false) => dense_gemm_cost(batch, k, n, m).time,
            (Dtype::Bf16, true) => sparse_gemm_cost(batch, k, n, sparsity, m).time,
            (Dtype::Int8, false) => dense_int8_gemm_cost(batch, k, n, m).time,
            (Dtype::Int8, true) => sparse_int8_gemm_cost(batch, k, n, sparsity, m).time,
        }
    }
}
