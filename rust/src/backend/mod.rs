//! Unified linear-layer dispatch: one kernel API, capability-detected
//! backends, sparsity-aware auto-selection.
//!
//! SparAMX's headline feature (paper §1, §4) is *automatic* replacement
//! of every linear layer with the best kernel for the hardware. This
//! module is that extension point for the Rust port: instead of call
//! sites hard-wiring `dense_amx_gemm_bf16` / `sparse_amx_gemm_bf16` /
//! `avx_sparse_gemm_bf16`, everything routes through a [`LinearBackend`]
//! trait object held in a cheap, cloneable [`Backend`] handle.
//!
//! * [`LinearBackend`] — the kernel API: dense + sparse GEMM in BF16 and
//!   INT8, a name, a capability gate, and a cost prediction used for
//!   auto-selection.
//! * [`AmxBackend`] / [`AvxBackend`] / [`RefBackend`] — the paper's AMX
//!   tile kernels, the Appendix-B AVX-512 kernel, and the f32 reference
//!   oracle, each wrapping the simulated kernels in
//!   [`crate::amx::kernels`].
//! * [`BaselineBackend`] — an adapter over the comparison-system cost
//!   models in [`crate::baselines::systems`] (stock PyTorch, DeepSparse,
//!   llama.cpp), so the figure benches and A/B tests can run baselines
//!   through the same API.
//! * [`CpuCaps`] / [`BackendRegistry`] — startup capability probing
//!   (AVX-512 via `is_x86_feature_detected!`, AMX via `/proc/cpuinfo`,
//!   `SPARAMX_CAPS` env override for CI machines without AMX) and the
//!   per-layer `select(shape, sparsity, dtype)` policy that reproduces
//!   the paper's dense-vs-sparse crossover (Table 2 / Figure 11) using
//!   the [`crate::perf::cost`] model.
//!
//! New backends (a NUMA-partitioned or sharded one, say) implement
//! [`LinearBackend`], register in the [`BackendRegistry`], and every
//! call site — attention, model forward, engine, benches — picks them up
//! without modification.

pub mod amx;
pub mod avx;
pub mod baseline;
pub mod caps;
pub mod reference;
pub mod registry;

pub use amx::AmxBackend;
pub use avx::AvxBackend;
pub use baseline::BaselineBackend;
pub use caps::CpuCaps;
pub use reference::RefBackend;
pub use registry::{BackendRegistry, Selection, PROBATION_PROBES, QUARANTINE_THRESHOLD};

use crate::amx::kernels::DenseWeights;
use crate::amx::EventCounters;
use crate::perf::Machine;
use crate::sparse::format::SparseTensor;
use crate::util::bf16::Bf16;
use std::fmt;
use std::sync::Arc;

/// Weight/activation precision of a dispatched GEMM.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Dtype {
    Bf16,
    Int8,
}

/// The logical shape of one linear-layer GEMM: `batch × k` activations
/// against a `k × n` weight matrix.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct GemmShape {
    pub batch: usize,
    pub k: usize,
    pub n: usize,
}

impl GemmShape {
    pub fn new(batch: usize, k: usize, n: usize) -> GemmShape {
        GemmShape { batch, k, n }
    }

    /// Shape of a named model linear at the given batch.
    pub fn for_linear(l: &crate::models::llama::LinearShape, batch: usize) -> GemmShape {
        GemmShape::new(batch, l.in_features, l.out_features)
    }
}

/// Kernel-class identity of a backend.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BackendKind {
    /// AMX tile kernels (paper §4.1/§4.3/§4.5).
    Amx,
    /// AVX-512 column-group kernel (paper §4.4, Appendix B).
    Avx,
    /// f32 reference oracle (always available; never auto-selected).
    Reference,
    /// Comparison-system adapter over [`crate::baselines::systems`].
    Baseline,
    /// NUMA/core-partitioned wrapper running an inner backend's kernel
    /// on column shards in parallel ([`crate::shard::ShardedBackend`]).
    Sharded,
}

/// User-facing backend directive (`--backend` / config `"backend"`).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum BackendChoice {
    /// Let [`BackendRegistry::select`] pick per layer.
    #[default]
    Auto,
    Amx,
    Avx,
    Reference,
}

impl BackendChoice {
    /// All accepted spellings, for help text.
    pub const HELP: &'static str = "auto|amx|avx|ref";
}

impl std::str::FromStr for BackendChoice {
    type Err = String;

    fn from_str(s: &str) -> Result<BackendChoice, String> {
        match s.to_ascii_lowercase().as_str() {
            "auto" => Ok(BackendChoice::Auto),
            "amx" => Ok(BackendChoice::Amx),
            "avx" => Ok(BackendChoice::Avx),
            "ref" | "reference" => Ok(BackendChoice::Reference),
            other => Err(format!("unknown backend '{other}' (expected {})", Self::HELP)),
        }
    }
}

impl fmt::Display for BackendChoice {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let s = match self {
            BackendChoice::Auto => "auto",
            BackendChoice::Amx => "amx",
            BackendChoice::Avx => "avx",
            BackendChoice::Reference => "ref",
        };
        write!(f, "{s}")
    }
}

/// The kernel API every backend implements. Object-safe so call sites
/// hold `dyn LinearBackend` behind a [`Backend`] handle.
///
/// All four GEMM entry points return numerics identical (up to
/// BF16/INT8 rounding) to the dense reference and tick the
/// [`EventCounters`] the perf model consumes — the same contract the
/// free-function kernels had.
pub trait LinearBackend: Send + Sync {
    /// Short stable name ("amx", "avx", "ref", "baseline-pytorch", ...).
    fn name(&self) -> &'static str;

    /// Kernel-class identity.
    fn kind(&self) -> BackendKind;

    /// Whether this backend's native instruction stream could run on a
    /// machine with the given capabilities. The simulated kernels
    /// themselves execute anywhere; this gates *auto-selection* so a
    /// deployment on a non-AMX host models what it could actually run.
    fn supported(&self, caps: &CpuCaps) -> bool;

    /// Dtype-refined capability gate (e.g. AMX INT8 needs `amx-int8`).
    fn supported_dtype(&self, caps: &CpuCaps, dtype: Dtype) -> bool {
        let _ = dtype;
        self.supported(caps)
    }

    /// Dense BF16 GEMM on pre-packed weights.
    fn gemm_bf16(
        &self,
        input: &[f32],
        batch: usize,
        w: &DenseWeights<Bf16>,
        ctr: &mut EventCounters,
    ) -> Vec<f32>;

    /// Sparse BF16 GEMM on the bitmap+values format.
    fn sparse_gemm_bf16(
        &self,
        input: &[f32],
        batch: usize,
        sp: &SparseTensor<Bf16>,
        ctr: &mut EventCounters,
    ) -> Vec<f32>;

    /// Dense INT8 GEMM (INT32 accumulation).
    fn gemm_int8(
        &self,
        input: &[i8],
        batch: usize,
        w: &DenseWeights<i8>,
        ctr: &mut EventCounters,
    ) -> Vec<i32>;

    /// Sparse INT8 GEMM.
    fn sparse_gemm_int8(
        &self,
        input: &[i8],
        batch: usize,
        sp: &SparseTensor<i8>,
        ctr: &mut EventCounters,
    ) -> Vec<i32>;

    /// Dense BF16 GEMM over a fused activation block (`batch` decode
    /// rows gathered into one call). The default loops the batch-1 path
    /// row by row — bit-exact by construction, but it re-streams the
    /// weights once per row. Kernel backends override this to stream
    /// each packed weight block once across all rows; every override
    /// must match this default bit-for-bit.
    fn gemm_bf16_batched(
        &self,
        input: &[f32],
        batch: usize,
        w: &DenseWeights<Bf16>,
        ctr: &mut EventCounters,
    ) -> Vec<f32> {
        let mut out = Vec::with_capacity(batch * w.cols);
        for b in 0..batch {
            out.extend(self.gemm_bf16(&input[b * w.rows..(b + 1) * w.rows], 1, w, ctr));
        }
        out
    }

    /// Sparse BF16 GEMM over a fused activation block. Same contract as
    /// [`LinearBackend::gemm_bf16_batched`]: the default loops batch-1
    /// calls (the bit-exact oracle), overrides amortize the compressed
    /// weight stream over the rows.
    fn sparse_gemm_bf16_batched(
        &self,
        input: &[f32],
        batch: usize,
        sp: &SparseTensor<Bf16>,
        ctr: &mut EventCounters,
    ) -> Vec<f32> {
        let mut out = Vec::with_capacity(batch * sp.cols);
        for b in 0..batch {
            out.extend(self.sparse_gemm_bf16(&input[b * sp.rows..(b + 1) * sp.rows], 1, sp, ctr));
        }
        out
    }

    /// Dense INT8 GEMM over a fused activation block (see
    /// [`LinearBackend::gemm_bf16_batched`] for the contract).
    fn gemm_int8_batched(
        &self,
        input: &[i8],
        batch: usize,
        w: &DenseWeights<i8>,
        ctr: &mut EventCounters,
    ) -> Vec<i32> {
        let mut out = Vec::with_capacity(batch * w.cols);
        for b in 0..batch {
            out.extend(self.gemm_int8(&input[b * w.rows..(b + 1) * w.rows], 1, w, ctr));
        }
        out
    }

    /// Sparse INT8 GEMM over a fused activation block.
    fn sparse_gemm_int8_batched(
        &self,
        input: &[i8],
        batch: usize,
        sp: &SparseTensor<i8>,
        ctr: &mut EventCounters,
    ) -> Vec<i32> {
        let mut out = Vec::with_capacity(batch * sp.cols);
        for b in 0..batch {
            out.extend(self.sparse_gemm_int8(&input[b * sp.rows..(b + 1) * sp.rows], 1, sp, ctr));
        }
        out
    }

    /// Modeled wall seconds for one GEMM of `shape` at `sparsity` on
    /// machine `m`, running this backend's dense (`sparse == false`) or
    /// sparse kernel class. Drives [`BackendRegistry::select`]; must
    /// agree with [`crate::perf::cost`] so the selection reproduces the
    /// paper's crossover points.
    fn predict(&self, shape: GemmShape, sparsity: f64, dtype: Dtype, sparse: bool, m: &Machine)
        -> f64;

    /// Whether dense-class operands should be packed as an all-elements
    /// value stream instead of a tile stream (the AVX kernel executes
    /// dense matrices that way and would re-convert tile layouts on
    /// every call otherwise). Wrappers delegate to their inner backend.
    fn dense_as_stream(&self) -> bool {
        self.kind() == BackendKind::Avx
    }

    /// Shard partitioning this backend wants applied at plan-compile
    /// time: `Some((shards, topology))` makes
    /// [`PackedOperand::pack_f32`] pre-slice the operand into that many
    /// column shards. `None` (the default) means unsharded operands.
    fn shard_spec(&self) -> Option<(usize, crate::shard::NumaTopology)> {
        None
    }

    /// BF16 GEMM on a pre-sharded operand. The default runs the parts
    /// sequentially and concatenates their outputs column-wise in shard
    /// order — any backend is therefore a bit-exact oracle for sharded
    /// execution. [`crate::shard::ShardedBackend`] overrides this with
    /// the parallel worker-pool path, which must match the default
    /// bit-for-bit.
    fn gemm_bf16_sharded(
        &self,
        input: &[f32],
        batch: usize,
        op: &crate::shard::ShardedOperand,
        ctr: &mut EventCounters,
    ) -> Vec<f32> {
        let parts: Vec<Vec<f32>> = op
            .parts
            .iter()
            .map(|p| match p {
                PackedOperand::Sparse(sp) => self.sparse_gemm_bf16(input, batch, sp, ctr),
                PackedOperand::Dense(dw) => self.gemm_bf16(input, batch, dw, ctr),
                PackedOperand::Sharded(_) => unreachable!("nested sharded operand"),
            })
            .collect();
        crate::shard::merge_col_outputs(&parts, &op.plan, batch, op.cols)
    }

    /// BF16 GEMM over a fused activation block on a pre-sharded
    /// operand. The default runs each column shard's *batched* kernel
    /// sequentially and merges in shard order, so fused GEMMs stay
    /// bit-exact under sharding (column partitioning only — the k
    /// dimension is never split). [`crate::shard::ShardedBackend`]
    /// overrides this to scatter the batched per-shard calls across the
    /// worker pool.
    fn gemm_bf16_sharded_batched(
        &self,
        input: &[f32],
        batch: usize,
        op: &crate::shard::ShardedOperand,
        ctr: &mut EventCounters,
    ) -> Vec<f32> {
        let parts: Vec<Vec<f32>> = op
            .parts
            .iter()
            .map(|p| match p {
                PackedOperand::Sparse(sp) => self.sparse_gemm_bf16_batched(input, batch, sp, ctr),
                PackedOperand::Dense(dw) => self.gemm_bf16_batched(input, batch, dw, ctr),
                PackedOperand::Sharded(_) => unreachable!("nested sharded operand"),
            })
            .collect();
        crate::shard::merge_col_outputs(&parts, &op.plan, batch, op.cols)
    }

    /// Snapshot of per-shard timing since the last call, for the
    /// metrics layer. `None` for backends that don't shard.
    fn shard_stats(&self) -> Option<crate::shard::ShardStatsSnapshot> {
        None
    }

    /// The persistent worker pool this backend executes on, if any.
    /// Lets other parallel phases (the fused attention head-group
    /// scatter) reuse the same workers instead of spawning their own.
    fn worker_pool(&self) -> Option<Arc<crate::shard::WorkerPool>> {
        None
    }
}

/// Cheap, cloneable handle to a [`LinearBackend`] — what call sites
/// carry (engine, attention, model forward, benches).
///
/// The handle's GEMM entry points are also the **kernel fault-recovery
/// seam**: when a [`crate::fault`] plan is armed, a panic inside the
/// kernel is caught here, retried once on the same backend (bit-exact —
/// deterministic faults are spent once fired), and on a second failure
/// the f32 reference oracle completes the call on the same
/// backend-agnostic operand while the failure is recorded for registry
/// quarantine. With no plan armed every entry point is a plain
/// delegating call.
#[derive(Clone)]
pub struct Backend(Arc<dyn LinearBackend>);

impl Backend {
    /// Wrap any backend implementation.
    pub fn from_impl(b: impl LinearBackend + 'static) -> Backend {
        Backend(Arc::new(b))
    }

    /// The AMX tile-kernel backend.
    pub fn amx() -> Backend {
        Backend::from_impl(AmxBackend)
    }

    /// The AVX-512 backend with the paper's default 16 column groups.
    pub fn avx() -> Backend {
        Backend::from_impl(AvxBackend::default())
    }

    /// The AVX-512 backend with an explicit column-group count.
    pub fn avx_with_groups(column_groups: usize) -> Backend {
        Backend::from_impl(AvxBackend::with_groups(column_groups))
    }

    /// The f32 reference oracle.
    pub fn reference() -> Backend {
        Backend::from_impl(RefBackend)
    }

    /// A comparison-system adapter.
    pub fn baseline(b: crate::baselines::systems::Baseline) -> Backend {
        Backend::from_impl(BaselineBackend::new(b))
    }

    /// A sharded wrapper over `inner`: operands are pre-partitioned into
    /// `shards` column shards at plan-compile time and executed in
    /// parallel on `pool`, bit-exact vs. `inner` run unsharded.
    pub fn sharded(
        inner: Backend,
        shards: usize,
        topo: crate::shard::NumaTopology,
        pool: Arc<crate::shard::WorkerPool>,
    ) -> Backend {
        Backend::from_impl(crate::shard::ShardedBackend::new(inner, shards, topo, pool))
    }

    pub fn name(&self) -> &'static str {
        self.0.name()
    }

    pub fn kind(&self) -> BackendKind {
        self.0.kind()
    }

    pub fn supported(&self, caps: &CpuCaps) -> bool {
        self.0.supported(caps)
    }

    pub fn supported_dtype(&self, caps: &CpuCaps, dtype: Dtype) -> bool {
        self.0.supported_dtype(caps, dtype)
    }

    /// Run one GEMM entry point under the kernel fault-recovery ladder
    /// (see the struct docs). Unarmed: a plain delegating call. Armed:
    /// attempt → same-backend retry → reference fallback, with the
    /// failure recorded for quarantine before falling back. Event
    /// counters merge only from the attempt that produced the returned
    /// output, so recovered calls account identically to fault-free ones.
    fn guarded<T>(
        &self,
        ctr: &mut EventCounters,
        f: impl Fn(&dyn LinearBackend, &mut EventCounters) -> T,
    ) -> T {
        if !crate::fault::armed() {
            return f(self.0.as_ref(), ctr);
        }
        let name = self.name();
        for _attempt in 0..2 {
            let mut tmp = EventCounters::default();
            let out = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                crate::fault::on_kernel_call(name);
                f(self.0.as_ref(), &mut tmp)
            }));
            if let Ok(out) = out {
                ctr.merge(&tmp);
                return out;
            }
        }
        crate::fault::record_backend_failure(name);
        let mut tmp = EventCounters::default();
        let out = f(&RefBackend, &mut tmp);
        ctr.merge(&tmp);
        out
    }

    pub fn gemm_bf16(
        &self,
        input: &[f32],
        batch: usize,
        w: &DenseWeights<Bf16>,
        ctr: &mut EventCounters,
    ) -> Vec<f32> {
        self.guarded(ctr, |b, c| b.gemm_bf16(input, batch, w, c))
    }

    pub fn sparse_gemm_bf16(
        &self,
        input: &[f32],
        batch: usize,
        sp: &SparseTensor<Bf16>,
        ctr: &mut EventCounters,
    ) -> Vec<f32> {
        self.guarded(ctr, |b, c| b.sparse_gemm_bf16(input, batch, sp, c))
    }

    pub fn gemm_int8(
        &self,
        input: &[i8],
        batch: usize,
        w: &DenseWeights<i8>,
        ctr: &mut EventCounters,
    ) -> Vec<i32> {
        self.guarded(ctr, |b, c| b.gemm_int8(input, batch, w, c))
    }

    pub fn sparse_gemm_int8(
        &self,
        input: &[i8],
        batch: usize,
        sp: &SparseTensor<i8>,
        ctr: &mut EventCounters,
    ) -> Vec<i32> {
        self.guarded(ctr, |b, c| b.sparse_gemm_int8(input, batch, sp, c))
    }

    pub fn gemm_bf16_batched(
        &self,
        input: &[f32],
        batch: usize,
        w: &DenseWeights<Bf16>,
        ctr: &mut EventCounters,
    ) -> Vec<f32> {
        self.guarded(ctr, |b, c| b.gemm_bf16_batched(input, batch, w, c))
    }

    pub fn sparse_gemm_bf16_batched(
        &self,
        input: &[f32],
        batch: usize,
        sp: &SparseTensor<Bf16>,
        ctr: &mut EventCounters,
    ) -> Vec<f32> {
        self.guarded(ctr, |b, c| b.sparse_gemm_bf16_batched(input, batch, sp, c))
    }

    pub fn gemm_int8_batched(
        &self,
        input: &[i8],
        batch: usize,
        w: &DenseWeights<i8>,
        ctr: &mut EventCounters,
    ) -> Vec<i32> {
        self.guarded(ctr, |b, c| b.gemm_int8_batched(input, batch, w, c))
    }

    pub fn sparse_gemm_int8_batched(
        &self,
        input: &[i8],
        batch: usize,
        sp: &SparseTensor<i8>,
        ctr: &mut EventCounters,
    ) -> Vec<i32> {
        self.guarded(ctr, |b, c| b.sparse_gemm_int8_batched(input, batch, sp, c))
    }

    pub fn predict(
        &self,
        shape: GemmShape,
        sparsity: f64,
        dtype: Dtype,
        sparse: bool,
        m: &Machine,
    ) -> f64 {
        self.0.predict(shape, sparsity, dtype, sparse, m)
    }

    pub fn dense_as_stream(&self) -> bool {
        self.0.dense_as_stream()
    }

    pub fn shard_spec(&self) -> Option<(usize, crate::shard::NumaTopology)> {
        self.0.shard_spec()
    }

    pub fn gemm_bf16_sharded(
        &self,
        input: &[f32],
        batch: usize,
        op: &crate::shard::ShardedOperand,
        ctr: &mut EventCounters,
    ) -> Vec<f32> {
        self.guarded(ctr, |b, c| b.gemm_bf16_sharded(input, batch, op, c))
    }

    pub fn gemm_bf16_sharded_batched(
        &self,
        input: &[f32],
        batch: usize,
        op: &crate::shard::ShardedOperand,
        ctr: &mut EventCounters,
    ) -> Vec<f32> {
        self.guarded(ctr, |b, c| b.gemm_bf16_sharded_batched(input, batch, op, c))
    }

    pub fn shard_stats(&self) -> Option<crate::shard::ShardStatsSnapshot> {
        self.0.shard_stats()
    }

    pub fn worker_pool(&self) -> Option<Arc<crate::shard::WorkerPool>> {
        self.0.worker_pool()
    }

    /// Shadow-probe entry point for quarantine probation: run the dense
    /// BF16 kernel raw — no fault seam, no retry, no reference fallback
    /// — and report `None` if it panicked. Probes deliberately bypass
    /// [`crate::fault::on_kernel_call`] so pinned `kernel_fail` windows
    /// are never consumed by probation traffic, and their event counters
    /// are discarded so analytic counter assertions on the serving path
    /// stay exact. The output is never served: the caller compares it
    /// against the serving backend's mirror of the same GEMM and feeds
    /// the verdict to `BackendRegistry::record_probe`.
    pub fn probe_gemm_bf16(
        &self,
        input: &[f32],
        batch: usize,
        w: &DenseWeights<Bf16>,
    ) -> Option<Vec<f32>> {
        std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let mut tmp = EventCounters::default();
            self.0.gemm_bf16(input, batch, w, &mut tmp)
        }))
        .ok()
    }
}

/// A weight matrix packed once into the operand class one backend's
/// kernel consumes — the single place the dense-vs-sparse packing
/// decision (including the AVX dense-as-stream special case) lives, so
/// the tinyforward dispatch and the decode-plan compiler cannot drift.
#[derive(Clone, Debug)]
pub enum PackedOperand {
    /// Bitmap+values stream for the sparse kernel class.
    Sparse(SparseTensor),
    /// Tile stream for the dense kernel class.
    Dense(DenseWeights<Bf16>),
    /// Pre-partitioned column shards for a sharding backend (built at
    /// plan-compile time; the decode loop never re-partitions).
    Sharded(crate::shard::ShardedOperand),
}

impl PackedOperand {
    /// Logical `(rows, cols)` of the packed matrix.
    pub fn dims(&self) -> (usize, usize) {
        match self {
            PackedOperand::Sparse(sp) => (sp.rows, sp.cols),
            PackedOperand::Dense(dw) => (dw.rows, dw.cols),
            PackedOperand::Sharded(so) => (so.rows, so.cols),
        }
    }

    /// Pack `w` (`rows × cols`, row-major f32) for `backend`'s
    /// `use_sparse` kernel class. Dense-class operands for stream-dense
    /// backends (AVX, or a sharded wrapper over AVX) are cached as an
    /// all-elements sparse stream ([`AvxBackend`] executes dense
    /// matrices as a value stream and would otherwise re-convert the
    /// tile layout on every call). If the backend declares a
    /// [`LinearBackend::shard_spec`], the whole operand is packed once
    /// and then sliced into per-shard parts — this is the only place
    /// shard partitioning happens on the serving path.
    pub fn pack_f32(
        backend: &Backend,
        w: &[f32],
        rows: usize,
        cols: usize,
        use_sparse: bool,
    ) -> PackedOperand {
        let whole = if use_sparse {
            PackedOperand::Sparse(SparseTensor::pack_f32(w, rows, cols))
        } else if backend.dense_as_stream() {
            PackedOperand::Sparse(SparseTensor::pack_dense_f32(w, rows, cols))
        } else {
            PackedOperand::Dense(DenseWeights::pack_f32(w, rows, cols))
        };
        match backend.shard_spec() {
            Some((shards, topo)) if shards > 1 => {
                let plan = crate::shard::ShardPlan::partition(cols, shards, &topo);
                PackedOperand::Sharded(crate::shard::ShardedOperand::from_whole(&whole, plan))
            }
            _ => whole,
        }
    }

    /// Dispatch one BF16 GEMM on the packed operand through `backend`.
    pub fn gemm_bf16(
        &self,
        backend: &Backend,
        x: &[f32],
        batch: usize,
        ctr: &mut EventCounters,
    ) -> Vec<f32> {
        match self {
            PackedOperand::Sparse(sp) => backend.sparse_gemm_bf16(x, batch, sp, ctr),
            PackedOperand::Dense(dw) => backend.gemm_bf16(x, batch, dw, ctr),
            PackedOperand::Sharded(so) => backend.gemm_bf16_sharded(x, batch, so, ctr),
        }
    }

    /// Dispatch one fused (multi-row) BF16 GEMM on the packed operand:
    /// the batched kernel entry points, which stream each weight block
    /// once across all `batch` rows.
    pub fn gemm_bf16_batched(
        &self,
        backend: &Backend,
        x: &[f32],
        batch: usize,
        ctr: &mut EventCounters,
    ) -> Vec<f32> {
        match self {
            PackedOperand::Sparse(sp) => backend.sparse_gemm_bf16_batched(x, batch, sp, ctr),
            PackedOperand::Dense(dw) => backend.gemm_bf16_batched(x, batch, dw, ctr),
            PackedOperand::Sharded(so) => backend.gemm_bf16_sharded_batched(x, batch, so, ctr),
        }
    }
}

impl fmt::Debug for Backend {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "Backend({})", self.name())
    }
}

impl PartialEq for Backend {
    fn eq(&self, other: &Backend) -> bool {
        self.kind() == other.kind() && self.name() == other.name()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn backend_choice_parses() {
        assert_eq!("auto".parse::<BackendChoice>().unwrap(), BackendChoice::Auto);
        assert_eq!("AMX".parse::<BackendChoice>().unwrap(), BackendChoice::Amx);
        assert_eq!("avx".parse::<BackendChoice>().unwrap(), BackendChoice::Avx);
        assert_eq!("ref".parse::<BackendChoice>().unwrap(), BackendChoice::Reference);
        assert_eq!(
            "reference".parse::<BackendChoice>().unwrap(),
            BackendChoice::Reference
        );
        assert!("mkl".parse::<BackendChoice>().is_err());
        assert_eq!(BackendChoice::default(), BackendChoice::Auto);
        assert_eq!(BackendChoice::Reference.to_string(), "ref");
    }

    #[test]
    fn handle_identity_and_debug() {
        let a = Backend::amx();
        assert_eq!(a, a.clone());
        assert_ne!(a, Backend::avx());
        assert_eq!(format!("{a:?}"), "Backend(amx)");
        assert_eq!(Backend::reference().kind(), BackendKind::Reference);
    }
}
