//! [`BaselineBackend`]: adapter putting the comparison-system cost
//! models of [`crate::baselines::systems`] behind the same
//! [`LinearBackend`] API as our kernels, so figure benches and A/B
//! tests dispatch baselines exactly like SparAMX backends.
//!
//! Numerics map each baseline to the kernel class the paper attributes
//! to it (§5, §7): stock PyTorch runs dense AMX GEMMs (on pruned
//! weights, densified — what eager PyTorch actually does with a pruned
//! checkpoint); DeepSparse runs the sparse AVX class; llama.cpp runs
//! dense AVX. Cost predictions delegate to
//! [`crate::baselines::systems::linear_cost`], which adds each system's
//! framework overhead / fusion factor.

use super::{AmxBackend, AvxBackend, BackendKind, CpuCaps, Dtype, GemmShape, LinearBackend};
use crate::amx::kernels::DenseWeights;
use crate::amx::EventCounters;
use crate::baselines::systems::{linear_cost, Baseline, Precision};
use crate::perf::Machine;
use crate::sparse::format::{Element, SparseTensor};
use crate::util::bf16::Bf16;

/// Adapter over one comparison system.
#[derive(Clone, Copy, Debug)]
pub struct BaselineBackend {
    pub baseline: Baseline,
    amx: AmxBackend,
    avx: AvxBackend,
}

/// Which kernel class executes a baseline's numerics.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum Class {
    AmxDense,
    AmxSparse,
    AvxDense,
    AvxSparse,
}

impl BaselineBackend {
    pub fn new(baseline: Baseline) -> BaselineBackend {
        BaselineBackend {
            baseline,
            amx: AmxBackend,
            avx: AvxBackend::default(),
        }
    }

    fn class(&self) -> Class {
        match self.baseline {
            Baseline::PyTorch | Baseline::SparAmxDense => Class::AmxDense,
            Baseline::SparAmxSparse => Class::AmxSparse,
            Baseline::SparAvxSparse | Baseline::DeepSparse => Class::AvxSparse,
            Baseline::LlamaCpp => Class::AvxDense,
        }
    }

    /// Densify a sparse operand for the dense-system classes.
    fn densify<T: Element>(sp: &SparseTensor<T>) -> DenseWeights<T> {
        DenseWeights::pack(&sp.to_dense(), sp.rows, sp.cols)
    }
}

impl LinearBackend for BaselineBackend {
    fn name(&self) -> &'static str {
        match self.baseline {
            Baseline::PyTorch => "baseline-pytorch",
            Baseline::SparAmxDense => "baseline-amx-dense",
            Baseline::SparAmxSparse => "baseline-amx-sparse",
            Baseline::SparAvxSparse => "baseline-avx-sparse",
            Baseline::DeepSparse => "baseline-deepsparse",
            Baseline::LlamaCpp => "baseline-llamacpp",
        }
    }

    fn kind(&self) -> BackendKind {
        BackendKind::Baseline
    }

    fn supported(&self, _caps: &CpuCaps) -> bool {
        // comparison systems carry their own runtime fallbacks; they are
        // never candidates for our auto-selection anyway
        true
    }

    fn gemm_bf16(
        &self,
        input: &[f32],
        batch: usize,
        w: &DenseWeights<Bf16>,
        ctr: &mut EventCounters,
    ) -> Vec<f32> {
        match self.class() {
            Class::AmxDense | Class::AmxSparse => self.amx.gemm_bf16(input, batch, w, ctr),
            Class::AvxDense | Class::AvxSparse => self.avx.gemm_bf16(input, batch, w, ctr),
        }
    }

    fn sparse_gemm_bf16(
        &self,
        input: &[f32],
        batch: usize,
        sp: &SparseTensor<Bf16>,
        ctr: &mut EventCounters,
    ) -> Vec<f32> {
        match self.class() {
            Class::AmxDense => self.amx.gemm_bf16(input, batch, &Self::densify(sp), ctr),
            Class::AmxSparse => self.amx.sparse_gemm_bf16(input, batch, sp, ctr),
            Class::AvxDense => self.avx.gemm_bf16(input, batch, &Self::densify(sp), ctr),
            Class::AvxSparse => self.avx.sparse_gemm_bf16(input, batch, sp, ctr),
        }
    }

    fn gemm_int8(
        &self,
        input: &[i8],
        batch: usize,
        w: &DenseWeights<i8>,
        ctr: &mut EventCounters,
    ) -> Vec<i32> {
        match self.class() {
            Class::AmxDense | Class::AmxSparse => self.amx.gemm_int8(input, batch, w, ctr),
            Class::AvxDense | Class::AvxSparse => self.avx.gemm_int8(input, batch, w, ctr),
        }
    }

    fn sparse_gemm_int8(
        &self,
        input: &[i8],
        batch: usize,
        sp: &SparseTensor<i8>,
        ctr: &mut EventCounters,
    ) -> Vec<i32> {
        match self.class() {
            Class::AmxDense => self.amx.gemm_int8(input, batch, &Self::densify(sp), ctr),
            Class::AmxSparse => self.amx.sparse_gemm_int8(input, batch, sp, ctr),
            Class::AvxDense => self.avx.gemm_int8(input, batch, &Self::densify(sp), ctr),
            Class::AvxSparse => self.avx.sparse_gemm_int8(input, batch, sp, ctr),
        }
    }

    fn predict(
        &self,
        shape: GemmShape,
        sparsity: f64,
        dtype: Dtype,
        sparse: bool,
        m: &Machine,
    ) -> f64 {
        // the kernel class (and hence dense/sparse) is inherent to the
        // baseline, so the `sparse` plan flag only zeroes the sparsity
        // for dense plans
        let s = if sparse { sparsity } else { 0.0 };
        let precision = match dtype {
            Dtype::Bf16 => Precision::Bf16,
            Dtype::Int8 => Precision::Int8,
        };
        linear_cost(self.baseline, precision, shape.batch, shape.k, shape.n, s, m)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::RefBackend;
    use crate::sparse::prune::magnitude_prune;
    use crate::util::XorShift;

    #[test]
    fn dense_system_densifies_sparse_operands() {
        // stock PyTorch runs pruned weights through its dense kernel:
        // the adapter must produce reference numerics and zero vpexpand.
        let mut g = XorShift::new(71);
        let (k, n) = (64usize, 48usize);
        let w = magnitude_prune(&g.normal_vec(k * n, 1.0), 0.5);
        let x = g.normal_vec(k, 1.0);
        let sp = SparseTensor::pack_f32(&w, k, n);
        let py = BaselineBackend::new(Baseline::PyTorch);
        let mut ctr = EventCounters::default();
        let got = py.sparse_gemm_bf16(&x, 1, &sp, &mut ctr);
        let want = RefBackend::matmul_f32(&x, 1, &w, k, n);
        let tol = 0.02 * (k as f32).sqrt();
        for i in 0..n {
            assert!((got[i] - want[i]).abs() <= tol + want[i].abs() * 0.02);
        }
        assert_eq!(ctr.vpexpand, 0, "dense class never decompresses");
        assert!(ctr.tdp_bf16 > 0, "dense AMX class uses tile compute");
    }

    #[test]
    fn pytorch_prediction_carries_framework_overhead() {
        let m = Machine::default();
        let shape = GemmShape::new(1, 1024, 1024);
        let py = BaselineBackend::new(Baseline::PyTorch)
            .predict(shape, 0.0, Dtype::Bf16, false, &m);
        let ours = BaselineBackend::new(Baseline::SparAmxDense)
            .predict(shape, 0.0, Dtype::Bf16, false, &m);
        assert!(py > ours, "framework overhead must show: {py} vs {ours}");
    }
}
