//! [`RefBackend`]: the f32 reference oracle behind the
//! [`LinearBackend`] API.
//!
//! Always available, never auto-selected: [`LinearBackend::predict`]
//! returns a sentinel far above any modeled kernel time, so
//! [`crate::backend::BackendRegistry::select`] only falls back to it
//! when no hardware backend is eligible. The oracle models no
//! architectural events — counters are left untouched.

use super::{BackendKind, CpuCaps, Dtype, GemmShape, LinearBackend};
use crate::amx::kernels::{ref_gemm_bf16, ref_gemm_int8, DenseWeights};
use crate::amx::EventCounters;
use crate::perf::Machine;
use crate::sparse::format::SparseTensor;
use crate::util::bf16::Bf16;

/// Sentinel predicted time (seconds) keeping the oracle out of
/// auto-selection while remaining finite for comparisons.
pub const REF_PREDICT_S: f64 = 1e9;

/// The reference oracle backend.
#[derive(Clone, Copy, Debug, Default)]
pub struct RefBackend;

impl RefBackend {
    /// Reference BF16-rounded GEMM on a raw row-major f32 matrix — the
    /// oracle every simulated kernel is validated against. Exposed as an
    /// inherent method so oracle call sites (attention's dense
    /// reference, parity tests) route through the backend layer too.
    pub fn matmul_f32(
        input: &[f32],
        batch: usize,
        w: &[f32],
        rows: usize,
        cols: usize,
    ) -> Vec<f32> {
        ref_gemm_bf16(input, batch, w, rows, cols)
    }

    /// Reference exact INT8 GEMM on a raw row-major i8 matrix.
    pub fn matmul_i8(input: &[i8], batch: usize, w: &[i8], rows: usize, cols: usize) -> Vec<i32> {
        ref_gemm_int8(input, batch, w, rows, cols)
    }
}

impl LinearBackend for RefBackend {
    fn name(&self) -> &'static str {
        "ref"
    }

    fn kind(&self) -> BackendKind {
        BackendKind::Reference
    }

    fn supported(&self, _caps: &CpuCaps) -> bool {
        true
    }

    fn gemm_bf16(
        &self,
        input: &[f32],
        batch: usize,
        w: &DenseWeights<Bf16>,
        _ctr: &mut EventCounters,
    ) -> Vec<f32> {
        Self::matmul_f32(input, batch, &w.to_dense_f32(), w.rows, w.cols)
    }

    fn sparse_gemm_bf16(
        &self,
        input: &[f32],
        batch: usize,
        sp: &SparseTensor<Bf16>,
        _ctr: &mut EventCounters,
    ) -> Vec<f32> {
        Self::matmul_f32(input, batch, &sp.to_dense_f32(), sp.rows, sp.cols)
    }

    fn gemm_int8(
        &self,
        input: &[i8],
        batch: usize,
        w: &DenseWeights<i8>,
        _ctr: &mut EventCounters,
    ) -> Vec<i32> {
        Self::matmul_i8(input, batch, &w.to_dense(), w.rows, w.cols)
    }

    fn sparse_gemm_int8(
        &self,
        input: &[i8],
        batch: usize,
        sp: &SparseTensor<i8>,
        _ctr: &mut EventCounters,
    ) -> Vec<i32> {
        Self::matmul_i8(input, batch, &sp.to_dense(), sp.rows, sp.cols)
    }

    fn predict(
        &self,
        _shape: GemmShape,
        _sparsity: f64,
        _dtype: Dtype,
        _sparse: bool,
        _m: &Machine,
    ) -> f64 {
        REF_PREDICT_S
    }
}
