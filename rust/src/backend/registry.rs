//! [`BackendRegistry`]: capability-probed backend inventory plus the
//! sparsity-aware per-layer selection policy.
//!
//! `select(shape, sparsity, dtype)` enumerates every (backend, plan)
//! pair eligible under the probed [`CpuCaps`] and picks the one with the
//! lowest [`LinearBackend::predict`] time on the registry's modeled
//! [`Machine`]. Because `predict` is the same [`crate::perf::cost`]
//! model that regenerates the paper's tables, the selection reproduces
//! the per-layer dense-vs-sparse crossover of Table 2 / Figure 11: at
//! batch 1 the memory-bound linears go sparse, at high batch the
//! compute-bound regime flips them back to dense, and on hosts without
//! AMX the AVX kernel (or ultimately [`RefBackend`]) takes over.

use super::{Backend, BackendChoice, BackendKind, CpuCaps, Dtype, GemmShape};
use crate::perf::Machine;
use std::collections::{BTreeMap, BTreeSet};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

/// Kernel failures (reference-fallback events recorded via
/// [`BackendRegistry::record_failure`]) before a backend is quarantined.
pub const QUARANTINE_THRESHOLD: u32 = 2;

/// Consecutive clean shadow probes (recorded via
/// [`BackendRegistry::record_probe`]) before a quarantined backend is
/// re-admitted to selection. One unclean probe resets the streak.
pub const PROBATION_PROBES: u32 = 3;

/// Whether `name` — or, for a sharded wrapper, the kernel class it wraps
/// — is in the quarantined set. Quarantining "amx" also sidelines
/// "sharded-amx" (same failing kernel class); quarantining
/// "sharded-amx" alone leaves the unsharded "amx" eligible (the pool,
/// not the kernel, was the problem).
/// Lock a health-state mutex, tolerating poison: each critical section
/// is a single insert/increment, so a panicked holder cannot leave the
/// maps logically inconsistent.
fn lock_clean<T>(m: &Mutex<T>) -> std::sync::MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|p| p.into_inner())
}

fn name_quarantined(q: &BTreeSet<String>, name: &str) -> bool {
    if q.contains(name) {
        return true;
    }
    match name.strip_prefix("sharded-") {
        Some(inner) => q.contains(inner),
        None => false,
    }
}

/// Outcome of one selection: which backend, which kernel class, and the
/// modeled time that won.
#[derive(Clone, Debug)]
pub struct Selection {
    pub backend: Backend,
    /// `true` → the sparse kernel (bitmap+values operand); `false` → the
    /// dense kernel on densified weights.
    pub use_sparse: bool,
    /// Modeled seconds of the winning plan.
    pub predicted_s: f64,
}

impl Selection {
    /// Human-readable plan, e.g. `amx/sparse`.
    pub fn describe(&self) -> String {
        format!(
            "{}/{}",
            self.backend.name(),
            if self.use_sparse { "sparse" } else { "dense" }
        )
    }
}

/// The startup-probed backend inventory.
pub struct BackendRegistry {
    caps: CpuCaps,
    machine: Machine,
    backends: Vec<Backend>,
    /// Selections computed through this registry (`select` + pinned
    /// `resolve`). Lets tests assert selection happens at model load
    /// and never in the token loop (ROADMAP invariant): snapshot after
    /// plan compilation, decode, snapshot again — any re-selection on
    /// the serving path ticks this counter.
    resolutions: AtomicU64,
    /// Backend-health state (PR 9): kernel failures recorded per backend
    /// name by the engine's recovery drain. A backend that keeps failing
    /// crosses [`QUARANTINE_THRESHOLD`] and lands in `quarantined`, after
    /// which `select` skips it and a pinned `resolve` reroutes to the
    /// reference oracle — the input to degraded-mode re-planning.
    failure_counts: Mutex<BTreeMap<String, u32>>,
    quarantined: Mutex<BTreeSet<String>>,
    /// Probation state (PR 10): consecutive clean shadow probes per
    /// quarantined backend. At [`PROBATION_PROBES`] the backend is
    /// released back into selection and its failure count cleared.
    probe_streaks: Mutex<BTreeMap<String, u32>>,
    /// Mirror of `quarantined.len()`, maintained under that lock, so the
    /// engine's per-step "anything on probation?" check is one relaxed
    /// atomic load instead of a mutex acquisition on the healthy path.
    quarantine_count: AtomicU64,
}

impl BackendRegistry {
    /// Probe the host (honouring the `SPARAMX_CAPS` override) and build
    /// the standard inventory: AMX, AVX, reference.
    pub fn probe() -> BackendRegistry {
        BackendRegistry::with_caps(CpuCaps::detect())
    }

    /// Build with explicit capabilities (tests, what-if modeling).
    pub fn with_caps(caps: CpuCaps) -> BackendRegistry {
        BackendRegistry {
            caps,
            machine: Machine::default(),
            backends: vec![Backend::amx(), Backend::avx(), Backend::reference()],
            resolutions: AtomicU64::new(0),
            failure_counts: Mutex::new(BTreeMap::new()),
            quarantined: Mutex::new(BTreeSet::new()),
            probe_streaks: Mutex::new(BTreeMap::new()),
            quarantine_count: AtomicU64::new(0),
        }
    }

    /// Record one kernel failure for the named backend: a GEMM call that
    /// still panicked after the guarded same-backend retry and had to be
    /// served by the reference oracle. Returns `true` when this failure
    /// crossed [`QUARANTINE_THRESHOLD`] and newly quarantined the
    /// backend — the caller's cue to recompile the decode plan on the
    /// survivors. The reference backend is never quarantined: it is the
    /// recovery floor.
    pub fn record_failure(&self, name: &str) -> bool {
        if name == "ref" {
            return false;
        }
        let crossed = {
            let mut counts = lock_clean(&self.failure_counts);
            let c = counts.entry(name.to_string()).or_insert(0);
            *c += 1;
            *c >= QUARANTINE_THRESHOLD
        };
        if !crossed {
            return false;
        }
        let mut q = lock_clean(&self.quarantined);
        let newly = q.insert(name.to_string());
        if newly {
            self.quarantine_count.store(q.len() as u64, Ordering::Relaxed);
            // a fresh quarantine starts probation from zero
            lock_clean(&self.probe_streaks).remove(name);
        }
        newly
    }

    /// Record the outcome of one shadow probe against a quarantined
    /// backend. A clean probe (output matched the serving backend)
    /// extends the streak; an unclean one resets it. Returns `true`
    /// when this probe completed a [`PROBATION_PROBES`]-long clean
    /// streak and released the backend — the caller's cue to recompile
    /// the decode plan exactly once. Release also clears the backend's
    /// failure count so a later relapse restarts from a clean slate.
    /// Probes against names that are not quarantined are no-ops.
    pub fn record_probe(&self, name: &str, clean: bool) -> bool {
        let mut q = lock_clean(&self.quarantined);
        if !q.contains(name) {
            return false;
        }
        let mut streaks = lock_clean(&self.probe_streaks);
        if !clean {
            streaks.insert(name.to_string(), 0);
            return false;
        }
        let s = streaks.entry(name.to_string()).or_insert(0);
        *s += 1;
        if *s < PROBATION_PROBES {
            return false;
        }
        streaks.remove(name);
        q.remove(name);
        self.quarantine_count.store(q.len() as u64, Ordering::Relaxed);
        lock_clean(&self.failure_counts).remove(name);
        true
    }

    /// Whether any backend is currently quarantined: one relaxed atomic
    /// load, so the engine can check every step without touching the
    /// health-state mutexes on the healthy path.
    pub fn has_quarantined(&self) -> bool {
        self.quarantine_count.load(Ordering::Relaxed) > 0
    }

    /// Fetch a backend by exact name from the inventory (probe path:
    /// quarantined backends are addressed by the recorded failure name,
    /// not by kind, so sharded wrappers resolve distinctly).
    pub fn backend_by_name(&self, name: &str) -> Option<Backend> {
        self.backends.iter().find(|b| b.name() == name).cloned()
    }

    /// Names currently quarantined, in sorted order.
    pub fn quarantined(&self) -> Vec<String> {
        lock_clean(&self.quarantined).iter().cloned().collect()
    }

    /// Whether the named backend (or, for a sharded wrapper, the kernel
    /// class it wraps) is quarantined.
    pub fn is_quarantined(&self, name: &str) -> bool {
        name_quarantined(&lock_clean(&self.quarantined), name)
    }

    /// How many selections this registry has computed so far.
    pub fn selections_resolved(&self) -> u64 {
        self.resolutions.load(Ordering::Relaxed)
    }

    /// Use a different modeled machine for selection.
    pub fn with_machine(mut self, machine: Machine) -> BackendRegistry {
        self.machine = machine;
        self
    }

    /// Extend the inventory with sharded wrappers over the AMX and AVX
    /// backends (one shared persistent worker pool). A no-op when
    /// `shards <= 1`, so single-node hosts with `--shards auto` keep the
    /// standard inventory — including the invariant that a no-ISA host
    /// has exactly one available backend (the reference oracle, which is
    /// never sharded: it exists for bit-exact oracle comparisons).
    /// Sharded entries are appended *after* the unsharded ones, so with
    /// the strict `<` in [`BackendRegistry::select`] they only win when
    /// `predict` says sharding is strictly faster (the Fig 11
    /// crossover). Pinning `--backend amx` bypasses them by kind — a
    /// documented limitation; use `auto` to let sharding compete.
    pub fn with_shards(
        mut self,
        shards: usize,
        topo: crate::shard::NumaTopology,
    ) -> BackendRegistry {
        if shards > 1 {
            let pool =
                std::sync::Arc::new(crate::shard::WorkerPool::with_topology(shards, &topo));
            self.backends.push(Backend::sharded(
                Backend::amx(),
                shards,
                topo,
                std::sync::Arc::clone(&pool),
            ));
            self.backends
                .push(Backend::sharded(Backend::avx(), shards, topo, pool));
        }
        self
    }

    pub fn caps(&self) -> &CpuCaps {
        &self.caps
    }

    pub fn machine(&self) -> &Machine {
        &self.machine
    }

    pub fn backends(&self) -> &[Backend] {
        &self.backends
    }

    /// Backends whose native instruction stream the probed CPU supports
    /// (the reference oracle is always included).
    pub fn available(&self) -> Vec<Backend> {
        self.backends
            .iter()
            .filter(|b| b.supported(&self.caps))
            .cloned()
            .collect()
    }

    /// Fetch a backend by kind from the inventory.
    pub fn get(&self, kind: BackendKind) -> Option<Backend> {
        self.backends.iter().find(|b| b.kind() == kind).cloned()
    }

    /// Pick the fastest eligible (backend, plan) pair for one layer.
    pub fn select(&self, shape: GemmShape, sparsity: f64, dtype: Dtype) -> Selection {
        self.resolutions.fetch_add(1, Ordering::Relaxed);
        let mut best: Option<Selection> = None;
        let quarantined = lock_clean(&self.quarantined).clone();
        for b in &self.backends {
            if b.kind() == BackendKind::Reference {
                continue; // fallback only, handled below
            }
            if name_quarantined(&quarantined, b.name()) {
                continue; // degraded mode: failing kernel class sidelined
            }
            if !b.supported_dtype(&self.caps, dtype) {
                continue;
            }
            for sparse in [false, true] {
                if sparse && sparsity <= 0.0 {
                    continue;
                }
                let t = b.predict(shape, sparsity, dtype, sparse, &self.machine);
                let better = match &best {
                    None => true,
                    Some(s) => t < s.predicted_s,
                };
                if better {
                    best = Some(Selection {
                        backend: b.clone(),
                        use_sparse: sparse,
                        predicted_s: t,
                    });
                }
            }
        }
        best.unwrap_or_else(|| self.reference_fallback(shape, sparsity, dtype))
    }

    /// Resolve a user directive: `auto` selects, anything else pins the
    /// named backend (the simulated kernels run anywhere, so pinning is
    /// honoured even when the probed CPU lacks the ISA — the plan is
    /// still chosen by modeled time within that backend).
    pub fn resolve(
        &self,
        choice: BackendChoice,
        shape: GemmShape,
        sparsity: f64,
        dtype: Dtype,
    ) -> Selection {
        let kind = match choice {
            // select() counts the resolution itself
            BackendChoice::Auto => return self.select(shape, sparsity, dtype),
            BackendChoice::Amx => BackendKind::Amx,
            BackendChoice::Avx => BackendKind::Avx,
            BackendChoice::Reference => BackendKind::Reference,
        };
        self.resolutions.fetch_add(1, Ordering::Relaxed);
        let backend = self
            .get(kind)
            .expect("standard inventory always holds amx/avx/ref");
        if kind == BackendKind::Reference {
            return self.reference_fallback(shape, sparsity, dtype);
        }
        if self.is_quarantined(backend.name()) {
            // Pinning does not override quarantine: a backend that kept
            // failing reroutes to the oracle rather than keep crashing.
            return self.reference_fallback(shape, sparsity, dtype);
        }
        let dense_t = backend.predict(shape, sparsity, dtype, false, &self.machine);
        let (use_sparse, predicted_s) = if sparsity > 0.0 {
            let sparse_t = backend.predict(shape, sparsity, dtype, true, &self.machine);
            if sparse_t < dense_t {
                (true, sparse_t)
            } else {
                (false, dense_t)
            }
        } else {
            (false, dense_t)
        };
        Selection {
            backend,
            use_sparse,
            predicted_s,
        }
    }

    fn reference_fallback(&self, shape: GemmShape, sparsity: f64, dtype: Dtype) -> Selection {
        let backend = self
            .get(BackendKind::Reference)
            .expect("standard inventory always holds ref");
        let predicted_s = backend.predict(shape, sparsity, dtype, false, &self.machine);
        Selection {
            backend,
            use_sparse: false,
            predicted_s,
        }
    }
}

impl Default for BackendRegistry {
    fn default() -> BackendRegistry {
        BackendRegistry::probe()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::perf::cost::{dense_gemm_cost, sparse_gemm_cost};

    fn amx_only() -> BackendRegistry {
        BackendRegistry::with_caps(CpuCaps::from_list("amx"))
    }

    #[test]
    fn fallback_to_reference_without_any_isa() {
        let reg = BackendRegistry::with_caps(CpuCaps::none());
        let sel = reg.select(GemmShape::new(1, 4096, 4096), 0.5, Dtype::Bf16);
        assert_eq!(sel.backend.kind(), BackendKind::Reference);
        assert!(!sel.use_sparse);
        assert_eq!(reg.available().len(), 1, "only ref is available");
    }

    #[test]
    fn memory_bound_decode_selects_sparse_amx() {
        // Llama 3 8B up_proj at batch 1 / 50% sparsity: the Table 1
        // regime where sparse wins on bandwidth.
        let reg = amx_only();
        let shape = GemmShape::new(1, 4096, 14336);
        let sel = reg.select(shape, 0.5, Dtype::Bf16);
        assert_eq!(sel.backend.kind(), BackendKind::Amx);
        assert!(sel.use_sparse, "batch-1 decode must go sparse");
        // the winning prediction IS the cost model's number
        let expect = sparse_gemm_cost(1, 4096, 14336, 0.5, reg.machine()).time;
        assert!((sel.predicted_s - expect).abs() < 1e-12);
    }

    #[test]
    fn compute_bound_batch_selects_dense() {
        // §7: compute-bound high batch flips the crossover back to dense.
        let reg = amx_only();
        let shape = GemmShape::new(256, 4096, 4096);
        let sel = reg.select(shape, 0.5, Dtype::Bf16);
        assert_eq!(sel.backend.kind(), BackendKind::Amx);
        assert!(!sel.use_sparse, "compute-bound batch must go dense");
        let expect = dense_gemm_cost(256, 4096, 4096, reg.machine()).time;
        assert!((sel.predicted_s - expect).abs() < 1e-12);
    }

    #[test]
    fn selection_matches_cost_model_across_grid() {
        // select()'s sparse/dense decision must equal the sign of the
        // cost-model comparison at every (batch, sparsity) grid point.
        let reg = amx_only();
        let m = reg.machine();
        for &batch in &[1usize, 8, 32, 128, 256] {
            for &s in &[0.2f64, 0.5, 0.8] {
                let sel = reg.select(GemmShape::new(batch, 4096, 4096), s, Dtype::Bf16);
                let dense = dense_gemm_cost(batch, 4096, 4096, m).time;
                let sparse = sparse_gemm_cost(batch, 4096, 4096, s, m).time;
                assert_eq!(
                    sel.use_sparse,
                    sparse < dense,
                    "batch {batch} sparsity {s}: selection disagrees with cost model"
                );
            }
        }
    }

    #[test]
    fn avx_only_host_selects_avx() {
        let reg = BackendRegistry::with_caps(CpuCaps::from_list("avx512"));
        let sel = reg.select(GemmShape::new(1, 4096, 14336), 0.5, Dtype::Bf16);
        assert_eq!(sel.backend.kind(), BackendKind::Avx);
        assert!(sel.use_sparse);
    }

    #[test]
    fn int8_needs_amx_int8() {
        let caps = CpuCaps::from_list("amx-bf16"); // BF16 tiles only
        let reg = BackendRegistry::with_caps(caps);
        let sel = reg.select(GemmShape::new(1, 4096, 4096), 0.5, Dtype::Int8);
        assert_eq!(
            sel.backend.kind(),
            BackendKind::Reference,
            "no amx-int8, no avx512 → reference fallback"
        );
        let full = BackendRegistry::with_caps(CpuCaps::from_list("amx"));
        let sel = full.select(GemmShape::new(1, 4096, 4096), 0.5, Dtype::Int8);
        assert_eq!(sel.backend.kind(), BackendKind::Amx);
    }

    #[test]
    fn resolve_pins_and_auto_delegates() {
        let reg = BackendRegistry::with_caps(CpuCaps::all());
        let shape = GemmShape::new(1, 4096, 14336);
        let pinned = reg.resolve(BackendChoice::Avx, shape, 0.5, Dtype::Bf16);
        assert_eq!(pinned.backend.kind(), BackendKind::Avx);
        assert!(pinned.use_sparse, "sparse beats dense within AVX at batch 1");
        let auto = reg.resolve(BackendChoice::Auto, shape, 0.5, Dtype::Bf16);
        let direct = reg.select(shape, 0.5, Dtype::Bf16);
        assert_eq!(auto.backend, direct.backend);
        assert_eq!(auto.use_sparse, direct.use_sparse);
        let r = reg.resolve(BackendChoice::Reference, shape, 0.5, Dtype::Bf16);
        assert_eq!(r.backend.kind(), BackendKind::Reference);
    }

    #[test]
    fn dense_weights_select_dense_plan() {
        let reg = amx_only();
        let sel = reg.select(GemmShape::new(1, 1024, 1024), 0.0, Dtype::Bf16);
        assert!(!sel.use_sparse, "zero sparsity must never plan sparse");
    }

    #[test]
    fn repeated_failures_quarantine_and_select_reroutes() {
        let reg = BackendRegistry::with_caps(CpuCaps::all());
        let shape = GemmShape::new(1, 4096, 14336);
        let before = reg.select(shape, 0.5, Dtype::Bf16);
        let winner = before.backend.name().to_string();
        assert_ne!(before.backend.kind(), BackendKind::Reference);
        assert!(
            !reg.record_failure(&winner),
            "one failure is below the threshold"
        );
        assert!(reg.quarantined().is_empty());
        assert!(
            reg.record_failure(&winner),
            "second failure newly quarantines"
        );
        assert!(
            !reg.record_failure(&winner),
            "already quarantined — not 'newly'"
        );
        assert_eq!(reg.quarantined(), vec![winner.clone()]);
        assert!(reg.is_quarantined(&winner));
        let after = reg.select(shape, 0.5, Dtype::Bf16);
        assert_ne!(
            after.backend.name(),
            winner,
            "select must skip the quarantined backend"
        );
    }

    #[test]
    fn reference_is_never_quarantined() {
        let reg = BackendRegistry::with_caps(CpuCaps::none());
        for _ in 0..5 {
            assert!(!reg.record_failure("ref"));
        }
        assert!(reg.quarantined().is_empty());
        let sel = reg.select(GemmShape::new(1, 512, 512), 0.5, Dtype::Bf16);
        assert_eq!(sel.backend.kind(), BackendKind::Reference);
    }

    #[test]
    fn probation_releases_after_n_consecutive_clean_probes() {
        let reg = BackendRegistry::with_caps(CpuCaps::all());
        assert!(!reg.has_quarantined());
        assert!(
            !reg.record_probe("amx", true),
            "probing a healthy backend is a no-op"
        );
        reg.record_failure("amx");
        reg.record_failure("amx");
        assert!(reg.has_quarantined());
        for i in 0..PROBATION_PROBES - 1 {
            assert!(!reg.record_probe("amx", true), "probe {i} is below the streak");
        }
        assert!(
            !reg.record_probe("amx", false),
            "an unclean probe resets the streak"
        );
        for _ in 0..PROBATION_PROBES - 1 {
            assert!(!reg.record_probe("amx", true));
        }
        assert!(
            reg.record_probe("amx", true),
            "{PROBATION_PROBES} consecutive clean probes release"
        );
        assert!(!reg.has_quarantined());
        assert!(!reg.is_quarantined("amx"));
        assert!(
            !reg.record_probe("amx", true),
            "released — further probes are no-ops"
        );
        // release cleared the failure count: a relapse needs the full
        // threshold again
        assert!(!reg.record_failure("amx"));
        assert!(reg.record_failure("amx"));
        assert!(reg.has_quarantined());
    }

    #[test]
    fn backend_by_name_resolves_sharded_wrappers_distinctly() {
        let topo = crate::shard::NumaTopology::modeled(2, 8);
        let reg = BackendRegistry::with_caps(CpuCaps::all()).with_shards(4, topo);
        assert_eq!(reg.backend_by_name("amx").unwrap().name(), "amx");
        assert_eq!(
            reg.backend_by_name("sharded-amx").unwrap().name(),
            "sharded-amx"
        );
        assert!(reg.backend_by_name("no-such-backend").is_none());
    }

    #[test]
    fn quarantining_a_kernel_class_sidelines_its_sharded_wrapper() {
        let topo = crate::shard::NumaTopology::modeled(2, 8);
        let reg = BackendRegistry::with_caps(CpuCaps::all()).with_shards(4, topo);
        reg.record_failure("amx");
        reg.record_failure("amx");
        assert!(reg.is_quarantined("amx"));
        assert!(
            reg.is_quarantined("sharded-amx"),
            "the sharded wrapper runs the same failing kernel class"
        );
        assert!(
            !reg.is_quarantined("sharded-avx"),
            "other kernel classes stay eligible"
        );
        let shape = GemmShape::new(1, 4096, 14336);
        let sel = reg.select(shape, 0.5, Dtype::Bf16);
        assert_ne!(sel.backend.name(), "amx");
        assert_ne!(sel.backend.name(), "sharded-amx");
        // pinning does not override quarantine
        let pinned = reg.resolve(BackendChoice::Amx, shape, 0.5, Dtype::Bf16);
        assert_eq!(pinned.backend.kind(), BackendKind::Reference);
        // quarantining only the wrapper leaves the inner kernel eligible
        let reg2 = BackendRegistry::with_caps(CpuCaps::all()).with_shards(4, topo);
        reg2.record_failure("sharded-avx");
        reg2.record_failure("sharded-avx");
        assert!(reg2.is_quarantined("sharded-avx"));
        assert!(!reg2.is_quarantined("avx"));
    }
}
