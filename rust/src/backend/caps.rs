//! CPU capability probing for backend eligibility.
//!
//! The functional kernels in this crate run anywhere — what capabilities
//! gate is *auto-selection*: on a host without AMX the registry must not
//! plan an AMX kernel for a real deployment. Detection uses
//! `is_x86_feature_detected!` for AVX-512 and `/proc/cpuinfo` flags for
//! AMX (the `amx-*` detection tokens require newer toolchains than this
//! offline build targets), with a `SPARAMX_CAPS` environment override so
//! CI machines without AMX can still exercise every selection path:
//!
//! ```sh
//! SPARAMX_CAPS=all    cargo test            # pretend full Sapphire Rapids
//! SPARAMX_CAPS=none   cargo run ...         # force the reference fallback
//! SPARAMX_CAPS=avx512 cargo run ...         # AVX-512 but no AMX
//! ```

/// Capability bits the backends care about.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CpuCaps {
    /// AMX tiles with BF16 `tdpbf16ps`.
    pub amx_bf16: bool,
    /// AMX tiles with INT8 `tdpbssd`.
    pub amx_int8: bool,
    /// AVX-512 foundation.
    pub avx512f: bool,
    /// AVX-512 VBMI2 (`vpexpandw`/`vpexpandb`, the decompression core).
    pub avx512_vbmi2: bool,
}

/// Environment variable overriding detection (see module docs).
pub const CAPS_ENV: &str = "SPARAMX_CAPS";

impl CpuCaps {
    /// Everything the paper's Sapphire Rapids testbed has.
    pub const fn all() -> CpuCaps {
        CpuCaps {
            amx_bf16: true,
            amx_int8: true,
            avx512f: true,
            avx512_vbmi2: true,
        }
    }

    /// No relevant ISA extensions (forces the reference fallback).
    pub const fn none() -> CpuCaps {
        CpuCaps {
            amx_bf16: false,
            amx_int8: false,
            avx512f: false,
            avx512_vbmi2: false,
        }
    }

    /// Probe at startup: `SPARAMX_CAPS` override if set, else the host.
    pub fn detect() -> CpuCaps {
        match std::env::var(CAPS_ENV) {
            Ok(list) => CpuCaps::from_list(&list),
            Err(_) => CpuCaps::host(),
        }
    }

    /// Capabilities for *modeling* runs (examples, cost tables, the
    /// eval CLI): the paper's full Sapphire Rapids testbed unless
    /// `SPARAMX_CAPS` overrides. Host detection ([`CpuCaps::detect`])
    /// is for deployment decisions; the simulated kernels themselves
    /// run anywhere, so a dev laptop without AVX-512 should still see
    /// the modeled AMX numbers by default.
    pub fn modeled() -> CpuCaps {
        match std::env::var(CAPS_ENV) {
            Ok(list) => CpuCaps::from_list(&list),
            Err(_) => CpuCaps::all(),
        }
    }

    /// Detect the actual host CPU.
    pub fn host() -> CpuCaps {
        #[cfg(target_arch = "x86_64")]
        {
            CpuCaps {
                amx_bf16: cpuinfo_has("amx_bf16"),
                amx_int8: cpuinfo_has("amx_int8"),
                avx512f: std::is_x86_feature_detected!("avx512f"),
                avx512_vbmi2: cpuinfo_has("avx512_vbmi2"),
            }
        }
        #[cfg(not(target_arch = "x86_64"))]
        {
            CpuCaps::none()
        }
    }

    /// Parse a comma-separated capability list: `all`, `none`, or any of
    /// `amx` (both AMX bits), `amx-bf16`, `amx-int8`, `avx512`
    /// (foundation + VBMI2), `avx512f`, `vbmi2`. Unknown tokens are
    /// ignored so the override stays forward-compatible.
    pub fn from_list(list: &str) -> CpuCaps {
        let mut caps = CpuCaps::none();
        for tok in list.split(',') {
            match tok.trim().to_ascii_lowercase().replace('_', "-").as_str() {
                "all" => caps = CpuCaps::all(),
                "none" => caps = CpuCaps::none(),
                "amx" => {
                    caps.amx_bf16 = true;
                    caps.amx_int8 = true;
                }
                "amx-bf16" => caps.amx_bf16 = true,
                "amx-int8" => caps.amx_int8 = true,
                "avx512" => {
                    caps.avx512f = true;
                    caps.avx512_vbmi2 = true;
                }
                "avx512f" => caps.avx512f = true,
                "vbmi2" | "avx512-vbmi2" => caps.avx512_vbmi2 = true,
                _ => {}
            }
        }
        caps
    }

    /// Human-readable summary for banners/logs.
    pub fn describe(&self) -> String {
        let mut parts = Vec::new();
        if self.amx_bf16 {
            parts.push("amx-bf16");
        }
        if self.amx_int8 {
            parts.push("amx-int8");
        }
        if self.avx512f {
            parts.push("avx512f");
        }
        if self.avx512_vbmi2 {
            parts.push("avx512-vbmi2");
        }
        if parts.is_empty() {
            "none".into()
        } else {
            parts.join(",")
        }
    }
}

/// Whole-word membership in the `/proc/cpuinfo` flags line (Linux; other
/// platforms report false and rely on the env override).
#[cfg(target_arch = "x86_64")]
fn cpuinfo_has(flag: &str) -> bool {
    #[cfg(target_os = "linux")]
    {
        if let Ok(text) = std::fs::read_to_string("/proc/cpuinfo") {
            for line in text.lines() {
                let Some((key, rest)) = line.split_once(':') else {
                    continue;
                };
                if key.trim() == "flags" {
                    return rest.split_whitespace().any(|f| f == flag);
                }
            }
        }
        false
    }
    #[cfg(not(target_os = "linux"))]
    {
        let _ = flag;
        false
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn list_parsing() {
        assert_eq!(CpuCaps::from_list("all"), CpuCaps::all());
        assert_eq!(CpuCaps::from_list("none"), CpuCaps::none());
        let amx_only = CpuCaps::from_list("amx");
        assert!(amx_only.amx_bf16 && amx_only.amx_int8);
        assert!(!amx_only.avx512f && !amx_only.avx512_vbmi2);
        let mixed = CpuCaps::from_list(" amx-bf16 , avx512 ");
        assert!(mixed.amx_bf16 && !mixed.amx_int8);
        assert!(mixed.avx512f && mixed.avx512_vbmi2);
        // underscores and unknown tokens tolerated
        let ub = CpuCaps::from_list("amx_bf16,quantum");
        assert!(ub.amx_bf16 && !ub.amx_int8);
    }

    #[test]
    fn describe_roundtrips_through_from_list() {
        for caps in [CpuCaps::all(), CpuCaps::none(), CpuCaps::from_list("amx")] {
            assert_eq!(CpuCaps::from_list(&caps.describe()), caps);
        }
    }

    #[test]
    fn host_detection_does_not_panic() {
        let _ = CpuCaps::host();
        let _ = CpuCaps::detect();
    }
}
