//! The AMX tile register file and tile instructions.
//!
//! Models the architecture described in paper §2.4 / Figure 4: eight tile
//! registers, each up to 16 rows × 64 bytes; `tdpbf16ps` multiplies a
//! BF16 A-tile by a VNNI-interleaved BF16 B-tile accumulating FP32;
//! `tdpbssd` does the same for signed INT8 with INT32 accumulation.

use super::events::EventCounters;
use crate::util::bf16::Bf16;

/// Maximum tile rows (architectural).
pub const MAX_ROWS: usize = 16;
/// Maximum bytes per tile row (architectural).
pub const MAX_COLSB: usize = 64;
/// Number of tile registers per AMX unit.
pub const NUM_TILES: usize = 8;

/// One tile register: raw bytes plus its configured shape.
#[derive(Clone)]
pub struct Tile {
    pub rows: usize,
    pub colsb: usize,
    data: [u8; MAX_ROWS * MAX_COLSB],
}

impl Default for Tile {
    fn default() -> Self {
        Tile {
            rows: MAX_ROWS,
            colsb: MAX_COLSB,
            data: [0; MAX_ROWS * MAX_COLSB],
        }
    }
}

impl Tile {
    fn row(&self, r: usize) -> &[u8] {
        &self.data[r * MAX_COLSB..r * MAX_COLSB + self.colsb]
    }

    fn row_mut(&mut self, r: usize) -> &mut [u8] {
        &mut self.data[r * MAX_COLSB..r * MAX_COLSB + self.colsb]
    }

    /// Read element `(r, i)` as BF16.
    pub fn bf16(&self, r: usize, i: usize) -> Bf16 {
        let b = self.row(r);
        Bf16::from_bits(u16::from_le_bytes([b[2 * i], b[2 * i + 1]]))
    }

    /// Read element `(r, i)` as f32 (for accumulator tiles).
    pub fn f32(&self, r: usize, i: usize) -> f32 {
        let b = self.row(r);
        f32::from_le_bytes([b[4 * i], b[4 * i + 1], b[4 * i + 2], b[4 * i + 3]])
    }

    fn set_f32(&mut self, r: usize, i: usize, v: f32) {
        let b = self.row_mut(r);
        b[4 * i..4 * i + 4].copy_from_slice(&v.to_le_bytes());
    }

    /// Read element `(r, i)` as i8.
    pub fn i8(&self, r: usize, i: usize) -> i8 {
        self.row(r)[i] as i8
    }

    /// Read element `(r, i)` as i32 (for INT8 accumulator tiles).
    pub fn i32(&self, r: usize, i: usize) -> i32 {
        let b = self.row(r);
        i32::from_le_bytes([b[4 * i], b[4 * i + 1], b[4 * i + 2], b[4 * i + 3]])
    }

    fn set_i32(&mut self, r: usize, i: usize, v: i32) {
        let b = self.row_mut(r);
        b[4 * i..4 * i + 4].copy_from_slice(&v.to_le_bytes());
    }
}

/// One AMX unit: 8 tile registers + the tile ISA. All instructions tick
/// the supplied [`EventCounters`].
#[derive(Default)]
pub struct AmxUnit {
    tiles: [Tile; NUM_TILES],
}

/// Classification of a `tileloadd` source, for traffic accounting.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LoadClass {
    /// Activation tile (input rows).
    Input,
    /// Weight tile streamed directly from the (dense) weight stream.
    WeightStream,
    /// Weight tile read back from the hot decompression buffer — charged
    /// to `scratch_bytes`, not the DRAM stream.
    Scratch,
}

impl AmxUnit {
    pub fn new() -> Self {
        Self::default()
    }

    /// Configure a tile's shape (models `ldtilecfg`).
    pub fn config(&mut self, t: usize, rows: usize, colsb: usize) {
        assert!(rows <= MAX_ROWS && colsb <= MAX_COLSB, "tile shape too large");
        self.tiles[t].rows = rows;
        self.tiles[t].colsb = colsb;
    }

    /// Borrow a tile (tests / kernel result extraction).
    pub fn tile(&self, t: usize) -> &Tile {
        &self.tiles[t]
    }

    /// `tilezero t`.
    pub fn tilezero(&mut self, t: usize, ctr: &mut EventCounters) {
        self.tiles[t].data = [0; MAX_ROWS * MAX_COLSB];
        ctr.tile_zero += 1;
    }

    /// `tileloadd t, [src + stride]`: load `rows × colsb` bytes. `class`
    /// decides which traffic counter the bytes land in.
    pub fn tileloadd(
        &mut self,
        t: usize,
        src: &[u8],
        stride: usize,
        class: LoadClass,
        ctr: &mut EventCounters,
    ) {
        let (rows, colsb) = (self.tiles[t].rows, self.tiles[t].colsb);
        for r in 0..rows {
            let line = &src[r * stride..r * stride + colsb];
            self.tiles[t].row_mut(r).copy_from_slice(line);
        }
        let bytes = (rows * colsb) as u64;
        match class {
            LoadClass::Input => {
                ctr.tile_load_input += 1;
                ctr.input_bytes += bytes;
            }
            LoadClass::WeightStream => {
                ctr.tile_load_weight += 1;
                ctr.weight_stream_bytes += bytes;
            }
            LoadClass::Scratch => {
                ctr.tile_load_weight += 1;
                ctr.scratch_bytes += bytes;
            }
        }
    }

    /// `tilestored [dst + stride], t`.
    pub fn tilestored(
        &mut self,
        t: usize,
        dst: &mut [u8],
        stride: usize,
        ctr: &mut EventCounters,
    ) {
        let (rows, colsb) = (self.tiles[t].rows, self.tiles[t].colsb);
        for r in 0..rows {
            dst[r * stride..r * stride + colsb].copy_from_slice(self.tiles[t].row(r));
        }
        ctr.tile_store += 1;
        ctr.output_bytes += (rows * colsb) as u64;
    }

    /// `tdpbf16ps dst, a, b` — BF16 tile matmul, FP32 accumulate.
    ///
    /// `a`: M rows × 2·Kp BF16 (VNNI pairs along the row).
    /// `b`: Kp rows × 32 BF16, row `k` holding `(n, pair)` interleaved.
    /// `dst`: M rows × 16 FP32, `dst[m][n] += Σ_k Σ_p a[m][2k+p]·b[k][2n+p]`.
    pub fn tdpbf16ps(&mut self, dst: usize, a: usize, b: usize, ctr: &mut EventCounters) {
        let m_rows = self.tiles[a].rows;
        let k_pairs = self.tiles[b].rows;
        debug_assert_eq!(self.tiles[a].colsb, k_pairs * 4, "A colsb must be 4·Kp");
        let n_cols = self.tiles[b].colsb / 4;
        // decode both operands to f32 once (perf: the naive version
        // re-extracted B's bf16 bytes m_rows times — EXPERIMENTS.md §Perf)
        let mut a_f32 = [[0f32; 32]; MAX_ROWS];
        for (m, row) in a_f32.iter_mut().enumerate().take(m_rows) {
            for (k, slot) in row.iter_mut().enumerate().take(2 * k_pairs) {
                *slot = self.tiles[a].bf16(m, k).to_f32();
            }
        }
        let mut b_f32 = [[0f32; 32]; MAX_ROWS];
        for (k, row) in b_f32.iter_mut().enumerate().take(k_pairs) {
            for (n, slot) in row.iter_mut().enumerate().take(2 * n_cols) {
                *slot = self.tiles[b].bf16(k, n).to_f32();
            }
        }
        let mut acc = [0f32; MAX_ROWS * 16];
        for m in 0..m_rows {
            let arow = &a_f32[m];
            let out = &mut acc[m * n_cols..(m + 1) * n_cols];
            for k in 0..k_pairs {
                let (a0, a1) = (arow[2 * k], arow[2 * k + 1]);
                let brow = &b_f32[k];
                for (n, o) in out.iter_mut().enumerate() {
                    *o += a0 * brow[2 * n] + a1 * brow[2 * n + 1];
                }
            }
        }
        for m in 0..m_rows {
            for n in 0..n_cols {
                let cur = self.tiles[dst].f32(m, n);
                self.tiles[dst].set_f32(m, n, cur + acc[m * n_cols + n]);
            }
        }
        ctr.tdp_bf16 += 1;
    }

    /// `tdpbssd dst, a, b` — signed INT8 tile matmul, INT32 accumulate.
    ///
    /// `a`: M rows × 4·Kq INT8. `b`: Kq rows × 64 INT8 with quads of `k`
    /// interleaved per output column. `dst`: M × 16 INT32.
    pub fn tdpbssd(&mut self, dst: usize, a: usize, b: usize, ctr: &mut EventCounters) {
        let m_rows = self.tiles[a].rows;
        let k_quads = self.tiles[b].rows;
        debug_assert_eq!(self.tiles[a].colsb, k_quads * 4, "A colsb must be 4·Kq");
        let n_cols = self.tiles[b].colsb / 4;
        for m in 0..m_rows {
            for n in 0..n_cols {
                let mut acc = 0i32;
                for k in 0..k_quads {
                    for p in 0..4 {
                        let av = self.tiles[a].i8(m, 4 * k + p) as i32;
                        let bv = self.tiles[b].i8(k, 4 * n + p) as i32;
                        acc += av * bv;
                    }
                }
                let cur = self.tiles[dst].i32(m, n);
                self.tiles[dst].set_i32(m, n, cur + acc);
            }
        }
        ctr.tdp_int8 += 1;
    }
}

/// Pack an `M × K` f32 activation block into A-tile bytes (row-major BF16,
/// which is already the VNNI-compatible layout for the A operand).
pub fn pack_a_bf16(input: &[f32], m: usize, k: usize, lead: usize) -> Vec<u8> {
    let mut out = vec![0u8; m * k * 2];
    for r in 0..m {
        for c in 0..k {
            let v = Bf16::from_f32(input[r * lead + c]).to_bits();
            out[(r * k + c) * 2..(r * k + c) * 2 + 2].copy_from_slice(&v.to_le_bytes());
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Dense reference matmul for validating tdp semantics.
    fn ref_matmul(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
        let mut out = vec![0f32; m * n];
        for i in 0..m {
            for kk in 0..k {
                for j in 0..n {
                    out[i * n + j] += a[i * k + kk] * b[kk * n + j];
                }
            }
        }
        out
    }

    /// Build a VNNI B tile (Kp rows × 32 bf16) from row-major b[k][n].
    fn pack_b_vnni(b: &[f32], k: usize, n: usize) -> Vec<u8> {
        assert!(k % 2 == 0 && n <= 16);
        let mut out = vec![0u8; (k / 2) * 64];
        for kk in 0..k {
            for j in 0..n {
                let row = kk / 2;
                let col = 2 * j + kk % 2;
                let bits = Bf16::from_f32(b[kk * n + j]).to_bits();
                let off = row * 64 + col * 2;
                out[off..off + 2].copy_from_slice(&bits.to_le_bytes());
            }
        }
        out
    }

    #[test]
    fn tdpbf16ps_matches_reference() {
        let (m, k, n) = (16, 32, 16);
        let mut g = crate::util::XorShift::new(5);
        let a: Vec<f32> = (0..m * k).map(|_| g.next_normal()).collect();
        let b: Vec<f32> = (0..k * n).map(|_| g.next_normal()).collect();
        // round through bf16 as the hardware sees it
        let ar: Vec<f32> = a.iter().map(|&x| crate::util::bf16::round_f32(x)).collect();
        let br: Vec<f32> = b.iter().map(|&x| crate::util::bf16::round_f32(x)).collect();
        let expect = ref_matmul(&ar, &br, m, k, n);

        let mut amx = AmxUnit::new();
        let mut ctr = EventCounters::default();
        amx.config(0, m, n * 4); // fp32 accumulator
        amx.config(4, m, k * 2); // A: 32 bf16 per row
        amx.config(6, k / 2, 64); // B: 16 rows x 32 bf16
        amx.tilezero(0, &mut ctr);
        let a_bytes = pack_a_bf16(&a, m, k, k);
        amx.tileloadd(4, &a_bytes, k * 2, LoadClass::Input, &mut ctr);
        let b_bytes = pack_b_vnni(&b, k, n);
        amx.tileloadd(6, &b_bytes, 64, LoadClass::WeightStream, &mut ctr);
        amx.tdpbf16ps(0, 4, 6, &mut ctr);

        for i in 0..m {
            for j in 0..n {
                let got = amx.tile(0).f32(i, j);
                let want = expect[i * n + j];
                assert!(
                    (got - want).abs() <= 1e-2 + want.abs() * 1e-2,
                    "({i},{j}): got {got}, want {want}"
                );
            }
        }
        assert_eq!(ctr.tdp_bf16, 1);
        assert_eq!(ctr.tile_load_input, 1);
        assert_eq!(ctr.tile_load_weight, 1);
        assert_eq!(ctr.input_bytes, (m * k * 2) as u64);
        assert_eq!(ctr.weight_stream_bytes, (k / 2 * 64) as u64);
    }

    #[test]
    fn tdpbf16ps_accumulates_across_calls() {
        let mut amx = AmxUnit::new();
        let mut ctr = EventCounters::default();
        let (m, k, n) = (2, 2, 2);
        amx.config(0, m, 16 * 4);
        amx.config(4, m, k * 2);
        amx.config(6, k / 2, 64);
        amx.tilezero(0, &mut ctr);
        let a = pack_a_bf16(&[1.0, 2.0, 3.0, 4.0], m, k, k);
        let b = pack_b_vnni(&[1.0, 0.0, 0.0, 1.0], k, n);
        amx.tileloadd(4, &a, k * 2, LoadClass::Input, &mut ctr);
        amx.tileloadd(6, &b, 64, LoadClass::WeightStream, &mut ctr);
        amx.tdpbf16ps(0, 4, 6, &mut ctr);
        amx.tdpbf16ps(0, 4, 6, &mut ctr);
        // identity matmul applied twice accumulates 2×A
        assert_eq!(amx.tile(0).f32(0, 0), 2.0);
        assert_eq!(amx.tile(0).f32(1, 1), 8.0);
    }

    #[test]
    fn tdpbssd_matches_reference_int8() {
        let (m, k, n) = (4, 64, 16);
        let mut g = crate::util::XorShift::new(6);
        let a: Vec<i8> = (0..m * k).map(|_| (g.below(255) as i32 - 127) as i8).collect();
        let b: Vec<i8> = (0..k * n).map(|_| (g.below(255) as i32 - 127) as i8).collect();
        let mut expect = vec![0i32; m * n];
        for i in 0..m {
            for kk in 0..k {
                for j in 0..n {
                    expect[i * n + j] += a[i * k + kk] as i32 * b[kk * n + j] as i32;
                }
            }
        }
        let mut amx = AmxUnit::new();
        let mut ctr = EventCounters::default();
        amx.config(0, m, n * 4);
        amx.config(4, m, k);
        amx.config(6, k / 4, 64);
        amx.tilezero(0, &mut ctr);
        let a_bytes: Vec<u8> = a.iter().map(|&x| x as u8).collect();
        amx.tileloadd(4, &a_bytes, k, LoadClass::Input, &mut ctr);
        // B quad-interleaved: row = k/4, col = 4n + k%4
        let mut b_bytes = vec![0u8; (k / 4) * 64];
        for kk in 0..k {
            for j in 0..n {
                b_bytes[(kk / 4) * 64 + 4 * j + kk % 4] = b[kk * n + j] as u8;
            }
        }
        amx.tileloadd(6, &b_bytes, 64, LoadClass::WeightStream, &mut ctr);
        amx.tdpbssd(0, 4, 6, &mut ctr);
        for i in 0..m {
            for j in 0..n {
                assert_eq!(amx.tile(0).i32(i, j), expect[i * n + j], "({i},{j})");
            }
        }
        assert_eq!(ctr.tdp_int8, 1);
    }

    #[test]
    fn tilestored_writes_and_counts() {
        let mut amx = AmxUnit::new();
        let mut ctr = EventCounters::default();
        amx.config(1, 2, 8);
        let src = [7u8; 16];
        amx.tileloadd(1, &src, 8, LoadClass::Input, &mut ctr);
        let mut dst = [0u8; 16];
        amx.tilestored(1, &mut dst, 8, &mut ctr);
        assert_eq!(dst, src);
        assert_eq!(ctr.output_bytes, 16);
    }

    #[test]
    #[should_panic(expected = "tile shape too large")]
    fn oversized_tile_config_rejected() {
        AmxUnit::new().config(0, 17, 64);
    }
}
