//! Functional, instruction-level simulator of the Intel AMX tile
//! architecture and the AVX-512 operations SparAMX uses, plus the four
//! paper kernels built on top of them.
//!
//! The container this repo runs in has no AMX (and may not even have
//! AVX-512), so the kernels execute against a software model that:
//!
//! 1. computes **bit-exact the same numerics** the hardware would
//!    (BF16 multiply → FP32 accumulate; INT8 → INT32), and
//! 2. counts **every architectural event** the real kernel would issue
//!    (tile loads/stores, `tdpbf16ps`/`tdpbssd`, `vpexpandw`,
//!    `vpopcntd`, prefix-sum steps, bytes streamed from DRAM vs. bytes
//!    bounced through the cached `weight_buffer`).
//!
//! The event counts drive the [`crate::perf`] cost model that regenerates
//! the paper's tables and figures (DESIGN.md §2, §5).

pub mod events;
pub mod tiles;
pub mod avx;
pub mod kernels;

pub use events::EventCounters;
pub use tiles::{AmxUnit, Tile, MAX_ROWS, MAX_COLSB};
