//! The four SparAMX kernels (paper §4), executed on the simulated ISA.
//!
//! * [`dense_amx_gemm_bf16`] — §4.1 dense kernel: the 8-tile schedule
//!   (4 accumulators + 2 input tiles + 2 weight tiles → 1:1
//!   compute-to-load ratio).
//! * [`sparse_amx_gemm_bf16`] — §4.3 sparse kernel: weight tiles are
//!   decompressed from bitmap+values with `vpexpandw`, `vpopcntd` and the
//!   Algorithm-1 prefix sum into a cache-hot `weight_buffer`, then
//!   `tileloadd`-ed into the AMX unit.
//! * [`avx_sparse_gemm_bf16`] — §4.4 AVX kernel: vector FMA with
//!   `num_column_groups` accumulator registers sharing one input
//!   broadcast (Appendix B).
//! * [`dense_amx_gemm_int8`] / [`sparse_amx_gemm_int8`] — §4.5 INT8
//!   variants (64-element tile rows, `vpexpandb`, `tdpbssd`).
//!
//! All kernels return numerics identical (up to BF16/INT8 rounding) to a
//! dense reference GEMM — asserted by the test suite — while ticking the
//! event counters the perf model consumes.

use super::avx;
use super::events::EventCounters;
use super::tiles::{pack_a_bf16, AmxUnit, LoadClass};
use crate::sparse::format::{Element, SparseTensor, TileOrder};
use crate::util::bf16::Bf16;

/// Alias used throughout the crate's public API.
pub type GemmCounters = EventCounters;

/// Dense weights pre-packed into the AMX B-tile stream (VNNI interleave),
/// same tile order as [`SparseTensor`]: column-block major, k fastest.
#[derive(Clone, Debug)]
pub struct DenseWeights<T: Element = Bf16> {
    pub rows: usize,
    pub cols: usize,
    pub rows_padded: usize,
    pub cols_padded: usize,
    pub order: TileOrder,
    /// `num_tiles × 16 × 64` bytes.
    pub tiles: Vec<u8>,
    _marker: std::marker::PhantomData<T>,
}

impl<T: Element> DenseWeights<T> {
    pub fn k_chunks(&self) -> usize {
        self.rows_padded / self.order.k_per_tile
    }
    pub fn col_blocks(&self) -> usize {
        self.cols_padded / self.order.cols_per_tile
    }
    pub fn tile_index(&self, col_block: usize, k_chunk: usize) -> usize {
        col_block * self.k_chunks() + k_chunk
    }
    /// Bytes of one tile (always 1 KiB on AMX).
    pub const TILE_BYTES: usize = 1024;

    pub fn tile_bytes(&self, tile: usize) -> &[u8] {
        &self.tiles[tile * Self::TILE_BYTES..(tile + 1) * Self::TILE_BYTES]
    }

    /// Total bytes the dense kernel streams for weights.
    pub fn stream_bytes(&self) -> usize {
        self.tiles.len()
    }

    /// Pack a row-major `rows × cols` matrix.
    pub fn pack(w: &[T], rows: usize, cols: usize) -> DenseWeights<T> {
        assert_eq!(w.len(), rows * cols);
        let order = TileOrder::for_elem::<T>();
        let rows_padded = rows.div_ceil(order.k_per_tile) * order.k_per_tile;
        let cols_padded = cols.div_ceil(order.cols_per_tile) * order.cols_per_tile;
        let k_chunks = rows_padded / order.k_per_tile;
        let col_blocks = cols_padded / order.cols_per_tile;
        let mut tiles = vec![0u8; k_chunks * col_blocks * Self::TILE_BYTES];
        let v = T::VNNI;
        for cb in 0..col_blocks {
            for kc in 0..k_chunks {
                let t = cb * k_chunks + kc;
                let base = t * Self::TILE_BYTES;
                for r in 0..order.tile_rows {
                    for c in 0..order.row_elems {
                        let k = kc * order.k_per_tile + r * v + c % v;
                        let n = cb * order.cols_per_tile + c / v;
                        if k < rows && n < cols {
                            let x = w[k * cols + n];
                            write_elem::<T>(&mut tiles[base + r * 64..], c, x);
                        }
                    }
                }
            }
        }
        DenseWeights {
            rows,
            cols,
            rows_padded,
            cols_padded,
            order,
            tiles,
            _marker: std::marker::PhantomData,
        }
    }
}

impl<T: Element> DenseWeights<T> {
    /// Slice out a contiguous range of 16-neuron column blocks as a
    /// standalone operand. The tile stream is column-block-major with k
    /// fastest, so the slice is one contiguous byte cut of `tiles`; no
    /// element moves relative to its k-order, which keeps sharded
    /// execution bit-exact (see `shard::plan`). Lives here because
    /// `_marker` is private to this module.
    pub fn slice_col_blocks(&self, blocks: std::ops::Range<usize>) -> DenseWeights<T> {
        assert!(
            blocks.end <= self.col_blocks(),
            "slice {blocks:?} out of range ({} col blocks)",
            self.col_blocks()
        );
        let kc = self.k_chunks();
        let (t0, t1) = (blocks.start * kc, blocks.end * kc);
        let cpt = self.order.cols_per_tile;
        let col0 = blocks.start * cpt;
        DenseWeights {
            rows: self.rows,
            cols: self.cols.min(blocks.end * cpt).saturating_sub(col0),
            rows_padded: self.rows_padded,
            cols_padded: blocks.len() * cpt,
            order: self.order,
            tiles: self.tiles[t0 * Self::TILE_BYTES..t1 * Self::TILE_BYTES].to_vec(),
            _marker: std::marker::PhantomData,
        }
    }

    /// Reconstruct the logical row-major matrix from the tile stream
    /// (reverse of [`DenseWeights::pack`]; used by backends that need the
    /// unpacked operand, e.g. the reference oracle).
    pub fn to_dense(&self) -> Vec<T> {
        let mut out = vec![T::default(); self.rows * self.cols];
        let v = T::VNNI;
        for cb in 0..self.col_blocks() {
            for kc in 0..self.k_chunks() {
                let tile = self.tile_index(cb, kc);
                let bytes = self.tile_bytes(tile);
                for r in 0..self.order.tile_rows {
                    for c in 0..self.order.row_elems {
                        let k = kc * self.order.k_per_tile + r * v + c % v;
                        let n = cb * self.order.cols_per_tile + c / v;
                        if k < self.rows && n < self.cols {
                            out[k * self.cols + n] = read_elem::<T>(&bytes[r * 64..], c);
                        }
                    }
                }
            }
        }
        out
    }

    /// The logical matrix as f32 (reference path).
    pub fn to_dense_f32(&self) -> Vec<f32> {
        self.to_dense().iter().map(|x| x.to_f32()).collect()
    }
}

impl DenseWeights<Bf16> {
    pub fn pack_f32(w: &[f32], rows: usize, cols: usize) -> DenseWeights<Bf16> {
        let wb: Vec<Bf16> = w.iter().map(|&x| Bf16::from_f32(x)).collect();
        DenseWeights::pack(&wb, rows, cols)
    }
}

fn write_elem<T: Element>(row: &mut [u8], c: usize, x: T) {
    match T::BYTES {
        2 => {
            let bits = (Bf16::from_f32(x.to_f32())).to_bits();
            row[2 * c..2 * c + 2].copy_from_slice(&bits.to_le_bytes());
        }
        1 => {
            row[c] = x.to_f32() as i8 as u8;
        }
        _ => unreachable!(),
    }
}

fn read_elem<T: Element>(row: &[u8], c: usize) -> T {
    match T::BYTES {
        2 => {
            let bits = u16::from_le_bytes([row[2 * c], row[2 * c + 1]]);
            T::from_f32(Bf16::from_bits(bits).to_f32())
        }
        1 => T::from_f32(row[c] as i8 as f32),
        _ => unreachable!(),
    }
}

/// Pack a `batch × rows_logical` f32 input into a zero-padded
/// `batch × rows_padded` BF16 buffer (row-major).
fn pack_input_bf16(input: &[f32], batch: usize, k: usize, k_padded: usize) -> Vec<u8> {
    let mut buf = vec![0f32; batch * k_padded];
    for b in 0..batch {
        buf[b * k_padded..b * k_padded + k].copy_from_slice(&input[b * k..(b + 1) * k]);
    }
    pack_a_bf16(&buf, batch, k_padded, k_padded)
}

/// Extract `batch × cols` logical outputs from a padded f32 accumulator.
fn extract_out(acc: &[f32], batch: usize, cols: usize, cols_padded: usize) -> Vec<f32> {
    let mut out = vec![0f32; batch * cols];
    for b in 0..batch {
        out[b * cols..(b + 1) * cols]
            .copy_from_slice(&acc[b * cols_padded..b * cols_padded + cols]);
    }
    out
}

/// Independent column-pair work items of the AMX schedule (the paper's
/// parallelization dimension).
fn col_tasks(cols_padded: usize) -> u64 {
    let cb = cols_padded / 16;
    (cb / 2 + cb % 2) as u64
}

/// Record a kernel's parallel granularity (min-merge semantics, see
/// [`EventCounters::parallel_tasks`]).
fn set_tasks(ctr: &mut EventCounters, tasks: u64) {
    ctr.parallel_tasks = match (ctr.parallel_tasks, tasks) {
        (0, x) => x,
        (a, b) => a.min(b),
    };
}

// ---------------------------------------------------------------------
// §4.1 dense AMX kernel
// ---------------------------------------------------------------------

/// Dense BF16 GEMM on the 8-tile schedule. `input` is `batch × w.rows`
/// row-major f32 (rounded through BF16 as the hardware would); returns
/// `batch × w.cols` f32.
pub fn dense_amx_gemm_bf16(
    input: &[f32],
    batch: usize,
    w: &DenseWeights<Bf16>,
    ctr: &mut EventCounters,
) -> Vec<f32> {
    assert_eq!(input.len(), batch * w.rows, "input shape");
    ctr.weight_unique_bytes += w.stream_bytes() as u64;
    ctr.input_unique_bytes += (batch * w.rows_padded * 2) as u64;
    set_tasks(ctr, col_tasks(w.cols_padded));
    let kp = w.order.k_per_tile; // 32
    let a_bytes = pack_input_bf16(input, batch, w.rows, w.rows_padded);
    let a_stride = w.rows_padded * 2;

    let mut acc = vec![0f32; batch * w.cols_padded];
    let mut amx = AmxUnit::new();
    let mut out_tile = vec![0u8; 16 * 64];

    // m in blocks of 32 rows (two input tiles), n in blocks of 32 cols
    // (two weight tiles) — the Figure 5 schedule.
    let mut m0 = 0;
    while m0 < batch {
        let m_rows = (batch - m0).min(32);
        let m_hi = m_rows.min(16); // rows in tile 4
        let m_lo = m_rows - m_hi; // rows in tile 5
        let mut n0 = 0;
        while n0 < w.cols_padded {
            let two_blocks = n0 + 16 < w.cols_padded;
            // accumulators: 0 ← 4×6, 1 ← 4×7, 2 ← 5×6, 3 ← 5×7
            amx.config(0, m_hi, 64);
            amx.tilezero(0, ctr);
            if two_blocks {
                amx.config(1, m_hi, 64);
                amx.tilezero(1, ctr);
            }
            if m_lo > 0 {
                amx.config(2, m_lo, 64);
                amx.tilezero(2, ctr);
                if two_blocks {
                    amx.config(3, m_lo, 64);
                    amx.tilezero(3, ctr);
                }
            }
            for kc in 0..w.k_chunks() {
                // input tiles
                amx.config(4, m_hi, kp * 2);
                let a_off = m0 * a_stride + kc * kp * 2;
                amx.tileloadd(4, &a_bytes[a_off..], a_stride, LoadClass::Input, ctr);
                if m_lo > 0 {
                    amx.config(5, m_lo, kp * 2);
                    let a_off2 = (m0 + 16) * a_stride + kc * kp * 2;
                    amx.tileloadd(5, &a_bytes[a_off2..], a_stride, LoadClass::Input, ctr);
                }
                // weight tiles straight from the dense stream
                amx.config(6, 16, 64);
                let t6 = w.tile_index(n0 / 16, kc);
                amx.tileloadd(6, w.tile_bytes(t6), 64, LoadClass::WeightStream, ctr);
                if two_blocks {
                    amx.config(7, 16, 64);
                    let t7 = w.tile_index(n0 / 16 + 1, kc);
                    amx.tileloadd(7, w.tile_bytes(t7), 64, LoadClass::WeightStream, ctr);
                }
                amx.tdpbf16ps(0, 4, 6, ctr);
                if two_blocks {
                    amx.tdpbf16ps(1, 4, 7, ctr);
                }
                if m_lo > 0 {
                    amx.tdpbf16ps(2, 5, 6, ctr);
                    if two_blocks {
                        amx.tdpbf16ps(3, 5, 7, ctr);
                    }
                }
            }
            // store the (up to) four result tiles
            let mut store = |amx: &mut AmxUnit,
                             t: usize,
                             rows: usize,
                             row0: usize,
                             col0: usize,
                             ctr: &mut EventCounters| {
                amx.tilestored(t, &mut out_tile, 64, ctr);
                for r in 0..rows {
                    for n in 0..16 {
                        let v = f32::from_le_bytes(
                            out_tile[r * 64 + 4 * n..r * 64 + 4 * n + 4]
                                .try_into()
                                .expect("4 bytes"),
                        );
                        acc[(row0 + r) * w.cols_padded + col0 + n] = v;
                    }
                }
            };
            store(&mut amx, 0, m_hi, m0, n0, ctr);
            if two_blocks {
                store(&mut amx, 1, m_hi, m0, n0 + 16, ctr);
            }
            if m_lo > 0 {
                store(&mut amx, 2, m_lo, m0 + 16, n0, ctr);
                if two_blocks {
                    store(&mut amx, 3, m_lo, m0 + 16, n0 + 16, ctr);
                }
            }
            n0 += if two_blocks { 32 } else { 16 };
        }
        m0 += 32;
    }
    extract_out(&acc, batch, w.cols, w.cols_padded)
}

// ---------------------------------------------------------------------
// §4.3 sparse AMX kernel
// ---------------------------------------------------------------------

/// Decompress one sparse BF16 tile into `weight_buffer` (Algorithm 2) and
/// return the buffer as tile bytes. Ticks: 1 bitmap load, 1 popcount,
/// 4 prefix steps, 16 `vpexpandw`, 16 scratch stores.
fn decompress_tile_bf16(
    sp: &SparseTensor<Bf16>,
    tile: usize,
    weight_buffer: &mut [Bf16],
    ctr: &mut EventCounters,
) {
    let meta = sp.tile_metadata(tile);
    let lanes = avx::vmovdqu32(meta, ctr);
    let pops = avx::vpopcntd(&lanes, ctr);
    let offsets = avx::prefix_sum_u32x16(&pops, ctr);
    let (vals, _) = sp.tile_values(tile);
    for r in 0..16 {
        let start = if r == 0 { 0 } else { offsets[r - 1] as usize };
        let (expanded, consumed) = avx::vpexpandw(lanes[r], &vals[start..], ctr);
        debug_assert_eq!(consumed, pops[r] as usize);
        avx::store_scratch_bf16(&expanded, &mut weight_buffer[r * 32..], ctr);
    }
}

/// Convert the expanded weight buffer to tile bytes into a reusable
/// scratch (perf: avoids a per-tile allocation — EXPERIMENTS.md §Perf).
fn buffer_to_bytes_bf16_into(weight_buffer: &[Bf16], out: &mut [u8]) {
    debug_assert_eq!(out.len(), weight_buffer.len() * 2);
    for (chunk, w) in out.chunks_exact_mut(2).zip(weight_buffer.iter()) {
        chunk.copy_from_slice(&w.to_bits().to_le_bytes());
    }
}

/// Sparse BF16 GEMM: identical schedule to the dense kernel, but weight
/// tiles are rebuilt from the compressed stream before each `tileloadd`.
pub fn sparse_amx_gemm_bf16(
    input: &[f32],
    batch: usize,
    sp: &SparseTensor<Bf16>,
    ctr: &mut EventCounters,
) -> Vec<f32> {
    assert_eq!(input.len(), batch * sp.rows, "input shape");
    ctr.weight_unique_bytes += sp.bytes_sparse() as u64;
    ctr.input_unique_bytes += (batch * sp.rows_padded * 2) as u64;
    set_tasks(ctr, col_tasks(sp.cols_padded));
    let kp = sp.order.k_per_tile;
    let a_bytes = pack_input_bf16(input, batch, sp.rows, sp.rows_padded);
    let a_stride = sp.rows_padded * 2;

    let mut acc = vec![0f32; batch * sp.cols_padded];
    let mut amx = AmxUnit::new();
    let mut out_tile = vec![0u8; 16 * 64];
    let mut weight_buffer = vec![Bf16::ZERO; 16 * 32];
    let mut tile_bytes = vec![0u8; 16 * 64];

    let mut m0 = 0;
    while m0 < batch {
        let m_rows = (batch - m0).min(32);
        let m_hi = m_rows.min(16);
        let m_lo = m_rows - m_hi;
        let mut n0 = 0;
        while n0 < sp.cols_padded {
            let two_blocks = n0 + 16 < sp.cols_padded;
            amx.config(0, m_hi, 64);
            amx.tilezero(0, ctr);
            if two_blocks {
                amx.config(1, m_hi, 64);
                amx.tilezero(1, ctr);
            }
            if m_lo > 0 {
                amx.config(2, m_lo, 64);
                amx.tilezero(2, ctr);
                if two_blocks {
                    amx.config(3, m_lo, 64);
                    amx.tilezero(3, ctr);
                }
            }
            for kc in 0..sp.k_chunks() {
                amx.config(4, m_hi, kp * 2);
                let a_off = m0 * a_stride + kc * kp * 2;
                amx.tileloadd(4, &a_bytes[a_off..], a_stride, LoadClass::Input, ctr);
                if m_lo > 0 {
                    amx.config(5, m_lo, kp * 2);
                    let a_off2 = (m0 + 16) * a_stride + kc * kp * 2;
                    amx.tileloadd(5, &a_bytes[a_off2..], a_stride, LoadClass::Input, ctr);
                }
                // decompress weight tile(s) into the hot buffer, then load
                amx.config(6, 16, 64);
                let t6 = sp.tile_index(n0 / 16, kc);
                decompress_tile_bf16(sp, t6, &mut weight_buffer, ctr);
                buffer_to_bytes_bf16_into(&weight_buffer, &mut tile_bytes);
                amx.tileloadd(6, &tile_bytes, 64, LoadClass::Scratch, ctr);
                if two_blocks {
                    amx.config(7, 16, 64);
                    let t7 = sp.tile_index(n0 / 16 + 1, kc);
                    decompress_tile_bf16(sp, t7, &mut weight_buffer, ctr);
                    buffer_to_bytes_bf16_into(&weight_buffer, &mut tile_bytes);
                    amx.tileloadd(7, &tile_bytes, 64, LoadClass::Scratch, ctr);
                }
                amx.tdpbf16ps(0, 4, 6, ctr);
                if two_blocks {
                    amx.tdpbf16ps(1, 4, 7, ctr);
                }
                if m_lo > 0 {
                    amx.tdpbf16ps(2, 5, 6, ctr);
                    if two_blocks {
                        amx.tdpbf16ps(3, 5, 7, ctr);
                    }
                }
            }
            let mut store = |amx: &mut AmxUnit,
                             t: usize,
                             rows: usize,
                             row0: usize,
                             col0: usize,
                             ctr: &mut EventCounters| {
                amx.tilestored(t, &mut out_tile, 64, ctr);
                for r in 0..rows {
                    for n in 0..16 {
                        let v = f32::from_le_bytes(
                            out_tile[r * 64 + 4 * n..r * 64 + 4 * n + 4]
                                .try_into()
                                .expect("4 bytes"),
                        );
                        acc[(row0 + r) * sp.cols_padded + col0 + n] = v;
                    }
                }
            };
            store(&mut amx, 0, m_hi, m0, n0, ctr);
            if two_blocks {
                store(&mut amx, 1, m_hi, m0, n0 + 16, ctr);
            }
            if m_lo > 0 {
                store(&mut amx, 2, m_lo, m0 + 16, n0, ctr);
                if two_blocks {
                    store(&mut amx, 3, m_lo, m0 + 16, n0 + 16, ctr);
                }
            }
            n0 += if two_blocks { 32 } else { 16 };
        }
        m0 += 32;
    }
    extract_out(&acc, batch, sp.cols, sp.cols_padded)
}

// ---------------------------------------------------------------------
// §4.4 AVX sparse kernel (Appendix B column groups)
// ---------------------------------------------------------------------

/// Sparse BF16 GEMM using only AVX-512: per 16-neuron column block, the
/// accumulator lives in a vector register; weight rows are expanded with
/// `vpexpandw` and consumed directly by `vdpbf16ps` — no scratch bounce
/// (this is why AVX can beat AMX at batch 1, paper §7).
///
/// `column_groups` (Appendix B `num_neuron_groups`): how many column
/// blocks share one input broadcast. Larger groups amortize the
/// broadcast and improve ILP; the value is baked into the packed layout
/// at load time in the real system.
pub fn avx_sparse_gemm_bf16(
    input: &[f32],
    batch: usize,
    sp: &SparseTensor<Bf16>,
    column_groups: usize,
    ctr: &mut EventCounters,
) -> Vec<f32> {
    assert_eq!(input.len(), batch * sp.rows, "input shape");
    let g = column_groups.max(1);
    ctr.weight_unique_bytes += sp.bytes_sparse() as u64;
    ctr.input_unique_bytes += (batch * sp.rows * 4) as u64;
    set_tasks(ctr, (sp.col_blocks().div_ceil(g)) as u64);
    let mut out = vec![0f32; batch * sp.cols];
    let cbs = sp.col_blocks();
    for b in 0..batch {
        let row = &input[b * sp.rows..(b + 1) * sp.rows];
        // input row is read once per column-group sweep
        let mut cb0 = 0;
        while cb0 < cbs {
            let group = (cbs - cb0).min(g);
            let mut accs = vec![[0f32; 16]; group];
            for kc in 0..sp.k_chunks() {
                // bitmap lanes + popcounts for each block in the group
                let mut lanes_g = Vec::with_capacity(group);
                let mut offs_g = Vec::with_capacity(group);
                for gi in 0..group {
                    let tile = sp.tile_index(cb0 + gi, kc);
                    let lanes = avx::vmovdqu32(sp.tile_metadata(tile), ctr);
                    let pops = avx::vpopcntd(&lanes, ctr);
                    offs_g.push(avx::prefix_sum_u32x16(&pops, ctr));
                    lanes_g.push(lanes);
                }
                for r in 0..16 {
                    // one broadcast of the input k-pair shared by the group
                    let k0 = kc * sp.order.k_per_tile + r * 2;
                    let x0 = if k0 < sp.rows { row[k0] } else { 0.0 };
                    let x1 = if k0 + 1 < sp.rows { row[k0 + 1] } else { 0.0 };
                    let mut pair = [Bf16::ZERO; 32];
                    for n in 0..16 {
                        pair[2 * n] = Bf16::from_f32(x0);
                        pair[2 * n + 1] = Bf16::from_f32(x1);
                    }
                    ctr.broadcast += 1;
                    ctr.input_bytes += 4;
                    for gi in 0..group {
                        let tile = sp.tile_index(cb0 + gi, kc);
                        let (vals, _) = sp.tile_values(tile);
                        let start = if r == 0 { 0 } else { offs_g[gi][r - 1] as usize };
                        let (wreg, _) = avx::vpexpandw(lanes_g[gi][r], &vals[start..], ctr);
                        avx::vdpbf16ps(&mut accs[gi], &wreg, &pair, ctr);
                        // model the dependency-chain stall (see analytic.rs)
                        let lat = 4u64;
                        ctr.fma_dep_stall += lat / (group as u64).min(lat) - 1;
                    }
                }
            }
            for (gi, acc) in accs.iter().enumerate() {
                let n0 = (cb0 + gi) * 16;
                let take = (sp.cols - n0).min(16);
                let mut padded = [0f32; 16];
                padded.copy_from_slice(acc);
                let mut dst = vec![0f32; 16];
                avx::store_f32x16(&padded, &mut dst, ctr);
                out[b * sp.cols + n0..b * sp.cols + n0 + take]
                    .copy_from_slice(&dst[..take]);
            }
            cb0 += group;
        }
    }
    out
}

/// Fused multi-row variant of [`avx_sparse_gemm_bf16`]: one pass over
/// the compressed weight stream serves every batch row. Bitmap loads,
/// popcount/prefix offsets, and `vpexpandw` expansions happen once per
/// weight tile row instead of once per (batch row, tile row), so the
/// weight side of the event stream amortizes over the batch while the
/// input broadcasts still scale with it. Per output element the
/// k-accumulation order is identical to the batch-1 kernel (`kc`
/// ascending, `r` ascending), so the result is bit-exact vs. looping
/// [`avx_sparse_gemm_bf16`] one row at a time.
pub fn avx_sparse_gemm_bf16_batched(
    input: &[f32],
    batch: usize,
    sp: &SparseTensor<Bf16>,
    column_groups: usize,
    ctr: &mut EventCounters,
) -> Vec<f32> {
    assert_eq!(input.len(), batch * sp.rows, "input shape");
    let g = column_groups.max(1);
    ctr.weight_unique_bytes += sp.bytes_sparse() as u64;
    ctr.input_unique_bytes += (batch * sp.rows * 4) as u64;
    set_tasks(ctr, (sp.col_blocks().div_ceil(g)) as u64);
    let mut out = vec![0f32; batch * sp.cols];
    let cbs = sp.col_blocks();
    let mut cb0 = 0;
    while cb0 < cbs {
        let group = (cbs - cb0).min(g);
        // one accumulator register per (batch row, group block)
        let mut accs = vec![[0f32; 16]; batch * group];
        for kc in 0..sp.k_chunks() {
            let mut lanes_g = Vec::with_capacity(group);
            let mut offs_g = Vec::with_capacity(group);
            for gi in 0..group {
                let tile = sp.tile_index(cb0 + gi, kc);
                let lanes = avx::vmovdqu32(sp.tile_metadata(tile), ctr);
                let pops = avx::vpopcntd(&lanes, ctr);
                offs_g.push(avx::prefix_sum_u32x16(&pops, ctr));
                lanes_g.push(lanes);
            }
            for r in 0..16 {
                let k0 = kc * sp.order.k_per_tile + r * 2;
                // expand each block's weight row once; every batch row
                // consumes the same register
                let mut wregs = Vec::with_capacity(group);
                for gi in 0..group {
                    let tile = sp.tile_index(cb0 + gi, kc);
                    let (vals, _) = sp.tile_values(tile);
                    let start = if r == 0 { 0 } else { offs_g[gi][r - 1] as usize };
                    let (wreg, _) = avx::vpexpandw(lanes_g[gi][r], &vals[start..], ctr);
                    wregs.push(wreg);
                }
                for b in 0..batch {
                    let row = &input[b * sp.rows..(b + 1) * sp.rows];
                    let x0 = if k0 < sp.rows { row[k0] } else { 0.0 };
                    let x1 = if k0 + 1 < sp.rows { row[k0 + 1] } else { 0.0 };
                    let mut pair = [Bf16::ZERO; 32];
                    for n in 0..16 {
                        pair[2 * n] = Bf16::from_f32(x0);
                        pair[2 * n + 1] = Bf16::from_f32(x1);
                    }
                    ctr.broadcast += 1;
                    ctr.input_bytes += 4;
                    for gi in 0..group {
                        avx::vdpbf16ps(&mut accs[b * group + gi], &wregs[gi], &pair, ctr);
                        // batch × group independent accumulators sit
                        // between reuses of the same register, so the
                        // dependency-chain stall shrinks with the batch
                        // (see analytic.rs)
                        let lat = 4u64;
                        ctr.fma_dep_stall += lat / ((group * batch) as u64).min(lat) - 1;
                    }
                }
            }
        }
        for b in 0..batch {
            for (gi, acc) in accs[b * group..(b + 1) * group].iter().enumerate() {
                let n0 = (cb0 + gi) * 16;
                let take = (sp.cols - n0).min(16);
                let mut dst = vec![0f32; 16];
                avx::store_f32x16(acc, &mut dst, ctr);
                out[b * sp.cols + n0..b * sp.cols + n0 + take].copy_from_slice(&dst[..take]);
            }
        }
        cb0 += group;
    }
    out
}

// ---------------------------------------------------------------------
// §4.5 INT8 kernels
// ---------------------------------------------------------------------

/// Dense INT8 GEMM (`tdpbssd`), INT32 outputs. `input` is `batch × rows`
/// row-major i8.
pub fn dense_amx_gemm_int8(
    input: &[i8],
    batch: usize,
    w: &DenseWeights<i8>,
    ctr: &mut EventCounters,
) -> Vec<i32> {
    ctr.weight_unique_bytes += w.stream_bytes() as u64;
    ctr.input_unique_bytes += (batch * w.rows_padded) as u64;
    set_tasks(ctr, col_tasks(w.cols_padded));
    int8_gemm_impl(input, batch, w.rows, w.rows_padded, w.cols, w.cols_padded, ctr, |amx,
         t,
         cb,
         kc,
         ctr| {
        let tile = w.tile_index(cb, kc);
        amx.tileloadd(t, w.tile_bytes(tile), 64, LoadClass::WeightStream, ctr);
    })
}

/// Sparse INT8 GEMM: metadata is fetched as two 512-bit registers per
/// tile (8 rows each — paper §4.5), expanded with `vpexpandb`.
pub fn sparse_amx_gemm_int8(
    input: &[i8],
    batch: usize,
    sp: &SparseTensor<i8>,
    ctr: &mut EventCounters,
) -> Vec<i32> {
    ctr.weight_unique_bytes += sp.bytes_sparse() as u64;
    ctr.input_unique_bytes += (batch * sp.rows_padded) as u64;
    set_tasks(ctr, col_tasks(sp.cols_padded));
    let mut weight_buffer = vec![0i8; 16 * 64];
    int8_gemm_impl(
        input,
        batch,
        sp.rows,
        sp.rows_padded,
        sp.cols,
        sp.cols_padded,
        ctr,
        |amx, t, cb, kc, ctr| {
            let tile = sp.tile_index(cb, kc);
            let meta = sp.tile_metadata(tile);
            // two bitmap registers of 8×64 bits
            ctr.avx_load += 2;
            ctr.weight_stream_bytes += 128;
            let (vals, _) = sp.tile_values(tile);
            let mut consumed = 0usize;
            for r in 0..16 {
                // popcount-based offsets: one vpopcnt per register half
                if r % 8 == 0 {
                    ctr.vpopcnt += 1;
                    ctr.prefix_step += 3; // log2(8)
                }
                let (expanded, c) = avx::vpexpandb(meta[r], &vals[consumed..], ctr);
                consumed += c;
                avx::store_scratch_i8(&expanded, &mut weight_buffer[r * 64..], ctr);
            }
            // reinterpret i8 scratch as bytes without allocating
            let bytes = unsafe {
                std::slice::from_raw_parts(weight_buffer.as_ptr() as *const u8, weight_buffer.len())
            };
            amx.tileloadd(t, bytes, 64, LoadClass::Scratch, ctr);
        },
    )
}

/// Shared INT8 schedule; `load_weight_tile(amx, reg, col_block, k_chunk)`
/// abstracts dense-stream vs decompress-then-load.
#[allow(clippy::too_many_arguments)]
fn int8_gemm_impl<F>(
    input: &[i8],
    batch: usize,
    rows: usize,
    rows_padded: usize,
    cols: usize,
    cols_padded: usize,
    ctr: &mut EventCounters,
    mut load_weight_tile: F,
) -> Vec<i32>
where
    F: FnMut(&mut AmxUnit, usize, usize, usize, &mut EventCounters),
{
    assert_eq!(input.len(), batch * rows, "input shape");
    let kp = 64usize;
    // zero-padded input
    let mut a = vec![0u8; batch * rows_padded];
    for b in 0..batch {
        for k in 0..rows {
            a[b * rows_padded + k] = input[b * rows + k] as u8;
        }
    }
    let k_chunks = rows_padded / kp;
    let mut acc = vec![0i32; batch * cols_padded];
    let mut amx = AmxUnit::new();
    let mut out_tile = vec![0u8; 16 * 64];

    let mut m0 = 0;
    while m0 < batch {
        let m_rows = (batch - m0).min(32);
        let m_hi = m_rows.min(16);
        let m_lo = m_rows - m_hi;
        let mut n0 = 0;
        while n0 < cols_padded {
            let two_blocks = n0 + 16 < cols_padded;
            amx.config(0, m_hi, 64);
            amx.tilezero(0, ctr);
            if two_blocks {
                amx.config(1, m_hi, 64);
                amx.tilezero(1, ctr);
            }
            if m_lo > 0 {
                amx.config(2, m_lo, 64);
                amx.tilezero(2, ctr);
                if two_blocks {
                    amx.config(3, m_lo, 64);
                    amx.tilezero(3, ctr);
                }
            }
            for kc in 0..k_chunks {
                amx.config(4, m_hi, kp);
                amx.tileloadd(
                    4,
                    &a[m0 * rows_padded + kc * kp..],
                    rows_padded,
                    LoadClass::Input,
                    ctr,
                );
                if m_lo > 0 {
                    amx.config(5, m_lo, kp);
                    amx.tileloadd(
                        5,
                        &a[(m0 + 16) * rows_padded + kc * kp..],
                        rows_padded,
                        LoadClass::Input,
                        ctr,
                    );
                }
                amx.config(6, 16, 64);
                load_weight_tile(&mut amx, 6, n0 / 16, kc, ctr);
                if two_blocks {
                    amx.config(7, 16, 64);
                    load_weight_tile(&mut amx, 7, n0 / 16 + 1, kc, ctr);
                }
                amx.tdpbssd(0, 4, 6, ctr);
                if two_blocks {
                    amx.tdpbssd(1, 4, 7, ctr);
                }
                if m_lo > 0 {
                    amx.tdpbssd(2, 5, 6, ctr);
                    if two_blocks {
                        amx.tdpbssd(3, 5, 7, ctr);
                    }
                }
            }
            let mut store = |amx: &mut AmxUnit,
                             t: usize,
                             rws: usize,
                             row0: usize,
                             col0: usize,
                             ctr: &mut EventCounters| {
                amx.tilestored(t, &mut out_tile, 64, ctr);
                for r in 0..rws {
                    for n in 0..16 {
                        let v = i32::from_le_bytes(
                            out_tile[r * 64 + 4 * n..r * 64 + 4 * n + 4]
                                .try_into()
                                .expect("4 bytes"),
                        );
                        acc[(row0 + r) * cols_padded + col0 + n] = v;
                    }
                }
            };
            store(&mut amx, 0, m_hi, m0, n0, ctr);
            if two_blocks {
                store(&mut amx, 1, m_hi, m0, n0 + 16, ctr);
            }
            if m_lo > 0 {
                store(&mut amx, 2, m_lo, m0 + 16, n0, ctr);
                if two_blocks {
                    store(&mut amx, 3, m_lo, m0 + 16, n0 + 16, ctr);
                }
            }
            n0 += if two_blocks { 32 } else { 16 };
        }
        m0 += 32;
    }
    let mut out = vec![0i32; batch * cols];
    for b in 0..batch {
        out[b * cols..(b + 1) * cols]
            .copy_from_slice(&acc[b * cols_padded..b * cols_padded + cols]);
    }
    out
}

/// Reference f32 GEMM with operands rounded through BF16 — the oracle the
/// simulated kernels are validated against.
pub fn ref_gemm_bf16(input: &[f32], batch: usize, w: &[f32], rows: usize, cols: usize) -> Vec<f32> {
    let mut out = vec![0f32; batch * cols];
    for b in 0..batch {
        for k in 0..rows {
            let x = crate::util::bf16::round_f32(input[b * rows + k]);
            if x == 0.0 {
                continue;
            }
            for n in 0..cols {
                let wv = crate::util::bf16::round_f32(w[k * cols + n]);
                out[b * cols + n] += x * wv;
            }
        }
    }
    out
}

/// Reference INT8 GEMM (exact INT32).
pub fn ref_gemm_int8(input: &[i8], batch: usize, w: &[i8], rows: usize, cols: usize) -> Vec<i32> {
    let mut out = vec![0i32; batch * cols];
    for b in 0..batch {
        for k in 0..rows {
            let x = input[b * rows + k] as i32;
            for n in 0..cols {
                out[b * cols + n] += x * w[k * cols + n] as i32;
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::prune::magnitude_prune;
    use crate::util::XorShift;

    fn rand_mat(g: &mut XorShift, n: usize) -> Vec<f32> {
        (0..n).map(|_| g.next_normal()).collect()
    }

    fn assert_close(got: &[f32], want: &[f32], k: usize) {
        assert_eq!(got.len(), want.len());
        // bf16 accumulation error grows with sqrt(k)
        let tol = 0.02 * (k as f32).sqrt();
        for (i, (g, w)) in got.iter().zip(want.iter()).enumerate() {
            assert!(
                (g - w).abs() <= tol + w.abs() * 0.02,
                "idx {i}: got {g}, want {w} (tol {tol})"
            );
        }
    }

    #[test]
    fn dense_kernel_matches_reference() {
        let mut g = XorShift::new(10);
        for &(batch, rows, cols) in
            &[(1usize, 64usize, 32usize), (4, 96, 48), (17, 32, 16), (33, 64, 80)]
        {
            let w = rand_mat(&mut g, rows * cols);
            let x = rand_mat(&mut g, batch * rows);
            let dw = DenseWeights::pack_f32(&w, rows, cols);
            let mut ctr = EventCounters::default();
            let got = dense_amx_gemm_bf16(&x, batch, &dw, &mut ctr);
            let want = ref_gemm_bf16(&x, batch, &w, rows, cols);
            assert_close(&got, &want, rows);
            assert!(ctr.tdp_bf16 > 0);
            assert_eq!(ctr.vpexpand, 0, "dense kernel never expands");
        }
    }

    #[test]
    fn sparse_kernel_matches_reference_across_sparsity() {
        let mut g = XorShift::new(11);
        let (batch, rows, cols) = (2usize, 128usize, 64usize);
        for s in [0.0, 0.3, 0.5, 0.9, 1.0] {
            let w = magnitude_prune(&rand_mat(&mut g, rows * cols), s);
            let x = rand_mat(&mut g, batch * rows);
            let sp = SparseTensor::pack_f32(&w, rows, cols);
            let mut ctr = EventCounters::default();
            let got = sparse_amx_gemm_bf16(&x, batch, &sp, &mut ctr);
            let want = ref_gemm_bf16(&x, batch, &w, rows, cols);
            assert_close(&got, &want, rows);
        }
    }

    #[test]
    fn sparse_kernel_unaligned_shapes() {
        let mut g = XorShift::new(12);
        let (batch, rows, cols) = (3usize, 50usize, 37usize);
        let w = magnitude_prune(&rand_mat(&mut g, rows * cols), 0.4);
        let x = rand_mat(&mut g, batch * rows);
        let sp = SparseTensor::pack_f32(&w, rows, cols);
        let mut ctr = EventCounters::default();
        let got = sparse_amx_gemm_bf16(&x, batch, &sp, &mut ctr);
        let want = ref_gemm_bf16(&x, batch, &w, rows, cols);
        assert_close(&got, &want, rows);
    }

    #[test]
    fn sparse_moves_fewer_weight_bytes_than_dense() {
        let mut g = XorShift::new(13);
        let (rows, cols) = (256, 128);
        let w = magnitude_prune(&rand_mat(&mut g, rows * cols), 0.7);
        let x = rand_mat(&mut g, rows);
        let dw = DenseWeights::pack_f32(&w, rows, cols);
        let sp = SparseTensor::pack_f32(&w, rows, cols);
        let mut cd = EventCounters::default();
        let mut cs = EventCounters::default();
        dense_amx_gemm_bf16(&x, 1, &dw, &mut cd);
        sparse_amx_gemm_bf16(&x, 1, &sp, &mut cs);
        // at 70% sparsity: bitmap 1/16 + values ~0.3 → ~0.36 of dense
        let ratio = cs.weight_stream_bytes as f64 / cd.weight_stream_bytes as f64;
        assert!(ratio < 0.45, "ratio={ratio}");
        // but identical tile-compute count
        assert_eq!(cd.tdp_bf16, cs.tdp_bf16);
        // and sparse pays decompression instructions
        assert!(cs.vpexpand > 0 && cs.vpopcnt > 0 && cs.prefix_step > 0);
    }

    #[test]
    fn avx_kernel_matches_reference_and_groups_are_equivalent() {
        let mut g = XorShift::new(14);
        let (batch, rows, cols) = (2usize, 64usize, 96usize);
        let w = magnitude_prune(&rand_mat(&mut g, rows * cols), 0.5);
        let x = rand_mat(&mut g, batch * rows);
        let sp = SparseTensor::pack_f32(&w, rows, cols);
        let want = ref_gemm_bf16(&x, batch, &w, rows, cols);
        let mut base_out = None;
        for groups in [1usize, 2, 4, 8] {
            let mut ctr = EventCounters::default();
            let got = avx_sparse_gemm_bf16(&x, batch, &sp, groups, &mut ctr);
            assert_close(&got, &want, rows);
            if let Some(b) = &base_out {
                assert_eq!(&got, b, "groups must not change numerics");
            } else {
                base_out = Some(got);
            }
        }
    }

    #[test]
    fn avx_column_groups_amortize_broadcasts() {
        let mut g = XorShift::new(15);
        let (rows, cols) = (64, 128);
        let w = magnitude_prune(&rand_mat(&mut g, rows * cols), 0.5);
        let x = rand_mat(&mut g, rows);
        let sp = SparseTensor::pack_f32(&w, rows, cols);
        let mut c1 = EventCounters::default();
        let mut c8 = EventCounters::default();
        avx_sparse_gemm_bf16(&x, 1, &sp, 1, &mut c1);
        avx_sparse_gemm_bf16(&x, 1, &sp, 8, &mut c8);
        assert!(c8.broadcast < c1.broadcast, "{} !< {}", c8.broadcast, c1.broadcast);
        assert_eq!(c1.avx_fma, c8.avx_fma, "same FMA work");
    }

    #[test]
    fn int8_dense_and_sparse_match_reference_exactly() {
        let mut g = XorShift::new(16);
        let (batch, rows, cols) = (3usize, 128usize, 48usize);
        let wf: Vec<i8> = (0..rows * cols)
            .map(|_| {
                if g.next_f64() < 0.5 {
                    0
                } else {
                    (g.below(200) as i32 - 100) as i8
                }
            })
            .collect();
        let x: Vec<i8> = (0..batch * rows).map(|_| (g.below(200) as i32 - 100) as i8).collect();
        let want = ref_gemm_int8(&x, batch, &wf, rows, cols);

        let dw: DenseWeights<i8> = DenseWeights::pack(&wf, rows, cols);
        let mut cd = EventCounters::default();
        assert_eq!(dense_amx_gemm_int8(&x, batch, &dw, &mut cd), want);

        let sp: SparseTensor<i8> = SparseTensor::pack(&wf, rows, cols);
        let mut cs = EventCounters::default();
        assert_eq!(sparse_amx_gemm_int8(&x, batch, &sp, &mut cs), want);
        assert!(cs.weight_stream_bytes < cd.weight_stream_bytes);
    }

    #[test]
    fn dense_weights_pack_to_dense_roundtrip() {
        let mut g = XorShift::new(18);
        // unaligned shape so padding must be stripped on the way back
        let (rows, cols) = (50usize, 37usize);
        let w = rand_mat(&mut g, rows * cols);
        let dw = DenseWeights::pack_f32(&w, rows, cols);
        let back = dw.to_dense_f32();
        let expect: Vec<f32> = w.iter().map(|&x| crate::util::bf16::round_f32(x)).collect();
        assert_eq!(back, expect);

        let wi: Vec<i8> = (0..rows * cols).map(|i| (i % 251) as i8).collect();
        let dwi: DenseWeights<i8> = DenseWeights::pack(&wi, rows, cols);
        assert_eq!(dwi.to_dense(), wi);
    }

    #[test]
    fn dense_weights_slice_col_blocks_matches_column_slice() {
        let mut g = XorShift::new(19);
        let (rows, cols) = (48usize, 112usize); // 7 column blocks
        let w = rand_mat(&mut g, rows * cols);
        let dw = DenseWeights::pack_f32(&w, rows, cols);
        let whole = dw.to_dense_f32();
        for (b0, b1) in [(0usize, 7usize), (0, 3), (2, 6), (6, 7)] {
            let sl = dw.slice_col_blocks(b0..b1);
            let (c0, c1) = (b0 * 16, (b1 * 16).min(cols));
            assert_eq!(sl.cols, c1 - c0);
            assert_eq!(sl.rows, rows);
            let got = sl.to_dense_f32();
            let mut expect = Vec::new();
            for k in 0..rows {
                expect.extend_from_slice(&whole[k * cols + c0..k * cols + c1]);
            }
            assert_eq!(got, expect, "blocks {b0}..{b1}");
        }
    }

    #[test]
    fn compute_to_load_ratio_is_one_for_interior_blocks() {
        // 8-tile schedule: per k-step in an interior 32x32 block, 4 loads
        // (2 input + 2 weight) and 4 tdp ops → 1:1 (paper §4.1).
        let mut g = XorShift::new(17);
        let (batch, rows, cols) = (32usize, 64usize, 64usize);
        let w = rand_mat(&mut g, rows * cols);
        let x = rand_mat(&mut g, batch * rows);
        let dw = DenseWeights::pack_f32(&w, rows, cols);
        let mut ctr = EventCounters::default();
        dense_amx_gemm_bf16(&x, batch, &dw, &mut ctr);
        let loads = ctr.tile_load_input + ctr.tile_load_weight;
        assert_eq!(ctr.tdp_bf16, loads, "1:1 compute-to-load");
    }
}
