//! Architectural event counters emitted by the simulated kernels.
//!
//! One [`EventCounters`] instance accumulates everything a kernel
//! invocation did; the [`crate::perf`] model converts the counts into
//! cycles, DRAM traffic, and pipeline-slot attribution.

/// Counts of simulated instructions and memory traffic.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct EventCounters {
    // ---- AMX tile instructions ----
    /// `tilezero` issued.
    pub tile_zero: u64,
    /// `tileloadd` of input (activation) tiles.
    pub tile_load_input: u64,
    /// `tileloadd` of weight tiles (dense kernel: straight from the weight
    /// stream; sparse kernel: from the decompression `weight_buffer`).
    pub tile_load_weight: u64,
    /// `tilestored` of result tiles.
    pub tile_store: u64,
    /// `tdpbf16ps` tile matmuls.
    pub tdp_bf16: u64,
    /// `tdpbssd` tile matmuls (INT8).
    pub tdp_int8: u64,

    // ---- AVX-512 instructions (decompression + AVX kernel) ----
    /// 512-bit vector loads (`vmovdqu32` et al.).
    pub avx_load: u64,
    /// 512-bit vector stores.
    pub avx_store: u64,
    /// `vpexpandw` / `vpexpandb` bitmap expansions.
    pub vpexpand: u64,
    /// `vpopcntd` population counts.
    pub vpopcnt: u64,
    /// shift+add steps of the Algorithm-1 parallel prefix sum.
    pub prefix_step: u64,
    /// `vdpbf16ps` vector FMA (AVX kernel compute).
    pub avx_fma: u64,
    /// broadcast of a scalar into a vector register.
    pub broadcast: u64,
    /// Cycles the AVX kernel stalls on the `vdpbf16ps` dependency chain:
    /// with fewer independent accumulators than the FMA latency (~4
    /// cycles), back-to-back FMAs into one register cannot be pipelined.
    /// Column groups exist to hide exactly this (Appendix B).
    pub fma_dep_stall: u64,

    // ---- memory traffic (bytes) ----
    /// Bytes of the weight stream read from DRAM (dense: the full dense
    /// matrix; sparse: bitmap + packed values — the paper's bandwidth
    /// saving shows up here).
    pub weight_stream_bytes: u64,
    /// Activation/input bytes read.
    pub input_bytes: u64,
    /// Output bytes written.
    pub output_bytes: u64,
    /// Traffic through the decompression `weight_buffer` (write by AVX,
    /// read by `tileloadd`). This region is small and hot, so the cost
    /// model charges it at cache, not DRAM, cost — exactly the paper's
    /// "frequent reuse of this memory region likely ensures it remains in
    /// the cache" argument (§4.3).
    pub scratch_bytes: u64,
    /// Unique bytes of the weight stream (one full pass). When the kernel
    /// sweeps the stream multiple times (batch > 32 → several m-blocks,
    /// or batch rows in the AVX kernel) and the stream fits in LLC, the
    /// repeats hit cache instead of DRAM — the cost model uses this to
    /// model the compute-bound crossover at high batch (§7).
    pub weight_unique_bytes: u64,
    /// Unique activation bytes (one copy of the input). The kernel
    /// re-reads the input block for every column iteration, but the
    /// copy is tiny and cache-resident; the cost model charges DRAM for
    /// one pass and LLC for the repeats.
    pub input_unique_bytes: u64,
    /// Number of independent column-dimension work items the kernel
    /// exposes (the paper parallelizes over `out_cols`). Caps the cores
    /// that can contribute; small models underutilize wide machines
    /// (§4.1). On merge, the minimum of the nonzero values is kept
    /// (conservative: sequential layers each have their own value).
    pub parallel_tasks: u64,
}

impl EventCounters {
    /// Merge another counter set into this one.
    pub fn merge(&mut self, other: &EventCounters) {
        self.tile_zero += other.tile_zero;
        self.tile_load_input += other.tile_load_input;
        self.tile_load_weight += other.tile_load_weight;
        self.tile_store += other.tile_store;
        self.tdp_bf16 += other.tdp_bf16;
        self.tdp_int8 += other.tdp_int8;
        self.avx_load += other.avx_load;
        self.avx_store += other.avx_store;
        self.vpexpand += other.vpexpand;
        self.vpopcnt += other.vpopcnt;
        self.prefix_step += other.prefix_step;
        self.avx_fma += other.avx_fma;
        self.broadcast += other.broadcast;
        self.fma_dep_stall += other.fma_dep_stall;
        self.weight_stream_bytes += other.weight_stream_bytes;
        self.input_bytes += other.input_bytes;
        self.output_bytes += other.output_bytes;
        self.scratch_bytes += other.scratch_bytes;
        self.weight_unique_bytes += other.weight_unique_bytes;
        self.input_unique_bytes += other.input_unique_bytes;
        self.parallel_tasks = match (self.parallel_tasks, other.parallel_tasks) {
            (0, x) | (x, 0) => x,
            (a, b) => a.min(b),
        };
    }

    /// Total bytes that must come from DRAM in steady state (weight stream
    /// is streaming and never reused within a decode step; inputs/outputs
    /// are charged to DRAM once as well).
    pub fn dram_bytes(&self) -> u64 {
        self.weight_stream_bytes + self.input_bytes + self.output_bytes
    }

    /// DRAM bytes after LLC-residency correction: if the unique weight
    /// stream fits in `llc_bytes`, repeated sweeps are served from LLC
    /// and only the first pass hits DRAM; the (small) activation block is
    /// likewise charged to DRAM once and to LLC for repeats. Returns
    /// `(dram, llc)` bytes.
    pub fn dram_llc_split(&self, llc_bytes: u64) -> (u64, u64) {
        let w_unique = self.weight_unique_bytes.min(self.weight_stream_bytes);
        let (w_dram, w_llc) = if w_unique > 0 && w_unique <= llc_bytes {
            (w_unique, self.weight_stream_bytes - w_unique)
        } else {
            (self.weight_stream_bytes, 0)
        };
        let i_unique = self.input_unique_bytes.min(self.input_bytes);
        let (i_dram, i_llc) = if i_unique > 0 {
            (i_unique, self.input_bytes - i_unique)
        } else {
            (self.input_bytes, 0)
        };
        (w_dram + i_dram + self.output_bytes, w_llc + i_llc)
    }

    /// Total AMX tile-compute instructions.
    pub fn tdp_total(&self) -> u64 {
        self.tdp_bf16 + self.tdp_int8
    }

    /// Total simulated instruction count (used for sanity checks).
    pub fn instructions(&self) -> u64 {
        self.tile_zero
            + self.tile_load_input
            + self.tile_load_weight
            + self.tile_store
            + self.tdp_total()
            + self.avx_load
            + self.avx_store
            + self.vpexpand
            + self.vpopcnt
            + self.prefix_step
            + self.avx_fma
            + self.broadcast
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn merge_accumulates() {
        let mut a = EventCounters {
            tdp_bf16: 2,
            weight_stream_bytes: 100,
            ..Default::default()
        };
        let b = EventCounters {
            tdp_bf16: 3,
            vpexpand: 7,
            weight_stream_bytes: 50,
            ..Default::default()
        };
        a.merge(&b);
        assert_eq!(a.tdp_bf16, 5);
        assert_eq!(a.vpexpand, 7);
        assert_eq!(a.weight_stream_bytes, 150);
    }

    #[test]
    fn dram_bytes_excludes_scratch() {
        let c = EventCounters {
            weight_stream_bytes: 10,
            input_bytes: 5,
            output_bytes: 3,
            scratch_bytes: 1000,
            ..Default::default()
        };
        assert_eq!(c.dram_bytes(), 18);
    }

    #[test]
    fn dram_llc_split_models_residency() {
        let c = EventCounters {
            weight_stream_bytes: 800,
            weight_unique_bytes: 100,
            input_bytes: 10,
            output_bytes: 5,
            ..Default::default()
        };
        // fits in LLC: first pass from DRAM, 7 repeats from LLC
        assert_eq!(c.dram_llc_split(1000), (115, 700));
        // does not fit: everything from DRAM
        assert_eq!(c.dram_llc_split(50), (815, 0));
        // single pass: no LLC reuse
        let single = EventCounters {
            weight_stream_bytes: 100,
            weight_unique_bytes: 100,
            ..Default::default()
        };
        assert_eq!(single.dram_llc_split(1000), (100, 0));
    }

    #[test]
    fn merge_takes_min_parallel_tasks() {
        let mut a = EventCounters {
            parallel_tasks: 8,
            ..Default::default()
        };
        a.merge(&EventCounters {
            parallel_tasks: 3,
            ..Default::default()
        });
        assert_eq!(a.parallel_tasks, 3);
        a.merge(&EventCounters::default());
        assert_eq!(a.parallel_tasks, 3);
    }

    #[test]
    fn instruction_total() {
        let c = EventCounters {
            tile_zero: 1,
            tdp_bf16: 2,
            avx_load: 3,
            prefix_step: 4,
            ..Default::default()
        };
        assert_eq!(c.instructions(), 10);
    }
}
