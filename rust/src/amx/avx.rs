//! The AVX-512 operations SparAMX's decompression and AVX kernels use.
//!
//! Modeled ops (paper §2.4, §4.3, Algorithm 1 & 2):
//! `vmovdqu32` (512-bit load), `vpexpandw`/`vpexpandb` (bitmask → dense
//! expansion), `vpopcntd` (per-lane popcount), the shift-add parallel
//! prefix sum, `vdpbf16ps` (BF16 dot-product FMA), and scalar broadcast.
//!
//! Each helper both computes the architectural result and ticks the
//! event counters.

use super::events::EventCounters;
use crate::util::bf16::Bf16;

/// Load 16 u32 lanes (one 512-bit `vmovdqu32`). Counts an AVX load and
/// charges `bytes` to the weight (bitmap) stream.
pub fn vmovdqu32(src: &[u64], ctr: &mut EventCounters) -> [u32; 16] {
    debug_assert!(src.len() >= 16);
    let mut out = [0u32; 16];
    for (o, &s) in out.iter_mut().zip(src.iter()) {
        *o = s as u32;
    }
    ctr.avx_load += 1;
    ctr.weight_stream_bytes += 64;
    out
}

/// `vpexpandw`: expand up to 32 BF16 values from `stream` into a 32-lane
/// register according to `mask` (bit i set → lane i gets the next stream
/// value; clear → zero). Returns the expanded lanes and the number of
/// values consumed. The value bytes consumed are charged to the weight
/// stream (they are read from the packed `weight_values` array in DRAM).
pub fn vpexpandw(mask: u32, stream: &[Bf16], ctr: &mut EventCounters) -> ([Bf16; 32], usize) {
    let mut out = [Bf16::ZERO; 32];
    let mut consumed = 0usize;
    for (i, o) in out.iter_mut().enumerate() {
        if mask >> i & 1 == 1 {
            *o = stream[consumed];
            consumed += 1;
        }
    }
    ctr.vpexpand += 1;
    ctr.weight_stream_bytes += (consumed * 2) as u64;
    out
        .iter()
        .for_each(|_| {}); // no-op; keeps clippy quiet about unused iter
    (out, consumed)
}

/// `vpexpandb`: the INT8 variant — expand up to 64 i8 values by a 64-bit
/// mask.
pub fn vpexpandb(mask: u64, stream: &[i8], ctr: &mut EventCounters) -> ([i8; 64], usize) {
    let mut out = [0i8; 64];
    let mut consumed = 0usize;
    for (i, o) in out.iter_mut().enumerate() {
        if mask >> i & 1 == 1 {
            *o = stream[consumed];
            consumed += 1;
        }
    }
    ctr.vpexpand += 1;
    ctr.weight_stream_bytes += consumed as u64;
    (out, consumed)
}

/// `vpopcntd`: per-lane popcount of 16 u32 lanes.
pub fn vpopcntd(lanes: &[u32; 16], ctr: &mut EventCounters) -> [u32; 16] {
    let mut out = [0u32; 16];
    for (o, &l) in out.iter_mut().zip(lanes.iter()) {
        *o = l.count_ones();
    }
    ctr.vpopcnt += 1;
    out
}

/// Parallel inclusive prefix sum over 16 u32 lanes — Algorithm 1 of the
/// paper: four shift-and-add rounds (log2(16)).
pub fn prefix_sum_u32x16(lanes: &[u32; 16], ctr: &mut EventCounters) -> [u32; 16] {
    let mut s = *lanes;
    let mut shift = 1usize;
    while shift < 16 {
        let mut next = s;
        for i in shift..16 {
            next[i] = s[i] + s[i - shift];
        }
        s = next;
        ctr.prefix_step += 1;
        shift <<= 1;
    }
    s
}

/// Broadcast one BF16 scalar across a 32-lane register.
pub fn broadcast_bf16(x: Bf16, ctr: &mut EventCounters) -> [Bf16; 32] {
    ctr.broadcast += 1;
    [x; 32]
}

/// `vdpbf16ps acc, a, b`: multiply 32 BF16 pairs, add each adjacent pair
/// into 16 FP32 accumulator lanes (paper §2.4).
pub fn vdpbf16ps(
    acc: &mut [f32; 16],
    a: &[Bf16; 32],
    b: &[Bf16; 32],
    ctr: &mut EventCounters,
) {
    for n in 0..16 {
        acc[n] += a[2 * n].to_f32() * b[2 * n].to_f32()
            + a[2 * n + 1].to_f32() * b[2 * n + 1].to_f32();
    }
    ctr.avx_fma += 1;
}

/// Store 16 FP32 lanes to memory (one 512-bit store), charged to output.
pub fn store_f32x16(acc: &[f32; 16], dst: &mut [f32], ctr: &mut EventCounters) {
    dst[..16].copy_from_slice(acc);
    ctr.avx_store += 1;
    ctr.output_bytes += 64;
}

/// Store 32 expanded BF16 lanes into the decompression scratch buffer
/// (charged to `scratch_bytes`: the buffer is cache-resident).
pub fn store_scratch_bf16(lanes: &[Bf16; 32], dst: &mut [Bf16], ctr: &mut EventCounters) {
    dst[..32].copy_from_slice(lanes);
    ctr.avx_store += 1;
    ctr.scratch_bytes += 64;
}

/// INT8 variant of [`store_scratch_bf16`].
pub fn store_scratch_i8(lanes: &[i8; 64], dst: &mut [i8], ctr: &mut EventCounters) {
    dst[..64].copy_from_slice(lanes);
    ctr.avx_store += 1;
    ctr.scratch_bytes += 64;
}

#[cfg(test)]
mod tests {
    use super::*;

    fn bf(x: f32) -> Bf16 {
        Bf16::from_f32(x)
    }

    #[test]
    fn vpexpandw_places_values_at_set_bits() {
        let mut ctr = EventCounters::default();
        let stream = [bf(1.0), bf(2.0), bf(3.0)];
        let mask = 0b1000_0000_0000_0000_0000_0000_0000_0101u32;
        let (out, consumed) = vpexpandw(mask, &stream, &mut ctr);
        assert_eq!(consumed, 3);
        assert_eq!(out[0], bf(1.0));
        assert_eq!(out[1], Bf16::ZERO);
        assert_eq!(out[2], bf(2.0));
        assert_eq!(out[31], bf(3.0));
        assert_eq!(ctr.vpexpand, 1);
        assert_eq!(ctr.weight_stream_bytes, 6);
    }

    #[test]
    fn vpexpandw_zero_mask_consumes_nothing() {
        let mut ctr = EventCounters::default();
        let (out, consumed) = vpexpandw(0, &[], &mut ctr);
        assert_eq!(consumed, 0);
        assert!(out.iter().all(|x| x.is_zero()));
    }

    #[test]
    fn vpexpandb_full_mask() {
        let mut ctr = EventCounters::default();
        let stream: Vec<i8> = (0..64).map(|i| i as i8 - 32).collect();
        let (out, consumed) = vpexpandb(u64::MAX, &stream, &mut ctr);
        assert_eq!(consumed, 64);
        assert_eq!(out.to_vec(), stream);
    }

    #[test]
    fn vpopcntd_counts_per_lane() {
        let mut ctr = EventCounters::default();
        let mut lanes = [0u32; 16];
        lanes[0] = 0b1011;
        lanes[15] = u32::MAX;
        let pc = vpopcntd(&lanes, &mut ctr);
        assert_eq!(pc[0], 3);
        assert_eq!(pc[1], 0);
        assert_eq!(pc[15], 32);
    }

    #[test]
    fn prefix_sum_matches_scan() {
        let mut ctr = EventCounters::default();
        let lanes: [u32; 16] = std::array::from_fn(|i| (i as u32 * 7 + 1) % 13);
        let got = prefix_sum_u32x16(&lanes, &mut ctr);
        let mut expect = [0u32; 16];
        let mut run = 0;
        for i in 0..16 {
            run += lanes[i];
            expect[i] = run;
        }
        assert_eq!(got, expect);
        assert_eq!(ctr.prefix_step, 4, "log2(16) = 4 shift-add rounds");
    }

    #[test]
    fn vdpbf16ps_pairwise_dot() {
        let mut ctr = EventCounters::default();
        let mut acc = [0f32; 16];
        let a: [Bf16; 32] = std::array::from_fn(|i| bf((i % 4) as f32));
        let b: [Bf16; 32] = std::array::from_fn(|_| bf(2.0));
        vdpbf16ps(&mut acc, &a, &b, &mut ctr);
        // lanes alternate: (0*2 + 1*2)=2, (2*2+3*2)=10, ...
        assert_eq!(acc[0], 2.0);
        assert_eq!(acc[1], 10.0);
        assert_eq!(acc[2], 2.0);
        assert_eq!(ctr.avx_fma, 1);
    }

    #[test]
    fn scratch_store_counts_scratch_not_dram() {
        let mut ctr = EventCounters::default();
        let lanes = [Bf16::ONE; 32];
        let mut buf = vec![Bf16::ZERO; 32];
        store_scratch_bf16(&lanes, &mut buf, &mut ctr);
        assert_eq!(ctr.scratch_bytes, 64);
        assert_eq!(ctr.weight_stream_bytes, 0);
        assert_eq!(buf[31], Bf16::ONE);
    }
}
