//! Deterministic fault injection for chaos-testing the serving stack.
//!
//! A [`FaultPlan`] is parsed from `--faults` / the `SPARAMX_FAULTS` env var
//! and installed process-globally. Instrumented seams — the shard pool's
//! job dispatch ([`on_shard_job`]) and the `Backend` handle GEMM entry
//! points ([`on_kernel_call`]) — consult the plan through cheap
//! counter-based hooks, so a CI job can replay an exact failure schedule
//! and assert on the recovery behaviour.
//!
//! Grammar: specs separated by `;`, keys by `,`:
//!
//! ```text
//! worker_panic@epoch=3,shard=1           panic shard 1's job in pool epoch 3 (0-based), once
//! kernel_fail@backend=amx,call=50        panic the 50th GEMM call on backend "amx" (1-based)
//! kernel_fail@backend=amx,call=5,count=2 panic calls 5 and 6 (defeats the same-backend retry)
//! slow_shard@shard=0,delay_us=500        delay shard 0's job by 500us in every pool epoch
//! slow_client@conn=1,delay_us=200        slow-loris connection 1 (1-based): 200us per line
//! disconnect@conn=2,after_bytes=10       sever connection 2 after 10 response bytes, once
//! admit_stall@request=3,delay_us=500     stall the 3rd admission (1-based) by 500us, once
//! ```
//!
//! Every trigger is counter-based — no clocks, no randomness — so a given
//! schedule against a given workload injects the same faults on every run.
//! `worker_panic` and each `kernel_fail` window fire a bounded number of
//! times (once, resp. `count` times), which is what lets the recovery
//! ladder (same-backend retry, healed-pool epoch retry) restore bit-exact
//! output: the retry re-runs the identical computation with the fault spent.

pub mod checkpoint;

use std::collections::BTreeMap;
use std::str::FromStr;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, MutexGuard};
use std::time::Duration;

/// Env var holding a fault schedule; `--faults` takes precedence.
pub const FAULTS_ENV: &str = "SPARAMX_FAULTS";

/// One deterministic fault trigger.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum FaultSpec {
    /// Panic the job for `shard` in pool epoch `epoch` (0-based), at most once.
    WorkerPanic { epoch: u64, shard: usize },
    /// Panic GEMM calls `[call, call + count)` (1-based, counted per
    /// backend name) on the named backend.
    KernelFail { backend: String, call: u64, count: u64 },
    /// Sleep `delay_us` before running `shard`'s job, every pool epoch.
    SlowShard { shard: usize, delay_us: u64 },
    /// Slow-loris the named server connection (1-based accept order):
    /// sleep `delay_us` before handling every request line it sends.
    SlowClient { conn: u64, delay_us: u64 },
    /// Sever the named server connection (1-based accept order) after it
    /// has been sent `after_bytes` response bytes — mid-line when the
    /// boundary falls inside a response. Fires at most once.
    Disconnect { conn: u64, after_bytes: u64 },
    /// Stall the `request`-th admission (1-based, counted per installed
    /// plan) by `delay_us` before it reaches the queue, at most once.
    AdmitStall { request: u64, delay_us: u64 },
}

/// A parsed fault schedule.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct FaultPlan {
    pub specs: Vec<FaultSpec>,
}

impl FaultPlan {
    /// Parse a `;`-separated list of fault specs. Empty input (or only
    /// separators/whitespace) yields an empty, unarmed plan.
    pub fn parse(text: &str) -> Result<FaultPlan, String> {
        let mut specs = Vec::new();
        for part in text.split(';') {
            let part = part.trim();
            if part.is_empty() {
                continue;
            }
            specs.push(parse_spec(part)?);
        }
        Ok(FaultPlan { specs })
    }
}

impl FromStr for FaultPlan {
    type Err = String;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        FaultPlan::parse(s)
    }
}

fn parse_spec(text: &str) -> Result<FaultSpec, String> {
    let (kind, rest) = text
        .split_once('@')
        .ok_or_else(|| format!("fault spec `{text}` is missing `@` (expected kind@key=value,...)"))?;
    let mut keys: BTreeMap<&str, &str> = BTreeMap::new();
    for kv in rest.split(',') {
        let (k, v) = kv
            .split_once('=')
            .ok_or_else(|| format!("fault spec `{text}`: `{kv}` is not key=value"))?;
        if keys.insert(k.trim(), v.trim()).is_some() {
            return Err(format!("fault spec `{text}`: duplicate key `{}`", k.trim()));
        }
    }
    let num = |key: &str| -> Result<u64, String> {
        keys.get(key)
            .ok_or_else(|| format!("fault spec `{text}` is missing `{key}=`"))?
            .parse::<u64>()
            .map_err(|_| format!("fault spec `{text}`: `{key}` must be an unsigned integer"))
    };
    let allow = |allowed: &[&str]| -> Result<(), String> {
        for k in keys.keys() {
            if !allowed.contains(k) {
                return Err(format!("fault spec `{text}`: unknown key `{k}`"));
            }
        }
        Ok(())
    };
    match kind.trim() {
        "worker_panic" => {
            allow(&["epoch", "shard"])?;
            Ok(FaultSpec::WorkerPanic { epoch: num("epoch")?, shard: num("shard")? as usize })
        }
        "kernel_fail" => {
            allow(&["backend", "call", "count"])?;
            let backend = keys
                .get("backend")
                .ok_or_else(|| format!("fault spec `{text}` is missing `backend=`"))?
                .to_string();
            if backend.is_empty() {
                return Err(format!("fault spec `{text}`: `backend` must be non-empty"));
            }
            let call = num("call")?;
            if call == 0 {
                return Err(format!("fault spec `{text}`: `call` is 1-based, must be >= 1"));
            }
            let count = if keys.contains_key("count") { num("count")? } else { 1 };
            if count == 0 {
                return Err(format!("fault spec `{text}`: `count` must be >= 1"));
            }
            Ok(FaultSpec::KernelFail { backend, call, count })
        }
        "slow_shard" => {
            allow(&["shard", "delay_us"])?;
            Ok(FaultSpec::SlowShard { shard: num("shard")? as usize, delay_us: num("delay_us")? })
        }
        "slow_client" => {
            allow(&["conn", "delay_us"])?;
            let conn = num("conn")?;
            if conn == 0 {
                return Err(format!("fault spec `{text}`: `conn` is 1-based, must be >= 1"));
            }
            Ok(FaultSpec::SlowClient { conn, delay_us: num("delay_us")? })
        }
        "disconnect" => {
            allow(&["conn", "after_bytes"])?;
            let conn = num("conn")?;
            if conn == 0 {
                return Err(format!("fault spec `{text}`: `conn` is 1-based, must be >= 1"));
            }
            Ok(FaultSpec::Disconnect { conn, after_bytes: num("after_bytes")? })
        }
        "admit_stall" => {
            allow(&["request", "delay_us"])?;
            let request = num("request")?;
            if request == 0 {
                return Err(format!("fault spec `{text}`: `request` is 1-based, must be >= 1"));
            }
            Ok(FaultSpec::AdmitStall { request, delay_us: num("delay_us")? })
        }
        other => Err(format!(
            "unknown fault kind `{other}` (expected worker_panic, kernel_fail, slow_shard, \
             slow_client, disconnect, or admit_stall)"
        )),
    }
}

/// Armed runtime state for one installed plan.
struct ArmedPlan {
    plan: FaultPlan,
    /// Per-spec fire counter: `worker_panic` fires while 0, a
    /// `kernel_fail` window fires while below its `count`. `slow_shard`
    /// never consults it.
    fired: Vec<AtomicU64>,
    /// Per-backend GEMM call counters (1-based, per installed plan).
    calls: Mutex<BTreeMap<String, u64>>,
    /// Server connection counter (1-based accept order, per installed plan).
    conns: AtomicU64,
    /// Admission counter (1-based, per installed plan).
    admits: AtomicU64,
}

static ARMED: AtomicBool = AtomicBool::new(false);
static STATE: Mutex<Option<Arc<ArmedPlan>>> = Mutex::new(None);
static INJECTED: AtomicU64 = AtomicU64::new(0);
static FAILURES: Mutex<Vec<String>> = Mutex::new(Vec::new());

/// Lock that shrugs off poisoning: an injected panic may unwind through a
/// thread that observed these globals, and the data is plain counters.
fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

/// Install `plan` process-globally, resetting all injection counters and
/// pending failure records. An empty plan leaves injection disarmed.
pub fn install(plan: FaultPlan) {
    let armed = !plan.specs.is_empty();
    let state = ArmedPlan {
        fired: plan.specs.iter().map(|_| AtomicU64::new(0)).collect(),
        calls: Mutex::new(BTreeMap::new()),
        conns: AtomicU64::new(0),
        admits: AtomicU64::new(0),
        plan,
    };
    *lock(&STATE) = Some(Arc::new(state));
    INJECTED.store(0, Ordering::Relaxed);
    lock(&FAILURES).clear();
    ARMED.store(armed, Ordering::Release);
}

/// Disarm injection and reset all counters and failure records.
pub fn clear() {
    ARMED.store(false, Ordering::Release);
    *lock(&STATE) = None;
    INJECTED.store(0, Ordering::Relaxed);
    lock(&FAILURES).clear();
}

/// Parse and install `text` when non-empty, otherwise fall back to the
/// `SPARAMX_FAULTS` env var. Returns whether a non-empty plan is armed.
pub fn install_str_or_env(text: &str) -> Result<bool, String> {
    let source = if text.trim().is_empty() {
        std::env::var(FAULTS_ENV).unwrap_or_default()
    } else {
        text.to_string()
    };
    if source.trim().is_empty() {
        return Ok(false);
    }
    let plan: FaultPlan = source.parse()?;
    let armed = !plan.specs.is_empty();
    install(plan);
    Ok(armed)
}

/// Cheap check the instrumented seams gate on: true iff a non-empty plan
/// is installed.
#[inline]
pub fn armed() -> bool {
    ARMED.load(Ordering::Acquire)
}

/// Total faults injected (panics + delays) since the last install/clear.
pub fn injected_count() -> u64 {
    INJECTED.load(Ordering::Relaxed)
}

fn state() -> Option<Arc<ArmedPlan>> {
    if !armed() {
        return None;
    }
    lock(&STATE).clone()
}

/// Shard-pool seam: called once per scattered job with the pool's 0-based
/// epoch index and the job (= shard) index. May sleep (`slow_shard`) or
/// panic (`worker_panic`); the pool catches the panic and surfaces it as
/// an `EpochError`.
pub fn on_shard_job(epoch: u64, shard: usize) {
    let Some(st) = state() else { return };
    for (i, spec) in st.plan.specs.iter().enumerate() {
        match spec {
            FaultSpec::SlowShard { shard: s, delay_us } if *s == shard => {
                INJECTED.fetch_add(1, Ordering::Relaxed);
                std::thread::sleep(Duration::from_micros(*delay_us));
            }
            FaultSpec::WorkerPanic { epoch: e, shard: s } if *e == epoch && *s == shard => {
                if st.fired[i].swap(1, Ordering::Relaxed) == 0 {
                    INJECTED.fetch_add(1, Ordering::Relaxed);
                    panic!("injected worker_panic (epoch {epoch}, shard {shard})");
                }
            }
            _ => {}
        }
    }
}

/// Backend-handle seam: called once per GEMM entry with the backend's
/// name. Counts calls per backend (1-based) and panics while inside a
/// matching `kernel_fail` window; the handle catches the panic, retries
/// once on the same backend, then falls back to the reference kernel.
pub fn on_kernel_call(backend: &str) {
    let Some(st) = state() else { return };
    let call = {
        let mut calls = lock(&st.calls);
        let c = calls.entry(backend.to_string()).or_insert(0);
        *c += 1;
        *c
    };
    for (i, spec) in st.plan.specs.iter().enumerate() {
        if let FaultSpec::KernelFail { backend: b, call: first, count } = spec {
            if b == backend
                && call >= *first
                && call < first + count
                && st.fired[i].fetch_add(1, Ordering::Relaxed) < *count
            {
                INJECTED.fetch_add(1, Ordering::Relaxed);
                panic!("injected kernel_fail (backend {backend}, call {call})");
            }
        }
    }
}

/// Server accept seam: called once per accepted connection. Returns the
/// connection's 1-based id under the installed plan, or 0 when unarmed
/// (ids are only consulted by the injection hooks below, so an unarmed
/// server never pays for the counter).
pub fn on_client_connect() -> u64 {
    let Some(st) = state() else { return 0 };
    st.conns.fetch_add(1, Ordering::Relaxed) + 1
}

/// Server read seam: called once per request line on connection `conn`
/// (1-based, 0 = unarmed). Sleeps for a matching `slow_client` spec —
/// the deterministic stand-in for a slow-loris client trickling bytes.
pub fn on_client_line(conn: u64) {
    let Some(st) = state() else { return };
    for spec in &st.plan.specs {
        if let FaultSpec::SlowClient { conn: c, delay_us } = spec {
            if *c == conn {
                INJECTED.fetch_add(1, Ordering::Relaxed);
                std::thread::sleep(Duration::from_micros(*delay_us));
            }
        }
    }
}

/// Server write seam: called before writing `len` response bytes to
/// connection `conn` which has already been sent `written` bytes. When a
/// matching `disconnect` spec's byte budget is exhausted by this write,
/// returns `Some(allowed_prefix_len)` — the server writes only that
/// prefix and severs the connection (mid-line when the boundary falls
/// inside the response). Fires at most once per spec.
pub fn on_client_write(conn: u64, written: u64, len: usize) -> Option<usize> {
    let st = state()?;
    for (i, spec) in st.plan.specs.iter().enumerate() {
        if let FaultSpec::Disconnect { conn: c, after_bytes } = spec {
            if *c == conn
                && written + len as u64 > *after_bytes
                && st.fired[i].swap(1, Ordering::Relaxed) == 0
            {
                INJECTED.fetch_add(1, Ordering::Relaxed);
                return Some(after_bytes.saturating_sub(written) as usize);
            }
        }
    }
    None
}

/// Admission seam: called once per admission attempt, *before* the queue
/// lock is taken, so a stalled admission never blocks co-admitted
/// requests. Sleeps when the 1-based admission counter matches an
/// `admit_stall` spec; each spec fires at most once.
pub fn on_admit() {
    let Some(st) = state() else { return };
    let n = st.admits.fetch_add(1, Ordering::Relaxed) + 1;
    for (i, spec) in st.plan.specs.iter().enumerate() {
        if let FaultSpec::AdmitStall { request, delay_us } = spec {
            if *request == n && st.fired[i].swap(1, Ordering::Relaxed) == 0 {
                INJECTED.fetch_add(1, Ordering::Relaxed);
                std::thread::sleep(Duration::from_micros(*delay_us));
            }
        }
    }
}

/// Record that `name` failed a GEMM call even after the same-backend
/// retry (the reference fallback completed the call). The engine drains
/// these into `BackendRegistry` health state to drive quarantine.
pub fn record_backend_failure(name: &str) {
    lock(&FAILURES).push(name.to_string());
}

/// Drain all backend failure records accumulated since the last drain.
pub fn drain_backend_failures() -> Vec<String> {
    std::mem::take(&mut *lock(&FAILURES))
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::panic::{catch_unwind, AssertUnwindSafe};

    /// Fault state is process-global; tests that install plans serialize
    /// here and use trigger values no other test's seams can reach.
    static SERIAL: Mutex<()> = Mutex::new(());

    fn serial() -> MutexGuard<'static, ()> {
        SERIAL.lock().unwrap_or_else(|e| e.into_inner())
    }

    #[test]
    fn parses_every_kind_and_count_default() {
        let plan = FaultPlan::parse(
            "worker_panic@epoch=3,shard=1; kernel_fail@backend=amx,call=50; \
             kernel_fail@backend=avx,call=5,count=2; slow_shard@shard=0,delay_us=500",
        )
        .unwrap();
        assert_eq!(
            plan.specs,
            vec![
                FaultSpec::WorkerPanic { epoch: 3, shard: 1 },
                FaultSpec::KernelFail { backend: "amx".into(), call: 50, count: 1 },
                FaultSpec::KernelFail { backend: "avx".into(), call: 5, count: 2 },
                FaultSpec::SlowShard { shard: 0, delay_us: 500 },
            ]
        );
    }

    #[test]
    fn parses_server_and_admission_kinds() {
        let plan = FaultPlan::parse(
            "slow_client@conn=1,delay_us=200; disconnect@conn=2,after_bytes=10; \
             admit_stall@request=3,delay_us=500",
        )
        .unwrap();
        assert_eq!(
            plan.specs,
            vec![
                FaultSpec::SlowClient { conn: 1, delay_us: 200 },
                FaultSpec::Disconnect { conn: 2, after_bytes: 10 },
                FaultSpec::AdmitStall { request: 3, delay_us: 500 },
            ]
        );
    }

    #[test]
    fn empty_and_separator_only_inputs_are_unarmed() {
        assert!(FaultPlan::parse("").unwrap().specs.is_empty());
        assert!(FaultPlan::parse(" ; ;; ").unwrap().specs.is_empty());
    }

    #[test]
    fn parse_rejects_malformed_specs() {
        for bad in [
            "worker_panic",                          // missing @
            "worker_panic@epoch=1",                  // missing shard
            "worker_panic@epoch=1,shard=2,zzz=3",    // unknown key
            "worker_panic@epoch=x,shard=2",          // non-numeric
            "worker_panic@epoch=1,epoch=2,shard=0",  // duplicate key
            "kernel_fail@backend=amx,call=0",        // call is 1-based
            "kernel_fail@backend=amx,call=1,count=0",
            "kernel_fail@call=1",                    // missing backend
            "slow_shard@shard=0",                    // missing delay_us
            "slow_client@conn=0,delay_us=1",         // conn is 1-based
            "slow_client@delay_us=1",                // missing conn
            "disconnect@conn=0,after_bytes=1",       // conn is 1-based
            "disconnect@conn=1",                     // missing after_bytes
            "admit_stall@request=0,delay_us=1",      // request is 1-based
            "admit_stall@request=1,zzz=2,delay_us=1", // unknown key
            "meteor_strike@shard=0",                 // unknown kind
        ] {
            assert!(FaultPlan::parse(bad).is_err(), "`{bad}` should fail to parse");
        }
    }

    #[test]
    fn worker_panic_fires_exactly_once() {
        let _g = serial();
        install(FaultPlan::parse("worker_panic@epoch=999983,shard=7").unwrap());
        assert!(armed());
        // Non-matching (epoch, shard) never fires.
        on_shard_job(999983, 6);
        on_shard_job(1, 7);
        let hit = catch_unwind(AssertUnwindSafe(|| on_shard_job(999983, 7)));
        assert!(hit.is_err(), "matching job should panic");
        // Spent: the healed-pool retry of the same epoch passes.
        on_shard_job(999983, 7);
        assert_eq!(injected_count(), 1);
        clear();
        assert!(!armed());
    }

    #[test]
    fn kernel_fail_window_counts_calls_per_backend() {
        let _g = serial();
        install(FaultPlan::parse("kernel_fail@backend=zz-test,call=3,count=2").unwrap());
        // Calls 1, 2 pass; other backends never trip the window.
        on_kernel_call("zz-test");
        on_kernel_call("zz-other");
        on_kernel_call("zz-test");
        // Calls 3 and 4 (the retry) panic; call 5 passes — window spent.
        assert!(catch_unwind(AssertUnwindSafe(|| on_kernel_call("zz-test"))).is_err());
        assert!(catch_unwind(AssertUnwindSafe(|| on_kernel_call("zz-test"))).is_err());
        on_kernel_call("zz-test");
        assert_eq!(injected_count(), 2);
        clear();
    }

    #[test]
    fn slow_shard_delays_without_failing() {
        let _g = serial();
        install(FaultPlan::parse("slow_shard@shard=97,delay_us=1").unwrap());
        on_shard_job(0, 97);
        on_shard_job(1, 97);
        on_shard_job(0, 96);
        assert_eq!(injected_count(), 2);
        clear();
    }

    #[test]
    fn connection_ids_are_one_based_and_zero_when_unarmed() {
        let _g = serial();
        clear();
        assert_eq!(on_client_connect(), 0);
        install(FaultPlan::parse("slow_client@conn=999979,delay_us=1").unwrap());
        assert_eq!(on_client_connect(), 1);
        assert_eq!(on_client_connect(), 2);
        // Only the named connection is slowed.
        on_client_line(1);
        assert_eq!(injected_count(), 0);
        on_client_line(999_979);
        on_client_line(999_979);
        assert_eq!(injected_count(), 2);
        clear();
    }

    #[test]
    fn disconnect_truncates_the_crossing_write_once() {
        let _g = serial();
        install(FaultPlan::parse("disconnect@conn=999977,after_bytes=10").unwrap());
        // Other connections and writes under the budget pass untouched.
        assert_eq!(on_client_write(1, 0, 100), None);
        assert_eq!(on_client_write(999_977, 0, 10), None);
        // The write that crosses byte 10 is truncated to the prefix…
        assert_eq!(on_client_write(999_977, 6, 8), Some(4));
        // …and the spec is spent.
        assert_eq!(on_client_write(999_977, 6, 8), None);
        assert_eq!(injected_count(), 1);
        clear();
    }

    #[test]
    fn admit_stall_fires_on_the_nth_admission_only() {
        let _g = serial();
        install(FaultPlan::parse("admit_stall@request=3,delay_us=1").unwrap());
        on_admit();
        on_admit();
        assert_eq!(injected_count(), 0);
        on_admit();
        assert_eq!(injected_count(), 1);
        on_admit();
        assert_eq!(injected_count(), 1);
        clear();
    }

    #[test]
    fn failure_records_drain_once() {
        let _g = serial();
        clear();
        record_backend_failure("zz-test");
        record_backend_failure("zz-test");
        assert_eq!(drain_backend_failures(), vec!["zz-test".to_string(), "zz-test".to_string()]);
        assert!(drain_backend_failures().is_empty());
    }

    #[test]
    fn install_str_or_env_prefers_explicit_text() {
        let _g = serial();
        assert!(install_str_or_env("worker_panic@epoch=999991,shard=3").unwrap());
        assert!(armed());
        assert!(install_str_or_env("nope").is_err());
        clear();
    }
}
