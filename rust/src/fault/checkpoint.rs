//! Crash-consistent slot snapshots: serialize every in-flight decode
//! slot — request identity, emitted tokens, deadline/cancel state, and
//! the backend-agnostic KV cache — to a versioned, checksummed file the
//! engine can restore after a process restart.
//!
//! Format (all integers little-endian):
//!
//! ```text
//! magic   4 bytes  "SPXC"
//! version u32      bumped on any layout change; mismatches are rejected
//! len     u64      payload byte count
//! payload len bytes
//! check   u64      FNV-1a 64 over the payload
//! ```
//!
//! The checksum is what makes restore *crash-consistent*: a snapshot torn
//! mid-write (or bit-rotted) fails verification and is skipped — the
//! engine records a `restore_rejected` and starts empty rather than
//! resuming from corrupt state. [`save`] additionally writes to a
//! temporary sibling and renames, so a crash during checkpointing never
//! clobbers the previous good snapshot.
//!
//! Only *machine-independent* state is serialized: token bytes, f32/bf16
//! bit patterns, and the packed sparse segments (whose tile geometry is a
//! pure function of the element type). Backend selections are
//! deliberately **not** stored — the restoring process recompiles its
//! decode plan against its own registry, so a snapshot written on an
//! AMX machine restores cleanly on an AVX-512-only (or no-ISA) one.

use crate::kvcache::cache::{HeadCache, KvCache};
use crate::sparse::format::{SparseTensor, TileOrder};
use crate::util::bf16::Bf16;

/// File magic: SParamX Checkpoint.
pub const MAGIC: [u8; 4] = *b"SPXC";
/// Snapshot layout version; bump on any change to the payload encoding.
pub const VERSION: u32 = 1;

/// One in-flight decode slot, as captured at a step boundary.
#[derive(Clone, Debug, PartialEq)]
pub struct SlotSnapshot {
    /// Original request id (kept across restore for log continuity).
    pub id: u64,
    pub prompt: Vec<u8>,
    pub max_new_tokens: usize,
    /// Tokens emitted before the snapshot.
    pub generated: Vec<u8>,
    /// Cache length the engine tracked for the slot.
    pub cache_len: usize,
    /// Next decode position.
    pub pos: usize,
    /// Token to feed into the next step.
    pub token: u8,
    /// Decode seconds accumulated before the snapshot.
    pub decode_time: f64,
    /// Deadline budget left at snapshot time; re-anchored to the restore
    /// instant (downtime does not count against the request).
    pub deadline_remaining_ms: Option<u64>,
    /// Whether cancellation had been requested.
    pub cancelled: bool,
    /// The slot's backend-agnostic KV cache.
    pub cache: KvCache,
}

/// A whole-engine snapshot: every active slot at one step boundary.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Snapshot {
    pub slots: Vec<SlotSnapshot>,
}

/// FNV-1a 64-bit over `bytes` — tiny, dependency-free, and plenty for
/// torn-write detection (this is integrity, not authentication).
pub fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

// ---------------------------------------------------------------------
// encode
// ---------------------------------------------------------------------

struct Writer(Vec<u8>);

impl Writer {
    fn u8(&mut self, v: u8) {
        self.0.push(v);
    }
    fn u32(&mut self, v: u32) {
        self.0.extend_from_slice(&v.to_le_bytes());
    }
    fn u64(&mut self, v: u64) {
        self.0.extend_from_slice(&v.to_le_bytes());
    }
    fn f64(&mut self, v: f64) {
        self.u64(v.to_bits());
    }
    fn bytes(&mut self, v: &[u8]) {
        self.u64(v.len() as u64);
        self.0.extend_from_slice(v);
    }
}

fn encode_sparse(w: &mut Writer, sp: &SparseTensor<Bf16>) {
    w.u64(sp.rows as u64);
    w.u64(sp.cols as u64);
    w.u64(sp.rows_padded as u64);
    w.u64(sp.cols_padded as u64);
    w.u64(sp.metadata.len() as u64);
    for &m in &sp.metadata {
        w.u64(m);
    }
    w.u64(sp.values.len() as u64);
    for &v in &sp.values {
        w.0.extend_from_slice(&v.to_bits().to_le_bytes());
    }
    w.u64(sp.tile_nnz_prefix.len() as u64);
    for &p in &sp.tile_nnz_prefix {
        w.u32(p);
    }
}

fn encode_f32s(w: &mut Writer, xs: &[f32]) {
    w.u64(xs.len() as u64);
    for &x in xs {
        w.u32(x.to_bits());
    }
}

fn encode_head(w: &mut Writer, hc: &HeadCache) {
    w.u64(hc.head_dim as u64);
    w.u64(hc.n_static as u64);
    encode_sparse(w, &hc.k_static);
    encode_sparse(w, &hc.v_static);
    encode_f32s(w, &hc.k_dyn);
    encode_f32s(w, &hc.v_dyn);
}

fn encode_cache(w: &mut Writer, cache: &KvCache) {
    w.u64(cache.heads.len() as u64);
    w.u64(cache.kv_heads as u64);
    for layer in &cache.heads {
        w.u64(layer.len() as u64);
        for hc in layer {
            encode_head(w, hc);
        }
    }
}

fn encode_slot(w: &mut Writer, s: &SlotSnapshot) {
    w.u64(s.id);
    w.bytes(&s.prompt);
    w.u64(s.max_new_tokens as u64);
    w.bytes(&s.generated);
    w.u64(s.cache_len as u64);
    w.u64(s.pos as u64);
    w.u8(s.token);
    w.f64(s.decode_time);
    match s.deadline_remaining_ms {
        Some(ms) => {
            w.u8(1);
            w.u64(ms);
        }
        None => w.u8(0),
    }
    w.u8(s.cancelled as u8);
    encode_cache(w, &s.cache);
}

/// Encode a snapshot into the full file image (header + payload +
/// checksum).
pub fn encode(snap: &Snapshot) -> Vec<u8> {
    let mut payload = Writer(Vec::new());
    payload.u32(snap.slots.len() as u32);
    for s in &snap.slots {
        encode_slot(&mut payload, s);
    }
    let payload = payload.0;
    let mut out = Writer(Vec::with_capacity(payload.len() + 24));
    out.0.extend_from_slice(&MAGIC);
    out.u32(VERSION);
    out.u64(payload.len() as u64);
    out.0.extend_from_slice(&payload);
    out.u64(fnv1a64(&payload));
    out.0
}

// ---------------------------------------------------------------------
// decode
// ---------------------------------------------------------------------

struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8], String> {
        if self.pos + n > self.buf.len() {
            return Err("truncated snapshot payload".to_string());
        }
        let out = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(out)
    }
    fn u8(&mut self) -> Result<u8, String> {
        Ok(self.take(1)?[0])
    }
    fn u16(&mut self) -> Result<u16, String> {
        Ok(u16::from_le_bytes(self.take(2)?.try_into().unwrap()))
    }
    fn u32(&mut self) -> Result<u32, String> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }
    fn u64(&mut self) -> Result<u64, String> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }
    fn f64(&mut self) -> Result<f64, String> {
        Ok(f64::from_bits(self.u64()?))
    }
    fn len(&mut self) -> Result<usize, String> {
        let n = self.u64()?;
        // A length can never exceed the bytes left; reject early so a
        // corrupt length cannot trigger a huge allocation.
        if n > self.buf.len() as u64 {
            return Err("snapshot length field exceeds payload".to_string());
        }
        Ok(n as usize)
    }
    fn bytes(&mut self) -> Result<Vec<u8>, String> {
        let n = self.len()?;
        Ok(self.take(n)?.to_vec())
    }
}

fn decode_sparse(r: &mut Reader) -> Result<SparseTensor<Bf16>, String> {
    let rows = r.u64()? as usize;
    let cols = r.u64()? as usize;
    let rows_padded = r.u64()? as usize;
    let cols_padded = r.u64()? as usize;
    let n_meta = r.len()?;
    let mut metadata = Vec::with_capacity(n_meta);
    for _ in 0..n_meta {
        metadata.push(r.u64()?);
    }
    let n_vals = r.len()?;
    let mut values = Vec::with_capacity(n_vals);
    for _ in 0..n_vals {
        values.push(Bf16::from_bits(r.u16()?));
    }
    let n_prefix = r.len()?;
    let mut tile_nnz_prefix = Vec::with_capacity(n_prefix);
    for _ in 0..n_prefix {
        tile_nnz_prefix.push(r.u32()?);
    }
    Ok(SparseTensor {
        rows,
        cols,
        rows_padded,
        cols_padded,
        // Tile geometry is a pure function of the element type — never
        // machine state — so it is rebuilt, not stored.
        order: TileOrder::for_elem::<Bf16>(),
        metadata,
        values,
        tile_nnz_prefix,
    })
}

fn decode_f32s(r: &mut Reader) -> Result<Vec<f32>, String> {
    let n = r.len()?;
    let mut out = Vec::with_capacity(n);
    for _ in 0..n {
        out.push(f32::from_bits(r.u32()?));
    }
    Ok(out)
}

fn decode_head(r: &mut Reader) -> Result<HeadCache, String> {
    let head_dim = r.u64()? as usize;
    let n_static = r.u64()? as usize;
    let k_static = decode_sparse(r)?;
    let v_static = decode_sparse(r)?;
    let k_dyn = decode_f32s(r)?;
    let v_dyn = decode_f32s(r)?;
    Ok(HeadCache {
        k_static,
        v_static,
        k_dyn,
        v_dyn,
        head_dim,
        n_static,
    })
}

fn decode_cache(r: &mut Reader) -> Result<KvCache, String> {
    let layers = r.len()?;
    let kv_heads = r.u64()? as usize;
    let mut heads = Vec::with_capacity(layers);
    for _ in 0..layers {
        let n = r.len()?;
        let mut layer = Vec::with_capacity(n);
        for _ in 0..n {
            layer.push(decode_head(r)?);
        }
        heads.push(layer);
    }
    Ok(KvCache { heads, kv_heads })
}

fn decode_slot(r: &mut Reader) -> Result<SlotSnapshot, String> {
    let id = r.u64()?;
    let prompt = r.bytes()?;
    let max_new_tokens = r.u64()? as usize;
    let generated = r.bytes()?;
    let cache_len = r.u64()? as usize;
    let pos = r.u64()? as usize;
    let token = r.u8()?;
    let decode_time = r.f64()?;
    let deadline_remaining_ms = match r.u8()? {
        0 => None,
        1 => Some(r.u64()?),
        b => return Err(format!("snapshot deadline flag must be 0/1, got {b}")),
    };
    let cancelled = match r.u8()? {
        0 => false,
        1 => true,
        b => return Err(format!("snapshot cancel flag must be 0/1, got {b}")),
    };
    let cache = decode_cache(r)?;
    Ok(SlotSnapshot {
        id,
        prompt,
        max_new_tokens,
        generated,
        cache_len,
        pos,
        token,
        decode_time,
        deadline_remaining_ms,
        cancelled,
        cache,
    })
}

/// Decode a full file image, verifying magic, version, and checksum
/// before touching the payload.
pub fn decode(bytes: &[u8]) -> Result<Snapshot, String> {
    if bytes.len() < 16 {
        return Err("snapshot file shorter than its header".to_string());
    }
    if bytes[..4] != MAGIC {
        return Err("snapshot magic mismatch (not a SparAMX checkpoint)".to_string());
    }
    let version = u32::from_le_bytes(bytes[4..8].try_into().unwrap());
    if version != VERSION {
        return Err(format!("snapshot version {version} != supported {VERSION}"));
    }
    let len = u64::from_le_bytes(bytes[8..16].try_into().unwrap()) as usize;
    if bytes.len() != 16 + len + 8 {
        return Err(format!(
            "snapshot length mismatch: header says {len} payload bytes, file holds {}",
            bytes.len().saturating_sub(24)
        ));
    }
    let payload = &bytes[16..16 + len];
    let want = u64::from_le_bytes(bytes[16 + len..].try_into().unwrap());
    let got = fnv1a64(payload);
    if got != want {
        return Err(format!("snapshot checksum mismatch ({got:#x} != {want:#x}) — torn write?"));
    }
    let mut r = Reader { buf: payload, pos: 0 };
    let count = r.u32()? as usize;
    let mut slots = Vec::with_capacity(count.min(1024));
    for _ in 0..count {
        slots.push(decode_slot(&mut r)?);
    }
    if r.pos != payload.len() {
        return Err("snapshot has trailing bytes after the last slot".to_string());
    }
    Ok(Snapshot { slots })
}

/// Write `snap` to `path` atomically: encode, write a temporary sibling,
/// fsync-free rename. A crash mid-write leaves the previous snapshot (or
/// a rejectable torn temporary) — never a silently corrupt current file.
pub fn save(path: &str, snap: &Snapshot) -> Result<(), String> {
    let bytes = encode(snap);
    let tmp = format!("{path}.tmp");
    std::fs::write(&tmp, &bytes).map_err(|e| format!("write {tmp}: {e}"))?;
    std::fs::rename(&tmp, path).map_err(|e| format!("rename {tmp} -> {path}: {e}"))
}

/// Load and verify a snapshot from `path`.
pub fn load(path: &str) -> Result<Snapshot, String> {
    let bytes = std::fs::read(path).map_err(|e| format!("read {path}: {e}"))?;
    decode(&bytes)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::XorShift;

    fn sample_snapshot(seed: u64) -> Snapshot {
        let mut g = XorShift::new(seed);
        let mut cache = KvCache::from_prefill(2, 2, 8, 4, 0.3, 0.5, |l, h| {
            let mut gg = XorShift::new(seed * 100 + (l * 10 + h) as u64);
            (gg.normal_vec(32, 1.0), gg.normal_vec(32, 1.0))
        });
        // grow a dynamic tail so both segments round-trip
        for layer in &mut cache.heads {
            for hc in layer {
                hc.append(&g.normal_vec(4, 1.0), &g.normal_vec(4, 1.0));
            }
        }
        Snapshot {
            slots: vec![SlotSnapshot {
                id: 42,
                prompt: b"the cat".to_vec(),
                max_new_tokens: 8,
                generated: vec![10, 20, 30],
                cache_len: 9,
                pos: 9,
                token: 30,
                decode_time: 0.125,
                deadline_remaining_ms: Some(750),
                cancelled: false,
                cache,
            }],
        }
    }

    #[test]
    fn roundtrip_is_bit_exact() {
        let snap = sample_snapshot(7);
        let decoded = decode(&encode(&snap)).expect("decode");
        assert_eq!(decoded, snap);
    }

    #[test]
    fn empty_snapshot_roundtrips() {
        let snap = Snapshot::default();
        assert_eq!(decode(&encode(&snap)).unwrap(), snap);
    }

    #[test]
    fn save_load_via_file() {
        let snap = sample_snapshot(8);
        let path = std::env::temp_dir()
            .join(format!("sparamx-ckpt-test-{}.bin", std::process::id()))
            .to_string_lossy()
            .into_owned();
        save(&path, &snap).expect("save");
        let loaded = load(&path).expect("load");
        std::fs::remove_file(&path).ok();
        assert_eq!(loaded, snap);
    }

    #[test]
    fn corruption_is_detected_by_checksum() {
        let mut bytes = encode(&sample_snapshot(9));
        let mid = 16 + (bytes.len() - 24) / 2; // somewhere in the payload
        bytes[mid] ^= 0x40;
        let err = decode(&bytes).unwrap_err();
        assert!(err.contains("checksum"), "{err}");
    }

    #[test]
    fn truncation_and_header_damage_are_rejected() {
        let bytes = encode(&sample_snapshot(10));
        // torn write: file cut short
        let err = decode(&bytes[..bytes.len() - 5]).unwrap_err();
        assert!(err.contains("length mismatch"), "{err}");
        // wrong magic
        let mut bad = bytes.clone();
        bad[0] = b'X';
        assert!(decode(&bad).unwrap_err().contains("magic"));
        // unsupported version
        let mut bad = bytes.clone();
        bad[4] = 99;
        assert!(decode(&bad).unwrap_err().contains("version"));
        // sub-header fragment
        assert!(decode(&bytes[..10]).unwrap_err().contains("header"));
    }
}
