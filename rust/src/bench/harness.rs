//! Wall-clock measurement harness (criterion-lite).
//!
//! `cargo bench` targets in `benches/` use `harness = false` and call
//! [`bench`] / [`report_row`] directly. Besides wall-clock benches, the
//! figure benches print modeled-time tables from [`crate::perf`]; both
//! paths share the same tabular output helpers so `bench_output.txt` is
//! self-describing.

use crate::util::stats::Summary;
use std::time::Instant;

/// Result of one benchmark case.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub summary: Summary,
}

impl BenchResult {
    /// Mean seconds per iteration.
    pub fn mean_s(&self) -> f64 {
        self.summary.mean
    }
}

/// Measure `f` with `warmup` unmeasured runs then `iters` timed runs.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, iters: usize, mut f: F) -> BenchResult {
    assert!(iters > 0);
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64());
    }
    BenchResult {
        name: name.to_string(),
        iters,
        summary: Summary::from(&samples),
    }
}

/// Auto-calibrating variant: picks an iteration count so the measured
/// region runs for roughly `target_s` seconds (min 3 iters).
pub fn bench_auto<F: FnMut()>(name: &str, target_s: f64, mut f: F) -> BenchResult {
    let t0 = Instant::now();
    f(); // warmup + calibration probe
    let probe = t0.elapsed().as_secs_f64().max(1e-9);
    let iters = ((target_s / probe).ceil() as usize).clamp(3, 10_000);
    bench(name, 1, iters, f)
}

/// Format seconds human-readably.
pub fn fmt_time(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.3} s")
    } else if s >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.3} µs", s * 1e6)
    } else {
        format!("{:.1} ns", s * 1e9)
    }
}

/// Print a table header (markdown-ish, stable for EXPERIMENTS.md).
pub fn report_header(title: &str, cols: &[&str]) {
    println!("\n## {title}");
    println!("| {} |", cols.join(" | "));
    println!("|{}|", cols.iter().map(|_| "---").collect::<Vec<_>>().join("|"));
}

/// Print one table row.
pub fn report_row(cells: &[String]) {
    println!("| {} |", cells.join(" | "));
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_runs_requested_iterations() {
        let mut count = 0usize;
        let r = bench("t", 2, 5, || count += 1);
        assert_eq!(count, 7);
        assert_eq!(r.iters, 5);
        assert!(r.mean_s() >= 0.0);
    }

    #[test]
    fn bench_auto_scales_iters() {
        let r = bench_auto("fast", 0.01, || {
            std::hint::black_box(1 + 1);
        });
        assert!(r.iters >= 3);
    }

    #[test]
    fn fmt_time_units() {
        assert!(fmt_time(2.5).contains('s'));
        assert!(fmt_time(2.5e-3).contains("ms"));
        assert!(fmt_time(2.5e-6).contains("µs"));
        assert!(fmt_time(2.5e-9).contains("ns"));
    }
}
