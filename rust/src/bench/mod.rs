//! Criterion-lite measurement harness (criterion is not vendored).
pub mod harness;
