//! Typed runtime configuration for the serving coordinator.

use super::json::Json;
use crate::backend::BackendChoice;
use std::fmt;

/// Which serving path runs the decode loop (`--engine` / config
/// `"engine"`).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum EngineChoice {
    /// Currently always resolves to [`EngineChoice::Native`]: the PJRT
    /// executor never wins auto-selection — it must be requested
    /// explicitly (`--engine pjrt`), since it needs the `pjrt` feature
    /// build plus a compiled artifact bundle. The variant exists so the
    /// default can grow artifact-sensitive resolution without a config
    /// break.
    #[default]
    Auto,
    /// Plan-compiled native decode: every linear runs the selected
    /// kernel backend end-to-end, no PJRT executor on the token path.
    Native,
    /// The AOT PJRT executables (requires the `pjrt` feature build).
    Pjrt,
}

impl EngineChoice {
    /// All accepted spellings, for help text.
    pub const HELP: &'static str = "auto|native|pjrt";

    /// Resolve the directive: `auto` serves natively — the PJRT
    /// executor is opt-in only (it needs the `pjrt` feature and a
    /// compiled artifact bundle).
    pub fn resolved_native(self) -> bool {
        !matches!(self, EngineChoice::Pjrt)
    }
}

impl std::str::FromStr for EngineChoice {
    type Err = String;

    fn from_str(s: &str) -> Result<EngineChoice, String> {
        match s.to_ascii_lowercase().as_str() {
            "auto" => Ok(EngineChoice::Auto),
            "native" => Ok(EngineChoice::Native),
            "pjrt" => Ok(EngineChoice::Pjrt),
            other => Err(format!("unknown engine '{other}' (expected {})", Self::HELP)),
        }
    }
}

impl fmt::Display for EngineChoice {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let s = match self {
            EngineChoice::Auto => "auto",
            EngineChoice::Native => "native",
            EngineChoice::Pjrt => "pjrt",
        };
        write!(f, "{s}")
    }
}

/// Serving-engine configuration. Loaded from JSON (file or inline) with
/// defaults matching the paper's evaluation setup.
#[derive(Clone, Debug, PartialEq)]
pub struct RuntimeConfig {
    /// Directory with AOT artifacts (`*.hlo.txt`, `weights.bin`,
    /// `manifest.json`).
    pub artifacts_dir: String,
    /// Maximum decode batch assembled by the continuous batcher.
    pub max_batch: usize,
    /// Maximum new tokens per request unless overridden.
    pub max_new_tokens: usize,
    /// Worker threads pinned at load time (fixed: the sparse-format
    /// thread partition depends on it, paper §7).
    pub threads: usize,
    /// Weight sparsity applied when packing (0 disables).
    pub weight_sparsity: f64,
    /// K-cache sparsity for the static segment (§6).
    pub k_sparsity: f64,
    /// V-cache sparsity for the static segment (§6).
    pub v_sparsity: f64,
    /// Microseconds the batcher waits to coalesce requests.
    pub batch_window_us: u64,
    /// TCP port for `sparamx serve`.
    pub port: u16,
    /// Admission-queue capacity; requests beyond it are rejected
    /// (backpressure).
    pub queue_capacity: usize,
    /// Kernel backend directive: `auto` lets the
    /// [`crate::backend::BackendRegistry`] pick per layer; `amx`, `avx`,
    /// `ref` pin one backend.
    pub backend: BackendChoice,
    /// Serving-path directive: `auto` (native unless PJRT is explicitly
    /// requested), `native`, or `pjrt`.
    pub engine: EngineChoice,
    /// Context window of the native decode path (static KV segment +
    /// dynamic tail per slot). The PJRT path reads its own `max_ctx`
    /// from the artifact manifest instead.
    pub max_ctx: usize,
    /// Shard-count directive (`--shards` / config `"shards"`): `auto`
    /// shards one-per-NUMA-node (off on single-node hosts), a number
    /// forces that many column shards; 1 disables. The `SPARAMX_SHARDS`
    /// env var overrides at resolve time.
    pub shards: crate::shard::ShardChoice,
    /// Per-token latency budget in milliseconds for plan-aware
    /// admission: requests whose modeled decode cost
    /// (`DecodePlan::predicted_step_s`) exceeds the budget are rejected
    /// at admission. `0` disables the check.
    pub latency_budget_ms: f64,
    /// Fused decode batch directive (`--max-batch-fuse` / config
    /// `"max_batch_fuse"`): `auto` compiles the fused regime at
    /// `max_batch`, a number caps it; 1 disables fusion. The
    /// `SPARAMX_BATCH_FUSE` env var overrides at resolve time.
    pub max_batch_fuse: crate::models::BatchFuseChoice,
    /// Deterministic fault-injection schedule (`--faults` / config
    /// `"faults"`), e.g. `"worker_panic@epoch=3,shard=1"` — see
    /// [`crate::fault::FaultPlan`] for the grammar. Empty disables
    /// injection; the `SPARAMX_FAULTS` env var fills in when empty.
    pub faults: String,
    /// Crash-consistency snapshot path (`--checkpoint` / config
    /// `"checkpoint"`). Non-empty enables periodic slot checkpointing
    /// (see [`crate::fault::checkpoint`]) and restore-on-startup from
    /// the same path. Empty disables both.
    pub checkpoint: String,
    /// Decode steps between snapshots (`--checkpoint-every-steps` /
    /// config `"checkpoint_every_steps"`). Only steps that actually
    /// advanced a slot count toward the cadence; must be >= 1.
    pub checkpoint_every_steps: u64,
}

impl Default for RuntimeConfig {
    fn default() -> Self {
        RuntimeConfig {
            artifacts_dir: "artifacts".into(),
            max_batch: 8,
            max_new_tokens: 64,
            threads: 1,
            weight_sparsity: 0.5,
            k_sparsity: 0.3,
            v_sparsity: 0.5,
            batch_window_us: 500,
            port: 7070,
            queue_capacity: 256,
            backend: BackendChoice::Auto,
            engine: EngineChoice::Auto,
            max_ctx: 256,
            shards: crate::shard::ShardChoice::Auto,
            latency_budget_ms: 0.0,
            max_batch_fuse: crate::models::BatchFuseChoice::Auto,
            faults: String::new(),
            checkpoint: String::new(),
            checkpoint_every_steps: 16,
        }
    }
}

impl RuntimeConfig {
    /// Load from a JSON string; unknown fields are rejected to catch
    /// typos, missing fields fall back to defaults.
    pub fn from_json(s: &str) -> Result<RuntimeConfig, String> {
        let v = Json::parse(s)?;
        let obj = match &v {
            Json::Obj(m) => m,
            _ => return Err("config must be a JSON object".into()),
        };
        let mut cfg = RuntimeConfig::default();
        for (k, val) in obj {
            match k.as_str() {
                "artifacts_dir" => {
                    cfg.artifacts_dir = val.as_str().ok_or("artifacts_dir: string")?.to_string()
                }
                "max_batch" => cfg.max_batch = val.as_usize().ok_or("max_batch: uint")?,
                "max_new_tokens" => {
                    cfg.max_new_tokens = val.as_usize().ok_or("max_new_tokens: uint")?
                }
                "threads" => cfg.threads = val.as_usize().ok_or("threads: uint")?,
                "weight_sparsity" => {
                    cfg.weight_sparsity = val.as_f64().ok_or("weight_sparsity: number")?
                }
                "k_sparsity" => cfg.k_sparsity = val.as_f64().ok_or("k_sparsity: number")?,
                "v_sparsity" => cfg.v_sparsity = val.as_f64().ok_or("v_sparsity: number")?,
                "batch_window_us" => {
                    cfg.batch_window_us = val.as_usize().ok_or("batch_window_us: uint")? as u64
                }
                "port" => {
                    cfg.port = val
                        .as_usize()
                        .filter(|&p| p <= u16::MAX as usize)
                        .ok_or("port: u16")? as u16
                }
                "queue_capacity" => {
                    cfg.queue_capacity = val.as_usize().ok_or("queue_capacity: uint")?
                }
                "backend" => {
                    cfg.backend = val
                        .as_str()
                        .ok_or("backend: string")?
                        .parse::<BackendChoice>()?
                }
                "engine" => {
                    cfg.engine = val
                        .as_str()
                        .ok_or("engine: string")?
                        .parse::<EngineChoice>()?
                }
                "max_ctx" => cfg.max_ctx = val.as_usize().ok_or("max_ctx: uint")?,
                "shards" => {
                    cfg.shards = if let Some(s) = val.as_str() {
                        s.parse::<crate::shard::ShardChoice>()?
                    } else if let Some(n) = val.as_usize() {
                        crate::shard::ShardChoice::Fixed(n)
                    } else {
                        return Err("shards: \"auto\" or uint".into());
                    }
                }
                "latency_budget_ms" => {
                    cfg.latency_budget_ms =
                        val.as_f64().ok_or("latency_budget_ms: number")?
                }
                "max_batch_fuse" => {
                    cfg.max_batch_fuse = if let Some(s) = val.as_str() {
                        s.parse::<crate::models::BatchFuseChoice>()?
                    } else if let Some(n) = val.as_usize() {
                        crate::models::BatchFuseChoice::Fixed(n)
                    } else {
                        return Err("max_batch_fuse: \"auto\" or uint".into());
                    }
                }
                "faults" => cfg.faults = val.as_str().ok_or("faults: string")?.to_string(),
                "checkpoint" => {
                    cfg.checkpoint = val.as_str().ok_or("checkpoint: string")?.to_string()
                }
                "checkpoint_every_steps" => {
                    cfg.checkpoint_every_steps =
                        val.as_usize().ok_or("checkpoint_every_steps: uint")? as u64
                }
                other => return Err(format!("unknown config field '{other}'")),
            }
        }
        cfg.validate()?;
        Ok(cfg)
    }

    /// Load from a file path.
    pub fn from_file(path: &str) -> Result<RuntimeConfig, String> {
        let text =
            std::fs::read_to_string(path).map_err(|e| format!("read {path}: {e}"))?;
        Self::from_json(&text)
    }

    /// Sanity checks shared by all construction paths.
    pub fn validate(&self) -> Result<(), String> {
        if self.max_batch == 0 {
            return Err("max_batch must be >= 1".into());
        }
        if self.threads == 0 {
            return Err("threads must be >= 1".into());
        }
        for (name, v) in [
            ("weight_sparsity", self.weight_sparsity),
            ("k_sparsity", self.k_sparsity),
            ("v_sparsity", self.v_sparsity),
        ] {
            if !(0.0..=1.0).contains(&v) {
                return Err(format!("{name} must be in [0,1], got {v}"));
            }
        }
        if self.queue_capacity == 0 {
            return Err("queue_capacity must be >= 1".into());
        }
        if self.max_ctx < 2 {
            return Err("max_ctx must be >= 2".into());
        }
        if !self.latency_budget_ms.is_finite() || self.latency_budget_ms < 0.0 {
            return Err(format!(
                "latency_budget_ms must be >= 0 (0 disables), got {}",
                self.latency_budget_ms
            ));
        }
        if !self.faults.trim().is_empty() {
            // reject bad fault grammar at config load, not mid-serve
            self.faults
                .parse::<crate::fault::FaultPlan>()
                .map_err(|e| format!("faults: {e}"))?;
        }
        if self.checkpoint_every_steps == 0 {
            return Err("checkpoint_every_steps must be >= 1".into());
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_are_valid() {
        RuntimeConfig::default().validate().unwrap();
    }

    #[test]
    fn parses_partial_config() {
        let cfg = RuntimeConfig::from_json(r#"{"max_batch": 32, "port": 9000}"#).unwrap();
        assert_eq!(cfg.max_batch, 32);
        assert_eq!(cfg.port, 9000);
        assert_eq!(cfg.max_new_tokens, RuntimeConfig::default().max_new_tokens);
    }

    #[test]
    fn rejects_unknown_field() {
        let err = RuntimeConfig::from_json(r#"{"max_batchh": 2}"#).unwrap_err();
        assert!(err.contains("max_batchh"));
    }

    #[test]
    fn rejects_out_of_range() {
        assert!(RuntimeConfig::from_json(r#"{"weight_sparsity": 1.5}"#).is_err());
        assert!(RuntimeConfig::from_json(r#"{"max_batch": 0}"#).is_err());
        assert!(RuntimeConfig::from_json(r#"{"port": 70000}"#).is_err());
    }

    #[test]
    fn rejects_wrong_type() {
        assert!(RuntimeConfig::from_json(r#"{"threads": "four"}"#).is_err());
    }

    #[test]
    fn parses_engine_choice() {
        assert_eq!(RuntimeConfig::default().engine, EngineChoice::Auto);
        let cfg = RuntimeConfig::from_json(r#"{"engine": "native", "max_ctx": 64}"#).unwrap();
        assert_eq!(cfg.engine, EngineChoice::Native);
        assert_eq!(cfg.max_ctx, 64);
        assert_eq!(
            RuntimeConfig::from_json(r#"{"engine": "pjrt"}"#).unwrap().engine,
            EngineChoice::Pjrt
        );
        assert!(RuntimeConfig::from_json(r#"{"engine": "tpu"}"#).is_err());
        assert!(RuntimeConfig::from_json(r#"{"engine": 1}"#).is_err());
        assert!(RuntimeConfig::from_json(r#"{"max_ctx": 1}"#).is_err());
    }

    #[test]
    fn engine_auto_resolves_native() {
        assert!(EngineChoice::Auto.resolved_native());
        assert!(EngineChoice::Native.resolved_native());
        assert!(!EngineChoice::Pjrt.resolved_native());
        assert_eq!("NATIVE".parse::<EngineChoice>().unwrap(), EngineChoice::Native);
        assert_eq!(EngineChoice::Pjrt.to_string(), "pjrt");
        assert!("xla".parse::<EngineChoice>().is_err());
    }

    #[test]
    fn parses_shards_and_latency_budget() {
        use crate::shard::ShardChoice;
        assert_eq!(RuntimeConfig::default().shards, ShardChoice::Auto);
        assert_eq!(RuntimeConfig::default().latency_budget_ms, 0.0);
        let cfg = RuntimeConfig::from_json(r#"{"shards": "auto"}"#).unwrap();
        assert_eq!(cfg.shards, ShardChoice::Auto);
        let cfg = RuntimeConfig::from_json(r#"{"shards": 4}"#).unwrap();
        assert_eq!(cfg.shards, ShardChoice::Fixed(4));
        let cfg = RuntimeConfig::from_json(r#"{"shards": "2"}"#).unwrap();
        assert_eq!(cfg.shards, ShardChoice::Fixed(2));
        assert!(RuntimeConfig::from_json(r#"{"shards": "lots"}"#).is_err());
        let cfg = RuntimeConfig::from_json(r#"{"latency_budget_ms": 12.5}"#).unwrap();
        assert_eq!(cfg.latency_budget_ms, 12.5);
        assert!(RuntimeConfig::from_json(r#"{"latency_budget_ms": -1}"#).is_err());
    }

    #[test]
    fn parses_max_batch_fuse() {
        use crate::models::BatchFuseChoice;
        assert_eq!(RuntimeConfig::default().max_batch_fuse, BatchFuseChoice::Auto);
        let cfg = RuntimeConfig::from_json(r#"{"max_batch_fuse": "auto"}"#).unwrap();
        assert_eq!(cfg.max_batch_fuse, BatchFuseChoice::Auto);
        let cfg = RuntimeConfig::from_json(r#"{"max_batch_fuse": 4}"#).unwrap();
        assert_eq!(cfg.max_batch_fuse, BatchFuseChoice::Fixed(4));
        let cfg = RuntimeConfig::from_json(r#"{"max_batch_fuse": "1"}"#).unwrap();
        assert_eq!(cfg.max_batch_fuse, BatchFuseChoice::Fixed(1));
        assert!(RuntimeConfig::from_json(r#"{"max_batch_fuse": "many"}"#).is_err());
    }

    #[test]
    fn parses_faults_and_rejects_bad_grammar() {
        assert!(RuntimeConfig::default().faults.is_empty());
        let cfg = RuntimeConfig::from_json(
            r#"{"faults": "worker_panic@epoch=3,shard=1;slow_shard@shard=0,delay_us=500"}"#,
        )
        .unwrap();
        assert!(cfg.faults.starts_with("worker_panic"));
        let err =
            RuntimeConfig::from_json(r#"{"faults": "worker_panic@epoch=3"}"#).unwrap_err();
        assert!(err.contains("faults:"), "{err}");
        assert!(RuntimeConfig::from_json(r#"{"faults": 3}"#).is_err());
        // empty spec is fine (injection disabled)
        RuntimeConfig::from_json(r#"{"faults": ""}"#).unwrap();
    }

    #[test]
    fn parses_checkpoint_settings() {
        let d = RuntimeConfig::default();
        assert!(d.checkpoint.is_empty(), "checkpointing is off by default");
        assert_eq!(d.checkpoint_every_steps, 16);
        let cfg = RuntimeConfig::from_json(
            r#"{"checkpoint": "/tmp/snap.spxc", "checkpoint_every_steps": 4}"#,
        )
        .unwrap();
        assert_eq!(cfg.checkpoint, "/tmp/snap.spxc");
        assert_eq!(cfg.checkpoint_every_steps, 4);
        assert!(RuntimeConfig::from_json(r#"{"checkpoint_every_steps": 0}"#).is_err());
        assert!(RuntimeConfig::from_json(r#"{"checkpoint": 7}"#).is_err());
    }

    #[test]
    fn parses_backend_choice() {
        assert_eq!(RuntimeConfig::default().backend, BackendChoice::Auto);
        let cfg = RuntimeConfig::from_json(r#"{"backend": "avx"}"#).unwrap();
        assert_eq!(cfg.backend, BackendChoice::Avx);
        assert!(RuntimeConfig::from_json(r#"{"backend": "mkl"}"#).is_err());
        assert!(RuntimeConfig::from_json(r#"{"backend": 3}"#).is_err());
    }
}
