//! A small recursive-descent JSON parser and serializer.
//!
//! Used for runtime config files, the artifact manifest written by
//! `python/compile/aot.py`, and the TCP server's request protocol. Covers
//! the full JSON grammar except `\u` surrogate pairs (accepted, replaced
//! with U+FFFD if unpaired).

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Parse a JSON document. Trailing whitespace is allowed; trailing
    /// garbage is an error.
    pub fn parse(s: &str) -> Result<Json, String> {
        let mut p = Parser {
            b: s.as_bytes(),
            i: 0,
        };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(format!("trailing garbage at byte {}", p.i));
        }
        Ok(v)
    }

    // -- typed accessors -------------------------------------------------

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().and_then(|x| {
            (x >= 0.0 && x.fract() == 0.0 && x <= usize::MAX as f64).then_some(x as usize)
        })
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    /// Object field lookup.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// `obj.get(key)` that errs with the key name — for config loading.
    pub fn req(&self, key: &str) -> Result<&Json, String> {
        self.get(key).ok_or_else(|| format!("missing field '{key}'"))
    }

    // -- serialization ---------------------------------------------------

    /// Compact serialization.
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    let _ = write!(out, "{}", *x as i64);
                } else {
                    let _ = write!(out, "{x}");
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    /// Build an object from pairs (test/bench convenience).
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!(
                "expected '{}' at byte {}, found {:?}",
                c as char,
                self.i,
                self.peek().map(|b| b as char)
            ))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        match self.peek() {
            Some(b'n') => self.lit("null", Json::Null),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'"') => self.string().map(Json::Str),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(format!("unexpected {other:?} at byte {}", self.i)),
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
            .unwrap_or(false)
        {
            self.i += 1;
        }
        let text = std::str::from_utf8(&self.b[start..self.i]).expect("ascii");
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|e| format!("bad number {text:?}: {e}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    let esc = self.peek().ok_or("truncated escape")?;
                    self.i += 1;
                    match esc {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                return Err("truncated \\u escape".into());
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])
                                .map_err(|_| "bad \\u escape")?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| "bad \\u escape hex")?;
                            self.i += 4;
                            out.push(char::from_u32(code).unwrap_or('\u{FFFD}'));
                        }
                        c => return Err(format!("bad escape \\{}", c as char)),
                    }
                }
                Some(_) => {
                    // consume one UTF-8 scalar
                    let rest = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|_| "invalid utf-8 in string")?;
                    let c = rest.chars().next().expect("nonempty");
                    out.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.eat(b'[')?;
        self.ws();
        let mut out = Vec::new();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            out.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                    self.ws();
                }
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(out));
                }
                other => return Err(format!("expected , or ] got {other:?}")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.eat(b'{')?;
        self.ws();
        let mut out = BTreeMap::new();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            let key = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let val = self.value()?;
            out.insert(key, val);
            self.ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                    self.ws();
                }
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(out));
                }
                other => return Err(format!("expected , or }} got {other:?}")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse("\"hi\\n\"").unwrap(), Json::Str("hi\n".into()));
    }

    #[test]
    fn parses_nested_structures() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "x"}], "c": null}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            v.get("a").unwrap().as_arr().unwrap()[2].get("b").unwrap().as_str(),
            Some("x")
        );
        assert_eq!(v.get("c"), Some(&Json::Null));
    }

    #[test]
    fn roundtrip_through_serializer() {
        let src = r#"{"arr":[1,2.5,"s"],"nested":{"t":true},"z":null}"#;
        let v = Json::parse(src).unwrap();
        let v2 = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn string_escapes_roundtrip() {
        let s = Json::Str("quote\" slash\\ nl\n tab\t unicode é".into());
        let parsed = Json::parse(&s.to_string()).unwrap();
        assert_eq!(parsed, s);
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(Json::parse(r#""A""#).unwrap(), Json::Str("A".into()));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("\"unterminated").is_err());
        assert!(Json::parse("nulll").is_err());
    }

    #[test]
    fn as_usize_guards() {
        assert_eq!(Json::Num(5.0).as_usize(), Some(5));
        assert_eq!(Json::Num(5.5).as_usize(), None);
        assert_eq!(Json::Num(-1.0).as_usize(), None);
    }

    #[test]
    fn req_reports_missing_key() {
        let v = Json::parse("{}").unwrap();
        assert!(v.req("port").unwrap_err().contains("port"));
    }
}
