//! Configuration: a minimal JSON parser (serde is not vendored in this
//! offline image) plus the typed config structs for models and the
//! serving runtime.

pub mod json;
pub mod runtime_config;

pub use json::Json;
pub use runtime_config::{EngineChoice, RuntimeConfig};
