//! Bfloat16 (BF16) numerics.
//!
//! AMX `tdpbf16ps` consumes BF16 operands and accumulates in FP32; the
//! paper stores weights, inputs, and the KV cache in BF16. This module is
//! the software model of that datatype: truncation from f32 (with
//! round-to-nearest-even, matching AVX-512 `vcvtneps2bf16`) and exact
//! widening back to f32.

/// A bfloat16 value: the top 16 bits of an IEEE-754 f32.
#[derive(Clone, Copy, PartialEq, Default)]
pub struct Bf16(pub u16);

impl Bf16 {
    pub const ZERO: Bf16 = Bf16(0);
    pub const ONE: Bf16 = Bf16(0x3F80);

    /// Convert from f32 with round-to-nearest-even (the hardware behaviour
    /// of `vcvtneps2bf16`; plain truncation loses ~0.5 bit of accuracy).
    #[inline]
    pub fn from_f32(x: f32) -> Self {
        let bits = x.to_bits();
        if x.is_nan() {
            // quiet NaN, preserve sign
            return Bf16(((bits >> 16) as u16) | 0x0040);
        }
        let round_bit = 0x0000_8000u32;
        let lsb = (bits >> 16) & 1;
        let rounded = bits.wrapping_add(0x0000_7FFF + lsb);
        let _ = round_bit;
        Bf16((rounded >> 16) as u16)
    }

    /// Exact widening conversion back to f32.
    #[inline]
    pub fn to_f32(self) -> f32 {
        f32::from_bits((self.0 as u32) << 16)
    }

    /// Reinterpret raw bits.
    #[inline]
    pub fn from_bits(b: u16) -> Self {
        Bf16(b)
    }

    #[inline]
    pub fn to_bits(self) -> u16 {
        self.0
    }

    #[inline]
    pub fn is_zero(self) -> bool {
        self.0 & 0x7FFF == 0
    }
}

impl std::fmt::Debug for Bf16 {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}bf", self.to_f32())
    }
}

impl From<f32> for Bf16 {
    fn from(x: f32) -> Self {
        Bf16::from_f32(x)
    }
}

impl From<Bf16> for f32 {
    fn from(x: Bf16) -> f32 {
        x.to_f32()
    }
}

/// Convert a slice of f32 to BF16 (used when packing weights).
pub fn vec_from_f32(xs: &[f32]) -> Vec<Bf16> {
    xs.iter().map(|&x| Bf16::from_f32(x)).collect()
}

/// Convert a slice of BF16 back to f32.
pub fn vec_to_f32(xs: &[Bf16]) -> Vec<f32> {
    xs.iter().map(|x| x.to_f32()).collect()
}

/// Round a f32 through BF16 precision (simulates storing + reloading).
#[inline]
pub fn round_f32(x: f32) -> f32 {
    Bf16::from_f32(x).to_f32()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exact_values_roundtrip() {
        for &x in &[0.0f32, 1.0, -1.0, 0.5, 2.0, -3.25, 65280.0] {
            assert_eq!(Bf16::from_f32(x).to_f32(), x, "{x} should be exact in bf16");
        }
    }

    #[test]
    fn rounding_is_nearest_even() {
        // 1.0 + 2^-9 is exactly halfway between two bf16 values around 1.0;
        // nearest-even rounds down to 1.0 (even mantissa).
        let halfway = f32::from_bits(0x3F80_8000);
        assert_eq!(Bf16::from_f32(halfway).to_f32(), 1.0);
        // Just above halfway rounds up.
        let above = f32::from_bits(0x3F80_8001);
        assert_eq!(Bf16::from_f32(above).to_f32(), f32::from_bits(0x3F81_0000));
    }

    #[test]
    fn relative_error_bounded() {
        let mut g = crate::util::XorShift::new(123);
        for _ in 0..10_000 {
            let x = (g.next_f32() - 0.5) * 100.0;
            if x == 0.0 {
                continue;
            }
            let y = round_f32(x);
            let rel = ((x - y) / x).abs();
            assert!(rel <= 1.0 / 256.0 + 1e-7, "x={x} y={y} rel={rel}");
        }
    }

    #[test]
    fn nan_and_inf() {
        assert!(Bf16::from_f32(f32::NAN).to_f32().is_nan());
        assert_eq!(Bf16::from_f32(f32::INFINITY).to_f32(), f32::INFINITY);
        assert_eq!(Bf16::from_f32(f32::NEG_INFINITY).to_f32(), f32::NEG_INFINITY);
    }

    #[test]
    fn is_zero_covers_negative_zero() {
        assert!(Bf16::from_f32(0.0).is_zero());
        assert!(Bf16::from_f32(-0.0).is_zero());
        assert!(!Bf16::from_f32(1e-3).is_zero());
    }

    #[test]
    fn vec_roundtrip() {
        let xs = vec![0.25f32, -8.0, 3.0, 0.0];
        assert_eq!(vec_to_f32(&vec_from_f32(&xs)), xs);
    }
}
