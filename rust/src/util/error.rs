//! Minimal `anyhow`-style error handling (the build is fully offline, so
//! `anyhow` itself is not available; this module provides the subset the
//! crate uses with the same call-site syntax).
//!
//! * [`Error`] — an opaque, context-carrying error.
//! * [`Result`] — `Result<T, Error>` alias with a defaulted error type.
//! * [`crate::anyhow!`] / [`crate::bail!`] / [`crate::ensure!`] — the
//!   familiar constructor macros (re-exported here so
//!   `use crate::util::error::{anyhow, bail}` works like the crate they
//!   replace).
//! * [`Context`] — `.context(..)` / `.with_context(..)` on `Result` and
//!   `Option`.

use std::fmt;

/// An opaque error: a message plus the chain of contexts wrapped around
/// it, newest first (exactly how `anyhow` renders with `{:#}`).
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Construct from a message.
    pub fn msg(m: impl fmt::Display) -> Error {
        Error {
            chain: vec![m.to_string()],
        }
    }

    /// Wrap with an outer context message.
    pub fn context(mut self, c: impl fmt::Display) -> Error {
        self.chain.insert(0, c.to_string());
        self
    }

    /// The outermost message.
    pub fn root(&self) -> &str {
        self.chain.first().map(String::as_str).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.join(": "))
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.join(": "))
    }
}

impl<E: std::error::Error> From<E> for Error {
    fn from(e: E) -> Error {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

/// `Result` with the crate's [`Error`] as the default error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Construct an [`Error`] from a format string or any `Display` value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::util::error::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::util::error::Error::msg(format!("{}", $err))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::util::error::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Return early with an [`Error`].
#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => {
        return Err($crate::anyhow!($($t)*))
    };
}

/// Return early with an [`Error`] unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($t:tt)*) => {
        if !($cond) {
            $crate::bail!($($t)*);
        }
    };
}

pub use crate::{anyhow, bail, ensure};

/// `.context(..)` / `.with_context(..)` for `Result` and `Option`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.map_err(|e| Error::msg(e).context(c))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::msg(e).context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(c))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing file")
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        let e = inner().unwrap_err();
        assert!(e.to_string().contains("missing file"));
    }

    #[test]
    fn context_wraps_outside_in() {
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.context("loading weights").unwrap_err();
        assert_eq!(e.to_string(), "loading weights: missing file");
        assert_eq!(e.root(), "loading weights");
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        assert!(v.context("no value").is_err());
        assert_eq!(Some(3u32).context("unused").unwrap(), 3);
    }

    #[test]
    fn macros_build_errors() {
        let name = "q_proj";
        let e = anyhow!("missing param {name}");
        assert_eq!(e.to_string(), "missing param q_proj");
        let from_string: Error = anyhow!(String::from("plain"));
        assert_eq!(from_string.to_string(), "plain");

        fn bails(flag: bool) -> Result<u32> {
            ensure!(flag, "flag was {flag}");
            Ok(1)
        }
        assert!(bails(false).is_err());
        assert_eq!(bails(true).unwrap(), 1);
    }
}
