//! Leveled stderr logging with a monotonic timestamp.

use std::sync::atomic::{AtomicU8, Ordering};
use std::time::Instant;

/// Log severity.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Debug = 0,
    Info = 1,
    Warn = 2,
    Error = 3,
}

static LEVEL: AtomicU8 = AtomicU8::new(Level::Info as u8);

/// Set the global log level.
pub fn set_level(level: Level) {
    LEVEL.store(level as u8, Ordering::Relaxed);
}

/// Current global log level.
pub fn level() -> Level {
    match LEVEL.load(Ordering::Relaxed) {
        0 => Level::Debug,
        1 => Level::Info,
        2 => Level::Warn,
        _ => Level::Error,
    }
}

fn start() -> Instant {
    use std::sync::OnceLock;
    static START: OnceLock<Instant> = OnceLock::new();
    *START.get_or_init(Instant::now)
}

/// Emit a log line if `lvl` passes the global filter.
pub fn log(lvl: Level, module: &str, msg: std::fmt::Arguments<'_>) {
    if lvl < level() {
        return;
    }
    let t = start().elapsed().as_secs_f64();
    let tag = match lvl {
        Level::Debug => "DEBUG",
        Level::Info => "INFO ",
        Level::Warn => "WARN ",
        Level::Error => "ERROR",
    };
    eprintln!("[{t:9.3}s {tag} {module}] {msg}");
}

#[macro_export]
macro_rules! log_info {
    ($($arg:tt)*) => { $crate::util::log::log($crate::util::log::Level::Info, module_path!(), format_args!($($arg)*)) };
}

#[macro_export]
macro_rules! log_warn {
    ($($arg:tt)*) => { $crate::util::log::log($crate::util::log::Level::Warn, module_path!(), format_args!($($arg)*)) };
}

#[macro_export]
macro_rules! log_debug {
    ($($arg:tt)*) => { $crate::util::log::log($crate::util::log::Level::Debug, module_path!(), format_args!($($arg)*)) };
}

#[macro_export]
macro_rules! log_error {
    ($($arg:tt)*) => { $crate::util::log::log($crate::util::log::Level::Error, module_path!(), format_args!($($arg)*)) };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_roundtrip() {
        let old = level();
        set_level(Level::Warn);
        assert_eq!(level(), Level::Warn);
        set_level(Level::Debug);
        assert_eq!(level(), Level::Debug);
        set_level(old);
    }

    #[test]
    fn ordering_matches_severity() {
        assert!(Level::Debug < Level::Info);
        assert!(Level::Info < Level::Warn);
        assert!(Level::Warn < Level::Error);
    }
}
