//! A small fixed-size thread pool with scoped parallel-for.
//!
//! The paper's kernels parallelize over output columns with a *fixed*
//! thread count chosen at model-load time (the `weight_value_index`
//! partitioning bakes the count in). This pool mirrors that contract: the
//! worker count is fixed at construction, and `parallel_for` dispatches
//! index ranges to the workers.
//!
//! rayon is not vendored in this offline image, so this is a minimal
//! std-only implementation built on `std::thread::scope`.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

/// Fixed-size pool. Workers are spawned per `parallel_for` call using
/// scoped threads, which keeps the API simple and borrows safe; on the
/// 1-core CI container thread reuse would not be measurable anyway, and
/// the simulated-core experiments never spawn real threads.
#[derive(Clone, Debug)]
pub struct ThreadPool {
    threads: usize,
}

impl ThreadPool {
    /// Create a pool with `threads` workers (minimum 1).
    pub fn new(threads: usize) -> Self {
        ThreadPool {
            threads: threads.max(1),
        }
    }

    /// Number of worker threads.
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Run `f(i)` for every `i in 0..n`, work-stealing via an atomic
    /// cursor. `f` must be `Sync` because all workers share it.
    pub fn parallel_for<F>(&self, n: usize, f: F)
    where
        F: Fn(usize) + Sync,
    {
        if n == 0 {
            return;
        }
        if self.threads == 1 || n == 1 {
            for i in 0..n {
                f(i);
            }
            return;
        }
        let cursor = Arc::new(AtomicUsize::new(0));
        let workers = self.threads.min(n);
        std::thread::scope(|s| {
            for _ in 0..workers {
                let cursor = Arc::clone(&cursor);
                let f = &f;
                s.spawn(move || loop {
                    let i = cursor.fetch_add(1, Ordering::Relaxed);
                    if i >= n {
                        break;
                    }
                    f(i);
                });
            }
        });
    }

    /// Map `f` over `0..n` collecting results in order.
    pub fn parallel_map<T, F>(&self, n: usize, f: F) -> Vec<T>
    where
        T: Send + Default + Clone,
        F: Fn(usize) -> T + Sync,
    {
        let mut out = vec![T::default(); n];
        {
            let slots: Vec<std::sync::Mutex<&mut T>> =
                out.iter_mut().map(std::sync::Mutex::new).collect();
            self.parallel_for(n, |i| {
                **slots[i].lock().expect("slot lock") = f(i);
            });
        }
        out
    }
}

/// Partition `n` items into `parts` contiguous ranges, sizes differing by
/// at most one. Used both by the pool and by the sparse-format thread
/// partitioner (Figure 9 of the paper).
pub fn partition_ranges(n: usize, parts: usize) -> Vec<std::ops::Range<usize>> {
    let parts = parts.max(1);
    let base = n / parts;
    let extra = n % parts;
    let mut out = Vec::with_capacity(parts);
    let mut start = 0;
    for p in 0..parts {
        let len = base + usize::from(p < extra);
        out.push(start..start + len);
        start += len;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicU64, Ordering};

    #[test]
    fn parallel_for_visits_every_index_once() {
        let pool = ThreadPool::new(4);
        let hits: Vec<AtomicU64> = (0..100).map(|_| AtomicU64::new(0)).collect();
        pool.parallel_for(100, |i| {
            hits[i].fetch_add(1, Ordering::SeqCst);
        });
        assert!(hits.iter().all(|h| h.load(Ordering::SeqCst) == 1));
    }

    #[test]
    fn parallel_map_preserves_order() {
        let pool = ThreadPool::new(3);
        let v = pool.parallel_map(50, |i| i * i);
        assert_eq!(v, (0..50).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn single_thread_pool_works() {
        let pool = ThreadPool::new(1);
        let v = pool.parallel_map(10, |i| i + 1);
        assert_eq!(v[9], 10);
    }

    #[test]
    fn zero_items_is_noop() {
        ThreadPool::new(2).parallel_for(0, |_| panic!("should not run"));
    }

    #[test]
    fn partition_ranges_cover_exactly() {
        for n in [0usize, 1, 7, 16, 100] {
            for parts in [1usize, 2, 3, 8, 32] {
                let rs = partition_ranges(n, parts);
                assert_eq!(rs.len(), parts);
                let total: usize = rs.iter().map(|r| r.len()).sum();
                assert_eq!(total, n);
                // contiguous and ordered
                let mut pos = 0;
                for r in &rs {
                    assert_eq!(r.start, pos);
                    pos = r.end;
                }
                // balanced within 1
                let lens: Vec<usize> = rs.iter().map(|r| r.len()).collect();
                let (mn, mx) = (lens.iter().min().unwrap(), lens.iter().max().unwrap());
                assert!(mx - mn <= 1);
            }
        }
    }
}
