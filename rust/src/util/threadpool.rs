//! A small fixed-size thread pool with parallel-for — now a thin shim
//! over the persistent [`crate::shard::WorkerPool`].
//!
//! The paper's kernels parallelize over output columns with a *fixed*
//! thread count chosen at model-load time (the `weight_value_index`
//! partitioning bakes the count in). Workers are spawned once at
//! construction and live until the last clone drops: each
//! `parallel_for`/`parallel_map` call dispatches one epoch onto the
//! shared [`crate::shard::WorkerPool`] mailboxes — a wakeup, not a
//! thread spawn — so per-token hot paths can call into the pool freely.
//! Clones share the same workers.

use std::sync::Arc;

/// Fixed-size pool over persistent workers. `Clone` shares the workers.
#[derive(Clone)]
pub struct ThreadPool {
    pool: Arc<crate::shard::WorkerPool>,
}

impl ThreadPool {
    /// Create a pool with `threads` workers (minimum 1). Workers are
    /// spawned here, once, and live until the last clone drops.
    pub fn new(threads: usize) -> Self {
        ThreadPool {
            pool: Arc::new(crate::shard::WorkerPool::new(threads)),
        }
    }

    /// Number of worker threads.
    pub fn threads(&self) -> usize {
        self.pool.workers()
    }

    /// Run `f(i)` for every `i in 0..n`, work-stealing via an atomic
    /// cursor. `f` must be `Sync` because all workers share it.
    pub fn parallel_for<F>(&self, n: usize, f: F)
    where
        F: Fn(usize) + Sync,
    {
        self.pool.parallel_for(n, f);
    }

    /// Map `f` over `0..n` collecting results in order.
    pub fn parallel_map<T, F>(&self, n: usize, f: F) -> Vec<T>
    where
        T: Send + Default + Clone,
        F: Fn(usize) -> T + Sync,
    {
        self.pool.parallel_map(n, f)
    }
}

impl std::fmt::Debug for ThreadPool {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "ThreadPool({} threads)", self.threads())
    }
}

/// Partition `n` items into `parts` contiguous ranges, sizes differing by
/// at most one. Used by the pool, the sparse-format thread partitioner
/// (Figure 9 of the paper), and the shard planner.
pub fn partition_ranges(n: usize, parts: usize) -> Vec<std::ops::Range<usize>> {
    let parts = parts.max(1);
    let base = n / parts;
    let extra = n % parts;
    let mut out = Vec::with_capacity(parts);
    let mut start = 0;
    for p in 0..parts {
        let len = base + usize::from(p < extra);
        out.push(start..start + len);
        start += len;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicU64, Ordering};

    #[test]
    fn parallel_for_visits_every_index_once() {
        let pool = ThreadPool::new(4);
        let hits: Vec<AtomicU64> = (0..100).map(|_| AtomicU64::new(0)).collect();
        pool.parallel_for(100, |i| {
            hits[i].fetch_add(1, Ordering::SeqCst);
        });
        assert!(hits.iter().all(|h| h.load(Ordering::SeqCst) == 1));
    }

    #[test]
    fn parallel_map_preserves_order() {
        let pool = ThreadPool::new(3);
        let v = pool.parallel_map(50, |i| i * i);
        assert_eq!(v, (0..50).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn single_thread_pool_works() {
        let pool = ThreadPool::new(1);
        let v = pool.parallel_map(10, |i| i + 1);
        assert_eq!(v[9], 10);
    }

    #[test]
    fn zero_items_is_noop() {
        ThreadPool::new(2).parallel_for(0, |_| panic!("should not run"));
    }

    #[test]
    fn clones_share_the_same_workers() {
        let pool = ThreadPool::new(2);
        let other = pool.clone();
        pool.parallel_for(8, |_| {});
        other.parallel_for(8, |_| {});
        assert_eq!(pool.threads(), other.threads());
        // both calls ran as epochs of ONE shared pool
        assert_eq!(Arc::strong_count(&pool.pool), 2);
    }

    #[test]
    fn partition_ranges_cover_exactly() {
        for n in [0usize, 1, 7, 16, 100] {
            for parts in [1usize, 2, 3, 8, 32] {
                let rs = partition_ranges(n, parts);
                assert_eq!(rs.len(), parts);
                let total: usize = rs.iter().map(|r| r.len()).sum();
                assert_eq!(total, n);
                // contiguous and ordered
                let mut pos = 0;
                for r in &rs {
                    assert_eq!(r.start, pos);
                    pos = r.end;
                }
                // balanced within 1
                let lens: Vec<usize> = rs.iter().map(|r| r.len()).collect();
                let (mn, mx) = (lens.iter().min().unwrap(), lens.iter().max().unwrap());
                assert!(mx - mn <= 1);
            }
        }
    }
}
