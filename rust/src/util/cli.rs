//! Minimal CLI argument parser (clap is not vendored offline).
//!
//! Supports `command --flag value --bool-flag positional` style used by
//! the `sparamx` binary and the examples.

use std::collections::BTreeMap;

/// Parsed arguments: a subcommand, `--key value` options, bare `--switch`
/// flags, and positionals.
#[derive(Debug, Default, Clone)]
pub struct Args {
    pub command: Option<String>,
    pub options: BTreeMap<String, String>,
    pub switches: Vec<String>,
    pub positional: Vec<String>,
}

impl Args {
    /// Parse from an iterator of argument strings (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(args: I) -> Args {
        let mut out = Args::default();
        let mut iter = args.into_iter().peekable();
        while let Some(a) = iter.next() {
            if let Some(name) = a.strip_prefix("--") {
                if let Some((k, v)) = name.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if iter
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = iter.next().expect("peeked");
                    out.options.insert(name.to_string(), v);
                } else {
                    out.switches.push(name.to_string());
                }
            } else if out.command.is_none() && out.positional.is_empty() {
                out.command = Some(a);
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    /// Parse from the process environment.
    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    /// String option with default.
    pub fn get(&self, key: &str, default: &str) -> String {
        self.options
            .get(key)
            .cloned()
            .unwrap_or_else(|| default.to_string())
    }

    /// Typed option with default; panics with a clear message on a
    /// malformed value (CLI misuse should fail loudly).
    pub fn get_parse<T: std::str::FromStr>(&self, key: &str, default: T) -> T
    where
        T::Err: std::fmt::Debug,
    {
        match self.options.get(key) {
            None => default,
            Some(v) => v
                .parse()
                .unwrap_or_else(|e| panic!("--{key}={v}: invalid value: {e:?}")),
        }
    }

    /// Whether a bare `--switch` was passed.
    pub fn has(&self, switch: &str) -> bool {
        self.switches.iter().any(|s| s == switch)
    }

    /// The `--backend {auto,amx,avx,ref}` directive shared by the
    /// `sparamx` binary and the examples; defaults to `auto` (registry
    /// selection). Panics with the accepted spellings on a bad value.
    pub fn backend(&self) -> crate::backend::BackendChoice {
        match self.options.get("backend") {
            None => crate::backend::BackendChoice::Auto,
            Some(v) => v
                .parse()
                .unwrap_or_else(|e: String| panic!("--backend={v}: {e}")),
        }
    }

    /// The `--engine {auto,native,pjrt}` serving-path directive;
    /// defaults to `auto` (native unless PJRT is explicitly requested).
    /// Panics with the accepted spellings on a bad value.
    pub fn engine(&self) -> crate::cfg::EngineChoice {
        match self.options.get("engine") {
            None => crate::cfg::EngineChoice::Auto,
            Some(v) => v
                .parse()
                .unwrap_or_else(|e: String| panic!("--engine={v}: {e}")),
        }
    }

    /// The `--shards {auto,N}` sharding directive; defaults to `auto`
    /// (one shard per detected NUMA node — off on single-node hosts).
    /// Panics with the accepted spellings on a bad value.
    pub fn shards(&self) -> crate::shard::ShardChoice {
        match self.options.get("shards") {
            None => crate::shard::ShardChoice::Auto,
            Some(v) => v
                .parse()
                .unwrap_or_else(|e: String| panic!("--shards={v}: {e}")),
        }
    }

    /// The `--max-batch-fuse {auto,N}` fused-decode directive; defaults
    /// to `auto` (fuse up to the engine's `max_batch`; 1 disables
    /// fusion). Panics with the accepted spellings on a bad value.
    pub fn max_batch_fuse(&self) -> crate::models::BatchFuseChoice {
        match self.options.get("max-batch-fuse") {
            None => crate::models::BatchFuseChoice::Auto,
            Some(v) => v
                .parse()
                .unwrap_or_else(|e: String| panic!("--max-batch-fuse={v}: {e}")),
        }
    }

    /// The `--faults SPEC` deterministic fault-injection directive;
    /// empty by default (no injection; the `SPARAMX_FAULTS` env var
    /// fills in when empty). Panics with the grammar error on a bad
    /// spec — a mistyped schedule should fail at startup, not silently
    /// run fault-free.
    pub fn faults(&self) -> String {
        if let Some(v) = self.options.get("faults") {
            if let Err(e) = v.parse::<crate::fault::FaultPlan>() {
                panic!("--faults={v}: {e}");
            }
            return v.clone();
        }
        String::new()
    }

    /// The `--checkpoint PATH` crash-consistency directive: non-empty
    /// enables periodic slot snapshots to PATH plus restore-on-startup
    /// from the same path. Empty (the default) disables both.
    pub fn checkpoint(&self) -> String {
        self.get("checkpoint", "")
    }

    /// The `--checkpoint-every-steps N` snapshot cadence (default 16
    /// productive decode steps). Panics on 0 or a malformed value — a
    /// zero cadence is a config error, not "every step".
    pub fn checkpoint_every_steps(&self) -> u64 {
        let n = self.get_parse::<u64>("checkpoint-every-steps", 16);
        if n == 0 {
            panic!("--checkpoint-every-steps=0: must be >= 1");
        }
        n
    }

    /// Comma-separated list option, e.g. `--cores 8,16,32`.
    pub fn get_list<T: std::str::FromStr>(&self, key: &str, default: &[T]) -> Vec<T>
    where
        T: Clone,
        T::Err: std::fmt::Debug,
    {
        match self.options.get(key) {
            None => default.to_vec(),
            Some(v) => v
                .split(',')
                .filter(|s| !s.is_empty())
                .map(|s| {
                    s.trim()
                        .parse()
                        .unwrap_or_else(|e| panic!("--{key}: bad item {s:?}: {e:?}"))
                })
                .collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn subcommand_and_options() {
        let a = parse("serve --port 7070 --model artifacts --verbose");
        assert_eq!(a.command.as_deref(), Some("serve"));
        assert_eq!(a.get("port", "0"), "7070");
        assert_eq!(a.get("model", ""), "artifacts");
        assert!(a.has("verbose"));
        assert!(!a.has("quiet"));
    }

    #[test]
    fn equals_syntax() {
        let a = parse("bench --sparsity=0.5");
        assert_eq!(a.get_parse::<f64>("sparsity", 0.0), 0.5);
    }

    #[test]
    fn typed_defaults() {
        let a = parse("run");
        assert_eq!(a.get_parse::<u32>("iters", 10), 10);
    }

    #[test]
    fn list_option() {
        let a = parse("sweep --cores 8,16,32");
        assert_eq!(a.get_list::<usize>("cores", &[1]), vec![8, 16, 32]);
        assert_eq!(a.get_list::<usize>("absent", &[4]), vec![4]);
    }

    #[test]
    fn positionals_after_command() {
        let a = parse("generate hello world");
        assert_eq!(a.command.as_deref(), Some("generate"));
        assert_eq!(a.positional, vec!["hello", "world"]);
    }

    #[test]
    #[should_panic(expected = "invalid value")]
    fn malformed_typed_option_panics() {
        let a = parse("x --iters abc");
        let _ = a.get_parse::<u32>("iters", 1);
    }

    #[test]
    fn backend_flag_parses_with_auto_default() {
        use crate::backend::BackendChoice;
        assert_eq!(parse("run").backend(), BackendChoice::Auto);
        assert_eq!(parse("run --backend amx").backend(), BackendChoice::Amx);
        assert_eq!(parse("run --backend=ref").backend(), BackendChoice::Reference);
    }

    #[test]
    #[should_panic(expected = "unknown backend")]
    fn backend_flag_rejects_unknown() {
        let _ = parse("run --backend mkl").backend();
    }

    #[test]
    fn shards_flag_parses_with_auto_default() {
        use crate::shard::ShardChoice;
        assert_eq!(parse("run").shards(), ShardChoice::Auto);
        assert_eq!(parse("run --shards auto").shards(), ShardChoice::Auto);
        assert_eq!(parse("run --shards 4").shards(), ShardChoice::Fixed(4));
        assert_eq!(parse("serve --shards=2").shards(), ShardChoice::Fixed(2));
    }

    #[test]
    #[should_panic(expected = "unknown shards value")]
    fn shards_flag_rejects_unknown() {
        let _ = parse("run --shards many").shards();
    }

    #[test]
    fn max_batch_fuse_flag_parses_with_auto_default() {
        use crate::models::BatchFuseChoice;
        assert_eq!(parse("run").max_batch_fuse(), BatchFuseChoice::Auto);
        assert_eq!(
            parse("run --max-batch-fuse auto").max_batch_fuse(),
            BatchFuseChoice::Auto
        );
        assert_eq!(
            parse("serve --max-batch-fuse=8").max_batch_fuse(),
            BatchFuseChoice::Fixed(8)
        );
    }

    #[test]
    #[should_panic(expected = "unknown max-batch-fuse value")]
    fn max_batch_fuse_flag_rejects_unknown() {
        let _ = parse("run --max-batch-fuse lots").max_batch_fuse();
    }

    #[test]
    fn faults_flag_parses_with_empty_default() {
        assert!(parse("serve").faults().is_empty());
        let spec = "kernel_fail@backend=amx,call=50";
        assert_eq!(parse(&format!("serve --faults {spec}")).faults(), spec);
    }

    #[test]
    #[should_panic(expected = "--faults=")]
    fn faults_flag_rejects_bad_grammar() {
        let _ = parse("serve --faults explode_now").faults();
    }

    #[test]
    fn checkpoint_flags_parse_with_defaults() {
        assert!(parse("serve").checkpoint().is_empty());
        assert_eq!(parse("serve").checkpoint_every_steps(), 16);
        let a = parse("serve --checkpoint /tmp/s.spxc --checkpoint-every-steps 4");
        assert_eq!(a.checkpoint(), "/tmp/s.spxc");
        assert_eq!(a.checkpoint_every_steps(), 4);
    }

    #[test]
    #[should_panic(expected = "must be >= 1")]
    fn checkpoint_cadence_rejects_zero() {
        let _ = parse("serve --checkpoint-every-steps 0").checkpoint_every_steps();
    }

    #[test]
    fn engine_flag_parses_with_auto_default() {
        use crate::cfg::EngineChoice;
        assert_eq!(parse("run").engine(), EngineChoice::Auto);
        assert_eq!(parse("run --engine native").engine(), EngineChoice::Native);
        assert_eq!(parse("run --engine=pjrt").engine(), EngineChoice::Pjrt);
    }

    #[test]
    #[should_panic(expected = "unknown engine")]
    fn engine_flag_rejects_unknown() {
        let _ = parse("run --engine tpu").engine();
    }
}
