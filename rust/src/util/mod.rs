//! Shared substrates: PRNG, bf16 numerics, statistics, a scoped thread
//! pool, a tiny CLI argument parser, leveled logging, and error handling.
//!
//! These exist because the build is fully offline: no crates are vendored,
//! so the usual ecosystem pieces (rand, half, rayon, clap, criterion,
//! anyhow) are reimplemented here at the scale this project needs.

pub mod prng;
pub mod bf16;
pub mod error;
pub mod stats;
pub mod threadpool;
pub mod cli;
pub mod log;

pub use bf16::Bf16;
pub use prng::XorShift;
