//! Shared substrates: PRNG, bf16 numerics, statistics, a scoped thread
//! pool, a tiny CLI argument parser, and leveled logging.
//!
//! These exist because the build is fully offline: the only vendored crates
//! are `xla` and `anyhow`, so the usual ecosystem pieces (rand, half,
//! rayon, clap, criterion) are reimplemented here at the scale this
//! project needs.

pub mod prng;
pub mod bf16;
pub mod stats;
pub mod threadpool;
pub mod cli;
pub mod log;

pub use bf16::Bf16;
pub use prng::XorShift;
