//! Summary statistics for benchmark and serving-latency reporting.

/// Summary of a sample of measurements (times in seconds, or any unit).
#[derive(Clone, Debug, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub p50: f64,
    pub p90: f64,
    pub p99: f64,
    pub max: f64,
}

impl Summary {
    /// Compute a summary from raw samples. Panics on an empty sample.
    pub fn from(samples: &[f64]) -> Summary {
        assert!(!samples.is_empty(), "empty sample");
        let mut sorted = samples.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).expect("NaN in samples"));
        let n = sorted.len();
        let mean = sorted.iter().sum::<f64>() / n as f64;
        let var = sorted.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>()
            / n.max(2) as f64;
        Summary {
            n,
            mean,
            std: var.sqrt(),
            min: sorted[0],
            p50: percentile_sorted(&sorted, 0.50),
            p90: percentile_sorted(&sorted, 0.90),
            p99: percentile_sorted(&sorted, 0.99),
            max: sorted[n - 1],
        }
    }
}

/// Linear-interpolated percentile of an already-sorted slice.
pub fn percentile_sorted(sorted: &[f64], q: f64) -> f64 {
    assert!(!sorted.is_empty());
    assert!((0.0..=1.0).contains(&q));
    if sorted.len() == 1 {
        return sorted[0];
    }
    let pos = q * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    let frac = pos - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

/// Geometric mean (used for the Fig 14 downstream-accuracy aggregate).
pub fn geomean(xs: &[f64]) -> f64 {
    assert!(!xs.is_empty());
    let log_sum: f64 = xs.iter().map(|&x| x.max(1e-300).ln()).sum();
    (log_sum / xs.len() as f64).exp()
}

/// Throughput in items/sec from a count and elapsed seconds.
pub fn throughput(items: usize, secs: f64) -> f64 {
    if secs <= 0.0 {
        return f64::INFINITY;
    }
    items as f64 / secs
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_of_constant() {
        let s = Summary::from(&[2.0; 10]);
        assert_eq!(s.mean, 2.0);
        assert_eq!(s.p50, 2.0);
        assert_eq!(s.p99, 2.0);
        assert_eq!(s.std, 0.0);
    }

    #[test]
    fn percentiles_interpolate() {
        let sorted: Vec<f64> = (0..=100).map(|i| i as f64).collect();
        assert_eq!(percentile_sorted(&sorted, 0.5), 50.0);
        assert_eq!(percentile_sorted(&sorted, 0.0), 0.0);
        assert_eq!(percentile_sorted(&sorted, 1.0), 100.0);
        assert!((percentile_sorted(&sorted, 0.25) - 25.0).abs() < 1e-9);
    }

    #[test]
    fn summary_orders_unsorted_input() {
        let s = Summary::from(&[5.0, 1.0, 3.0]);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert_eq!(s.p50, 3.0);
    }

    #[test]
    fn geomean_basic() {
        assert!((geomean(&[1.0, 4.0]) - 2.0).abs() < 1e-12);
        assert!((geomean(&[2.0, 2.0, 2.0]) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn throughput_basic() {
        assert_eq!(throughput(100, 2.0), 50.0);
        assert!(throughput(1, 0.0).is_infinite());
    }

    #[test]
    #[should_panic(expected = "empty sample")]
    fn summary_panics_on_empty() {
        let _ = Summary::from(&[]);
    }
}
