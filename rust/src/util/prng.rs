//! Deterministic xorshift128+ PRNG.
//!
//! Every experiment in this repository is seeded so that benches and tests
//! are exactly reproducible run-to-run (the paper's figures are regenerated
//! deterministically; see DESIGN.md §2).

/// xorshift128+ generator (Vigna 2014). Not cryptographic; fast and good
/// enough for weight synthesis, pruning masks, and workload generation.
#[derive(Clone, Debug)]
pub struct XorShift {
    s0: u64,
    s1: u64,
}

impl XorShift {
    /// Create a generator from a seed. A zero seed is remapped so the
    /// state never becomes all-zero (which would be absorbing).
    pub fn new(seed: u64) -> Self {
        let mut x = seed.wrapping_add(0x9E37_79B9_7F4A_7C15);
        // splitmix64 to fill both words from one seed
        let mut next = || {
            x = x.wrapping_add(0x9E37_79B9_7F4A_7C15);
            let mut z = x;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
            z ^ (z >> 31)
        };
        let s0 = next();
        let s1 = next();
        XorShift {
            s0: if s0 == 0 { 1 } else { s0 },
            s1: if s1 == 0 { 2 } else { s1 },
        }
    }

    /// Next raw 64-bit value.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let mut x = self.s0;
        let y = self.s1;
        self.s0 = y;
        x ^= x << 23;
        self.s1 = x ^ y ^ (x >> 17) ^ (y >> 26);
        self.s1.wrapping_add(y)
    }

    /// Next u32.
    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform f32 in [0, 1).
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n). Uses rejection-free Lemire reduction;
    /// the bias for n << 2^64 is negligible for our workloads.
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Standard normal via Box–Muller (one value per call; simple > fast).
    pub fn next_normal(&mut self) -> f32 {
        loop {
            let u1 = self.next_f64();
            if u1 <= f64::MIN_POSITIVE {
                continue;
            }
            let u2 = self.next_f64();
            let r = (-2.0 * u1.ln()).sqrt();
            return (r * (2.0 * std::f64::consts::PI * u2).cos()) as f32;
        }
    }

    /// Vector of standard-normal f32 values scaled by `scale`.
    pub fn normal_vec(&mut self, n: usize, scale: f32) -> Vec<f32> {
        (0..n).map(|_| self.next_normal() * scale).collect()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_same_seed() {
        let mut a = XorShift::new(42);
        let mut b = XorShift::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = XorShift::new(1);
        let mut b = XorShift::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 4);
    }

    #[test]
    fn f32_in_unit_interval() {
        let mut g = XorShift::new(7);
        for _ in 0..10_000 {
            let x = g.next_f32();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_respects_bound_and_covers_range() {
        let mut g = XorShift::new(9);
        let mut seen = [false; 10];
        for _ in 0..10_000 {
            let x = g.below(10);
            assert!(x < 10);
            seen[x] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_has_sane_moments() {
        let mut g = XorShift::new(3);
        let xs = g.normal_vec(20_000, 1.0);
        let mean: f32 = xs.iter().sum::<f32>() / xs.len() as f32;
        let var: f32 =
            xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>() / xs.len() as f32;
        assert!(mean.abs() < 0.05, "mean={mean}");
        assert!((var - 1.0).abs() < 0.1, "var={var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut g = XorShift::new(11);
        let mut v: Vec<u32> = (0..100).collect();
        g.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn zero_seed_is_valid() {
        let mut g = XorShift::new(0);
        assert_ne!(g.next_u64(), 0u64.wrapping_add(g.next_u64()));
    }
}
