//! Per-layer backend plans: the model-load-time compilation step that
//! turns a [`ModelConfig`]'s linear shapes into cached kernel
//! [`Selection`]s and pre-packed operands, so the decode loop never
//! consults the registry or repacks a weight (the paper's
//! "preprocessing happens once", §7).
//!
//! Two levels:
//!
//! * [`plan_model`] — pure shape-level planning over any
//!   [`ModelConfig`]: one [`Selection`] per *distinct* `LinearShape`
//!   (q/k/v/o, gate/up/down, lm_head), resolved once through the
//!   [`BackendRegistry`]. Layers share shapes, so a 32-layer model
//!   computes at most eight selections. This is the per-layer
//!   heterogeneous dispatch of Shen et al. (arXiv:2306.16601) grounded
//!   in the roofline-style cost model (`perf/cost.rs`), as in DECA
//!   (arXiv:2505.19349).
//! * [`DecodePlan::compile`] — binds a shape plan to an actual
//!   [`TinyModel`]'s weights: every projection matrix is packed once
//!   into the operand class its selection chose (bitmap+values sparse
//!   stream or dense tile stream), producing [`PlannedLinear`]s the
//!   native engine dispatches directly.
//!
//! [`NativeModel`] is the serving-side forward built on a compiled
//! plan: batched prefill that also builds the per-(layer, kv-head)
//! [`HeadCache`]s, and a per-token `decode_step` that runs every
//! projection through its planned kernel and attention through
//! [`crate::kvcache::attention::attend_sparse`]. Kernel free functions
//! stay confined to `backend/` and `amx/kernels.rs`; this module only
//! speaks the [`Backend`] handle API.

use crate::amx::EventCounters;
use crate::backend::{
    Backend, BackendChoice, BackendRegistry, Dtype, GemmShape, PackedOperand, Selection,
};
use crate::kvcache::attention::{attend_sparse_batched, attend_sparse_scratched, AttentionScratch};
use crate::kvcache::cache::{layer_head_groups, HeadCache, HeadGroup, KvCache};
use crate::models::llama::{LinearShape, ModelConfig};
use crate::models::tinyforward::{
    add_inplace, rmsnorm_rows, rope_rows_from, silu, treat, TinyModel,
};
use crate::shard::WorkerPool;
use std::collections::HashMap;
use std::sync::Arc;

/// One planned linear shape: the shape plus the load-time selection
/// that every layer instance of this shape shares.
#[derive(Clone, Debug)]
pub struct PlannedShape {
    pub shape: LinearShape,
    pub selection: Selection,
}

/// Shape-level plan for a whole model: per-layer shapes plus the LM
/// head, each bound to a cached [`Selection`].
#[derive(Clone, Debug)]
pub struct ModelPlan {
    /// The seven per-layer linears in [`ModelConfig::layer_linears`]
    /// order (shared by every decoder layer).
    pub per_layer: Vec<PlannedShape>,
    pub lm_head: PlannedShape,
    /// How many distinct selections the registry actually computed —
    /// the cache hit assertion for tests: equals the number of distinct
    /// `(in_features, out_features)` pairs, never `linears_planned`.
    pub selections_computed: usize,
    /// Total linear instances covered (layers × per-layer + head).
    pub linears_planned: usize,
}

impl ModelPlan {
    /// Selection for a named per-layer linear.
    pub fn for_name(&self, name: &str) -> Option<&PlannedShape> {
        if self.lm_head.shape.name == name {
            return Some(&self.lm_head);
        }
        self.per_layer.iter().find(|p| p.shape.name == name)
    }

    /// Human-readable one-plan-per-shape summary for logs/`info`.
    pub fn describe(&self) -> String {
        let mut parts: Vec<String> = self
            .per_layer
            .iter()
            .map(|p| format!("{}={}", p.shape.name, p.selection.describe()))
            .collect();
        parts.push(format!("lm_head={}", self.lm_head.selection.describe()));
        format!(
            "{} ({} selections for {} linears)",
            parts.join(" "),
            self.selections_computed,
            self.linears_planned
        )
    }
}

/// Walk a [`ModelConfig`]'s linear shapes and resolve one [`Selection`]
/// per distinct shape through the registry. `batch` is the decode
/// batch the plan optimizes for (per-slot decode GEMMs run at batch 1);
/// `sparsity` is the weight sparsity the matrices will be pruned to.
///
/// Selection runs here — at model load — and never in the token loop;
/// [`ModelPlan::selections_computed`] counts the registry consultations
/// so tests can assert exactly one per distinct shape.
pub fn plan_model(
    registry: &BackendRegistry,
    choice: BackendChoice,
    model: &ModelConfig,
    batch: usize,
    sparsity: f64,
    dtype: Dtype,
) -> ModelPlan {
    let mut cache: HashMap<(usize, usize, usize), Selection> = HashMap::new();
    let mut computed = 0usize;
    plan_model_cached(
        registry, choice, model, batch, sparsity, dtype, &mut cache, &mut computed,
    )
}

/// [`plan_model`] body over a caller-owned `(shape, batch)` selection
/// cache, so multi-regime compiles share resolutions between regimes
/// whose batches coincide. `computed` ticks once per genuine registry
/// consultation; the returned plan's `selections_computed` counts the
/// distinct shapes in *this* plan (equal to the consultations when the
/// cache starts empty).
#[allow(clippy::too_many_arguments)]
fn plan_model_cached(
    registry: &BackendRegistry,
    choice: BackendChoice,
    model: &ModelConfig,
    batch: usize,
    sparsity: f64,
    dtype: Dtype,
    cache: &mut HashMap<(usize, usize, usize), Selection>,
    computed: &mut usize,
) -> ModelPlan {
    let mut local: HashMap<(usize, usize), Selection> = HashMap::new();
    let mut resolve = |shape: &LinearShape| -> Selection {
        local
            .entry((shape.in_features, shape.out_features))
            .or_insert_with(|| {
                cache
                    .entry((shape.in_features, shape.out_features, batch))
                    .or_insert_with(|| {
                        *computed += 1;
                        registry.resolve(
                            choice,
                            GemmShape::for_linear(shape, batch),
                            sparsity,
                            dtype,
                        )
                    })
                    .clone()
            })
            .clone()
    };
    let per_layer: Vec<PlannedShape> = model
        .layer_linears()
        .iter()
        .map(|l| PlannedShape {
            shape: *l,
            selection: resolve(l),
        })
        .collect();
    let head = model.lm_head();
    let lm_head = PlannedShape {
        selection: resolve(&head),
        shape: head,
    };
    drop(resolve);
    ModelPlan {
        linears_planned: model.layers * per_layer.len() + 1,
        per_layer,
        lm_head,
        selections_computed: local.len(),
    }
}

/// The three serving regimes a compiled plan carries selections for.
/// The regime is picked from live engine state each step (slot count,
/// prefill vs. decode); the *selection per regime* is fixed at compile.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Regime {
    /// Per-slot decode: one token, batch 1.
    DecodeB1,
    /// Fused decode: all active slots gathered into one activation
    /// block, one batched GEMM per projection.
    DecodeFused,
    /// Prompt prefill: one multi-row pass over the prompt.
    Prefill,
}

/// Fused decode batch the default plan compiles for (the runtime
/// config's default `max_batch`).
pub const DEFAULT_FUSED_BATCH: usize = 8;

/// Representative prompt length the default prefill regime prices.
pub const DEFAULT_PREFILL_BATCH: usize = 32;

/// The GEMM batch each regime compiles its selections at. Batch-1
/// decode is always 1; the other two are deployment knobs
/// (`--max-batch-fuse`, prompt-length geometry).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct RegimeBatches {
    pub decode_fused: usize,
    pub prefill: usize,
}

impl Default for RegimeBatches {
    fn default() -> RegimeBatches {
        RegimeBatches {
            decode_fused: DEFAULT_FUSED_BATCH,
            prefill: DEFAULT_PREFILL_BATCH,
        }
    }
}

impl RegimeBatches {
    /// The GEMM batch `r`'s selections are resolved at.
    pub fn batch_of(&self, r: Regime) -> usize {
        match r {
            Regime::DecodeB1 => 1,
            Regime::DecodeFused => self.decode_fused.max(1),
            Regime::Prefill => self.prefill.max(1),
        }
    }
}

/// Environment override for the fused decode batch, mirroring
/// `SPARAMX_SHARDS` (useful in CI, where the matrix sweeps fusion on
/// and off without touching configs).
pub const BATCH_FUSE_ENV: &str = "SPARAMX_BATCH_FUSE";

/// The `--max-batch-fuse {auto,N}` knob: `Auto` fuses up to the
/// engine's `max_batch`; `Fixed(n)` caps the fused-regime batch at `n`
/// (1 disables fusion — every decode step then runs the batch-1
/// regime). The `SPARAMX_BATCH_FUSE` env var overrides at resolve time.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BatchFuseChoice {
    Auto,
    Fixed(usize),
}

impl BatchFuseChoice {
    pub const HELP: &'static str = "auto|N (fused decode batch cap, 1 disables fusion)";

    /// Resolve the fused-regime batch against the engine's `max_batch`,
    /// honoring the `SPARAMX_BATCH_FUSE` environment override. The
    /// result is clamped to `[1, max_batch]` — fusing beyond the
    /// batcher's ceiling would compile a regime no step can reach.
    pub fn resolve(self, max_batch: usize) -> usize {
        if let Ok(v) = std::env::var(BATCH_FUSE_ENV) {
            if let Ok(c) = v.parse::<BatchFuseChoice>() {
                return c.resolve_no_env(max_batch);
            }
        }
        self.resolve_no_env(max_batch)
    }

    fn resolve_no_env(self, max_batch: usize) -> usize {
        match self {
            BatchFuseChoice::Auto => max_batch.max(1),
            BatchFuseChoice::Fixed(n) => n.clamp(1, max_batch.max(1)),
        }
    }
}

impl Default for BatchFuseChoice {
    fn default() -> Self {
        BatchFuseChoice::Auto
    }
}

impl std::str::FromStr for BatchFuseChoice {
    type Err = String;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s.trim().to_ascii_lowercase().as_str() {
            "auto" => Ok(BatchFuseChoice::Auto),
            t => t.parse::<usize>().map(BatchFuseChoice::Fixed).map_err(|_| {
                format!(
                    "unknown max-batch-fuse value '{s}' (expected {})",
                    Self::HELP
                )
            }),
        }
    }
}

impl std::fmt::Display for BatchFuseChoice {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            BatchFuseChoice::Auto => write!(f, "auto"),
            BatchFuseChoice::Fixed(n) => write!(f, "{n}"),
        }
    }
}

/// Shape-level plans for all three regimes, resolved through one shared
/// `(shape, batch)` cache: regimes whose batches coincide (e.g. fusion
/// disabled → fused batch 1) reuse the batch-1 resolutions instead of
/// re-consulting the registry.
pub struct ModelRegimePlans {
    pub decode_b1: ModelPlan,
    pub decode_fused: ModelPlan,
    pub prefill: ModelPlan,
    /// Total distinct `(shape, batch)` registry consultations across
    /// all three regimes.
    pub selections_computed: usize,
    /// The batches the regimes were compiled at.
    pub batches: RegimeBatches,
}

impl ModelRegimePlans {
    /// One line per distinct shape showing the selection each regime
    /// compiled — the dense/sparse crossover table `sparamx info`
    /// prints (the Fig 12 axis: a shape may be sparse at batch 1 and
    /// dense once the fused batch fills the compute side).
    pub fn regime_table(&self) -> String {
        let mut seen: Vec<(usize, usize)> = Vec::new();
        let mut lines = Vec::new();
        for p in self.decode_b1.per_layer.iter().chain([&self.decode_b1.lm_head]) {
            let key = (p.shape.in_features, p.shape.out_features);
            if seen.contains(&key) {
                continue;
            }
            seen.push(key);
            let name = p.shape.name;
            let fused = self
                .decode_fused
                .for_name(name)
                .expect("regimes share shape names");
            let pre = self.prefill.for_name(name).expect("regimes share shape names");
            lines.push(format!(
                "  {name} {}x{}: b1={} fused@{}={} prefill@{}={}",
                key.0,
                key.1,
                p.selection.describe(),
                self.batches.batch_of(Regime::DecodeFused),
                fused.selection.describe(),
                self.batches.batch_of(Regime::Prefill),
                pre.selection.describe(),
            ));
        }
        lines.join("\n")
    }
}

/// Resolve all three regimes' shape plans through one shared cache.
pub fn plan_model_regimes(
    registry: &BackendRegistry,
    choice: BackendChoice,
    model: &ModelConfig,
    batches: RegimeBatches,
    sparsity: f64,
    dtype: Dtype,
) -> ModelRegimePlans {
    let mut cache: HashMap<(usize, usize, usize), Selection> = HashMap::new();
    let mut computed = 0usize;
    let decode_b1 = plan_model_cached(
        registry, choice, model, 1, sparsity, dtype, &mut cache, &mut computed,
    );
    let decode_fused = plan_model_cached(
        registry,
        choice,
        model,
        batches.batch_of(Regime::DecodeFused),
        sparsity,
        dtype,
        &mut cache,
        &mut computed,
    );
    let prefill = plan_model_cached(
        registry,
        choice,
        model,
        batches.batch_of(Regime::Prefill),
        sparsity,
        dtype,
        &mut cache,
        &mut computed,
    );
    ModelRegimePlans {
        decode_b1,
        decode_fused,
        prefill,
        selections_computed: computed,
        batches,
    }
}

/// One serving linear: pre-packed operands + the per-regime selections
/// that chose their kernels. The token loop only ever calls `run` /
/// `run_fused` / `run_prefill` — selection and packing both happened at
/// compile time.
pub struct PlannedLinear {
    pub name: &'static str,
    /// Inner dimension (input features).
    pub rows: usize,
    /// Output features.
    pub cols: usize,
    /// Batch-1 decode-regime selection (what per-slot decode runs).
    pub selection: Selection,
    /// Fused decode-regime selection (multi-slot steps).
    pub fused: Selection,
    /// Prefill-regime selection (multi-row prompt pass).
    pub prefill: Selection,
    operand: Arc<PackedOperand>,
    fused_operand: Arc<PackedOperand>,
    prefill_operand: Arc<PackedOperand>,
}

impl PlannedLinear {
    /// Pack `w` (`rows × cols`, row-major) once per *distinct* operand
    /// class across the three regimes: regimes whose selections agree
    /// on `(backend, use_sparse)` share the packed bytes, so dual-regime
    /// plans don't double the weight footprint unless the regimes
    /// genuinely chose different kernel classes.
    fn pack(
        name: &'static str,
        w: &[f32],
        rows: usize,
        cols: usize,
        b1: Selection,
        fused: Selection,
        prefill: Selection,
    ) -> PlannedLinear {
        debug_assert_eq!(w.len(), rows * cols, "{name}: weight shape mismatch");
        let operand = Arc::new(PackedOperand::pack_f32(
            &b1.backend,
            w,
            rows,
            cols,
            b1.use_sparse,
        ));
        let pack_for = |sel: &Selection,
                        prior: &[(&Selection, &Arc<PackedOperand>)]|
         -> Arc<PackedOperand> {
            for (ps, op) in prior {
                if ps.backend == sel.backend && ps.use_sparse == sel.use_sparse {
                    return Arc::clone(op);
                }
            }
            Arc::new(PackedOperand::pack_f32(
                &sel.backend,
                w,
                rows,
                cols,
                sel.use_sparse,
            ))
        };
        let fused_operand = pack_for(&fused, &[(&b1, &operand)]);
        let prefill_operand = pack_for(&prefill, &[(&b1, &operand), (&fused, &fused_operand)]);
        PlannedLinear {
            name,
            rows,
            cols,
            selection: b1,
            fused,
            prefill,
            operand,
            fused_operand,
            prefill_operand,
        }
    }

    /// Dispatch one batch-1-regime GEMM: `x` is `batch × rows`
    /// row-major, output is `batch × cols`. No selection, no packing —
    /// both happened at compile time.
    pub fn run(&self, x: &[f32], batch: usize, ctr: &mut EventCounters) -> Vec<f32> {
        debug_assert_eq!(x.len(), batch * self.rows, "{}: input shape", self.name);
        self.operand.gemm_bf16(&self.selection.backend, x, batch, ctr)
    }

    /// Fused decode-regime dispatch: one batched GEMM over all active
    /// slots' gathered rows, streaming each weight block once.
    pub fn run_fused(&self, x: &[f32], batch: usize, ctr: &mut EventCounters) -> Vec<f32> {
        debug_assert_eq!(x.len(), batch * self.rows, "{}: input shape", self.name);
        self.fused_operand
            .gemm_bf16_batched(&self.fused.backend, x, batch, ctr)
    }

    /// Prefill-regime dispatch over `batch` prompt positions.
    pub fn run_prefill(&self, x: &[f32], batch: usize, ctr: &mut EventCounters) -> Vec<f32> {
        debug_assert_eq!(x.len(), batch * self.rows, "{}: input shape", self.name);
        self.prefill_operand
            .gemm_bf16_batched(&self.prefill.backend, x, batch, ctr)
    }
}

/// One decoder layer's planned projections.
pub struct LayerPlan {
    pub wq: PlannedLinear,
    pub wk: PlannedLinear,
    pub wv: PlannedLinear,
    pub wo: PlannedLinear,
    pub wgate: PlannedLinear,
    pub wup: PlannedLinear,
    pub wdown: PlannedLinear,
}

/// The compiled serving plan for a loaded model: every projection
/// pre-packed and bound to its selected kernel, plus the backend the
/// attention static segment runs through.
pub struct DecodePlan {
    pub layers: Vec<LayerPlan>,
    pub lm_head: PlannedLinear,
    /// Backend serving the KV static-segment GEMMs in attention (the
    /// kernel class that won the q_proj shape at batch 1).
    pub attention: Backend,
    /// Total distinct `(shape, batch)` registry consultations across
    /// all three regimes, carried over from [`plan_model_regimes`].
    pub selections_computed: usize,
    pub linears_planned: usize,
    /// Fused decode-regime batch this plan compiled for (1 = fusion
    /// disabled; every step then runs the batch-1 regime).
    pub fused_batch: usize,
    /// Prompt length the prefill regime priced.
    pub prefill_batch: usize,
}

impl DecodePlan {
    /// Compile a plan for `model` at the default regime batches.
    pub fn compile(
        registry: &BackendRegistry,
        choice: BackendChoice,
        model: &TinyModel,
        sparsity: f64,
    ) -> DecodePlan {
        DecodePlan::compile_with(registry, choice, model, sparsity, RegimeBatches::default())
    }

    /// Compile a plan for `model` (weights already pruned to
    /// `sparsity`): resolve selections per distinct shape *per regime*
    /// via [`plan_model_regimes`], then pack every projection matrix
    /// once per distinct operand class.
    pub fn compile_with(
        registry: &BackendRegistry,
        choice: BackendChoice,
        model: &TinyModel,
        sparsity: f64,
        batches: RegimeBatches,
    ) -> DecodePlan {
        let mc = model_config_of(model);
        let rp = plan_model_regimes(registry, choice, &mc, batches, sparsity, Dtype::Bf16);
        let sel = |plan: &ModelPlan, name: &str| -> Selection {
            plan.for_name(name)
                .expect("plan_model covers every projection name")
                .selection
                .clone()
        };
        let pack = |name: &'static str, w: &[f32], rows: usize, cols: usize| -> PlannedLinear {
            PlannedLinear::pack(
                name,
                w,
                rows,
                cols,
                sel(&rp.decode_b1, name),
                sel(&rp.decode_fused, name),
                sel(&rp.prefill, name),
            )
        };
        let (h, inter, qd, kvd) = (
            model.hidden,
            model.inter,
            model.heads * model.head_dim,
            model.kv_heads * model.head_dim,
        );
        let layers = model
            .layers
            .iter()
            .map(|l| LayerPlan {
                wq: pack("q_proj", &l.wq, h, qd),
                wk: pack("k_proj", &l.wk, h, kvd),
                wv: pack("v_proj", &l.wv, h, kvd),
                wo: pack("o_proj", &l.wo, qd, h),
                wgate: pack("gate_proj", &l.wgate, h, inter),
                wup: pack("up_proj", &l.wup, h, inter),
                wdown: pack("down_proj", &l.wdown, inter, h),
            })
            .collect();
        DecodePlan {
            layers,
            lm_head: pack("lm_head", &model.lm_head, h, model.vocab),
            attention: rp
                .decode_b1
                .for_name("q_proj")
                .expect("q_proj always planned")
                .selection
                .backend
                .clone(),
            selections_computed: rp.selections_computed,
            linears_planned: rp.decode_b1.linears_planned,
            fused_batch: batches.batch_of(Regime::DecodeFused),
            prefill_batch: batches.batch_of(Regime::Prefill),
        }
    }

    /// Predicted seconds for one full decode step (batch 1): the sum of
    /// every planned linear's load-time `predicted_s` — 7 projections
    /// per layer plus the LM head. Attention and elementwise work are
    /// excluded (memory-bound decode is dominated by the weight
    /// streams, Table 1), so this is a *lower bound* the admission
    /// budget treats as the per-token cost.
    pub fn predicted_step_s(&self) -> f64 {
        let per_layer: f64 = self
            .layers
            .iter()
            .map(|l| {
                [
                    &l.wq, &l.wk, &l.wv, &l.wo, &l.wgate, &l.wup, &l.wdown,
                ]
                .iter()
                .map(|p| p.selection.predicted_s)
                .sum::<f64>()
            })
            .sum();
        per_layer + self.lm_head.selection.predicted_s
    }

    /// Predicted seconds for one fused multi-slot decode step: same sum
    /// as [`DecodePlan::predicted_step_s`] but over the fused-regime
    /// selections, which were priced at `fused_batch` rows. The engine's
    /// deadline sweep prices the *upcoming* step with whichever of the
    /// two matches the regime it is about to run.
    pub fn predicted_fused_step_s(&self) -> f64 {
        let per_layer: f64 = self
            .layers
            .iter()
            .map(|l| {
                [
                    &l.wq, &l.wk, &l.wv, &l.wo, &l.wgate, &l.wup, &l.wdown,
                ]
                .iter()
                .map(|p| p.fused.predicted_s)
                .sum::<f64>()
            })
            .sum();
        per_layer + self.lm_head.fused.predicted_s
    }

    /// Human-readable plan summary for banners/logs.
    pub fn describe(&self) -> String {
        let head = &self.lm_head;
        let first = self.layers.first();
        let layer_desc = first
            .map(|l| {
                format!(
                    "qkv={} mlp={} ",
                    l.wq.selection.describe(),
                    l.wup.selection.describe()
                )
            })
            .unwrap_or_default();
        format!(
            "{layer_desc}head={} ({} selections / {} linears, fused@{}, prefill@{})",
            head.selection.describe(),
            self.selections_computed,
            self.linears_planned,
            self.fused_batch,
            self.prefill_batch
        )
    }

    /// Per-shape regime table (one line per distinct shape) showing the
    /// batch-1, fused, and prefill selections side by side.
    pub fn regime_table(&self) -> String {
        let mut seen: Vec<(usize, usize)> = Vec::new();
        let mut lines = Vec::new();
        let head = [&self.lm_head];
        let linears = self
            .layers
            .first()
            .map(|l| {
                vec![
                    &l.wq, &l.wk, &l.wv, &l.wo, &l.wgate, &l.wup, &l.wdown,
                ]
            })
            .unwrap_or_default()
            .into_iter()
            .chain(head);
        for p in linears {
            let key = (p.rows, p.cols);
            if seen.contains(&key) {
                continue;
            }
            seen.push(key);
            lines.push(format!(
                "  {} {}x{}: b1={} fused@{}={} prefill@{}={}",
                p.name,
                p.rows,
                p.cols,
                p.selection.describe(),
                self.fused_batch,
                p.fused.describe(),
                self.prefill_batch,
                p.prefill.describe(),
            ));
        }
        lines.join("\n")
    }
}

/// Derive the shape config of a loaded tiny-family model (works for the
/// build-time checkpoint and synthetic test models alike).
fn model_config_of(model: &TinyModel) -> ModelConfig {
    ModelConfig {
        name: "native".into(),
        hidden: model.hidden,
        intermediate: model.inter,
        layers: model.layers.len(),
        heads: model.heads,
        kv_heads: model.kv_heads,
        head_dim: model.head_dim,
        vocab: model.vocab,
    }
}

/// The plan-compiled serving model: weights + [`DecodePlan`]. This is
/// the native engine's whole forward surface — prefill builds the
/// per-slot [`KvCache`], `decode_step` serves one token.
pub struct NativeModel {
    pub model: TinyModel,
    pub plan: DecodePlan,
    /// Optional worker pool for scattering independent KV head groups of
    /// the fused attention path across cores. Attention shards by head
    /// group — never by k — so the column-partitioning invariant of the
    /// sharded *linear* backends is untouched. Left `None` (sequential
    /// fused attention) unless the engine wires a pool in.
    attn_pool: Option<Arc<WorkerPool>>,
}

impl NativeModel {
    /// Compile a plan for an already-pruned model at the default regime
    /// batches.
    pub fn new(
        registry: &BackendRegistry,
        choice: BackendChoice,
        model: TinyModel,
        sparsity: f64,
    ) -> NativeModel {
        NativeModel::with_regimes(registry, choice, model, sparsity, RegimeBatches::default())
    }

    /// Compile a plan for an already-pruned model at explicit regime
    /// batches (the engine passes its resolved fuse batch and context
    /// geometry here).
    pub fn with_regimes(
        registry: &BackendRegistry,
        choice: BackendChoice,
        model: TinyModel,
        sparsity: f64,
        batches: RegimeBatches,
    ) -> NativeModel {
        let plan = DecodePlan::compile_with(registry, choice, &model, sparsity, batches);
        NativeModel {
            model,
            plan,
            attn_pool: None,
        }
    }

    /// Wire a worker pool into the fused attention path: independent
    /// (slot, kv-head) groups of `decode_step_batched` scatter across
    /// its workers. Ignored (kept for bit-exactness, see the deadlock
    /// guard in `decode_step_batched`) when the attention backend is
    /// itself sharded — a nested scatter from inside a worker would
    /// deadlock the pool.
    pub fn set_attention_pool(&mut self, pool: Option<Arc<WorkerPool>>) {
        self.attn_pool = pool;
    }

    pub fn vocab(&self) -> usize {
        self.model.vocab
    }

    /// Prefill over `tokens` (the prompt minus its final token): run the
    /// planned forward, build the pruned static KV segment per (layer,
    /// kv-head), and discard the logits (the decode loop produces the
    /// first output from the final prompt token).
    ///
    /// Prompt hidden states use the same per-head-pruned K/V the caches
    /// store, so prefill and decode see one consistent context (§6.1).
    pub fn prefill(
        &self,
        tokens: &[u8],
        k_sparsity: f64,
        v_sparsity: f64,
        ctr: &mut EventCounters,
    ) -> KvCache {
        let m = &self.model;
        let (h_dim, heads, kvh, hd) = (m.hidden, m.heads, m.kv_heads, m.head_dim);
        let s = tokens.len();
        let group = heads / kvh;
        if s == 0 {
            let heads_empty = (0..m.layers.len())
                .map(|_| {
                    (0..kvh)
                        .map(|_| HeadCache::from_prefill(&[], &[], 0, hd, k_sparsity, v_sparsity))
                        .collect()
                })
                .collect();
            return KvCache {
                heads: heads_empty,
                kv_heads: kvh,
            };
        }
        let mut h = vec![0f32; s * h_dim];
        for (t, &tok) in tokens.iter().enumerate() {
            h[t * h_dim..(t + 1) * h_dim]
                .copy_from_slice(&m.emb[tok as usize * h_dim..(tok as usize + 1) * h_dim]);
        }
        let mut cache_layers: Vec<Vec<HeadCache>> = Vec::with_capacity(m.layers.len());
        for (lw, lp) in m.layers.iter().zip(self.plan.layers.iter()) {
            let x = rmsnorm_rows(&h, s, h_dim, &lw.ln1);
            let mut q = lp.wq.run_prefill(&x, s, ctr);
            let mut k = lp.wk.run_prefill(&x, s, ctr);
            let v = lp.wv.run_prefill(&x, s, ctr);
            rope_rows_from(&mut q, s, heads, hd, 0);
            rope_rows_from(&mut k, s, kvh, hd, 0);
            // build this layer's static segment from the post-RoPE K/V
            let mut layer_caches = Vec::with_capacity(kvh);
            for head in 0..kvh {
                let mut kh = Vec::with_capacity(s * hd);
                let mut vh = Vec::with_capacity(s * hd);
                for t in 0..s {
                    kh.extend_from_slice(&k[(t * kvh + head) * hd..(t * kvh + head) * hd + hd]);
                    vh.extend_from_slice(&v[(t * kvh + head) * hd..(t * kvh + head) * hd + hd]);
                }
                layer_caches.push(HeadCache::from_prefill(
                    &kh, &vh, s, hd, k_sparsity, v_sparsity,
                ));
            }
            cache_layers.push(layer_caches);
            // prompt hidden states attend over the pruned K/V (dense
            // causal math — prefill is compute-bound and runs once)
            let kt = treat(&k, s, kvh, hd, k_sparsity, false);
            let vt = treat(&v, s, kvh, hd, v_sparsity, false);
            let mut ctx = vec![0f32; s * heads * hd];
            let scale = 1.0 / (hd as f32).sqrt();
            for qh in 0..heads {
                let khh = qh / group;
                for t in 0..s {
                    let qrow = &q[(t * heads + qh) * hd..(t * heads + qh) * hd + hd];
                    let mut scores = Vec::with_capacity(t + 1);
                    for u in 0..=t {
                        let krow = &kt[(u * kvh + khh) * hd..(u * kvh + khh) * hd + hd];
                        let dot: f32 = qrow.iter().zip(krow).map(|(a, b)| a * b).sum();
                        scores.push(dot * scale);
                    }
                    crate::kvcache::attention::softmax(&mut scores);
                    let out = &mut ctx[(t * heads + qh) * hd..(t * heads + qh) * hd + hd];
                    for (u, &p) in scores.iter().enumerate() {
                        let vrow = &vt[(u * kvh + khh) * hd..(u * kvh + khh) * hd + hd];
                        for d in 0..hd {
                            out[d] += p * vrow[d];
                        }
                    }
                }
            }
            let o = lp.wo.run_prefill(&ctx, s, ctr);
            add_inplace(&mut h, &o);
            let x = rmsnorm_rows(&h, s, h_dim, &lw.ln2);
            let gate = lp.wgate.run_prefill(&x, s, ctr);
            let up = lp.wup.run_prefill(&x, s, ctr);
            let act: Vec<f32> = gate
                .iter()
                .zip(up.iter())
                .map(|(&g, &u)| silu(g) * u)
                .collect();
            let down = lp.wdown.run_prefill(&act, s, ctr);
            add_inplace(&mut h, &down);
        }
        KvCache {
            heads: cache_layers,
            kv_heads: kvh,
        }
    }

    /// One token of plan-driven decode: every projection runs its
    /// pre-selected kernel on its pre-packed operand, attention runs
    /// [`attend_sparse_scratched`] over the slot's cache (sparse static
    /// segment + dense dynamic tail) through one scratch reused across
    /// layers and heads, and the new K/V rows append to the tail.
    /// Returns the next-token logits (`vocab` long).
    pub fn decode_step(
        &self,
        token: u8,
        pos: usize,
        cache: &mut KvCache,
        ctr: &mut EventCounters,
    ) -> Vec<f32> {
        let m = &self.model;
        let (h_dim, heads, kvh, hd) = (m.hidden, m.heads, m.kv_heads, m.head_dim);
        let group = heads / kvh;
        let mut h =
            m.emb[token as usize * h_dim..(token as usize + 1) * h_dim].to_vec();
        // one scratch reused across every (layer, head) attention call:
        // the token loop performs no per-call score allocation
        let mut scratch = AttentionScratch::default();
        for (layer_idx, (lw, lp)) in m.layers.iter().zip(self.plan.layers.iter()).enumerate() {
            let x = rmsnorm_rows(&h, 1, h_dim, &lw.ln1);
            let mut q = lp.wq.run(&x, 1, ctr);
            let mut k = lp.wk.run(&x, 1, ctr);
            let v = lp.wv.run(&x, 1, ctr);
            rope_rows_from(&mut q, 1, heads, hd, pos);
            rope_rows_from(&mut k, 1, kvh, hd, pos);
            // append this token's K/V to the dynamic tail first so
            // attention sees position `pos` (causal self-inclusion)
            for head in 0..kvh {
                cache.heads[layer_idx][head]
                    .append(&k[head * hd..(head + 1) * hd], &v[head * hd..(head + 1) * hd]);
            }
            let mut ctx = vec![0f32; heads * hd];
            for qh in 0..heads {
                let hc = &cache.heads[layer_idx][qh / group];
                attend_sparse_scratched(
                    hc,
                    &q[qh * hd..(qh + 1) * hd],
                    &self.plan.attention,
                    &mut scratch,
                    &mut ctx[qh * hd..(qh + 1) * hd],
                    ctr,
                );
            }
            let o = lp.wo.run(&ctx, 1, ctr);
            add_inplace(&mut h, &o);
            let x = rmsnorm_rows(&h, 1, h_dim, &lw.ln2);
            let gate = lp.wgate.run(&x, 1, ctr);
            let up = lp.wup.run(&x, 1, ctr);
            let act: Vec<f32> = gate
                .iter()
                .zip(up.iter())
                .map(|(&g, &u)| silu(g) * u)
                .collect();
            let down = lp.wdown.run(&act, 1, ctr);
            add_inplace(&mut h, &down);
        }
        let xf = rmsnorm_rows(&h, 1, h_dim, &m.ln_f);
        self.plan.lm_head.run(&xf, 1, ctr)
    }

    /// One fused decode step over `nb` active slots: their hidden states
    /// are gathered into one `nb × hidden` activation block and every
    /// projection runs **one** batched GEMM through the fused-regime
    /// operand, streaming each packed weight block once for the whole
    /// batch instead of once per slot. Attention runs fused per (slot,
    /// kv-head) group whenever `heads / kv_heads > 1`: the group's query
    /// rows go through one batched QKᵀ + R·V pair so the static K/V
    /// segment streams once per step instead of once per query head
    /// (bit-exact vs. the looped path by the PR 7 batched-GEMM
    /// invariant). KV appends stay per-slot (each slot owns its cache
    /// and position). Returns one logits vector per slot, in input
    /// order.
    ///
    /// `tokens`, `positions`, and `caches` are parallel arrays: row `b`
    /// of the activation block belongs to slot `b`.
    pub fn decode_step_batched(
        &self,
        tokens: &[u8],
        positions: &[usize],
        caches: &mut [&mut KvCache],
        ctr: &mut EventCounters,
    ) -> Vec<Vec<f32>> {
        let nb = tokens.len();
        debug_assert_eq!(positions.len(), nb, "positions per slot");
        debug_assert_eq!(caches.len(), nb, "one cache per slot");
        if nb == 0 {
            return Vec::new();
        }
        let m = &self.model;
        let (h_dim, heads, kvh, hd) = (m.hidden, m.heads, m.kv_heads, m.head_dim);
        let group = heads / kvh;
        // gather: one activation block, row per slot
        let mut h = vec![0f32; nb * h_dim];
        for (b, &tok) in tokens.iter().enumerate() {
            h[b * h_dim..(b + 1) * h_dim]
                .copy_from_slice(&m.emb[tok as usize * h_dim..(tok as usize + 1) * h_dim]);
        }
        // one scratch reused across every layer's attention groups
        let mut scratch = AttentionScratch::default();
        for (layer_idx, (lw, lp)) in m.layers.iter().zip(self.plan.layers.iter()).enumerate() {
            let x = rmsnorm_rows(&h, nb, h_dim, &lw.ln1);
            let mut q = lp.wq.run_fused(&x, nb, ctr);
            let mut k = lp.wk.run_fused(&x, nb, ctr);
            let v = lp.wv.run_fused(&x, nb, ctr);
            // RoPE per slot: each row rotates at its own position
            for b in 0..nb {
                let (p, qr) = (positions[b], b * heads * hd);
                rope_rows_from(&mut q[qr..qr + heads * hd], 1, heads, hd, p);
                rope_rows_from(&mut k[b * kvh * hd..(b + 1) * kvh * hd], 1, kvh, hd, p);
            }
            let mut ctx = vec![0f32; nb * heads * hd];
            // append every slot's new K/V row *before* any attention so
            // the fused path sees all tails at position `pos` — bit-exact
            // vs. the interleaved order (each slot's attention only ever
            // reads its own cache, which is fully appended either way)
            for b in 0..nb {
                let kb = &k[b * kvh * hd..(b + 1) * kvh * hd];
                let vb = &v[b * kvh * hd..(b + 1) * kvh * hd];
                for head in 0..kvh {
                    caches[b].heads[layer_idx][head]
                        .append(&kb[head * hd..(head + 1) * hd], &vb[head * hd..(head + 1) * hd]);
                }
            }
            if group > 1 {
                // fused path: the `group` query heads sharing a KV head
                // are contiguous in the q layout, so each (slot, kv-head)
                // group is one `group × hd` activation block — one
                // batched QKᵀ + R·V pair per group streams that group's
                // static K/V segment once per step
                let groups = layer_head_groups(caches, layer_idx);
                let q_off =
                    |g: &HeadGroup| -> usize { (g.slot * heads + g.kv_head * group) * hd };
                // Scatter independent head groups across the worker pool
                // when one is wired in — unless the attention backend is
                // itself sharded (its GEMM would scatter on the same pool
                // from inside a worker and deadlock).
                let scatter = self.attn_pool.as_ref().filter(|_| {
                    groups.len() > 1
                        && self.plan.attention.kind() != crate::backend::BackendKind::Sharded
                });
                if let Some(pool) = scatter {
                    let backend = &self.plan.attention;
                    // Per-worker scratch arena: each pool thread keeps its
                    // own `AttentionScratch` alive across groups, layers,
                    // and steps, so the scatter path stops allocating
                    // fresh score/probability buffers on every group.
                    thread_local! {
                        static SCATTER_SCRATCH: std::cell::RefCell<AttentionScratch> =
                            std::cell::RefCell::new(AttentionScratch::default());
                    }
                    let run_group = |gi: usize| {
                        let g = &groups[gi];
                        let off = q_off(g);
                        let mut out = vec![0f32; group * hd];
                        let mut c = EventCounters::default();
                        SCATTER_SCRATCH.with(|s| {
                            attend_sparse_batched(
                                g.cache,
                                &q[off..off + group * hd],
                                group,
                                backend,
                                &mut s.borrow_mut(),
                                &mut out,
                                &mut c,
                            );
                        });
                        (out, c)
                    };
                    let parts: Vec<(Vec<f32>, EventCounters)> =
                        match pool.try_parallel_map(groups.len(), &run_group) {
                            Ok(v) => v,
                            // A worker died mid-epoch: the pool has healed
                            // itself; recompute every group inline for this
                            // step. The closure is pure per group, so the
                            // sequential re-run is bit-exact.
                            Err(_) => (0..groups.len()).map(&run_group).collect(),
                        };
                    // deterministic merge: fixed group order regardless of
                    // worker completion order
                    for (g, (out, c)) in groups.iter().zip(parts.iter()) {
                        let off = q_off(g);
                        ctx[off..off + group * hd].copy_from_slice(out);
                        ctr.merge(c);
                    }
                } else {
                    for g in &groups {
                        let off = q_off(g);
                        attend_sparse_batched(
                            g.cache,
                            &q[off..off + group * hd],
                            group,
                            &self.plan.attention,
                            &mut scratch,
                            &mut ctx[off..off + group * hd],
                            ctr,
                        );
                    }
                }
            } else {
                // MHA (group == 1): no query rows share a static segment,
                // fall back to the looped scratched path
                for b in 0..nb {
                    for qh in 0..heads {
                        let hc = &caches[b].heads[layer_idx][qh / group];
                        let qrow = &q[(b * heads + qh) * hd..(b * heads + qh) * hd + hd];
                        attend_sparse_scratched(
                            hc,
                            qrow,
                            &self.plan.attention,
                            &mut scratch,
                            &mut ctx[(b * heads + qh) * hd..(b * heads + qh) * hd + hd],
                            ctr,
                        );
                    }
                }
            }
            let o = lp.wo.run_fused(&ctx, nb, ctr);
            add_inplace(&mut h, &o);
            let x = rmsnorm_rows(&h, nb, h_dim, &lw.ln2);
            let gate = lp.wgate.run_fused(&x, nb, ctr);
            let up = lp.wup.run_fused(&x, nb, ctr);
            let act: Vec<f32> = gate
                .iter()
                .zip(up.iter())
                .map(|(&g, &u)| silu(g) * u)
                .collect();
            let down = lp.wdown.run_fused(&act, nb, ctr);
            add_inplace(&mut h, &down);
        }
        let xf = rmsnorm_rows(&h, nb, h_dim, &m.ln_f);
        let logits = self.plan.lm_head.run_fused(&xf, nb, ctr);
        let vocab = m.vocab;
        (0..nb)
            .map(|b| logits[b * vocab..(b + 1) * vocab].to_vec())
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::{BackendKind, CpuCaps};

    fn toy_model() -> TinyModel {
        let mut g = crate::util::XorShift::new(7);
        let (h, inter, heads, kvh, hd, vocab) = (16, 24, 4, 2, 4, 32);
        let mut mk = |n: usize| g.normal_vec(n, 0.3);
        TinyModel {
            hidden: h,
            inter,
            heads,
            kv_heads: kvh,
            head_dim: hd,
            vocab,
            emb: mk(vocab * h),
            layers: (0..2)
                .map(|_| crate::models::tinyforward::LayerW {
                    ln1: vec![1.0; h],
                    wq: mk(h * heads * hd),
                    wk: mk(h * kvh * hd),
                    wv: mk(h * kvh * hd),
                    wo: mk(heads * hd * h),
                    ln2: vec![1.0; h],
                    wgate: mk(h * inter),
                    wup: mk(h * inter),
                    wdown: mk(inter * h),
                })
                .collect(),
            ln_f: vec![1.0; h],
            lm_head: mk(h * vocab),
        }
    }

    #[test]
    fn plan_model_caches_one_selection_per_distinct_shape() {
        let reg = BackendRegistry::with_caps(CpuCaps::all());
        let mc = ModelConfig::tiny();
        let plan = plan_model(&reg, BackendChoice::Auto, &mc, 1, 0.5, Dtype::Bf16);
        // tiny shapes: q=o=(128,128), k=v=(128,64), gate=up=(128,352),
        // down=(352,128), lm_head=(128,256) → 5 distinct
        assert_eq!(plan.selections_computed, 5);
        assert_eq!(plan.linears_planned, mc.layers * 7 + 1);
        assert_eq!(plan.per_layer.len(), 7);
        // shared shapes share the same resolved plan
        let q = plan.for_name("q_proj").unwrap();
        let o = plan.for_name("o_proj").unwrap();
        assert_eq!(q.selection.backend, o.selection.backend);
        assert_eq!(q.selection.use_sparse, o.selection.use_sparse);
    }

    #[test]
    fn plan_model_big_model_stays_small() {
        // 32-layer Llama 3 8B: 225 linears, at most 8 distinct shapes.
        let reg = BackendRegistry::with_caps(CpuCaps::all());
        let mc = ModelConfig::llama3_8b();
        let plan = plan_model(&reg, BackendChoice::Auto, &mc, 1, 0.5, Dtype::Bf16);
        assert_eq!(plan.linears_planned, 32 * 7 + 1);
        assert!(plan.selections_computed <= 8, "{}", plan.selections_computed);
        assert!(plan.describe().contains("lm_head="));
    }

    #[test]
    fn compile_packs_every_projection() {
        let reg = BackendRegistry::with_caps(CpuCaps::all());
        let model = toy_model();
        let plan = DecodePlan::compile(&reg, BackendChoice::Auto, &model, 0.0);
        assert_eq!(plan.layers.len(), 2);
        assert_eq!(plan.lm_head.cols, model.vocab);
        assert_eq!(plan.linears_planned, 2 * 7 + 1);
        // zero sparsity must never plan the sparse kernel class
        for l in &plan.layers {
            assert!(!l.wq.selection.use_sparse);
            assert!(!l.wdown.selection.use_sparse);
        }
        let mut ctr = EventCounters::default();
        let x = vec![0.5f32; model.hidden];
        let out = plan.lm_head.run(&x, 1, &mut ctr);
        assert_eq!(out.len(), model.vocab);
        assert!(out.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn predicted_step_sums_every_planned_linear() {
        let reg = BackendRegistry::with_caps(CpuCaps::all());
        let model = toy_model();
        let plan = DecodePlan::compile(&reg, BackendChoice::Auto, &model, 0.5);
        let head = plan.lm_head.selection.predicted_s;
        let by_hand: f64 = plan
            .layers
            .iter()
            .flat_map(|l| {
                [
                    l.wq.selection.predicted_s,
                    l.wk.selection.predicted_s,
                    l.wv.selection.predicted_s,
                    l.wo.selection.predicted_s,
                    l.wgate.selection.predicted_s,
                    l.wup.selection.predicted_s,
                    l.wdown.selection.predicted_s,
                ]
            })
            .sum::<f64>()
            + head;
        let got = plan.predicted_step_s();
        assert!(got > 0.0, "predicted step time must be positive");
        assert!((got - by_hand).abs() < 1e-15, "{got} vs {by_hand}");
    }

    #[test]
    fn predicted_fused_step_sums_fused_selections() {
        let reg = BackendRegistry::with_caps(CpuCaps::all());
        let model = toy_model();
        let batches = RegimeBatches {
            decode_fused: 8,
            ..RegimeBatches::default()
        };
        let plan =
            DecodePlan::compile_with(&reg, BackendChoice::Auto, &model, 0.5, batches);
        let by_hand: f64 = plan
            .layers
            .iter()
            .flat_map(|l| {
                [
                    l.wq.fused.predicted_s,
                    l.wk.fused.predicted_s,
                    l.wv.fused.predicted_s,
                    l.wo.fused.predicted_s,
                    l.wgate.fused.predicted_s,
                    l.wup.fused.predicted_s,
                    l.wdown.fused.predicted_s,
                ]
            })
            .sum::<f64>()
            + plan.lm_head.fused.predicted_s;
        let got = plan.predicted_fused_step_s();
        assert!(got > 0.0);
        assert!((got - by_hand).abs() < 1e-15, "{got} vs {by_hand}");
        assert!(
            got >= plan.predicted_step_s(),
            "an 8-row fused step is never priced below a batch-1 step"
        );
    }

    #[test]
    fn planned_linear_matches_reference_numerics() {
        let reg = BackendRegistry::with_caps(CpuCaps::all());
        let mut model = toy_model();
        model.prune_weights(0.5);
        let plan = DecodePlan::compile(&reg, BackendChoice::Auto, &model, 0.5);
        let mut g = crate::util::XorShift::new(11);
        let x = g.normal_vec(model.hidden, 1.0);
        let mut ctr = EventCounters::default();
        let got = plan.layers[0].wq.run(&x, 1, &mut ctr);
        // plain f32 reference on the same pruned weights
        let (rows, cols) = (model.hidden, model.heads * model.head_dim);
        let w = &model.layers[0].wq;
        for c in 0..cols {
            let mut want = 0f32;
            for r in 0..rows {
                want += x[r] * w[r * cols + c];
            }
            assert!(
                (got[c] - want).abs() < 0.05 + want.abs() * 0.05,
                "col {c}: {} vs {want}",
                got[c]
            );
        }
    }

    #[test]
    fn caps_none_plan_falls_back_to_reference_everywhere() {
        let reg = BackendRegistry::with_caps(CpuCaps::none());
        let mc = ModelConfig::tiny();
        let plan = plan_model(&reg, BackendChoice::Auto, &mc, 1, 0.5, Dtype::Bf16);
        for p in plan.per_layer.iter().chain([&plan.lm_head]) {
            assert_eq!(p.selection.backend.kind(), BackendKind::Reference);
            assert!(!p.selection.use_sparse);
        }
    }

    #[test]
    fn decode_step_extends_cache_and_returns_logits() {
        let reg = BackendRegistry::with_caps(CpuCaps::all());
        let model = toy_model();
        let nm = NativeModel::new(&reg, BackendChoice::Auto, model, 0.0);
        let mut ctr = EventCounters::default();
        let mut cache = nm.prefill(&[1, 2, 3], 0.0, 0.0, &mut ctr);
        assert_eq!(cache.heads.len(), 2);
        assert_eq!(cache.heads[0][0].len(), 3);
        let logits = nm.decode_step(4, 3, &mut cache, &mut ctr);
        assert_eq!(logits.len(), nm.vocab());
        assert!(logits.iter().all(|v| v.is_finite()));
        assert_eq!(cache.heads[0][0].len(), 4, "decode appends to the tail");
        assert_eq!(cache.heads[1][1].dyn_len(), 1);
        assert!(ctr.instructions() > 0, "planned kernels tick events");
    }

    #[test]
    fn llama3_regimes_flip_sparse_to_dense_with_batch() {
        // Fig 12: the dense/sparse crossover moves with batch. The
        // 4096×4096 q/o projection is memory-bound at batch 1 (sparse
        // wins: less to stream) and compute-bound at a filled fused
        // batch (dense wins: the decompress work stops amortizing).
        let reg = BackendRegistry::with_caps(CpuCaps::all());
        let mc = ModelConfig::llama3_8b();
        let rp = plan_model_regimes(
            &reg,
            BackendChoice::Auto,
            &mc,
            RegimeBatches {
                decode_fused: 256,
                prefill: 512,
            },
            0.5,
            Dtype::Bf16,
        );
        let b1 = rp.decode_b1.for_name("q_proj").unwrap();
        let fused = rp.decode_fused.for_name("q_proj").unwrap();
        assert!(b1.selection.use_sparse, "batch-1 decode is memory-bound: sparse wins");
        assert!(
            !fused.selection.use_sparse,
            "batch-256 fused decode is compute-bound: dense wins"
        );
        assert!(rp.regime_table().contains("q_proj"));
    }

    #[test]
    fn coinciding_regime_batches_share_resolutions() {
        // fused batch forced to 1 + prefill at 1 → all three regimes hit
        // the same (shape, batch) cache entries: 5 consultations total.
        let reg = BackendRegistry::with_caps(CpuCaps::all());
        let mc = ModelConfig::tiny();
        let rp = plan_model_regimes(
            &reg,
            BackendChoice::Auto,
            &mc,
            RegimeBatches {
                decode_fused: 1,
                prefill: 1,
            },
            0.5,
            Dtype::Bf16,
        );
        assert_eq!(rp.selections_computed, 5, "coinciding batches must dedupe");
        // distinct batches consult once each per shape
        let reg2 = BackendRegistry::with_caps(CpuCaps::all());
        let rp2 = plan_model_regimes(
            &reg2,
            BackendChoice::Auto,
            &mc,
            RegimeBatches::default(),
            0.5,
            Dtype::Bf16,
        );
        assert_eq!(rp2.selections_computed, 15, "3 distinct batches x 5 shapes");
    }

    #[test]
    fn batch_fuse_choice_parses_and_clamps() {
        assert_eq!("auto".parse::<BatchFuseChoice>().unwrap(), BatchFuseChoice::Auto);
        assert_eq!("8".parse::<BatchFuseChoice>().unwrap(), BatchFuseChoice::Fixed(8));
        assert!("lots".parse::<BatchFuseChoice>().is_err());
        assert_eq!(BatchFuseChoice::Auto.to_string(), "auto");
        assert_eq!(BatchFuseChoice::Fixed(4).to_string(), "4");
        // resolve_no_env sidesteps SPARAMX_BATCH_FUSE interference in CI
        assert_eq!(BatchFuseChoice::Auto.resolve_no_env(8), 8);
        assert_eq!(BatchFuseChoice::Fixed(4).resolve_no_env(8), 4);
        assert_eq!(BatchFuseChoice::Fixed(99).resolve_no_env(8), 8, "clamped to max_batch");
        assert_eq!(BatchFuseChoice::Fixed(0).resolve_no_env(8), 1, "floor at 1");
        assert_eq!(BatchFuseChoice::Auto.resolve_no_env(0), 1);
    }

    #[test]
    fn decode_step_batched_matches_looped_decode_steps() {
        // engine-level fusion contract in miniature: the fused step over
        // n slots is bit-exact vs. n independent batch-1 steps. Regimes
        // are pinned to coincide so both paths run the same kernel class
        // — this isolates the gather/RoPE/attention/split plumbing (the
        // per-backend batched-vs-looped kernel parity lives in
        // tests/batched_parity.rs; regimes that pick different kernels
        // are allowed to differ in f32 rounding).
        let reg = BackendRegistry::with_caps(CpuCaps::all());
        let mut model = toy_model();
        model.prune_weights(0.5);
        let nm = NativeModel::with_regimes(
            &reg,
            BackendChoice::Auto,
            model,
            0.5,
            RegimeBatches {
                decode_fused: 1,
                prefill: 1,
            },
        );
        let prompts: [&[u8]; 3] = [&[1, 2, 3], &[7, 8], &[4, 5, 6, 9]];
        let mut ctr = EventCounters::default();
        // looped oracle: per-slot decode_step
        let mut caches_a: Vec<KvCache> =
            prompts.iter().map(|p| nm.prefill(p, 0.0, 0.0, &mut ctr)).collect();
        let mut looped = Vec::new();
        for (b, p) in prompts.iter().enumerate() {
            looped.push(nm.decode_step(11, p.len(), &mut caches_a[b], &mut ctr));
        }
        // fused: one batched step over the same slots
        let mut caches_b: Vec<KvCache> =
            prompts.iter().map(|p| nm.prefill(p, 0.0, 0.0, &mut ctr)).collect();
        let mut refs: Vec<&mut KvCache> = caches_b.iter_mut().collect();
        let positions: Vec<usize> = prompts.iter().map(|p| p.len()).collect();
        let fused =
            nm.decode_step_batched(&[11, 11, 11], &positions, &mut refs, &mut ctr);
        assert_eq!(fused.len(), 3);
        for b in 0..3 {
            assert_eq!(fused[b], looped[b], "slot {b} diverged");
            assert_eq!(
                caches_a[b].heads[0][0].len(),
                caches_b[b].heads[0][0].len(),
                "slot {b} cache length diverged"
            );
        }
        // empty batch is a no-op
        assert!(nm
            .decode_step_batched(&[], &[], &mut [], &mut ctr)
            .is_empty());
    }

    #[test]
    fn empty_prefill_then_decode_works() {
        let reg = BackendRegistry::with_caps(CpuCaps::all());
        let nm = NativeModel::new(&reg, BackendChoice::Auto, toy_model(), 0.0);
        let mut ctr = EventCounters::default();
        let mut cache = nm.prefill(&[], 0.0, 0.0, &mut ctr);
        let logits = nm.decode_step(9, 0, &mut cache, &mut ctr);
        assert_eq!(logits.len(), nm.vocab());
        assert_eq!(cache.heads[0][0].len(), 1);
    }
}
