//! Per-layer backend plans: the model-load-time compilation step that
//! turns a [`ModelConfig`]'s linear shapes into cached kernel
//! [`Selection`]s and pre-packed operands, so the decode loop never
//! consults the registry or repacks a weight (the paper's
//! "preprocessing happens once", §7).
//!
//! Two levels:
//!
//! * [`plan_model`] — pure shape-level planning over any
//!   [`ModelConfig`]: one [`Selection`] per *distinct* `LinearShape`
//!   (q/k/v/o, gate/up/down, lm_head), resolved once through the
//!   [`BackendRegistry`]. Layers share shapes, so a 32-layer model
//!   computes at most eight selections. This is the per-layer
//!   heterogeneous dispatch of Shen et al. (arXiv:2306.16601) grounded
//!   in the roofline-style cost model (`perf/cost.rs`), as in DECA
//!   (arXiv:2505.19349).
//! * [`DecodePlan::compile`] — binds a shape plan to an actual
//!   [`TinyModel`]'s weights: every projection matrix is packed once
//!   into the operand class its selection chose (bitmap+values sparse
//!   stream or dense tile stream), producing [`PlannedLinear`]s the
//!   native engine dispatches directly.
//!
//! [`NativeModel`] is the serving-side forward built on a compiled
//! plan: batched prefill that also builds the per-(layer, kv-head)
//! [`HeadCache`]s, and a per-token `decode_step` that runs every
//! projection through its planned kernel and attention through
//! [`crate::kvcache::attention::attend_sparse`]. Kernel free functions
//! stay confined to `backend/` and `amx/kernels.rs`; this module only
//! speaks the [`Backend`] handle API.

use crate::amx::EventCounters;
use crate::backend::{
    Backend, BackendChoice, BackendRegistry, Dtype, GemmShape, PackedOperand, Selection,
};
use crate::kvcache::attention::attend_sparse;
use crate::kvcache::cache::{HeadCache, KvCache};
use crate::models::llama::{LinearShape, ModelConfig};
use crate::models::tinyforward::{
    add_inplace, rmsnorm_rows, rope_rows_from, silu, treat, TinyModel,
};
use std::collections::HashMap;

/// One planned linear shape: the shape plus the load-time selection
/// that every layer instance of this shape shares.
#[derive(Clone, Debug)]
pub struct PlannedShape {
    pub shape: LinearShape,
    pub selection: Selection,
}

/// Shape-level plan for a whole model: per-layer shapes plus the LM
/// head, each bound to a cached [`Selection`].
#[derive(Clone, Debug)]
pub struct ModelPlan {
    /// The seven per-layer linears in [`ModelConfig::layer_linears`]
    /// order (shared by every decoder layer).
    pub per_layer: Vec<PlannedShape>,
    pub lm_head: PlannedShape,
    /// How many distinct selections the registry actually computed —
    /// the cache hit assertion for tests: equals the number of distinct
    /// `(in_features, out_features)` pairs, never `linears_planned`.
    pub selections_computed: usize,
    /// Total linear instances covered (layers × per-layer + head).
    pub linears_planned: usize,
}

impl ModelPlan {
    /// Selection for a named per-layer linear.
    pub fn for_name(&self, name: &str) -> Option<&PlannedShape> {
        if self.lm_head.shape.name == name {
            return Some(&self.lm_head);
        }
        self.per_layer.iter().find(|p| p.shape.name == name)
    }

    /// Human-readable one-plan-per-shape summary for logs/`info`.
    pub fn describe(&self) -> String {
        let mut parts: Vec<String> = self
            .per_layer
            .iter()
            .map(|p| format!("{}={}", p.shape.name, p.selection.describe()))
            .collect();
        parts.push(format!("lm_head={}", self.lm_head.selection.describe()));
        format!(
            "{} ({} selections for {} linears)",
            parts.join(" "),
            self.selections_computed,
            self.linears_planned
        )
    }
}

/// Walk a [`ModelConfig`]'s linear shapes and resolve one [`Selection`]
/// per distinct shape through the registry. `batch` is the decode
/// batch the plan optimizes for (per-slot decode GEMMs run at batch 1);
/// `sparsity` is the weight sparsity the matrices will be pruned to.
///
/// Selection runs here — at model load — and never in the token loop;
/// [`ModelPlan::selections_computed`] counts the registry consultations
/// so tests can assert exactly one per distinct shape.
pub fn plan_model(
    registry: &BackendRegistry,
    choice: BackendChoice,
    model: &ModelConfig,
    batch: usize,
    sparsity: f64,
    dtype: Dtype,
) -> ModelPlan {
    let mut cache: HashMap<(usize, usize), Selection> = HashMap::new();
    let mut computed = 0usize;
    let mut resolve = |shape: &LinearShape| -> Selection {
        cache
            .entry((shape.in_features, shape.out_features))
            .or_insert_with(|| {
                computed += 1;
                registry.resolve(choice, GemmShape::for_linear(shape, batch), sparsity, dtype)
            })
            .clone()
    };
    let per_layer: Vec<PlannedShape> = model
        .layer_linears()
        .iter()
        .map(|l| PlannedShape {
            shape: *l,
            selection: resolve(l),
        })
        .collect();
    let head = model.lm_head();
    let lm_head = PlannedShape {
        selection: resolve(&head),
        shape: head,
    };
    drop(resolve);
    ModelPlan {
        linears_planned: model.layers * per_layer.len() + 1,
        per_layer,
        lm_head,
        selections_computed: computed,
    }
}

/// One serving linear: pre-packed operand + the selection that chose
/// its kernel. `run` is the only thing the token loop calls.
pub struct PlannedLinear {
    pub name: &'static str,
    /// Inner dimension (input features).
    pub rows: usize,
    /// Output features.
    pub cols: usize,
    pub selection: Selection,
    operand: PackedOperand,
}

impl PlannedLinear {
    /// Pack `w` (`rows × cols`, row-major) for `selection`'s kernel
    /// class via the shared [`PackedOperand`] policy.
    fn pack(
        name: &'static str,
        w: &[f32],
        rows: usize,
        cols: usize,
        selection: Selection,
    ) -> PlannedLinear {
        debug_assert_eq!(w.len(), rows * cols, "{name}: weight shape mismatch");
        let operand =
            PackedOperand::pack_f32(&selection.backend, w, rows, cols, selection.use_sparse);
        PlannedLinear {
            name,
            rows,
            cols,
            selection,
            operand,
        }
    }

    /// Dispatch one GEMM: `x` is `batch × rows` row-major, output is
    /// `batch × cols`. No selection, no packing — both happened at
    /// compile time.
    pub fn run(&self, x: &[f32], batch: usize, ctr: &mut EventCounters) -> Vec<f32> {
        debug_assert_eq!(x.len(), batch * self.rows, "{}: input shape", self.name);
        self.operand.gemm_bf16(&self.selection.backend, x, batch, ctr)
    }
}

/// One decoder layer's planned projections.
pub struct LayerPlan {
    pub wq: PlannedLinear,
    pub wk: PlannedLinear,
    pub wv: PlannedLinear,
    pub wo: PlannedLinear,
    pub wgate: PlannedLinear,
    pub wup: PlannedLinear,
    pub wdown: PlannedLinear,
}

/// The compiled serving plan for a loaded model: every projection
/// pre-packed and bound to its selected kernel, plus the backend the
/// attention static segment runs through.
pub struct DecodePlan {
    pub layers: Vec<LayerPlan>,
    pub lm_head: PlannedLinear,
    /// Backend serving the KV static-segment GEMMs in attention (the
    /// kernel class that won the q_proj shape).
    pub attention: Backend,
    /// Shape-level plan stats, carried over from [`plan_model`].
    pub selections_computed: usize,
    pub linears_planned: usize,
}

impl DecodePlan {
    /// Compile a plan for `model` (weights already pruned to
    /// `sparsity`): resolve selections per distinct shape via
    /// [`plan_model`], then pack every projection matrix once.
    pub fn compile(
        registry: &BackendRegistry,
        choice: BackendChoice,
        model: &TinyModel,
        sparsity: f64,
    ) -> DecodePlan {
        let mc = model_config_of(model);
        let sp = plan_model(registry, choice, &mc, 1, sparsity, Dtype::Bf16);
        let sel = |name: &str| -> Selection {
            sp.for_name(name)
                .expect("plan_model covers every projection name")
                .selection
                .clone()
        };
        let (h, inter, qd, kvd) = (
            model.hidden,
            model.inter,
            model.heads * model.head_dim,
            model.kv_heads * model.head_dim,
        );
        let layers = model
            .layers
            .iter()
            .map(|l| LayerPlan {
                wq: PlannedLinear::pack("q_proj", &l.wq, h, qd, sel("q_proj")),
                wk: PlannedLinear::pack("k_proj", &l.wk, h, kvd, sel("k_proj")),
                wv: PlannedLinear::pack("v_proj", &l.wv, h, kvd, sel("v_proj")),
                wo: PlannedLinear::pack("o_proj", &l.wo, qd, h, sel("o_proj")),
                wgate: PlannedLinear::pack("gate_proj", &l.wgate, h, inter, sel("gate_proj")),
                wup: PlannedLinear::pack("up_proj", &l.wup, h, inter, sel("up_proj")),
                wdown: PlannedLinear::pack("down_proj", &l.wdown, inter, h, sel("down_proj")),
            })
            .collect();
        DecodePlan {
            layers,
            lm_head: PlannedLinear::pack(
                "lm_head",
                &model.lm_head,
                h,
                model.vocab,
                sel("lm_head"),
            ),
            attention: sp
                .for_name("q_proj")
                .expect("q_proj always planned")
                .selection
                .backend
                .clone(),
            selections_computed: sp.selections_computed,
            linears_planned: sp.linears_planned,
        }
    }

    /// Predicted seconds for one full decode step (batch 1): the sum of
    /// every planned linear's load-time `predicted_s` — 7 projections
    /// per layer plus the LM head. Attention and elementwise work are
    /// excluded (memory-bound decode is dominated by the weight
    /// streams, Table 1), so this is a *lower bound* the admission
    /// budget treats as the per-token cost.
    pub fn predicted_step_s(&self) -> f64 {
        let per_layer: f64 = self
            .layers
            .iter()
            .map(|l| {
                [
                    &l.wq, &l.wk, &l.wv, &l.wo, &l.wgate, &l.wup, &l.wdown,
                ]
                .iter()
                .map(|p| p.selection.predicted_s)
                .sum::<f64>()
            })
            .sum();
        per_layer + self.lm_head.selection.predicted_s
    }

    /// Human-readable plan summary for banners/logs.
    pub fn describe(&self) -> String {
        let head = &self.lm_head;
        let first = self.layers.first();
        let layer_desc = first
            .map(|l| {
                format!(
                    "qkv={} mlp={} ",
                    l.wq.selection.describe(),
                    l.wup.selection.describe()
                )
            })
            .unwrap_or_default();
        format!(
            "{layer_desc}head={} ({} selections / {} linears)",
            head.selection.describe(),
            self.selections_computed,
            self.linears_planned
        )
    }
}

/// Derive the shape config of a loaded tiny-family model (works for the
/// build-time checkpoint and synthetic test models alike).
fn model_config_of(model: &TinyModel) -> ModelConfig {
    ModelConfig {
        name: "native".into(),
        hidden: model.hidden,
        intermediate: model.inter,
        layers: model.layers.len(),
        heads: model.heads,
        kv_heads: model.kv_heads,
        head_dim: model.head_dim,
        vocab: model.vocab,
    }
}

/// The plan-compiled serving model: weights + [`DecodePlan`]. This is
/// the native engine's whole forward surface — prefill builds the
/// per-slot [`KvCache`], `decode_step` serves one token.
pub struct NativeModel {
    pub model: TinyModel,
    pub plan: DecodePlan,
}

impl NativeModel {
    /// Compile a plan for an already-pruned model.
    pub fn new(
        registry: &BackendRegistry,
        choice: BackendChoice,
        model: TinyModel,
        sparsity: f64,
    ) -> NativeModel {
        let plan = DecodePlan::compile(registry, choice, &model, sparsity);
        NativeModel { model, plan }
    }

    pub fn vocab(&self) -> usize {
        self.model.vocab
    }

    /// Prefill over `tokens` (the prompt minus its final token): run the
    /// planned forward, build the pruned static KV segment per (layer,
    /// kv-head), and discard the logits (the decode loop produces the
    /// first output from the final prompt token).
    ///
    /// Prompt hidden states use the same per-head-pruned K/V the caches
    /// store, so prefill and decode see one consistent context (§6.1).
    pub fn prefill(
        &self,
        tokens: &[u8],
        k_sparsity: f64,
        v_sparsity: f64,
        ctr: &mut EventCounters,
    ) -> KvCache {
        let m = &self.model;
        let (h_dim, heads, kvh, hd) = (m.hidden, m.heads, m.kv_heads, m.head_dim);
        let s = tokens.len();
        let group = heads / kvh;
        if s == 0 {
            let heads_empty = (0..m.layers.len())
                .map(|_| {
                    (0..kvh)
                        .map(|_| HeadCache::from_prefill(&[], &[], 0, hd, k_sparsity, v_sparsity))
                        .collect()
                })
                .collect();
            return KvCache {
                heads: heads_empty,
                kv_heads: kvh,
            };
        }
        let mut h = vec![0f32; s * h_dim];
        for (t, &tok) in tokens.iter().enumerate() {
            h[t * h_dim..(t + 1) * h_dim]
                .copy_from_slice(&m.emb[tok as usize * h_dim..(tok as usize + 1) * h_dim]);
        }
        let mut cache_layers: Vec<Vec<HeadCache>> = Vec::with_capacity(m.layers.len());
        for (lw, lp) in m.layers.iter().zip(self.plan.layers.iter()) {
            let x = rmsnorm_rows(&h, s, h_dim, &lw.ln1);
            let mut q = lp.wq.run(&x, s, ctr);
            let mut k = lp.wk.run(&x, s, ctr);
            let v = lp.wv.run(&x, s, ctr);
            rope_rows_from(&mut q, s, heads, hd, 0);
            rope_rows_from(&mut k, s, kvh, hd, 0);
            // build this layer's static segment from the post-RoPE K/V
            let mut layer_caches = Vec::with_capacity(kvh);
            for head in 0..kvh {
                let mut kh = Vec::with_capacity(s * hd);
                let mut vh = Vec::with_capacity(s * hd);
                for t in 0..s {
                    kh.extend_from_slice(&k[(t * kvh + head) * hd..(t * kvh + head) * hd + hd]);
                    vh.extend_from_slice(&v[(t * kvh + head) * hd..(t * kvh + head) * hd + hd]);
                }
                layer_caches.push(HeadCache::from_prefill(
                    &kh, &vh, s, hd, k_sparsity, v_sparsity,
                ));
            }
            cache_layers.push(layer_caches);
            // prompt hidden states attend over the pruned K/V (dense
            // causal math — prefill is compute-bound and runs once)
            let kt = treat(&k, s, kvh, hd, k_sparsity, false);
            let vt = treat(&v, s, kvh, hd, v_sparsity, false);
            let mut ctx = vec![0f32; s * heads * hd];
            let scale = 1.0 / (hd as f32).sqrt();
            for qh in 0..heads {
                let khh = qh / group;
                for t in 0..s {
                    let qrow = &q[(t * heads + qh) * hd..(t * heads + qh) * hd + hd];
                    let mut scores = Vec::with_capacity(t + 1);
                    for u in 0..=t {
                        let krow = &kt[(u * kvh + khh) * hd..(u * kvh + khh) * hd + hd];
                        let dot: f32 = qrow.iter().zip(krow).map(|(a, b)| a * b).sum();
                        scores.push(dot * scale);
                    }
                    crate::kvcache::attention::softmax(&mut scores);
                    let out = &mut ctx[(t * heads + qh) * hd..(t * heads + qh) * hd + hd];
                    for (u, &p) in scores.iter().enumerate() {
                        let vrow = &vt[(u * kvh + khh) * hd..(u * kvh + khh) * hd + hd];
                        for d in 0..hd {
                            out[d] += p * vrow[d];
                        }
                    }
                }
            }
            let o = lp.wo.run(&ctx, s, ctr);
            add_inplace(&mut h, &o);
            let x = rmsnorm_rows(&h, s, h_dim, &lw.ln2);
            let gate = lp.wgate.run(&x, s, ctr);
            let up = lp.wup.run(&x, s, ctr);
            let act: Vec<f32> = gate
                .iter()
                .zip(up.iter())
                .map(|(&g, &u)| silu(g) * u)
                .collect();
            let down = lp.wdown.run(&act, s, ctr);
            add_inplace(&mut h, &down);
        }
        KvCache {
            heads: cache_layers,
            kv_heads: kvh,
        }
    }

    /// One token of plan-driven decode: every projection runs its
    /// pre-selected kernel on its pre-packed operand, attention runs
    /// [`attend_sparse`] over the slot's cache (sparse static segment +
    /// dense dynamic tail), and the new K/V rows append to the tail.
    /// Returns the next-token logits (`vocab` long).
    pub fn decode_step(
        &self,
        token: u8,
        pos: usize,
        cache: &mut KvCache,
        ctr: &mut EventCounters,
    ) -> Vec<f32> {
        let m = &self.model;
        let (h_dim, heads, kvh, hd) = (m.hidden, m.heads, m.kv_heads, m.head_dim);
        let group = heads / kvh;
        let mut h =
            m.emb[token as usize * h_dim..(token as usize + 1) * h_dim].to_vec();
        for (layer_idx, (lw, lp)) in m.layers.iter().zip(self.plan.layers.iter()).enumerate() {
            let x = rmsnorm_rows(&h, 1, h_dim, &lw.ln1);
            let mut q = lp.wq.run(&x, 1, ctr);
            let mut k = lp.wk.run(&x, 1, ctr);
            let v = lp.wv.run(&x, 1, ctr);
            rope_rows_from(&mut q, 1, heads, hd, pos);
            rope_rows_from(&mut k, 1, kvh, hd, pos);
            // append this token's K/V to the dynamic tail first so
            // attention sees position `pos` (causal self-inclusion)
            for head in 0..kvh {
                cache.heads[layer_idx][head]
                    .append(&k[head * hd..(head + 1) * hd], &v[head * hd..(head + 1) * hd]);
            }
            let mut ctx = vec![0f32; heads * hd];
            for qh in 0..heads {
                let hc = &cache.heads[layer_idx][qh / group];
                let out = attend_sparse(hc, &q[qh * hd..(qh + 1) * hd], &self.plan.attention, ctr);
                ctx[qh * hd..(qh + 1) * hd].copy_from_slice(&out);
            }
            let o = lp.wo.run(&ctx, 1, ctr);
            add_inplace(&mut h, &o);
            let x = rmsnorm_rows(&h, 1, h_dim, &lw.ln2);
            let gate = lp.wgate.run(&x, 1, ctr);
            let up = lp.wup.run(&x, 1, ctr);
            let act: Vec<f32> = gate
                .iter()
                .zip(up.iter())
                .map(|(&g, &u)| silu(g) * u)
                .collect();
            let down = lp.wdown.run(&act, 1, ctr);
            add_inplace(&mut h, &down);
        }
        let xf = rmsnorm_rows(&h, 1, h_dim, &m.ln_f);
        self.plan.lm_head.run(&xf, 1, ctr)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::{BackendKind, CpuCaps};

    fn toy_model() -> TinyModel {
        let mut g = crate::util::XorShift::new(7);
        let (h, inter, heads, kvh, hd, vocab) = (16, 24, 4, 2, 4, 32);
        let mut mk = |n: usize| g.normal_vec(n, 0.3);
        TinyModel {
            hidden: h,
            inter,
            heads,
            kv_heads: kvh,
            head_dim: hd,
            vocab,
            emb: mk(vocab * h),
            layers: (0..2)
                .map(|_| crate::models::tinyforward::LayerW {
                    ln1: vec![1.0; h],
                    wq: mk(h * heads * hd),
                    wk: mk(h * kvh * hd),
                    wv: mk(h * kvh * hd),
                    wo: mk(heads * hd * h),
                    ln2: vec![1.0; h],
                    wgate: mk(h * inter),
                    wup: mk(h * inter),
                    wdown: mk(inter * h),
                })
                .collect(),
            ln_f: vec![1.0; h],
            lm_head: mk(h * vocab),
        }
    }

    #[test]
    fn plan_model_caches_one_selection_per_distinct_shape() {
        let reg = BackendRegistry::with_caps(CpuCaps::all());
        let mc = ModelConfig::tiny();
        let plan = plan_model(&reg, BackendChoice::Auto, &mc, 1, 0.5, Dtype::Bf16);
        // tiny shapes: q=o=(128,128), k=v=(128,64), gate=up=(128,352),
        // down=(352,128), lm_head=(128,256) → 5 distinct
        assert_eq!(plan.selections_computed, 5);
        assert_eq!(plan.linears_planned, mc.layers * 7 + 1);
        assert_eq!(plan.per_layer.len(), 7);
        // shared shapes share the same resolved plan
        let q = plan.for_name("q_proj").unwrap();
        let o = plan.for_name("o_proj").unwrap();
        assert_eq!(q.selection.backend, o.selection.backend);
        assert_eq!(q.selection.use_sparse, o.selection.use_sparse);
    }

    #[test]
    fn plan_model_big_model_stays_small() {
        // 32-layer Llama 3 8B: 225 linears, at most 8 distinct shapes.
        let reg = BackendRegistry::with_caps(CpuCaps::all());
        let mc = ModelConfig::llama3_8b();
        let plan = plan_model(&reg, BackendChoice::Auto, &mc, 1, 0.5, Dtype::Bf16);
        assert_eq!(plan.linears_planned, 32 * 7 + 1);
        assert!(plan.selections_computed <= 8, "{}", plan.selections_computed);
        assert!(plan.describe().contains("lm_head="));
    }

    #[test]
    fn compile_packs_every_projection() {
        let reg = BackendRegistry::with_caps(CpuCaps::all());
        let model = toy_model();
        let plan = DecodePlan::compile(&reg, BackendChoice::Auto, &model, 0.0);
        assert_eq!(plan.layers.len(), 2);
        assert_eq!(plan.lm_head.cols, model.vocab);
        assert_eq!(plan.linears_planned, 2 * 7 + 1);
        // zero sparsity must never plan the sparse kernel class
        for l in &plan.layers {
            assert!(!l.wq.selection.use_sparse);
            assert!(!l.wdown.selection.use_sparse);
        }
        let mut ctr = EventCounters::default();
        let x = vec![0.5f32; model.hidden];
        let out = plan.lm_head.run(&x, 1, &mut ctr);
        assert_eq!(out.len(), model.vocab);
        assert!(out.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn predicted_step_sums_every_planned_linear() {
        let reg = BackendRegistry::with_caps(CpuCaps::all());
        let model = toy_model();
        let plan = DecodePlan::compile(&reg, BackendChoice::Auto, &model, 0.5);
        let head = plan.lm_head.selection.predicted_s;
        let by_hand: f64 = plan
            .layers
            .iter()
            .flat_map(|l| {
                [
                    l.wq.selection.predicted_s,
                    l.wk.selection.predicted_s,
                    l.wv.selection.predicted_s,
                    l.wo.selection.predicted_s,
                    l.wgate.selection.predicted_s,
                    l.wup.selection.predicted_s,
                    l.wdown.selection.predicted_s,
                ]
            })
            .sum::<f64>()
            + head;
        let got = plan.predicted_step_s();
        assert!(got > 0.0, "predicted step time must be positive");
        assert!((got - by_hand).abs() < 1e-15, "{got} vs {by_hand}");
    }

    #[test]
    fn planned_linear_matches_reference_numerics() {
        let reg = BackendRegistry::with_caps(CpuCaps::all());
        let mut model = toy_model();
        model.prune_weights(0.5);
        let plan = DecodePlan::compile(&reg, BackendChoice::Auto, &model, 0.5);
        let mut g = crate::util::XorShift::new(11);
        let x = g.normal_vec(model.hidden, 1.0);
        let mut ctr = EventCounters::default();
        let got = plan.layers[0].wq.run(&x, 1, &mut ctr);
        // plain f32 reference on the same pruned weights
        let (rows, cols) = (model.hidden, model.heads * model.head_dim);
        let w = &model.layers[0].wq;
        for c in 0..cols {
            let mut want = 0f32;
            for r in 0..rows {
                want += x[r] * w[r * cols + c];
            }
            assert!(
                (got[c] - want).abs() < 0.05 + want.abs() * 0.05,
                "col {c}: {} vs {want}",
                got[c]
            );
        }
    }

    #[test]
    fn caps_none_plan_falls_back_to_reference_everywhere() {
        let reg = BackendRegistry::with_caps(CpuCaps::none());
        let mc = ModelConfig::tiny();
        let plan = plan_model(&reg, BackendChoice::Auto, &mc, 1, 0.5, Dtype::Bf16);
        for p in plan.per_layer.iter().chain([&plan.lm_head]) {
            assert_eq!(p.selection.backend.kind(), BackendKind::Reference);
            assert!(!p.selection.use_sparse);
        }
    }

    #[test]
    fn decode_step_extends_cache_and_returns_logits() {
        let reg = BackendRegistry::with_caps(CpuCaps::all());
        let model = toy_model();
        let nm = NativeModel::new(&reg, BackendChoice::Auto, model, 0.0);
        let mut ctr = EventCounters::default();
        let mut cache = nm.prefill(&[1, 2, 3], 0.0, 0.0, &mut ctr);
        assert_eq!(cache.heads.len(), 2);
        assert_eq!(cache.heads[0][0].len(), 3);
        let logits = nm.decode_step(4, 3, &mut cache, &mut ctr);
        assert_eq!(logits.len(), nm.vocab());
        assert!(logits.iter().all(|v| v.is_finite()));
        assert_eq!(cache.heads[0][0].len(), 4, "decode appends to the tail");
        assert_eq!(cache.heads[1][1].dyn_len(), 1);
        assert!(ctr.instructions() > 0, "planned kernels tick events");
    }

    #[test]
    fn empty_prefill_then_decode_works() {
        let reg = BackendRegistry::with_caps(CpuCaps::all());
        let nm = NativeModel::new(&reg, BackendChoice::Auto, toy_model(), 0.0);
        let mut ctr = EventCounters::default();
        let mut cache = nm.prefill(&[], 0.0, 0.0, &mut ctr);
        let logits = nm.decode_step(9, 0, &mut cache, &mut ctr);
        assert_eq!(logits.len(), nm.vocab());
        assert_eq!(cache.heads[0][0].len(), 1);
    }
}
