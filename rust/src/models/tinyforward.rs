//! Rust-native forward pass of the tiny checkpoint (mirrors
//! `python/compile/model.py`), used by the accuracy experiments
//! (Figs 10/14/17/18 analogues) so quality-vs-sparsity curves are
//! measured without Python on the path.
//!
//! Numerics are validated against the PJRT `eval_logits` artifact in the
//! integration tests (same weights → same NLL to float tolerance).

use crate::runtime::artifact::Bundle;
use crate::sparse::prune::{magnitude_prune, magnitude_prune_inplace};
use anyhow::{anyhow, Result};

/// Per-layer weights.
#[derive(Clone, Debug)]
pub struct LayerW {
    pub ln1: Vec<f32>,
    pub wq: Vec<f32>,
    pub wk: Vec<f32>,
    pub wv: Vec<f32>,
    pub wo: Vec<f32>,
    pub ln2: Vec<f32>,
    pub wgate: Vec<f32>,
    pub wup: Vec<f32>,
    pub wdown: Vec<f32>,
}

/// The tiny model, loaded from `artifacts/weights.bin`.
#[derive(Clone, Debug)]
pub struct TinyModel {
    pub hidden: usize,
    pub inter: usize,
    pub heads: usize,
    pub kv_heads: usize,
    pub head_dim: usize,
    pub vocab: usize,
    pub emb: Vec<f32>,
    pub layers: Vec<LayerW>,
    pub ln_f: Vec<f32>,
    pub lm_head: Vec<f32>,
}

/// KV-cache treatment during evaluation (the §6 experiments).
#[derive(Clone, Copy, Debug, Default)]
pub struct KvTreatment {
    /// Magnitude sparsity applied to cached K (per layer × head).
    pub k_sparsity: f64,
    /// Magnitude sparsity applied to cached V.
    pub v_sparsity: f64,
    /// Quantize the cache to INT8 before use (Fig 18).
    pub int8: bool,
}

/// Evaluation result over a token stream.
#[derive(Clone, Copy, Debug)]
pub struct EvalResult {
    /// Mean negative log-likelihood per predicted token (nats).
    pub nll: f64,
    /// Perplexity = exp(nll).
    pub ppl: f64,
    /// Top-1 next-token accuracy.
    pub top1: f64,
    /// Predicted tokens counted.
    pub tokens: usize,
}

impl TinyModel {
    /// Load from an artifact bundle (names follow the manifest layout).
    pub fn from_bundle(bundle: &Bundle) -> Result<TinyModel> {
        let get = |name: &str| -> Result<Vec<f32>> {
            Ok(bundle
                .param(name)
                .ok_or_else(|| anyhow!("missing param {name}"))?
                .data
                .clone())
        };
        let layers_n = bundle.config_usize("layers")?;
        let mut layers = Vec::with_capacity(layers_n);
        for l in 0..layers_n {
            layers.push(LayerW {
                ln1: get(&format!("layers/{l}/ln1"))?,
                wq: get(&format!("layers/{l}/wq"))?,
                wk: get(&format!("layers/{l}/wk"))?,
                wv: get(&format!("layers/{l}/wv"))?,
                wo: get(&format!("layers/{l}/wo"))?,
                ln2: get(&format!("layers/{l}/ln2"))?,
                wgate: get(&format!("layers/{l}/wgate"))?,
                wup: get(&format!("layers/{l}/wup"))?,
                wdown: get(&format!("layers/{l}/wdown"))?,
            });
        }
        Ok(TinyModel {
            hidden: bundle.config_usize("hidden")?,
            inter: bundle.config_usize("inter")?,
            heads: bundle.config_usize("heads")?,
            kv_heads: bundle.config_usize("kv_heads")?,
            head_dim: bundle.config_usize("head_dim")?,
            vocab: bundle.config_usize("vocab")?,
            emb: get("emb")?,
            layers,
            ln_f: get("ln_f")?,
            lm_head: get("lm_head")?,
        })
    }

    /// Magnitude-prune all projection matrices (Fig 10's x-axis).
    pub fn prune_weights(&mut self, sparsity: f64) {
        for l in &mut self.layers {
            for w in [
                &mut l.wq, &mut l.wk, &mut l.wv, &mut l.wo, &mut l.wgate, &mut l.wup,
                &mut l.wdown,
            ] {
                magnitude_prune_inplace(w, sparsity);
            }
        }
    }

    /// Forward over one sequence → per-position logits `[S, vocab]`.
    pub fn forward(&self, tokens: &[u8], kv: KvTreatment) -> Vec<f32> {
        let s = tokens.len();
        let (h_dim, heads, kvh, hd) = (self.hidden, self.heads, self.kv_heads, self.head_dim);
        let group = heads / kvh;
        let mut h = vec![0f32; s * h_dim];
        for (t, &tok) in tokens.iter().enumerate() {
            h[t * h_dim..(t + 1) * h_dim]
                .copy_from_slice(&self.emb[tok as usize * h_dim..(tok as usize + 1) * h_dim]);
        }
        for layer in &self.layers {
            let x = rmsnorm_rows(&h, s, h_dim, &layer.ln1);
            let mut q = gemm(&x, s, h_dim, &layer.wq, heads * hd);
            let mut k = gemm(&x, s, h_dim, &layer.wk, kvh * hd);
            let v = gemm(&x, s, h_dim, &layer.wv, kvh * hd);
            rope_rows(&mut q, s, heads, hd);
            rope_rows(&mut k, s, kvh, hd);
            // KV-cache treatment: prune/quantize the cached K and V
            let k = treat(&k, s, kvh, hd, kv.k_sparsity, kv.int8);
            let v = treat(&v, s, kvh, hd, kv.v_sparsity, kv.int8);
            // causal GQA attention
            let mut ctx = vec![0f32; s * heads * hd];
            let scale = 1.0 / (hd as f32).sqrt();
            for qh in 0..heads {
                let khh = qh / group;
                for t in 0..s {
                    // scores over positions 0..=t
                    let qrow = &q[(t * heads + qh) * hd..(t * heads + qh) * hd + hd];
                    let mut scores = Vec::with_capacity(t + 1);
                    for u in 0..=t {
                        let krow = &k[(u * kvh + khh) * hd..(u * kvh + khh) * hd + hd];
                        let dot: f32 = qrow.iter().zip(krow).map(|(a, b)| a * b).sum();
                        scores.push(dot * scale);
                    }
                    crate::kvcache::attention::softmax(&mut scores);
                    let out = &mut ctx[(t * heads + qh) * hd..(t * heads + qh) * hd + hd];
                    for (u, &p) in scores.iter().enumerate() {
                        let vrow = &v[(u * kvh + khh) * hd..(u * kvh + khh) * hd + hd];
                        for d in 0..hd {
                            out[d] += p * vrow[d];
                        }
                    }
                }
            }
            let o = gemm(&ctx, s, heads * hd, &layer.wo, h_dim);
            add_inplace(&mut h, &o);
            let x = rmsnorm_rows(&h, s, h_dim, &layer.ln2);
            let gate = gemm(&x, s, h_dim, &layer.wgate, self.inter);
            let up = gemm(&x, s, h_dim, &layer.wup, self.inter);
            let act: Vec<f32> = gate
                .iter()
                .zip(up.iter())
                .map(|(&g, &u)| silu(g) * u)
                .collect();
            let down = gemm(&act, s, self.inter, &layer.wdown, h_dim);
            add_inplace(&mut h, &down);
        }
        let xf = rmsnorm_rows(&h, s, h_dim, &self.ln_f);
        gemm(&xf, s, h_dim, &self.lm_head, self.vocab)
    }

    /// NLL / perplexity / top-1 accuracy of next-token prediction over a
    /// token stream, chunked into `chunk`-length sequences.
    pub fn evaluate(&self, stream: &[u8], chunk: usize, kv: KvTreatment) -> EvalResult {
        assert!(chunk >= 2);
        let mut nll_sum = 0f64;
        let mut correct = 0usize;
        let mut count = 0usize;
        for seq in stream.chunks(chunk) {
            if seq.len() < 2 {
                continue;
            }
            let logits = self.forward(seq, kv);
            for t in 0..seq.len() - 1 {
                let row = &logits[t * self.vocab..(t + 1) * self.vocab];
                let target = seq[t + 1] as usize;
                let (logp, am) = log_softmax_at(row, target);
                nll_sum -= logp as f64;
                correct += usize::from(am == target);
                count += 1;
            }
        }
        let nll = nll_sum / count.max(1) as f64;
        EvalResult {
            nll,
            ppl: nll.exp(),
            top1: correct as f64 / count.max(1) as f64,
            tokens: count,
        }
    }
}

/// Prune and/or INT8-roundtrip a cached tensor, per head.
fn treat(x: &[f32], s: usize, heads: usize, hd: usize, sparsity: f64, int8: bool) -> Vec<f32> {
    let mut out = x.to_vec();
    if sparsity > 0.0 {
        // per-head grouping: gather each head's values across positions
        for h in 0..heads {
            let mut vals: Vec<f32> = (0..s * hd)
                .map(|i| x[(i / hd * heads + h) * hd + i % hd])
                .collect();
            vals = magnitude_prune(&vals, sparsity);
            for (i, v) in vals.iter().enumerate() {
                out[(i / hd * heads + h) * hd + i % hd] = *v;
            }
        }
    }
    if int8 {
        for h in 0..heads {
            let mut amax = 0f32;
            for t in 0..s {
                for d in 0..hd {
                    amax = amax.max(out[(t * heads + h) * hd + d].abs());
                }
            }
            let scale = if amax > 0.0 { amax / 127.0 } else { 1.0 };
            for t in 0..s {
                for d in 0..hd {
                    let i = (t * heads + h) * hd + d;
                    out[i] = (out[i] / scale).round().clamp(-127.0, 127.0) * scale;
                }
            }
        }
    }
    out
}

fn gemm(x: &[f32], rows: usize, inner: usize, w: &[f32], cols: usize) -> Vec<f32> {
    debug_assert_eq!(x.len(), rows * inner);
    debug_assert_eq!(w.len(), inner * cols);
    let mut out = vec![0f32; rows * cols];
    for r in 0..rows {
        for k in 0..inner {
            let xv = x[r * inner + k];
            if xv == 0.0 {
                continue;
            }
            let wrow = &w[k * cols..(k + 1) * cols];
            let orow = &mut out[r * cols..(r + 1) * cols];
            for c in 0..cols {
                orow[c] += xv * wrow[c];
            }
        }
    }
    out
}

fn rmsnorm_rows(x: &[f32], rows: usize, dim: usize, g: &[f32]) -> Vec<f32> {
    let mut out = vec![0f32; rows * dim];
    for r in 0..rows {
        let row = &x[r * dim..(r + 1) * dim];
        let ms: f32 = row.iter().map(|v| v * v).sum::<f32>() / dim as f32;
        let inv = 1.0 / (ms + 1e-5).sqrt();
        for d in 0..dim {
            out[r * dim + d] = row[d] * g[d] * inv;
        }
    }
    out
}

/// Rotary embedding matching `model.py::rope` (half-split layout).
fn rope_rows(x: &mut [f32], s: usize, heads: usize, hd: usize) {
    let half = hd / 2;
    for t in 0..s {
        for h in 0..heads {
            let base = (t * heads + h) * hd;
            for i in 0..half {
                let freq = 1.0 / 10000f32.powf(i as f32 / half as f32);
                let angle = t as f32 * freq;
                let (sin, cos) = angle.sin_cos();
                let a = x[base + i];
                let b = x[base + half + i];
                x[base + i] = a * cos - b * sin;
                x[base + half + i] = a * sin + b * cos;
            }
        }
    }
}

fn add_inplace(a: &mut [f32], b: &[f32]) {
    for (x, y) in a.iter_mut().zip(b.iter()) {
        *x += y;
    }
}

fn silu(x: f32) -> f32 {
    x / (1.0 + (-x).exp())
}

/// log-softmax value at `target` plus the argmax index.
fn log_softmax_at(row: &[f32], target: usize) -> (f32, usize) {
    let mut max = f32::NEG_INFINITY;
    let mut am = 0;
    for (i, &v) in row.iter().enumerate() {
        if v > max {
            max = v;
            am = i;
        }
    }
    let logsum: f32 = row.iter().map(|&v| (v - max).exp()).sum::<f32>().ln() + max;
    (row[target] - logsum, am)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy_model() -> TinyModel {
        // deterministic small random model for invariant tests
        let mut g = crate::util::XorShift::new(42);
        let (h, inter, heads, kvh, hd, vocab) = (16, 24, 4, 2, 4, 32);
        let mut mk = |n: usize| g.normal_vec(n, 0.3);
        TinyModel {
            hidden: h,
            inter,
            heads,
            kv_heads: kvh,
            head_dim: hd,
            vocab,
            emb: mk(vocab * h),
            layers: (0..2)
                .map(|_| LayerW {
                    ln1: vec![1.0; h],
                    wq: mk(h * heads * hd),
                    wk: mk(h * kvh * hd),
                    wv: mk(h * kvh * hd),
                    wo: mk(heads * hd * h),
                    ln2: vec![1.0; h],
                    wgate: mk(h * inter),
                    wup: mk(h * inter),
                    wdown: mk(inter * h),
                })
                .collect(),
            ln_f: vec![1.0; h],
            lm_head: mk(h * vocab),
        }
    }

    #[test]
    fn forward_shapes_and_finite() {
        let m = toy_model();
        let logits = m.forward(&[1, 2, 3, 4, 5], KvTreatment::default());
        assert_eq!(logits.len(), 5 * m.vocab);
        assert!(logits.iter().all(|x| x.is_finite()));
    }

    #[test]
    fn causality_prefix_invariance() {
        // logits at position t must not depend on tokens after t
        let m = toy_model();
        let a = m.forward(&[1, 2, 3, 9, 9], KvTreatment::default());
        let b = m.forward(&[1, 2, 3, 4, 5], KvTreatment::default());
        for i in 0..3 * m.vocab {
            assert!(
                (a[i] - b[i]).abs() < 1e-4,
                "position {} leaked future tokens",
                i / m.vocab
            );
        }
    }

    #[test]
    fn evaluate_counts_predictions() {
        let m = toy_model();
        let stream: Vec<u8> = (0..40).map(|i| (i % 30) as u8).collect();
        let r = m.evaluate(&stream, 10, KvTreatment::default());
        assert_eq!(r.tokens, 36); // 4 chunks × 9 predictions
        assert!(r.nll > 0.0 && r.ppl > 1.0);
        assert!((0.0..=1.0).contains(&r.top1));
    }

    #[test]
    fn kv_pruning_degrades_gracefully() {
        let m = toy_model();
        let stream: Vec<u8> = (0..60).map(|i| (i * 7 % 31) as u8).collect();
        let base = m.evaluate(&stream, 20, KvTreatment::default());
        let light = m.evaluate(
            &stream,
            20,
            KvTreatment {
                k_sparsity: 0.2,
                v_sparsity: 0.2,
                int8: false,
            },
        );
        let heavy = m.evaluate(
            &stream,
            20,
            KvTreatment {
                k_sparsity: 0.9,
                v_sparsity: 0.9,
                int8: false,
            },
        );
        assert!(light.nll < heavy.nll, "heavier pruning must hurt more");
        assert!(base.nll <= light.nll + 0.5);
    }

    #[test]
    fn int8_kv_is_mild() {
        let m = toy_model();
        let stream: Vec<u8> = (0..40).map(|i| (i * 3 % 29) as u8).collect();
        let base = m.evaluate(&stream, 20, KvTreatment::default());
        let q = m.evaluate(
            &stream,
            20,
            KvTreatment {
                int8: true,
                ..Default::default()
            },
        );
        assert!((q.nll - base.nll).abs() < 0.2, "int8 KV should be mild");
    }

    #[test]
    fn weight_pruning_pushes_toward_uniform() {
        // An untrained toy model has no quality to lose, so assert the
        // mechanistic effect instead: near-total pruning collapses the
        // logits toward the uniform distribution (NLL → ln(vocab)).
        let mut m0 = toy_model();
        let stream: Vec<u8> = (0..40).map(|i| (i * 5 % 23) as u8).collect();
        let base = m0.evaluate(&stream, 20, KvTreatment::default());
        m0.prune_weights(0.98);
        let pruned = m0.evaluate(&stream, 20, KvTreatment::default());
        let uniform = (m0.vocab as f64).ln();
        assert!(
            (pruned.nll - uniform).abs() < (base.nll - uniform).abs(),
            "pruned NLL {:.3} should be closer to uniform {:.3} than base {:.3}",
            pruned.nll,
            uniform,
            base.nll
        );
        assert!((pruned.nll - base.nll).abs() > 1e-6, "pruning must change NLL");
    }
}
