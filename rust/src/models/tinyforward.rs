//! Rust-native forward pass of the tiny checkpoint (mirrors
//! `python/compile/model.py`), used by the accuracy experiments
//! (Figs 10/14/17/18 analogues) so quality-vs-sparsity curves are
//! measured without Python on the path.
//!
//! Numerics are validated against the PJRT `eval_logits` artifact in the
//! integration tests (same weights → same NLL to float tolerance).

use crate::amx::EventCounters;
use crate::backend::{Backend, PackedOperand};
use crate::runtime::artifact::Bundle;
use crate::sparse::prune::{magnitude_prune, magnitude_prune_inplace};
use crate::util::error::{anyhow, Result};

/// Per-layer weights.
#[derive(Clone, Debug)]
pub struct LayerW {
    pub ln1: Vec<f32>,
    pub wq: Vec<f32>,
    pub wk: Vec<f32>,
    pub wv: Vec<f32>,
    pub wo: Vec<f32>,
    pub ln2: Vec<f32>,
    pub wgate: Vec<f32>,
    pub wup: Vec<f32>,
    pub wdown: Vec<f32>,
}

/// The tiny model, loaded from `artifacts/weights.bin`.
#[derive(Clone, Debug)]
pub struct TinyModel {
    pub hidden: usize,
    pub inter: usize,
    pub heads: usize,
    pub kv_heads: usize,
    pub head_dim: usize,
    pub vocab: usize,
    pub emb: Vec<f32>,
    pub layers: Vec<LayerW>,
    pub ln_f: Vec<f32>,
    pub lm_head: Vec<f32>,
}

/// KV-cache treatment during evaluation (the §6 experiments).
#[derive(Clone, Copy, Debug, Default)]
pub struct KvTreatment {
    /// Magnitude sparsity applied to cached K (per layer × head).
    pub k_sparsity: f64,
    /// Magnitude sparsity applied to cached V.
    pub v_sparsity: f64,
    /// Quantize the cache to INT8 before use (Fig 18).
    pub int8: bool,
}

/// Evaluation result over a token stream.
#[derive(Clone, Copy, Debug)]
pub struct EvalResult {
    /// Mean negative log-likelihood per predicted token (nats).
    pub nll: f64,
    /// Perplexity = exp(nll).
    pub ppl: f64,
    /// Top-1 next-token accuracy.
    pub top1: f64,
    /// Predicted tokens counted.
    pub tokens: usize,
}

impl TinyModel {
    /// Load from an artifact bundle (names follow the manifest layout).
    pub fn from_bundle(bundle: &Bundle) -> Result<TinyModel> {
        let get = |name: &str| -> Result<Vec<f32>> {
            Ok(bundle
                .param(name)
                .ok_or_else(|| anyhow!("missing param {name}"))?
                .data
                .clone())
        };
        let layers_n = bundle.config_usize("layers")?;
        let mut layers = Vec::with_capacity(layers_n);
        for l in 0..layers_n {
            layers.push(LayerW {
                ln1: get(&format!("layers/{l}/ln1"))?,
                wq: get(&format!("layers/{l}/wq"))?,
                wk: get(&format!("layers/{l}/wk"))?,
                wv: get(&format!("layers/{l}/wv"))?,
                wo: get(&format!("layers/{l}/wo"))?,
                ln2: get(&format!("layers/{l}/ln2"))?,
                wgate: get(&format!("layers/{l}/wgate"))?,
                wup: get(&format!("layers/{l}/wup"))?,
                wdown: get(&format!("layers/{l}/wdown"))?,
            });
        }
        Ok(TinyModel {
            hidden: bundle.config_usize("hidden")?,
            inter: bundle.config_usize("inter")?,
            heads: bundle.config_usize("heads")?,
            kv_heads: bundle.config_usize("kv_heads")?,
            head_dim: bundle.config_usize("head_dim")?,
            vocab: bundle.config_usize("vocab")?,
            emb: get("emb")?,
            layers,
            ln_f: get("ln_f")?,
            lm_head: get("lm_head")?,
        })
    }

    /// Magnitude-prune all projection matrices (Fig 10's x-axis).
    pub fn prune_weights(&mut self, sparsity: f64) {
        for l in &mut self.layers {
            for w in [
                &mut l.wq, &mut l.wk, &mut l.wv, &mut l.wo, &mut l.wgate, &mut l.wup,
                &mut l.wdown,
            ] {
                magnitude_prune_inplace(w, sparsity);
            }
        }
    }

    /// Forward over one sequence → per-position logits `[S, vocab]`,
    /// using the plain f32 linear op (the numerics oracle).
    pub fn forward(&self, tokens: &[u8], kv: KvTreatment) -> Vec<f32> {
        self.forward_impl(tokens, kv, &mut |x, rows, inner, w, cols| {
            gemm(x, rows, inner, w, cols)
        })
    }

    /// Forward with every projection dispatched through a [`Backend`]:
    /// weights are packed per matrix and routed to the sparse kernel
    /// when they are meaningfully sparse (the paper's automatic
    /// linear-layer replacement, at tiny-model scale). Ticks `ctr` with
    /// the kernel events of every projection.
    pub fn forward_backend(
        &self,
        tokens: &[u8],
        kv: KvTreatment,
        backend: &Backend,
        ctr: &mut EventCounters,
    ) -> Vec<f32> {
        let mut cache = PackCache::default();
        self.forward_backend_cached(tokens, kv, backend, &mut cache, ctr)
    }

    /// [`TinyModel::forward_backend`] with an explicit operand cache so
    /// repeated forwards (evaluation over many chunks) pack each weight
    /// matrix once — the paper's "preprocessing happens once" (§7).
    pub fn forward_backend_cached<'m>(
        &'m self,
        tokens: &[u8],
        kv: KvTreatment,
        backend: &Backend,
        cache: &mut PackCache<'m>,
        ctr: &mut EventCounters,
    ) -> Vec<f32> {
        self.forward_impl(tokens, kv, &mut |x, rows, inner, w, cols| {
            backend_linear(backend, cache, x, rows, inner, w, cols, ctr)
        })
    }

    /// Shared forward skeleton; `linear(x, rows, inner, w, cols)` is the
    /// dispatched matmul (`x: rows × inner` row-major against a
    /// row-major `inner × cols` weight matrix).
    fn forward_impl(
        &self,
        tokens: &[u8],
        kv: KvTreatment,
        linear: &mut dyn FnMut(&[f32], usize, usize, &[f32], usize) -> Vec<f32>,
    ) -> Vec<f32> {
        let s = tokens.len();
        let (h_dim, heads, kvh, hd) = (self.hidden, self.heads, self.kv_heads, self.head_dim);
        let group = heads / kvh;
        let mut h = vec![0f32; s * h_dim];
        for (t, &tok) in tokens.iter().enumerate() {
            h[t * h_dim..(t + 1) * h_dim]
                .copy_from_slice(&self.emb[tok as usize * h_dim..(tok as usize + 1) * h_dim]);
        }
        for layer in &self.layers {
            let x = rmsnorm_rows(&h, s, h_dim, &layer.ln1);
            let mut q = linear(&x, s, h_dim, &layer.wq, heads * hd);
            let mut k = linear(&x, s, h_dim, &layer.wk, kvh * hd);
            let v = linear(&x, s, h_dim, &layer.wv, kvh * hd);
            rope_rows(&mut q, s, heads, hd);
            rope_rows(&mut k, s, kvh, hd);
            // KV-cache treatment: prune/quantize the cached K and V
            let k = treat(&k, s, kvh, hd, kv.k_sparsity, kv.int8);
            let v = treat(&v, s, kvh, hd, kv.v_sparsity, kv.int8);
            // causal GQA attention
            let mut ctx = vec![0f32; s * heads * hd];
            let scale = 1.0 / (hd as f32).sqrt();
            for qh in 0..heads {
                let khh = qh / group;
                for t in 0..s {
                    // scores over positions 0..=t
                    let qrow = &q[(t * heads + qh) * hd..(t * heads + qh) * hd + hd];
                    let mut scores = Vec::with_capacity(t + 1);
                    for u in 0..=t {
                        let krow = &k[(u * kvh + khh) * hd..(u * kvh + khh) * hd + hd];
                        let dot: f32 = qrow.iter().zip(krow).map(|(a, b)| a * b).sum();
                        scores.push(dot * scale);
                    }
                    crate::kvcache::attention::softmax(&mut scores);
                    let out = &mut ctx[(t * heads + qh) * hd..(t * heads + qh) * hd + hd];
                    for (u, &p) in scores.iter().enumerate() {
                        let vrow = &v[(u * kvh + khh) * hd..(u * kvh + khh) * hd + hd];
                        for d in 0..hd {
                            out[d] += p * vrow[d];
                        }
                    }
                }
            }
            let o = linear(&ctx, s, heads * hd, &layer.wo, h_dim);
            add_inplace(&mut h, &o);
            let x = rmsnorm_rows(&h, s, h_dim, &layer.ln2);
            let gate = linear(&x, s, h_dim, &layer.wgate, self.inter);
            let up = linear(&x, s, h_dim, &layer.wup, self.inter);
            let act: Vec<f32> = gate
                .iter()
                .zip(up.iter())
                .map(|(&g, &u)| silu(g) * u)
                .collect();
            let down = linear(&act, s, self.inter, &layer.wdown, h_dim);
            add_inplace(&mut h, &down);
        }
        let xf = rmsnorm_rows(&h, s, h_dim, &self.ln_f);
        linear(&xf, s, h_dim, &self.lm_head, self.vocab)
    }

    /// NLL / perplexity / top-1 accuracy of next-token prediction over a
    /// token stream, chunked into `chunk`-length sequences (plain f32
    /// oracle path).
    pub fn evaluate(&self, stream: &[u8], chunk: usize, kv: KvTreatment) -> EvalResult {
        self.evaluate_impl(stream, chunk, &mut |seq| self.forward(seq, kv))
    }

    /// [`TinyModel::evaluate`] with every projection dispatched through
    /// `backend`. Weights are packed once (cached across chunks) and
    /// the kernel events of the whole evaluation accumulate into `ctr`
    /// for the caller to report.
    pub fn evaluate_backend(
        &self,
        stream: &[u8],
        chunk: usize,
        kv: KvTreatment,
        backend: &Backend,
        ctr: &mut EventCounters,
    ) -> EvalResult {
        let mut cache = PackCache::default();
        self.evaluate_impl(stream, chunk, &mut |seq| {
            self.forward_backend_cached(seq, kv, backend, &mut cache, ctr)
        })
    }

    fn evaluate_impl(
        &self,
        stream: &[u8],
        chunk: usize,
        forward: &mut dyn FnMut(&[u8]) -> Vec<f32>,
    ) -> EvalResult {
        assert!(chunk >= 2);
        let mut nll_sum = 0f64;
        let mut correct = 0usize;
        let mut count = 0usize;
        for seq in stream.chunks(chunk) {
            if seq.len() < 2 {
                continue;
            }
            let logits = forward(seq);
            for t in 0..seq.len() - 1 {
                let row = &logits[t * self.vocab..(t + 1) * self.vocab];
                let target = seq[t + 1] as usize;
                let (logp, am) = log_softmax_at(row, target);
                nll_sum -= logp as f64;
                correct += usize::from(am == target);
                count += 1;
            }
        }
        let nll = nll_sum / count.max(1) as f64;
        EvalResult {
            nll,
            ppl: nll.exp(),
            top1: correct as f64 / count.max(1) as f64,
            tokens: count,
        }
    }
}

/// Prune and/or INT8-roundtrip a cached tensor, per head. Shared with
/// the native decode path ([`crate::models::plan`]) so prefill applies
/// the same per-head KV treatment as full-sequence evaluation.
pub(crate) fn treat(
    x: &[f32],
    s: usize,
    heads: usize,
    hd: usize,
    sparsity: f64,
    int8: bool,
) -> Vec<f32> {
    let mut out = x.to_vec();
    if sparsity > 0.0 {
        // per-head grouping: gather each head's values across positions
        for h in 0..heads {
            let mut vals: Vec<f32> = (0..s * hd)
                .map(|i| x[(i / hd * heads + h) * hd + i % hd])
                .collect();
            vals = magnitude_prune(&vals, sparsity);
            for (i, v) in vals.iter().enumerate() {
                out[(i / hd * heads + h) * hd + i % hd] = *v;
            }
        }
    }
    if int8 {
        for h in 0..heads {
            let mut amax = 0f32;
            for t in 0..s {
                for d in 0..hd {
                    amax = amax.max(out[(t * heads + h) * hd + d].abs());
                }
            }
            let scale = if amax > 0.0 { amax / 127.0 } else { 1.0 };
            for t in 0..s {
                for d in 0..hd {
                    let i = (t * heads + h) * hd + d;
                    out[i] = (out[i] / scale).round().clamp(-127.0, 127.0) * scale;
                }
            }
        }
    }
    out
}

/// Fraction of zero weights above which a matrix is packed sparse and
/// dispatched to the backend's sparse kernel (the bitmap costs 1/16 of
/// dense, so sparsity must clear that overhead to pay off — Fig 6).
const SPARSE_DISPATCH_THRESHOLD: f64 = 0.25;

/// Packed-operand cache keyed by the weight matrix's data pointer +
/// length. The lifetime parameter ties the cache to a borrow of the
/// model whose weights it packed, so the borrow checker rejects using
/// a cache after that model is dropped (when an allocator could hand
/// another model the same address). Weights are immutable while the
/// cache is alive, so keys stay stable. One cache serves one backend:
/// the dense-class operand layout is chosen per backend kind (the
/// shared [`PackedOperand`] policy).
#[derive(Default)]
pub struct PackCache<'m> {
    packed: std::collections::HashMap<(usize, usize), PackedOperand>,
    _model: std::marker::PhantomData<&'m TinyModel>,
}

/// One backend-dispatched projection: pack on first sight (dense vs
/// sparse class by the matrix's actual zero fraction), then reuse.
fn backend_linear(
    backend: &Backend,
    cache: &mut PackCache<'_>,
    x: &[f32],
    rows: usize,
    inner: usize,
    w: &[f32],
    cols: usize,
    ctr: &mut EventCounters,
) -> Vec<f32> {
    debug_assert_eq!(x.len(), rows * inner);
    debug_assert_eq!(w.len(), inner * cols);
    let key = (w.as_ptr() as usize, w.len());
    let packed = cache.packed.entry(key).or_insert_with(|| {
        let zeros = w.iter().filter(|&&v| v == 0.0).count();
        let use_sparse = (zeros as f64) > SPARSE_DISPATCH_THRESHOLD * w.len() as f64;
        PackedOperand::pack_f32(backend, w, inner, cols, use_sparse)
    });
    packed.gemm_bf16(backend, x, rows, ctr)
}

fn gemm(x: &[f32], rows: usize, inner: usize, w: &[f32], cols: usize) -> Vec<f32> {
    debug_assert_eq!(x.len(), rows * inner);
    debug_assert_eq!(w.len(), inner * cols);
    let mut out = vec![0f32; rows * cols];
    for r in 0..rows {
        for k in 0..inner {
            let xv = x[r * inner + k];
            if xv == 0.0 {
                continue;
            }
            let wrow = &w[k * cols..(k + 1) * cols];
            let orow = &mut out[r * cols..(r + 1) * cols];
            for c in 0..cols {
                orow[c] += xv * wrow[c];
            }
        }
    }
    out
}

pub(crate) fn rmsnorm_rows(x: &[f32], rows: usize, dim: usize, g: &[f32]) -> Vec<f32> {
    let mut out = vec![0f32; rows * dim];
    for r in 0..rows {
        let row = &x[r * dim..(r + 1) * dim];
        let ms: f32 = row.iter().map(|v| v * v).sum::<f32>() / dim as f32;
        let inv = 1.0 / (ms + 1e-5).sqrt();
        for d in 0..dim {
            out[r * dim + d] = row[d] * g[d] * inv;
        }
    }
    out
}

/// Rotary embedding matching `model.py::rope` (half-split layout).
fn rope_rows(x: &mut [f32], s: usize, heads: usize, hd: usize) {
    rope_rows_from(x, s, heads, hd, 0)
}

/// [`rope_rows`] with an absolute starting position, for incremental
/// decode: row `t` of `x` is rotated as sequence position `start + t`.
pub(crate) fn rope_rows_from(x: &mut [f32], s: usize, heads: usize, hd: usize, start: usize) {
    let half = hd / 2;
    for t in 0..s {
        for h in 0..heads {
            let base = (t * heads + h) * hd;
            for i in 0..half {
                let freq = 1.0 / 10000f32.powf(i as f32 / half as f32);
                let angle = (start + t) as f32 * freq;
                let (sin, cos) = angle.sin_cos();
                let a = x[base + i];
                let b = x[base + half + i];
                x[base + i] = a * cos - b * sin;
                x[base + half + i] = a * sin + b * cos;
            }
        }
    }
}

pub(crate) fn add_inplace(a: &mut [f32], b: &[f32]) {
    for (x, y) in a.iter_mut().zip(b.iter()) {
        *x += y;
    }
}

pub(crate) fn silu(x: f32) -> f32 {
    x / (1.0 + (-x).exp())
}

/// log-softmax value at `target` plus the argmax index.
fn log_softmax_at(row: &[f32], target: usize) -> (f32, usize) {
    let mut max = f32::NEG_INFINITY;
    let mut am = 0;
    for (i, &v) in row.iter().enumerate() {
        if v > max {
            max = v;
            am = i;
        }
    }
    let logsum: f32 = row.iter().map(|&v| (v - max).exp()).sum::<f32>().ln() + max;
    (row[target] - logsum, am)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy_model() -> TinyModel {
        // deterministic small random model for invariant tests
        let mut g = crate::util::XorShift::new(42);
        let (h, inter, heads, kvh, hd, vocab) = (16, 24, 4, 2, 4, 32);
        let mut mk = |n: usize| g.normal_vec(n, 0.3);
        TinyModel {
            hidden: h,
            inter,
            heads,
            kv_heads: kvh,
            head_dim: hd,
            vocab,
            emb: mk(vocab * h),
            layers: (0..2)
                .map(|_| LayerW {
                    ln1: vec![1.0; h],
                    wq: mk(h * heads * hd),
                    wk: mk(h * kvh * hd),
                    wv: mk(h * kvh * hd),
                    wo: mk(heads * hd * h),
                    ln2: vec![1.0; h],
                    wgate: mk(h * inter),
                    wup: mk(h * inter),
                    wdown: mk(inter * h),
                })
                .collect(),
            ln_f: vec![1.0; h],
            lm_head: mk(h * vocab),
        }
    }

    #[test]
    fn forward_shapes_and_finite() {
        let m = toy_model();
        let logits = m.forward(&[1, 2, 3, 4, 5], KvTreatment::default());
        assert_eq!(logits.len(), 5 * m.vocab);
        assert!(logits.iter().all(|x| x.is_finite()));
    }

    #[test]
    fn backend_forward_tracks_oracle_forward() {
        // The backend-dispatched path rounds through BF16, so it drifts
        // from the f32 oracle only by rounding noise; AMX and the
        // reference backend must agree tightly with each other.
        let m = toy_model();
        let tokens = [1u8, 5, 9, 2, 7];
        let plain = m.forward(&tokens, KvTreatment::default());
        let mut c_amx = EventCounters::default();
        let amx = m.forward_backend(&tokens, KvTreatment::default(), &Backend::amx(), &mut c_amx);
        let mut c_ref = EventCounters::default();
        let oracle =
            m.forward_backend(&tokens, KvTreatment::default(), &Backend::reference(), &mut c_ref);
        assert_eq!(amx.len(), plain.len());
        for i in 0..amx.len() {
            assert!((amx[i] - oracle[i]).abs() < 0.15, "amx vs ref at {i}");
            assert!((amx[i] - plain[i]).abs() < 0.5, "amx vs f32 at {i}");
        }
        assert!(c_amx.tdp_bf16 > 0, "dense projections use tile compute");
    }

    #[test]
    fn backend_forward_dispatches_sparse_after_pruning() {
        let mut m = toy_model();
        m.prune_weights(0.6);
        let mut ctr = EventCounters::default();
        let _ = m.forward_backend(&[1, 2, 3], KvTreatment::default(), &Backend::amx(), &mut ctr);
        assert!(
            ctr.vpexpand > 0,
            "pruned projections must route to the sparse kernel"
        );
    }

    #[test]
    fn evaluate_backend_counts_like_oracle_and_surfaces_events() {
        let m = toy_model();
        let stream: Vec<u8> = (0..40).map(|i| (i % 30) as u8).collect();
        let plain = m.evaluate(&stream, 10, KvTreatment::default());
        let b = Backend::amx();
        let mut ctr = EventCounters::default();
        let routed = m.evaluate_backend(&stream, 10, KvTreatment::default(), &b, &mut ctr);
        assert_eq!(routed.tokens, plain.tokens);
        assert!((routed.nll - plain.nll).abs() < 0.5, "{} vs {}", routed.nll, plain.nll);
        assert!(ctr.instructions() > 0, "kernel events must reach the caller");
        // weights pack once: unique weight bytes are counted per kernel
        // call, so the 4-chunk eval must tick exactly 4x one forward's
        // worth of tile compute — sanity that caching didn't skip work
        assert_eq!(ctr.tdp_bf16 % 4, 0);
    }

    #[test]
    fn causality_prefix_invariance() {
        // logits at position t must not depend on tokens after t
        let m = toy_model();
        let a = m.forward(&[1, 2, 3, 9, 9], KvTreatment::default());
        let b = m.forward(&[1, 2, 3, 4, 5], KvTreatment::default());
        for i in 0..3 * m.vocab {
            assert!(
                (a[i] - b[i]).abs() < 1e-4,
                "position {} leaked future tokens",
                i / m.vocab
            );
        }
    }

    #[test]
    fn evaluate_counts_predictions() {
        let m = toy_model();
        let stream: Vec<u8> = (0..40).map(|i| (i % 30) as u8).collect();
        let r = m.evaluate(&stream, 10, KvTreatment::default());
        assert_eq!(r.tokens, 36); // 4 chunks × 9 predictions
        assert!(r.nll > 0.0 && r.ppl > 1.0);
        assert!((0.0..=1.0).contains(&r.top1));
    }

    #[test]
    fn kv_pruning_degrades_gracefully() {
        let m = toy_model();
        let stream: Vec<u8> = (0..60).map(|i| (i * 7 % 31) as u8).collect();
        let base = m.evaluate(&stream, 20, KvTreatment::default());
        let light = m.evaluate(
            &stream,
            20,
            KvTreatment {
                k_sparsity: 0.2,
                v_sparsity: 0.2,
                int8: false,
            },
        );
        let heavy = m.evaluate(
            &stream,
            20,
            KvTreatment {
                k_sparsity: 0.9,
                v_sparsity: 0.9,
                int8: false,
            },
        );
        assert!(light.nll < heavy.nll, "heavier pruning must hurt more");
        assert!(base.nll <= light.nll + 0.5);
    }

    #[test]
    fn int8_kv_is_mild() {
        let m = toy_model();
        let stream: Vec<u8> = (0..40).map(|i| (i * 3 % 29) as u8).collect();
        let base = m.evaluate(&stream, 20, KvTreatment::default());
        let q = m.evaluate(
            &stream,
            20,
            KvTreatment {
                int8: true,
                ..Default::default()
            },
        );
        assert!((q.nll - base.nll).abs() < 0.2, "int8 KV should be mild");
    }

    #[test]
    fn weight_pruning_pushes_toward_uniform() {
        // An untrained toy model has no quality to lose, so assert the
        // mechanistic effect instead: near-total pruning collapses the
        // logits toward the uniform distribution (NLL → ln(vocab)).
        let mut m0 = toy_model();
        let stream: Vec<u8> = (0..40).map(|i| (i * 5 % 23) as u8).collect();
        let base = m0.evaluate(&stream, 20, KvTreatment::default());
        m0.prune_weights(0.98);
        let pruned = m0.evaluate(&stream, 20, KvTreatment::default());
        let uniform = (m0.vocab as f64).ln();
        assert!(
            (pruned.nll - uniform).abs() < (base.nll - uniform).abs(),
            "pruned NLL {:.3} should be closer to uniform {:.3} than base {:.3}",
            pruned.nll,
            uniform,
            base.nll
        );
        assert!((pruned.nll - base.nll).abs() > 1e-6, "pruning must change NLL");
    }
}
