//! Model shape configurations and the layer inventory driving the cost
//! model, plus the synthetic weight store used by simulator experiments.

pub mod llama;
pub mod plan;
pub mod weights;
pub mod tinyforward;

pub use llama::{LinearShape, ModelConfig};
pub use plan::{
    plan_model, plan_model_regimes, BatchFuseChoice, DecodePlan, ModelPlan, NativeModel,
    RegimeBatches,
};
