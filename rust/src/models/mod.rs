//! Model shape configurations and the layer inventory driving the cost
//! model, plus the synthetic weight store used by simulator experiments.

pub mod llama;
pub mod weights;
pub mod tinyforward;

pub use llama::{LinearShape, ModelConfig};
