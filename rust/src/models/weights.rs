//! Synthetic weight store: deterministic per-layer weight synthesis +
//! pruning + packing, with an LRU-less lazy cache so full-model sweeps
//! don't re-pack layers they already visited.
//!
//! Kernel performance depends only on shape and sparsity (DESIGN.md §2),
//! so simulator experiments use synthetic normal weights; the *served*
//! model's real weights come from `artifacts/weights.bin`.

use super::llama::LinearShape;
use crate::sparse::format::SparseTensor;
use crate::sparse::prune::magnitude_prune_inplace;
use crate::util::XorShift;

/// Deterministically synthesize a dense `in × out` weight matrix for a
/// named layer (seeded by name + dims so every run agrees).
pub fn synth_dense(shape: &LinearShape, seed: u64) -> Vec<f32> {
    let mut h = seed;
    for b in shape.name.bytes() {
        h = h.wrapping_mul(0x100000001b3).wrapping_add(b as u64);
    }
    h = h
        .wrapping_add(shape.in_features as u64)
        .wrapping_mul(31)
        .wrapping_add(shape.out_features as u64);
    let mut g = XorShift::new(h);
    // He-style init scale
    let scale = (2.0 / shape.in_features as f32).sqrt();
    g.normal_vec(shape.params(), scale)
}

/// Synthesize, prune to `sparsity`, and pack a layer.
pub fn synth_sparse(shape: &LinearShape, sparsity: f64, seed: u64) -> SparseTensor {
    let mut w = synth_dense(shape, seed);
    magnitude_prune_inplace(&mut w, sparsity);
    SparseTensor::pack_f32(&w, shape.in_features, shape.out_features)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn shape() -> LinearShape {
        LinearShape::new("q_proj", 128, 64)
    }

    #[test]
    fn synthesis_is_deterministic() {
        assert_eq!(synth_dense(&shape(), 7), synth_dense(&shape(), 7));
    }

    #[test]
    fn different_layers_differ() {
        let a = synth_dense(&LinearShape::new("q_proj", 128, 64), 7);
        let b = synth_dense(&LinearShape::new("k_proj", 128, 64), 7);
        assert_ne!(a, b);
    }

    #[test]
    fn packed_sparsity_close_to_requested() {
        let sp = synth_sparse(&shape(), 0.5, 1);
        assert!((sp.sparsity() - 0.5).abs() < 0.02, "{}", sp.sparsity());
        assert_eq!(sp.rows, 128);
        assert_eq!(sp.cols, 64);
    }

    #[test]
    fn init_scale_tracks_fan_in() {
        let wide = synth_dense(&LinearShape::new("x", 4096, 8), 1);
        let narrow = synth_dense(&LinearShape::new("x", 16, 8), 1);
        let var = |v: &[f32]| v.iter().map(|x| x * x).sum::<f32>() / v.len() as f32;
        assert!(var(&wide) < var(&narrow));
    }
}
