//! Llama-family model shape configs (the paper evaluates Llama 3 8B,
//! smaller Llama 3.2 variants, and INT8 Llama 2 7B).
//!
//! Only *shapes* matter for kernel performance; weight values come from
//! [`super::weights`] (synthetic) or the build-time-trained tiny
//! checkpoint for accuracy experiments.

/// One linear layer's GEMM shape: `in_features × out_features`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct LinearShape {
    pub name: &'static str,
    pub in_features: usize,
    pub out_features: usize,
}

impl LinearShape {
    pub const fn new(name: &'static str, i: usize, o: usize) -> LinearShape {
        LinearShape {
            name,
            in_features: i,
            out_features: o,
        }
    }

    /// Parameter count.
    pub fn params(&self) -> usize {
        self.in_features * self.out_features
    }
}

/// Transformer decoder shape config.
#[derive(Clone, Debug, PartialEq)]
pub struct ModelConfig {
    pub name: String,
    pub hidden: usize,
    pub intermediate: usize,
    pub layers: usize,
    pub heads: usize,
    pub kv_heads: usize,
    pub head_dim: usize,
    pub vocab: usize,
}

impl ModelConfig {
    /// Llama 3 8B (the paper's main model).
    pub fn llama3_8b() -> ModelConfig {
        ModelConfig {
            name: "llama3-8b".into(),
            hidden: 4096,
            intermediate: 14336,
            layers: 32,
            heads: 32,
            kv_heads: 8,
            head_dim: 128,
            vocab: 128_256,
        }
    }

    /// Llama 3.2 3B.
    pub fn llama32_3b() -> ModelConfig {
        ModelConfig {
            name: "llama3.2-3b".into(),
            hidden: 3072,
            intermediate: 8192,
            layers: 28,
            heads: 24,
            kv_heads: 8,
            head_dim: 128,
            vocab: 128_256,
        }
    }

    /// Llama 3.2 1B.
    pub fn llama32_1b() -> ModelConfig {
        ModelConfig {
            name: "llama3.2-1b".into(),
            hidden: 2048,
            intermediate: 8192,
            layers: 16,
            heads: 32,
            kv_heads: 8,
            head_dim: 64,
            vocab: 128_256,
        }
    }

    /// Llama 2 7B (the DeepSparse INT8 comparison model, Fig 13).
    pub fn llama2_7b() -> ModelConfig {
        ModelConfig {
            name: "llama2-7b".into(),
            hidden: 4096,
            intermediate: 11008,
            layers: 32,
            heads: 32,
            kv_heads: 32,
            head_dim: 128,
            vocab: 32_000,
        }
    }

    /// The tiny build-time-trained model served end-to-end (DESIGN.md §2):
    /// byte-level vocab, 2 layers, GQA. Must match
    /// `python/compile/model.py::TINY_CONFIG`.
    pub fn tiny() -> ModelConfig {
        ModelConfig {
            name: "tiny-1m".into(),
            hidden: 128,
            intermediate: 352,
            layers: 2,
            heads: 4,
            kv_heads: 2,
            head_dim: 32,
            vocab: 256,
        }
    }

    /// Look up a config by name.
    pub fn by_name(name: &str) -> Option<ModelConfig> {
        match name {
            "llama3-8b" => Some(Self::llama3_8b()),
            "llama3.2-3b" => Some(Self::llama32_3b()),
            "llama3.2-1b" => Some(Self::llama32_1b()),
            "llama2-7b" => Some(Self::llama2_7b()),
            "tiny-1m" | "tiny" => Some(Self::tiny()),
            _ => None,
        }
    }

    /// KV-projection output width (GQA: kv_heads × head_dim).
    pub fn kv_dim(&self) -> usize {
        self.kv_heads * self.head_dim
    }

    /// The seven per-layer linear shapes (paper Table 2 rows).
    pub fn layer_linears(&self) -> Vec<LinearShape> {
        vec![
            LinearShape::new("q_proj", self.hidden, self.heads * self.head_dim),
            LinearShape::new("k_proj", self.hidden, self.kv_dim()),
            LinearShape::new("v_proj", self.hidden, self.kv_dim()),
            LinearShape::new("o_proj", self.heads * self.head_dim, self.hidden),
            LinearShape::new("gate_proj", self.hidden, self.intermediate),
            LinearShape::new("up_proj", self.hidden, self.intermediate),
            LinearShape::new("down_proj", self.intermediate, self.hidden),
        ]
    }

    /// LM head shape (tied embeddings are not assumed).
    pub fn lm_head(&self) -> LinearShape {
        LinearShape::new("lm_head", self.hidden, self.vocab)
    }

    /// Total linear-layer parameters across the model (decoder + head).
    pub fn linear_params(&self) -> usize {
        self.layers * self.layer_linears().iter().map(|l| l.params()).sum::<usize>()
            + self.lm_head().params()
    }

    /// KV-cache bytes per token (BF16): 2 (K and V) × kv_dim × layers × 2B.
    pub fn kv_bytes_per_token(&self) -> usize {
        2 * self.kv_dim() * self.layers * 2
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn llama3_8b_matches_paper_table2_shapes() {
        let m = ModelConfig::llama3_8b();
        let lin = m.layer_linears();
        let find = |n: &str| lin.iter().find(|l| l.name == n).unwrap();
        assert_eq!(
            (find("q_proj").in_features, find("q_proj").out_features),
            (4096, 4096)
        );
        assert_eq!(
            (find("k_proj").in_features, find("k_proj").out_features),
            (4096, 1024)
        );
        assert_eq!(
            (find("up_proj").in_features, find("up_proj").out_features),
            (4096, 14336)
        );
        assert_eq!(
            (find("down_proj").in_features, find("down_proj").out_features),
            (14336, 4096)
        );
    }

    #[test]
    fn parameter_counts_are_plausible() {
        // linear params ≈ 7B for Llama 3 8B (embeddings excluded)
        let p = ModelConfig::llama3_8b().linear_params() as f64;
        assert!((6.0e9..8.0e9).contains(&p), "params={p}");
        let p1 = ModelConfig::llama32_1b().linear_params() as f64;
        assert!(p1 < 2.0e9);
    }

    #[test]
    fn model_size_ordering() {
        let sizes: Vec<usize> = ["llama3.2-1b", "llama3.2-3b", "llama3-8b"]
            .iter()
            .map(|n| ModelConfig::by_name(n).unwrap().linear_params())
            .collect();
        assert!(sizes[0] < sizes[1] && sizes[1] < sizes[2]);
    }

    #[test]
    fn tiny_model_is_gqa() {
        let t = ModelConfig::tiny();
        assert!(t.kv_heads < t.heads);
        assert_eq!(t.heads * t.head_dim, t.hidden);
    }

    #[test]
    fn by_name_unknown_is_none() {
        assert!(ModelConfig::by_name("gpt-5").is_none());
    }

    #[test]
    fn kv_bytes_per_token_llama3() {
        // 2 * 1024 * 32 layers * 2 bytes = 131072
        assert_eq!(ModelConfig::llama3_8b().kv_bytes_per_token(), 131_072);
    }
}
