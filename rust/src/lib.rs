//! # SparAMX — unstructured sparsity for memory-bound LLM decode
//!
//! Rust + JAX + Pallas reproduction of *"SparAMX: Accelerating Compressed
//! LLMs Token Generation on AMX-powered CPUs"* (AbouElhamayed et al., 2025).
//!
//! The library is organized in three layers (see `DESIGN.md`):
//!
//! * **Layer 1/2 (build time, Python)** — Pallas kernels + a JAX Llama-style
//!   model, AOT-lowered to HLO text under `artifacts/`.
//! * **Layer 3 (this crate)** — the serving coordinator, the paper's sparse
//!   weight format, a functional AMX/AVX-512 instruction simulator, a
//!   Sapphire-Rapids cost model that regenerates every table and figure of
//!   the paper, and a PJRT runtime that executes the AOT artifacts.
//!
//! Module map:
//!
//! | module | role |
//! |---|---|
//! | [`util`] | PRNG, bf16, stats, thread pool, CLI, logging substrates |
//! | [`cfg`] | config structs + minimal JSON parser |
//! | [`sparse`] | bitmap+values format, magnitude pruning, thread partition |
//! | [`amx`] | AMX tile + AVX-512 instruction simulator and the four kernels |
//! | [`backend`] | `LinearBackend` dispatch: capability probing, registry, sparsity-aware selection |
//! | [`shard`] | NUMA/core-partitioned sharded execution: shard plans, persistent worker pool, `ShardedBackend` |
//! | [`fault`] | deterministic fault injection: `FaultPlan` grammar, counter-based seams, failure records |
//! | [`perf`] | Sapphire Rapids memory/cost model, pipeline slots, roofline |
//! | [`models`] | Llama-family shape configs, synthetic weights, per-layer decode plans + native forward |
//! | [`kvcache`] | §6.2 static-sparse + dynamic-dense KV cache manager |
//! | [`baselines`] | PyTorch / DeepSparse / llama.cpp cost models |
//! | [`runtime`] | PJRT client wrapper, HLO artifact loader, executor |
//! | [`coordinator`] | request queue, continuous batcher, engine (native + PJRT paths), server |
//! | [`bench`] | criterion-lite measurement harness |

pub mod util;
pub mod cfg;
pub mod sparse;
pub mod amx;
pub mod backend;
pub mod shard;
pub mod fault;
pub mod perf;
pub mod models;
pub mod kvcache;
pub mod baselines;
pub mod runtime;
pub mod coordinator;
pub mod bench;

/// Crate version string reported by the CLI and the server banner.
pub const VERSION: &str = env!("CARGO_PKG_VERSION");
