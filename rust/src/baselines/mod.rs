//! Cost models of the comparison systems (stock PyTorch, DeepSparse,
//! llama.cpp) — see DESIGN.md §2 for the substitution rationale.
pub mod systems;
