//! Cost models of the comparison systems in the paper's figures.
//!
//! Each baseline is characterized by its kernel class — exactly how the
//! paper itself describes them (§5, §7):
//!
//! * **Stock PyTorch** — dense AMX GEMMs via oneDNN, plus per-op
//!   framework dispatch overhead. (The paper's primary baseline; it
//!   "utilizes AMX when available".)
//! * **DeepSparse** — sparse **AVX**-class kernels with additional
//!   proprietary fusion (modeled as a fixed efficiency factor), no AMX.
//! * **llama.cpp** — dense AVX INT8 kernels, minimal overhead.
//! * **SparAMX** (ours) — the simulated sparse/dense AMX kernels.
//!
//! To *execute* a baseline's kernel class through the unified dispatch
//! API (not just cost it), wrap it in
//! [`crate::backend::BaselineBackend`].

use crate::models::llama::ModelConfig;
use crate::perf::analytic;
use crate::perf::cost::KernelCost;
use crate::perf::Machine;

/// Which system executes the decode step.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Baseline {
    /// Stock PyTorch (dense AMX + framework overhead).
    PyTorch,
    /// Our dense AMX kernel (no framework overhead).
    SparAmxDense,
    /// Our sparse AMX kernel at the model's weight sparsity.
    SparAmxSparse,
    /// Our sparse AVX kernel (`column_groups` fixed at 16).
    SparAvxSparse,
    /// DeepSparse-like: sparse AVX + fusion bonus, INT8 only in Fig 13.
    DeepSparse,
    /// llama.cpp-like: dense AVX INT8.
    LlamaCpp,
}

/// Precision of the modeled weights.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Precision {
    Bf16,
    Int8,
}

/// DeepSparse's extra fusion/scheduling advantage over our un-fused AVX
/// kernel class (operator fusion the paper credits OpenVINO/DeepSparse
/// with but keeps out of scope for SparAMX).
const DEEPSPARSE_FUSION_SPEEDUP: f64 = 1.25;

/// Modeled time for all *linear layers* of one decode step.
pub fn linear_stack_cost(
    model: &ModelConfig,
    baseline: Baseline,
    precision: Precision,
    batch: usize,
    sparsity: f64,
    m: &Machine,
) -> f64 {
    let mut total = 0.0;
    for layer in model.layer_linears() {
        let (k, n) = (layer.in_features, layer.out_features);
        total += linear_cost(baseline, precision, batch, k, n, sparsity, m);
    }
    total *= model.layers as f64;
    // LM head runs once, always dense in every system (never pruned)
    let head = model.lm_head();
    total += linear_cost(
        match baseline {
            Baseline::SparAmxSparse => Baseline::SparAmxDense,
            Baseline::DeepSparse => Baseline::LlamaCpp, // dense AVX class
            b => b,
        },
        precision,
        batch,
        head.in_features,
        head.out_features,
        0.0,
        m,
    );
    total
}

/// Modeled time of one linear of shape `k × n` on a baseline.
pub fn linear_cost(
    baseline: Baseline,
    precision: Precision,
    batch: usize,
    k: usize,
    n: usize,
    sparsity: f64,
    m: &Machine,
) -> f64 {
    let nnz = ((1.0 - sparsity.clamp(0.0, 1.0)) * (k * n) as f64).round() as usize;
    let ctr = match (baseline, precision) {
        (Baseline::PyTorch | Baseline::SparAmxDense, Precision::Bf16) => {
            analytic::dense_bf16(batch, k, n)
        }
        (Baseline::PyTorch | Baseline::SparAmxDense | Baseline::LlamaCpp, Precision::Int8) => {
            analytic::dense_int8(batch, k, n)
        }
        (Baseline::SparAmxSparse, Precision::Bf16) => analytic::sparse_bf16(batch, k, n, nnz),
        (Baseline::SparAmxSparse, Precision::Int8) => analytic::sparse_int8(batch, k, n, nnz),
        (Baseline::SparAvxSparse | Baseline::DeepSparse, _) => {
            // AVX class; INT8 halves the value-stream bytes, which the
            // bf16 counter model approximates by halving nnz bytes — use
            // the bf16 counters and rescale below.
            analytic::avx_sparse_bf16(batch, k, n, nnz, 16)
        }
        (Baseline::LlamaCpp, Precision::Bf16) => {
            // llama.cpp on CPU runs AVX dense; model as dense AVX = AVX
            // sparse with nnz = all elements and no bitmap saving.
            analytic::avx_sparse_bf16(batch, k, n, k * n, 16)
        }
    };
    let cost = KernelCost::from_counters(&ctr, m);
    let mut time = cost.time;
    // INT8 on the AVX classes: half the weight-value bytes of bf16
    // (shared with the AVX backend's prediction so `BaselineBackend`
    // and `AvxBackend` agree)
    if precision == Precision::Int8
        && matches!(baseline, Baseline::SparAvxSparse | Baseline::DeepSparse)
    {
        time = crate::backend::avx::int8_time(&cost);
    }
    match baseline {
        Baseline::PyTorch => time + m.framework_overhead_s,
        Baseline::DeepSparse => time / DEEPSPARSE_FUSION_SPEEDUP,
        _ => time,
    }
}

/// Modeled attention time for one decode step at `ctx` cached tokens
/// (dense cache, BF16): bandwidth-dominated streaming of K and V.
pub fn attention_cost(model: &ModelConfig, batch: usize, ctx: usize, m: &Machine) -> f64 {
    // per layer: read K and V of shape ctx × kv_dim once per batch row
    let bytes =
        (2 * ctx * model.kv_dim() * 2) as f64 * model.layers as f64 * batch as f64;
    let dram = bytes / (m.effective_bw_gbs() * 1e9);
    // score/softmax compute is minor; charge 2 FLOP/byte at AVX rate
    let flops = 2.0 * bytes;
    let compute = flops / m.peak_avx_bf16_flops();
    dram.max(compute) + 2e-6 * model.layers as f64
}

/// Non-GEMM per-step overhead (norms, RoPE, softmax glue, sampling):
/// roughly proportional to hidden × layers.
pub fn other_cost(model: &ModelConfig, batch: usize, m: &Machine) -> f64 {
    let elems = (model.hidden * model.layers * batch) as f64;
    let bytes = elems * 2.0 * 6.0; // a handful of elementwise passes
    bytes / (m.effective_bw_gbs() * 1e9) + 1e-6 * model.layers as f64
}

/// Full decode-step latency for a baseline (linears + attention + other).
pub fn decode_step_cost(
    model: &ModelConfig,
    baseline: Baseline,
    precision: Precision,
    batch: usize,
    ctx: usize,
    sparsity: f64,
    m: &Machine,
) -> f64 {
    let mut t = linear_stack_cost(model, baseline, precision, batch, sparsity, m)
        + attention_cost(model, batch, ctx, m)
        + other_cost(model, batch, m);
    if baseline == Baseline::PyTorch {
        // PyTorch's eager attention + cache handling overhead per step
        t += m.framework_overhead_s * (2 * model.layers) as f64;
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    fn m32() -> Machine {
        Machine::sapphire_rapids(32)
    }

    #[test]
    fn fig1_shape_sparse_beats_pytorch_end_to_end() {
        // 50% sparsity, ctx 512, batch 1 — the Fig 1 setting.
        let m = m32();
        for cfg in [
            ModelConfig::llama32_1b(),
            ModelConfig::llama32_3b(),
            ModelConfig::llama3_8b(),
        ] {
            let py = decode_step_cost(&cfg, Baseline::PyTorch, Precision::Bf16, 1, 512, 0.0, &m);
            let ours =
                decode_step_cost(&cfg, Baseline::SparAmxSparse, Precision::Bf16, 1, 512, 0.5, &m);
            let speedup = py / ours;
            assert!(
                speedup > 1.05 && speedup < 2.2,
                "{}: speedup {speedup}",
                cfg.name
            );
        }
    }

    #[test]
    fn speedup_grows_with_model_size() {
        // Fig 1: "improvement tends to be greater as model size increases"
        let m = m32();
        let sp = |cfg: &ModelConfig| {
            decode_step_cost(cfg, Baseline::PyTorch, Precision::Bf16, 1, 512, 0.0, &m)
                / decode_step_cost(cfg, Baseline::SparAmxSparse, Precision::Bf16, 1, 512, 0.5, &m)
        };
        let s1 = sp(&ModelConfig::llama32_1b());
        let s8 = sp(&ModelConfig::llama3_8b());
        assert!(s8 > s1, "8B speedup {s8} should exceed 1B speedup {s1}");
    }

    #[test]
    fn linear_layers_dominate_at_short_context() {
        // Fig 3 shape: at ctx 512, linears ≫ attention; at 16K attention
        // catches up substantially.
        let m = m32();
        let cfg = ModelConfig::llama3_8b();
        let lin = linear_stack_cost(&cfg, Baseline::PyTorch, Precision::Bf16, 1, 0.0, &m);
        let att_512 = attention_cost(&cfg, 1, 512, &m);
        let att_16k = attention_cost(&cfg, 1, 16384, &m);
        assert!(lin > 5.0 * att_512, "linears dominate at 512");
        assert!(att_16k > 10.0 * att_512, "attention grows with context");
    }

    #[test]
    fn deepsparse_crossover_at_high_batch() {
        // Fig 13 shape: DeepSparse (AVX) wins at batch 1..4 but our AMX
        // INT8 sparse kernel wins at batch ≥ 16.
        let m = m32();
        let cfg = ModelConfig::llama2_7b();
        let ours_b1 =
            decode_step_cost(&cfg, Baseline::SparAmxSparse, Precision::Int8, 1, 2, 0.5, &m);
        let ds_b1 = decode_step_cost(&cfg, Baseline::DeepSparse, Precision::Int8, 1, 2, 0.5, &m);
        let ours_b32 =
            decode_step_cost(&cfg, Baseline::SparAmxSparse, Precision::Int8, 32, 2, 0.5, &m);
        let ds_b32 = decode_step_cost(&cfg, Baseline::DeepSparse, Precision::Int8, 32, 2, 0.5, &m);
        // throughput = batch / time
        let thr = |b: f64, t: f64| b / t;
        assert!(
            thr(32.0, ours_b32) > thr(32.0, ds_b32),
            "ours must win at batch 32: {} vs {}",
            thr(32.0, ours_b32),
            thr(32.0, ds_b32)
        );
        // and the gap at batch 1 must be smaller than at batch 32
        let gap1 = thr(1.0, ours_b1) / thr(1.0, ds_b1);
        let gap32 = thr(32.0, ours_b32) / thr(32.0, ds_b32);
        assert!(gap32 > gap1, "AMX advantage grows with batch");
    }

    #[test]
    fn pytorch_overhead_visible_on_small_models() {
        let m = m32();
        let tiny = ModelConfig::tiny();
        let py = decode_step_cost(&tiny, Baseline::PyTorch, Precision::Bf16, 1, 64, 0.0, &m);
        let ours = decode_step_cost(&tiny, Baseline::SparAmxDense, Precision::Bf16, 1, 64, 0.0, &m);
        assert!(py > ours, "framework overhead dominates tiny models");
    }
}
