//! `sparamx` CLI — the Layer-3 leader binary.
//!
//! Subcommands:
//!   serve     — start the TCP serving engine (native plan-compiled
//!               decode by default; `--engine pjrt` for AOT artifacts)
//!   generate  — one-shot generation for a prompt (loads engine inline)
//!   eval      — perplexity / accuracy of the tiny checkpoint under
//!               weight and KV sparsity (the paper's §6 experiments)
//!   info      — print artifact + machine-model + decode-plan info

use sparamx::amx::EventCounters;
use sparamx::backend::{BackendChoice, BackendRegistry, CpuCaps, Dtype, GemmShape};
use sparamx::cfg::{EngineChoice, RuntimeConfig};
use sparamx::coordinator::batcher::{AdmissionQueue, LatencyBudget};
use sparamx::coordinator::engine::Engine;
use sparamx::coordinator::server::ServerCtx;
use sparamx::coordinator::{request, server};
use sparamx::models::plan::plan_model_regimes;
use sparamx::models::tinyforward::{KvTreatment, TinyModel};
use sparamx::models::ModelConfig;
use sparamx::perf::Machine;
use sparamx::runtime::artifact::Bundle;
use sparamx::runtime::executor::Runtime;
use sparamx::util::cli::Args;
use std::sync::{mpsc, Arc};
use std::time::Instant;

fn main() {
    let args = Args::from_env();
    let code = match args.command.as_deref() {
        Some("serve") => cmd_serve(&args),
        Some("generate") => cmd_generate(&args),
        Some("eval") => cmd_eval(&args),
        Some("info") => cmd_info(&args),
        _ => {
            eprintln!(
                "sparamx {} — usage:\n  sparamx serve    [--artifacts DIR] [--port P] [--sparsity S] [--backend {b}] [--engine {e}] [--shards {s}] [--max-batch-fuse {f}] [--latency-budget-ms MS] [--faults SPEC] [--checkpoint PATH] [--checkpoint-every-steps N]\n  sparamx generate [--artifacts DIR] [--max-tokens N] [--backend {b}] [--engine {e}] [--shards {s}] [--max-batch-fuse {f}] [--faults SPEC] PROMPT...\n  sparamx eval     [--artifacts DIR] [--sparsity S] [--k-sparsity S] [--v-sparsity S] [--int8-kv] [--backend {b}]\n  sparamx info     [--artifacts DIR] [--cores N] [--model NAME] [--sparsity S] [--shards {s}] [--max-batch-fuse {f}]",
                sparamx::VERSION,
                b = BackendChoice::HELP,
                e = EngineChoice::HELP,
                s = sparamx::shard::ShardChoice::HELP,
                f = sparamx::models::BatchFuseChoice::HELP
            );
            2
        }
    };
    std::process::exit(code);
}

fn config_from(args: &Args) -> RuntimeConfig {
    let mut cfg = match args.options.get("config") {
        Some(path) => RuntimeConfig::from_file(path).expect("load config file"),
        None => RuntimeConfig::default(),
    };
    cfg.artifacts_dir = args.get("artifacts", &cfg.artifacts_dir);
    cfg.port = args.get_parse("port", cfg.port);
    cfg.weight_sparsity = args.get_parse("sparsity", cfg.weight_sparsity);
    cfg.max_new_tokens = args.get_parse("max-tokens", cfg.max_new_tokens);
    cfg.max_ctx = args.get_parse("max-ctx", cfg.max_ctx);
    if args.options.contains_key("backend") {
        cfg.backend = args.backend();
    }
    if args.options.contains_key("engine") {
        cfg.engine = args.engine();
    }
    if args.options.contains_key("shards") {
        cfg.shards = args.shards();
    }
    if args.options.contains_key("max-batch-fuse") {
        cfg.max_batch_fuse = args.max_batch_fuse();
    }
    cfg.latency_budget_ms = args.get_parse("latency-budget-ms", cfg.latency_budget_ms);
    if args.options.contains_key("faults") {
        cfg.faults = args.faults();
    }
    cfg.checkpoint = args.get("checkpoint", &cfg.checkpoint);
    if args.options.contains_key("checkpoint-every-steps") {
        cfg.checkpoint_every_steps = args.checkpoint_every_steps();
    }
    cfg.validate().expect("config");
    cfg
}

/// Arm the deterministic fault-injection plan for this process:
/// `--faults` / config takes precedence, `SPARAMX_FAULTS` fills in when
/// empty. Serving continues fault-free on an empty spec.
fn install_faults(cfg: &RuntimeConfig) {
    match sparamx::fault::install_str_or_env(&cfg.faults) {
        Ok(true) => {
            let source = if cfg.faults.trim().is_empty() {
                format!("env {}", sparamx::fault::FAULTS_ENV)
            } else {
                cfg.faults.clone()
            };
            eprintln!("fault injection armed: {source}");
        }
        Ok(false) => {}
        Err(e) => {
            // config validation already rejects bad --faults; this
            // catches a malformed SPARAMX_FAULTS env var
            panic!("fault spec: {e}");
        }
    }
}

/// Build the engine for the resolved `--engine` directive. The PJRT
/// runtime is only constructed when that path is explicitly requested
/// (the default build stubs it out); it is returned alongside the
/// engine so the client outlives the compiled executables.
fn load_engine(bundle: &Bundle, cfg: &RuntimeConfig) -> (Engine, Option<Runtime>) {
    if cfg.engine.resolved_native() {
        (Engine::load_native(bundle, cfg.clone()).expect("engine"), None)
    } else {
        let rt = Runtime::cpu().expect("pjrt client");
        let engine = Engine::load_pjrt(&rt, bundle, cfg.clone()).expect("engine");
        (engine, Some(rt))
    }
}

fn cmd_serve(args: &Args) -> i32 {
    let cfg = config_from(args);
    install_faults(&cfg);
    let bundle = Bundle::load(&cfg.artifacts_dir).expect("load artifacts");
    let (mut engine, _rt) = load_engine(&bundle, &cfg);
    // plan-aware admission: the compiled plan predicts a decode step's
    // cost, so a request's token ask prices out before any prefill work
    let budget = (cfg.latency_budget_ms > 0.0).then(|| LatencyBudget {
        budget_s: cfg.latency_budget_ms * 1e-3,
        per_token_s: engine.predicted_step_s(),
    });
    let queue = Arc::new(AdmissionQueue::with_budget(cfg.queue_capacity, budget));
    // crash consistency: re-seat any in-flight slots from the snapshot
    // (bit-exact resume; the plan is recompiled against *this* host's
    // registry, never deserialized). The pre-crash client connections
    // are gone, so each restored answer drains on a detached thread.
    for (id, rx) in engine.restore_from_file(&cfg.checkpoint) {
        std::thread::spawn(move || {
            if let Ok(resp) = rx.recv() {
                let note = resp
                    .partial_reason
                    .as_deref()
                    .map(|r| format!(" (partial: {r})"))
                    .unwrap_or_default();
                eprintln!("restored request {id}: {} tokens{note}", resp.tokens.len());
            }
        });
    }
    let listener =
        std::net::TcpListener::bind(("127.0.0.1", cfg.port)).expect("bind port");
    println!(
        "sparamx serving on 127.0.0.1:{} (engine {}, sparsity {:.0}%, batch {})",
        cfg.port,
        engine.describe(),
        cfg.weight_sparsity * 100.0,
        engine.geometry().decode_batch
    );
    if let Some(b) = queue.budget() {
        println!(
            "latency budget: {:.1} ms (predicted {:.3} ms/token → max {} tokens/request)",
            b.budget_s * 1e3,
            b.per_token_s * 1e3,
            if b.per_token_s > 0.0 {
                (b.budget_s / b.per_token_s) as u64
            } else {
                u64::MAX
            }
        );
    }
    let ctx = ServerCtx {
        queue: Arc::clone(&queue),
        default_max_tokens: cfg.max_new_tokens,
        metrics: Arc::clone(&engine.metrics),
        engine: engine.describe(),
        predicted_step_s: engine.predicted_step_s(),
    };
    std::thread::spawn(move || server::serve(listener, ctx));
    engine.run(&queue).expect("engine loop");
    0
}

fn cmd_generate(args: &Args) -> i32 {
    let cfg = config_from(args);
    let prompt = args.positional.join(" ");
    if prompt.is_empty() {
        eprintln!("generate: missing prompt");
        return 2;
    }
    install_faults(&cfg);
    let bundle = Bundle::load(&cfg.artifacts_dir).expect("load artifacts");
    let (mut engine, _rt) = load_engine(&bundle, &cfg);
    let queue = Arc::new(AdmissionQueue::new(4));
    let (tx, rx) = mpsc::channel();
    queue
        .admit(request::Request {
            id: 1,
            prompt: prompt.clone().into_bytes(),
            max_new_tokens: cfg.max_new_tokens,
            arrived: Instant::now(),
            respond: tx,
            deadline_ms: None,
            cancel: Arc::new(std::sync::atomic::AtomicBool::new(false)),
        })
        .expect("admit");
    queue.close();
    engine.run(&queue).expect("engine loop");
    let resp = rx.recv().expect("response");
    println!("{prompt}{}", resp.text());
    eprintln!(
        "[{} tokens, {:.1} ms total, {:.2} ms/token, engine {}]",
        resp.tokens.len(),
        resp.total_latency_s * 1e3,
        resp.per_token_s * 1e3,
        engine.engine_path()
    );
    0
}

fn cmd_eval(args: &Args) -> i32 {
    let cfg = config_from(args);
    let bundle = Bundle::load(&cfg.artifacts_dir).expect("load artifacts");
    let mut model = TinyModel::from_bundle(&bundle).expect("model");
    let ws: f64 = args.get_parse("sparsity", 0.0);
    if ws > 0.0 {
        model.prune_weights(ws);
    }
    let kv = KvTreatment {
        k_sparsity: args.get_parse("k-sparsity", 0.0),
        v_sparsity: args.get_parse("v-sparsity", 0.0),
        int8: args.has("int8-kv"),
    };
    let chunk: usize = args.get_parse("chunk", 128);
    let limit: usize = args.get_parse("limit", bundle.eval_tokens.len());
    // resolve the kernel backend for the projections (auto = registry
    // selection over the model's widest linear at the actual batch,
    // i.e. the chunk length). Only the backend is taken from the
    // selection: the dense-vs-sparse class is then chosen per
    // projection from each matrix's measured sparsity. Eval is a
    // modeling run, so caps default to the paper's testbed
    // (SPARAMX_CAPS still overrides) rather than probing the host.
    let registry = BackendRegistry::with_caps(CpuCaps::modeled());
    let shape = GemmShape::new(chunk, model.hidden, model.vocab);
    let sel = registry.resolve(args.backend(), shape, ws, Dtype::Bf16);
    let mut ctr = EventCounters::default();
    let r = model.evaluate_backend(
        &bundle.eval_tokens[..limit.min(bundle.eval_tokens.len())],
        chunk,
        kv,
        &sel.backend,
        &mut ctr,
    );
    println!(
        "backend={} (per-projection dense/sparse) weight_sparsity={ws:.2} k={:.2} v={:.2} int8={} → ppl {:.3} nll {:.4} top1 {:.3} ({} tokens, {} kernel instrs, {} weight B streamed)",
        sel.backend.name(),
        kv.k_sparsity,
        kv.v_sparsity,
        kv.int8,
        r.ppl,
        r.nll,
        r.top1,
        r.tokens,
        ctr.instructions(),
        ctr.weight_stream_bytes
    );
    0
}

fn cmd_info(args: &Args) -> i32 {
    let cfg = config_from(args);
    match Bundle::load(&cfg.artifacts_dir) {
        Ok(bundle) => {
            let n_params: usize = bundle.params.iter().map(|t| t.len()).sum();
            println!(
                "artifacts: {} ({} tensors, {:.2}M params, {} eval tokens)",
                cfg.artifacts_dir,
                bundle.params.len(),
                n_params as f64 / 1e6,
                bundle.eval_tokens.len()
            );
        }
        Err(e) => println!("artifacts: unavailable ({e})"),
    }
    let cores: usize = args.get_parse("cores", 32);
    let m = Machine::sapphire_rapids(cores);
    println!(
        "machine model: {} cores @ {:.1} GHz, {:.0} GB/s DRAM, AMX peak {:.1} TFLOP/s bf16",
        m.cores,
        m.freq_ghz,
        m.effective_bw_gbs(),
        m.peak_amx_bf16_flops() / 1e12
    );
    let topo = sparamx::shard::NumaTopology::detect();
    let shards = cfg.shards.resolve(&topo);
    println!(
        "topology: {} NUMA node(s), {} core(s) → shards={} (--shards {})",
        topo.nodes, topo.cores, shards, cfg.shards
    );
    let registry = BackendRegistry::probe()
        .with_machine(m.with_numa_nodes(topo.nodes))
        .with_shards(shards, topo);
    let names: Vec<&str> = registry.available().iter().map(|b| b.name()).collect();
    println!(
        "backends: caps [{}], available [{}]",
        registry.caps().describe(),
        names.join(", ")
    );
    // decode-plan preview: the per-shape selections each serving regime
    // would cache for a named config — the Fig 12 crossover table
    let model_name = args.get("model", "tiny");
    match ModelConfig::by_name(&model_name) {
        Some(mc) => {
            let fuse = cfg.max_batch_fuse.resolve(cfg.max_batch);
            let batches = sparamx::models::RegimeBatches {
                decode_fused: fuse,
                prefill: cfg.max_ctx,
            };
            let rp = plan_model_regimes(
                &registry,
                cfg.backend,
                &mc,
                batches,
                cfg.weight_sparsity,
                Dtype::Bf16,
            );
            println!(
                "decode plan [{}]: {} ({} selections across 3 regimes)",
                mc.name,
                rp.decode_b1.describe(),
                rp.selections_computed
            );
            println!("regime table (b1 / fused / prefill):\n{}", rp.regime_table());
            // fused-attention pricing: one batched QKᵀ+R·V per (slot,
            // KV head) group streams the static K/V segment once,
            // amortized over the group's query rows (the GQA ratio; Fig
            // 15 regime)
            let n_q = (mc.heads / mc.kv_heads.max(1)).max(1);
            let looped = sparamx::perf::cost::looped_attention_cost(
                n_q,
                cfg.max_ctx,
                mc.head_dim,
                cfg.k_sparsity,
                cfg.v_sparsity,
                &m,
            );
            let fused_c = sparamx::perf::cost::fused_attention_cost(
                n_q,
                cfg.max_ctx,
                mc.head_dim,
                cfg.k_sparsity,
                cfg.v_sparsity,
                &m,
            );
            println!(
                "fused attention [{}]: {} query rows/KV head (GQA {}:{}) @ ctx {} → looped {:.1}µs fused {:.1}µs ({:.2}x)",
                mc.name,
                n_q,
                mc.heads,
                mc.kv_heads,
                cfg.max_ctx,
                looped * 1e6,
                fused_c * 1e6,
                looped / fused_c
            );
        }
        None => println!("decode plan: unknown model '{model_name}'"),
    }
    0
}
