//! Machine parameters for the modeled CPU.
//!
//! Defaults describe a Sapphire Rapids Xeon (the paper's Intel Xeon Gold
//! 6430L class part): numbers from Intel's optimization manual and
//! published microbenchmarks (uops.info throughputs; Advanced Matrix
//! Extensions white paper for AMX).

/// Reciprocal throughputs (cycles between issues) of the instructions the
/// kernels use, per core.
#[derive(Clone, Copy, Debug)]
pub struct InstrCosts {
    pub tile_zero: f64,
    pub tile_load: f64,
    pub tile_store: f64,
    /// `tdpbf16ps` / `tdpbssd`: 16 rows retire through the systolic array,
    /// issue ≈ 1/16 cycles.
    pub tdp: f64,
    pub avx_load: f64,
    pub avx_store: f64,
    pub vpexpand: f64,
    pub vpopcnt: f64,
    pub prefix_step: f64,
    pub avx_fma: f64,
    pub broadcast: f64,
}

impl Default for InstrCosts {
    fn default() -> Self {
        InstrCosts {
            tile_zero: 1.0,
            tile_load: 8.0,
            tile_store: 16.0,
            tdp: 16.0,
            avx_load: 0.5,
            avx_store: 1.0,
            vpexpand: 2.0,
            vpopcnt: 1.0,
            prefix_step: 2.0,
            avx_fma: 1.0,
            broadcast: 1.0,
        }
    }
}

/// The modeled machine.
#[derive(Clone, Copy, Debug)]
pub struct Machine {
    /// All-core sustained frequency under AMX load (GHz).
    pub freq_ghz: f64,
    /// Active cores for the experiment.
    pub cores: usize,
    /// Socket DRAM bandwidth ceiling (GB/s) — 8× DDR5-4800.
    pub socket_bw_gbs: f64,
    /// Per-core achievable DRAM read bandwidth (GB/s): a single core
    /// cannot saturate the socket.
    pub per_core_bw_gbs: f64,
    /// Per-core L2 bandwidth (GB/s) for the hot decompression buffer.
    pub l2_bw_gbs: f64,
    /// Per-core LLC bandwidth (GB/s) for cache-resident weight re-sweeps.
    pub llc_bw_per_core_gbs: f64,
    /// Shared LLC capacity (bytes) — decides whether a weight stream can
    /// be cache-resident between decode steps (it cannot, for LLM layers).
    pub llc_bytes: u64,
    pub instr: InstrCosts,
    /// Per-linear-op framework dispatch overhead (seconds) for the stock
    /// PyTorch baseline; ours is ~0 (static C++ extension path). Used by
    /// `baselines`.
    pub framework_overhead_s: f64,
    /// NUMA nodes (sockets/memory controllers). Unsharded kernels are
    /// NUMA-unaware and see one socket's bandwidth (`socket_bw_gbs`);
    /// the sharded backend's cost model unlocks the other nodes'
    /// controllers (see `perf::cost::shard_machine`).
    pub numa_nodes: usize,
}

impl Default for Machine {
    fn default() -> Self {
        Machine::sapphire_rapids(32)
    }
}

impl Machine {
    /// Sapphire Rapids profile with `cores` active cores.
    pub fn sapphire_rapids(cores: usize) -> Machine {
        Machine {
            freq_ghz: 2.0,
            cores: cores.max(1),
            socket_bw_gbs: 250.0,
            per_core_bw_gbs: 12.0,
            l2_bw_gbs: 120.0,
            llc_bw_per_core_gbs: 60.0,
            llc_bytes: 60 * 1024 * 1024,
            instr: InstrCosts::default(),
            framework_overhead_s: 5e-6,
            // the paper's testbed is a dual-socket Xeon Gold 6430L
            numa_nodes: 2,
        }
    }

    /// Same machine with a different core count.
    pub fn with_cores(mut self, cores: usize) -> Machine {
        self.cores = cores.max(1);
        self
    }

    /// Same machine with a different NUMA node count.
    pub fn with_numa_nodes(mut self, nodes: usize) -> Machine {
        self.numa_nodes = nodes.max(1);
        self
    }

    /// Effective DRAM bandwidth at the configured core count:
    /// per-core-limited until the socket ceiling.
    pub fn effective_bw_gbs(&self) -> f64 {
        self.effective_bw_gbs_at(self.cores)
    }

    /// Effective DRAM bandwidth with only `active` cores issuing requests
    /// (the kernel's parallel granularity can leave cores idle).
    pub fn effective_bw_gbs_at(&self, active: usize) -> f64 {
        (active.max(1) as f64 * self.per_core_bw_gbs).min(self.socket_bw_gbs)
    }

    /// LLC bandwidth with `active` cores (capped at 8× socket DRAM bw).
    pub fn llc_bw_gbs_at(&self, active: usize) -> f64 {
        (active.max(1) as f64 * self.llc_bw_per_core_gbs).min(8.0 * self.socket_bw_gbs)
    }

    /// Aggregate L2 bandwidth (private per core).
    pub fn aggregate_l2_bw_gbs(&self) -> f64 {
        self.cores as f64 * self.l2_bw_gbs
    }

    /// Peak BF16 FLOP/s with AMX: one tdpbf16ps = 16×16×32 MACs = 16384
    /// FLOPs, issued every `instr.tdp` cycles per core.
    pub fn peak_amx_bf16_flops(&self) -> f64 {
        let per_tdp = 2.0 * 16.0 * 16.0 * 32.0;
        self.cores as f64 * self.freq_ghz * 1e9 * per_tdp / self.instr.tdp
    }

    /// Peak AVX-512 BF16 FLOP/s: one vdpbf16ps = 32 MACs.
    pub fn peak_avx_bf16_flops(&self) -> f64 {
        self.cores as f64 * self.freq_ghz * 1e9 * 64.0 / self.instr.avx_fma
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bandwidth_scales_then_saturates() {
        let m8 = Machine::sapphire_rapids(8);
        let m16 = Machine::sapphire_rapids(16);
        let m32 = Machine::sapphire_rapids(32);
        assert!(m8.effective_bw_gbs() < m16.effective_bw_gbs());
        assert!(m16.effective_bw_gbs() < m32.effective_bw_gbs());
        assert_eq!(Machine::sapphire_rapids(64).effective_bw_gbs(), 250.0);
    }

    #[test]
    fn amx_peak_dwarfs_avx_peak() {
        let m = Machine::sapphire_rapids(32);
        // AMX 1024 FLOP / 16 cyc = 64 FLOP/cyc vs AVX 64 FLOP/cyc... the
        // AMX advantage on SPR is ~8x per the 2-unit pipelines; our single
        // tdp pipe gives parity per issue but 16x the data per op. Check
        // the model at least does not rank AVX above AMX.
        assert!(m.peak_amx_bf16_flops() >= m.peak_avx_bf16_flops());
    }

    #[test]
    fn with_cores_clamps_to_one() {
        assert_eq!(Machine::default().with_cores(0).cores, 1);
    }
}
