//! Pipeline-slot attribution (reproduces paper Table 1).
//!
//! VTune's top-down method classifies pipeline slots into retiring /
//! front-end / core-bound / memory-bound, and memory-bound further into
//! cache-bound vs DRAM-bound. We reconstruct the same attribution from
//! the cost model's time components:
//!
//! * slots where the core waits on *any* memory (DRAM stream or the L2
//!   scratch bounce) and has no instructions to issue → **memory bound**;
//! * the subset waiting specifically on DRAM → **DRAM bound**.
//!
//! For the dense kernel almost no instructions overlap the huge weight
//! stream → ~100% memory bound, mostly DRAM. The sparse kernel trades
//! stream bytes for decompression instructions → stalls collapse.

use super::cost::KernelCost;

/// Pipeline-slot attribution percentages.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SlotReport {
    /// % of slots stalled on any memory level.
    pub memory_bound_pct: f64,
    /// % of slots stalled specifically on DRAM.
    pub dram_bound_pct: f64,
    /// % of slots doing useful issue (retiring + core).
    pub busy_pct: f64,
}

/// Attribute slots for a kernel cost.
///
/// A slot is **busy** while the core issues instructions or services its
/// private cache traffic (`core_time` = issue + scratch + LLC): those
/// slots retire decompression uops even while the DRAM stream is in
/// flight. Everything else is stalled on memory; the stall splits
/// between DRAM and caches in proportion to their traffic times.
pub fn attribute(cost: &KernelCost) -> SlotReport {
    let total = cost.time.max(1e-18);
    let busy = cost.core_time.min(total);
    let stall = (total - busy).max(0.0);
    let cache_traffic = cost.scratch_time + cost.llc_time;
    let mem_traffic = cost.dram_time + cache_traffic;
    let dram_share = if mem_traffic > 0.0 {
        cost.dram_time / mem_traffic
    } else {
        0.0
    };
    let dram_stall = (stall * dram_share).min(cost.dram_time);
    let memory_bound_pct = 100.0 * stall / total;
    let dram_bound_pct = 100.0 * dram_stall / total;
    SlotReport {
        memory_bound_pct,
        dram_bound_pct,
        busy_pct: 100.0 - memory_bound_pct,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::perf::cost::{dense_gemm_cost, sparse_gemm_cost};
    use crate::perf::Machine;

    /// Table 1 workload: 32 consecutive linears, 4192 inputs (hidden dim;
    /// the paper's text) × 14336 outputs, batch 1, 32 cores.
    fn table1(sparsity: Option<f64>) -> SlotReport {
        let m = Machine::sapphire_rapids(32);
        let cost = match sparsity {
            None => dense_gemm_cost(1, 4192, 14336, &m),
            Some(s) => sparse_gemm_cost(1, 4192, 14336, s, &m),
        };
        attribute(&cost)
    }

    #[test]
    fn dense_is_almost_fully_memory_bound() {
        let r = table1(None);
        assert!(r.memory_bound_pct > 85.0, "dense memory bound {r:?}");
        assert!(r.dram_bound_pct > 70.0, "dense DRAM bound {r:?}");
    }

    #[test]
    fn sparse_collapses_the_stalls() {
        let dense = table1(None);
        let sparse = table1(Some(0.5));
        assert!(
            sparse.memory_bound_pct < dense.memory_bound_pct / 2.0,
            "sparse {sparse:?} vs dense {dense:?}"
        );
        assert!(sparse.dram_bound_pct < dense.dram_bound_pct / 3.0);
    }

    #[test]
    fn percentages_are_consistent() {
        for r in [table1(None), table1(Some(0.5)), table1(Some(0.9))] {
            assert!((0.0..=100.0).contains(&r.memory_bound_pct));
            assert!(r.dram_bound_pct <= r.memory_bound_pct + 1e-9);
            assert!((r.busy_pct + r.memory_bound_pct - 100.0).abs() < 1e-9);
        }
    }
}
