//! Event counts → seconds: the bounded-overlap cost model.
//!
//! Decode-phase GEMMs are streaming workloads: the weight stream is read
//! once per step (far larger than LLC), while inputs/outputs and the
//! decompression buffer stay cache-hot. The model therefore computes
//!
//! * `dram_time`   — DRAM-stream bytes / effective bandwidth,
//! * `core_time`   — instruction issue cycles / (cores × freq), including
//!   the decompression work, plus L2 traffic for the scratch buffer,
//! * `time = max(dram_time, core_time)` — hardware prefetchers overlap
//!   the weight stream with compute almost perfectly for these regular
//!   access patterns (the paper's Table 1 shows the dense kernel is 100%
//!   memory-bound, i.e. fully overlapped compute).
//!
//! Work is assumed parallel over output columns (the paper's
//! parallelization dimension); a small non-parallel fraction models the
//! per-call fixed cost.

use super::machine::Machine;
use crate::amx::EventCounters;

/// Cost breakdown of one kernel invocation on the modeled machine.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct KernelCost {
    /// DRAM streaming time (s).
    pub dram_time: f64,
    /// Core instruction-issue time including scratch-buffer traffic (s).
    pub core_time: f64,
    /// Scratch (L2) traffic time alone (s), for attribution.
    pub scratch_time: f64,
    /// LLC re-sweep traffic time (s), for attribution.
    pub llc_time: f64,
    /// Modeled wall time (s).
    pub time: f64,
}

/// Fixed per-invocation overhead (thread fan-out, tile config): ~2 µs.
const LAUNCH_OVERHEAD_S: f64 = 2e-6;

/// DRAM stream ramp: prefetchers and TLBs take roughly this many bytes
/// to reach steady-state bandwidth, charged once per kernel. This is why
/// small layers (small models) achieve a lower fraction of peak and why
/// Fig 1's speedup grows with model size.
const STREAM_RAMP_BYTES: f64 = 1.5e6;

impl KernelCost {
    /// Cost of a kernel run described by `ctr` on machine `m`.
    ///
    /// Two second-order effects matter for the paper's figures:
    /// * **parallel granularity** — the kernel parallelizes over column
    ///   pairs; if it exposes fewer tasks than cores, the idle cores
    ///   contribute neither issue slots nor memory parallelism (§4.1,
    ///   and the reason small models speed up less in Fig 1);
    /// * **LLC residency** — at batch > 32 the weight stream is swept
    ///   once per 32-row m-block; if the (compressed) stream fits in LLC
    ///   the repeats are served from cache, which is what turns the
    ///   high-batch regime compute-bound (§7).
    pub fn from_counters(ctr: &EventCounters, m: &Machine) -> KernelCost {
        let active = if ctr.parallel_tasks == 0 {
            m.cores
        } else {
            m.cores.min(ctr.parallel_tasks as usize)
        };
        let i = &m.instr;
        let cycles = ctr.tile_zero as f64 * i.tile_zero
            + (ctr.tile_load_input + ctr.tile_load_weight) as f64 * i.tile_load
            + ctr.tile_store as f64 * i.tile_store
            + ctr.tdp_total() as f64 * i.tdp
            + ctr.avx_load as f64 * i.avx_load
            + ctr.avx_store as f64 * i.avx_store
            + ctr.vpexpand as f64 * i.vpexpand
            + ctr.vpopcnt as f64 * i.vpopcnt
            + ctr.prefix_step as f64 * i.prefix_step
            + ctr.avx_fma as f64 * i.avx_fma
            + ctr.broadcast as f64 * i.broadcast
            + ctr.fma_dep_stall as f64;
        let issue_time = cycles / (m.freq_ghz * 1e9) / active as f64;
        let scratch_time = ctr.scratch_bytes as f64
            / (active as f64 * m.l2_bw_gbs * 1e9);
        let (dram_bytes, llc_bytes) = ctr.dram_llc_split(m.llc_bytes);
        let ramp = if dram_bytes > 0 { STREAM_RAMP_BYTES } else { 0.0 };
        let dram_time =
            (dram_bytes as f64 + ramp) / (m.effective_bw_gbs_at(active) * 1e9);
        let llc_time = llc_bytes as f64 / (m.llc_bw_gbs_at(active) * 1e9);
        let core_time = issue_time + scratch_time + llc_time;
        KernelCost {
            dram_time,
            core_time,
            scratch_time,
            llc_time,
            time: dram_time.max(core_time) + LAUNCH_OVERHEAD_S,
        }
    }

    /// Whether the invocation is DRAM-bandwidth bound.
    pub fn memory_bound(&self) -> bool {
        self.dram_time >= self.core_time
    }
}

/// Convenience: cost of a dense BF16 GEMM of the given shape.
pub fn dense_gemm_cost(batch: usize, rows: usize, cols: usize, m: &Machine) -> KernelCost {
    KernelCost::from_counters(&super::analytic::dense_bf16(batch, rows, cols), m)
}

/// Convenience: cost of a sparse BF16 GEMM at `sparsity` (nnz derived).
pub fn sparse_gemm_cost(
    batch: usize,
    rows: usize,
    cols: usize,
    sparsity: f64,
    m: &Machine,
) -> KernelCost {
    let nnz = ((1.0 - sparsity.clamp(0.0, 1.0)) * (rows * cols) as f64).round() as usize;
    KernelCost::from_counters(&super::analytic::sparse_bf16(batch, rows, cols, nnz), m)
}

/// Convenience: cost of a dense INT8 GEMM of the given shape.
pub fn dense_int8_gemm_cost(batch: usize, rows: usize, cols: usize, m: &Machine) -> KernelCost {
    KernelCost::from_counters(&super::analytic::dense_int8(batch, rows, cols), m)
}

/// Convenience: cost of a sparse INT8 GEMM at `sparsity` (nnz derived).
pub fn sparse_int8_gemm_cost(
    batch: usize,
    rows: usize,
    cols: usize,
    sparsity: f64,
    m: &Machine,
) -> KernelCost {
    let nnz = ((1.0 - sparsity.clamp(0.0, 1.0)) * (rows * cols) as f64).round() as usize;
    KernelCost::from_counters(&super::analytic::sparse_int8(batch, rows, cols, nnz), m)
}

/// Per-epoch cost of the sharded backend's scatter + barrier on the
/// persistent worker pool: mailbox wakeups, the epoch barrier, and the
/// fixed-order column merge. This is why sharding loses small batch-1
/// shapes (the Fig 11 crossover): each shard also pays its own
/// `STREAM_RAMP_BYTES`, and the barrier is pure overhead.
pub const SHARD_BARRIER_S: f64 = 3e-6;

/// The machine one shard of `shards` sees: its slice of the cores, and
/// its NUMA node's share of the memory controllers. Unsharded kernels
/// are NUMA-unaware and stream from one socket (`socket_bw_gbs`);
/// sharding one shard per node unlocks the other nodes' controllers,
/// while packing several shards onto a node splits that node's
/// bandwidth between them.
pub fn shard_machine(m: &Machine, shards: usize) -> Machine {
    let shards = shards.max(1);
    let per_node = shards.div_ceil(m.numa_nodes.max(1));
    let mut sm = *m;
    sm.cores = (m.cores / shards).max(1);
    sm.socket_bw_gbs = m.socket_bw_gbs / per_node as f64;
    sm
}

/// Wall time of a column-sharded GEMM: the slowest shard's kernel on its
/// shard machine, plus the epoch barrier. `per_shard(cols, machine)`
/// prices one shard's kernel — the sharded backend passes its inner
/// backend's `predict` here, so registry selection and this model agree
/// by construction. Width computation uses the non-ticking
/// `ShardPlan::col_widths` (pricing a hypothetical sharding is not a
/// plan-compile event). A single-shard plan degenerates to the plain
/// inner kernel with no barrier, so at equal cost the unsharded backend
/// wins selection (strict `<` keeps earlier registry entries).
pub fn sharded_time(
    cols: usize,
    shards: usize,
    m: &Machine,
    per_shard: &dyn Fn(usize, &Machine) -> f64,
) -> f64 {
    let widths = crate::shard::ShardPlan::col_widths(cols, shards);
    if widths.len() <= 1 {
        return per_shard(cols, m);
    }
    let sm = shard_machine(m, widths.len());
    widths
        .iter()
        .map(|&w| per_shard(w, &sm))
        .fold(0.0, f64::max)
        + SHARD_BARRIER_S
}

/// Convenience: sharded sparse BF16 GEMM wall time.
pub fn sharded_sparse_gemm_cost(
    batch: usize,
    rows: usize,
    cols: usize,
    sparsity: f64,
    shards: usize,
    m: &Machine,
) -> f64 {
    sharded_time(cols, shards, m, &|w, sm| {
        sparse_gemm_cost(batch, rows, w, sparsity, sm).time
    })
}

/// Convenience: sharded dense BF16 GEMM wall time.
pub fn sharded_dense_gemm_cost(
    batch: usize,
    rows: usize,
    cols: usize,
    shards: usize,
    m: &Machine,
) -> f64 {
    sharded_time(cols, shards, m, &|w, sm| dense_gemm_cost(batch, rows, w, sm).time)
}

/// Modeled wall time of serving `batch` decode rows as `batch`
/// independent batch-1 calls — the looped path the engine takes when
/// fusion is disabled. Every call re-streams the full weight stream and
/// pays its own launch overhead and DRAM ramp; this is the baseline the
/// fused regime (one call at `batch`) amortizes away.
pub fn looped_dense_gemm_cost(batch: usize, rows: usize, cols: usize, m: &Machine) -> f64 {
    batch as f64 * dense_gemm_cost(1, rows, cols, m).time
}

/// Looped-path wall time for the sparse BF16 kernel (see
/// [`looped_dense_gemm_cost`]).
pub fn looped_sparse_gemm_cost(
    batch: usize,
    rows: usize,
    cols: usize,
    sparsity: f64,
    m: &Machine,
) -> f64 {
    batch as f64 * sparse_gemm_cost(1, rows, cols, sparsity, m).time
}

/// Modeled speedup of fusing `batch` active slots into one batched
/// sparse GEMM vs. looping batch-1 calls: `looped / fused`. In the
/// memory-bound decode regime this approaches `batch` (the weight
/// stream is read once instead of `batch` times); in the compute-bound
/// regime it approaches 1 (the MACs don't amortize).
pub fn fused_sparse_speedup(
    batch: usize,
    rows: usize,
    cols: usize,
    sparsity: f64,
    m: &Machine,
) -> f64 {
    let fused = sparse_gemm_cost(batch, rows, cols, sparsity, m).time;
    looped_sparse_gemm_cost(batch, rows, cols, sparsity, m) / fused
}

/// Modeled wall time of the *looped* split-cache attention path for one
/// KV head group with `n_q` query rows: each row runs its own batch-1
/// QKᵀ (K stored transposed, `head_dim × ctx`, sparse at `k_sparsity`)
/// and R·V (`ctx × head_dim`, sparse at `v_sparsity`), so the static
/// K/V segment streams once *per query row* — same shape as
/// [`looped_sparse_gemm_cost`]. The dense dynamic tail is a few rows of
/// cache-hot work and is excluded (both paths pay it identically).
pub fn looped_attention_cost(
    n_q: usize,
    ctx: usize,
    head_dim: usize,
    k_sparsity: f64,
    v_sparsity: f64,
    m: &Machine,
) -> f64 {
    n_q as f64
        * (sparse_gemm_cost(1, head_dim, ctx, k_sparsity, m).time
            + sparse_gemm_cost(1, ctx, head_dim, v_sparsity, m).time)
}

/// Modeled wall time of the *fused* attention path for the same group:
/// one batched QKᵀ and one batched R·V over all `n_q` rows, so each
/// static K/V segment's stream bytes are read once per step and
/// amortized over the query rows. At `n_q == 1` this degenerates to the
/// looped cost exactly (same two batch-1 calls).
pub fn fused_attention_cost(
    n_q: usize,
    ctx: usize,
    head_dim: usize,
    k_sparsity: f64,
    v_sparsity: f64,
    m: &Machine,
) -> f64 {
    sparse_gemm_cost(n_q, head_dim, ctx, k_sparsity, m).time
        + sparse_gemm_cost(n_q, ctx, head_dim, v_sparsity, m).time
}

/// Modeled speedup of fused over looped attention for one KV head group:
/// `looped_attention_cost / fused_attention_cost`. Approaches `n_q` in
/// the memory-bound long-context regime (Fig 15's setting) and 1.0 when
/// the group is a single row.
pub fn fused_attention_speedup(
    n_q: usize,
    ctx: usize,
    head_dim: usize,
    k_sparsity: f64,
    v_sparsity: f64,
    m: &Machine,
) -> f64 {
    looped_attention_cost(n_q, ctx, head_dim, k_sparsity, v_sparsity, m)
        / fused_attention_cost(n_q, ctx, head_dim, k_sparsity, v_sparsity, m)
}

/// Convenience: AVX sparse GEMM cost.
pub fn avx_sparse_gemm_cost(
    batch: usize,
    rows: usize,
    cols: usize,
    sparsity: f64,
    column_groups: usize,
    m: &Machine,
) -> KernelCost {
    let nnz = ((1.0 - sparsity.clamp(0.0, 1.0)) * (rows * cols) as f64).round() as usize;
    KernelCost::from_counters(
        &super::analytic::avx_sparse_bf16(batch, rows, cols, nnz, column_groups),
        m,
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::perf::analytic;

    fn m32() -> Machine {
        Machine::sapphire_rapids(32)
    }

    #[test]
    fn dense_decode_gemm_is_memory_bound() {
        // Llama 3 8B up_proj at batch 1: the paper's Table 1 regime.
        let c = dense_gemm_cost(1, 4096, 14336, &m32());
        assert!(c.memory_bound(), "dense decode GEMM must be DRAM bound: {c:?}");
        assert!(c.dram_time > 3.0 * c.core_time);
    }

    #[test]
    fn sparse_is_faster_than_dense_at_50pct_batch1() {
        let m = m32();
        let d = dense_gemm_cost(1, 4096, 14336, &m);
        let s = sparse_gemm_cost(1, 4096, 14336, 0.5, &m);
        assert!(s.time < d.time, "sparse {s:?} !< dense {d:?}");
        // the paper's per-layer speedups are 1.2–2.0x at 50%
        let speedup = d.time / s.time;
        assert!(speedup > 1.1 && speedup < 2.5, "speedup={speedup}");
    }

    #[test]
    fn sparse_loses_at_high_batch_compute_bound() {
        // §7: "in compute-bound scenarios applying unstructured sparsity
        // may reduce performance".
        let m = m32();
        let d = dense_gemm_cost(256, 4096, 4096, &m);
        let s = sparse_gemm_cost(256, 4096, 4096, 0.5, &m);
        assert!(!d.memory_bound(), "batch 256 should be compute bound");
        assert!(s.time >= d.time, "sparse should not win when compute-bound");
    }

    #[test]
    fn speedup_increases_with_sparsity() {
        let m = m32();
        let d = dense_gemm_cost(1, 4096, 4096, &m).time;
        let mut last = 0.0;
        for s in [0.2, 0.4, 0.6, 0.8] {
            let sp = d / sparse_gemm_cost(1, 4096, 4096, s, &m).time;
            assert!(sp > last, "speedup must grow with sparsity");
            last = sp;
        }
    }

    #[test]
    fn more_cores_never_slower() {
        for cores in [8usize, 16, 32] {
            let a = sparse_gemm_cost(1, 4096, 14336, 0.5, &Machine::sapphire_rapids(cores));
            let b = sparse_gemm_cost(1, 4096, 14336, 0.5, &Machine::sapphire_rapids(cores * 2));
            assert!(b.time <= a.time, "{cores}→{} cores regressed", cores * 2);
        }
    }

    #[test]
    fn avx_beats_amx_at_batch1_low_cores() {
        // §7: at batch 1 AVX sometimes outperforms AMX because AMX pays
        // the scratch bounce. With few cores both are compute-limited on
        // decompression; AVX avoids the extra scratch traffic.
        let m = Machine::sapphire_rapids(8);
        let amx = sparse_gemm_cost(1, 4096, 14336, 0.5, &m);
        let avx = avx_sparse_gemm_cost(1, 4096, 14336, 0.5, 16, &m);
        // allow either to win but they must be within 2x — the paper
        // shows them close at batch 1
        let ratio = amx.time / avx.time;
        assert!((0.5..=2.0).contains(&ratio), "ratio={ratio}");
    }

    #[test]
    fn amx_beats_avx_at_batch32() {
        // Fig 12: AMX pulls ahead at high batch (matrix-matrix regime).
        let m = m32();
        let amx = sparse_gemm_cost(32, 4096, 14336, 0.5, &m);
        let avx = avx_sparse_gemm_cost(32, 4096, 14336, 0.5, 16, &m);
        assert!(amx.time < avx.time, "AMX {amx:?} !< AVX {avx:?}");
    }

    #[test]
    fn int8_sparse_beats_dense_when_memory_bound() {
        // Fig 13 regime: Llama 2 7B gate_proj, batch 1, 50% sparse INT8.
        let m = m32();
        let d = dense_int8_gemm_cost(1, 4096, 11008, &m);
        let s = sparse_int8_gemm_cost(1, 4096, 11008, 0.5, &m);
        assert!(d.memory_bound(), "batch-1 INT8 decode is DRAM bound");
        assert!(s.time < d.time, "sparse {s:?} !< dense {d:?}");
    }

    #[test]
    fn launch_overhead_dominates_tiny_kernels() {
        let c = KernelCost::from_counters(&analytic::dense_bf16(1, 32, 16), &m32());
        assert!(c.time >= LAUNCH_OVERHEAD_S);
    }

    #[test]
    fn fused_batched_call_beats_looped_batch1_calls() {
        // the tentpole's premise: N batch-1 calls stream the weights N
        // times; one batch-N call streams them once.
        let m = m32();
        let mut last = 1.0;
        for b in [2usize, 4, 8, 16] {
            let sp = fused_sparse_speedup(b, 4096, 14336, 0.5, &m);
            assert!(sp > 1.5, "batch {b}: fused speedup {sp} too small");
            assert!(sp > last, "speedup must grow with batch");
            last = sp;
        }
    }

    #[test]
    fn fused_speedup_saturates_when_compute_bound() {
        // once the batched call is compute-bound, adding rows stops
        // amortizing: the speedup flattens well below `batch`.
        let m = m32();
        let sp = fused_sparse_speedup(256, 4096, 4096, 0.5, &m);
        assert!(sp < 256.0 * 0.5, "compute-bound speedup must fall off: {sp}");
    }

    #[test]
    fn looped_cost_is_batch_times_single_call() {
        let m = m32();
        let one = sparse_gemm_cost(1, 1024, 1024, 0.5, &m).time;
        let four = looped_sparse_gemm_cost(4, 1024, 1024, 0.5, &m);
        assert!((four - 4.0 * one).abs() < 1e-15);
        let d1 = dense_gemm_cost(1, 1024, 1024, &m).time;
        assert!((looped_dense_gemm_cost(3, 1024, 1024, &m) - 3.0 * d1).abs() < 1e-15);
    }

    #[test]
    fn fused_attention_never_loses_in_fig15_regime() {
        // acceptance: fused ≤ looped for >1 query row in the modeled
        // Fig 15 regime (long context, 50% unstructured K/V sparsity).
        let m = m32();
        for n_q in [2usize, 4, 8] {
            let looped = looped_attention_cost(n_q, 4096, 128, 0.5, 0.5, &m);
            let fused = fused_attention_cost(n_q, 4096, 128, 0.5, 0.5, &m);
            assert!(fused <= looped, "n_q={n_q}: fused {fused} !<= looped {looped}");
        }
    }

    #[test]
    fn fused_attention_degenerates_to_looped_at_one_row() {
        let m = m32();
        let looped = looped_attention_cost(1, 2048, 128, 0.5, 0.3, &m);
        let fused = fused_attention_cost(1, 2048, 128, 0.5, 0.3, &m);
        assert!((fused - looped).abs() < 1e-15, "n_q=1 must price identically");
    }

    #[test]
    fn fused_attention_speedup_grows_with_group_size() {
        // the KV stream amortizes over more query rows as the GQA group
        // (× co-resident slots) grows.
        let m = m32();
        let mut last = 1.0;
        for n_q in [2usize, 4, 8, 16] {
            let sp = fused_attention_speedup(n_q, 4096, 128, 0.5, 0.5, &m);
            assert!(sp >= last, "speedup must not shrink with n_q: {sp} < {last}");
            last = sp;
        }
        assert!(last > 1.2, "16-row group should clearly beat looped: {last}");
    }

    #[test]
    fn shard_machine_splits_cores_and_unlocks_nodes() {
        let m = m32(); // 32 cores, 2 NUMA nodes, 250 GB/s per socket
        let s2 = shard_machine(&m, 2); // one shard per node
        assert_eq!(s2.cores, 16);
        assert_eq!(s2.socket_bw_gbs, 250.0, "one shard per node: full socket each");
        let s4 = shard_machine(&m, 4); // two shards share each node
        assert_eq!(s4.cores, 8);
        assert_eq!(s4.socket_bw_gbs, 125.0);
        assert_eq!(shard_machine(&m, 1).cores, 32);
    }

    #[test]
    fn sharding_wins_large_memory_bound_shapes() {
        // Fig 11 regime: Llama 3 8B up_proj, batch 1, 50% sparse. Two
        // shards stream from both sockets' controllers at once.
        let m = m32();
        let un = sparse_gemm_cost(1, 4096, 14336, 0.5, &m).time;
        let sh = sharded_sparse_gemm_cost(1, 4096, 14336, 0.5, 2, &m);
        assert!(sh < un, "sharded {sh} !< unsharded {un}");
    }

    #[test]
    fn sharding_loses_small_batch1_shapes() {
        // Per-shard stream ramp + barrier cost swamp a tiny layer — the
        // crossover's other side.
        let m = m32();
        let un = dense_gemm_cost(1, 128, 128, &m).time;
        let sh = sharded_dense_gemm_cost(1, 128, 128, 2, &m);
        assert!(sh > un, "sharded {sh} !> unsharded {un}");
    }

    #[test]
    fn single_shard_degenerates_to_inner_cost() {
        let m = m32();
        let un = sparse_gemm_cost(1, 4096, 4096, 0.5, &m).time;
        let sh = sharded_sparse_gemm_cost(1, 4096, 4096, 0.5, 1, &m);
        assert_eq!(sh, un, "one shard must add no barrier");
    }

}
