//! Performance model: converts simulated architectural events into time,
//! pipeline-slot attribution, and roofline positions for a Sapphire
//! Rapids-class CPU.
//!
//! The container this repo runs in has one core and no AMX, so wall-clock
//! timing cannot reproduce the paper's testbed. Instead (DESIGN.md §2):
//!
//! 1. the [`crate::amx`] simulator (or [`analytic`], validated against
//!    it) produces exact per-kernel event counts;
//! 2. [`machine`] holds published Sapphire Rapids parameters (frequency,
//!    DRAM bandwidth, instruction throughputs);
//! 3. [`cost`] turns counts into seconds with a bounded-overlap model;
//! 4. [`pipeline`] attributes pipeline slots (Table 1);
//! 5. [`roofline`] reports achieved-vs-peak ratios for the §Perf pass.

pub mod machine;
pub mod analytic;
pub mod cost;
pub mod pipeline;
pub mod roofline;

pub use cost::KernelCost;
pub use machine::Machine;
