//! Closed-form event counters for the SparAMX kernels.
//!
//! Full-size LLM layers (e.g. Llama 3 8B `up_proj`, 4096×14336) are too
//! large to push through the functional simulator for every point of
//! every figure. This module computes the **exact** counter values the
//! simulator would produce, from shapes alone; the test suite asserts
//! equality against [`crate::amx::kernels`] on a grid of small shapes,
//! so the big-shape numbers are trustworthy by construction.

use crate::amx::EventCounters;

/// Padded sizes used by the tile stream.
fn pad(x: usize, to: usize) -> usize {
    x.div_ceil(to) * to
}

/// Iterate the Figure-5 schedule structure, calling `body(nacc, m_hi,
/// m_lo, two_blocks)` once per (m-block, n-iteration).
fn for_schedule(batch: usize, cols_padded: usize, mut body: impl FnMut(u64, usize, usize, bool)) {
    let mut m0 = 0;
    while m0 < batch {
        let m_rows = (batch - m0).min(32);
        let m_hi = m_rows.min(16);
        let m_lo = m_rows - m_hi;
        let mut n0 = 0;
        while n0 < cols_padded {
            let two = n0 + 16 < cols_padded;
            let nacc = (if two { 2 } else { 1 }) * (if m_lo > 0 { 2 } else { 1 });
            body(nacc as u64, m_hi, m_lo, two);
            n0 += if two { 32 } else { 16 };
        }
        m0 += 32;
    }
}

/// Number of 32-row m-blocks.
fn m_blocks(batch: usize) -> u64 {
    batch.div_ceil(32) as u64
}

/// Counters for [`crate::amx::kernels::dense_amx_gemm_bf16`].
pub fn dense_bf16(batch: usize, rows: usize, cols: usize) -> EventCounters {
    gemm_amx(batch, rows, cols, 32, 2, None)
}

/// Counters for [`crate::amx::kernels::sparse_amx_gemm_bf16`]. `nnz` is
/// the packed non-zero count (`SparseTensor::nnz()`).
pub fn sparse_bf16(batch: usize, rows: usize, cols: usize, nnz: usize) -> EventCounters {
    gemm_amx(batch, rows, cols, 32, 2, Some(SparseDecomp { nnz, int8: false }))
}

/// Counters for [`crate::amx::kernels::dense_amx_gemm_int8`].
pub fn dense_int8(batch: usize, rows: usize, cols: usize) -> EventCounters {
    gemm_amx(batch, rows, cols, 64, 1, None)
}

/// Counters for [`crate::amx::kernels::sparse_amx_gemm_int8`].
pub fn sparse_int8(batch: usize, rows: usize, cols: usize, nnz: usize) -> EventCounters {
    gemm_amx(batch, rows, cols, 64, 1, Some(SparseDecomp { nnz, int8: true }))
}

struct SparseDecomp {
    nnz: usize,
    int8: bool,
}

fn gemm_amx(
    batch: usize,
    rows: usize,
    cols: usize,
    k_per_tile: usize,
    elem_bytes: usize,
    sparse: Option<SparseDecomp>,
) -> EventCounters {
    let rows_padded = pad(rows.max(1), k_per_tile);
    let cols_padded = pad(cols.max(1), 16);
    let k_chunks = (rows_padded / k_per_tile) as u64;
    let col_blocks = (cols_padded / 16) as u64;
    let num_tiles = k_chunks * col_blocks;
    let mut c = EventCounters::default();
    c.parallel_tasks = col_blocks / 2 + col_blocks % 2;
    c.input_unique_bytes = (batch * rows_padded * elem_bytes) as u64;

    for_schedule(batch, cols_padded, |nacc, m_hi, m_lo, two| {
        c.tile_zero += nacc;
        c.tile_store += nacc;
        c.output_bytes += (m_hi + m_lo) as u64 * 64 * if two { 2 } else { 1 };
        let input_loads = 1 + u64::from(m_lo > 0);
        let weight_loads = if two { 2u64 } else { 1 };
        c.tile_load_input += input_loads * k_chunks;
        c.input_bytes += (m_hi + m_lo) as u64 * 64 * k_chunks;
        c.tile_load_weight += weight_loads * k_chunks;
        c.tdp_bf16 += nacc * k_chunks; // reclassified below for int8
    });

    if elem_bytes == 1 {
        c.tdp_int8 = c.tdp_bf16;
        c.tdp_bf16 = 0;
    }

    let sweeps = m_blocks(batch);
    match sparse {
        None => {
            // dense: every weight tileloadd streams 1 KiB from DRAM
            c.weight_stream_bytes += c.tile_load_weight * 1024;
            c.weight_unique_bytes = num_tiles * 1024;
        }
        Some(sd) => {
            let tiles_total = num_tiles * sweeps; // decompressed once per sweep
            debug_assert_eq!(c.tile_load_weight, tiles_total);
            if sd.int8 {
                c.avx_load += 2 * tiles_total;
                c.weight_stream_bytes += 128 * tiles_total; // 16×64-bit bitmap
                c.vpopcnt += 2 * tiles_total;
                c.prefix_step += 6 * tiles_total;
            } else {
                c.avx_load += tiles_total;
                c.weight_stream_bytes += 64 * tiles_total; // 16×32-bit bitmap
                c.vpopcnt += tiles_total;
                c.prefix_step += 4 * tiles_total;
            }
            c.vpexpand += 16 * tiles_total;
            c.avx_store += 16 * tiles_total;
            // values stream: nnz elements per sweep
            c.weight_stream_bytes += (sd.nnz * elem_bytes) as u64 * sweeps;
            // scratch: 16 stores of 64 B + the 1 KiB tileloadd read-back
            c.scratch_bytes += 2048 * tiles_total;
            let meta_bytes = if sd.int8 { 128 } else { 64 };
            c.weight_unique_bytes =
                num_tiles * meta_bytes + (sd.nnz * elem_bytes) as u64;
        }
    }
    c
}

/// Counters for [`crate::amx::kernels::avx_sparse_gemm_bf16`].
pub fn avx_sparse_bf16(
    batch: usize,
    rows: usize,
    cols: usize,
    nnz: usize,
    column_groups: usize,
) -> EventCounters {
    let g = column_groups.max(1);
    let rows_padded = pad(rows.max(1), 32);
    let cols_padded = pad(cols.max(1), 16);
    let k_chunks = (rows_padded / 32) as u64;
    let col_blocks = cols_padded / 16;
    let mut c = EventCounters::default();
    c.parallel_tasks = (col_blocks.div_ceil(g)) as u64;
    c.weight_unique_bytes = ((col_blocks * k_chunks as usize) * 64 + nnz * 2) as u64;
    c.input_unique_bytes = (batch * rows * 4) as u64;
    for _b in 0..batch {
        let mut cb0 = 0;
        while cb0 < col_blocks {
            let group = (col_blocks - cb0).min(g) as u64;
            // per k-chunk: bitmap + popcount + prefix per block in group
            c.avx_load += group * k_chunks;
            c.weight_stream_bytes += 64 * group * k_chunks;
            c.vpopcnt += group * k_chunks;
            c.prefix_step += 4 * group * k_chunks;
            // per row: one shared broadcast, then expand+fma per block
            c.broadcast += 16 * k_chunks;
            c.input_bytes += 4 * 16 * k_chunks;
            c.vpexpand += 16 * group * k_chunks;
            c.avx_fma += 16 * group * k_chunks;
            // FMA latency ~4 cycles: with `group` independent accumulator
            // registers, each FMA stalls max(0, 4/min(group,4) - 1) cycles
            let lat = 4u64;
            let stall_per_fma = lat / group.min(lat) - 1;
            c.fma_dep_stall += 16 * group * k_chunks * stall_per_fma;
            // epilogue store per block
            c.avx_store += group;
            c.output_bytes += 64 * group;
            cb0 += group as usize;
        }
        // values stream: all non-zeros expanded once per batch row
        c.weight_stream_bytes += (nnz * 2) as u64;
    }
    c
}

/// FLOPs of the logical GEMM (for roofline reporting).
pub fn gemm_flops(batch: usize, rows: usize, cols: usize) -> f64 {
    2.0 * batch as f64 * rows as f64 * cols as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::amx::kernels::{DenseWeights, GemmCounters};
    use crate::backend::{AmxBackend, AvxBackend, LinearBackend};
    use crate::sparse::format::SparseTensor;
    use crate::sparse::prune::magnitude_prune;
    use crate::util::XorShift;

    fn rand_mat(g: &mut XorShift, n: usize) -> Vec<f32> {
        (0..n).map(|_| g.next_normal() + 2.0).collect()
    }

    #[test]
    fn dense_bf16_matches_simulator_exactly() {
        let mut g = XorShift::new(21);
        let amx = AmxBackend;
        for &(b, k, n) in &[
            (1usize, 32usize, 16usize),
            (1, 64, 48),
            (4, 96, 80),
            (17, 32, 32),
            (33, 64, 16),
            (40, 50, 37),
        ] {
            let w = rand_mat(&mut g, k * n);
            let x = rand_mat(&mut g, b * k);
            let dw = DenseWeights::pack_f32(&w, k, n);
            let mut sim = GemmCounters::default();
            amx.gemm_bf16(&x, b, &dw, &mut sim);
            let ana = dense_bf16(b, k, n);
            assert_eq!(ana, sim, "shape ({b},{k},{n})");
        }
    }

    #[test]
    fn sparse_bf16_matches_simulator_exactly() {
        let mut g = XorShift::new(22);
        let amx = AmxBackend;
        for &(b, k, n, s) in &[
            (1usize, 64usize, 32usize, 0.5f64),
            (2, 96, 48, 0.8),
            (17, 50, 37, 0.3),
            (33, 32, 16, 0.0),
            (1, 64, 64, 1.0),
        ] {
            let w = magnitude_prune(&rand_mat(&mut g, k * n), s);
            let x = rand_mat(&mut g, b * k);
            let sp = SparseTensor::pack_f32(&w, k, n);
            let mut sim = GemmCounters::default();
            amx.sparse_gemm_bf16(&x, b, &sp, &mut sim);
            let ana = sparse_bf16(b, k, n, sp.nnz());
            assert_eq!(ana, sim, "shape ({b},{k},{n},{s})");
        }
    }

    #[test]
    fn avx_sparse_matches_simulator_exactly() {
        let mut g = XorShift::new(23);
        for &(b, k, n, s, grp) in &[
            (1usize, 64usize, 96usize, 0.5f64, 1usize),
            (1, 64, 96, 0.5, 4),
            (2, 50, 37, 0.7, 8),
            (3, 32, 160, 0.2, 3),
        ] {
            let avx = AvxBackend::with_groups(grp);
            let w = magnitude_prune(&rand_mat(&mut g, k * n), s);
            let x = rand_mat(&mut g, b * k);
            let sp = SparseTensor::pack_f32(&w, k, n);
            let mut sim = GemmCounters::default();
            avx.sparse_gemm_bf16(&x, b, &sp, &mut sim);
            let ana = avx_sparse_bf16(b, k, n, sp.nnz(), grp);
            assert_eq!(ana, sim, "shape ({b},{k},{n},{s},g{grp})");
        }
    }

    #[test]
    fn int8_matches_simulator_exactly() {
        let mut g = XorShift::new(24);
        let amx = AmxBackend;
        for &(b, k, n, s) in
            &[(1usize, 64usize, 32usize, 0.5f64), (5, 128, 48, 0.7), (2, 70, 20, 0.4)]
        {
            let w: Vec<i8> = (0..k * n)
                .map(|_| {
                    if g.next_f64() < s {
                        0
                    } else {
                        (g.below(200) as i32 - 100).max(1) as i8
                    }
                })
                .collect();
            let x: Vec<i8> = (0..b * k).map(|_| (g.below(200) as i32 - 100) as i8).collect();
            let dw: DenseWeights<i8> = DenseWeights::pack(&w, k, n);
            let sp: SparseTensor<i8> = SparseTensor::pack(&w, k, n);
            let mut simd = GemmCounters::default();
            amx.gemm_int8(&x, b, &dw, &mut simd);
            assert_eq!(dense_int8(b, k, n), simd, "dense ({b},{k},{n})");
            let mut sims = GemmCounters::default();
            amx.sparse_gemm_int8(&x, b, &sp, &mut sims);
            assert_eq!(sparse_int8(b, k, n, sp.nnz()), sims, "sparse ({b},{k},{n})");
        }
    }

    #[test]
    fn weight_traffic_ratio_follows_paper_bound() {
        // sparse/dense weight bytes ≈ 1/16 (bitmap) + (1-s) (values)
        let (k, n) = (4096, 4096);
        for s in [0.3f64, 0.5, 0.7, 0.9] {
            let nnz = ((1.0 - s) * (k * n) as f64).round() as usize;
            let d = dense_bf16(1, k, n).weight_stream_bytes as f64;
            let sp = sparse_bf16(1, k, n, nnz).weight_stream_bytes as f64;
            let expect = 1.0 / 16.0 + (1.0 - s);
            assert!((sp / d - expect).abs() < 0.01, "s={s}: {} vs {}", sp / d, expect);
        }
    }

    #[test]
    fn gemm_flops_counts_macs_twice() {
        assert_eq!(gemm_flops(2, 3, 4), 48.0);
    }
}
