//! Roofline analysis for the §Perf deliverable.
//!
//! Positions a kernel on the (arithmetic intensity, performance) plane of
//! the modeled machine and reports the achieved fraction of the relevant
//! roof — the "efficiency ratio" the paper's numbers translate to
//! (DESIGN.md §7).

use super::machine::Machine;
use crate::amx::EventCounters;

/// Roofline position of one kernel invocation.
#[derive(Clone, Copy, Debug)]
pub struct RooflinePoint {
    /// FLOP per DRAM byte.
    pub intensity: f64,
    /// Achieved FLOP/s under the cost model.
    pub achieved_flops: f64,
    /// min(peak compute, intensity × bandwidth): the roof at this
    /// intensity.
    pub roof_flops: f64,
    /// achieved / roof — the efficiency ratio.
    pub efficiency: f64,
    /// True if the roof at this intensity is the bandwidth slope.
    pub bandwidth_limited: bool,
}

/// Compute the roofline position for a kernel with `flops` useful FLOPs.
pub fn position(flops: f64, ctr: &EventCounters, m: &Machine) -> RooflinePoint {
    let cost = super::cost::KernelCost::from_counters(ctr, m);
    let (dram, _llc) = ctr.dram_llc_split(m.llc_bytes);
    let bytes = dram.max(1) as f64;
    let intensity = flops / bytes;
    let bw = m.effective_bw_gbs() * 1e9;
    let peak = m.peak_amx_bf16_flops();
    let roof = (intensity * bw).min(peak);
    let achieved = flops / cost.time.max(1e-18);
    RooflinePoint {
        intensity,
        achieved_flops: achieved,
        roof_flops: roof,
        efficiency: achieved / roof,
        bandwidth_limited: intensity * bw < peak,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::perf::analytic;

    #[test]
    fn decode_gemm_sits_on_bandwidth_roof() {
        let m = Machine::sapphire_rapids(32);
        let (b, k, n) = (1, 4096, 14336);
        let ctr = analytic::dense_bf16(b, k, n);
        let p = position(analytic::gemm_flops(b, k, n), &ctr, &m);
        assert!(p.bandwidth_limited, "batch-1 GEMM must be bandwidth limited");
        assert!(p.efficiency > 0.8, "dense kernel should track its roof: {p:?}");
        assert!(p.efficiency <= 1.05);
    }

    #[test]
    fn large_batch_moves_toward_compute_roof() {
        let m = Machine::sapphire_rapids(32);
        let p1 = position(
            analytic::gemm_flops(1, 4096, 4096),
            &analytic::dense_bf16(1, 4096, 4096),
            &m,
        );
        let p1024 = position(
            analytic::gemm_flops(1024, 4096, 4096),
            &analytic::dense_bf16(1024, 4096, 4096),
            &m,
        );
        assert!(p1024.intensity > 100.0 * p1.intensity);
        assert!(!p1024.bandwidth_limited);
    }

    #[test]
    fn sparse_raises_intensity_at_batch1() {
        // fewer DRAM bytes for the same useful FLOPs → higher intensity
        let m = Machine::sapphire_rapids(32);
        let flops = analytic::gemm_flops(1, 4096, 14336);
        let d = position(flops, &analytic::dense_bf16(1, 4096, 14336), &m);
        let nnz = (0.5 * (4096.0 * 14336.0)) as usize;
        let s = position(flops, &analytic::sparse_bf16(1, 4096, 14336, nnz), &m);
        assert!(s.intensity > 1.5 * d.intensity, "sparse {s:?} vs dense {d:?}");
    }
}
