//! Shard planning: partitioning a packed weight operand into per-worker
//! column-block shards with NUMA node hints.
//!
//! The shardable axis is the **output-column** axis, at the packing
//! granularity of one 16-column block ([`COLS_PER_BLOCK`]): both
//! `SparseTensor` and `DenseWeights` lay tiles out column-block-major
//! with the k dimension fastest (`tile_index = col_block * k_chunks +
//! k_chunk`), so a shard is a contiguous slice of tiles/metadata/values
//! and — crucially — the per-column accumulation order over k is the
//! same as in the unsharded kernel. Merging shard outputs is pure
//! column concatenation in fixed shard order, never a floating-point
//! re-association, which is what makes sharded execution bit-exact.
//!
//! Partitioning is a plan-compile-time operation: [`ShardPlan::partition`]
//! ticks a process-wide counter (same pattern as the PR-2 registry
//! resolution counter) so tests can assert the token loop never
//! re-partitions.

use std::sync::atomic::{AtomicU64, Ordering};

use crate::backend::PackedOperand;

/// Column granularity of a shard boundary: one packed tile column block.
pub const COLS_PER_BLOCK: usize = 16;

/// Environment override for the shard count, mirroring `SPARAMX_CAPS`.
pub const SHARDS_ENV: &str = "SPARAMX_SHARDS";

/// Process-wide count of shard partitioning operations. Partitioning
/// (slicing a packed operand into shards) must happen at plan-compile
/// time only; the decode loop asserts this stays flat.
static PARTITIONS: AtomicU64 = AtomicU64::new(0);

/// How many shard partitioning operations have run in this process.
pub fn partitions_performed() -> u64 {
    PARTITIONS.load(Ordering::Relaxed)
}

/// The `--shards {auto,N}` knob: `Auto` shards one-per-NUMA-node (no
/// sharding on single-node hosts), `Fixed(n)` forces `n` shards.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ShardChoice {
    Auto,
    Fixed(usize),
}

impl ShardChoice {
    pub const HELP: &'static str = "auto|N (number of shards, 1 disables)";

    /// Resolve the effective shard count against a topology, honoring
    /// the `SPARAMX_SHARDS` environment override (useful in CI, where
    /// every runner is single-node and `auto` would disable sharding).
    pub fn resolve(self, topo: &NumaTopology) -> usize {
        if let Ok(v) = std::env::var(SHARDS_ENV) {
            if let Ok(c) = v.parse::<ShardChoice>() {
                return c.resolve_no_env(topo);
            }
        }
        self.resolve_no_env(topo)
    }

    fn resolve_no_env(self, topo: &NumaTopology) -> usize {
        match self {
            ShardChoice::Auto => topo.nodes,
            ShardChoice::Fixed(n) => n.max(1),
        }
    }
}

impl Default for ShardChoice {
    fn default() -> Self {
        ShardChoice::Auto
    }
}

impl std::str::FromStr for ShardChoice {
    type Err = String;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s.trim().to_ascii_lowercase().as_str() {
            "auto" => Ok(ShardChoice::Auto),
            t => t
                .parse::<usize>()
                .map(ShardChoice::Fixed)
                .map_err(|_| format!("unknown shards value '{s}' (expected {})", Self::HELP)),
        }
    }
}

impl std::fmt::Display for ShardChoice {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ShardChoice::Auto => write!(f, "auto"),
            ShardChoice::Fixed(n) => write!(f, "{n}"),
        }
    }
}

/// NUMA topology of the host: node count and total core count. Detection
/// reads `/sys/devices/system/node`; everything else in this simulated
/// setting treats the node assignment as an advisory placement hint.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct NumaTopology {
    pub nodes: usize,
    pub cores: usize,
}

impl NumaTopology {
    /// A synthetic topology for tests and cost-model experiments.
    pub fn modeled(nodes: usize, cores: usize) -> NumaTopology {
        NumaTopology {
            nodes: nodes.max(1),
            cores: cores.max(1),
        }
    }

    /// Single-node topology with `cores` cores.
    pub fn single(cores: usize) -> NumaTopology {
        NumaTopology::modeled(1, cores)
    }

    /// Detect the host topology from sysfs; falls back to one node with
    /// the parallelism the OS reports.
    pub fn detect() -> NumaTopology {
        let cores = std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1);
        let nodes = std::fs::read_dir("/sys/devices/system/node")
            .map(|rd| {
                rd.filter_map(|e| e.ok())
                    .filter(|e| {
                        let n = e.file_name();
                        let n = n.to_string_lossy();
                        n.strip_prefix("node")
                            .map(|r| r.chars().all(|c| c.is_ascii_digit()) && !r.is_empty())
                            .unwrap_or(false)
                    })
                    .count()
            })
            .unwrap_or(0)
            .max(1);
        NumaTopology { nodes, cores }
    }

    /// Node hint for worker `w` of `workers`: contiguous worker ranges
    /// map to contiguous nodes.
    pub fn node_of(&self, w: usize, workers: usize) -> usize {
        if workers == 0 {
            return 0;
        }
        (w * self.nodes / workers).min(self.nodes - 1)
    }
}

/// A compiled shard partition of one weight operand's column axis:
/// which column blocks (and therefore which logical columns) each shard
/// owns, plus the NUMA node each shard is hinted to.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ShardPlan {
    pub shards: usize,
    /// Per-shard range of 16-column packed blocks.
    pub block_ranges: Vec<std::ops::Range<usize>>,
    /// Per-shard range of logical (unpadded) output columns.
    pub col_ranges: Vec<std::ops::Range<usize>>,
    /// Per-shard NUMA node hint.
    pub nodes: Vec<usize>,
}

impl ShardPlan {
    /// Build a plan without ticking the partition counter — used by the
    /// cost model, which must be able to price a hypothetical sharding
    /// without looking like a plan-compile event.
    pub fn build(cols: usize, shards: usize, topo: &NumaTopology) -> ShardPlan {
        let blocks = cols.div_ceil(COLS_PER_BLOCK).max(1);
        let shards = shards.clamp(1, blocks);
        let block_ranges = crate::util::threadpool::partition_ranges(blocks, shards);
        let col_ranges = block_ranges
            .iter()
            .map(|br| {
                let start = (br.start * COLS_PER_BLOCK).min(cols);
                let end = (br.end * COLS_PER_BLOCK).min(cols);
                start..end
            })
            .collect();
        let nodes = (0..shards).map(|s| topo.node_of(s, shards)).collect();
        ShardPlan {
            shards,
            block_ranges,
            col_ranges,
            nodes,
        }
    }

    /// Build a plan for real execution; ticks the process-wide partition
    /// counter (see [`partitions_performed`]).
    pub fn partition(cols: usize, shards: usize, topo: &NumaTopology) -> ShardPlan {
        PARTITIONS.fetch_add(1, Ordering::Relaxed);
        ShardPlan::build(cols, shards, topo)
    }

    /// Logical column width of each shard — the non-ticking helper the
    /// cost model uses to price per-shard kernels.
    pub fn col_widths(cols: usize, shards: usize) -> Vec<usize> {
        let blocks = cols.div_ceil(COLS_PER_BLOCK).max(1);
        let shards = shards.clamp(1, blocks);
        crate::util::threadpool::partition_ranges(blocks, shards)
            .iter()
            .map(|br| {
                (br.end * COLS_PER_BLOCK).min(cols) - (br.start * COLS_PER_BLOCK).min(cols)
            })
            .collect()
    }

    /// Total logical columns covered by the plan.
    pub fn cols(&self) -> usize {
        self.col_ranges.last().map(|r| r.end).unwrap_or(0)
    }
}

/// A weight operand pre-partitioned into per-shard packed slices at
/// plan-compile time. The decode loop hands this to
/// `Backend::gemm_bf16_sharded`, which runs the parts (in parallel on a
/// `ShardedBackend`, sequentially otherwise) and concatenates outputs
/// column-wise in shard order.
#[derive(Debug, Clone)]
pub struct ShardedOperand {
    pub rows: usize,
    pub cols: usize,
    pub plan: ShardPlan,
    pub parts: Vec<PackedOperand>,
}

impl ShardedOperand {
    /// Slice a whole packed operand into per-shard parts following
    /// `plan`. The whole operand is packed once; parts are contiguous
    /// tile-range slices, so no value is re-quantized or re-ordered.
    pub fn from_whole(whole: &PackedOperand, plan: ShardPlan) -> ShardedOperand {
        let (rows, cols) = whole.dims();
        debug_assert_eq!(plan.cols(), cols, "shard plan must cover the operand");
        let parts = plan
            .block_ranges
            .iter()
            .map(|br| match whole {
                PackedOperand::Sparse(sp) => {
                    PackedOperand::Sparse(sp.slice_col_blocks(br.clone()))
                }
                PackedOperand::Dense(dw) => {
                    PackedOperand::Dense(dw.slice_col_blocks(br.clone()))
                }
                PackedOperand::Sharded(_) => {
                    unreachable!("sharded operands cannot be re-sharded")
                }
            })
            .collect();
        ShardedOperand {
            rows,
            cols,
            plan,
            parts,
        }
    }
}

/// Concatenate per-shard output slabs column-wise in fixed shard order.
/// `parts[s]` is row-major `batch × col_ranges[s].len()`; the result is
/// row-major `batch × cols`. Pure data movement — bit-exact by
/// construction.
pub fn merge_col_outputs<T: Copy + Default>(
    parts: &[Vec<T>],
    plan: &ShardPlan,
    batch: usize,
    cols: usize,
) -> Vec<T> {
    let mut out = vec![T::default(); batch * cols];
    for (part, cr) in parts.iter().zip(&plan.col_ranges) {
        let sc = cr.len();
        for b in 0..batch {
            out[b * cols + cr.start..b * cols + cr.end]
                .copy_from_slice(&part[b * sc..(b + 1) * sc]);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shard_choice_parses() {
        assert_eq!("auto".parse::<ShardChoice>().unwrap(), ShardChoice::Auto);
        assert_eq!("4".parse::<ShardChoice>().unwrap(), ShardChoice::Fixed(4));
        assert!("lots".parse::<ShardChoice>().is_err());
        assert_eq!(ShardChoice::Fixed(2).to_string(), "2");
        assert_eq!(ShardChoice::Auto.to_string(), "auto");
    }

    #[test]
    fn auto_resolves_to_node_count() {
        let two = NumaTopology::modeled(2, 32);
        let one = NumaTopology::single(8);
        // resolve_no_env avoids interference from SPARAMX_SHARDS in the
        // test environment
        assert_eq!(ShardChoice::Auto.resolve_no_env(&two), 2);
        assert_eq!(ShardChoice::Auto.resolve_no_env(&one), 1);
        assert_eq!(ShardChoice::Fixed(4).resolve_no_env(&one), 4);
        assert_eq!(ShardChoice::Fixed(0).resolve_no_env(&one), 1);
    }

    #[test]
    fn detect_reports_at_least_one_node() {
        let t = NumaTopology::detect();
        assert!(t.nodes >= 1);
        assert!(t.cores >= 1);
    }

    #[test]
    fn plan_covers_columns_at_block_granularity() {
        // 112 cols = 7 blocks; 4 shards → blocks [2,2,2,1], cols
        // [32,32,32,16]
        let plan = ShardPlan::build(112, 4, &NumaTopology::modeled(2, 8));
        assert_eq!(plan.shards, 4);
        assert_eq!(
            plan.col_ranges,
            vec![0..32, 32..64, 64..96, 96..112]
        );
        assert_eq!(plan.nodes, vec![0, 0, 1, 1]);
        assert_eq!(plan.cols(), 112);
        assert_eq!(ShardPlan::col_widths(112, 4), vec![32, 32, 32, 16]);
    }

    #[test]
    fn plan_clamps_shards_to_blocks() {
        // 20 cols = 2 blocks; asking for 8 shards yields 2
        let plan = ShardPlan::build(20, 8, &NumaTopology::single(4));
        assert_eq!(plan.shards, 2);
        assert_eq!(plan.col_ranges, vec![0..16, 16..20]);
    }

    #[test]
    fn partition_ticks_counter_build_does_not() {
        // the single lib test that touches the global counter — all
        // non-ticking paths are asserted here so no parallel test races
        let topo = NumaTopology::single(4);
        let before = partitions_performed();
        let _ = ShardPlan::build(64, 2, &topo);
        let _ = ShardPlan::col_widths(64, 2);
        let m = crate::perf::Machine::sapphire_rapids(32);
        let _ = crate::perf::cost::sharded_sparse_gemm_cost(1, 4096, 14336, 0.5, 4, &m);
        let _ = crate::perf::cost::sharded_dense_gemm_cost(1, 4096, 14336, 4, &m);
        assert_eq!(
            partitions_performed(),
            before,
            "plan build / cost prediction must not count as partitioning"
        );
        let _ = ShardPlan::partition(64, 2, &topo);
        assert_eq!(partitions_performed(), before + 1);
    }

    #[test]
    fn merge_concatenates_columns_in_shard_order() {
        let plan = ShardPlan::build(32, 2, &NumaTopology::single(2));
        // batch=2, shard cols 16+16
        let a: Vec<f32> = (0..32).map(|i| i as f32).collect();
        let b: Vec<f32> = (0..32).map(|i| 100.0 + i as f32).collect();
        let out = merge_col_outputs(&[a.clone(), b.clone()], &plan, 2, 32);
        assert_eq!(&out[0..16], &a[0..16]);
        assert_eq!(&out[16..32], &b[0..16]);
        assert_eq!(&out[32..48], &a[16..32]);
        assert_eq!(&out[48..64], &b[16..32]);
    }
}
