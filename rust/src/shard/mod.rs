//! Sharded parallel execution subsystem (PR 6).
//!
//! SparAMX decode is memory-bound (Table 1), so the end-to-end lever is
//! spreading the weight stream across cores *and* memory controllers —
//! Fig 11's sparsity × core-count sweeps. This module adds that layer:
//!
//! * [`plan::ShardPlan`] — partitions a packed operand's output-column
//!   axis into contiguous 16-column-block shards with NUMA node hints;
//! * [`pool::WorkerPool`] — persistent worker threads with per-worker
//!   mailboxes and an epoch barrier (replaces per-call thread spawning
//!   in `util/threadpool.rs`, which is now a shim over this pool);
//!   panicked workers are respawned on the next scatter and a failed
//!   epoch surfaces as [`pool::EpochError`] instead of re-panicking;
//! * [`backend::ShardedBackend`] — wraps any inner `LinearBackend`,
//!   runs shards in parallel, and merges outputs by column
//!   concatenation in fixed shard order — bit-exact vs. the unsharded
//!   inner backend because the per-column k-accumulation order is
//!   untouched.
//!
//! Shard partitioning happens at plan-compile time only
//! ([`plan::partitions_performed`] is the assertion hook); the token
//! loop dispatches pre-packed [`plan::ShardedOperand`]s.

pub mod backend;
pub mod plan;
pub mod pool;

pub use backend::{ShardStatsSnapshot, ShardedBackend};
pub use plan::{
    merge_col_outputs, partitions_performed, NumaTopology, ShardChoice, ShardPlan,
    ShardedOperand, COLS_PER_BLOCK, SHARDS_ENV,
};
pub use pool::{EpochError, WorkerPool};
