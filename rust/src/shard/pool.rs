//! [`WorkerPool`]: persistent worker threads with per-worker mailboxes
//! and an epoch barrier.
//!
//! The paper's deployment pins one worker per partition for the process
//! lifetime (§7: the `weight_value_index` thread partition is computed
//! once, so the thread count is fixed at load). The old
//! `util/threadpool.rs` spawned OS threads on every `parallel_for` call;
//! this pool spawns them once and reuses them, which is what makes the
//! per-epoch overhead a constant (`SHARD_BARRIER_S` in `perf/cost.rs`)
//! instead of a per-call thread-creation cost.
//!
//! Execution model:
//!
//! * every worker owns a **mailbox** (FIFO + condvar) and sleeps on it;
//! * [`WorkerPool::scatter`] posts one closure per shard — shard `i`
//!   goes to worker `i * workers / shards`, keeping consecutive shards
//!   on consecutive workers (contiguous NUMA placement when the worker
//!   range is split across nodes);
//! * a shared **epoch barrier** (pending counter + condvar) blocks the
//!   caller until every posted job ran — which is also what makes the
//!   scoped-borrow transmute below sound;
//! * worker panics are caught, the epoch still completes, and the panic
//!   is re-raised on the caller so a broken shard can't hang the pool.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};

type Job = Box<dyn FnOnce() + Send + 'static>;

/// One worker's job queue. `closed` tells the worker to exit once the
/// queue drains (set by `Drop`).
struct Mailbox {
    queue: Mutex<(VecDeque<Job>, bool)>,
    ready: Condvar,
}

/// Epoch barrier: jobs outstanding in the current scatter, plus whether
/// any of them panicked.
struct Barrier {
    state: Mutex<(usize, bool)>,
    done: Condvar,
}

struct Shared {
    mailboxes: Vec<Mailbox>,
    barrier: Barrier,
}

/// Fixed-size persistent worker pool (workers spawned once, at
/// construction; see module docs).
pub struct WorkerPool {
    shared: Arc<Shared>,
    handles: Vec<std::thread::JoinHandle<()>>,
    /// Serializes scatters: the epoch barrier tracks one epoch at a time.
    submit: Mutex<()>,
    workers: usize,
    epochs: AtomicU64,
    /// NUMA node hint per worker (from the topology the pool was built
    /// for); purely advisory in this simulated setting.
    node_hints: Vec<usize>,
}

impl WorkerPool {
    /// Spawn `workers` persistent threads (minimum 1), assuming a single
    /// NUMA node.
    pub fn new(workers: usize) -> WorkerPool {
        WorkerPool::with_topology(workers, &super::plan::NumaTopology::single(workers))
    }

    /// Spawn `workers` persistent threads with NUMA node hints from
    /// `topo`: worker `w` is hinted to node `w * nodes / workers`, so a
    /// contiguous worker range maps to a contiguous node range.
    pub fn with_topology(workers: usize, topo: &super::plan::NumaTopology) -> WorkerPool {
        let workers = workers.max(1);
        let shared = Arc::new(Shared {
            mailboxes: (0..workers)
                .map(|_| Mailbox {
                    queue: Mutex::new((VecDeque::new(), false)),
                    ready: Condvar::new(),
                })
                .collect(),
            barrier: Barrier {
                state: Mutex::new((0, false)),
                done: Condvar::new(),
            },
        });
        let handles = (0..workers)
            .map(|w| {
                let shared = Arc::clone(&shared);
                std::thread::Builder::new()
                    .name(format!("sparamx-shard-{w}"))
                    .spawn(move || worker_loop(&shared, w))
                    .expect("spawn pool worker")
            })
            .collect();
        let node_hints = (0..workers).map(|w| topo.node_of(w, workers)).collect();
        WorkerPool {
            shared,
            handles,
            submit: Mutex::new(()),
            workers,
            epochs: AtomicU64::new(0),
            node_hints,
        }
    }

    /// Number of persistent workers.
    pub fn workers(&self) -> usize {
        self.workers
    }

    /// NUMA node hint of worker `w`.
    pub fn worker_node(&self, w: usize) -> usize {
        self.node_hints[w]
    }

    /// Barrier epochs completed so far (one per [`WorkerPool::scatter`]
    /// that posted at least one job) — lets tests assert the same
    /// persistent workers served every epoch.
    pub fn epochs(&self) -> u64 {
        self.epochs.load(Ordering::Relaxed)
    }

    /// Run one epoch: post each job to its worker's mailbox, then block
    /// on the barrier until all of them finished. Job `i` of `n` runs on
    /// worker `i * workers / n` (consecutive jobs → consecutive
    /// workers). Panics in a job are re-raised here after the epoch
    /// completes.
    pub fn scatter<'scope>(&self, jobs: Vec<Box<dyn FnOnce() + Send + 'scope>>) {
        if jobs.is_empty() {
            return;
        }
        let _serial = self.submit.lock().expect("pool submit lock");
        let n = jobs.len();
        {
            let mut st = self.shared.barrier.state.lock().expect("pool barrier lock");
            debug_assert_eq!(st.0, 0, "epoch barrier must be idle between scatters");
            *st = (n, false);
        }
        for (i, job) in jobs.into_iter().enumerate() {
            // SAFETY: the barrier wait below does not return until every
            // posted job has run to completion, so any borrow captured by
            // `job` (lifetime 'scope, which outlives this call) is live
            // for the job's whole execution. The 'static erasure never
            // lets a job outlive its borrows.
            let job: Job = unsafe {
                std::mem::transmute::<Box<dyn FnOnce() + Send + 'scope>, Job>(job)
            };
            let mb = &self.shared.mailboxes[i * self.workers / n];
            mb.queue.lock().expect("pool mailbox lock").0.push_back(job);
            mb.ready.notify_one();
        }
        let mut st = self.shared.barrier.state.lock().expect("pool barrier lock");
        while st.0 > 0 {
            st = self
                .shared
                .barrier
                .done
                .wait(st)
                .expect("pool barrier wait");
        }
        let panicked = st.1;
        st.1 = false;
        drop(st);
        self.epochs.fetch_add(1, Ordering::Relaxed);
        if panicked {
            panic!("worker pool job panicked");
        }
    }

    /// Run `f(i)` for every `i in 0..n`, work-stealing via an atomic
    /// cursor over the persistent workers. Inline when there is nothing
    /// to parallelize (the old `ThreadPool` contract).
    pub fn parallel_for<F>(&self, n: usize, f: F)
    where
        F: Fn(usize) + Sync,
    {
        if n == 0 {
            return;
        }
        if self.workers == 1 || n == 1 {
            for i in 0..n {
                f(i);
            }
            return;
        }
        let cursor = AtomicUsize::new(0);
        let lanes = self.workers.min(n);
        let jobs: Vec<Box<dyn FnOnce() + Send + '_>> = (0..lanes)
            .map(|_| {
                let f = &f;
                let cursor = &cursor;
                Box::new(move || loop {
                    let i = cursor.fetch_add(1, Ordering::Relaxed);
                    if i >= n {
                        break;
                    }
                    f(i);
                }) as Box<dyn FnOnce() + Send + '_>
            })
            .collect();
        self.scatter(jobs);
    }

    /// Map `f` over `0..n` collecting results in order.
    pub fn parallel_map<T, F>(&self, n: usize, f: F) -> Vec<T>
    where
        T: Send + Default + Clone,
        F: Fn(usize) -> T + Sync,
    {
        let mut out = vec![T::default(); n];
        {
            let slots: Vec<Mutex<&mut T>> = out.iter_mut().map(Mutex::new).collect();
            self.parallel_for(n, |i| {
                **slots[i].lock().expect("slot lock") = f(i);
            });
        }
        out
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        for mb in &self.shared.mailboxes {
            mb.queue.lock().expect("pool mailbox lock").1 = true;
            mb.ready.notify_all();
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

impl std::fmt::Debug for WorkerPool {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "WorkerPool({} workers, {} epochs)",
            self.workers,
            self.epochs()
        )
    }
}

fn worker_loop(shared: &Shared, w: usize) {
    let mb = &shared.mailboxes[w];
    loop {
        let job = {
            let mut q = mb.queue.lock().expect("pool mailbox lock");
            loop {
                if let Some(job) = q.0.pop_front() {
                    break Some(job);
                }
                if q.1 {
                    break None;
                }
                q = mb.ready.wait(q).expect("pool mailbox wait");
            }
        };
        let Some(job) = job else { return };
        let panicked =
            std::panic::catch_unwind(std::panic::AssertUnwindSafe(job)).is_err();
        let mut st = shared.barrier.state.lock().expect("pool barrier lock");
        if panicked {
            st.1 = true;
        }
        st.0 -= 1;
        if st.0 == 0 {
            shared.barrier.done.notify_all();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64 as TestCounter;

    #[test]
    fn scatter_runs_every_job_and_counts_epochs() {
        let pool = WorkerPool::new(3);
        let hits: Vec<TestCounter> = (0..7).map(|_| TestCounter::new(0)).collect();
        for _ in 0..4 {
            let jobs: Vec<Box<dyn FnOnce() + Send + '_>> = (0..7)
                .map(|i| {
                    let h = &hits[i];
                    Box::new(move || {
                        h.fetch_add(1, Ordering::SeqCst);
                    }) as Box<dyn FnOnce() + Send + '_>
                })
                .collect();
            pool.scatter(jobs);
        }
        assert!(hits.iter().all(|h| h.load(Ordering::SeqCst) == 4));
        assert_eq!(pool.epochs(), 4, "one epoch per scatter, threads reused");
        assert_eq!(pool.workers(), 3);
    }

    #[test]
    fn workers_persist_across_epochs() {
        // the same worker thread serves every epoch: record thread ids
        let pool = WorkerPool::new(2);
        let ids = Mutex::new(std::collections::BTreeSet::new());
        for _ in 0..10 {
            pool.parallel_for(8, |_| {
                ids.lock().unwrap().insert(format!("{:?}", std::thread::current().id()));
            });
        }
        // 10 epochs × up to 2 lanes, but only 2 distinct threads ever ran
        assert!(ids.lock().unwrap().len() <= 2);
        assert_eq!(pool.epochs(), 10);
    }

    #[test]
    fn parallel_for_visits_every_index_once() {
        let pool = WorkerPool::new(4);
        let hits: Vec<TestCounter> = (0..100).map(|_| TestCounter::new(0)).collect();
        pool.parallel_for(100, |i| {
            hits[i].fetch_add(1, Ordering::SeqCst);
        });
        assert!(hits.iter().all(|h| h.load(Ordering::SeqCst) == 1));
    }

    #[test]
    fn shard_to_worker_mapping_is_contiguous() {
        // 4 jobs on 8 workers land on workers 0,2,4,6; 8 jobs on 4
        // workers double up in order.
        let assign = |jobs: usize, workers: usize| -> Vec<usize> {
            (0..jobs).map(|i| i * workers / jobs).collect()
        };
        assert_eq!(assign(4, 8), vec![0, 2, 4, 6]);
        assert_eq!(assign(8, 4), vec![0, 0, 1, 1, 2, 2, 3, 3]);
        assert_eq!(assign(3, 2), vec![0, 0, 1]);
    }

    #[test]
    fn node_hints_split_workers_across_nodes() {
        let topo = crate::shard::NumaTopology::modeled(2, 8);
        let pool = WorkerPool::with_topology(4, &topo);
        let hints: Vec<usize> = (0..4).map(|w| pool.worker_node(w)).collect();
        assert_eq!(hints, vec![0, 0, 1, 1]);
    }

    #[test]
    #[should_panic(expected = "worker pool job panicked")]
    fn job_panic_reraises_on_caller() {
        let pool = WorkerPool::new(2);
        pool.parallel_for(4, |i| {
            if i == 2 {
                panic!("boom");
            }
        });
    }

    #[test]
    fn pool_survives_a_panicked_epoch() {
        let pool = WorkerPool::new(2);
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            pool.parallel_for(4, |_| panic!("boom"));
        }));
        assert!(r.is_err());
        // the barrier reset; the next epoch runs normally
        let n = TestCounter::new(0);
        pool.parallel_for(8, |_| {
            n.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(n.load(Ordering::SeqCst), 8);
    }
}
