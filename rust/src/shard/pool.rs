//! [`WorkerPool`]: persistent worker threads with per-worker mailboxes,
//! an epoch barrier, and self-healing on worker panic.
//!
//! The paper's deployment pins one worker per partition for the process
//! lifetime (§7: the `weight_value_index` thread partition is computed
//! once, so the thread count is fixed at load). The old
//! `util/threadpool.rs` spawned OS threads on every `parallel_for` call;
//! this pool spawns them once and reuses them, which is what makes the
//! per-epoch overhead a constant (`SHARD_BARRIER_S` in `perf/cost.rs`)
//! instead of a per-call thread-creation cost.
//!
//! Execution model:
//!
//! * every worker owns a **mailbox** (FIFO + condvar) and sleeps on it;
//! * [`WorkerPool::try_scatter`] posts one closure per shard — shard `i`
//!   goes to worker `i * workers / shards`, keeping consecutive shards
//!   on consecutive workers (contiguous NUMA placement when the worker
//!   range is split across nodes). A worker's jobs are posted under a
//!   single mailbox lock, so a worker observes either none or all of its
//!   epoch's jobs;
//! * a shared **epoch barrier** (pending counter + condvar) blocks the
//!   caller until every posted job ran or was abandoned — which is also
//!   what makes the scoped-borrow transmute below sound;
//! * a job panic **kills its worker**: the worker drains its remaining
//!   queued jobs (same epoch) so the barrier still completes, flags
//!   itself dead, and exits. The epoch then reports the failed job
//!   indices through [`EpochError`] instead of re-panicking, and the next
//!   scatter **heals** the pool by joining dead workers and spawning
//!   replacements (counted in [`WorkerPool::respawns`]);
//! * the legacy entry points ([`WorkerPool::scatter`],
//!   [`WorkerPool::parallel_for`], [`WorkerPool::parallel_map`]) keep the
//!   old contract and re-raise a failed epoch as a panic; recovery-aware
//!   callers use the `try_` forms;
//! * deterministic fault injection ([`crate::fault`]) hooks every
//!   scattered job with its (epoch, job index) pair, so a pinned
//!   `SPARAMX_FAULTS` schedule replays the exact same failure.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};

type Job = Box<dyn FnOnce() + Send + 'static>;

/// An epoch that completed with failed jobs: the indicated job indices
/// did not run (their job panicked, or their worker died before reaching
/// them). The pool stays usable — dead workers are respawned on the next
/// scatter.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct EpochError {
    /// 0-based index of the epoch that failed.
    pub epoch: u64,
    /// Ascending indices of jobs that did not run to completion.
    pub failed_jobs: Vec<usize>,
}

impl std::fmt::Display for EpochError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "shard epoch {} failed: jobs {:?} did not complete",
            self.epoch, self.failed_jobs
        )
    }
}

impl std::error::Error for EpochError {}

/// One worker's job queue. `closed` tells the worker to exit once the
/// queue drains (set by `Drop`). Jobs carry their epoch index so a dying
/// worker can report which ones it abandoned.
struct Mailbox {
    queue: Mutex<(VecDeque<(usize, Job)>, bool)>,
    ready: Condvar,
}

/// Epoch barrier: jobs outstanding in the current scatter, plus the
/// indices of jobs that did not complete.
struct Barrier {
    state: Mutex<(usize, Vec<usize>)>,
    done: Condvar,
}

struct Shared {
    mailboxes: Vec<Mailbox>,
    barrier: Barrier,
    /// Set by a worker that is exiting after a panicked job; cleared by
    /// `heal()` when the replacement thread is spawned.
    dead: Vec<AtomicBool>,
}

/// Fixed-size persistent worker pool (workers spawned once, at
/// construction, and respawned individually after a panicked job; see
/// module docs).
pub struct WorkerPool {
    shared: Arc<Shared>,
    handles: Mutex<Vec<Option<std::thread::JoinHandle<()>>>>,
    /// Serializes scatters: the epoch barrier tracks one epoch at a time.
    submit: Mutex<()>,
    workers: usize,
    epochs: AtomicU64,
    /// Cumulative workers respawned since construction.
    respawns_total: AtomicU64,
    /// Respawns not yet drained by [`WorkerPool::take_respawns`].
    respawns_pending: AtomicU64,
    /// NUMA node hint per worker (from the topology the pool was built
    /// for); purely advisory in this simulated setting.
    node_hints: Vec<usize>,
}

impl WorkerPool {
    /// Spawn `workers` persistent threads (minimum 1), assuming a single
    /// NUMA node.
    pub fn new(workers: usize) -> WorkerPool {
        WorkerPool::with_topology(workers, &super::plan::NumaTopology::single(workers))
    }

    /// Spawn `workers` persistent threads with NUMA node hints from
    /// `topo`: worker `w` is hinted to node `w * nodes / workers`, so a
    /// contiguous worker range maps to a contiguous node range.
    pub fn with_topology(workers: usize, topo: &super::plan::NumaTopology) -> WorkerPool {
        let workers = workers.max(1);
        let shared = Arc::new(Shared {
            mailboxes: (0..workers)
                .map(|_| Mailbox {
                    queue: Mutex::new((VecDeque::new(), false)),
                    ready: Condvar::new(),
                })
                .collect(),
            barrier: Barrier {
                state: Mutex::new((0, Vec::new())),
                done: Condvar::new(),
            },
            dead: (0..workers).map(|_| AtomicBool::new(false)).collect(),
        });
        let handles = (0..workers)
            .map(|w| Some(spawn_worker(&shared, w)))
            .collect();
        let node_hints = (0..workers).map(|w| topo.node_of(w, workers)).collect();
        WorkerPool {
            shared,
            handles: Mutex::new(handles),
            submit: Mutex::new(()),
            workers,
            epochs: AtomicU64::new(0),
            respawns_total: AtomicU64::new(0),
            respawns_pending: AtomicU64::new(0),
            node_hints,
        }
    }

    /// Number of persistent workers.
    pub fn workers(&self) -> usize {
        self.workers
    }

    /// NUMA node hint of worker `w`.
    pub fn worker_node(&self, w: usize) -> usize {
        self.node_hints[w]
    }

    /// Barrier epochs completed so far (one per scatter that posted at
    /// least one job, failed epochs included) — lets tests assert the
    /// same persistent workers served every epoch.
    pub fn epochs(&self) -> u64 {
        self.epochs.load(Ordering::Relaxed)
    }

    /// Cumulative workers respawned since construction.
    pub fn respawns(&self) -> u64 {
        self.respawns_total.load(Ordering::Relaxed)
    }

    /// Drain the respawn counter (the engine pulls this into its
    /// `worker_respawns` metric every step).
    pub fn take_respawns(&self) -> u64 {
        self.respawns_pending.swap(0, Ordering::Relaxed)
    }

    /// Join workers that died on a panicked job and spawn replacements.
    /// Runs under the submit lock at every scatter entry, so the pool is
    /// whole again before any new jobs are posted.
    fn heal(&self) {
        let mut handles = self.handles.lock().expect("pool handles lock");
        for w in 0..self.workers {
            if !self.shared.dead[w].swap(false, Ordering::Acquire) {
                continue;
            }
            if let Some(h) = handles[w].take() {
                let _ = h.join();
            }
            handles[w] = Some(spawn_worker(&self.shared, w));
            self.respawns_total.fetch_add(1, Ordering::Relaxed);
            self.respawns_pending.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Run one epoch: post each job to its worker's mailbox, then block
    /// on the barrier until all of them finished or were abandoned. Job
    /// `i` of `n` runs on worker `i * workers / n` (consecutive jobs →
    /// consecutive workers). Dead workers from a previous epoch are
    /// respawned before posting. Returns [`EpochError`] listing the jobs
    /// that did not complete, if any.
    pub fn try_scatter<'scope>(
        &self,
        jobs: Vec<Box<dyn FnOnce() + Send + 'scope>>,
    ) -> Result<(), EpochError> {
        if jobs.is_empty() {
            return Ok(());
        }
        let _serial = self.submit.lock().expect("pool submit lock");
        self.heal();
        let n = jobs.len();
        let epoch = self.epochs.load(Ordering::Relaxed);
        {
            let mut st = self.shared.barrier.state.lock().expect("pool barrier lock");
            debug_assert_eq!(st.0, 0, "epoch barrier must be idle between scatters");
            st.0 = n;
            st.1.clear();
        }
        // Group each worker's jobs so they are posted under a single
        // mailbox lock: a worker then observes either none or all of its
        // epoch's jobs, which is what lets a panicking worker drain
        // exactly its own leftovers before exiting.
        let mut per_worker: Vec<Vec<(usize, Job)>> =
            (0..self.workers).map(|_| Vec::new()).collect();
        for (i, job) in jobs.into_iter().enumerate() {
            // Fault-injection seam: every job is tagged with its (epoch,
            // index) pair so a pinned schedule replays deterministically.
            let armed: Box<dyn FnOnce() + Send + 'scope> = Box::new(move || {
                crate::fault::on_shard_job(epoch, i);
                job();
            });
            // SAFETY: the barrier wait below does not return until every
            // posted job has run to completion or been dropped unrun, so
            // any borrow captured by `job` (lifetime 'scope, which
            // outlives this call) is live for the job's whole execution.
            // The 'static erasure never lets a job outlive its borrows.
            let armed: Job = unsafe {
                std::mem::transmute::<Box<dyn FnOnce() + Send + 'scope>, Job>(armed)
            };
            per_worker[i * self.workers / n].push((i, armed));
        }
        for (w, batch) in per_worker.into_iter().enumerate() {
            if batch.is_empty() {
                continue;
            }
            let mb = &self.shared.mailboxes[w];
            mb.queue.lock().expect("pool mailbox lock").0.extend(batch);
            mb.ready.notify_one();
        }
        let mut st = self.shared.barrier.state.lock().expect("pool barrier lock");
        while st.0 > 0 {
            st = self
                .shared
                .barrier
                .done
                .wait(st)
                .expect("pool barrier wait");
        }
        let mut failed = std::mem::take(&mut st.1);
        drop(st);
        self.epochs.fetch_add(1, Ordering::Relaxed);
        if failed.is_empty() {
            Ok(())
        } else {
            failed.sort_unstable();
            Err(EpochError { epoch, failed_jobs: failed })
        }
    }

    /// Legacy epoch entry point: like [`WorkerPool::try_scatter`] but a
    /// failed epoch re-raises as a panic on the caller.
    pub fn scatter<'scope>(&self, jobs: Vec<Box<dyn FnOnce() + Send + 'scope>>) {
        if self.try_scatter(jobs).is_err() {
            panic!("worker pool job panicked");
        }
    }

    /// Run `f(i)` for every `i in 0..n`, work-stealing via an atomic
    /// cursor over the persistent workers. Inline when there is nothing
    /// to parallelize (the old `ThreadPool` contract). Returns
    /// [`EpochError`] if any lane panicked — note the surviving lanes
    /// keep draining the cursor, so indices other than the panicked ones
    /// still complete.
    pub fn try_parallel_for<F>(&self, n: usize, f: F) -> Result<(), EpochError>
    where
        F: Fn(usize) + Sync,
    {
        if n == 0 {
            return Ok(());
        }
        if self.workers == 1 || n == 1 {
            for i in 0..n {
                f(i);
            }
            return Ok(());
        }
        let cursor = AtomicUsize::new(0);
        let lanes = self.workers.min(n);
        let jobs: Vec<Box<dyn FnOnce() + Send + '_>> = (0..lanes)
            .map(|_| {
                let f = &f;
                let cursor = &cursor;
                Box::new(move || loop {
                    let i = cursor.fetch_add(1, Ordering::Relaxed);
                    if i >= n {
                        break;
                    }
                    f(i);
                }) as Box<dyn FnOnce() + Send + '_>
            })
            .collect();
        self.try_scatter(jobs)
    }

    /// Legacy form of [`WorkerPool::try_parallel_for`]: re-raises a
    /// failed epoch as a panic.
    pub fn parallel_for<F>(&self, n: usize, f: F)
    where
        F: Fn(usize) + Sync,
    {
        if self.try_parallel_for(n, f).is_err() {
            panic!("worker pool job panicked");
        }
    }

    /// Map `f` over `0..n` collecting results in order. On
    /// [`EpochError`] the partially-written results are discarded —
    /// recovery-aware callers re-run inline (bit-exact, the closure is
    /// pure per index).
    pub fn try_parallel_map<T, F>(&self, n: usize, f: F) -> Result<Vec<T>, EpochError>
    where
        T: Send + Default + Clone,
        F: Fn(usize) -> T + Sync,
    {
        let mut out = vec![T::default(); n];
        {
            let slots: Vec<Mutex<&mut T>> = out.iter_mut().map(Mutex::new).collect();
            self.try_parallel_for(n, |i| {
                **slots[i].lock().expect("slot lock") = f(i);
            })?;
        }
        Ok(out)
    }

    /// Legacy form of [`WorkerPool::try_parallel_map`]: re-raises a
    /// failed epoch as a panic.
    pub fn parallel_map<T, F>(&self, n: usize, f: F) -> Vec<T>
    where
        T: Send + Default + Clone,
        F: Fn(usize) -> T + Sync,
    {
        match self.try_parallel_map(n, f) {
            Ok(v) => v,
            Err(_) => panic!("worker pool job panicked"),
        }
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        for mb in &self.shared.mailboxes {
            mb.queue.lock().expect("pool mailbox lock").1 = true;
            mb.ready.notify_all();
        }
        let mut handles = self.handles.lock().expect("pool handles lock");
        for h in handles.iter_mut() {
            if let Some(h) = h.take() {
                let _ = h.join();
            }
        }
    }
}

impl std::fmt::Debug for WorkerPool {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "WorkerPool({} workers, {} epochs, {} respawns)",
            self.workers,
            self.epochs(),
            self.respawns()
        )
    }
}

fn spawn_worker(shared: &Arc<Shared>, w: usize) -> std::thread::JoinHandle<()> {
    let shared = Arc::clone(shared);
    std::thread::Builder::new()
        .name(format!("sparamx-shard-{w}"))
        .spawn(move || worker_loop(&shared, w))
        .expect("spawn pool worker")
}

fn worker_loop(shared: &Shared, w: usize) {
    let mb = &shared.mailboxes[w];
    loop {
        let job = {
            let mut q = mb.queue.lock().expect("pool mailbox lock");
            loop {
                if let Some(job) = q.0.pop_front() {
                    break Some(job);
                }
                if q.1 {
                    break None;
                }
                q = mb.ready.wait(q).expect("pool mailbox wait");
            }
        };
        let Some((idx, job)) = job else { return };
        let panicked = std::panic::catch_unwind(std::panic::AssertUnwindSafe(job)).is_err();
        if panicked {
            // This worker is going down. Drain its remaining queued jobs
            // (all from the same epoch — scatter posts a worker's batch
            // atomically) so the barrier still completes, flag the worker
            // dead for heal(), and exit the thread.
            let abandoned: Vec<usize> = {
                let mut q = mb.queue.lock().expect("pool mailbox lock");
                q.0.drain(..).map(|(i, _)| i).collect()
            };
            shared.dead[w].store(true, Ordering::Release);
            let mut st = shared.barrier.state.lock().expect("pool barrier lock");
            st.1.push(idx);
            st.1.extend(&abandoned);
            st.0 -= 1 + abandoned.len();
            if st.0 == 0 {
                shared.barrier.done.notify_all();
            }
            return;
        }
        let mut st = shared.barrier.state.lock().expect("pool barrier lock");
        st.0 -= 1;
        if st.0 == 0 {
            shared.barrier.done.notify_all();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64 as TestCounter;

    #[test]
    fn scatter_runs_every_job_and_counts_epochs() {
        let pool = WorkerPool::new(3);
        let hits: Vec<TestCounter> = (0..7).map(|_| TestCounter::new(0)).collect();
        for _ in 0..4 {
            let jobs: Vec<Box<dyn FnOnce() + Send + '_>> = (0..7)
                .map(|i| {
                    let h = &hits[i];
                    Box::new(move || {
                        h.fetch_add(1, Ordering::SeqCst);
                    }) as Box<dyn FnOnce() + Send + '_>
                })
                .collect();
            pool.scatter(jobs);
        }
        assert!(hits.iter().all(|h| h.load(Ordering::SeqCst) == 4));
        assert_eq!(pool.epochs(), 4, "one epoch per scatter, threads reused");
        assert_eq!(pool.workers(), 3);
        assert_eq!(pool.respawns(), 0);
    }

    #[test]
    fn workers_persist_across_epochs() {
        // the same worker thread serves every epoch: record thread ids
        let pool = WorkerPool::new(2);
        let ids = Mutex::new(std::collections::BTreeSet::new());
        for _ in 0..10 {
            pool.parallel_for(8, |_| {
                ids.lock().unwrap().insert(format!("{:?}", std::thread::current().id()));
            });
        }
        // 10 epochs × up to 2 lanes, but only 2 distinct threads ever ran
        assert!(ids.lock().unwrap().len() <= 2);
        assert_eq!(pool.epochs(), 10);
    }

    #[test]
    fn parallel_for_visits_every_index_once() {
        let pool = WorkerPool::new(4);
        let hits: Vec<TestCounter> = (0..100).map(|_| TestCounter::new(0)).collect();
        pool.parallel_for(100, |i| {
            hits[i].fetch_add(1, Ordering::SeqCst);
        });
        assert!(hits.iter().all(|h| h.load(Ordering::SeqCst) == 1));
    }

    #[test]
    fn shard_to_worker_mapping_is_contiguous() {
        // 4 jobs on 8 workers land on workers 0,2,4,6; 8 jobs on 4
        // workers double up in order.
        let assign = |jobs: usize, workers: usize| -> Vec<usize> {
            (0..jobs).map(|i| i * workers / jobs).collect()
        };
        assert_eq!(assign(4, 8), vec![0, 2, 4, 6]);
        assert_eq!(assign(8, 4), vec![0, 0, 1, 1, 2, 2, 3, 3]);
        assert_eq!(assign(3, 2), vec![0, 0, 1]);
    }

    #[test]
    fn node_hints_split_workers_across_nodes() {
        let topo = crate::shard::NumaTopology::modeled(2, 8);
        let pool = WorkerPool::with_topology(4, &topo);
        let hints: Vec<usize> = (0..4).map(|w| pool.worker_node(w)).collect();
        assert_eq!(hints, vec![0, 0, 1, 1]);
    }

    #[test]
    #[should_panic(expected = "worker pool job panicked")]
    fn job_panic_reraises_on_caller() {
        let pool = WorkerPool::new(2);
        pool.parallel_for(4, |i| {
            if i == 2 {
                panic!("boom");
            }
        });
    }

    #[test]
    fn pool_survives_a_panicked_epoch_and_respawns_workers() {
        let pool = WorkerPool::new(2);
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            pool.parallel_for(4, |_| panic!("boom"));
        }));
        assert!(r.is_err());
        // the barrier reset; the next epoch heals the pool and runs normally
        let n = TestCounter::new(0);
        pool.parallel_for(8, |_| {
            n.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(n.load(Ordering::SeqCst), 8);
        // both lanes panicked above, so both workers were replaced
        assert_eq!(pool.respawns(), 2);
        assert_eq!(pool.take_respawns(), 2);
        assert_eq!(pool.take_respawns(), 0, "pending counter drains once");
        assert_eq!(pool.respawns(), 2, "cumulative counter survives the drain");
    }

    #[test]
    fn try_scatter_reports_failed_jobs_and_heals() {
        let pool = WorkerPool::new(2);
        let ran = TestCounter::new(0);
        let jobs: Vec<Box<dyn FnOnce() + Send + '_>> = (0..2)
            .map(|i| {
                let ran = &ran;
                Box::new(move || {
                    if i == 1 {
                        panic!("injected");
                    }
                    ran.fetch_add(1, Ordering::SeqCst);
                }) as Box<dyn FnOnce() + Send + '_>
            })
            .collect();
        let err = pool.try_scatter(jobs).unwrap_err();
        assert_eq!(err.epoch, 0);
        assert_eq!(err.failed_jobs, vec![1]);
        assert_eq!(ran.load(Ordering::SeqCst), 1, "surviving shard completed");
        assert!(format!("{err}").contains("epoch 0"));
        // retry on the healed pool: both jobs complete
        let jobs: Vec<Box<dyn FnOnce() + Send + '_>> = (0..2)
            .map(|_| {
                let ran = &ran;
                Box::new(move || {
                    ran.fetch_add(1, Ordering::SeqCst);
                }) as Box<dyn FnOnce() + Send + '_>
            })
            .collect();
        pool.try_scatter(jobs).unwrap();
        assert_eq!(ran.load(Ordering::SeqCst), 3);
        assert_eq!(pool.respawns(), 1);
        assert_eq!(pool.epochs(), 2, "failed epochs still count");
    }

    #[test]
    fn dying_worker_abandons_its_queued_jobs_without_hanging() {
        // 4 jobs on 1 worker... a single worker pool runs jobs inline via
        // parallel_for, so scatter directly: all 4 jobs queue on worker 0,
        // job 0 panics, jobs 1..3 are abandoned but the barrier completes.
        let pool = WorkerPool::new(1);
        let ran = TestCounter::new(0);
        let jobs: Vec<Box<dyn FnOnce() + Send + '_>> = (0..4)
            .map(|i| {
                let ran = &ran;
                Box::new(move || {
                    if i == 0 {
                        panic!("injected");
                    }
                    ran.fetch_add(1, Ordering::SeqCst);
                }) as Box<dyn FnOnce() + Send + '_>
            })
            .collect();
        let err = pool.try_scatter(jobs).unwrap_err();
        assert_eq!(err.failed_jobs, vec![0, 1, 2, 3]);
        assert_eq!(ran.load(Ordering::SeqCst), 0);
        // healed pool still works (scatter, not parallel_for: a 1-worker
        // parallel_for runs inline and would never reach heal())
        let job: Vec<Box<dyn FnOnce() + Send + '_>> = vec![Box::new(|| {
            ran.fetch_add(1, Ordering::SeqCst);
        })];
        pool.try_scatter(job).unwrap();
        assert_eq!(ran.load(Ordering::SeqCst), 1);
        assert_eq!(pool.respawns(), 1);
    }
}
