//! [`ShardedBackend`]: a `LinearBackend` wrapper that executes column
//! shards of an operand in parallel on the persistent [`WorkerPool`].
//!
//! Correctness contract: the output is **bit-exact** vs. the inner
//! backend run unsharded. Shards split the *output-column* axis at
//! packed-block granularity, so every output column is still computed by
//! one kernel invocation with the exact same k-accumulation order; the
//! "reduction" is a fixed-shard-order column concatenation
//! ([`crate::shard::merge_col_outputs`]), never a floating-point
//! re-association. The sequential oracle is the trait's default
//! `gemm_bf16_sharded`; tests assert the pool-parallel path matches it
//! and the unsharded inner backend exactly.
//!
//! Performance contract: `predict` prices one epoch as the slowest
//! shard's kernel on its NUMA-partitioned slice of the machine plus the
//! epoch barrier ([`crate::perf::cost::sharded_time`]) — the Fig 11
//! crossover where sharding wins large memory-bound shapes and loses
//! small batch-1 shapes.
//!
//! Recovery contract: a failed pool epoch (a worker panicked —
//! [`crate::shard::EpochError`]) is retried **once** on the healed pool,
//! then falls back to running the shards sequentially inline. Both rungs
//! reuse the exact same per-shard kernels and fixed merge order, so
//! recovery is bit-exact vs. a fault-free run; per-job event counters
//! merge only from the attempt that completed. Retries are surfaced in
//! [`ShardStatsSnapshot::epoch_retries`].

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use super::plan::{merge_col_outputs, NumaTopology, ShardPlan};
use super::pool::WorkerPool;
use crate::amx::kernels::DenseWeights;
use crate::amx::EventCounters;
use crate::backend::{Backend, BackendKind, CpuCaps, Dtype, GemmShape, LinearBackend};
use crate::perf::Machine;
use crate::sparse::format::SparseTensor;
use crate::util::bf16::Bf16;

/// Per-shard timing accumulated since the last snapshot, drained by the
/// metrics layer via `LinearBackend::shard_stats`.
#[derive(Debug, Clone, PartialEq)]
pub struct ShardStatsSnapshot {
    /// Accumulated wall seconds each shard spent in its kernel.
    pub per_shard_time_s: Vec<f64>,
    /// Pool epochs contributing to the accumulation.
    pub epochs: u64,
    /// Epochs that had to be retried on the healed pool after a worker
    /// panic (see the module's recovery contract).
    pub epoch_retries: u64,
}

impl ShardStatsSnapshot {
    /// Max/min shard-time ratio — the load-imbalance gauge (1.0 =
    /// perfectly balanced; large = one shard straggles the barrier).
    pub fn imbalance(&self) -> f64 {
        let mn = self.per_shard_time_s.iter().copied().fold(f64::MAX, f64::min);
        let mx = self.per_shard_time_s.iter().copied().fold(0.0, f64::max);
        if self.per_shard_time_s.is_empty() || mn <= 0.0 {
            1.0
        } else {
            mx / mn
        }
    }
}

/// Column-sharding wrapper over an inner backend (see module docs).
pub struct ShardedBackend {
    inner: Backend,
    shards: usize,
    topo: NumaTopology,
    pool: Arc<WorkerPool>,
    /// Accumulated per-shard kernel seconds since the last snapshot.
    stats: Mutex<Vec<f64>>,
    epochs: AtomicU64,
    /// Epoch retries since the last snapshot (see module docs).
    retries: AtomicU64,
}

impl ShardedBackend {
    /// Wrap `inner`, splitting operands into `shards` column shards run
    /// on `pool`. Sharding a sharded backend is a construction error.
    pub fn new(
        inner: Backend,
        shards: usize,
        topo: NumaTopology,
        pool: Arc<WorkerPool>,
    ) -> ShardedBackend {
        assert!(
            inner.kind() != BackendKind::Sharded,
            "cannot shard an already-sharded backend"
        );
        ShardedBackend {
            inner,
            shards: shards.max(1),
            topo,
            pool,
            stats: Mutex::new(Vec::new()),
            epochs: AtomicU64::new(0),
            retries: AtomicU64::new(0),
        }
    }

    /// The wrapped backend.
    pub fn inner(&self) -> &Backend {
        &self.inner
    }

    /// Configured shard count (actual plans clamp to the operand's
    /// block count).
    pub fn shards(&self) -> usize {
        self.shards
    }

    fn record_epoch(&self, times: &[f64]) {
        let mut acc = self.stats.lock().expect("shard stats lock");
        if acc.len() < times.len() {
            acc.resize(times.len(), 0.0);
        }
        for (a, t) in acc.iter_mut().zip(times) {
            *a += t;
        }
        self.epochs.fetch_add(1, Ordering::Relaxed);
    }

    /// Run one sharded epoch: execute `run(shard, ctr)` for every shard
    /// of `plan` on the worker pool, merge event counters in fixed
    /// shard order, record per-shard times, and concatenate the output
    /// columns. A degenerate single-shard plan runs inline.
    ///
    /// Recovery ladder (module docs): a failed pool epoch is retried
    /// once on the healed pool, then falls back to sequential inline
    /// execution of the same shards. Every rung is bit-exact vs. a
    /// fault-free run, and counters merge only from the attempt that
    /// completed.
    fn run_epoch<T, F>(
        &self,
        plan: &ShardPlan,
        batch: usize,
        cols: usize,
        ctr: &mut EventCounters,
        run: F,
    ) -> Vec<T>
    where
        T: Copy + Default + Send,
        F: Fn(usize, &mut EventCounters) -> Vec<T> + Sync,
    {
        let n = plan.shards;
        if n <= 1 {
            let t0 = std::time::Instant::now();
            let out = run(0, ctr);
            self.record_epoch(&[t0.elapsed().as_secs_f64()]);
            return out;
        }
        for attempt in 0..2 {
            let mut slots: Vec<Option<(Vec<T>, EventCounters, f64)>> =
                (0..n).map(|_| None).collect();
            let scattered = {
                let slot_refs: Vec<Mutex<&mut Option<(Vec<T>, EventCounters, f64)>>> =
                    slots.iter_mut().map(Mutex::new).collect();
                let jobs: Vec<Box<dyn FnOnce() + Send + '_>> = (0..n)
                    .map(|s| {
                        let slot = &slot_refs[s];
                        let run = &run;
                        Box::new(move || {
                            let t0 = std::time::Instant::now();
                            let mut c = EventCounters::default();
                            let out = run(s, &mut c);
                            **slot.lock().expect("shard slot lock") =
                                Some((out, c, t0.elapsed().as_secs_f64()));
                        }) as Box<dyn FnOnce() + Send + '_>
                    })
                    .collect();
                self.pool.try_scatter(jobs)
            };
            match scattered {
                Ok(()) => {
                    let mut parts = Vec::with_capacity(n);
                    let mut times = vec![0.0f64; n];
                    for (s, slot) in slots.into_iter().enumerate() {
                        let (out, c, dt) = slot.expect("shard job ran (barrier passed)");
                        ctr.merge(&c);
                        times[s] = dt;
                        parts.push(out);
                    }
                    self.record_epoch(&times);
                    return merge_col_outputs(&parts, plan, batch, cols);
                }
                Err(_) if attempt == 0 => {
                    self.retries.fetch_add(1, Ordering::Relaxed);
                }
                Err(_) => break,
            }
        }
        // Sequential inline fallback: same shards, same kernels, same
        // fixed merge order — identical numerics to the pool path.
        let mut parts = Vec::with_capacity(n);
        let mut times = vec![0.0f64; n];
        for (s, time) in times.iter_mut().enumerate() {
            let t0 = std::time::Instant::now();
            let mut c = EventCounters::default();
            parts.push(run(s, &mut c));
            ctr.merge(&c);
            *time = t0.elapsed().as_secs_f64();
        }
        self.record_epoch(&times);
        merge_col_outputs(&parts, plan, batch, cols)
    }
}

impl LinearBackend for ShardedBackend {
    fn name(&self) -> &'static str {
        match self.inner.kind() {
            BackendKind::Amx => "sharded-amx",
            BackendKind::Avx => "sharded-avx",
            BackendKind::Reference => "sharded-ref",
            BackendKind::Baseline => "sharded-baseline",
            BackendKind::Sharded => unreachable!("checked at construction"),
        }
    }

    fn kind(&self) -> BackendKind {
        BackendKind::Sharded
    }

    fn supported(&self, caps: &CpuCaps) -> bool {
        self.inner.supported(caps)
    }

    fn supported_dtype(&self, caps: &CpuCaps, dtype: Dtype) -> bool {
        self.inner.supported_dtype(caps, dtype)
    }

    fn dense_as_stream(&self) -> bool {
        self.inner.dense_as_stream()
    }

    fn shard_spec(&self) -> Option<(usize, NumaTopology)> {
        Some((self.shards, self.topo))
    }

    /// Direct-call dense path: partitions on the fly (ticks the
    /// partition counter — the serving path avoids this by pre-packing
    /// a `ShardedOperand` at plan-compile time).
    fn gemm_bf16(
        &self,
        input: &[f32],
        batch: usize,
        w: &DenseWeights<Bf16>,
        ctr: &mut EventCounters,
    ) -> Vec<f32> {
        let plan = ShardPlan::partition(w.cols, self.shards, &self.topo);
        let parts: Vec<DenseWeights<Bf16>> = plan
            .block_ranges
            .iter()
            .map(|br| w.slice_col_blocks(br.clone()))
            .collect();
        self.run_epoch(&plan, batch, w.cols, ctr, |s, c| {
            self.inner.gemm_bf16(input, batch, &parts[s], c)
        })
    }

    fn sparse_gemm_bf16(
        &self,
        input: &[f32],
        batch: usize,
        sp: &SparseTensor<Bf16>,
        ctr: &mut EventCounters,
    ) -> Vec<f32> {
        let plan = ShardPlan::partition(sp.cols, self.shards, &self.topo);
        let parts: Vec<SparseTensor<Bf16>> = plan
            .block_ranges
            .iter()
            .map(|br| sp.slice_col_blocks(br.clone()))
            .collect();
        self.run_epoch(&plan, batch, sp.cols, ctr, |s, c| {
            self.inner.sparse_gemm_bf16(input, batch, &parts[s], c)
        })
    }

    fn gemm_int8(
        &self,
        input: &[i8],
        batch: usize,
        w: &DenseWeights<i8>,
        ctr: &mut EventCounters,
    ) -> Vec<i32> {
        let plan = ShardPlan::partition(w.cols, self.shards, &self.topo);
        let parts: Vec<DenseWeights<i8>> = plan
            .block_ranges
            .iter()
            .map(|br| w.slice_col_blocks(br.clone()))
            .collect();
        self.run_epoch(&plan, batch, w.cols, ctr, |s, c| {
            self.inner.gemm_int8(input, batch, &parts[s], c)
        })
    }

    fn sparse_gemm_int8(
        &self,
        input: &[i8],
        batch: usize,
        sp: &SparseTensor<i8>,
        ctr: &mut EventCounters,
    ) -> Vec<i32> {
        let plan = ShardPlan::partition(sp.cols, self.shards, &self.topo);
        let parts: Vec<SparseTensor<i8>> = plan
            .block_ranges
            .iter()
            .map(|br| sp.slice_col_blocks(br.clone()))
            .collect();
        self.run_epoch(&plan, batch, sp.cols, ctr, |s, c| {
            self.inner.sparse_gemm_int8(input, batch, &parts[s], c)
        })
    }

    /// Serving path: the operand was partitioned at plan-compile time;
    /// no partitioning (and no counter tick) happens here.
    fn gemm_bf16_sharded(
        &self,
        input: &[f32],
        batch: usize,
        op: &crate::shard::ShardedOperand,
        ctr: &mut EventCounters,
    ) -> Vec<f32> {
        self.run_epoch(&op.plan, batch, op.cols, ctr, |s, c| {
            match &op.parts[s] {
                crate::backend::PackedOperand::Sparse(sp) => {
                    self.inner.sparse_gemm_bf16(input, batch, sp, c)
                }
                crate::backend::PackedOperand::Dense(dw) => {
                    self.inner.gemm_bf16(input, batch, dw, c)
                }
                crate::backend::PackedOperand::Sharded(_) => {
                    unreachable!("nested sharded operand")
                }
            }
        })
    }

    // Fused (multi-row) entry points: the same epoch machinery runs the
    // inner backend's *batched* kernels per column shard, so a fused
    // GEMM both amortizes the weight stream over the batch and splits
    // the column axis across workers. Still column partitioning only —
    // merge order and per-column k-accumulation are unchanged, so these
    // stay bit-exact vs. the unsharded batched call and vs. looping
    // batch 1.

    fn gemm_bf16_batched(
        &self,
        input: &[f32],
        batch: usize,
        w: &DenseWeights<Bf16>,
        ctr: &mut EventCounters,
    ) -> Vec<f32> {
        let plan = ShardPlan::partition(w.cols, self.shards, &self.topo);
        let parts: Vec<DenseWeights<Bf16>> = plan
            .block_ranges
            .iter()
            .map(|br| w.slice_col_blocks(br.clone()))
            .collect();
        self.run_epoch(&plan, batch, w.cols, ctr, |s, c| {
            self.inner.gemm_bf16_batched(input, batch, &parts[s], c)
        })
    }

    fn sparse_gemm_bf16_batched(
        &self,
        input: &[f32],
        batch: usize,
        sp: &SparseTensor<Bf16>,
        ctr: &mut EventCounters,
    ) -> Vec<f32> {
        let plan = ShardPlan::partition(sp.cols, self.shards, &self.topo);
        let parts: Vec<SparseTensor<Bf16>> = plan
            .block_ranges
            .iter()
            .map(|br| sp.slice_col_blocks(br.clone()))
            .collect();
        self.run_epoch(&plan, batch, sp.cols, ctr, |s, c| {
            self.inner.sparse_gemm_bf16_batched(input, batch, &parts[s], c)
        })
    }

    fn gemm_int8_batched(
        &self,
        input: &[i8],
        batch: usize,
        w: &DenseWeights<i8>,
        ctr: &mut EventCounters,
    ) -> Vec<i32> {
        let plan = ShardPlan::partition(w.cols, self.shards, &self.topo);
        let parts: Vec<DenseWeights<i8>> = plan
            .block_ranges
            .iter()
            .map(|br| w.slice_col_blocks(br.clone()))
            .collect();
        self.run_epoch(&plan, batch, w.cols, ctr, |s, c| {
            self.inner.gemm_int8_batched(input, batch, &parts[s], c)
        })
    }

    fn sparse_gemm_int8_batched(
        &self,
        input: &[i8],
        batch: usize,
        sp: &SparseTensor<i8>,
        ctr: &mut EventCounters,
    ) -> Vec<i32> {
        let plan = ShardPlan::partition(sp.cols, self.shards, &self.topo);
        let parts: Vec<SparseTensor<i8>> = plan
            .block_ranges
            .iter()
            .map(|br| sp.slice_col_blocks(br.clone()))
            .collect();
        self.run_epoch(&plan, batch, sp.cols, ctr, |s, c| {
            self.inner.sparse_gemm_int8_batched(input, batch, &parts[s], c)
        })
    }

    /// Serving path for fused decode: pre-partitioned operand, batched
    /// inner kernels, no partitioning tick.
    fn gemm_bf16_sharded_batched(
        &self,
        input: &[f32],
        batch: usize,
        op: &crate::shard::ShardedOperand,
        ctr: &mut EventCounters,
    ) -> Vec<f32> {
        self.run_epoch(&op.plan, batch, op.cols, ctr, |s, c| {
            match &op.parts[s] {
                crate::backend::PackedOperand::Sparse(sp) => {
                    self.inner.sparse_gemm_bf16_batched(input, batch, sp, c)
                }
                crate::backend::PackedOperand::Dense(dw) => {
                    self.inner.gemm_bf16_batched(input, batch, dw, c)
                }
                crate::backend::PackedOperand::Sharded(_) => {
                    unreachable!("nested sharded operand")
                }
            }
        })
    }

    /// Slowest shard on its NUMA slice of the machine + barrier; shares
    /// `perf::cost::sharded_time` with the cost-model convenience
    /// functions so registry selection agrees by construction.
    fn predict(
        &self,
        shape: GemmShape,
        sparsity: f64,
        dtype: Dtype,
        sparse: bool,
        m: &Machine,
    ) -> f64 {
        crate::perf::cost::sharded_time(shape.n, self.shards, m, &|cols, sm| {
            self.inner
                .predict(GemmShape::new(shape.batch, shape.k, cols), sparsity, dtype, sparse, sm)
        })
    }

    fn shard_stats(&self) -> Option<ShardStatsSnapshot> {
        let mut acc = self.stats.lock().expect("shard stats lock");
        let per_shard_time_s = std::mem::take(&mut *acc);
        Some(ShardStatsSnapshot {
            per_shard_time_s,
            epochs: self.epochs.swap(0, Ordering::Relaxed),
            epoch_retries: self.retries.swap(0, Ordering::Relaxed),
        })
    }

    fn worker_pool(&self) -> Option<Arc<WorkerPool>> {
        Some(Arc::clone(&self.pool))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sharded_over(inner: Backend, shards: usize) -> Backend {
        let topo = NumaTopology::modeled(2, 8);
        let pool = Arc::new(WorkerPool::with_topology(shards.min(4), &topo));
        Backend::sharded(inner, shards, topo, pool)
    }

    #[test]
    fn names_follow_inner_kind() {
        assert_eq!(sharded_over(Backend::amx(), 2).name(), "sharded-amx");
        assert_eq!(sharded_over(Backend::avx(), 2).name(), "sharded-avx");
        assert_eq!(sharded_over(Backend::reference(), 2).name(), "sharded-ref");
        assert_eq!(sharded_over(Backend::amx(), 2).kind(), BackendKind::Sharded);
    }

    #[test]
    #[should_panic(expected = "already-sharded")]
    fn nesting_sharded_backends_is_rejected() {
        let once = sharded_over(Backend::amx(), 2);
        let _ = sharded_over(once, 2);
    }

    #[test]
    fn imbalance_gauge() {
        let s = ShardStatsSnapshot {
            per_shard_time_s: vec![2.0, 1.0, 4.0],
            epochs: 3,
            epoch_retries: 0,
        };
        assert!((s.imbalance() - 4.0).abs() < 1e-12);
        let empty = ShardStatsSnapshot {
            per_shard_time_s: vec![],
            epochs: 0,
            epoch_retries: 0,
        };
        assert_eq!(empty.imbalance(), 1.0);
    }

    #[test]
    fn shard_stats_drain_and_accumulate() {
        // pre-partitioned serving path (ShardPlan::build, not
        // ::partition) so this test never ticks the global partition
        // counter other lib tests assert on
        let topo = NumaTopology::modeled(2, 8);
        let pool = Arc::new(WorkerPool::with_topology(2, &topo));
        let b = Backend::sharded(Backend::reference(), 2, topo, pool);
        let w: Vec<f32> = (0..64 * 32).map(|i| (i % 7) as f32 - 3.0).collect();
        let sp = SparseTensor::pack_f32(&w, 64, 32);
        let whole = crate::backend::PackedOperand::Sparse(sp);
        let op = crate::shard::ShardedOperand::from_whole(
            &whole,
            ShardPlan::build(32, 2, &topo),
        );
        let x = vec![1.0f32; 64];
        let mut ctr = EventCounters::default();
        let _ = b.gemm_bf16_sharded(&x, 1, &op, &mut ctr);
        let snap = b.shard_stats().expect("sharded backend reports stats");
        assert_eq!(snap.epochs, 1);
        assert_eq!(snap.per_shard_time_s.len(), 2);
        assert_eq!(snap.epoch_retries, 0);
        // drained: second snapshot starts empty
        let again = b.shard_stats().expect("still Some");
        assert_eq!(again.epochs, 0);
        assert!(again.per_shard_time_s.is_empty());
    }

    #[test]
    fn run_epoch_retries_once_on_worker_panic_and_stays_bit_exact() {
        let topo = NumaTopology::modeled(1, 4);
        let pool = Arc::new(WorkerPool::with_topology(2, &topo));
        let sb = ShardedBackend::new(Backend::reference(), 2, topo, Arc::clone(&pool));
        let plan = ShardPlan::build(32, 2, &topo);
        let shard_cols = 16; // 32 cols / 2 shards
        let fails = AtomicU64::new(0);
        let mut ctr = EventCounters::default();
        let out = sb.run_epoch(&plan, 1, 32, &mut ctr, |s, _c| {
            // shard 1's first invocation dies like a worker fault would
            if s == 1 && fails.fetch_add(1, Ordering::SeqCst) == 0 {
                panic!("injected shard failure");
            }
            vec![(s as f32) + 1.0; shard_cols]
        });
        let mut want = vec![1.0f32; shard_cols];
        want.extend(vec![2.0f32; shard_cols]);
        assert_eq!(out, want, "retry reproduces the fault-free output exactly");
        let snap = sb.shard_stats().expect("stats");
        assert_eq!(snap.epoch_retries, 1);
        assert_eq!(snap.epochs, 1, "only the successful attempt is recorded");
        assert_eq!(pool.respawns(), 1, "the panicked worker was replaced");
    }

    #[test]
    fn run_epoch_falls_back_to_sequential_after_two_failed_attempts() {
        let topo = NumaTopology::modeled(1, 4);
        let pool = Arc::new(WorkerPool::with_topology(2, &topo));
        let sb = ShardedBackend::new(Backend::reference(), 2, topo, Arc::clone(&pool));
        let plan = ShardPlan::build(32, 2, &topo);
        let shard_cols = 16;
        let fails = AtomicU64::new(0);
        let mut ctr = EventCounters::default();
        let out = sb.run_epoch(&plan, 1, 32, &mut ctr, |s, _c| {
            // shard 0 dies on both pool attempts; the sequential inline
            // fallback (third invocation) completes it
            if s == 0 && fails.fetch_add(1, Ordering::SeqCst) < 2 {
                panic!("injected shard failure");
            }
            vec![(s as f32) + 1.0; shard_cols]
        });
        let mut want = vec![1.0f32; shard_cols];
        want.extend(vec![2.0f32; shard_cols]);
        assert_eq!(out, want, "sequential fallback is the bit-exact oracle");
        let snap = sb.shard_stats().expect("stats");
        assert_eq!(snap.epoch_retries, 1);
        assert_eq!(snap.epochs, 1);
    }
}
