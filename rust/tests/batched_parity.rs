//! Batched-vs-looped parity: the tentpole invariant that one
//! `*_batched` call over `batch` gathered rows is **bit-exact** against
//! looping the batch-1 entry point row by row — for every backend
//! (including the sharded wrapper at 1 and 4 shards), both dtypes,
//! dense and sparse packs, across odd and pow-2 batches.
//!
//! The fused call is a pure performance transform (it streams each
//! packed weight block once for the whole batch instead of once per
//! row), so any numeric divergence is a bug, not rounding. A final
//! regression pins the other tentpole invariant: regime selection runs
//! at plan compile, never in the fused token loop.

use sparamx::amx::kernels::DenseWeights;
use sparamx::amx::EventCounters;
use sparamx::backend::{Backend, BackendChoice, BackendRegistry, CpuCaps, PackedOperand};
use sparamx::kvcache::cache::KvCache;
use sparamx::models::plan::{NativeModel, RegimeBatches};
use sparamx::models::tinyforward::{LayerW, TinyModel};
use sparamx::shard::{NumaTopology, WorkerPool};
use sparamx::sparse::format::SparseTensor;
use sparamx::sparse::prune::magnitude_prune;
use sparamx::util::bf16::Bf16;
use sparamx::util::XorShift;
use std::sync::Arc;

const BATCHES: [usize; 5] = [1, 2, 3, 8, 17];

fn sharded_over(inner: Backend, shards: usize) -> Backend {
    let topo = NumaTopology::modeled(2, 8);
    let pool = Arc::new(WorkerPool::with_topology(shards, &topo));
    Backend::sharded(inner, shards, topo, pool)
}

/// Every backend the matrix sweeps: the three plain implementations
/// plus the sharded wrapper at shards {1, 4} over two inner kinds.
fn backends() -> Vec<Backend> {
    vec![
        Backend::amx(),
        Backend::avx(),
        Backend::reference(),
        sharded_over(Backend::reference(), 1),
        sharded_over(Backend::reference(), 4),
        sharded_over(Backend::amx(), 4),
    ]
}

#[test]
fn batched_bf16_is_bit_exact_vs_looped_batch1_for_every_backend() {
    let mut g = XorShift::new(7001);
    let (rows, cols) = (40usize, 72usize);
    let w = magnitude_prune(&g.normal_vec(rows * cols, 1.0), 0.5);
    let sp: SparseTensor<Bf16> = SparseTensor::pack_f32(&w, rows, cols);
    let dw: DenseWeights<Bf16> = DenseWeights::pack_f32(&w, rows, cols);
    for &batch in &BATCHES {
        let x = g.normal_vec(batch * rows, 1.0);
        for b in backends() {
            // looped oracle: the same backend's batch-1 path, row by row
            let mut looped_sparse = Vec::new();
            let mut looped_dense = Vec::new();
            for r in 0..batch {
                let row = &x[r * rows..(r + 1) * rows];
                let mut c = EventCounters::default();
                looped_sparse.extend(b.sparse_gemm_bf16(row, 1, &sp, &mut c));
                let mut c = EventCounters::default();
                looped_dense.extend(b.gemm_bf16(row, 1, &dw, &mut c));
            }
            let mut c1 = EventCounters::default();
            let fused_sparse = b.sparse_gemm_bf16_batched(&x, batch, &sp, &mut c1);
            assert_eq!(
                fused_sparse,
                looped_sparse,
                "{} sparse bf16 batch {batch} not bit-exact",
                b.name()
            );
            let mut c2 = EventCounters::default();
            let fused_dense = b.gemm_bf16_batched(&x, batch, &dw, &mut c2);
            assert_eq!(
                fused_dense,
                looped_dense,
                "{} dense bf16 batch {batch} not bit-exact",
                b.name()
            );
        }
    }
}

#[test]
fn batched_int8_is_bit_exact_vs_looped_batch1_for_every_backend() {
    let mut g = XorShift::new(7002);
    let (rows, cols) = (48usize, 56usize);
    let w: Vec<i8> = (0..rows * cols)
        .map(|_| {
            if g.next_f64() < 0.5 {
                0
            } else {
                (g.below(200) as i32 - 100) as i8
            }
        })
        .collect();
    let sp: SparseTensor<i8> = SparseTensor::pack(&w, rows, cols);
    let dw: DenseWeights<i8> = DenseWeights::pack(&w, rows, cols);
    for &batch in &BATCHES {
        let x: Vec<i8> = (0..batch * rows)
            .map(|_| (g.below(200) as i32 - 100) as i8)
            .collect();
        for b in backends() {
            let mut looped_sparse = Vec::new();
            let mut looped_dense = Vec::new();
            for r in 0..batch {
                let row = &x[r * rows..(r + 1) * rows];
                let mut c = EventCounters::default();
                looped_sparse.extend(b.sparse_gemm_int8(row, 1, &sp, &mut c));
                let mut c = EventCounters::default();
                looped_dense.extend(b.gemm_int8(row, 1, &dw, &mut c));
            }
            let mut c1 = EventCounters::default();
            assert_eq!(
                b.sparse_gemm_int8_batched(&x, batch, &sp, &mut c1),
                looped_sparse,
                "{} sparse int8 batch {batch} not bit-exact",
                b.name()
            );
            let mut c2 = EventCounters::default();
            assert_eq!(
                b.gemm_int8_batched(&x, batch, &dw, &mut c2),
                looped_dense,
                "{} dense int8 batch {batch} not bit-exact",
                b.name()
            );
        }
    }
}

#[test]
fn batched_calls_through_pre_sharded_operands_stay_bit_exact() {
    // the serving path: operands packed once through the sharded
    // backend (pre-partitioned), then dispatched batched — must match
    // both the looped pre-sharded path and the unsharded inner kernel.
    let mut g = XorShift::new(7003);
    let (rows, cols) = (32usize, 96usize);
    let w = magnitude_prune(&g.normal_vec(rows * cols, 1.0), 0.5);
    for shards in [1usize, 4] {
        for inner in [Backend::reference(), Backend::amx()] {
            let sharded = sharded_over(inner.clone(), shards);
            let op = PackedOperand::pack_f32(&sharded, &w, rows, cols, true);
            let whole = PackedOperand::pack_f32(&inner, &w, rows, cols, true);
            for &batch in &BATCHES {
                let x = g.normal_vec(batch * rows, 1.0);
                let mut c = EventCounters::default();
                let fused = op.gemm_bf16_batched(&sharded, &x, batch, &mut c);
                let mut looped = Vec::new();
                for r in 0..batch {
                    let mut cr = EventCounters::default();
                    looped.extend(op.gemm_bf16(
                        &sharded,
                        &x[r * rows..(r + 1) * rows],
                        1,
                        &mut cr,
                    ));
                }
                assert_eq!(
                    fused,
                    looped,
                    "sharded({}x{shards}) batch {batch}: fused vs looped",
                    inner.name()
                );
                let mut cu = EventCounters::default();
                let unsharded = whole.gemm_bf16_batched(&inner, &x, batch, &mut cu);
                assert_eq!(
                    fused,
                    unsharded,
                    "sharded({}x{shards}) batch {batch}: vs unsharded inner",
                    inner.name()
                );
            }
        }
    }
}

fn toy_model(seed: u64) -> TinyModel {
    let mut g = XorShift::new(seed);
    let (h, inter, heads, kvh, hd, vocab) = (16, 24, 4, 2, 4, 256);
    let mut mk = |n: usize| g.normal_vec(n, 0.3);
    TinyModel {
        hidden: h,
        inter,
        heads,
        kv_heads: kvh,
        head_dim: hd,
        vocab,
        emb: mk(vocab * h),
        layers: (0..2)
            .map(|_| LayerW {
                ln1: vec![1.0; h],
                wq: mk(h * heads * hd),
                wk: mk(h * kvh * hd),
                wv: mk(h * kvh * hd),
                wo: mk(heads * hd * h),
                ln2: vec![1.0; h],
                wgate: mk(h * inter),
                wup: mk(h * inter),
                wdown: mk(inter * h),
            })
            .collect(),
        ln_f: vec![1.0; h],
        lm_head: mk(h * vocab),
    }
}

#[test]
fn fused_token_loop_never_reruns_regime_selection() {
    // all three regimes' selections resolve at plan compile; a fused
    // decode loop over multiple slots must not consult the registry
    // again (the per-instance resolution counter would tick and fail).
    let reg = BackendRegistry::with_caps(CpuCaps::all());
    assert_eq!(reg.selections_resolved(), 0);
    let nm = NativeModel::with_regimes(
        &reg,
        BackendChoice::Auto,
        toy_model(7004),
        0.0,
        RegimeBatches {
            decode_fused: 4,
            prefill: 16,
        },
    );
    let at_load = reg.selections_resolved();
    assert!(at_load > 0, "compile must consult the registry");
    let prompts: [&[u8]; 3] = [&[1, 2, 3], &[9, 8], &[5, 5, 5, 5]];
    let mut ctr = EventCounters::default();
    let mut caches: Vec<KvCache> = prompts
        .iter()
        .map(|p| nm.prefill(p, 0.0, 0.0, &mut ctr))
        .collect();
    let mut tokens = [7u8, 11, 13];
    let mut positions: Vec<usize> = prompts.iter().map(|p| p.len()).collect();
    for _step in 0..8 {
        let mut refs: Vec<&mut KvCache> = caches.iter_mut().collect();
        let logits = nm.decode_step_batched(&tokens, &positions, &mut refs, &mut ctr);
        assert_eq!(logits.len(), 3);
        for (b, row) in logits.iter().enumerate() {
            let mut best = 0;
            for (i, &v) in row.iter().enumerate() {
                if v > row[best] {
                    best = i;
                }
            }
            tokens[b] = best as u8;
            positions[b] += 1;
        }
    }
    assert_eq!(
        reg.selections_resolved(),
        at_load,
        "fused token loop re-ran selection"
    );
}
