//! Randomized property tests (proptest-lite: seeded case generation via
//! the crate's own PRNG since proptest is not vendored offline).
//!
//! Invariants covered:
//!  P1  pack → to_dense is the identity for any shape/sparsity
//!  P2  sparse kernel ≡ dense kernel ≡ reference for any random case
//!  P3  ThreadPartition offsets ≡ full scan for any thread count
//!  P4  analytic counters ≡ simulator counters on random shapes
//!  P5  magnitude pruning: exact count, keeps max, subset monotonicity
//!  P6  batcher: FIFO, no loss, no duplication under concurrency
//!  P7  attention: softmax-weighted output stays in the convex hull of V
//!  P8  engine: random admit/cancel/deadline/fault/checkpoint-restore
//!      schedules — every slot answers exactly once and frees its KV

use sparamx::amx::kernels::{DenseWeights, GemmCounters};
use sparamx::backend::{Backend, RefBackend};
use sparamx::coordinator::batcher::AdmissionQueue;
use sparamx::coordinator::request::Request;
use sparamx::perf::analytic;
use sparamx::sparse::format::SparseTensor;
use sparamx::sparse::partition::ThreadPartition;
use sparamx::sparse::prune::magnitude_prune;
use sparamx::util::XorShift;

const CASES: usize = 40;

fn rand_case(g: &mut XorShift) -> (usize, usize, usize, f64) {
    let batch = 1 + g.below(36);
    let rows = 1 + g.below(120);
    let cols = 1 + g.below(100);
    let sparsity = g.next_f64();
    (batch, rows, cols, sparsity)
}

#[test]
fn p1_pack_roundtrip_any_shape() {
    let mut g = XorShift::new(1001);
    for case in 0..CASES {
        let (_, rows, cols, s) = rand_case(&mut g);
        let w = magnitude_prune(&g.normal_vec(rows * cols, 1.0), s);
        let wq: Vec<f32> = w.iter().map(|&x| sparamx::util::bf16::round_f32(x)).collect();
        let sp = SparseTensor::pack_f32(&w, rows, cols);
        assert_eq!(sp.to_dense_f32(), wq, "case {case}: ({rows},{cols},{s})");
        // nnz consistency with the bitmap
        let pop: u32 = sp.metadata.iter().map(|m| m.count_ones()).sum();
        assert_eq!(pop as usize, sp.nnz());
    }
}

#[test]
fn p2_kernels_agree_with_reference() {
    let mut g = XorShift::new(1002);
    let amx = Backend::amx();
    for case in 0..12 {
        let (batch, rows, cols, s) = rand_case(&mut g);
        let batch = batch.min(8);
        let w = magnitude_prune(&g.normal_vec(rows * cols, 1.0), s);
        let x = g.normal_vec(batch * rows, 1.0);
        let want = RefBackend::matmul_f32(&x, batch, &w, rows, cols);
        let tol = 0.03 * (rows as f32).sqrt().max(1.0);

        let sp = SparseTensor::pack_f32(&w, rows, cols);
        let mut c1 = GemmCounters::default();
        let got_s = amx.sparse_gemm_bf16(&x, batch, &sp, &mut c1);
        let dw = DenseWeights::pack_f32(&w, rows, cols);
        let mut c2 = GemmCounters::default();
        let got_d = amx.gemm_bf16(&x, batch, &dw, &mut c2);
        let avx = Backend::avx_with_groups(1 + g.below(8));
        let mut c3 = GemmCounters::default();
        let got_a = avx.sparse_gemm_bf16(&x, batch, &sp, &mut c3);
        for i in 0..want.len() {
            for (name, got) in [("sparse", &got_s), ("dense", &got_d), ("avx", &got_a)] {
                assert!(
                    (got[i] - want[i]).abs() <= tol + want[i].abs() * 0.03,
                    "case {case} {name} idx {i}: {} vs {}",
                    got[i],
                    want[i]
                );
            }
        }
    }
}

#[test]
fn p3_partition_offsets_match_scan() {
    let mut g = XorShift::new(1003);
    for _ in 0..CASES {
        let (_, rows, cols, s) = rand_case(&mut g);
        let w = magnitude_prune(&g.normal_vec(rows * cols, 1.0), s);
        let sp = SparseTensor::pack_f32(&w, rows, cols);
        let threads = 1 + g.below(40);
        let part = ThreadPartition::build(&sp, threads);
        part.validate(&sp).expect("partition invariant");
    }
}

#[test]
fn p4_analytic_equals_simulator_on_random_shapes() {
    let mut g = XorShift::new(1004);
    let amx = Backend::amx();
    for case in 0..10 {
        let (batch, rows, cols, s) = rand_case(&mut g);
        let batch = batch.min(40);
        let w = magnitude_prune(&g.normal_vec(rows * cols, 1.0), s);
        let x = g.normal_vec(batch * rows, 1.0);
        let sp = SparseTensor::pack_f32(&w, rows, cols);
        let mut sim = GemmCounters::default();
        amx.sparse_gemm_bf16(&x, batch, &sp, &mut sim);
        assert_eq!(
            analytic::sparse_bf16(batch, rows, cols, sp.nnz()),
            sim,
            "case {case}: ({batch},{rows},{cols})"
        );
        let dw = DenseWeights::pack_f32(&w, rows, cols);
        let mut simd = GemmCounters::default();
        amx.gemm_bf16(&x, batch, &dw, &mut simd);
        assert_eq!(analytic::dense_bf16(batch, rows, cols), simd);
    }
}

#[test]
fn p5_pruning_properties() {
    let mut g = XorShift::new(1005);
    for _ in 0..CASES {
        let n = 1 + g.below(4000);
        let w = g.normal_vec(n, 1.0);
        let s = g.next_f64();
        let p = magnitude_prune(&w, s);
        // exact count
        let zeros = p.iter().filter(|&&x| x == 0.0).count();
        assert_eq!(zeros, (n as f64 * s).round() as usize);
        // survivors keep their values, and every survivor's magnitude ≥
        // every pruned element's magnitude
        let min_kept = p
            .iter()
            .filter(|&&x| x != 0.0)
            .map(|x| x.abs())
            .fold(f32::INFINITY, f32::min);
        for (orig, pruned) in w.iter().zip(p.iter()) {
            if *pruned != 0.0 {
                assert_eq!(orig, pruned);
            } else if min_kept.is_finite() {
                assert!(orig.abs() <= min_kept + 1e-6);
            }
        }
    }
}

#[test]
fn p6_batcher_no_loss_no_dup_under_concurrency() {
    let queue = std::sync::Arc::new(AdmissionQueue::new(10_000));
    let producers = 4;
    let per = 200u64;
    std::thread::scope(|s| {
        for t in 0..producers {
            let q = std::sync::Arc::clone(&queue);
            s.spawn(move || {
                for i in 0..per {
                    let (tx, rx) = std::sync::mpsc::channel();
                    std::mem::forget(rx);
                    q.admit(Request {
                        id: t * 1000 + i,
                        prompt: vec![],
                        max_new_tokens: 1,
                        arrived: std::time::Instant::now(),
                        respond: tx,
                        deadline_ms: None,
                        cancel: std::sync::Arc::new(
                            std::sync::atomic::AtomicBool::new(false),
                        ),
                    })
                    .expect("capacity is ample");
                }
            });
        }
    });
    queue.close();
    let mut seen = std::collections::HashSet::new();
    while let Some(batch) = queue.take_batch(7, std::time::Duration::from_millis(1)) {
        for r in batch {
            assert!(seen.insert(r.id), "duplicate id {}", r.id);
        }
    }
    assert_eq!(seen.len() as u64, producers * per, "requests lost");
}

#[test]
fn p8_random_schedules_answer_every_slot_exactly_once() {
    use sparamx::cfg::{EngineChoice, RuntimeConfig};
    use sparamx::coordinator::engine::Engine;
    use sparamx::models::tinyforward::{LayerW, TinyModel};
    use std::sync::atomic::AtomicBool;
    use std::sync::{mpsc, Arc};
    use std::time::Instant;

    fn toy(seed: u64) -> TinyModel {
        let mut g = XorShift::new(seed);
        let (h, inter, heads, kvh, hd, vocab) = (16, 24, 4, 2, 4, 256);
        let mut mk = |n: usize| g.normal_vec(n, 0.3);
        TinyModel {
            hidden: h,
            inter,
            heads,
            kv_heads: kvh,
            head_dim: hd,
            vocab,
            emb: mk(vocab * h),
            layers: (0..2)
                .map(|_| LayerW {
                    ln1: vec![1.0; h],
                    wq: mk(h * heads * hd),
                    wk: mk(h * kvh * hd),
                    wv: mk(h * kvh * hd),
                    wo: mk(heads * hd * h),
                    ln2: vec![1.0; h],
                    wgate: mk(h * inter),
                    wup: mk(h * inter),
                    wdown: mk(inter * h),
                })
                .collect(),
            ln_f: vec![1.0; h],
            lm_head: mk(h * vocab),
        }
    }

    let mut g = XorShift::new(1008);
    for case in 0..6u64 {
        sparamx::fault::clear();
        // only the admission seam: kernel faults are process-global and
        // would perturb the kernel property tests running concurrently
        if g.below(2) == 0 {
            let req = 1 + g.below(4);
            sparamx::fault::install(
                format!("admit_stall@request={req},delay_us=500").parse().unwrap(),
            );
        }
        let path = std::env::temp_dir()
            .join(format!("sparamx_p8_{}_{case}.spxc", std::process::id()));
        let cfg = RuntimeConfig {
            weight_sparsity: 0.0,
            k_sparsity: 0.0,
            v_sparsity: 0.0,
            max_batch: 2 + g.below(3),
            max_new_tokens: 4,
            max_ctx: 48,
            engine: EngineChoice::Auto,
            checkpoint: path.to_string_lossy().into_owned(),
            checkpoint_every_steps: 1 + g.below(4) as u64,
            ..Default::default()
        };
        let mut engine = Engine::from_tiny_model(toy(1100 + case), cfg.clone()).expect("engine");
        let queue = Arc::new(AdmissionQueue::new(64));
        let n = 3 + g.below(5);
        let mut rxs = Vec::new();
        for i in 0..n {
            let (tx, rx) = mpsc::channel();
            let deadline_ms = match g.below(4) {
                0 => Some(0),
                1 => Some(60_000),
                _ => None,
            };
            // non-empty: the native prefill needs at least one byte
            let len = 1 + g.below(12);
            let prompt: Vec<u8> = (0..len).map(|_| b'a' + g.below(26) as u8).collect();
            queue
                .admit(Request {
                    id: i as u64,
                    prompt,
                    max_new_tokens: 4,
                    arrived: Instant::now(),
                    respond: tx,
                    deadline_ms,
                    cancel: Arc::new(AtomicBool::new(g.below(4) == 0)),
                })
                .expect("capacity is ample");
            rxs.push(rx);
        }
        queue.close();
        engine.run(&queue).expect("engine drains");
        for (i, rx) in rxs.into_iter().enumerate() {
            let r = rx
                .recv()
                .unwrap_or_else(|_| panic!("case {case}: slot {i} never answered"));
            assert_eq!(r.id, i as u64, "case {case}");
            assert!(rx.try_recv().is_err(), "case {case}: slot {i} answered twice");
        }
        assert_eq!(engine.active_slots(), 0, "case {case}");
        assert_eq!(engine.kv_resident_bytes(), 0, "case {case}: KV leak");

        // restore leg: whatever the last checkpoint froze mid-flight
        // must drain on a fresh engine, again answering exactly once
        let mut fresh = Engine::from_tiny_model(toy(1100 + case), cfg.clone()).expect("engine");
        let receivers = fresh.restore_from_file(&cfg.checkpoint);
        let empty = Arc::new(AdmissionQueue::new(1));
        empty.close();
        fresh.run(&empty).expect("restored engine drains");
        for (id, rx) in receivers {
            let r = rx
                .recv()
                .unwrap_or_else(|_| panic!("case {case}: restored {id} unanswered"));
            assert_eq!(r.id, id, "case {case}");
            assert!(rx.try_recv().is_err(), "case {case}: restored {id} answered twice");
        }
        assert_eq!(fresh.kv_resident_bytes(), 0, "case {case}: restored KV leak");
        sparamx::fault::clear();
        let _ = std::fs::remove_file(&cfg.checkpoint);
    }
}

#[test]
fn p7_attention_output_in_value_hull() {
    let mut g = XorShift::new(1007);
    for _ in 0..10 {
        let ctx = 8 + g.below(56);
        let hd = 8 + 8 * g.below(5);
        let k = g.normal_vec(ctx * hd, 1.0);
        let v = g.normal_vec(ctx * hd, 1.0);
        let q = g.normal_vec(hd, 1.0);
        let hc = sparamx::kvcache::cache::HeadCache::from_prefill(
            &k, &v, ctx, hd, g.next_f64() * 0.5, g.next_f64() * 0.5,
        );
        let mut ctr = sparamx::amx::EventCounters::default();
        let out =
            sparamx::kvcache::attention::attend_sparse(&hc, &q, &Backend::amx(), &mut ctr);
        // softmax-weighted mix of (pruned) V rows stays within min/max
        // of each coordinate of the pruned V, with bf16 slack
        let vp = hc.v_static.to_dense_f32();
        for d in 0..hd {
            let (mut lo, mut hi) = (f32::INFINITY, f32::NEG_INFINITY);
            for t in 0..ctx {
                lo = lo.min(vp[t * hd + d]);
                hi = hi.max(vp[t * hd + d]);
            }
            assert!(
                out[d] >= lo - 0.05 && out[d] <= hi + 0.05,
                "coord {d}: {} outside [{lo}, {hi}]",
                out[d]
            );
        }
    }
}
