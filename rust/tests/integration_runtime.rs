//! Integration tests over the PJRT runtime + AOT artifacts.
//!
//! These run only when `artifacts/` exists (built by `make artifacts`);
//! otherwise they skip so `cargo test` works on a fresh checkout.

use sparamx::cfg::{EngineChoice, RuntimeConfig};
use sparamx::coordinator::batcher::AdmissionQueue;
use sparamx::coordinator::engine::Engine;
use sparamx::coordinator::request::Request;
use sparamx::models::tinyforward::{KvTreatment, TinyModel};
use sparamx::runtime::artifact::Bundle;
use sparamx::runtime::executor::{lit_f32, lit_i32, lit_u32, to_f32, to_i32, Runtime};
use std::sync::{mpsc, Arc};
use std::time::Instant;

fn artifacts_dir() -> Option<String> {
    if cfg!(not(feature = "pjrt")) {
        eprintln!("skipping: built without the pjrt feature (stub executor)");
        return None;
    }
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    dir.join("manifest.json").exists().then(|| dir.to_string_lossy().into_owned())
}

macro_rules! require_artifacts {
    () => {
        match artifacts_dir() {
            Some(d) => d,
            None => {
                eprintln!("skipping: artifacts/ not built");
                return;
            }
        }
    };
}

/// Pack a dense K×N f32 matrix into the Python kernels' (mask, vals)
/// layout (see python/compile/kernels/packing.py) padded to `vmax`.
fn pack_mask_vals(w: &[f32], k: usize, n: usize, vmax: usize) -> (Vec<u32>, Vec<f32>, usize) {
    let cb = n.div_ceil(16);
    let mut mask = vec![0u32; cb * k];
    let mut vals = vec![0f32; cb * vmax];
    for b in 0..cb {
        let mut vi = 0;
        for kk in 0..k {
            let mut word = 0u32;
            for c in 0..16 {
                let col = b * 16 + c;
                if col < n && w[kk * n + col] != 0.0 {
                    word |= 1 << c;
                    vals[b * vmax + vi] = w[kk * n + col];
                    vi += 1;
                }
            }
            mask[b * k + kk] = word;
        }
        assert!(vi <= vmax, "vmax too small");
    }
    (mask, vals, cb)
}

#[test]
fn sparse_gemm_artifact_matches_rust_reference() {
    let dir = require_artifacts!();
    let bundle = Bundle::load(&dir).expect("bundle");
    let g = bundle.manifest.req("gemm_shape").unwrap();
    let (batch, k, n, vmax) = (
        g.req("batch").unwrap().as_usize().unwrap(),
        g.req("k").unwrap().as_usize().unwrap(),
        g.req("n").unwrap().as_usize().unwrap(),
        g.req("vmax").unwrap().as_usize().unwrap(),
    );
    let rt = Runtime::cpu().expect("client");
    let exe = rt.load_hlo(&bundle.hlo_path("sparse_gemm")).expect("compile");

    let mut prng = sparamx::util::XorShift::new(99);
    let w = sparamx::sparse::prune::magnitude_prune(&prng.normal_vec(k * n, 1.0), 0.5);
    let x = prng.normal_vec(batch * k, 1.0);
    let (mask, vals, cb) = pack_mask_vals(&w, k, n, vmax);

    let outs = exe
        .run(&[
            lit_f32(&x, &[batch as i64, k as i64]).unwrap(),
            lit_u32(&mask, &[cb as i64, k as i64]).unwrap(),
            lit_f32(&vals, &[cb as i64, vmax as i64]).unwrap(),
        ])
        .expect("run");
    let got = to_f32(&outs[0]).unwrap();
    assert_eq!(got.len(), batch * n);

    // rust-side reference (plain f32 GEMM — the artifact computes in f32)
    for b in 0..batch {
        for j in 0..n {
            let mut want = 0f32;
            for kk in 0..k {
                want += x[b * k + kk] * w[kk * n + j];
            }
            let gotv = got[b * n + j];
            assert!(
                (gotv - want).abs() < 1e-3 + want.abs() * 1e-3,
                "({b},{j}): {gotv} vs {want}"
            );
        }
    }
}

#[test]
fn int8_gemm_artifact_exact() {
    let dir = require_artifacts!();
    let bundle = Bundle::load(&dir).expect("bundle");
    let g = bundle.manifest.req("gemm_shape").unwrap();
    let (batch, k, n, vmax) = (
        g.req("batch").unwrap().as_usize().unwrap(),
        g.req("k").unwrap().as_usize().unwrap(),
        g.req("n").unwrap().as_usize().unwrap(),
        g.req("vmax").unwrap().as_usize().unwrap(),
    );
    let rt = Runtime::cpu().expect("client");
    let exe = rt.load_hlo(&bundle.hlo_path("int8_gemm")).expect("compile");
    let mut prng = sparamx::util::XorShift::new(7);
    let wi: Vec<i8> = (0..k * n)
        .map(|_| {
            if prng.next_f64() < 0.5 {
                0
            } else {
                (prng.below(200) as i32 - 100) as i8
            }
        })
        .collect();
    let wf: Vec<f32> = wi.iter().map(|&v| v as f32).collect();
    let (mask, valsf, cb) = pack_mask_vals(&wf, k, n, vmax);
    let vals: Vec<i8> = valsf.iter().map(|&v| v as i8).collect();
    let x: Vec<i8> = (0..batch * k).map(|_| (prng.below(200) as i32 - 100) as i8).collect();
    let outs = exe
        .run(&[
            sparamx::runtime::executor::lit_i8(&x, &[batch as i64, k as i64]).unwrap(),
            lit_u32(&mask, &[cb as i64, k as i64]).unwrap(),
            sparamx::runtime::executor::lit_i8(&vals, &[cb as i64, vmax as i64]).unwrap(),
        ])
        .expect("run");
    let got = to_i32(&outs[0]).unwrap();
    for b in 0..batch {
        for j in 0..n {
            let mut want = 0i32;
            for kk in 0..k {
                want += x[b * k + kk] as i32 * wi[kk * n + j] as i32;
            }
            assert_eq!(got[b * n + j], want, "({b},{j})");
        }
    }
}

#[test]
fn eval_logits_artifact_agrees_with_rust_forward() {
    let dir = require_artifacts!();
    let bundle = Bundle::load(&dir).expect("bundle");
    let rt = Runtime::cpu().expect("client");
    let exe = rt.load_hlo(&bundle.hlo_path("eval_logits")).expect("compile");
    let eval_len = bundle
        .manifest
        .req("eval_len")
        .unwrap()
        .as_usize()
        .unwrap();
    let tokens: Vec<i32> = bundle.eval_tokens[..eval_len].iter().map(|&b| b as i32).collect();

    let mut inputs: Vec<sparamx::runtime::executor::Literal> = bundle
        .params
        .iter()
        .map(|t| {
            let dims: Vec<i64> = t.shape.iter().map(|&d| d as i64).collect();
            lit_f32(&t.data, &dims).unwrap()
        })
        .collect();
    inputs.push(lit_i32(&tokens, &[1, eval_len as i64]).unwrap());
    let outs = exe.run(&inputs).expect("run");
    let pjrt_logits = to_f32(&outs[0]).unwrap();

    let model = TinyModel::from_bundle(&bundle).expect("model");
    let rust_logits = model.forward(&bundle.eval_tokens[..eval_len], KvTreatment::default());
    assert_eq!(pjrt_logits.len(), rust_logits.len());
    let mut max_err = 0f32;
    for (a, b) in pjrt_logits.iter().zip(rust_logits.iter()) {
        max_err = max_err.max((a - b).abs());
    }
    assert!(
        max_err < 5e-2,
        "rust forward diverges from PJRT artifact: max err {max_err}"
    );
}

#[test]
fn engine_serves_batch_of_requests() {
    let dir = require_artifacts!();
    let bundle = Bundle::load(&dir).expect("bundle");
    let rt = Runtime::cpu().expect("client");
    let cfg = RuntimeConfig {
        artifacts_dir: dir,
        weight_sparsity: 0.0,
        max_new_tokens: 8,
        engine: EngineChoice::Pjrt, // this test covers the AOT path
        ..Default::default()
    };
    let mut engine = Engine::load(&rt, &bundle, cfg).expect("engine");
    let queue = Arc::new(AdmissionQueue::new(16));
    let mut rxs = Vec::new();
    for (i, prompt) in ["the cat ", "a dog ", "the queen ", "my robot ", "one bird "]
        .iter()
        .enumerate()
    {
        let (tx, rx) = mpsc::channel();
        queue
            .admit(Request {
                id: i as u64,
                prompt: prompt.as_bytes().to_vec(),
                max_new_tokens: 8,
                arrived: Instant::now(),
                respond: tx,
                deadline_ms: None,
                cancel: Arc::new(std::sync::atomic::AtomicBool::new(false)),
            })
            .unwrap();
        rxs.push(rx);
    }
    queue.close();
    engine.run(&queue).expect("engine drains");
    for rx in rxs {
        let resp = rx.recv().expect("every request answered");
        assert_eq!(resp.tokens.len(), 8);
        assert!(resp.total_latency_s > 0.0);
    }
    assert_eq!(
        engine
            .metrics
            .requests_completed
            .load(std::sync::atomic::Ordering::Relaxed),
        5
    );
}

#[test]
fn engine_weight_pruning_changes_output_not_stability() {
    let dir = require_artifacts!();
    let bundle = Bundle::load(&dir).expect("bundle");
    let rt = Runtime::cpu().expect("client");
    let run_one = |sparsity: f64| {
        let cfg = RuntimeConfig {
            artifacts_dir: artifacts_dir().unwrap(),
            weight_sparsity: sparsity,
            max_new_tokens: 6,
            engine: EngineChoice::Pjrt, // this test covers the AOT path
            ..Default::default()
        };
        let mut engine = Engine::load(&rt, &bundle, cfg).expect("engine");
        let queue = Arc::new(AdmissionQueue::new(4));
        let (tx, rx) = mpsc::channel();
        queue
            .admit(Request {
                id: 1,
                prompt: b"the cat sees ".to_vec(),
                max_new_tokens: 6,
                arrived: Instant::now(),
                respond: tx,
                deadline_ms: None,
                cancel: Arc::new(std::sync::atomic::AtomicBool::new(false)),
            })
            .unwrap();
        queue.close();
        engine.run(&queue).unwrap();
        rx.recv().unwrap().tokens
    };
    let dense = run_one(0.0);
    let sparse = run_one(0.5);
    assert_eq!(dense.len(), 6);
    assert_eq!(sparse.len(), 6);
    // 50% pruning of a tiny model may or may not change 6 greedy tokens,
    // but both paths must produce valid output without panicking.
}
