//! Concurrency semantics of the [`AdmissionQueue`]: backpressure under
//! a full queue from multiple producer threads, `close()` waking
//! blocked consumers, and no request loss or duplication across
//! admit/refill races.

use sparamx::coordinator::batcher::{AdmissionQueue, AdmitError};
use sparamx::coordinator::request::Request;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{mpsc, Arc};
use std::time::{Duration, Instant};

fn req(id: u64) -> Request {
    let (tx, rx) = mpsc::channel();
    std::mem::forget(rx); // tests only inspect queue behaviour
    Request {
        id,
        prompt: vec![],
        max_new_tokens: 1,
        arrived: Instant::now(),
        respond: tx,
        deadline_ms: None,
        cancel: Arc::new(std::sync::atomic::AtomicBool::new(false)),
    }
}

#[test]
fn backpressure_holds_under_concurrent_producers() {
    // 8 producers hammer a capacity-16 queue with no consumer: exactly
    // 16 admissions succeed, every other attempt is rejected with
    // `Full`, and the queue never exceeds capacity.
    const CAP: usize = 16;
    const PRODUCERS: usize = 8;
    const PER_PRODUCER: usize = 50;
    let q = Arc::new(AdmissionQueue::new(CAP));
    let admitted = Arc::new(AtomicUsize::new(0));
    let rejected = Arc::new(AtomicUsize::new(0));
    std::thread::scope(|s| {
        for t in 0..PRODUCERS {
            let q = Arc::clone(&q);
            let admitted = Arc::clone(&admitted);
            let rejected = Arc::clone(&rejected);
            s.spawn(move || {
                for i in 0..PER_PRODUCER {
                    match q.admit(req((t * PER_PRODUCER + i) as u64)) {
                        Ok(()) => {
                            admitted.fetch_add(1, Ordering::Relaxed);
                        }
                        Err(AdmitError::Full) => {
                            rejected.fetch_add(1, Ordering::Relaxed);
                        }
                        Err(AdmitError::Closed) => panic!("queue was never closed"),
                    }
                    assert!(q.depth() <= CAP, "queue overflowed capacity");
                }
            });
        }
    });
    assert_eq!(admitted.load(Ordering::Relaxed), CAP);
    assert_eq!(
        admitted.load(Ordering::Relaxed) + rejected.load(Ordering::Relaxed),
        PRODUCERS * PER_PRODUCER
    );
    assert_eq!(q.depth(), CAP);
}

#[test]
fn close_wakes_a_blocked_consumer() {
    // A consumer blocked in `take_batch` with a long window must return
    // promptly (None) when another thread closes the empty queue — not
    // after the full timeout.
    let q = Arc::new(AdmissionQueue::new(4));
    let q2 = Arc::clone(&q);
    let consumer = std::thread::spawn(move || {
        let t0 = Instant::now();
        // tolerate spurious condvar wakeups: keep waiting until the
        // queue reports closed (None) or the guard budget trips
        loop {
            match q2.take_batch(4, Duration::from_secs(30)) {
                None => return (true, t0.elapsed()),
                Some(b) => {
                    assert!(b.is_empty(), "nothing was ever admitted");
                    if t0.elapsed() > Duration::from_secs(10) {
                        return (false, t0.elapsed());
                    }
                }
            }
        }
    });
    // give the consumer time to block, then close
    std::thread::sleep(Duration::from_millis(50));
    q.close();
    let (saw_close, waited) = consumer.join().expect("consumer thread");
    assert!(saw_close, "closed empty queue reports None");
    assert!(
        waited < Duration::from_secs(5),
        "close() must wake the blocked consumer, waited {waited:?}"
    );
}

#[test]
fn close_lets_pending_requests_drain_before_reporting_closed() {
    let q = AdmissionQueue::new(8);
    for i in 0..3 {
        q.admit(req(i)).unwrap();
    }
    q.close();
    assert_eq!(q.admit(req(99)), Err(AdmitError::Closed));
    let batch = q.take_batch(8, Duration::from_millis(1)).expect("drains");
    assert_eq!(batch.len(), 3);
    assert!(q.take_batch(8, Duration::from_millis(1)).is_none());
}

#[test]
fn no_request_loss_across_admit_refill_races() {
    // Producers retry on backpressure while a consumer drains in small
    // batches (the engine's refill pattern): every admitted id must be
    // consumed exactly once.
    const PRODUCERS: u64 = 4;
    const PER_PRODUCER: u64 = 200;
    let q = Arc::new(AdmissionQueue::new(8));
    let consumed: Arc<std::sync::Mutex<Vec<u64>>> = Arc::new(std::sync::Mutex::new(Vec::new()));
    std::thread::scope(|s| {
        for t in 0..PRODUCERS {
            let q = Arc::clone(&q);
            s.spawn(move || {
                for i in 0..PER_PRODUCER {
                    let id = t * PER_PRODUCER + i;
                    loop {
                        match q.admit(req(id)) {
                            Ok(()) => break,
                            Err(AdmitError::Full) => std::thread::yield_now(),
                            Err(AdmitError::Closed) => panic!("closed mid-production"),
                        }
                    }
                }
            });
        }
        // single consumer (the engine is the serial resource)
        let q_c = Arc::clone(&q);
        let consumed_c = Arc::clone(&consumed);
        s.spawn(move || {
            let total = (PRODUCERS * PER_PRODUCER) as usize;
            let mut seen = 0usize;
            while seen < total {
                if let Some(batch) = q_c.take_batch(3, Duration::from_millis(5)) {
                    seen += batch.len();
                    consumed_c
                        .lock()
                        .unwrap()
                        .extend(batch.iter().map(|r| r.id));
                }
            }
        });
    });
    let mut ids = consumed.lock().unwrap().clone();
    ids.sort_unstable();
    let expect: Vec<u64> = (0..PRODUCERS * PER_PRODUCER).collect();
    assert_eq!(ids, expect, "every request consumed exactly once");
    assert_eq!(q.depth(), 0);
}
