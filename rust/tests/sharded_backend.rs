//! Sharded execution acceptance tests: the pool-parallel
//! [`ShardedBackend`] must be **bit-exact** against its inner backend
//! run unsharded (dense + sparse, BF16 + INT8, divisible and
//! non-divisible shard counts), its capability gating must follow the
//! inner backend, and registry auto-selection must pick sharding
//! exactly where the cost model says it wins (the Fig 11 crossover).
//!
//! The partition-counter (compile-time-only) invariants live in
//! `shard_plan_compile.rs` — a separate binary, because these parity
//! tests tick the global partition counter freely.

use sparamx::amx::kernels::DenseWeights;
use sparamx::amx::EventCounters;
use sparamx::backend::{
    Backend, BackendKind, BackendRegistry, CpuCaps, Dtype, GemmShape, PackedOperand,
};
use sparamx::perf::cost::{sharded_sparse_gemm_cost, sparse_gemm_cost};
use sparamx::shard::{NumaTopology, ShardPlan, ShardedOperand, WorkerPool};
use sparamx::sparse::format::SparseTensor;
use sparamx::sparse::prune::magnitude_prune;
use sparamx::util::XorShift;
use std::sync::Arc;

/// 48×112 = 7 packed column blocks: 4-way sharding splits non-divisibly
/// (2+2+2+1 blocks) and 7-way gives one block per shard.
const ROWS: usize = 48;
const COLS: usize = 112;
const BATCH: usize = 3;
const SHARD_COUNTS: [usize; 4] = [1, 2, 4, 7];

fn inners() -> Vec<Backend> {
    vec![Backend::amx(), Backend::avx(), Backend::reference()]
}

fn sharded_over(inner: Backend, shards: usize) -> Backend {
    let topo = NumaTopology::modeled(2, 8);
    let pool = Arc::new(WorkerPool::with_topology(shards, &topo));
    Backend::sharded(inner, shards, topo, pool)
}

#[test]
fn sharded_is_bit_exact_vs_unsharded_bf16() {
    let mut g = XorShift::new(61);
    let w = magnitude_prune(&g.normal_vec(ROWS * COLS, 1.0), 0.6);
    let x = g.normal_vec(BATCH * ROWS, 1.0);
    let sp = SparseTensor::pack_f32(&w, ROWS, COLS);
    let dw = DenseWeights::pack_f32(&w, ROWS, COLS);
    for inner in inners() {
        let mut c = EventCounters::default();
        let want_sparse = inner.sparse_gemm_bf16(&x, BATCH, &sp, &mut c);
        let want_dense = inner.gemm_bf16(&x, BATCH, &dw, &mut c);
        for shards in SHARD_COUNTS {
            let b = sharded_over(inner.clone(), shards);
            let mut cs = EventCounters::default();
            assert_eq!(
                b.sparse_gemm_bf16(&x, BATCH, &sp, &mut cs),
                want_sparse,
                "{} sparse, {shards} shards: not bit-exact",
                b.name()
            );
            let mut cd = EventCounters::default();
            assert_eq!(
                b.gemm_bf16(&x, BATCH, &dw, &mut cd),
                want_dense,
                "{} dense, {shards} shards: not bit-exact",
                b.name()
            );
            if inner.kind() != BackendKind::Reference {
                assert!(cs.instructions() > 0, "sharded kernels tick merged events");
            }
        }
    }
}

#[test]
fn sharded_is_bit_exact_vs_unsharded_int8() {
    let mut g = XorShift::new(62);
    let w: Vec<i8> = (0..ROWS * COLS)
        .map(|_| {
            if g.next_f64() < 0.5 {
                0
            } else {
                (g.below(200) as i32 - 100) as i8
            }
        })
        .collect();
    let x: Vec<i8> = (0..BATCH * ROWS).map(|_| (g.below(200) as i32 - 100) as i8).collect();
    let sp: SparseTensor<i8> = SparseTensor::pack(&w, ROWS, COLS);
    let dw: DenseWeights<i8> = DenseWeights::pack(&w, ROWS, COLS);
    for inner in inners() {
        let mut c = EventCounters::default();
        let want_sparse = inner.sparse_gemm_int8(&x, BATCH, &sp, &mut c);
        let want_dense = inner.gemm_int8(&x, BATCH, &dw, &mut c);
        for shards in SHARD_COUNTS {
            let b = sharded_over(inner.clone(), shards);
            let mut cs = EventCounters::default();
            assert_eq!(
                b.sparse_gemm_int8(&x, BATCH, &sp, &mut cs),
                want_sparse,
                "{} sparse int8, {shards} shards",
                b.name()
            );
            let mut cd = EventCounters::default();
            assert_eq!(
                b.gemm_int8(&x, BATCH, &dw, &mut cd),
                want_dense,
                "{} dense int8, {shards} shards",
                b.name()
            );
        }
    }
}

#[test]
fn pool_parallel_path_matches_sequential_trait_oracle() {
    // The trait-default gemm_bf16_sharded runs shards sequentially — any
    // backend is a bit-exact oracle for the pool-parallel override.
    let mut g = XorShift::new(63);
    let w = magnitude_prune(&g.normal_vec(ROWS * COLS, 1.0), 0.5);
    let x = g.normal_vec(BATCH * ROWS, 1.0);
    let topo = NumaTopology::modeled(2, 8);
    let whole = PackedOperand::Sparse(SparseTensor::pack_f32(&w, ROWS, COLS));
    for shards in SHARD_COUNTS {
        let op = ShardedOperand::from_whole(&whole, ShardPlan::partition(COLS, shards, &topo));
        for inner in inners() {
            let mut c1 = EventCounters::default();
            let want = inner.gemm_bf16_sharded(&x, BATCH, &op, &mut c1);
            let b = sharded_over(inner.clone(), shards);
            let mut c2 = EventCounters::default();
            let got = b.gemm_bf16_sharded(&x, BATCH, &op, &mut c2);
            assert_eq!(got, want, "{} {shards} shards: pool != sequential oracle", b.name());
        }
    }
}

#[test]
fn sharded_capability_gating_follows_inner() {
    let amx_only = CpuCaps::from_list("amx");
    let avx_only = CpuCaps::from_list("avx512");
    let none = CpuCaps::none();
    let s_amx = sharded_over(Backend::amx(), 2);
    let s_avx = sharded_over(Backend::avx(), 2);
    assert!(s_amx.supported(&amx_only));
    assert!(!s_amx.supported(&avx_only));
    assert!(!s_amx.supported(&none));
    assert!(s_avx.supported(&avx_only));
    assert!(!s_avx.supported(&amx_only));
    assert!(!s_avx.supported(&none));
}

#[test]
fn registry_selects_sharding_exactly_at_the_cost_model_crossover() {
    // Dual-socket machine, two shards (one per NUMA node): the big
    // memory-bound decode linear goes sharded because both sockets'
    // controllers stream at once; a tiny batch-1 layer stays unsharded
    // because the per-shard stream ramp + barrier swamp it.
    let topo = NumaTopology::modeled(2, 32);
    let reg = BackendRegistry::with_caps(CpuCaps::all()).with_shards(2, topo);
    let m = reg.machine();

    let big = reg.select(GemmShape::new(1, 4096, 14336), 0.5, Dtype::Bf16);
    assert_eq!(big.backend.kind(), BackendKind::Sharded, "{}", big.describe());
    assert_eq!(big.backend.name(), "sharded-amx");
    assert!(big.use_sparse, "sharding wraps the sparse kernel at batch 1");
    // registry selection and the cost model agree on the winning number
    let expect = sharded_sparse_gemm_cost(1, 4096, 14336, 0.5, 2, m);
    assert!((big.predicted_s - expect).abs() < 1e-12);
    assert!(
        expect < sparse_gemm_cost(1, 4096, 14336, 0.5, m).time,
        "crossover premise: sharding must beat the single-socket stream"
    );

    let small = reg.select(GemmShape::new(1, 128, 128), 0.0, Dtype::Bf16);
    assert_ne!(
        small.backend.kind(),
        BackendKind::Sharded,
        "tiny batch-1 layer must stay unsharded: {}",
        small.describe()
    );
}

#[test]
fn with_shards_one_is_a_no_op_and_preserves_the_no_isa_invariant() {
    let reg = BackendRegistry::with_caps(CpuCaps::all()).with_shards(1, NumaTopology::single(8));
    assert!(
        reg.backends().iter().all(|b| b.kind() != BackendKind::Sharded),
        "shards=1 must not register sharded backends"
    );
    // no-ISA host still has exactly the reference oracle available
    let none = BackendRegistry::with_caps(CpuCaps::none()).with_shards(2, NumaTopology::single(8));
    assert_eq!(none.available().len(), 1);
    assert_eq!(none.available()[0].kind(), BackendKind::Reference);
}

#[test]
fn model_plan_shards_the_wide_layers_on_a_dual_socket_host() {
    use sparamx::backend::BackendChoice;
    use sparamx::models::plan::plan_model;
    use sparamx::models::ModelConfig;
    // Shape-level planning only (no weights, no packing): Llama 3 8B at
    // batch 1 / 50% sparsity on a dual-socket registry must shard its
    // widest linears while the model's selections stay cost-ranked.
    let topo = NumaTopology::modeled(2, 32);
    let reg = BackendRegistry::with_caps(CpuCaps::all()).with_shards(2, topo);
    let mc = ModelConfig::llama3_8b();
    let plan = plan_model(&reg, BackendChoice::Auto, &mc, 1, 0.5, Dtype::Bf16);
    let up = plan.for_name("up_proj").expect("planned");
    assert_eq!(
        up.selection.backend.kind(),
        BackendKind::Sharded,
        "wide mlp linear must shard: {}",
        plan.describe()
    );
}
