//! Chaos suite for the fault-tolerant serving stack: deterministic
//! `SPARAMX_FAULTS` schedules replayed against the real recovery seams.
//!
//! What must hold (ISSUE 9 acceptance):
//! * an injected worker panic heals the pool and the retried epoch is
//!   **bit-exact** vs. the fault-free run;
//! * an injected kernel failure is retried on the same backend and the
//!   engine's served tokens are **bit-exact** vs. the fault-free run;
//! * repeated kernel failures quarantine the backend and the engine
//!   recompiles its plan mid-serve with **no token loss**;
//! * deadline-expired and cancelled slots answer partial results and
//!   free their KV cache;
//! * a schedule handed in via the `SPARAMX_FAULTS` env var (the CI
//!   chaos jobs) completes every admitted request.
//!
//! Fault state is process-global, so every test here serializes on one
//! mutex and clears the installed plan on entry and exit.

use sparamx::amx::EventCounters;
use sparamx::backend::{Backend, PackedOperand};
use sparamx::cfg::{EngineChoice, RuntimeConfig};
use sparamx::coordinator::batcher::AdmissionQueue;
use sparamx::coordinator::engine::Engine;
use sparamx::coordinator::request::{Request, Response};
use sparamx::fault;
use sparamx::models::tinyforward::{LayerW, TinyModel};
use sparamx::shard::{NumaTopology, ShardPlan, ShardedOperand, WorkerPool};
use sparamx::sparse::format::SparseTensor;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex, MutexGuard};
use std::time::Instant;

/// Serializes every test in this binary: the fault plan, its counters,
/// and the backend-failure records are process-global, and even an
/// unarmed engine run drains the global failure records.
static SERIAL: Mutex<()> = Mutex::new(());

fn serial() -> MutexGuard<'static, ()> {
    SERIAL.lock().unwrap_or_else(|e| e.into_inner())
}

fn m(v: &AtomicU64) -> u64 {
    v.load(Ordering::Relaxed)
}

/// Deterministic synthetic tiny model (same family as the build-time
/// checkpoint: 2 layers, GQA, byte-level vocab).
fn toy_model(seed: u64) -> TinyModel {
    let mut g = sparamx::util::XorShift::new(seed);
    let (h, inter, heads, kvh, hd, vocab) = (16, 24, 4, 2, 4, 256);
    let mut mk = |n: usize| g.normal_vec(n, 0.3);
    TinyModel {
        hidden: h,
        inter,
        heads,
        kv_heads: kvh,
        head_dim: hd,
        vocab,
        emb: mk(vocab * h),
        layers: (0..2)
            .map(|_| LayerW {
                ln1: vec![1.0; h],
                wq: mk(h * heads * hd),
                wk: mk(h * kvh * hd),
                wv: mk(h * kvh * hd),
                wo: mk(heads * hd * h),
                ln2: vec![1.0; h],
                wgate: mk(h * inter),
                wup: mk(h * inter),
                wdown: mk(inter * h),
            })
            .collect(),
        ln_f: vec![1.0; h],
        lm_head: mk(h * vocab),
    }
}

fn native_cfg() -> RuntimeConfig {
    RuntimeConfig {
        weight_sparsity: 0.0,
        k_sparsity: 0.0,
        v_sparsity: 0.0,
        max_batch: 4,
        max_new_tokens: 8,
        max_ctx: 64,
        engine: EngineChoice::Auto,
        ..Default::default()
    }
}

/// Admit `prompts` (8 new tokens each), serve to drain, and return the
/// engine plus one response per prompt in admission order.
fn serve_prompts(
    model: TinyModel,
    cfg: RuntimeConfig,
    prompts: &[&[u8]],
    deadline_ms: Option<u64>,
    cancel_now: bool,
) -> (Engine, Vec<Response>) {
    let mut engine = Engine::from_tiny_model(model, cfg).expect("engine");
    let queue = Arc::new(AdmissionQueue::new(16));
    let mut rxs = Vec::new();
    for (i, p) in prompts.iter().enumerate() {
        let (tx, rx) = mpsc::channel();
        queue
            .admit(Request {
                id: i as u64,
                prompt: p.to_vec(),
                max_new_tokens: 8,
                arrived: Instant::now(),
                respond: tx,
                deadline_ms,
                cancel: Arc::new(AtomicBool::new(cancel_now)),
            })
            .expect("admit");
        rxs.push(rx);
    }
    queue.close();
    engine.run(&queue).expect("engine drains");
    let resps = rxs
        .into_iter()
        .map(|rx| rx.recv().expect("every request answered"))
        .collect();
    (engine, resps)
}

/// The kernel backend a fresh engine would dispatch its LM head through
/// — the name deterministic fault schedules target. Host-agnostic: the
/// suite derives it instead of assuming which ISA the registry picked.
fn selected_backend_name(cfg: &RuntimeConfig) -> String {
    let probe = Engine::from_tiny_model(toy_model(90), cfg.clone()).expect("probe engine");
    probe.backend().name().to_string()
}

/// A 4-shard reference-backed [`ShardedBackend`] over a pre-partitioned
/// operand (the serving path — no partition-counter tick), plus its
/// pool and a fixed input.
fn sharded_ref() -> (Backend, Arc<WorkerPool>, ShardedOperand, Vec<f32>) {
    let topo = NumaTopology::modeled(1, 8);
    let pool = Arc::new(WorkerPool::with_topology(4, &topo));
    let b = Backend::sharded(Backend::reference(), 4, topo, Arc::clone(&pool));
    let w: Vec<f32> = (0..64 * 64).map(|i| ((i * 31 + 7) % 13) as f32 - 6.0).collect();
    let sp = SparseTensor::pack_f32(&w, 64, 64);
    let op = ShardedOperand::from_whole(
        &PackedOperand::Sparse(sp),
        ShardPlan::build(64, 4, &topo),
    );
    let x: Vec<f32> = (0..64).map(|i| (i % 5) as f32 * 0.25 - 0.5).collect();
    (b, pool, op, x)
}

// ---------------------------------------------------------------------
// Worker-panic recovery on the shard pool (direct seam)
// ---------------------------------------------------------------------

#[test]
fn injected_worker_panic_heals_the_pool_bit_exact() {
    let _g = serial();
    fault::clear();
    let (b, pool, op, x) = sharded_ref();
    let mut ctr = EventCounters::default();
    let clean = b.gemm_bf16_sharded(&x, 1, &op, &mut ctr);
    let _ = b.shard_stats(); // drain the baseline epoch

    // the next scatter runs at the pool's current epoch index
    let epoch = pool.epochs();
    fault::install(format!("worker_panic@epoch={epoch},shard=1").parse().unwrap());
    let recovered = b.gemm_bf16_sharded(&x, 1, &op, &mut ctr);
    assert_eq!(
        recovered, clean,
        "healed-pool retry must reproduce the fault-free output exactly"
    );
    assert_eq!(fault::injected_count(), 1);
    let snap = b.shard_stats().expect("sharded backend reports stats");
    assert_eq!(snap.epoch_retries, 1, "exactly one epoch retry");
    assert_eq!(pool.respawns(), 1, "the panicked worker was replaced");
    fault::clear();
}

#[test]
fn double_worker_panic_falls_back_to_sequential_bit_exact() {
    let _g = serial();
    fault::clear();
    let (b, pool, op, x) = sharded_ref();
    let mut ctr = EventCounters::default();
    let clean = b.gemm_bf16_sharded(&x, 1, &op, &mut ctr);
    let _ = b.shard_stats();

    // kill shard 0 on the first attempt *and* on the healed-pool retry:
    // the sequential inline fallback must complete the call
    let e = pool.epochs();
    fault::install(
        format!(
            "worker_panic@epoch={e},shard=0;worker_panic@epoch={},shard=0",
            e + 1
        )
        .parse()
        .unwrap(),
    );
    let recovered = b.gemm_bf16_sharded(&x, 1, &op, &mut ctr);
    assert_eq!(
        recovered, clean,
        "sequential fallback must reproduce the fault-free output exactly"
    );
    assert_eq!(fault::injected_count(), 2);
    let snap = b.shard_stats().expect("stats");
    assert_eq!(snap.epoch_retries, 1, "one retry, then the inline rung");
    fault::clear();

    // the next (unarmed) epoch heals the second dead worker and serves
    let again = b.gemm_bf16_sharded(&x, 1, &op, &mut ctr);
    assert_eq!(again, clean);
    assert_eq!(pool.respawns(), 2, "both panicked workers were replaced");
}

#[test]
fn slow_shard_delays_an_epoch_without_changing_output() {
    let _g = serial();
    fault::clear();
    let (b, _pool, op, x) = sharded_ref();
    let mut ctr = EventCounters::default();
    let clean = b.gemm_bf16_sharded(&x, 1, &op, &mut ctr);

    fault::install("slow_shard@shard=0,delay_us=200".parse().unwrap());
    let delayed = b.gemm_bf16_sharded(&x, 1, &op, &mut ctr);
    assert_eq!(delayed, clean, "a straggling shard must not change the merge");
    assert!(fault::injected_count() >= 1, "the delay was injected");
    fault::clear();
}

// ---------------------------------------------------------------------
// Kernel-failure recovery through the serving engine
// ---------------------------------------------------------------------

#[test]
fn injected_kernel_failure_serves_bit_exact_tokens() {
    let _g = serial();
    fault::clear();
    let cfg = native_cfg();
    let prompts: &[&[u8]] = &[b"the cat sees "];
    let (_e0, clean) = serve_prompts(toy_model(91), cfg.clone(), prompts, None, false);
    assert_eq!(clean[0].tokens.len(), 8);

    // single-shot failure on the engine's own LM-head backend: the
    // same-backend retry finds the fault spent and recovery is bit-exact
    let name = selected_backend_name(&cfg);
    fault::install(
        format!("kernel_fail@backend={name},call=3").parse().unwrap(),
    );
    let (engine, faulty) = serve_prompts(toy_model(91), cfg, prompts, None, false);
    assert_eq!(
        faulty[0].tokens, clean[0].tokens,
        "same-backend retry must reproduce the fault-free tokens exactly"
    );
    assert!(faulty[0].partial_reason.is_none());
    assert_eq!(fault::injected_count(), 1, "the window fired exactly once");
    assert_eq!(m(&engine.metrics.faults_injected), 1);
    assert_eq!(m(&engine.metrics.backend_quarantines), 0);
    assert_eq!(m(&engine.metrics.plan_recompiles), 0);
    fault::clear();
}

#[test]
fn repeated_kernel_failures_quarantine_and_replan_without_token_loss() {
    let _g = serial();
    fault::clear();
    let cfg = native_cfg();
    let name = selected_backend_name(&cfg);
    if name == "ref" {
        eprintln!("skipping: reference backend is never quarantined");
        return;
    }
    // two 2-call windows: each defeats the same-backend retry, records a
    // failure, and the second record crosses the quarantine threshold
    fault::install(
        format!(
            "kernel_fail@backend={name},call=2,count=2;\
             kernel_fail@backend={name},call=6,count=2"
        )
        .parse()
        .unwrap(),
    );
    let prompts: &[&[u8]] = &[b"the cat ", b"a dog ", b"the queen "];
    let (engine, resps) = serve_prompts(toy_model(92), cfg, prompts, None, false);
    for r in &resps {
        assert_eq!(r.tokens.len(), 8, "request {} lost tokens", r.id);
        assert!(r.partial_reason.is_none(), "request {} cut short", r.id);
    }
    assert_eq!(m(&engine.metrics.tokens_generated), 24, "no step loss");
    assert_eq!(m(&engine.metrics.backend_quarantines), 1);
    assert_eq!(m(&engine.metrics.plan_recompiles), 1, "degraded-mode re-plan ran");
    let registry = engine.registry().expect("native engine exposes its registry");
    assert!(
        registry.is_quarantined(&name),
        "{name} should be quarantined after repeated failures"
    );
    assert_eq!(fault::injected_count(), 4, "both windows fired fully");
    fault::clear();
}

/// PR 10 probation: after the double-window recipe sidelines the hot
/// backend, continued fault-free serving on the *same* engine routes a
/// shadow probe GEMM to it every few steps (mirrored, compared, never
/// served). Three consecutive clean probes re-admit the backend with
/// exactly one release recompile — and none of it disturbs serving.
#[test]
fn quarantined_backend_is_readmitted_after_clean_probation() {
    let _g = serial();
    fault::clear();
    let cfg = native_cfg();
    let name = selected_backend_name(&cfg);
    if name == "ref" {
        eprintln!("skipping: reference backend is never quarantined");
        return;
    }
    fault::install(
        format!(
            "kernel_fail@backend={name},call=2,count=2;\
             kernel_fail@backend={name},call=6,count=2"
        )
        .parse()
        .unwrap(),
    );
    let prompts: &[&[u8]] = &[b"the cat ", b"a dog ", b"the queen "];
    let (mut engine, _resps) = serve_prompts(toy_model(96), cfg, prompts, None, false);
    {
        let registry = engine.registry().expect("native engine exposes its registry");
        assert!(registry.is_quarantined(&name), "setup: {name} must be quarantined");
    }
    assert_eq!(m(&engine.metrics.plan_recompiles), 1, "setup: degraded re-plan");
    fault::clear(); // probation itself runs fault-free

    // Keep serving on the same engine: probe traffic rides the step
    // loop, so three light rounds of traffic give probation more than
    // enough ticks to re-admit the backend.
    for round in 0..3u64 {
        let queue = Arc::new(AdmissionQueue::new(16));
        let mut rxs = Vec::new();
        for i in 0..2u64 {
            let (tx, rx) = mpsc::channel();
            queue
                .admit(Request {
                    id: 100 + round * 10 + i,
                    prompt: b"the cat sees ".to_vec(),
                    max_new_tokens: 8,
                    arrived: Instant::now(),
                    respond: tx,
                    deadline_ms: None,
                    cancel: Arc::new(AtomicBool::new(false)),
                })
                .expect("admit");
            rxs.push(rx);
        }
        queue.close();
        engine.run(&queue).expect("engine");
        for rx in rxs {
            let r = rx.recv().expect("answered");
            assert_eq!(r.tokens.len(), 8, "probation must not disturb serving");
            assert!(r.partial_reason.is_none());
        }
    }

    let registry = engine.registry().expect("native engine exposes its registry");
    assert!(
        !registry.is_quarantined(&name),
        "{name} must be re-admitted after three clean probation probes"
    );
    assert_eq!(m(&engine.metrics.quarantine_releases), 1);
    assert!(m(&engine.metrics.probe_calls) >= 3, "at least three shadow probes ran");
    assert_eq!(m(&engine.metrics.plan_recompiles), 2, "exactly one recompile on release");
    assert_eq!(engine.kv_resident_bytes(), 0);
}

// ---------------------------------------------------------------------
// Deadlines and cancellation
// ---------------------------------------------------------------------

#[test]
fn deadline_expired_slot_returns_partial_and_frees_kv_cache() {
    let _g = serial();
    fault::clear();
    let prompts: &[&[u8]] = &[b"the cat sees "];
    let (engine, resps) = serve_prompts(toy_model(93), native_cfg(), prompts, Some(0), false);
    let r = &resps[0];
    assert_eq!(r.partial_reason.as_deref(), Some("deadline"));
    assert!(
        r.tokens.len() < 8,
        "an already-expired deadline must cut generation short"
    );
    assert_eq!(m(&engine.metrics.deadline_expirations), 1);
    assert_eq!(engine.active_slots(), 0);
    assert_eq!(
        engine.kv_resident_bytes(),
        0,
        "a deadline-expired slot must free its KV cache"
    );
}

#[test]
fn cancelled_request_drains_its_slot_with_partial_reason() {
    let _g = serial();
    fault::clear();
    let prompts: &[&[u8]] = &[b"the cat sees "];
    let (engine, resps) = serve_prompts(toy_model(94), native_cfg(), prompts, None, true);
    assert_eq!(resps[0].partial_reason.as_deref(), Some("cancelled"));
    assert!(resps[0].tokens.len() < 8);
    assert_eq!(engine.active_slots(), 0);
    assert_eq!(engine.kv_resident_bytes(), 0);
    assert_eq!(
        m(&engine.metrics.deadline_expirations),
        0,
        "cancellation is not a deadline expiry"
    );
}

// ---------------------------------------------------------------------
// CI env-var replay
// ---------------------------------------------------------------------

/// Replays whatever schedule the CI chaos job pinned in
/// `SPARAMX_FAULTS` (no-op when the var is unset): every admitted
/// request must complete its full token budget — the recovery ladder
/// (same-backend retry, pool healing, reference fallback, quarantine +
/// re-plan) guarantees completion for any single valid schedule.
#[test]
fn env_pinned_schedule_completes_every_admitted_request() {
    let _g = serial();
    fault::clear();
    let armed = fault::install_str_or_env("").expect("SPARAMX_FAULTS must parse");
    if !armed {
        return; // not a chaos job
    }
    let prompts: &[&[u8]] =
        &[b"the cat ", b"a dog ", b"the queen ", b"my robot ", b"one bird "];
    let (engine, resps) = serve_prompts(toy_model(95), native_cfg(), prompts, None, false);
    for r in &resps {
        assert_eq!(r.tokens.len(), 8, "request {} lost tokens under chaos", r.id);
        assert!(r.partial_reason.is_none(), "request {} cut short", r.id);
    }
    assert_eq!(m(&engine.metrics.tokens_generated), 40);
    assert_eq!(
        m(&engine.metrics.faults_injected),
        fault::injected_count(),
        "stats must report the injected-fault count"
    );
    fault::clear();
}
