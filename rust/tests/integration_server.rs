//! Live TCP serving test: engine + server + client over a real socket,
//! including malformed-request and backpressure failure injection.
//! Requires artifacts (skips otherwise).

use sparamx::cfg::{EngineChoice, RuntimeConfig};
use sparamx::coordinator::batcher::AdmissionQueue;
use sparamx::coordinator::engine::Engine;
use sparamx::coordinator::server;
use sparamx::coordinator::server::ServerCtx;
use sparamx::runtime::artifact::Bundle;
use sparamx::runtime::executor::Runtime;
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::Arc;

fn artifacts_dir() -> Option<String> {
    if cfg!(not(feature = "pjrt")) {
        eprintln!("skipping: built without the pjrt feature (stub executor)");
        return None;
    }
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    dir.join("manifest.json")
        .exists()
        .then(|| dir.to_string_lossy().into_owned())
}

#[test]
fn tcp_round_trip_with_failure_injection() {
    let Some(dir) = artifacts_dir() else {
        eprintln!("skipping: artifacts/ not built");
        return;
    };
    let cfg = RuntimeConfig {
        artifacts_dir: dir,
        weight_sparsity: 0.0,
        max_new_tokens: 6,
        engine: EngineChoice::Pjrt, // this test covers the AOT path
        ..Default::default()
    };
    let bundle = Bundle::load(&cfg.artifacts_dir).expect("bundle");
    let rt = Runtime::cpu().expect("client");
    let mut engine = Engine::load(&rt, &bundle, cfg).expect("engine");
    let queue = Arc::new(AdmissionQueue::new(16));

    let listener = TcpListener::bind("127.0.0.1:0").expect("bind");
    let addr = listener.local_addr().unwrap();
    let ctx = ServerCtx {
        queue: Arc::clone(&queue),
        default_max_tokens: 6,
        metrics: Arc::clone(&engine.metrics),
        engine: engine.describe(),
        predicted_step_s: engine.predicted_step_s(),
    };
    std::thread::spawn(move || server::serve(listener, ctx));

    // The PJRT executable is not Send, so the engine stays on this
    // thread; the TCP client runs on a helper thread and closes the
    // queue when it is done, which lets `engine.run` drain and return.
    let q_client = Arc::clone(&queue);
    let client = std::thread::spawn(move || {
        let mut stream = TcpStream::connect(addr).expect("connect");
        let mut reader = BufReader::new(stream.try_clone().unwrap());

        // failure injection: malformed JSON → error response, connection lives
        stream.write_all(b"this is not json\n").unwrap();
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        assert!(line.contains("error"), "malformed request must error: {line}");

        // failure injection: missing prompt
        line.clear();
        stream.write_all(b"{\"max_new_tokens\": 3}\n").unwrap();
        reader.read_line(&mut line).unwrap();
        assert!(line.contains("error"));

        // happy path: two sequential generations on one connection
        for prompt in ["the cat ", "a dog "] {
            line.clear();
            let req = format!("{{\"prompt\": \"{prompt}\", \"max_new_tokens\": 6}}\n");
            stream.write_all(req.as_bytes()).unwrap();
            reader.read_line(&mut line).unwrap();
            let v = sparamx::cfg::Json::parse(line.trim()).expect("json response");
            assert_eq!(v.get("tokens").and_then(|t| t.as_usize()), Some(6), "{line}");
            assert!(v.get("latency_ms").and_then(|t| t.as_f64()).unwrap() > 0.0);
        }
        q_client.close();
    });

    engine.run(&queue).expect("engine");
    client.join().expect("client thread");
    assert_eq!(
        engine
            .metrics
            .requests_completed
            .load(std::sync::atomic::Ordering::Relaxed),
        2
    );
}
