//! Native decode pipeline acceptance tests: plan compilation caching,
//! RefBackend selection rules, logits/token parity between the
//! plan-driven incremental decode and the full-sequence forward oracle
//! (the numerics reference the PJRT artifact is itself validated
//! against), and end-to-end serving through the continuous-batching
//! engine + TCP server — all runnable without artifacts or the `pjrt`
//! feature.

use sparamx::amx::EventCounters;
use sparamx::backend::{BackendChoice, BackendKind, BackendRegistry, CpuCaps, Dtype};
use sparamx::cfg::{EngineChoice, RuntimeConfig};
use sparamx::coordinator::batcher::AdmissionQueue;
use sparamx::coordinator::engine::Engine;
use sparamx::coordinator::request::Request;
use sparamx::coordinator::server::{self, ServerCtx};
use sparamx::models::plan::{plan_model, DecodePlan, NativeModel, RegimeBatches};
use sparamx::models::tinyforward::{KvTreatment, LayerW, TinyModel};
use sparamx::models::ModelConfig;
use std::sync::{mpsc, Arc};
use std::time::Instant;

/// Deterministic synthetic tiny model (same family as the build-time
/// checkpoint: 2 layers, GQA, byte-level vocab so ASCII prompts are
/// valid token streams).
fn toy_model(seed: u64) -> TinyModel {
    let mut g = sparamx::util::XorShift::new(seed);
    let (h, inter, heads, kvh, hd, vocab) = (16, 24, 4, 2, 4, 256);
    let mut mk = |n: usize| g.normal_vec(n, 0.3);
    TinyModel {
        hidden: h,
        inter,
        heads,
        kv_heads: kvh,
        head_dim: hd,
        vocab,
        emb: mk(vocab * h),
        layers: (0..2)
            .map(|_| LayerW {
                ln1: vec![1.0; h],
                wq: mk(h * heads * hd),
                wk: mk(h * kvh * hd),
                wv: mk(h * kvh * hd),
                wo: mk(heads * hd * h),
                ln2: vec![1.0; h],
                wgate: mk(h * inter),
                wup: mk(h * inter),
                wdown: mk(inter * h),
            })
            .collect(),
        ln_f: vec![1.0; h],
        lm_head: mk(h * vocab),
    }
}

fn argmax(xs: &[f32]) -> usize {
    let mut best = 0;
    for (i, &x) in xs.iter().enumerate() {
        if x > xs[best] {
            best = i;
        }
    }
    best
}

/// Last-position logits of the full-sequence f32 oracle forward.
fn oracle_row(model: &TinyModel, seq: &[u8]) -> Vec<f32> {
    let logits = model.forward(seq, KvTreatment::default());
    logits[(seq.len() - 1) * model.vocab..seq.len() * model.vocab].to_vec()
}

/// Native greedy decode: prefill the prompt prefix, then `n` plan-driven
/// decode steps. Returns (tokens, per-step logits).
fn native_greedy(nm: &NativeModel, prompt: &[u8], n: usize) -> (Vec<u8>, Vec<Vec<f32>>) {
    let mut ctr = EventCounters::default();
    let mut cache = nm.prefill(&prompt[..prompt.len() - 1], 0.0, 0.0, &mut ctr);
    let mut token = *prompt.last().unwrap();
    let mut pos = prompt.len() - 1;
    let mut tokens = Vec::new();
    let mut rows = Vec::new();
    for _ in 0..n {
        let logits = nm.decode_step(token, pos, &mut cache, &mut ctr);
        token = argmax(&logits) as u8;
        pos += 1;
        tokens.push(token);
        rows.push(logits);
    }
    (tokens, rows)
}

// ---------------------------------------------------------------------
// Plan compilation: selection caching + RefBackend rules
// ---------------------------------------------------------------------

#[test]
fn decode_plan_caches_one_selection_per_distinct_shape() {
    let reg = BackendRegistry::with_caps(CpuCaps::all());
    let model = toy_model(42);
    let plan = DecodePlan::compile(&reg, BackendChoice::Auto, &model, 0.5);
    // toy shapes: q=o=(16,16), k=v=(16,8), gate=up=(16,24),
    // down=(24,16), lm_head=(16,256) → exactly 5 distinct, resolved at
    // each of the 3 default regime batches (1 / 8 / 32)
    assert_eq!(plan.selections_computed, 15);
    assert_eq!(plan.linears_planned, 2 * 7 + 1);
}

#[test]
fn selection_runs_at_load_never_in_the_token_loop() {
    let reg = BackendRegistry::with_caps(CpuCaps::all());
    assert_eq!(reg.selections_resolved(), 0);
    let model = toy_model(42);
    let nm = NativeModel::new(&reg, BackendChoice::Auto, model, 0.0);
    assert_eq!(
        nm.plan.selections_computed, 15,
        "one selection per distinct shape per regime batch"
    );
    // the registry's own call counter confirms compile consulted it
    // exactly once per distinct (shape, regime batch)...
    let at_load = reg.selections_resolved();
    assert_eq!(at_load, 15, "plan compile = 5 shapes x 3 regime batches");
    // ...and a dozen decode steps later it has not moved: selection
    // runs at load, never in the token loop (ROADMAP invariant). Any
    // future re-selection through this registry on the serving path
    // would tick the counter and fail here.
    let (_tokens, rows) = native_greedy(&nm, &[1, 2, 3, 4], 12);
    assert_eq!(rows.len(), 12);
    assert_eq!(reg.selections_resolved(), at_load, "token loop re-ran selection");
}

#[test]
fn plan_never_selects_reference_when_an_isa_backend_is_eligible() {
    let mc = ModelConfig::tiny();
    for caps in ["all", "amx", "avx512", "amx-bf16"] {
        let reg = BackendRegistry::with_caps(CpuCaps::from_list(caps));
        let plan = plan_model(&reg, BackendChoice::Auto, &mc, 1, 0.5, Dtype::Bf16);
        for p in plan.per_layer.iter().chain([&plan.lm_head]) {
            assert_ne!(
                p.selection.backend.kind(),
                BackendKind::Reference,
                "caps={caps}: {} fell back to the reference oracle",
                p.shape.name
            );
        }
    }
}

#[test]
fn caps_none_plan_still_produces_correct_logits_via_reference_fallback() {
    let reg = BackendRegistry::with_caps(CpuCaps::none());
    let model = toy_model(43);
    let oracle = model.clone();
    let nm = NativeModel::new(&reg, BackendChoice::Auto, model, 0.0);
    for l in &nm.plan.layers {
        assert_eq!(l.wq.selection.backend.kind(), BackendKind::Reference);
    }
    let prompt = [1u8, 5, 9, 2];
    let (tokens, rows) = native_greedy(&nm, &prompt, 6);
    // teacher-forced oracle comparison along the native trajectory
    let mut seq = prompt.to_vec();
    for (i, row) in rows.iter().enumerate() {
        let want = oracle_row(&oracle, &seq);
        for (a, b) in row.iter().zip(want.iter()) {
            assert!(
                (a - b).abs() < 0.3,
                "step {i}: ref-fallback logits diverge ({a} vs {b})"
            );
        }
        seq.push(tokens[i]);
    }
}

// ---------------------------------------------------------------------
// Parity: plan-driven incremental decode vs full-sequence forward
// ---------------------------------------------------------------------

#[test]
fn native_decode_logits_match_oracle_teacher_forced() {
    // Feed a fixed token stream through the incremental native decode
    // and compare every step's logits against the full-sequence oracle
    // forward — no compounding through greedy choices. Reference-pinned
    // backend: GEMM math is the f32 oracle over BF16-packed operands,
    // so drift is operand rounding plus the KV cache's BF16 packing
    // (tighter than the full AMX tile band below).
    let reg = BackendRegistry::with_caps(CpuCaps::all());
    let model = toy_model(44);
    let oracle = model.clone();
    let nm = NativeModel::new(&reg, BackendChoice::Reference, model, 0.0);
    let stream: Vec<u8> = vec![3, 7, 1, 9, 4, 2, 8, 6, 5, 10, 11, 1];
    let prefix = 4usize;
    let mut ctr = EventCounters::default();
    let mut cache = nm.prefill(&stream[..prefix - 1], 0.0, 0.0, &mut ctr);
    for t in (prefix - 1)..stream.len() - 1 {
        let logits = nm.decode_step(stream[t], t, &mut cache, &mut ctr);
        let want = oracle_row(&oracle, &stream[..t + 1]);
        for (j, (a, b)) in logits.iter().zip(want.iter()).enumerate() {
            assert!(
                (a - b).abs() < 0.3,
                "pos {t} vocab {j}: native {a} vs oracle {b}"
            );
        }
    }
}

#[test]
fn native_decode_tokens_match_oracle_greedy() {
    // Greedy-token parity with a margin guard: steps where the oracle's
    // top-2 margin is inside the numeric noise band are not compared
    // (a near-tie flips on BF16 rounding by construction).
    let reg = BackendRegistry::with_caps(CpuCaps::all());
    let model = toy_model(45);
    let oracle = model.clone();
    let nm = NativeModel::new(&reg, BackendChoice::Reference, model, 0.0);
    let prompt = [2u8, 6, 1, 8];
    let n = 10;
    let (tokens, rows) = native_greedy(&nm, &prompt, n);
    let mut seq = prompt.to_vec();
    for i in 0..n {
        let want = oracle_row(&oracle, &seq);
        let top = argmax(&want);
        let mut second = f32::NEG_INFINITY;
        for (j, &v) in want.iter().enumerate() {
            if j != top && v > second {
                second = v;
            }
        }
        if want[top] - second < 0.6 {
            break; // near-tie: token identity is not defined under rounding
        }
        assert_eq!(
            tokens[i] as usize, top,
            "step {i}: native token diverges from oracle greedy"
        );
        // and the winning logit agrees numerically
        assert!((rows[i][top] - want[top]).abs() < 0.3);
        seq.push(tokens[i]);
    }
}

#[test]
fn native_decode_with_amx_plan_tracks_oracle_within_bf16_noise() {
    // The full kernel path (AMX tile GEMMs everywhere) rounds inputs
    // and weights through BF16; logits stay within kernel-rounding
    // tolerance of the f32 oracle (same band the tinyforward
    // backend-vs-oracle test uses).
    let reg = BackendRegistry::with_caps(CpuCaps::all());
    let model = toy_model(46);
    let oracle = model.clone();
    let nm = NativeModel::new(&reg, BackendChoice::Auto, model, 0.0);
    let stream: Vec<u8> = vec![1, 4, 9, 3, 7, 2, 5];
    let mut ctr = EventCounters::default();
    let mut cache = nm.prefill(&stream[..2], 0.0, 0.0, &mut ctr);
    for t in 2..stream.len() - 1 {
        let logits = nm.decode_step(stream[t], t, &mut cache, &mut ctr);
        let want = oracle_row(&oracle, &stream[..t + 1]);
        for (a, b) in logits.iter().zip(want.iter()) {
            assert!((a - b).abs() < 0.8, "pos {t}: {a} vs {b}");
        }
    }
    assert!(ctr.instructions() > 0, "kernels must tick events");
}

// ---------------------------------------------------------------------
// Engine + server end-to-end on the native path (no artifacts needed)
// ---------------------------------------------------------------------

fn native_cfg() -> RuntimeConfig {
    RuntimeConfig {
        weight_sparsity: 0.0,
        k_sparsity: 0.0,
        v_sparsity: 0.0,
        max_batch: 4,
        max_new_tokens: 8,
        max_ctx: 64,
        engine: EngineChoice::Auto, // auto resolves native
        ..Default::default()
    }
}

#[test]
fn engine_serves_batches_through_the_native_path() {
    let mut engine = Engine::from_tiny_model(toy_model(47), native_cfg()).expect("engine");
    assert_eq!(engine.engine_path(), "native");
    assert!(engine.plan().is_some(), "native engine exposes its plan");
    let queue = Arc::new(AdmissionQueue::new(16));
    let mut rxs = Vec::new();
    for (i, prompt) in [&b"the cat "[..], b"a dog ", b"the queen ", b"my robot ", b"one bird "]
        .iter()
        .enumerate()
    {
        let (tx, rx) = mpsc::channel();
        queue
            .admit(Request {
                id: i as u64,
                prompt: prompt.to_vec(),
                max_new_tokens: 8,
                arrived: Instant::now(),
                respond: tx,
                deadline_ms: None,
                cancel: Arc::new(std::sync::atomic::AtomicBool::new(false)),
            })
            .unwrap();
        rxs.push(rx);
    }
    queue.close();
    engine.run(&queue).expect("engine drains");
    for rx in rxs {
        let resp = rx.recv().expect("every request answered");
        assert_eq!(resp.tokens.len(), 8);
        assert!(resp.tokens.iter().all(|&t| (t as usize) < 256), "tokens in vocab");
        assert!(resp.total_latency_s > 0.0);
    }
    assert_eq!(
        engine
            .metrics
            .requests_completed
            .load(std::sync::atomic::Ordering::Relaxed),
        5
    );
    // the metrics record which path/backend served every step
    let by_path = engine.metrics.steps_by_path();
    assert!(!by_path.is_empty());
    assert!(
        by_path.keys().all(|k| k.starts_with("native/")),
        "all steps served natively: {by_path:?}"
    );
    let steps = engine
        .metrics
        .decode_steps
        .load(std::sync::atomic::Ordering::Relaxed);
    assert_eq!(engine.metrics.step_hist.total(), steps);
    assert!(engine.kernel_events().instructions() > 0);
}

#[test]
fn engine_generation_equals_direct_plan_decode() {
    // The slotted engine must produce exactly what a bare NativeModel
    // greedy loop produces for the same weights — continuous batching
    // must not perturb per-request state.
    let cfg = native_cfg();
    let prompt = b"the cat sees ".to_vec();
    let registry = BackendRegistry::probe();
    // Mirror the engine's regime batches so the direct model's prefill
    // regime resolves the same selections the engine's plan did.
    let batches = RegimeBatches {
        decode_fused: cfg.max_batch_fuse.resolve(cfg.max_batch),
        prefill: cfg.max_ctx,
    };
    let nm = NativeModel::with_regimes(&registry, cfg.backend, toy_model(48), 0.0, batches);
    let (want_tokens, _) = native_greedy(&nm, &prompt, 8);

    let mut engine = Engine::from_tiny_model(toy_model(48), cfg).expect("engine");
    let queue = Arc::new(AdmissionQueue::new(4));
    let (tx, rx) = mpsc::channel();
    queue
        .admit(Request {
            id: 1,
            prompt,
            max_new_tokens: 8,
            arrived: Instant::now(),
            respond: tx,
            deadline_ms: None,
            cancel: Arc::new(std::sync::atomic::AtomicBool::new(false)),
        })
        .unwrap();
    queue.close();
    engine.run(&queue).unwrap();
    let resp = rx.recv().unwrap();
    assert_eq!(resp.tokens, want_tokens, "engine and direct decode agree");
}

#[test]
fn tcp_server_round_trip_on_the_native_engine_with_stats() {
    use std::io::{BufRead, BufReader, Write};
    use std::net::{TcpListener, TcpStream};

    let mut engine = Engine::from_tiny_model(toy_model(49), native_cfg()).expect("engine");
    let queue = Arc::new(AdmissionQueue::new(16));
    let listener = TcpListener::bind("127.0.0.1:0").expect("bind");
    let addr = listener.local_addr().unwrap();
    let ctx = ServerCtx {
        queue: Arc::clone(&queue),
        default_max_tokens: 6,
        metrics: Arc::clone(&engine.metrics),
        engine: engine.describe(),
        predicted_step_s: engine.predicted_step_s(),
    };
    std::thread::spawn(move || server::serve(listener, ctx));

    let q_client = Arc::clone(&queue);
    let client = std::thread::spawn(move || {
        let mut stream = TcpStream::connect(addr).expect("connect");
        let mut reader = BufReader::new(stream.try_clone().unwrap());
        let mut line = String::new();

        // generation round trip
        stream
            .write_all(b"{\"prompt\": \"the cat \", \"max_new_tokens\": 6}\n")
            .unwrap();
        reader.read_line(&mut line).unwrap();
        let v = sparamx::cfg::Json::parse(line.trim()).expect("json response");
        assert_eq!(v.get("tokens").and_then(|t| t.as_usize()), Some(6), "{line}");

        // stats endpoint reports the native path and the step histogram
        line.clear();
        stream.write_all(b"{\"stats\": true}\n").unwrap();
        reader.read_line(&mut line).unwrap();
        let s = sparamx::cfg::Json::parse(line.trim()).expect("stats json");
        assert!(
            s.get("engine").and_then(|e| e.as_str()).unwrap_or("").starts_with("native"),
            "{line}"
        );
        assert_eq!(s.get("tokens_generated").and_then(|t| t.as_usize()), Some(6));
        let by = s.get("steps_by_path").expect("steps_by_path");
        let total: f64 = match by {
            sparamx::cfg::Json::Obj(m) => m.values().filter_map(|v| v.as_f64()).sum(),
            _ => panic!("steps_by_path must be an object"),
        };
        assert_eq!(total as u64, 6, "{line}");
        q_client.close();
    });

    engine.run(&queue).expect("engine");
    client.join().expect("client thread");
}
