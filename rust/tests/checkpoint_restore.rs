//! Crash-consistency suite: slot checkpoint/restore round trips
//! (ISSUE 10 acceptance).
//!
//! What must hold:
//! * a run that checkpoints every N steps and is then "restarted" (a
//!   fresh engine restoring the snapshot file) finishes every in-flight
//!   request **bit-exact** with zero token loss;
//! * the same holds when the restoring process sees a *different*
//!   `SPARAMX_CAPS` capability set — restored plans are compiled on the
//!   current machine's registry, never deserialized;
//! * a torn or corrupt snapshot is detected by checksum and skipped
//!   (`restore_rejected`), never trusted;
//! * a snapshot whose slot geometry does not fit the restoring engine
//!   is rejected per slot.
//!
//! The caps test mutates process-global env vars and fault state is
//! process-global, so every test serializes on one mutex.

use sparamx::backend::BackendChoice;
use sparamx::cfg::{EngineChoice, RuntimeConfig};
use sparamx::coordinator::batcher::AdmissionQueue;
use sparamx::coordinator::engine::Engine;
use sparamx::coordinator::request::{Request, Response};
use sparamx::fault;
use sparamx::models::tinyforward::{LayerW, TinyModel};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex, MutexGuard};
use std::time::Instant;

/// Serializes every test in this binary: the caps test mutates the
/// process-global `SPARAMX_CAPS` env var, and even an unarmed engine
/// run drains the global fault records.
static SERIAL: Mutex<()> = Mutex::new(());

fn serial() -> MutexGuard<'static, ()> {
    SERIAL.lock().unwrap_or_else(|e| e.into_inner())
}

fn m(v: &AtomicU64) -> u64 {
    v.load(Ordering::Relaxed)
}

/// Unique-per-test snapshot path under the system temp dir.
fn snap_path(tag: &str) -> String {
    std::env::temp_dir()
        .join(format!("sparamx_ckpt_{}_{tag}.spxc", std::process::id()))
        .to_string_lossy()
        .into_owned()
}

/// Restore an env var to its pre-test value on drop (panic-safe).
struct EnvGuard {
    key: &'static str,
    saved: Option<String>,
}

impl EnvGuard {
    fn set(key: &'static str, val: &str) -> EnvGuard {
        let saved = std::env::var(key).ok();
        std::env::set_var(key, val);
        EnvGuard { key, saved }
    }
}

impl Drop for EnvGuard {
    fn drop(&mut self) {
        match &self.saved {
            Some(v) => std::env::set_var(self.key, v),
            None => std::env::remove_var(self.key),
        }
    }
}

/// Deterministic synthetic tiny model (same family as the build-time
/// checkpoint: 2 layers, GQA, byte-level vocab).
fn toy_model(seed: u64) -> TinyModel {
    let mut g = sparamx::util::XorShift::new(seed);
    let (h, inter, heads, kvh, hd, vocab) = (16, 24, 4, 2, 4, 256);
    let mut mk = |n: usize| g.normal_vec(n, 0.3);
    TinyModel {
        hidden: h,
        inter,
        heads,
        kv_heads: kvh,
        head_dim: hd,
        vocab,
        emb: mk(vocab * h),
        layers: (0..2)
            .map(|_| LayerW {
                ln1: vec![1.0; h],
                wq: mk(h * heads * hd),
                wk: mk(h * kvh * hd),
                wv: mk(h * kvh * hd),
                wo: mk(heads * hd * h),
                ln2: vec![1.0; h],
                wgate: mk(h * inter),
                wup: mk(h * inter),
                wdown: mk(inter * h),
            })
            .collect(),
        ln_f: vec![1.0; h],
        lm_head: mk(h * vocab),
    }
}

fn native_cfg() -> RuntimeConfig {
    RuntimeConfig {
        weight_sparsity: 0.0,
        k_sparsity: 0.0,
        v_sparsity: 0.0,
        max_batch: 4,
        max_new_tokens: 10,
        max_ctx: 64,
        engine: EngineChoice::Auto,
        ..Default::default()
    }
}

/// Admit `prompts` (`cfg.max_new_tokens` new tokens each), serve to
/// drain, and return the engine plus one response per prompt.
fn serve_prompts(
    model: TinyModel,
    cfg: RuntimeConfig,
    prompts: &[&[u8]],
) -> (Engine, Vec<Response>) {
    let max_new_tokens = cfg.max_new_tokens;
    let mut engine = Engine::from_tiny_model(model, cfg).expect("engine");
    let queue = Arc::new(AdmissionQueue::new(16));
    let mut rxs = Vec::new();
    for (i, p) in prompts.iter().enumerate() {
        let (tx, rx) = mpsc::channel();
        queue
            .admit(Request {
                id: i as u64,
                prompt: p.to_vec(),
                max_new_tokens,
                arrived: Instant::now(),
                respond: tx,
                deadline_ms: None,
                cancel: Arc::new(AtomicBool::new(false)),
            })
            .expect("admit");
        rxs.push(rx);
    }
    queue.close();
    engine.run(&queue).expect("engine drains");
    let resps = rxs
        .into_iter()
        .map(|rx| rx.recv().expect("every request answered"))
        .collect();
    (engine, resps)
}

/// Restore from `path` into a fresh engine, drain it against a closed
/// queue, and return the engine plus the restored responses.
fn restore_and_drain(model: TinyModel, cfg: RuntimeConfig, path: &str) -> (Engine, Vec<Response>) {
    let mut engine = Engine::from_tiny_model(model, cfg).expect("engine");
    let restored = engine.restore_from_file(path);
    let queue = Arc::new(AdmissionQueue::new(4));
    queue.close();
    engine.run(&queue).expect("engine drains");
    let resps = restored
        .into_iter()
        .map(|(id, rx)| {
            let resp = rx.recv().expect("restored slot answers exactly once");
            assert_eq!(resp.id, id, "restored response keeps its request id");
            assert!(rx.try_recv().is_err(), "slot {id} answered more than once");
            resp
        })
        .collect();
    (engine, resps)
}

// ---------------------------------------------------------------------
// Bit-exact restart round trip
// ---------------------------------------------------------------------

#[test]
fn restart_resumes_in_flight_request_bit_exact() {
    let _g = serial();
    fault::clear();
    let path = snap_path("resume");
    let _ = std::fs::remove_file(&path);
    let cfg = native_cfg();
    let prompts: &[&[u8]] = &[b"the cat sees "];

    // uninterrupted baseline
    let (_e0, clean) = serve_prompts(toy_model(70), cfg.clone(), prompts);
    assert_eq!(clean[0].tokens.len(), 10);

    // writer: same run, checkpointing every 4 productive steps — the
    // final snapshot on disk is the post-step-8 state (8 of 10 tokens)
    let mut wcfg = cfg.clone();
    wcfg.checkpoint = path.clone();
    wcfg.checkpoint_every_steps = 4;
    let (wengine, wresp) = serve_prompts(toy_model(70), wcfg, prompts);
    assert_eq!(wresp[0].tokens, clean[0].tokens, "checkpointing must not perturb decode");
    assert_eq!(m(&wengine.metrics.checkpoints_written), 2, "steps 4 and 8");
    assert!(std::path::Path::new(&path).exists());

    // "restart": a fresh engine restores the snapshot and finishes the
    // request — bit-exact, zero token loss, answered exactly once
    let (rengine, resps) = restore_and_drain(toy_model(70), cfg, &path);
    assert_eq!(m(&rengine.metrics.slots_restored), 1);
    assert_eq!(m(&rengine.metrics.restore_rejected), 0);
    assert_eq!(resps.len(), 1);
    assert_eq!(
        resps[0].tokens, clean[0].tokens,
        "resumed decode must be bit-exact with the uninterrupted run"
    );
    assert!(resps[0].partial_reason.is_none());
    assert_eq!(rengine.active_slots(), 0);
    assert_eq!(rengine.kv_resident_bytes(), 0, "restored slot frees its KV on exit");
    let _ = std::fs::remove_file(&path);
}

// ---------------------------------------------------------------------
// Cross-capability restore (different SPARAMX_CAPS per "machine")
// ---------------------------------------------------------------------

#[test]
fn restore_is_bit_exact_across_differing_caps() {
    let _g = serial();
    fault::clear();
    let path = snap_path("caps");
    let _ = std::fs::remove_file(&path);
    // pin the serving kernel class so the writer and the restorer decode
    // through the same kernel even though their registries differ
    let mut cfg = native_cfg();
    cfg.backend = BackendChoice::Amx;
    let prompts: &[&[u8]] = &[b"a dog runs "];

    // "machine A": full capability set
    let caps = EnvGuard::set(sparamx::backend::caps::CAPS_ENV, "all");
    let (_e0, clean) = serve_prompts(toy_model(71), cfg.clone(), prompts);
    assert_eq!(clean[0].tokens.len(), 10);
    let mut wcfg = cfg.clone();
    wcfg.checkpoint = path.clone();
    wcfg.checkpoint_every_steps = 4;
    let (wengine, _wresp) = serve_prompts(toy_model(71), wcfg, prompts);
    assert!(m(&wengine.metrics.checkpoints_written) >= 1);
    let writer_backends: Vec<String> = wengine
        .registry()
        .expect("native engine exposes its registry")
        .available()
        .iter()
        .map(|b| b.name().to_string())
        .collect();

    // "machine B": AMX-only caps — a genuinely different registry; the
    // restored plan is compiled here, never read from the snapshot
    std::env::set_var(sparamx::backend::caps::CAPS_ENV, "amx");
    let (rengine, resps) = restore_and_drain(toy_model(71), cfg, &path);
    let restore_backends: Vec<String> = rengine
        .registry()
        .expect("native engine exposes its registry")
        .available()
        .iter()
        .map(|b| b.name().to_string())
        .collect();
    assert_ne!(
        writer_backends, restore_backends,
        "the two machines' registries must actually differ"
    );
    assert_eq!(m(&rengine.metrics.slots_restored), 1);
    assert_eq!(
        resps[0].tokens, clean[0].tokens,
        "cross-caps resume must be bit-exact (same pinned kernel class)"
    );
    assert!(resps[0].partial_reason.is_none());
    drop(caps);
    let _ = std::fs::remove_file(&path);
}

// ---------------------------------------------------------------------
// Torn / corrupt / incompatible snapshots
// ---------------------------------------------------------------------

#[test]
fn corrupt_or_torn_snapshot_is_rejected_not_trusted() {
    let _g = serial();
    fault::clear();
    let path = snap_path("corrupt");
    let _ = std::fs::remove_file(&path);
    let cfg = native_cfg();
    let mut wcfg = cfg.clone();
    wcfg.checkpoint = path.clone();
    wcfg.checkpoint_every_steps = 4;
    let (_w, _r) = serve_prompts(toy_model(72), wcfg.clone(), &[b"the queen is "]);
    let pristine = std::fs::read(&path).expect("snapshot written");

    // bit flip in the payload → checksum mismatch → rejected
    let mut bytes = pristine.clone();
    let mid = bytes.len() / 2;
    bytes[mid] ^= 0x40;
    std::fs::write(&path, &bytes).unwrap();
    let (engine, resps) = restore_and_drain(toy_model(72), cfg.clone(), &path);
    assert!(resps.is_empty(), "a corrupt snapshot must restore nothing");
    assert_eq!(m(&engine.metrics.restore_rejected), 1);
    assert_eq!(m(&engine.metrics.slots_restored), 0);
    assert_eq!(engine.active_slots(), 0);

    // torn write (truncated file) → rejected
    std::fs::write(&path, &pristine[..pristine.len() - 5]).unwrap();
    let (engine, resps) = restore_and_drain(toy_model(72), cfg.clone(), &path);
    assert!(resps.is_empty(), "a torn snapshot must restore nothing");
    assert_eq!(m(&engine.metrics.restore_rejected), 1);

    // geometry mismatch: a valid snapshot whose cached positions exceed
    // the restoring engine's context window is rejected per slot
    std::fs::write(&path, &pristine).unwrap();
    let mut small = cfg.clone();
    small.max_ctx = 8; // snapshot cache_len is ~20 here
    let (engine, resps) = restore_and_drain(toy_model(72), small, &path);
    assert!(resps.is_empty(), "an oversized slot must not be restored");
    assert_eq!(m(&engine.metrics.restore_rejected), 1);
    assert_eq!(engine.kv_resident_bytes(), 0);

    // the pristine file still restores cleanly (the checks above were
    // about the data, not the reader)
    let (engine, resps) = restore_and_drain(toy_model(72), cfg, &path);
    assert_eq!(resps.len(), 1);
    assert_eq!(m(&engine.metrics.restore_rejected), 0);
    let _ = std::fs::remove_file(&path);
}

// ---------------------------------------------------------------------
// Missing file is a clean cold start
// ---------------------------------------------------------------------

#[test]
fn missing_snapshot_is_a_clean_cold_start() {
    let _g = serial();
    fault::clear();
    let path = snap_path("absent");
    let _ = std::fs::remove_file(&path);
    let (engine, resps) = restore_and_drain(toy_model(73), native_cfg(), &path);
    assert!(resps.is_empty());
    assert_eq!(m(&engine.metrics.restore_rejected), 0, "absence is not corruption");
    assert_eq!(m(&engine.metrics.slots_restored), 0);
}
