//! Compile-time-only partitioning invariant, end to end.
//!
//! `sparamx::shard::partitions_performed()` is a process-global
//! counter, so this binary holds exactly ONE test: a separate test
//! binary is a separate process (its own counter), and a single `#[test]`
//! rules out intra-binary test parallelism. Parity and registry tests —
//! which tick the counter freely — live in `sharded_backend.rs`.

use sparamx::amx::EventCounters;
use sparamx::backend::{Backend, BackendChoice, BackendRegistry, CpuCaps, PackedOperand};
use sparamx::models::plan::NativeModel;
use sparamx::models::tinyforward::{LayerW, TinyModel};
use sparamx::shard::{partitions_performed, NumaTopology, WorkerPool};
use sparamx::sparse::prune::magnitude_prune;
use sparamx::util::XorShift;
use std::sync::Arc;

/// Same synthetic checkpoint family as `native_engine.rs`.
fn toy_model(seed: u64) -> TinyModel {
    let mut g = XorShift::new(seed);
    let (h, inter, heads, kvh, hd, vocab) = (16, 24, 4, 2, 4, 256);
    let mut mk = |n: usize| g.normal_vec(n, 0.3);
    TinyModel {
        hidden: h,
        inter,
        heads,
        kv_heads: kvh,
        head_dim: hd,
        vocab,
        emb: mk(vocab * h),
        layers: (0..2)
            .map(|_| LayerW {
                ln1: vec![1.0; h],
                wq: mk(h * heads * hd),
                wk: mk(h * kvh * hd),
                wv: mk(h * kvh * hd),
                wo: mk(heads * hd * h),
                ln2: vec![1.0; h],
                wgate: mk(h * inter),
                wup: mk(h * inter),
                wdown: mk(inter * h),
            })
            .collect(),
        ln_f: vec![1.0; h],
        lm_head: mk(h * vocab),
    }
}

#[test]
fn partitioning_happens_at_pack_time_never_in_the_serving_loop() {
    let topo = NumaTopology::modeled(2, 8);
    let inner = Backend::amx();
    let pool = Arc::new(WorkerPool::with_topology(2, &topo));
    let sharded = Backend::sharded(inner.clone(), 2, topo, pool);

    // (a) packing an operand through a sharded backend partitions it
    // exactly once and yields the pre-sharded variant
    let (rows, cols, batch) = (48usize, 112usize, 3usize);
    let mut g = XorShift::new(71);
    let w = magnitude_prune(&g.normal_vec(rows * cols, 1.0), 0.5);
    let x = g.normal_vec(batch * rows, 1.0);
    let before = partitions_performed();
    let op = PackedOperand::pack_f32(&sharded, &w, rows, cols, true);
    assert_eq!(partitions_performed(), before + 1, "pack partitions exactly once");
    assert!(
        matches!(op, PackedOperand::Sharded(_)),
        "sharded backend must pack a pre-sharded operand"
    );

    // (b) running the packed operand never re-partitions, and matches
    // the unsharded inner backend bit-exactly
    let whole = PackedOperand::pack_f32(&inner, &w, rows, cols, true);
    let mut c = EventCounters::default();
    let want = whole.gemm_bf16(&inner, &x, batch, &mut c);
    let at_pack = partitions_performed();
    for step in 0..5 {
        let mut cs = EventCounters::default();
        let got = op.gemm_bf16(&sharded, &x, batch, &mut cs);
        assert_eq!(got, want, "step {step}: pre-sharded run not bit-exact");
    }
    assert_eq!(partitions_performed(), at_pack, "serving runs must not re-partition");

    // (c) full native pipeline on a sharded registry: all partitioning
    // and backend selection happens inside NativeModel::new (plan
    // compile + weight pack); a dozen decode steps move neither counter
    let reg = BackendRegistry::with_caps(CpuCaps::all()).with_shards(2, topo);
    assert_eq!(reg.selections_resolved(), 0);
    let nm = NativeModel::new(&reg, BackendChoice::Auto, toy_model(72), 0.0);
    let at_load_parts = partitions_performed();
    let at_load_sels = reg.selections_resolved();
    assert_eq!(
        at_load_sels, 15,
        "plan compile = one resolution per distinct shape per regime batch"
    );

    let prompt = [1u8, 5, 9, 2];
    let mut ctr = EventCounters::default();
    let mut cache = nm.prefill(&prompt[..prompt.len() - 1], 0.0, 0.0, &mut ctr);
    let mut token = *prompt.last().unwrap();
    let mut pos = prompt.len() - 1;
    for _ in 0..12 {
        let logits = nm.decode_step(token, pos, &mut cache, &mut ctr);
        let mut best = 0;
        for (i, &v) in logits.iter().enumerate() {
            if v > logits[best] {
                best = i;
            }
        }
        token = best as u8;
        pos += 1;
    }
    assert_eq!(
        partitions_performed(),
        at_load_parts,
        "token loop re-partitioned a shard plan"
    );
    assert_eq!(reg.selections_resolved(), at_load_sels, "token loop re-ran selection");
}
