//! Server-edge chaos suite: deterministic fault schedules on the
//! accept/read/write and admission path (ISSUE 10 acceptance).
//!
//! What must hold:
//! * a `slow_client` connection crawling through its lines never stalls
//!   co-admitted requests on other connections, and every served token
//!   stream is **bit-exact** vs. the fault-free run;
//! * a pinned `disconnect` tears exactly one reply mid-line and severs
//!   that socket; other connections keep serving and no slot leaks;
//! * a pinned `admit_stall` delays exactly one admission *outside* the
//!   queue lock, so admissions on other connections flow during the
//!   stall;
//! * a schedule handed in via the `SPARAMX_FAULTS` env var (the CI
//!   server-chaos job) completes every admitted request server-side,
//!   severed replies included.
//!
//! Connection numbers are assigned in handler order, so each test pins
//! conn 1 with a stats handshake (request + full reply) before opening
//! conn 2 — making the fault's target deterministic. Fault state is
//! process-global: every test serializes on one mutex.

use sparamx::cfg::{EngineChoice, Json, RuntimeConfig};
use sparamx::coordinator::batcher::AdmissionQueue;
use sparamx::coordinator::engine::Engine;
use sparamx::coordinator::request::Request;
use sparamx::coordinator::server::{self, ServerCtx};
use sparamx::fault;
use sparamx::models::tinyforward::{LayerW, TinyModel};
use std::io::{BufRead, BufReader, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex, MutexGuard};
use std::time::{Duration, Instant};

static SERIAL: Mutex<()> = Mutex::new(());

fn serial() -> MutexGuard<'static, ()> {
    SERIAL.lock().unwrap_or_else(|e| e.into_inner())
}

fn m(v: &AtomicU64) -> u64 {
    v.load(Ordering::Relaxed)
}

/// Deterministic synthetic tiny model (same family as the build-time
/// checkpoint: 2 layers, GQA, byte-level vocab).
fn toy_model(seed: u64) -> TinyModel {
    let mut g = sparamx::util::XorShift::new(seed);
    let (h, inter, heads, kvh, hd, vocab) = (16, 24, 4, 2, 4, 256);
    let mut mk = |n: usize| g.normal_vec(n, 0.3);
    TinyModel {
        hidden: h,
        inter,
        heads,
        kv_heads: kvh,
        head_dim: hd,
        vocab,
        emb: mk(vocab * h),
        layers: (0..2)
            .map(|_| LayerW {
                ln1: vec![1.0; h],
                wq: mk(h * heads * hd),
                wk: mk(h * kvh * hd),
                wv: mk(h * kvh * hd),
                wo: mk(heads * hd * h),
                ln2: vec![1.0; h],
                wgate: mk(h * inter),
                wup: mk(h * inter),
                wdown: mk(inter * h),
            })
            .collect(),
        ln_f: vec![1.0; h],
        lm_head: mk(h * vocab),
    }
}

fn native_cfg() -> RuntimeConfig {
    RuntimeConfig {
        weight_sparsity: 0.0,
        k_sparsity: 0.0,
        v_sparsity: 0.0,
        max_batch: 4,
        max_new_tokens: 8,
        max_ctx: 64,
        engine: EngineChoice::Auto,
        ..Default::default()
    }
}

/// Build a native engine and spawn its TCP server; the caller runs
/// `engine.run(&queue)` on its own thread while a client drives the
/// socket and closes the queue when done.
fn start(seed: u64) -> (Engine, Arc<AdmissionQueue>, SocketAddr) {
    let engine = Engine::from_tiny_model(toy_model(seed), native_cfg()).expect("engine");
    let queue = Arc::new(AdmissionQueue::new(16));
    let listener = TcpListener::bind("127.0.0.1:0").expect("bind");
    let addr = listener.local_addr().unwrap();
    let ctx = ServerCtx {
        queue: Arc::clone(&queue),
        default_max_tokens: 8,
        metrics: Arc::clone(&engine.metrics),
        engine: engine.describe(),
        predicted_step_s: engine.predicted_step_s(),
    };
    std::thread::spawn(move || server::serve(listener, ctx));
    (engine, queue, addr)
}

/// Fault-free reference texts for `prompts` (engine-only: the server
/// path drives the same decode, so these are the bit-exact oracle).
/// The chaos timelines serialize requests — each decodes solo — so
/// callers pass one prompt per call to keep the batch shape identical.
fn baseline_texts(seed: u64, prompts: &[&str]) -> Vec<String> {
    let mut engine = Engine::from_tiny_model(toy_model(seed), native_cfg()).expect("engine");
    let queue = Arc::new(AdmissionQueue::new(16));
    let mut rxs = Vec::new();
    for (i, p) in prompts.iter().enumerate() {
        let (tx, rx) = mpsc::channel();
        queue
            .admit(Request {
                id: i as u64,
                prompt: p.as_bytes().to_vec(),
                max_new_tokens: 8,
                arrived: Instant::now(),
                respond: tx,
                deadline_ms: None,
                cancel: Arc::new(AtomicBool::new(false)),
            })
            .expect("admit");
        rxs.push(rx);
    }
    queue.close();
    engine.run(&queue).expect("engine drains");
    rxs.into_iter()
        .map(|rx| rx.recv().expect("answered").text())
        .collect()
}

fn send_request(stream: &mut TcpStream, prompt: &str) {
    let line = format!("{{\"prompt\": \"{prompt}\", \"max_new_tokens\": 8}}\n");
    stream.write_all(line.as_bytes()).expect("send request");
}

fn read_reply(reader: &mut BufReader<TcpStream>) -> Json {
    let mut line = String::new();
    reader.read_line(&mut line).expect("read reply");
    Json::parse(line.trim()).expect("reply is valid JSON")
}

/// Pin this connection as the *next* conn number: a full stats
/// round-trip proves its handler (and its numbering) ran before any
/// later connection is opened.
fn handshake(stream: &mut TcpStream, reader: &mut BufReader<TcpStream>) {
    stream.write_all(b"{\"stats\": true}\n").expect("send stats");
    let mut line = String::new();
    reader.read_line(&mut line).expect("stats reply");
    assert!(line.contains("requests_admitted"), "stats handshake: {line}");
}

// ---------------------------------------------------------------------
// slow_client: a crawling connection never stalls its neighbors
// ---------------------------------------------------------------------

#[test]
fn slow_client_never_stalls_co_admitted_requests() {
    let _g = serial();
    fault::clear();
    let base_cat = baseline_texts(81, &["the cat "]).remove(0);
    let base_dog = baseline_texts(81, &["a dog "]).remove(0);
    fault::install("slow_client@conn=2,delay_us=1000000".parse().unwrap());
    let (mut engine, queue, addr) = start(81);
    let q = Arc::clone(&queue);
    let client = std::thread::spawn(move || {
        let mut c1 = TcpStream::connect(addr).expect("connect 1");
        let mut r1 = BufReader::new(c1.try_clone().unwrap());
        handshake(&mut c1, &mut r1); // conn 1 pinned

        // conn 2 crawls: its line is held 1 s before any processing
        let mut c2 = TcpStream::connect(addr).expect("connect 2");
        let mut r2 = BufReader::new(c2.try_clone().unwrap());
        send_request(&mut c2, "a dog ");

        // co-admitted traffic on conn 1 must not wait behind conn 2
        let t0 = Instant::now();
        send_request(&mut c1, "the cat ");
        let v1 = read_reply(&mut r1);
        assert!(
            t0.elapsed() < Duration::from_millis(900),
            "conn 1 stalled behind the crawling conn 2"
        );
        assert_eq!(v1.get("text").unwrap().as_str(), Some(base_cat.as_str()));

        // the slow connection itself still serves — late, not wrong
        let v2 = read_reply(&mut r2);
        assert_eq!(v2.get("text").unwrap().as_str(), Some(base_dog.as_str()));
        q.close();
    });
    engine.run(&queue).expect("engine");
    client.join().expect("client thread");
    assert!(fault::injected_count() >= 1, "the slow-client delay fired");
    assert_eq!(m(&engine.metrics.requests_completed), 2);
    assert_eq!(engine.kv_resident_bytes(), 0);
    fault::clear();
}

// ---------------------------------------------------------------------
// disconnect: one torn reply, bounded damage
// ---------------------------------------------------------------------

#[test]
fn disconnect_tears_one_reply_without_corrupting_neighbors() {
    let _g = serial();
    fault::clear();
    let base = baseline_texts(82, &["the cat "]);
    fault::install("disconnect@conn=2,after_bytes=5".parse().unwrap());
    let (mut engine, queue, addr) = start(82);
    let q = Arc::clone(&queue);
    let client = std::thread::spawn(move || {
        let mut c1 = TcpStream::connect(addr).expect("connect 1");
        let mut r1 = BufReader::new(c1.try_clone().unwrap());
        handshake(&mut c1, &mut r1); // conn 1 pinned

        // conn 2's first reply crosses byte 5 → truncated + severed
        let mut c2 = TcpStream::connect(addr).expect("connect 2");
        let mut r2 = BufReader::new(c2.try_clone().unwrap());
        send_request(&mut c2, "a dog ");
        let mut torn = Vec::new();
        let _ = r2.read_to_end(&mut torn); // EOF after the truncated prefix
        assert!(torn.len() <= 5, "reply must be cut at the byte threshold");
        assert!(!torn.contains(&b'\n'), "the torn reply must not look complete");

        // the neighbor connection keeps serving bit-exact
        send_request(&mut c1, "the cat ");
        let v1 = read_reply(&mut r1);
        assert_eq!(v1.get("text").unwrap().as_str(), Some(base[0].as_str()));
        q.close();
    });
    engine.run(&queue).expect("engine");
    client.join().expect("client thread");
    assert_eq!(fault::injected_count(), 1, "the disconnect fired exactly once");
    // the torn request still completed server-side: damage is bounded
    // to its socket, the slot itself never leaks
    assert_eq!(m(&engine.metrics.requests_completed), 2);
    assert_eq!(engine.kv_resident_bytes(), 0);
    fault::clear();
}

// ---------------------------------------------------------------------
// admit_stall: a stalled admission blocks nobody else
// ---------------------------------------------------------------------

#[test]
fn stalled_admission_does_not_block_other_connections() {
    let _g = serial();
    fault::clear();
    let base_cat = baseline_texts(83, &["the cat "]).remove(0);
    let base_dog = baseline_texts(83, &["a dog "]).remove(0);
    fault::install("admit_stall@request=1,delay_us=800000".parse().unwrap());
    let (mut engine, queue, addr) = start(83);
    let q = Arc::clone(&queue);
    let client = std::thread::spawn(move || {
        // conn A's admission is the first → held 800 ms before the
        // queue lock is taken
        let mut ca = TcpStream::connect(addr).expect("connect a");
        let mut ra = BufReader::new(ca.try_clone().unwrap());
        send_request(&mut ca, "a dog ");
        std::thread::sleep(Duration::from_millis(150)); // reach the stall

        // conn B admits during the stall and completes promptly
        let mut cb = TcpStream::connect(addr).expect("connect b");
        let mut rb = BufReader::new(cb.try_clone().unwrap());
        let t0 = Instant::now();
        send_request(&mut cb, "the cat ");
        let vb = read_reply(&mut rb);
        assert!(
            t0.elapsed() < Duration::from_millis(600),
            "conn B's admission waited behind the stalled one"
        );
        assert_eq!(vb.get("text").unwrap().as_str(), Some(base_cat.as_str()));

        // the stalled admission itself completes — late, not lost
        let va = read_reply(&mut ra);
        assert_eq!(va.get("text").unwrap().as_str(), Some(base_dog.as_str()));
        q.close();
    });
    engine.run(&queue).expect("engine");
    client.join().expect("client thread");
    assert_eq!(fault::injected_count(), 1, "the admission stall fired exactly once");
    assert_eq!(m(&engine.metrics.requests_completed), 2);
    fault::clear();
}

// ---------------------------------------------------------------------
// CI env-var replay
// ---------------------------------------------------------------------

/// Replays whatever schedule the CI server-chaos job pinned in
/// `SPARAMX_FAULTS` (no-op when the var is unset): four sequential
/// connections each submit one request. Every request must run to
/// completion server-side — a pinned disconnect may tear its *reply*,
/// but never stalls or corrupts the others, and no slot leaks KV.
#[test]
fn env_pinned_server_schedule_completes_every_admitted_request() {
    let _g = serial();
    fault::clear();
    let armed = fault::install_str_or_env("").expect("SPARAMX_FAULTS must parse");
    if !armed {
        return; // not a chaos job
    }
    let (mut engine, queue, addr) = start(84);
    let q = Arc::clone(&queue);
    let client = std::thread::spawn(move || {
        let mut full_replies = 0;
        for i in 0..4 {
            let mut c = TcpStream::connect(addr).expect("connect");
            let mut r = BufReader::new(c.try_clone().unwrap());
            send_request(&mut c, &format!("prompt {i} "));
            let mut line = String::new();
            let _ = r.read_line(&mut line);
            if line.ends_with('\n') {
                let v = Json::parse(line.trim()).expect("full replies are valid JSON");
                assert_eq!(
                    v.get("tokens").and_then(|t| t.as_usize()),
                    Some(8),
                    "request {i} lost tokens under chaos: {line}"
                );
                full_replies += 1;
            }
            // else: a pinned disconnect tore this reply mid-line —
            // bounded damage, verified server-side below
        }
        q.close();
        full_replies
    });
    engine.run(&queue).expect("engine");
    let full_replies = client.join().expect("client thread");
    assert_eq!(
        m(&engine.metrics.requests_completed),
        4,
        "every admitted request must complete server-side"
    );
    assert_eq!(engine.kv_resident_bytes(), 0, "no slot may leak KV under chaos");
    assert!(full_replies >= 1, "at least one connection sees a full reply");
    fault::clear();
}
