//! Backend parity matrix: every [`LinearBackend`] implementation must
//! agree (within BF16/INT8 rounding) on random (shape, sparsity, dtype)
//! combinations, and the registry must fall back / cross over exactly
//! as the cost model predicts.

use sparamx::amx::kernels::{DenseWeights, GemmCounters};
use sparamx::backend::{
    Backend, BackendChoice, BackendKind, BackendRegistry, CpuCaps, Dtype, GemmShape,
};
use sparamx::perf::cost::{dense_gemm_cost, sparse_gemm_cost};
use sparamx::sparse::format::SparseTensor;
use sparamx::sparse::prune::magnitude_prune;
use sparamx::util::XorShift;

fn backends() -> Vec<Backend> {
    vec![Backend::amx(), Backend::avx(), Backend::reference()]
}

#[test]
fn bf16_backends_agree_across_shape_sparsity_matrix() {
    let mut g = XorShift::new(2001);
    for case in 0..14 {
        let batch = 1 + g.below(6);
        let rows = 1 + g.below(110);
        let cols = 1 + g.below(90);
        let sparsity = g.next_f64();
        let w = magnitude_prune(&g.normal_vec(rows * cols, 1.0), sparsity);
        let x = g.normal_vec(batch * rows, 1.0);
        let sp = SparseTensor::pack_f32(&w, rows, cols);
        let dw = DenseWeights::pack_f32(&w, rows, cols);
        let tol = 0.03 * (rows as f32).sqrt().max(1.0);

        // reference output from the ref backend's sparse entry point
        let mut rctr = GemmCounters::default();
        let want = Backend::reference().sparse_gemm_bf16(&x, batch, &sp, &mut rctr);

        for b in backends() {
            let mut c1 = GemmCounters::default();
            let got_sparse = b.sparse_gemm_bf16(&x, batch, &sp, &mut c1);
            let mut c2 = GemmCounters::default();
            let got_dense = b.gemm_bf16(&x, batch, &dw, &mut c2);
            assert_eq!(got_sparse.len(), want.len());
            assert_eq!(got_dense.len(), want.len());
            for i in 0..want.len() {
                assert!(
                    (got_sparse[i] - want[i]).abs() <= tol + want[i].abs() * 0.03,
                    "case {case} {} sparse idx {i}: {} vs {}",
                    b.name(),
                    got_sparse[i],
                    want[i]
                );
                assert!(
                    (got_dense[i] - want[i]).abs() <= tol + want[i].abs() * 0.03,
                    "case {case} {} dense idx {i}: {} vs {}",
                    b.name(),
                    got_dense[i],
                    want[i]
                );
            }
        }
    }
}

#[test]
fn int8_backends_agree_exactly() {
    let mut g = XorShift::new(2002);
    for _case in 0..8 {
        let batch = 1 + g.below(4);
        let rows = 1 + g.below(100);
        let cols = 1 + g.below(60);
        let sparsity = g.next_f64() * 0.8;
        let w: Vec<i8> = (0..rows * cols)
            .map(|_| {
                if g.next_f64() < sparsity {
                    0
                } else {
                    (g.below(200) as i32 - 100) as i8
                }
            })
            .collect();
        let x: Vec<i8> = (0..batch * rows).map(|_| (g.below(200) as i32 - 100) as i8).collect();
        let sp: SparseTensor<i8> = SparseTensor::pack(&w, rows, cols);
        let dw: DenseWeights<i8> = DenseWeights::pack(&w, rows, cols);

        let mut rctr = GemmCounters::default();
        let want = Backend::reference().sparse_gemm_int8(&x, batch, &sp, &mut rctr);
        for b in backends() {
            let mut c1 = GemmCounters::default();
            assert_eq!(b.sparse_gemm_int8(&x, batch, &sp, &mut c1), want, "{} sparse", b.name());
            let mut c2 = GemmCounters::default();
            assert_eq!(b.gemm_int8(&x, batch, &dw, &mut c2), want, "{} dense", b.name());
        }
    }
}

#[test]
fn registry_falls_back_to_ref_without_amx_or_avx() {
    let reg = BackendRegistry::with_caps(CpuCaps::none());
    let sel = reg.select(GemmShape::new(1, 4096, 14336), 0.5, Dtype::Bf16);
    assert_eq!(sel.backend.kind(), BackendKind::Reference);
    // and the pinned directives still resolve
    for choice in [BackendChoice::Amx, BackendChoice::Avx, BackendChoice::Reference] {
        let pinned = reg.resolve(choice, GemmShape::new(1, 256, 256), 0.5, Dtype::Bf16);
        assert_eq!(format!("{choice}") == "ref", pinned.backend.kind() == BackendKind::Reference);
    }
}

#[test]
fn selection_reproduces_cost_model_crossover() {
    // The paper's Table 2 / §7 story end-to-end: batch-1 decode of the
    // Llama 3 8B up_proj goes sparse; batch-256 (compute-bound) goes
    // dense — and the predicted times are exactly the cost model's.
    let reg = BackendRegistry::with_caps(CpuCaps::from_list("amx"));
    let m = reg.machine();

    let decode = reg.select(GemmShape::new(1, 4096, 14336), 0.5, Dtype::Bf16);
    assert_eq!(decode.backend.kind(), BackendKind::Amx);
    assert!(decode.use_sparse);
    let sparse_cost = sparse_gemm_cost(1, 4096, 14336, 0.5, m).time;
    let dense_cost = dense_gemm_cost(1, 4096, 14336, m).time;
    assert!((decode.predicted_s - sparse_cost).abs() < 1e-12);
    assert!(sparse_cost < dense_cost, "crossover premise");

    let batched = reg.select(GemmShape::new(256, 4096, 4096), 0.5, Dtype::Bf16);
    assert!(!batched.use_sparse);
    let dense256 = dense_gemm_cost(256, 4096, 4096, m).time;
    assert!((batched.predicted_s - dense256).abs() < 1e-12);
}

#[test]
fn executed_counters_match_selected_plan_prediction_inputs() {
    // select() says "sparse on AMX"; running that plan must actually
    // stream fewer weight bytes than the dense plan it beat.
    let mut g = XorShift::new(2003);
    let (rows, cols) = (256usize, 128usize);
    let w = magnitude_prune(&g.normal_vec(rows * cols, 1.0), 0.7);
    let x = g.normal_vec(rows, 1.0);
    let reg = BackendRegistry::with_caps(CpuCaps::from_list("amx"));
    let sel = reg.select(GemmShape::new(1, rows, cols), 0.7, Dtype::Bf16);
    assert!(sel.use_sparse);

    let sp = SparseTensor::pack_f32(&w, rows, cols);
    let dw = DenseWeights::pack_f32(&w, rows, cols);
    let mut cs = GemmCounters::default();
    sel.backend.sparse_gemm_bf16(&x, 1, &sp, &mut cs);
    let mut cd = GemmCounters::default();
    sel.backend.gemm_bf16(&x, 1, &dw, &mut cd);
    assert!(
        cs.weight_stream_bytes < cd.weight_stream_bytes,
        "selected sparse plan must move fewer weight bytes"
    );
}
