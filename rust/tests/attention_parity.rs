//! Fused-vs-looped attention parity: `attend_sparse_batched` gathers
//! all query rows sharing one (slot, KV head) cache into a single pair
//! of batched GEMMs — QKᵀ over the static segment, then R·V — and must
//! be **bit-exact** against looping `attend_sparse` row by row, for
//! every backend (including the sharded wrapper at shards {1, 4}),
//! every slot count, MHA and GQA head layouts, and every static/dynamic
//! tail split of the cache.
//!
//! The fused call is a pure streaming transform: each static K/V
//! segment's packed weights are streamed once per step for the whole
//! query group instead of once per row. A counter test pins that
//! invariant (`weight_stream_bytes` fused == batch-1, looped == n_q ×),
//! and a model-level regression pins that the fused attention path
//! never re-runs backend regime selection inside the token loop.

use sparamx::amx::EventCounters;
use sparamx::backend::{Backend, BackendChoice, BackendRegistry, CpuCaps};
use sparamx::kvcache::attention::{attend_sparse, attend_sparse_batched, AttentionScratch};
use sparamx::kvcache::cache::{HeadCache, KvCache};
use sparamx::models::plan::{NativeModel, RegimeBatches};
use sparamx::models::tinyforward::{LayerW, TinyModel};
use sparamx::shard::{NumaTopology, WorkerPool};
use sparamx::util::XorShift;
use std::sync::Arc;

fn sharded_over(inner: Backend, shards: usize) -> Backend {
    let topo = NumaTopology::modeled(2, 8);
    let pool = Arc::new(WorkerPool::with_topology(shards, &topo));
    Backend::sharded(inner, shards, topo, pool)
}

/// Every backend the matrix sweeps: the three plain implementations
/// plus the sharded wrapper at shards {1, 4}.
fn backends() -> Vec<Backend> {
    vec![
        Backend::amx(),
        Backend::avx(),
        Backend::reference(),
        sharded_over(Backend::reference(), 1),
        sharded_over(Backend::reference(), 4),
        sharded_over(Backend::amx(), 4),
    ]
}

/// One (slot, KV head) cache: `ctx` prefill tokens split into the
/// sparse static segment, then `tail` dynamically appended rows.
fn head_cache(g: &mut XorShift, ctx: usize, tail: usize, hd: usize) -> HeadCache {
    let k = g.normal_vec(ctx * hd, 1.0);
    let v = g.normal_vec(ctx * hd, 1.0);
    let mut hc = HeadCache::from_prefill(&k, &v, ctx, hd, 0.4, 0.4);
    for _ in 0..tail {
        let kr = g.normal_vec(hd, 1.0);
        let vr = g.normal_vec(hd, 1.0);
        hc.append(&kr, &vr);
    }
    hc
}

/// Fused call over one (slot, KV head) group vs looping
/// `attend_sparse` over its rows — must match bitwise, row by row.
fn check_group(
    backend: &Backend,
    hc: &HeadCache,
    qb: &[f32],
    group: usize,
    hd: usize,
    scratch: &mut AttentionScratch,
    tag: &str,
) {
    let mut fused = vec![0f32; group * hd];
    let mut cf = EventCounters::default();
    attend_sparse_batched(hc, qb, group, backend, scratch, &mut fused, &mut cf);
    for r in 0..group {
        let row = &qb[r * hd..(r + 1) * hd];
        let mut cl = EventCounters::default();
        let want = attend_sparse(hc, row, backend, &mut cl);
        let got = &fused[r * hd..(r + 1) * hd];
        assert_eq!(got, &want[..], "{tag} row {r} diverged");
    }
}

#[test]
fn fused_attention_bit_exact_across_backends_slots_gqa_and_splits() {
    let hd = 16usize;
    // (heads, kv_heads): MHA single head, GQA-degenerate group 4, and
    // the GQA shape the native model fuses (group 2).
    let head_layouts = [(1usize, 1usize), (4, 1), (4, 2)];
    // (static ctx, dynamic tail): static-only, static + tail, tail-only.
    let splits = [(24usize, 0usize), (24, 3), (0, 3)];
    for backend in backends() {
        for &slots in &[1usize, 2, 3, 8] {
            for &(heads, kvh) in &head_layouts {
                let group = heads / kvh;
                for &(ctx, tail) in &splits {
                    let seed = (slots * 1000 + heads * 100 + kvh * 10 + ctx + tail) as u64;
                    let mut g = XorShift::new(8100 + seed);
                    // one scratch shared across every group in the
                    // step, as the decode loop reuses it per layer
                    let mut scratch = AttentionScratch::default();
                    for s in 0..slots {
                        // slot-varying lengths: no two slots share a shape
                        let (sctx, stail) = if ctx > 0 {
                            (ctx + s, tail)
                        } else {
                            (0, tail + s)
                        };
                        let q = g.normal_vec(heads * hd, 1.0);
                        for h in 0..kvh {
                            let hc = head_cache(&mut g, sctx, stail, hd);
                            let qb = &q[h * group * hd..(h + 1) * group * hd];
                            let tag = format!(
                                "{} slots={slots} heads={heads}/{kvh} ctx={sctx} tail={stail} slot={s} kv_head={h}",
                                backend.name()
                            );
                            check_group(&backend, &hc, qb, group, hd, &mut scratch, &tag);
                        }
                    }
                }
            }
        }
    }
}

#[test]
fn fused_path_streams_each_static_segment_once_per_step() {
    // The whole point of the fused path: the static K/V segment's packed
    // weights stream once for the entire query group. `weight_stream_bytes`
    // for the fused call must equal a single batch-1 call, while looping
    // the batch-1 entry pays the stream once per row.
    let mut g = XorShift::new(8200);
    let (ctx, hd, n_q) = (32usize, 16usize, 4usize);
    let hc = head_cache(&mut g, ctx, 1, hd);
    let qb = g.normal_vec(n_q * hd, 1.0);
    let backend = Backend::amx();

    let mut c1 = EventCounters::default();
    let _ = attend_sparse(&hc, &qb[..hd], &backend, &mut c1);
    assert!(c1.weight_stream_bytes > 0, "AMX path must stream K/V tiles");

    let mut cl = EventCounters::default();
    for r in 0..n_q {
        let _ = attend_sparse(&hc, &qb[r * hd..(r + 1) * hd], &backend, &mut cl);
    }

    let mut scratch = AttentionScratch::default();
    let mut fused = vec![0f32; n_q * hd];
    let mut cf = EventCounters::default();
    attend_sparse_batched(&hc, &qb, n_q, &backend, &mut scratch, &mut fused, &mut cf);

    assert_eq!(
        cf.weight_stream_bytes,
        c1.weight_stream_bytes,
        "fused step must stream each static K/V segment exactly once"
    );
    assert_eq!(
        cl.weight_stream_bytes,
        n_q as u64 * c1.weight_stream_bytes,
        "looped path pays the K/V stream once per query row"
    );
}

fn toy_model(seed: u64) -> TinyModel {
    let mut g = XorShift::new(seed);
    let (h, inter, heads, kvh, hd, vocab) = (16, 24, 4, 2, 4, 256);
    let mut mk = |n: usize| g.normal_vec(n, 0.3);
    TinyModel {
        hidden: h,
        inter,
        heads,
        kv_heads: kvh,
        head_dim: hd,
        vocab,
        emb: mk(vocab * h),
        layers: (0..2)
            .map(|_| LayerW {
                ln1: vec![1.0; h],
                wq: mk(h * heads * hd),
                wk: mk(h * kvh * hd),
                wv: mk(h * kvh * hd),
                wo: mk(heads * hd * h),
                ln2: vec![1.0; h],
                wgate: mk(h * inter),
                wup: mk(h * inter),
                wdown: mk(inter * h),
            })
            .collect(),
        ln_f: vec![1.0; h],
        lm_head: mk(h * vocab),
    }
}

fn prefill_slots(nm: &NativeModel, prompts: &[&[u8]]) -> Vec<KvCache> {
    let mut ctr = EventCounters::default();
    prompts
        .iter()
        .map(|p| nm.prefill(p, 0.0, 0.0, &mut ctr))
        .collect()
}

#[test]
fn fused_gqa_decode_matches_per_slot_looped_decode() {
    // Model-level parity: the batched GQA decode (fused attention per
    // (slot, KV head) group) against running each slot through the
    // single-slot decode path. decode_fused pinned to 1 so both sides
    // compile the same projection regime — attention fusion is then the
    // only difference, and it must be bit-exact over multiple steps.
    let reg = BackendRegistry::with_caps(CpuCaps::all());
    let nm = NativeModel::with_regimes(
        &reg,
        BackendChoice::Auto,
        toy_model(8300),
        0.0,
        RegimeBatches {
            decode_fused: 1,
            prefill: 8,
        },
    );
    let prompts: [&[u8]; 3] = [&[1, 2, 3], &[9, 8], &[5, 5, 5, 5]];
    let mut batched_caches = prefill_slots(&nm, &prompts);
    let mut looped_caches = batched_caches.clone();
    let mut tokens = [7u8, 11, 13];
    let mut positions: Vec<usize> = prompts.iter().map(|p| p.len()).collect();
    for step in 0..6 {
        let mut ctr = EventCounters::default();
        let mut refs: Vec<&mut KvCache> = batched_caches.iter_mut().collect();
        let fused = nm.decode_step_batched(&tokens, &positions, &mut refs, &mut ctr);
        for (b, cache) in looped_caches.iter_mut().enumerate() {
            let mut cl = EventCounters::default();
            let want = nm.decode_step(tokens[b], positions[b], cache, &mut cl);
            assert_eq!(
                fused[b],
                want,
                "step {step} slot {b}: fused GQA attention diverged from looped decode"
            );
        }
        for (b, row) in fused.iter().enumerate() {
            let mut best = 0;
            for (i, &v) in row.iter().enumerate() {
                if v > row[best] {
                    best = i;
                }
            }
            tokens[b] = best as u8;
            positions[b] += 1;
        }
    }
}

#[test]
fn pool_scattered_fused_attention_matches_sequential() {
    // Scattering independent (slot, KV head) groups across the worker
    // pool must be invisible: same outputs, in order, as the sequential
    // fused loop. Attention shards by head group, never by k.
    let reg = BackendRegistry::with_caps(CpuCaps::all());
    let batches = RegimeBatches {
        decode_fused: 4,
        prefill: 8,
    };
    let seq = NativeModel::with_regimes(&reg, BackendChoice::Auto, toy_model(8400), 0.0, batches);
    let mut par =
        NativeModel::with_regimes(&reg, BackendChoice::Auto, toy_model(8400), 0.0, batches);
    let topo = NumaTopology::modeled(2, 8);
    par.set_attention_pool(Some(Arc::new(WorkerPool::with_topology(4, &topo))));
    let prompts: [&[u8]; 3] = [&[1, 2, 3], &[9, 8], &[5, 5, 5, 5]];
    let mut seq_caches = prefill_slots(&seq, &prompts);
    let mut par_caches = seq_caches.clone();
    let mut tokens = [7u8, 11, 13];
    let mut positions: Vec<usize> = prompts.iter().map(|p| p.len()).collect();
    for step in 0..4 {
        let mut cs = EventCounters::default();
        let mut refs: Vec<&mut KvCache> = seq_caches.iter_mut().collect();
        let a = seq.decode_step_batched(&tokens, &positions, &mut refs, &mut cs);
        let mut cp = EventCounters::default();
        let mut refs: Vec<&mut KvCache> = par_caches.iter_mut().collect();
        let b = par.decode_step_batched(&tokens, &positions, &mut refs, &mut cp);
        assert_eq!(a, b, "step {step}: pool-scattered attention diverged");
        for (s, row) in a.iter().enumerate() {
            let mut best = 0;
            for (i, &v) in row.iter().enumerate() {
                if v > row[best] {
                    best = i;
                }
            }
            tokens[s] = best as u8;
            positions[s] += 1;
        }
    }
}

#[test]
fn fused_attention_token_loop_never_reruns_regime_selection() {
    // Backend selection resolves at plan compile; the fused attention
    // path (including the pool scatter) must never consult the registry
    // inside the token loop.
    let reg = BackendRegistry::with_caps(CpuCaps::all());
    let mut nm = NativeModel::with_regimes(
        &reg,
        BackendChoice::Auto,
        toy_model(8500),
        0.0,
        RegimeBatches {
            decode_fused: 4,
            prefill: 16,
        },
    );
    let topo = NumaTopology::modeled(2, 8);
    nm.set_attention_pool(Some(Arc::new(WorkerPool::with_topology(4, &topo))));
    let at_load = reg.selections_resolved();
    assert!(at_load > 0, "compile must consult the registry");
    let prompts: [&[u8]; 3] = [&[1, 2, 3], &[9, 8], &[5, 5, 5, 5]];
    let mut caches = prefill_slots(&nm, &prompts);
    let mut tokens = [7u8, 11, 13];
    let mut positions: Vec<usize> = prompts.iter().map(|p| p.len()).collect();
    for _step in 0..8 {
        let mut ctr = EventCounters::default();
        let mut refs: Vec<&mut KvCache> = caches.iter_mut().collect();
        let logits = nm.decode_step_batched(&tokens, &positions, &mut refs, &mut ctr);
        for (b, row) in logits.iter().enumerate() {
            let mut best = 0;
            for (i, &v) in row.iter().enumerate() {
                if v > row[best] {
                    best = i;
                }
            }
            tokens[b] = best as u8;
            positions[b] += 1;
        }
    }
    assert_eq!(
        reg.selections_resolved(),
        at_load,
        "fused attention token loop re-ran selection"
    );
}
