//! Fig 13: INT8 decoding throughput vs batch — our AMX INT8 dense and
//! sparse kernels vs DeepSparse-like and llama.cpp-like baselines
//! (Llama 2 7B, ctx 2, 32 cores, 50% sparsity). Paper: ours wins at
//! high batch (up to 1.46×); DeepSparse competitive at low batch.

use sparamx::baselines::systems::{decode_step_cost, Baseline, Precision};
use sparamx::bench::harness::{report_header, report_row};
use sparamx::models::ModelConfig;
use sparamx::perf::Machine;

fn main() {
    let m = Machine::sapphire_rapids(32);
    let cfg = ModelConfig::llama2_7b();
    report_header(
        "Fig 13 — INT8 decode throughput (tokens/s) vs batch (Llama 2 7B, ctx 2)",
        &["batch", "AMX dense", "AMX sparse", "DeepSparse", "llama.cpp", "ours/DS"],
    );
    for batch in [1usize, 2, 4, 8, 16, 32] {
        let thr = |b: Baseline, s: f64| {
            batch as f64 / decode_step_cost(&cfg, b, Precision::Int8, batch, 2, s, &m)
        };
        let amx_d = thr(Baseline::SparAmxDense, 0.0);
        let amx_s = thr(Baseline::SparAmxSparse, 0.5);
        let ds = thr(Baseline::DeepSparse, 0.5);
        let lcpp = thr(Baseline::LlamaCpp, 0.0);
        report_row(&[
            format!("{batch}"),
            format!("{amx_d:.1}"),
            format!("{amx_s:.1}"),
            format!("{ds:.1}"),
            format!("{lcpp:.1}"),
            format!("{:.2}x", amx_s / ds),
        ]);
    }
    println!("\npaper shape: AMX overtakes DeepSparse/llama.cpp as batch grows");
}
