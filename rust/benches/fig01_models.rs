//! Fig 1: end-to-end decode speedup over stock PyTorch across Llama
//! model sizes (ctx 512, batch 1, 32 cores, 50% sparsity).
//! Paper shape: speedup > 1 everywhere, growing with model size, ≈1.42×
//! for Llama 3 8B.

use sparamx::baselines::systems::{decode_step_cost, Baseline, Precision};
use sparamx::bench::harness::{report_header, report_row};
use sparamx::models::ModelConfig;
use sparamx::perf::Machine;

fn main() {
    let m = Machine::sapphire_rapids(32);
    report_header(
        "Fig 1 — decode speedup vs stock PyTorch (ctx 512, batch 1, 50% sparse, 32 cores)",
        &["model", "pytorch ms/tok", "sparamx ms/tok", "speedup"],
    );
    for cfg in [
        ModelConfig::llama32_1b(),
        ModelConfig::llama32_3b(),
        ModelConfig::llama2_7b(),
        ModelConfig::llama3_8b(),
    ] {
        let py = decode_step_cost(&cfg, Baseline::PyTorch, Precision::Bf16, 1, 512, 0.0, &m);
        let ours =
            decode_step_cost(&cfg, Baseline::SparAmxSparse, Precision::Bf16, 1, 512, 0.5, &m);
        report_row(&[
            cfg.name.clone(),
            format!("{:.2}", py * 1e3),
            format!("{:.2}", ours * 1e3),
            format!("{:.2}x", py / ours),
        ]);
    }
    println!("\npaper: speedup grows with model size, 1.42x at Llama 3 8B");
}
