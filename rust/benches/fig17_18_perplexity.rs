//! Figs 17 & 18: perplexity vs KV sparsity, BF16 (Fig 17) and with
//! INT8-quantized KV (Fig 18). Tiny trained checkpoint (WikiText2
//! substitution, DESIGN.md §2). Paper: ppl 6.136 → 6.745 at 30% K /
//! 50% V; INT8 KV adds < 1 ppl.

use sparamx::bench::harness::{report_header, report_row};
use sparamx::models::tinyforward::{KvTreatment, TinyModel};
use sparamx::runtime::artifact::Bundle;

fn main() {
    let Ok(bundle) = Bundle::load("artifacts") else {
        println!("fig17/18: artifacts/ not built — run `make artifacts`");
        return;
    };
    let model = TinyModel::from_bundle(&bundle).expect("model");
    let limit = bundle.eval_tokens.len().min(1280);
    let eval = &bundle.eval_tokens[..limit];
    for int8 in [false, true] {
        report_header(
            &format!(
                "Fig {} — perplexity vs KV sparsity ({})",
                if int8 { 18 } else { 17 },
                if int8 { "INT8 KV" } else { "BF16 KV" }
            ),
            &["K sparsity", "V sparsity", "ppl", "Δppl vs dense"],
        );
        let base = model.evaluate(eval, 128, KvTreatment { int8, ..Default::default() });
        for (ks, vs) in [
            (0.0, 0.0),
            (0.1, 0.3),
            (0.3, 0.3),
            (0.3, 0.5),
            (0.5, 0.5),
            (0.5, 0.7),
            (0.7, 0.7),
        ] {
            let r = model.evaluate(
                eval,
                128,
                KvTreatment {
                    k_sparsity: ks,
                    v_sparsity: vs,
                    int8,
                },
            );
            report_row(&[
                format!("{:.0}%", ks * 100.0),
                format!("{:.0}%", vs * 100.0),
                format!("{:.3}", r.ppl),
                format!("{:+.3}", r.ppl - base.ppl),
            ]);
        }
    }
    println!("\npaper shape: ppl rises gently to 30/50, then accelerates; INT8 adds <1");
}
