//! Fig 14: downstream accuracy vs K/V cache sparsity (tiny trained
//! checkpoint; DESIGN.md §2 substitution for the PIQA/ARC/BoolQ/
//! HellaSwag/WinoGrande geomean). Paper: <1% drop at 30% K / 50% V.

use sparamx::bench::harness::{report_header, report_row};
use sparamx::models::tinyforward::{KvTreatment, TinyModel};
use sparamx::runtime::artifact::Bundle;

fn main() {
    let Ok(bundle) = Bundle::load("artifacts") else {
        println!("fig14: artifacts/ not built — run `make artifacts`");
        return;
    };
    let model = TinyModel::from_bundle(&bundle).expect("model");
    let limit = bundle.eval_tokens.len().min(1280);
    let eval = &bundle.eval_tokens[..limit];
    report_header(
        "Fig 14 — tiny-LM next-byte accuracy vs KV sparsity",
        &["K sparsity", "V sparsity", "top1 acc", "acc drop %"],
    );
    let base = model.evaluate(eval, 128, KvTreatment::default());
    for (ks, vs) in [
        (0.0, 0.0),
        (0.1, 0.1),
        (0.3, 0.3),
        (0.3, 0.5),
        (0.5, 0.5),
        (0.7, 0.7),
        (0.9, 0.9),
    ] {
        let r = model.evaluate(
            eval,
            128,
            KvTreatment {
                k_sparsity: ks,
                v_sparsity: vs,
                int8: false,
            },
        );
        report_row(&[
            format!("{:.0}%", ks * 100.0),
            format!("{:.0}%", vs * 100.0),
            format!("{:.3}", r.top1),
            format!("{:+.2}", 100.0 * (base.top1 - r.top1)),
        ]);
    }
    println!("\npaper shape: <1% drop at 30% K / 50% V; collapse at extreme sparsity");
}
