//! Fig 12: batched decoding throughput (tokens/s) vs batch size —
//! stock PyTorch, AMX dense, AMX sparse, relative to the AVX sparse
//! kernel. Paper: AMX pulls ahead at high batch; 20.8% over PyTorch at
//! batch 32.

use sparamx::baselines::systems::{decode_step_cost, Baseline, Precision};
use sparamx::bench::harness::{report_header, report_row};
use sparamx::models::ModelConfig;
use sparamx::perf::Machine;

fn main() {
    let m = Machine::sapphire_rapids(32);
    let cfg = ModelConfig::llama3_8b();
    report_header(
        "Fig 12 — decode throughput (tokens/s) vs batch (ctx 512, 50% sparse, 32 cores)",
        &["batch", "pytorch", "AMX dense", "AMX sparse", "AVX sparse", "AMXsparse/AVX"],
    );
    for batch in [1usize, 2, 4, 8, 16, 32] {
        let thr = |b: Baseline, s: f64| {
            batch as f64
                / decode_step_cost(&cfg, b, Precision::Bf16, batch, 512, s, &m)
        };
        let py = thr(Baseline::PyTorch, 0.0);
        let amx_d = thr(Baseline::SparAmxDense, 0.0);
        let amx_s = thr(Baseline::SparAmxSparse, 0.5);
        let avx_s = thr(Baseline::SparAvxSparse, 0.5);
        report_row(&[
            format!("{batch}"),
            format!("{py:.1}"),
            format!("{amx_d:.1}"),
            format!("{amx_s:.1}"),
            format!("{avx_s:.1}"),
            format!("{:.2}x", amx_s / avx_s),
        ]);
    }
    println!("\npaper shape: AMX kernels widen their lead over AVX as batch grows");
}
