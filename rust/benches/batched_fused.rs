//! Batched decode smoke: wall-clock of the real fused batched kernels
//! (`*_gemm_bf16_batched` — one weight stream for the whole activation
//! block) against looping the batch-1 entry point row by row, at the
//! batch sizes the engine actually fuses. Also prints the cost model's
//! predicted fused-over-looped speedup for the same shape so the
//! functional numbers and the analytical ones sit side by side.

use sparamx::amx::kernels::{DenseWeights, GemmCounters};
use sparamx::backend::Backend;
use sparamx::bench::harness::{bench, fmt_time, report_header, report_row};
use sparamx::perf::cost::fused_sparse_speedup;
use sparamx::perf::Machine;
use sparamx::sparse::format::SparseTensor;
use sparamx::sparse::prune::magnitude_prune;
use sparamx::util::XorShift;

fn main() {
    let mut g = XorShift::new(12);
    let (k, n) = (1024usize, 1024usize);
    let w = magnitude_prune(&g.normal_vec(k * n, 1.0), 0.5);
    let sp = SparseTensor::pack_f32(&w, k, n);
    let dw = DenseWeights::pack_f32(&w, k, n);
    let m = Machine::sapphire_rapids(32);

    report_header(
        "Batched decode — fused one-call GEMM vs looped batch-1 (1024x1024, 50% sparse)",
        &["backend", "batch", "looped", "fused", "wall x", "model x"],
    );

    for backend in [Backend::amx(), Backend::avx()] {
        let x16 = g.normal_vec(16 * k, 1.0);
        for batch in [1usize, 4, 16] {
            let x = &x16[..batch * k];
            let looped = bench("looped", 2, 12, || {
                let mut ctr = GemmCounters::default();
                for b in 0..batch {
                    std::hint::black_box(backend.sparse_gemm_bf16(
                        &x[b * k..(b + 1) * k],
                        1,
                        &sp,
                        &mut ctr,
                    ));
                }
            });
            let fused = bench("fused", 2, 12, || {
                let mut ctr = GemmCounters::default();
                std::hint::black_box(backend.sparse_gemm_bf16_batched(x, batch, &sp, &mut ctr));
            });
            report_row(&[
                backend.name().into(),
                format!("{batch}"),
                fmt_time(looped.mean_s()),
                fmt_time(fused.mean_s()),
                format!("{:.2}x", looped.mean_s() / fused.mean_s()),
                format!("{:.2}x", fused_sparse_speedup(batch, k, n, 0.5, &m)),
            ]);
        }
    }

    // dense path sanity at the largest fused batch: the dense batched
    // kernel must also amortize its (uncompressed) weight stream
    let x16 = g.normal_vec(16 * k, 1.0);
    let looped = bench("dense-looped", 2, 12, || {
        let mut ctr = GemmCounters::default();
        for b in 0..16 {
            let row = &x16[b * k..(b + 1) * k];
            std::hint::black_box(Backend::amx().gemm_bf16(row, 1, &dw, &mut ctr));
        }
    });
    let fused = bench("dense-fused", 2, 12, || {
        let mut ctr = GemmCounters::default();
        std::hint::black_box(Backend::amx().gemm_bf16_batched(&x16, 16, &dw, &mut ctr));
    });
    report_row(&[
        "amx dense".into(),
        "16".into(),
        fmt_time(looped.mean_s()),
        fmt_time(fused.mean_s()),
        format!("{:.2}x", looped.mean_s() / fused.mean_s()),
        "-".into(),
    ]);

    println!("\npaper shape: one fused call streams the compressed weights once per");
    println!("step instead of once per active slot, so wall and modeled speedup");
    println!("both grow with batch until the kernel turns compute-bound");
}
