//! Table 2: per-projection sparse-vs-dense speedup for Llama 3 8B layer
//! linears (batch 1, 50% sparsity). Paper: 1.22× (up_proj) … 2.03×
//! (k_proj).

use sparamx::bench::harness::{report_header, report_row};
use sparamx::models::ModelConfig;
use sparamx::perf::cost::{dense_gemm_cost, sparse_gemm_cost};
use sparamx::perf::Machine;

fn main() {
    let m = Machine::sapphire_rapids(32);
    let cfg = ModelConfig::llama3_8b();
    let paper: &[(&str, f64)] = &[
        ("q_proj", 1.44),
        ("k_proj", 2.03),
        ("v_proj", 1.41),
        ("o_proj", 1.30),
        ("gate_proj", 1.26),
        ("up_proj", 1.22),
        ("down_proj", 1.36),
    ];
    report_header(
        "Table 2 — per-projection speedup, Llama 3 8B layer 5 (50% sparse, batch 1)",
        &["name", "dims", "modeled speedup", "paper speedup"],
    );
    for lin in cfg.layer_linears() {
        let d = dense_gemm_cost(1, lin.in_features, lin.out_features, &m);
        let s = sparse_gemm_cost(1, lin.in_features, lin.out_features, 0.5, &m);
        let paper_x = paper
            .iter()
            .find(|(n, _)| *n == lin.name)
            .map(|(_, x)| *x)
            .unwrap_or(f64::NAN);
        report_row(&[
            lin.name.to_string(),
            format!("{}x{}", lin.in_features, lin.out_features),
            format!("{:.2}x", d.time / s.time),
            format!("{paper_x:.2}x"),
        ]);
    }
    println!("\npaper shape: every projection speeds up; k/v (smallest) most");
}
