//! Fig 11: Llama 3 8B decode speedup over stock PyTorch vs sparsity,
//! for 8/16/32 cores, AVX and AMX sparse kernels (ctx 512).
//! Paper shape: speedup grows with sparsity; AMX–AVX gap narrows as
//! cores increase.

use sparamx::baselines::systems::{decode_step_cost, Baseline, Precision};
use sparamx::bench::harness::{report_header, report_row};
use sparamx::models::ModelConfig;
use sparamx::perf::Machine;

fn main() {
    let cfg = ModelConfig::llama3_8b();
    for cores in [8usize, 16, 32] {
        let m = Machine::sapphire_rapids(cores);
        let py = decode_step_cost(&cfg, Baseline::PyTorch, Precision::Bf16, 1, 512, 0.0, &m);
        report_header(
            &format!("Fig 11 — speedup vs sparsity (cores = {cores})"),
            &["sparsity", "AMX sparse", "AVX sparse"],
        );
        for s in [0.0, 0.2, 0.4, 0.6, 0.8, 0.9] {
            let amx =
                decode_step_cost(&cfg, Baseline::SparAmxSparse, Precision::Bf16, 1, 512, s, &m);
            let avx =
                decode_step_cost(&cfg, Baseline::SparAvxSparse, Precision::Bf16, 1, 512, s, &m);
            report_row(&[
                format!("{:.0}%", s * 100.0),
                format!("{:.2}x", py / amx),
                format!("{:.2}x", py / avx),
            ]);
        }
    }
    println!("\npaper shape: monotone in sparsity; AMX/AVX gap shrinks with cores");
}
