//! Fig 10: end-to-end speedup vs downstream accuracy across weight
//! sparsity points. Accuracy comes from the tiny trained checkpoint
//! (DESIGN.md §2 substitution for GSM8K); speedup from the Llama 3 8B
//! cost model at the same sparsity.

use sparamx::baselines::systems::{decode_step_cost, Baseline, Precision};
use sparamx::bench::harness::{report_header, report_row};
use sparamx::models::tinyforward::{KvTreatment, TinyModel};
use sparamx::models::ModelConfig;
use sparamx::perf::Machine;
use sparamx::runtime::artifact::Bundle;

fn main() {
    let m = Machine::sapphire_rapids(32);
    let cfg = ModelConfig::llama3_8b();
    let bundle = Bundle::load("artifacts").ok();
    report_header(
        "Fig 10 — speedup vs accuracy across weight sparsity",
        &["sparsity", "speedup (8B model)", "tiny-LM top1", "tiny-LM ppl"],
    );
    let py = decode_step_cost(&cfg, Baseline::PyTorch, Precision::Bf16, 1, 512, 0.0, &m);
    for s in [0.0, 0.2, 0.4, 0.5, 0.6, 0.8] {
        let ours = decode_step_cost(&cfg, Baseline::SparAmxSparse, Precision::Bf16, 1, 512, s, &m);
        let (top1, ppl) = match &bundle {
            Some(b) => {
                let mut model = TinyModel::from_bundle(b).expect("model");
                model.prune_weights(s);
                let limit = b.eval_tokens.len().min(1280);
                let r = model.evaluate(&b.eval_tokens[..limit], 128, KvTreatment::default());
                (format!("{:.3}", r.top1), format!("{:.2}", r.ppl))
            }
            None => ("n/a (no artifacts)".into(), "n/a".into()),
        };
        report_row(&[
            format!("{:.0}%", s * 100.0),
            format!("{:.2}x", py / ours),
            top1,
            ppl,
        ]);
    }
    println!("\npaper shape: speedup rises with sparsity; accuracy degrades past a knee");
}
