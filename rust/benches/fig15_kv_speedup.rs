//! Fig 15: end-to-end decode latency speedup vs K/V sparsity at 16K
//! context, sparse attention kernel vs the dense kernel baseline.
//! Paper: 1.14× at the <1%-accuracy-loss setting (30% K / 50% V).
//!
//! The attention stream cost comes from the same analytic counters the
//! functional kernels are validated against; the baseline is the dense
//! kernel (≈ stock PyTorch at decode, per the paper).

use sparamx::bench::harness::{report_header, report_row};
use sparamx::models::ModelConfig;
use sparamx::perf::cost::KernelCost;
use sparamx::perf::{analytic, Machine};

/// Decode-step attention cost with a sparse static cache: per layer and
/// kv-head, QKᵀ is a (1 × hd)·(hd × ctx) sparse GEMM and R·V is a
/// (1 × ctx)·(ctx × hd) sparse GEMM.
fn attention_step(cfg: &ModelConfig, ctx: usize, ks: f64, vs: f64, m: &Machine) -> f64 {
    let hd = cfg.head_dim;
    let mut total = 0.0;
    let heads = cfg.kv_heads * cfg.layers;
    let k_nnz = ((1.0 - ks) * (hd * ctx) as f64).round() as usize;
    let v_nnz = ((1.0 - vs) * (ctx * hd) as f64).round() as usize;
    let qk = KernelCost::from_counters(&analytic::sparse_bf16(1, hd, ctx, k_nnz), m);
    let rv = KernelCost::from_counters(&analytic::sparse_bf16(1, ctx, hd, v_nnz), m);
    total += (qk.time + rv.time) * heads as f64;
    total
}

fn attention_step_dense(cfg: &ModelConfig, ctx: usize, m: &Machine) -> f64 {
    let hd = cfg.head_dim;
    let heads = cfg.kv_heads * cfg.layers;
    let qk = KernelCost::from_counters(&analytic::dense_bf16(1, hd, ctx), m);
    let rv = KernelCost::from_counters(&analytic::dense_bf16(1, ctx, hd), m);
    (qk.time + rv.time) * heads as f64
}

fn main() {
    let m = Machine::sapphire_rapids(32);
    let cfg = ModelConfig::llama3_8b();
    let ctx = 16_384;
    // linears stay dense for this figure (isolating the attention effect)
    let lin = sparamx::baselines::systems::linear_stack_cost(
        &cfg,
        sparamx::baselines::systems::Baseline::SparAmxDense,
        sparamx::baselines::systems::Precision::Bf16,
        1,
        0.0,
        &m,
    );
    let dense_att = attention_step_dense(&cfg, ctx, &m);
    let dense_total = lin + dense_att;
    report_header(
        "Fig 15 — decode speedup vs KV sparsity (16K ctx, dense-kernel baseline)",
        &["K sparsity", "V sparsity", "attention ms", "end-to-end speedup"],
    );
    for (ks, vs) in [
        (0.0, 0.0),
        (0.1, 0.1),
        (0.3, 0.3),
        (0.3, 0.5),
        (0.5, 0.5),
        (0.7, 0.7),
        (0.9, 0.9),
    ] {
        let att = attention_step(&cfg, ctx, ks, vs, &m);
        let total = lin + att;
        report_row(&[
            format!("{:.0}%", ks * 100.0),
            format!("{:.0}%", vs * 100.0),
            format!("{:.2}", att * 1e3),
            format!("{:.3}x", dense_total / total),
        ]);
    }
    println!("\npaper: 1.14x at 30% K / 50% V with <1% accuracy loss");
}
