//! §Perf microbenchmarks: wall-clock of the real Rust hot paths on this
//! container (1 core) — the functional simulator's decompression, the
//! pack/prune pipeline, and the engine-adjacent pieces. These are the
//! before/after numbers tracked in EXPERIMENTS.md §Perf.

use sparamx::amx::kernels::{DenseWeights, GemmCounters};
use sparamx::backend::Backend;
use sparamx::bench::harness::{bench_auto, fmt_time, report_header, report_row};
use sparamx::sparse::format::SparseTensor;
use sparamx::sparse::prune::magnitude_prune;
use sparamx::util::XorShift;

fn main() {
    let mut g = XorShift::new(42);
    let (k, n) = (1024usize, 1024usize);
    let w = magnitude_prune(&g.normal_vec(k * n, 1.0), 0.5);
    let x = g.normal_vec(k, 1.0);
    let sp = SparseTensor::pack_f32(&w, k, n);
    let dw = DenseWeights::pack_f32(&w, k, n);
    let amx = Backend::amx();

    report_header(
        "§Perf — hot-path wall clock (1024x1024, batch 1, this container)",
        &["path", "time", "throughput"],
    );

    let r = bench_auto("pack", 0.5, || {
        std::hint::black_box(SparseTensor::pack_f32(&w, k, n));
    });
    report_row(&[
        "SparseTensor::pack_f32".into(),
        fmt_time(r.mean_s()),
        format!("{:.2} Melem/s", (k * n) as f64 / r.mean_s() / 1e6),
    ]);

    let r = bench_auto("prune", 0.5, || {
        std::hint::black_box(magnitude_prune(&w, 0.5));
    });
    report_row(&[
        "magnitude_prune".into(),
        fmt_time(r.mean_s()),
        format!("{:.2} Melem/s", (k * n) as f64 / r.mean_s() / 1e6),
    ]);

    let r = bench_auto("sim-sparse-gemm", 1.0, || {
        let mut ctr = GemmCounters::default();
        std::hint::black_box(amx.sparse_gemm_bf16(&x, 1, &sp, &mut ctr));
    });
    report_row(&[
        "simulated sparse AMX GEMM".into(),
        fmt_time(r.mean_s()),
        format!("{:.2} MMAC/s", (k * n) as f64 / r.mean_s() / 1e6),
    ]);

    let r = bench_auto("sim-dense-gemm", 1.0, || {
        let mut ctr = GemmCounters::default();
        std::hint::black_box(amx.gemm_bf16(&x, 1, &dw, &mut ctr));
    });
    report_row(&[
        "simulated dense AMX GEMM".into(),
        fmt_time(r.mean_s()),
        format!("{:.2} MMAC/s", (k * n) as f64 / r.mean_s() / 1e6),
    ]);

    // decompression stream rate: bitmap+values bytes consumed per second
    let r = bench_auto("decompress-only", 1.0, || {
        let mut ctr = GemmCounters::default();
        std::hint::black_box(amx.sparse_gemm_bf16(&x, 1, &sp, &mut ctr));
    });
    let stream = sp.bytes_sparse() as f64;
    report_row(&[
        "compressed-stream rate".into(),
        fmt_time(r.mean_s()),
        format!("{:.2} MB/s", stream / r.mean_s() / 1e6),
    ]);
}
