//! Table 1: % pipeline slots memory-bound / DRAM-bound, dense vs sparse
//! kernel, on 32 consecutive up_proj-shaped linears (4192×14336).
//! Paper: dense 100 / 87.5, sparse 21.1 / 5.7.

use sparamx::bench::harness::{report_header, report_row};
use sparamx::perf::cost::{dense_gemm_cost, sparse_gemm_cost};
use sparamx::perf::pipeline::attribute;
use sparamx::perf::Machine;

fn main() {
    let m = Machine::sapphire_rapids(32);
    report_header(
        "Table 1 — pipeline-slot attribution (4192x14336 linear, batch 1, 32 cores)",
        &["kernel", "memory bound %", "DRAM bound %", "paper memory %", "paper DRAM %"],
    );
    let dense = attribute(&dense_gemm_cost(1, 4192, 14336, &m));
    let sparse = attribute(&sparse_gemm_cost(1, 4192, 14336, 0.5, &m));
    report_row(&[
        "dense".into(),
        format!("{:.1}", dense.memory_bound_pct),
        format!("{:.1}", dense.dram_bound_pct),
        "100".into(),
        "87.5".into(),
    ]);
    report_row(&[
        "sparse (50%)".into(),
        format!("{:.1}", sparse.memory_bound_pct),
        format!("{:.1}", sparse.dram_bound_pct),
        "21.1".into(),
        "5.7".into(),
    ]);
    println!("\npaper shape: sparse collapses both stall categories");
}
