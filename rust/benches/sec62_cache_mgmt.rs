//! §6.2: cache-management cost — the static+dynamic split vs the stock
//! realloc-per-token + repeat_kv behaviour. Paper: ">6× faster
//! decoding" from avoiding reallocation and GQA materialization.
//! This one is a real wall-clock benchmark (pure memory management).

use sparamx::bench::harness::{bench_auto, fmt_time, report_header, report_row};
use sparamx::kvcache::cache::{HeadCache, NaiveCache};
use sparamx::util::XorShift;

fn main() {
    let (hd, group) = (128usize, 4usize);
    report_header(
        "§6.2 — per-token cache management cost (one kv-head, GQA group 4)",
        &["context", "naive (realloc+repeat_kv)", "split cache append", "speedup"],
    );
    for ctx in [1024usize, 4096, 16384] {
        let mut g = XorShift::new(1);
        let k0 = g.normal_vec(ctx * hd, 1.0);
        let v0 = g.normal_vec(ctx * hd, 1.0);
        let row = g.normal_vec(hd, 1.0);

        let naive = bench_auto(&format!("naive-{ctx}"), 0.3, || {
            let mut nc = NaiveCache::new(k0.clone(), v0.clone(), hd);
            nc.append_realloc(&row, &row);
            let (rk, rv) = nc.repeat_kv(group);
            std::hint::black_box((rk.len(), rv.len()));
        });
        // split cache: build once outside the loop (it is static state),
        // append into the dynamic tail per token
        let mut hc = HeadCache::from_prefill(&k0, &v0, ctx, hd, 0.3, 0.5);
        let split = bench_auto(&format!("split-{ctx}"), 0.3, || {
            hc.append(&row, &row);
            std::hint::black_box(hc.dyn_len());
        });
        report_row(&[
            format!("{ctx}"),
            fmt_time(naive.mean_s()),
            fmt_time(split.mean_s()),
            format!("{:.1}x", naive.mean_s() / split.mean_s()),
        ]);
    }
    println!("\npaper: >6x faster cache handling at long context");
}
