//! Fused sparse attention smoke: wall-clock of `attend_sparse_batched`
//! (all query rows sharing a KV head in one QKᵀ/R·V pair, one static
//! K/V stream per step) against looping `attend_sparse` row by row, at
//! the query-row counts the engine actually gathers (slots × GQA
//! group). Also prints the cost model's predicted fused-over-looped
//! attention speedup for the same geometry so the functional numbers
//! and the analytical ones sit side by side.

use sparamx::amx::EventCounters;
use sparamx::backend::Backend;
use sparamx::bench::harness::{bench, fmt_time, report_header, report_row};
use sparamx::kvcache::attention::{attend_sparse, attend_sparse_batched, AttentionScratch};
use sparamx::kvcache::cache::HeadCache;
use sparamx::perf::cost::fused_attention_speedup;
use sparamx::perf::Machine;
use sparamx::util::XorShift;

fn main() {
    let mut g = XorShift::new(15);
    let (ctx, hd) = (1024usize, 128usize);
    let (k_sp, v_sp) = (0.5f64, 0.5f64);
    let k = g.normal_vec(ctx * hd, 1.0);
    let v = g.normal_vec(ctx * hd, 1.0);
    let mut hc = HeadCache::from_prefill(&k, &v, ctx, hd, k_sp, v_sp);
    // a short dynamic tail, as mid-generation caches carry
    for _ in 0..4 {
        let kr = g.normal_vec(hd, 1.0);
        let vr = g.normal_vec(hd, 1.0);
        hc.append(&kr, &vr);
    }
    let m = Machine::sapphire_rapids(32);

    report_header(
        "Fused sparse attention — one KV stream per step vs looped rows (ctx 1024, hd 128, 50% sparse)",
        &["backend", "rows", "looped", "fused", "wall x", "model x"],
    );

    for backend in [Backend::amx(), Backend::avx()] {
        let q16 = g.normal_vec(16 * hd, 1.0);
        for rows in [1usize, 4, 16] {
            let q = &q16[..rows * hd];
            let looped = bench("looped", 2, 12, || {
                let mut ctr = EventCounters::default();
                for r in 0..rows {
                    std::hint::black_box(attend_sparse(
                        &hc,
                        &q[r * hd..(r + 1) * hd],
                        &backend,
                        &mut ctr,
                    ));
                }
            });
            let mut scratch = AttentionScratch::default();
            let mut out = vec![0f32; rows * hd];
            let fused = bench("fused", 2, 12, || {
                let mut ctr = EventCounters::default();
                attend_sparse_batched(&hc, q, rows, &backend, &mut scratch, &mut out, &mut ctr);
                std::hint::black_box(&out);
            });
            report_row(&[
                backend.name().into(),
                format!("{rows}"),
                fmt_time(looped.mean_s()),
                fmt_time(fused.mean_s()),
                format!("{:.2}x", looped.mean_s() / fused.mean_s()),
                format!(
                    "{:.2}x",
                    fused_attention_speedup(rows, ctx, hd, k_sp, v_sp, &m)
                ),
            ]);
        }
    }

    println!("\npaper shape: the fused path streams each static K/V segment once per");
    println!("decode step for the whole query group (slots × GQA heads), so the");
    println!("win grows with gathered rows until the kernel turns compute-bound");
}
