//! Ablations called out in DESIGN.md §5:
//!  A1 — 8-tile vs naive 3-tile AMX schedule (compute-to-load ratio)
//!  A2 — prefix-sum offsets vs serial offset update (instruction count)
//!  A3 — weight_value_index vs per-thread stream scan (load-time cost)
//!  A4 — static+dynamic KV split vs repacking the whole cache per token

use sparamx::bench::harness::{report_header, report_row};
use sparamx::perf::{analytic, Machine};
use sparamx::perf::cost::KernelCost;
use sparamx::sparse::format::SparseTensor;
use sparamx::sparse::partition::ThreadPartition;
use sparamx::sparse::prune::magnitude_prune;
use sparamx::util::XorShift;
use std::time::Instant;

fn main() {
    let m = Machine::sapphire_rapids(32);

    // A1: the naive schedule loads 2 tiles per tdp (1 weight + 1 input
    // re-load), the 8-tile schedule amortizes to 1 load per tdp.
    report_header(
        "A1 — 8-tile schedule vs naive 3-tile (4096x4096, batch 256, LLC-resident)",
        &["schedule", "tile loads / tdp", "modeled time"],
    );
    let c8 = analytic::dense_bf16(256, 4096, 4096);
    let loads8 = (c8.tile_load_input + c8.tile_load_weight) as f64 / c8.tdp_total() as f64;
    let t8 = KernelCost::from_counters(&c8, &m).time;
    let mut c3 = c8.clone();
    // naive: one result tile at a time → every tdp needs its own A and B load
    c3.tile_load_input = c3.tdp_total();
    c3.tile_load_weight = c3.tdp_total();
    c3.weight_stream_bytes = c3.tile_load_weight * 1024;
    c3.input_bytes = c3.tile_load_input * 1024;
    let t3 = KernelCost::from_counters(&c3, &m).time;
    report_row(&["8-tile (paper)".into(), format!("{loads8:.2}"), format!("{:.0} µs", t8 * 1e6)]);
    report_row(&["naive 3-tile".into(), "2.00".into(), format!("{:.0} µs", t3 * 1e6)]);
    report_row(&["advantage".into(), String::new(), format!("{:.2}x", t3 / t8)]);

    // A2: Algorithm-1 prefix sum = 4 vector steps per tile; a serial
    // scan is 16 dependent scalar updates.
    report_header(
        "A2 — prefix-sum offsets vs serial update (per weight tile)",
        &["method", "ops/tile", "modeled decompress overhead (4096x14336)"],
    );
    let nnz = (0.5 * 4096.0 * 14336.0) as usize;
    let cs = analytic::sparse_bf16(1, 4096, 14336, nnz);
    let prefix_t = KernelCost::from_counters(&cs, &m).time;
    let mut serial = cs.clone();
    // 16 dependent scalar updates at ~3-cycle latency each vs 4 vector
    // steps at 2 cycles: express as an equivalent prefix_step count
    serial.prefix_step = 24 * (serial.vpexpand / 16);
    let serial_t = KernelCost::from_counters(&serial, &m).time;
    report_row(&["prefix sum (paper)".into(), "4".into(), format!("{:.0} µs", prefix_t * 1e6)]);
    report_row(&["serial scan".into(), "16".into(), format!("{:.0} µs", serial_t * 1e6)]);

    // A3: weight_value_index precompute vs scanning the bitmap stream
    // per thread at every call (wall clock, real data structures).
    report_header(
        "A3 — weight_value_index vs per-call bitmap scan (4096x4096 @ 50%)",
        &["method", "cost", "when"],
    );
    let mut g = XorShift::new(3);
    let w = magnitude_prune(&g.normal_vec(4096 * 4096, 1.0), 0.5);
    let sp = SparseTensor::pack_f32(&w, 4096, 4096);
    let t0 = Instant::now();
    let part = ThreadPartition::build(&sp, 32);
    let build = t0.elapsed();
    let t0 = Instant::now();
    // per-call scan: each thread popcounts every preceding tile
    let mut scanned = 0usize;
    for t in 0..sp.num_tiles() {
        scanned += sp
            .tile_metadata(t)
            .iter()
            .map(|w| w.count_ones() as usize)
            .sum::<usize>();
    }
    let scan = t0.elapsed();
    std::hint::black_box((part.weight_value_index.len(), scanned));
    report_row(&["weight_value_index (paper)".into(), format!("{build:?}"), "once at load".into()]);
    report_row(&["full bitmap scan".into(), format!("{scan:?}"), "every kernel call".into()]);

    // A4: split cache vs re-packing the static segment every token.
    report_header(
        "A4 — dynamic tail vs repacking static cache per token (ctx 4096, hd 128)",
        &["method", "per-token cost"],
    );
    let k0 = g.normal_vec(4096 * 128, 1.0);
    let v0 = g.normal_vec(4096 * 128, 1.0);
    let mut hc =
        sparamx::kvcache::cache::HeadCache::from_prefill(&k0, &v0, 4096, 128, 0.3, 0.5);
    let row = g.normal_vec(128, 1.0);
    let t0 = Instant::now();
    for _ in 0..100 {
        hc.append(&row, &row);
    }
    let tail = t0.elapsed() / 100;
    let t0 = Instant::now();
    let _repack =
        sparamx::kvcache::cache::HeadCache::from_prefill(&k0, &v0, 4096, 128, 0.3, 0.5);
    let repack = t0.elapsed();
    report_row(&["dynamic tail (paper §6.2)".into(), format!("{tail:?}")]);
    report_row(&["repack whole cache".into(), format!("{repack:?}")]);
}
