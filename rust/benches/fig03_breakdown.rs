//! Fig 3: decode latency breakdown (linear / attention / other) of
//! Llama 3 8B across context lengths. Paper shape: linear layers
//! dominate at short contexts; attention grows with context.

use sparamx::baselines::systems::{
    attention_cost, linear_stack_cost, other_cost, Baseline, Precision,
};
use sparamx::bench::harness::{report_header, report_row};
use sparamx::models::ModelConfig;
use sparamx::perf::Machine;

fn main() {
    let m = Machine::sapphire_rapids(32);
    let cfg = ModelConfig::llama3_8b();
    report_header(
        "Fig 3 — Llama 3 8B decode latency breakdown vs context (stock PyTorch class)",
        &["context", "linear %", "attention %", "other %", "total ms"],
    );
    for ctx in [512usize, 1024, 2048, 4096, 8192, 16384] {
        let lin = linear_stack_cost(&cfg, Baseline::PyTorch, Precision::Bf16, 1, 0.0, &m);
        let att = attention_cost(&cfg, 1, ctx, &m);
        let oth = other_cost(&cfg, 1, &m);
        let total = lin + att + oth;
        report_row(&[
            format!("{ctx}"),
            format!("{:.1}", 100.0 * lin / total),
            format!("{:.1}", 100.0 * att / total),
            format!("{:.1}", 100.0 * oth / total),
            format!("{:.2}", total * 1e3),
        ]);
    }
    println!("\npaper: linears dominate at 512; attention share rises toward 16K");
}
