//! Fig 16 (Appendix B): AVX kernel speedup vs `num_column_groups` at
//! 8/16/32 cores, single-token decode. Baseline = 1 column group on 8
//! cores. Paper shape: more groups → better, approaching/passing AMX.

use sparamx::bench::harness::{report_header, report_row};
use sparamx::models::ModelConfig;
use sparamx::perf::cost::{avx_sparse_gemm_cost, sparse_gemm_cost};
use sparamx::perf::Machine;

fn model_avx_time(cfg: &ModelConfig, groups: usize, m: &Machine) -> f64 {
    cfg.layer_linears()
        .iter()
        .map(|l| avx_sparse_gemm_cost(1, l.in_features, l.out_features, 0.5, groups, m).time)
        .sum::<f64>()
        * cfg.layers as f64
}

fn main() {
    let cfg = ModelConfig::llama3_8b();
    let baseline = model_avx_time(&cfg, 1, &Machine::sapphire_rapids(8));
    report_header(
        "Fig 16 — AVX speedup vs column groups (baseline: 1 group @ 8 cores)",
        &["groups", "8 cores", "16 cores", "32 cores", "AMX @32 (ref)"],
    );
    let amx32: f64 = cfg
        .layer_linears()
        .iter()
        .map(|l| {
            sparse_gemm_cost(1, l.in_features, l.out_features, 0.5, &Machine::sapphire_rapids(32))
                .time
        })
        .sum::<f64>()
        * cfg.layers as f64;
    for groups in [1usize, 2, 4, 8, 16, 32] {
        let t8 = model_avx_time(&cfg, groups, &Machine::sapphire_rapids(8));
        let t16 = model_avx_time(&cfg, groups, &Machine::sapphire_rapids(16));
        let t32 = model_avx_time(&cfg, groups, &Machine::sapphire_rapids(32));
        report_row(&[
            format!("{groups}"),
            format!("{:.2}x", baseline / t8),
            format!("{:.2}x", baseline / t16),
            format!("{:.2}x", baseline / t32),
            format!("{:.2}x", baseline / amx32),
        ]);
    }
    println!("\npaper shape: speedup grows with groups and cores, up to ~3.5x");
}
