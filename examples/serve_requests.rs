//! End-to-end serving driver (the DESIGN.md §6 validation run):
//! loads the build-time-trained tiny model, compiles its per-layer
//! decode plan, runs the continuous-batching engine over a workload of
//! prompts entirely on the native kernel path (no PJRT required), and
//! reports latency + throughput, plus the modeled Sapphire Rapids
//! speedup of the sparse configuration.
//!
//! ```sh
//! make artifacts && cargo run --release --offline --example serve_requests
//! ```

use sparamx::baselines::systems::{decode_step_cost, Baseline, Precision};
use sparamx::cfg::RuntimeConfig;
use sparamx::coordinator::batcher::AdmissionQueue;
use sparamx::coordinator::engine::Engine;
use sparamx::coordinator::request::Request;
use sparamx::models::ModelConfig;
use sparamx::perf::Machine;
use sparamx::runtime::artifact::Bundle;
use std::sync::{mpsc, Arc};
use std::time::Instant;

fn main() -> sparamx::util::error::Result<()> {
    let cfg = RuntimeConfig {
        weight_sparsity: 0.5,
        max_new_tokens: 24,
        k_sparsity: 0.0,
        v_sparsity: 0.0,
        ..Default::default()
    };
    let bundle = Bundle::load(&cfg.artifacts_dir)?;
    let mut engine = Engine::load_native(&bundle, cfg.clone())?;
    println!(
        "engine: {} decode slots, weights pruned to {:.0}%, {}",
        engine.geometry().decode_batch,
        cfg.weight_sparsity * 100.0,
        engine.describe()
    );

    // workload: 12 prompts drawn from the corpus grammar
    let prompts = [
        "the cat sees ", "a dog likes ", "the queen finds ", "my robot paints ",
        "one bird sings to ", "the old man feeds ", "a tiny fox chases ",
        "the ship follows ", "her friend greets ", "the wizard builds ",
        "the cat chases ", "a dog finds ",
    ];
    let queue = Arc::new(AdmissionQueue::new(64));
    let mut rxs = Vec::new();
    let t0 = Instant::now();
    for (i, p) in prompts.iter().enumerate() {
        let (tx, rx) = mpsc::channel();
        queue
            .admit(Request {
                id: i as u64,
                prompt: p.as_bytes().to_vec(),
                max_new_tokens: cfg.max_new_tokens,
                arrived: Instant::now(),
                respond: tx,
            })
            .expect("admit");
        rxs.push((p, rx));
    }
    queue.close();
    engine.run(&queue)?;
    let wall = t0.elapsed().as_secs_f64();

    let mut total_tokens = 0usize;
    for (p, rx) in rxs {
        let resp = rx.recv()?;
        total_tokens += resp.tokens.len();
        println!(
            "  [{:>5.1} ms | {:>5.2} ms/tok] {p}{}",
            resp.total_latency_s * 1e3,
            resp.per_token_s * 1e3,
            resp.text().trim_end()
        );
    }
    println!("\n{}", engine.metrics.report());
    let ev = engine.kernel_events();
    println!(
        "kernel events: {} instrs, {} weight B streamed ({} decode path)",
        ev.instructions(),
        ev.weight_stream_bytes,
        engine.engine_path()
    );
    println!(
        "throughput: {:.1} tokens/s over {} requests in {:.2} s (1-core CPU container)",
        total_tokens as f64 / wall,
        prompts.len(),
        wall
    );

    // the paper-scale projection: what this configuration models on the
    // target machine for Llama 3 8B
    let m = Machine::sapphire_rapids(32);
    let big = ModelConfig::llama3_8b();
    let py = decode_step_cost(&big, Baseline::PyTorch, Precision::Bf16, 1, 512, 0.0, &m);
    let ours = decode_step_cost(&big, Baseline::SparAmxSparse, Precision::Bf16, 1, 512, 0.5, &m);
    println!(
        "modeled Llama 3 8B on 32-core SPR: PyTorch {:.1} ms/tok, SparAMX {:.1} ms/tok → {:.2}x (paper: 1.42x)",
        py * 1e3,
        ours * 1e3,
        py / ours
    );
    Ok(())
}
