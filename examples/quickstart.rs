//! Quickstart: prune → pack → dispatch through the backend layer →
//! verify vs dense.
//!
//! ```sh
//! cargo run --release --offline --example quickstart -- --backend auto
//! ```
//!
//! `--backend {auto,amx,avx,ref}` pins the kernel backend; `auto` lets
//! the capability-probed registry pick dense-vs-sparse per the cost
//! model (override detection with `SPARAMX_CAPS=all|none|...`).

use sparamx::amx::kernels::{DenseWeights, GemmCounters};
use sparamx::backend::{BackendRegistry, Dtype, GemmShape, RefBackend};
use sparamx::perf::{cost::KernelCost, Machine};
use sparamx::sparse::format::SparseTensor;
use sparamx::sparse::prune::magnitude_prune;
use sparamx::util::cli::Args;
use sparamx::util::XorShift;

fn main() {
    let args = Args::from_env();

    // 1. a dense weight matrix (say, one projection of a small model)
    let (k, n) = (256usize, 512usize);
    let mut rng = XorShift::new(7);
    let dense = rng.normal_vec(k * n, 0.5);

    // 2. magnitude-prune to 50% unstructured sparsity (paper §6.1)
    let sparsity = 0.5;
    let pruned = magnitude_prune(&dense, sparsity);

    // 3. pack into the SparAMX bitmap + values format (paper Fig 6)
    let sp = SparseTensor::pack_f32(&pruned, k, n);
    println!(
        "packed: {} nnz, sparsity {:.1}%, {} B sparse vs {} B dense ({:.2}x smaller)",
        sp.nnz(),
        sp.sparsity() * 100.0,
        sp.bytes_sparse(),
        sp.bytes_dense(),
        sp.bytes_dense() as f64 / sp.bytes_sparse() as f64
    );

    // 4. resolve the backend and run both kernel classes through it
    // (modeled caps: full Sapphire Rapids unless SPARAMX_CAPS overrides
    // — the simulated kernels run on any host)
    let registry = BackendRegistry::with_caps(sparamx::backend::CpuCaps::modeled());
    let shape = GemmShape::new(1, k, n);
    let sel = registry.resolve(args.backend(), shape, sparsity, Dtype::Bf16);
    if sel.backend.kind() == sparamx::backend::BackendKind::Reference {
        println!(
            "backend: ref (caps [{}], reference oracle — no modeled time)",
            registry.caps().describe()
        );
    } else {
        println!(
            "backend: {} (caps [{}], predicted {:.1} µs)",
            sel.describe(),
            registry.caps().describe(),
            sel.predicted_s * 1e6
        );
    }
    let backend = &sel.backend;

    let x = rng.normal_vec(k, 1.0);
    let mut sparse_ctr = GemmCounters::default();
    let y_sparse = backend.sparse_gemm_bf16(&x, 1, &sp, &mut sparse_ctr);
    let dw = DenseWeights::pack_f32(&pruned, k, n);
    let mut dense_ctr = GemmCounters::default();
    let y_dense = backend.gemm_bf16(&x, 1, &dw, &mut dense_ctr);

    // 5. verify numerics against the reference oracle
    let want = RefBackend::matmul_f32(&x, 1, &pruned, k, n);
    let tol = 0.02 * (k as f32).sqrt();
    for i in 0..n {
        assert!((y_sparse[i] - want[i]).abs() <= tol + want[i].abs() * 0.02);
        assert!((y_dense[i] - want[i]).abs() <= tol + want[i].abs() * 0.02);
    }
    println!("numerics: sparse == dense == reference ✓");

    // 6. what the hardware would see (the paper's core claim)
    if sparse_ctr.weight_stream_bytes > 0 && dense_ctr.weight_stream_bytes > 0 {
        println!(
            "weight bytes streamed: dense {} vs sparse {} ({:.2}x less traffic)",
            dense_ctr.weight_stream_bytes,
            sparse_ctr.weight_stream_bytes,
            dense_ctr.weight_stream_bytes as f64 / sparse_ctr.weight_stream_bytes as f64
        );
        let m = Machine::sapphire_rapids(32);
        let td = KernelCost::from_counters(&dense_ctr, &m);
        let ts = KernelCost::from_counters(&sparse_ctr, &m);
        println!(
            "modeled on 32-core Sapphire Rapids: dense {:.1} µs, sparse {:.1} µs → {:.2}x",
            td.time * 1e6,
            ts.time * 1e6,
            td.time / ts.time
        );
    } else {
        println!("(reference backend models no hardware events — pick amx/avx for traffic stats)");
    }
}
