//! Reproduce-your-own Fig 11: sweep sparsity × cores for any model
//! config from the CLI, with the registry's per-layer auto-selection
//! shown alongside the fixed kernel classes.
//!
//! ```sh
//! cargo run --release --offline --example sparsity_sweep -- \
//!     --model llama3-8b --cores 8,16,32 --sparsities 0.3,0.5,0.7,0.9 \
//!     --backend auto
//! ```
//!
//! `--backend {auto,amx,avx,ref}`: `auto` reports what the registry
//! would dispatch for the model's up_proj at each sparsity; a pinned
//! backend restricts the selection column to that backend's best plan.

use sparamx::backend::{BackendRegistry, CpuCaps, Dtype, GemmShape};
use sparamx::baselines::systems::{decode_step_cost, Baseline, Precision};
use sparamx::bench::harness::{report_header, report_row};
use sparamx::models::ModelConfig;
use sparamx::perf::Machine;
use sparamx::util::cli::Args;

fn main() {
    let args = Args::from_env();
    let model_name = args.get("model", "llama3-8b");
    let Some(cfg) = ModelConfig::by_name(&model_name) else {
        eprintln!("unknown model {model_name}; options: llama3-8b, llama3.2-3b, llama3.2-1b, llama2-7b, tiny");
        std::process::exit(2);
    };
    let choice = args.backend();
    let cores = args.get_list("cores", &[8usize, 16, 32]);
    let sparsities = args.get_list("sparsities", &[0.0, 0.3, 0.5, 0.7, 0.9]);
    let ctx: usize = args.get_parse("ctx", 512);
    let batch: usize = args.get_parse("batch", 1);

    // the selection column models the paper's testbed (full caps unless
    // SPARAMX_CAPS overrides); the host's real caps only matter when
    // actually deploying
    let up = cfg
        .layer_linears()
        .into_iter()
        .find(|l| l.name == "up_proj")
        .expect("every config has up_proj");
    for &c in &cores {
        let m = Machine::sapphire_rapids(c);
        let registry = BackendRegistry::with_caps(CpuCaps::modeled()).with_machine(m);
        let py = decode_step_cost(&cfg, Baseline::PyTorch, Precision::Bf16, batch, ctx, 0.0, &m);
        report_header(
            &format!("{model_name} — {c} cores, ctx {ctx}, batch {batch}, --backend {choice}"),
            &[
                "sparsity",
                "pytorch ms/tok",
                "AMX sparse ms/tok",
                "AVX sparse ms/tok",
                "AMX speedup",
                "selected (up_proj)",
            ],
        );
        for &s in &sparsities {
            let amx =
                decode_step_cost(&cfg, Baseline::SparAmxSparse, Precision::Bf16, batch, ctx, s, &m);
            let avx =
                decode_step_cost(&cfg, Baseline::SparAvxSparse, Precision::Bf16, batch, ctx, s, &m);
            let sel = registry.resolve(choice, GemmShape::for_linear(&up, batch), s, Dtype::Bf16);
            report_row(&[
                format!("{:.0}%", s * 100.0),
                format!("{:.2}", py * 1e3 / batch as f64),
                format!("{:.2}", amx * 1e3 / batch as f64),
                format!("{:.2}", avx * 1e3 / batch as f64),
                format!("{:.2}x", py / amx),
                format!("{} ({:.0} µs)", sel.describe(), sel.predicted_s * 1e6),
            ]);
        }
    }
}
