//! Long-context decode with the §6.2 sparse static KV cache: prefill a
//! 16K-token context once, prune it (30% K / 50% V), then decode with
//! the sparse attention kernel and compare modeled latency against the
//! dense cache.
//!
//! ```sh
//! cargo run --release --offline --example long_context_kv
//! ```

use sparamx::amx::EventCounters;
use sparamx::backend::{BackendRegistry, CpuCaps, Dtype, GemmShape};
use sparamx::kvcache::attention::{attend_dense_ref, attend_sparse};
use sparamx::kvcache::cache::HeadCache;
use sparamx::perf::{cost::KernelCost, Machine};
use sparamx::util::cli::Args;
use sparamx::util::XorShift;

fn main() {
    let args = Args::from_env();
    // one kv-head of a Llama-scale model at 16K context, scaled-down
    // functional check at 2K (the full 16K runs through the analytic
    // model; the numerics are context-length independent)
    let (ctx, hd) = (2048usize, 128usize);
    let mut g = XorShift::new(11);
    let k = g.normal_vec(ctx * hd, 1.0);
    let v = g.normal_vec(ctx * hd, 1.0);
    let q = g.normal_vec(hd, 1.0);

    println!("prefilling {ctx}-token context, pruning K 30% / V 50% ...");
    let mut hc = HeadCache::from_prefill(&k, &v, ctx, hd, 0.3, 0.5);
    println!(
        "static cache: {} B sparse (dense would be {} B)",
        hc.bytes(),
        2 * ctx * hd * 2
    );

    // resolve the attention backend (the static segment's QKᵀ / R·V are
    // sparse GEMMs of shape head_dim × ctx)
    let registry = BackendRegistry::with_caps(CpuCaps::modeled());
    let sel = registry.resolve(args.backend(), GemmShape::new(1, hd, ctx), 0.4, Dtype::Bf16);
    println!("attention backend: {}", sel.describe());

    // decode 4 tokens into the dynamic tail
    let mut ctr = EventCounters::default();
    let mut out = Vec::new();
    for _ in 0..4 {
        out = attend_sparse(&hc, &q, &sel.backend, &mut ctr);
        let new_k = g.normal_vec(hd, 1.0);
        let new_v = g.normal_vec(hd, 1.0);
        hc.append(&new_k, &new_v);
    }
    println!(
        "decoded 4 tokens; cache now {} static + {} dynamic positions",
        hc.n_static,
        hc.dyn_len()
    );

    // sanity: output close to the dense reference over the same cache
    let dense = attend_dense_ref(&k, &v, ctx, hd, &q);
    let rms: f32 = (out
        .iter()
        .zip(dense.iter())
        .map(|(a, b)| (a - b) * (a - b))
        .sum::<f32>()
        / hd as f32)
        .sqrt();
    println!("attention output RMS deviation from unpruned dense: {rms:.4}");

    // modeled 16K-context step on the target machine
    let m = Machine::sapphire_rapids(32);
    let big_ctx = 16_384;
    let layers_heads = 32 * 8; // Llama 3 8B layers × kv heads
    let nnz_k = (0.7 * (hd * big_ctx) as f64) as usize;
    let nnz_v = (0.5 * (big_ctx * hd) as f64) as usize;
    let sparse_t = (KernelCost::from_counters(
        &sparamx::perf::analytic::sparse_bf16(1, hd, big_ctx, nnz_k),
        &m,
    )
    .time
        + KernelCost::from_counters(
            &sparamx::perf::analytic::sparse_bf16(1, big_ctx, hd, nnz_v),
            &m,
        )
        .time)
        * layers_heads as f64;
    let dense_t = (KernelCost::from_counters(
        &sparamx::perf::analytic::dense_bf16(1, hd, big_ctx),
        &m,
    )
    .time
        + KernelCost::from_counters(&sparamx::perf::analytic::dense_bf16(1, big_ctx, hd), &m).time)
        * layers_heads as f64;
    println!(
        "modeled 16K-ctx attention / decode step on 32-core SPR: dense {:.2} ms, sparse {:.2} ms → {:.2}x (paper: 1.14x end-to-end)",
        dense_t * 1e3,
        sparse_t * 1e3,
        dense_t / sparse_t
    );
}
