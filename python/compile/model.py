"""Layer-2 JAX model: a tiny Llama-architecture LM (RMSNorm, RoPE, GQA,
SwiGLU) used for (a) the end-to-end serving path and (b) the accuracy
experiments (Figs 10/14/17/18 analogues).

Must stay in sync with ``rust/src/models/llama.rs::ModelConfig::tiny()``
and the Rust `tinyforward` module, which re-implements this forward pass
over the simulated AMX kernels.

Inference entry points (`decode_step`, `prefill`, `eval_logits`) route
every linear through the Layer-1 Pallas `dense_gemm` kernel so the AOT
artifact exercises the kernel end-to-end; the training path uses plain
jnp for speed (build-time only).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernels.dense_gemm import dense_gemm

TINY_CONFIG = dict(
    vocab=256,
    hidden=128,
    inter=352,
    layers=2,
    heads=4,
    kv_heads=2,
    head_dim=32,
    max_ctx=320,
)

PREFILL_LEN = 64
EVAL_LEN = 128


# ---------------------------------------------------------------------
# parameters
# ---------------------------------------------------------------------

def init_params(key, cfg=TINY_CONFIG):
    """He-initialized parameter pytree."""
    h, inter, v = cfg["hidden"], cfg["inter"], cfg["vocab"]
    kvd = cfg["kv_heads"] * cfg["head_dim"]
    qd = cfg["heads"] * cfg["head_dim"]

    def dense(key, i, o):
        return jax.random.normal(key, (i, o), jnp.float32) * (2.0 / i) ** 0.5

    keys = jax.random.split(key, 2 + 7 * cfg["layers"])
    params = {
        "emb": jax.random.normal(keys[0], (v, h), jnp.float32) * 0.02,
        "ln_f": jnp.ones((h,), jnp.float32),
        "lm_head": dense(keys[1], h, v),
        "layers": [],
    }
    ki = 2
    for _ in range(cfg["layers"]):
        params["layers"].append(
            {
                "ln1": jnp.ones((h,), jnp.float32),
                "wq": dense(keys[ki + 0], h, qd),
                "wk": dense(keys[ki + 1], h, kvd),
                "wv": dense(keys[ki + 2], h, kvd),
                "wo": dense(keys[ki + 3], qd, h),
                "ln2": jnp.ones((h,), jnp.float32),
                "wgate": dense(keys[ki + 4], h, inter),
                "wup": dense(keys[ki + 5], h, inter),
                "wdown": dense(keys[ki + 6], inter, h),
            }
        )
        ki += 7
    return params


def param_manifest(params):
    """Deterministic (name, shape) list in `tree_flatten` leaf order — the
    contract the Rust runtime uses to feed PJRT buffers."""
    leaves = jax.tree_util.tree_flatten_with_path(params)[0]
    out = []
    for path, leaf in leaves:
        name = "/".join(
            str(getattr(p, "key", getattr(p, "idx", p))) for p in path
        )
        out.append((name, tuple(leaf.shape)))
    return out


# ---------------------------------------------------------------------
# building blocks
# ---------------------------------------------------------------------

def rmsnorm(x, g, eps=1e-5):
    return x * g * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)


def rope(x, pos):
    """Rotary embedding. x: [..., seq, heads, hd]; pos: [..., seq]."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = 1.0 / (10000.0 ** (jnp.arange(half, dtype=jnp.float32) / half))
    # angles: [..., seq, 1, half], broadcast over the heads axis
    angles = pos[..., :, None, None].astype(jnp.float32) * freqs
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)


def _repeat_kv(x, group):
    """[..., kv_heads, hd] → [..., heads, hd] (training path only; the
    serving path never materializes this — §6.2)."""
    return jnp.repeat(x, group, axis=-2)


# ---------------------------------------------------------------------
# training / evaluation path (pure jnp, batched over sequences)
# ---------------------------------------------------------------------

def forward_seq(params, tokens, cfg=TINY_CONFIG):
    """Causal forward over full sequences: tokens [B, S] → logits [B, S, V]."""
    b, s = tokens.shape
    h = params["emb"][tokens]  # [B, S, H]
    pos = jnp.arange(s)
    heads, kvh, hd = cfg["heads"], cfg["kv_heads"], cfg["head_dim"]
    group = heads // kvh
    causal = jnp.tril(jnp.ones((s, s), bool))
    for layer in params["layers"]:
        x = rmsnorm(h, layer["ln1"])
        q = rope((x @ layer["wq"]).reshape(b, s, heads, hd), jnp.broadcast_to(pos, (b, s)))
        k = rope((x @ layer["wk"]).reshape(b, s, kvh, hd), jnp.broadcast_to(pos, (b, s)))
        v = (x @ layer["wv"]).reshape(b, s, kvh, hd)
        k, v = _repeat_kv(k, group), _repeat_kv(v, group)
        scores = jnp.einsum("bqhd,bkhd->bhqk", q, k) / hd**0.5
        scores = jnp.where(causal, scores, -1e30)
        att = jax.nn.softmax(scores, axis=-1)
        ctx = jnp.einsum("bhqk,bkhd->bqhd", att, v).reshape(b, s, heads * hd)
        h = h + ctx @ layer["wo"]
        x = rmsnorm(h, layer["ln2"])
        h = h + (jax.nn.silu(x @ layer["wgate"]) * (x @ layer["wup"])) @ layer["wdown"]
    return rmsnorm(h, params["ln_f"]) @ params["lm_head"]


# ---------------------------------------------------------------------
# inference path (Pallas kernels, KV cache) — the AOT artifacts
# ---------------------------------------------------------------------

def _linear(x, w):
    """Layer-1 kernel dispatch: every inference linear runs the Pallas
    blocked GEMM."""
    return dense_gemm(x, w)


def _attend_cached(q, k_cache, v_cache, cache_len, cfg):
    """Decode attention over the dense runtime cache with length masking.

    q: [B, heads, hd]; caches: [B, kvh, max_ctx, hd]; cache_len counts
    valid positions (including the current token's slot).
    """
    heads, kvh, hd = cfg["heads"], cfg["kv_heads"], cfg["head_dim"]
    group = heads // kvh
    b = q.shape[0]
    qg = q.reshape(b, kvh, group, hd)
    scores = jnp.einsum("bhgd,bhcd->bhgc", qg, k_cache) / hd**0.5
    pos = jnp.arange(cfg["max_ctx"])
    valid = pos[None, None, None, :] < cache_len[:, None, None, None]
    scores = jnp.where(valid, scores, -1e30)
    att = jax.nn.softmax(scores, axis=-1)
    ctx = jnp.einsum("bhgc,bhcd->bhgd", att, v_cache)
    return ctx.reshape(b, heads * hd)


def decode_step(params, token, pos, k_cache, v_cache, cache_len, cfg=TINY_CONFIG):
    """One decode step.

    Args:
      token: int32[B] current token ids.
      pos: int32[B] absolute positions.
      k_cache/v_cache: f32[B, kvh, max_ctx, hd] with the new slot free.
      cache_len: int32[B] valid length *after* inserting this token.

    Returns:
      (logits [B, V], k_cache', v_cache') — caches updated functionally.
    """
    heads, kvh, hd = cfg["heads"], cfg["kv_heads"], cfg["head_dim"]
    b = token.shape[0]
    h = params["emb"][token]  # [B, H]
    layer_caches = []
    for li, layer in enumerate(params["layers"]):
        x = rmsnorm(h, layer["ln1"])
        q = rope(_linear(x, layer["wq"]).reshape(b, 1, heads, hd),
                 pos[:, None]).reshape(b, heads, hd)
        k = rope(_linear(x, layer["wk"]).reshape(b, 1, kvh, hd),
                 pos[:, None]).reshape(b, kvh, hd)
        v = _linear(x, layer["wv"]).reshape(b, kvh, hd)
        # insert at slot cache_len-1 (functional update)
        slot = cache_len - 1
        kc = _insert(k_cache[li], k, slot)
        vc = _insert(v_cache[li], v, slot)
        layer_caches.append((kc, vc))
        ctx = _attend_cached(q, kc, vc, cache_len, cfg)
        h = h + _linear(ctx, layer["wo"])
        x = rmsnorm(h, layer["ln2"])
        h = h + _linear(
            jax.nn.silu(_linear(x, layer["wgate"])) * _linear(x, layer["wup"]),
            layer["wdown"],
        )
    logits = _linear(rmsnorm(h, params["ln_f"]), params["lm_head"])
    new_k = jnp.stack([c[0] for c in layer_caches])
    new_v = jnp.stack([c[1] for c in layer_caches])
    return logits, new_k, new_v


def _insert(cache, row, slot):
    """cache [B, kvh, C, hd] ← row [B, kvh, hd] at per-batch slot."""
    onehot = (jnp.arange(cache.shape[2])[None, :] == slot[:, None]).astype(cache.dtype)
    return cache * (1 - onehot[:, None, :, None]) + (
        row[:, :, None, :] * onehot[:, None, :, None]
    )


def prefill(params, tokens, cfg=TINY_CONFIG):
    """Process a fixed-length prompt: tokens [B, S] → (last logits [B, V],
    k [layers, B, kvh, S, hd], v [...]) for cache initialization."""
    b, s = tokens.shape
    heads, kvh, hd = cfg["heads"], cfg["kv_heads"], cfg["head_dim"]
    group = heads // kvh
    pos = jnp.broadcast_to(jnp.arange(s), (b, s))
    h = params["emb"][tokens]
    causal = jnp.tril(jnp.ones((s, s), bool))
    ks, vs = [], []
    for layer in params["layers"]:
        x = rmsnorm(h, layer["ln1"])
        q = rope((x @ layer["wq"]).reshape(b, s, heads, hd), pos)
        k = rope((x @ layer["wk"]).reshape(b, s, kvh, hd), pos)
        v = (x @ layer["wv"]).reshape(b, s, kvh, hd)
        ks.append(k.transpose(0, 2, 1, 3))  # [B, kvh, S, hd]
        vs.append(v.transpose(0, 2, 1, 3))
        kr, vr = _repeat_kv(k, group), _repeat_kv(v, group)
        scores = jnp.einsum("bqhd,bkhd->bhqk", q, kr) / hd**0.5
        scores = jnp.where(causal, scores, -1e30)
        att = jax.nn.softmax(scores, axis=-1)
        ctx = jnp.einsum("bhqk,bkhd->bqhd", att, vr).reshape(b, s, heads * hd)
        h = h + ctx @ layer["wo"]
        x = rmsnorm(h, layer["ln2"])
        h = h + (jax.nn.silu(x @ layer["wgate"]) * (x @ layer["wup"])) @ layer["wdown"]
    logits = _linear(rmsnorm(h[:, -1], params["ln_f"]), params["lm_head"])
    return logits, jnp.stack(ks), jnp.stack(vs)


def eval_logits(params, tokens, cfg=TINY_CONFIG):
    """Per-position logits for perplexity evaluation: [1, EVAL_LEN] →
    [1, EVAL_LEN, V]."""
    return forward_seq(params, tokens, cfg)


# ---------------------------------------------------------------------
# loss
# ---------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=())
def next_token_loss(params, tokens):
    """Mean cross-entropy of next-token prediction over [B, S]."""
    logits = forward_seq(params, tokens)
    logp = jax.nn.log_softmax(logits[:, :-1], axis=-1)
    tgt = tokens[:, 1:]
    nll = -jnp.take_along_axis(logp, tgt[..., None], axis=-1).squeeze(-1)
    return nll.mean()
