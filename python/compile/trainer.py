"""Build-time trainer for the tiny checkpoint (DESIGN.md §2).

The paper evaluates pretrained Llama checkpoints; offline we train a
~1 M-parameter byte-level LM on a synthetic structured corpus so the
accuracy experiments (Figs 10/14/17/18 analogues) measure a model that
genuinely learned something. Adam is implemented inline (optax is not in
the image).

Run: ``python -m compile.trainer --steps 300 --out ../artifacts``.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from . import io, model

SUBJECTS = ["the cat", "a dog", "the queen", "my robot", "one bird",
            "the old man", "a tiny fox", "the ship", "her friend", "the wizard"]
VERBS = ["sees", "likes", "chases", "finds", "paints", "builds", "sings to",
         "feeds", "follows", "greets"]
OBJECTS = ["the moon", "a red ball", "the river", "an apple", "the tower",
           "a green hat", "the garden", "a small stone", "the market", "a book"]


def synth_corpus(n_sentences: int, seed: int) -> np.ndarray:
    """Deterministic synthetic corpus: grammatical S-V-O sentences with a
    counting clause, byte-level tokens. Learnable structure at every
    scale: characters → words → phrase grammar."""
    rng = np.random.default_rng(seed)
    parts = []
    for _ in range(n_sentences):
        s = rng.choice(SUBJECTS)
        v = rng.choice(VERBS)
        o = rng.choice(OBJECTS)
        k = int(rng.integers(2, 9))
        parts.append(f"{s} {v} {o} {k} times. ")
    text = "".join(parts).encode()
    return np.frombuffer(text, dtype=np.uint8).copy()


def batches(corpus: np.ndarray, batch: int, seq: int, steps: int, seed: int):
    rng = np.random.default_rng(seed)
    hi = len(corpus) - seq - 1
    for _ in range(steps):
        starts = rng.integers(0, hi, size=batch)
        yield np.stack([corpus[s : s + seq] for s in starts]).astype(np.int32)


def adam_init(params):
    zeros = jax.tree.map(jnp.zeros_like, params)
    return {"m": zeros, "v": jax.tree.map(jnp.zeros_like, params), "t": jnp.zeros((), jnp.int32), "_": zeros}


@jax.jit
def adam_step(params, opt, grads, lr=1e-3, b1=0.9, b2=0.999, eps=1e-8):
    t = opt["t"] + 1
    m = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g, opt["m"], grads)
    v = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * g * g, opt["v"], grads)
    mh = jax.tree.map(lambda m: m / (1 - b1 ** t.astype(jnp.float32)), m)
    vh = jax.tree.map(lambda v: v / (1 - b2 ** t.astype(jnp.float32)), v)
    new = jax.tree.map(lambda p, mh, vh: p - lr * mh / (jnp.sqrt(vh) + eps), params, mh, vh)
    return new, {"m": m, "v": v, "t": t, "_": opt["_"]}


def train(steps: int = 300, batch: int = 16, seq: int = 48, seed: int = 0,
          log_every: int = 25):
    """Train and return (params, loss_log, eval_tokens)."""
    corpus = synth_corpus(20_000, seed)
    split = int(len(corpus) * 0.9)
    train_c, eval_c = corpus[:split], corpus[split:]
    params = model.init_params(jax.random.PRNGKey(seed))
    opt = adam_init(params)
    loss_grad = jax.jit(jax.value_and_grad(model.next_token_loss))
    log = []
    t0 = time.time()
    for step, toks in enumerate(batches(train_c, batch, seq, steps, seed + 1)):
        loss, grads = loss_grad(params, jnp.asarray(toks))
        params, opt = adam_step(params, opt, grads)
        if step % log_every == 0 or step == steps - 1:
            log.append((step, float(loss)))
            print(f"step {step:4d}  loss {float(loss):.4f}  "
                  f"({time.time() - t0:.1f}s)", flush=True)
    return params, log, eval_c[: model.EVAL_LEN * 40]


def flatten_params(params):
    """(name, array) pairs in the manifest order (tree_flatten order)."""
    names = [n for n, _ in model.param_manifest(params)]
    leaves = jax.tree_util.tree_flatten(params)[0]
    return list(zip(names, [np.asarray(x) for x in leaves]))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    params, log, eval_tokens = train(steps=args.steps, seed=args.seed)
    io.write_weights(f"{args.out}/weights.bin", flatten_params(params))
    io.write_tokens(f"{args.out}/eval_tokens.bin", eval_tokens)
    with open(f"{args.out}/train_log.txt", "w") as f:
        for step, loss in log:
            f.write(f"{step}\t{loss:.6f}\n")
    print(f"saved weights + eval tokens to {args.out}")


if __name__ == "__main__":
    main()
