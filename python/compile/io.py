"""Binary weight/token interchange between the build (Python) and serve
(Rust) layers.

``weights.bin`` format (little-endian):
  magic  b"SPX1"
  u32    tensor count
  per tensor:
    u16   name length, name bytes (utf-8)
    u8    ndim
    u32×n dims
    f32×∏ data (row-major)

``eval_tokens.bin``: magic b"SPT1", u32 count, u8×count token bytes.
"""

from __future__ import annotations

import struct

import numpy as np

WEIGHTS_MAGIC = b"SPX1"
TOKENS_MAGIC = b"SPT1"


def write_weights(path: str, named: list[tuple[str, np.ndarray]]) -> None:
    with open(path, "wb") as f:
        f.write(WEIGHTS_MAGIC)
        f.write(struct.pack("<I", len(named)))
        for name, arr in named:
            arr = np.ascontiguousarray(arr, dtype=np.float32)
            nb = name.encode()
            f.write(struct.pack("<H", len(nb)))
            f.write(nb)
            f.write(struct.pack("<B", arr.ndim))
            for d in arr.shape:
                f.write(struct.pack("<I", d))
            f.write(arr.tobytes())


def read_weights(path: str) -> list[tuple[str, np.ndarray]]:
    with open(path, "rb") as f:
        assert f.read(4) == WEIGHTS_MAGIC, "bad magic"
        (count,) = struct.unpack("<I", f.read(4))
        out = []
        for _ in range(count):
            (nlen,) = struct.unpack("<H", f.read(2))
            name = f.read(nlen).decode()
            (ndim,) = struct.unpack("<B", f.read(1))
            dims = struct.unpack(f"<{ndim}I", f.read(4 * ndim))
            n = int(np.prod(dims)) if ndim else 1
            data = np.frombuffer(f.read(4 * n), dtype="<f4").reshape(dims)
            out.append((name, data.copy()))
        return out


def write_tokens(path: str, tokens: np.ndarray) -> None:
    tokens = np.asarray(tokens, dtype=np.uint8)
    with open(path, "wb") as f:
        f.write(TOKENS_MAGIC)
        f.write(struct.pack("<I", tokens.size))
        f.write(tokens.tobytes())


def read_tokens(path: str) -> np.ndarray:
    with open(path, "rb") as f:
        assert f.read(4) == TOKENS_MAGIC, "bad magic"
        (count,) = struct.unpack("<I", f.read(4))
        return np.frombuffer(f.read(count), dtype=np.uint8).copy()
