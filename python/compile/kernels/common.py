"""Shared in-kernel decompression for the Pallas kernels.

The TPU re-think of the paper's AVX-512 decompression (DESIGN.md
§Hardware-Adaptation): the `vpexpandw` + `vpopcntd` + prefix-sum sequence
becomes a vectorized *bit-rank gather* —

1. per inner-dim row ``k``: ``counts[k] = popcount(mask[k])``
   (`vpopcntd`),
2. exclusive prefix-sum of ``counts`` → ``row_start`` (Algorithm 1),
3. per (row, column) lane: rank = popcount of the mask bits *below* the
   lane → ``vals[row_start + rank]`` (`vpexpandw`'s scatter, expressed as
   a gather so it vectorizes on the VPU),

producing the dense 16-column weight block in VMEM scratch that the MXU
then consumes — HBM only ever sees the compressed stream.
"""

from __future__ import annotations

import jax.numpy as jnp

COLS_PER_BLOCK = 16


def decompress_block(mask, vals, out_dtype):
    """Expand one column block.

    Args:
      mask: ``uint32[K]`` — 16-bit column bitmaps per inner-dim row.
      vals: ``[Vmax]`` packed non-zeros (k-major, column order).
      out_dtype: element type of the dense block.

    Returns:
      ``[K, 16]`` dense weight block.
    """
    k_dim = mask.shape[0]
    counts = jnp.bitwise_count(mask).astype(jnp.int32)  # vpopcntd
    row_start = jnp.cumsum(counts) - counts  # exclusive prefix sum
    lanes = jnp.arange(COLS_PER_BLOCK, dtype=jnp.uint32)
    below = (jnp.uint32(1) << lanes) - jnp.uint32(1)  # bits strictly below lane
    m = mask.reshape(k_dim, 1)
    bit = (m >> lanes) & jnp.uint32(1)  # [K, 16]
    rank = jnp.bitwise_count(m & below).astype(jnp.int32)  # [K, 16]
    idx = row_start.reshape(k_dim, 1) + rank
    gathered = jnp.take(vals, jnp.clip(idx, 0, vals.shape[0] - 1), axis=0)
    return jnp.where(bit == 1, gathered.astype(out_dtype), jnp.zeros((), out_dtype))


def decompress_all(mask, vals, out_dtype):
    """Expand every column block: ``mask[cb, K]``, ``vals[cb, Vmax]`` →
    dense ``[K, cb*16]`` (used by the fused attention kernel)."""
    import jax

    blocks = jax.vmap(lambda m, v: decompress_block(m, v, out_dtype))(mask, vals)
    # blocks: [cb, K, 16] → [K, cb*16]
    return blocks.transpose(1, 0, 2).reshape(mask.shape[1], -1)
