"""Layer-1 Pallas kernels (build-time only; lowered into the AOT HLO)."""

from . import packing, ref  # noqa: F401
from .dense_gemm import dense_gemm, dense_gemm_bf16  # noqa: F401
from .int8_gemm import int8_sparse_gemm  # noqa: F401
from .sparse_gemm import sparse_gemm  # noqa: F401
from .attention import sparse_kv_attention  # noqa: F401
