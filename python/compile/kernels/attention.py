"""Layer-1 Pallas kernel: decode attention over a sparse static KV cache
(§6.2), fused QKᵀ → softmax → R·V per kv-head.

The prefilled cache arrives compressed (bitmap + values for Kᵀ and V,
per kv-head); the dynamic tail (tokens generated since prefill) arrives
dense. One program per kv-head:

1. decompress Kᵀ ``[hd, ctx]`` and V ``[ctx, hd]`` into VMEM,
2. ``scores = q · Kᵀ / sqrt(hd)`` over [static ‖ dynamic] positions,
3. masked softmax (positions ≥ ``dyn_len`` in the tail are padding),
4. ``out = probs · V``.

GQA is folded in: the ``group`` query heads that share this kv-head are
the leading axis of ``q`` — no `repeat_kv` materialization (the §6.2
6×-faster cache-management claim).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import decompress_all


def _kernel(q_ref, kt_mask_ref, kt_vals_ref, v_mask_ref, v_vals_ref,
            k_dyn_ref, v_dyn_ref, dyn_len_ref, o_ref):
    q = q_ref[0]  # [group, hd]
    hd = q.shape[-1]
    kt_static = decompress_all(kt_mask_ref[0], kt_vals_ref[0], q.dtype)  # [hd, ctx_s]
    # V's packed columns are head_dim, padded to a multiple of 16 — slice back
    v_static = decompress_all(v_mask_ref[0], v_vals_ref[0], q.dtype)[:, :hd]
    ctx_s = kt_static.shape[1]
    k_dyn = k_dyn_ref[0]  # [max_dyn, hd]
    v_dyn = v_dyn_ref[0]
    dyn_len = dyn_len_ref[0]

    scale = 1.0 / jnp.sqrt(jnp.asarray(hd, q.dtype))
    s_static = jnp.dot(q, kt_static, preferred_element_type=jnp.float32)
    s_dyn = jnp.dot(q, k_dyn.T, preferred_element_type=jnp.float32)
    scores = jnp.concatenate([s_static, s_dyn], axis=1) * scale  # [group, ctx_s+max_dyn]
    pos = jnp.arange(scores.shape[1])
    valid = pos < (ctx_s + dyn_len)
    scores = jnp.where(valid, scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.dot(probs[:, :ctx_s], v_static, preferred_element_type=jnp.float32)
    out = out + jnp.dot(probs[:, ctx_s:], v_dyn, preferred_element_type=jnp.float32)
    o_ref[0] = out.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=())
def sparse_kv_attention(q, kt_mask, kt_vals, v_mask, v_vals, k_dyn, v_dyn, dyn_len):
    """Fused decode attention over the split cache.

    Args:
      q: ``f32[kv_heads, group, hd]`` — query heads grouped by kv-head.
      kt_mask/kt_vals: packed Kᵀ per head (``[kv_heads, cb_ctx, hd]`` /
        ``[kv_heads, cb_ctx, Vmax]``), ctx padded to a multiple of 16.
      v_mask/v_vals: packed V per head (``[kv_heads, cb_hd, ctx_s]`` /
        ``[kv_heads, cb_hd, Vmax2]``).
      k_dyn/v_dyn: dense dynamic tail ``f32[kv_heads, max_dyn, hd]``.
      dyn_len: ``int32[kv_heads]`` — live rows in the tail.

    Returns:
      ``f32[kv_heads, group, hd]`` attention outputs.
    """
    kv_heads, group, hd = q.shape
    cb_ctx, _ = kt_mask.shape[1:]
    # the kernel cannot mask Kᵀ column padding, so the static context
    # length must be exact (prefill lengths are multiples of 16)
    assert kt_mask.shape[2] == hd, "kt_mask must be [kv_heads, cb_ctx, hd]"
    assert cb_ctx * 16 == v_mask.shape[2], "static ctx must be a multiple of 16"
    max_dyn = k_dyn.shape[1]
    return pl.pallas_call(
        _kernel,
        grid=(kv_heads,),
        in_specs=[
            pl.BlockSpec((1, group, hd), lambda h: (h, 0, 0)),
            pl.BlockSpec((1,) + kt_mask.shape[1:], lambda h: (h, 0, 0)),
            pl.BlockSpec((1,) + kt_vals.shape[1:], lambda h: (h, 0, 0)),
            pl.BlockSpec((1,) + v_mask.shape[1:], lambda h: (h, 0, 0)),
            pl.BlockSpec((1,) + v_vals.shape[1:], lambda h: (h, 0, 0)),
            pl.BlockSpec((1, max_dyn, hd), lambda h: (h, 0, 0)),
            pl.BlockSpec((1, max_dyn, hd), lambda h: (h, 0, 0)),
            pl.BlockSpec((1,), lambda h: (h,)),
        ],
        out_specs=pl.BlockSpec((1, group, hd), lambda h: (h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((kv_heads, group, hd), q.dtype),
        interpret=True,
    )(q, kt_mask, kt_vals, v_mask, v_vals, k_dyn, v_dyn, dyn_len)
